"""C2P2SL on TPU pods: the paper's micro-batch pipeline as a 2-stage
pipeline over the ``pod`` mesh axis (DESIGN.md §3-4), demonstrated on
virtual devices.

    python examples/pipeline_pods.py      # (sets its own XLA_FLAGS)
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core.ao import lemma1_k  # noqa: F401  (k selection, see below)
from repro.data import lm_batch_for
from repro.models import LM, LMConfig
from repro.parallel.compat import make_mesh, mesh_context
from repro.parallel.pipeline import PipelineSpec, make_pipelined_loss
from repro.parallel.sharding import ShardingPolicy
from repro.training import adamw


def main():
    cfg = LMConfig(name="pipe-demo", num_layers=8, d_model=128, n_heads=8,
                   n_kv=4, d_ff=256, vocab=512, dtype="float32")
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    batch = lm_batch_for(cfg, 16, 64)

    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    print(f"mesh: {dict(mesh.shape)} over {mesh.size} devices")

    # stage split: UE-side = first L/2 layers on pod 0, BS-side on pod 1;
    # k chosen like the paper's Lemma 1 — here the link is fast, so a
    # moderate k=4 keeps the bubble small without shrinking micro-batches
    spec = PipelineSpec(num_stages=2, microbatches=4)
    loss_fn = make_pipelined_loss(model, spec, mesh=mesh)

    loss_plain, _ = model.forward(params, batch)
    with mesh_context(mesh):
        loss_pipe, _ = jax.jit(loss_fn)(params, batch)
    print(f"loss plain {float(loss_plain):.6f} == pipelined "
          f"{float(loss_pipe):.6f} "
          f"(diff {abs(float(loss_plain)-float(loss_pipe)):.2e})")

    # interleaved virtual stages: each pod holds v=2 round-robin chunks
    # of L/(S*v) layers, shrinking the pipeline bubble to (S-1)/v ticks
    # per direction at the same k — same math, same loss
    spec_v = PipelineSpec(num_stages=2, microbatches=4, virtual_stages=2)
    loss_fn_v = make_pipelined_loss(model, spec_v, mesh=mesh)
    with mesh_context(mesh):
        loss_inter, _ = jax.jit(loss_fn_v)(params, batch)
    print(f"loss interleaved (v=2) {float(loss_inter):.6f} "
          f"(diff {abs(float(loss_plain)-float(loss_inter)):.2e})")

    # a few pipelined training steps
    opt = adamw(1e-3)
    state = {"params": params, "opt_state": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    policy = ShardingPolicy(mesh, pod_is_pipeline=True)

    @jax.jit
    def train_step(state, batch):
        (loss, mets), grads = jax.value_and_grad(loss_fn,
                                                 has_aux=True)(
            state["params"], batch)
        new_p, new_o = opt.update(grads, state["opt_state"],
                                  state["params"], state["step"])
        return {"params": new_p, "opt_state": new_o,
                "step": state["step"] + 1}, loss

    with mesh_context(mesh):
        for i in range(5):
            state, loss = train_step(state, batch)
            print(f"pipelined step {i}: loss {float(loss):.4f}")
    print("OK — C2P2SL pipeline trains over the pod axis")


if __name__ == "__main__":
    main()
