"""Batched serving example: prefill + decode with the KV/recurrent-state
serve path (the decode_32k / long_500k dry-run shapes, laptop scale).

    PYTHONPATH=src python examples/serve_batch.py --arch rwkv6-3b
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main as serve_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-3b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()
    serve_main(["--arch", args.arch, "--size", "smoke",
                "--batch", str(args.batch), "--prompt-len", "16",
                "--gen", str(args.gen)])


if __name__ == "__main__":
    main()
