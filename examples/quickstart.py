"""Quickstart: end-to-end training of a small decoder LM with the framework.

    PYTHONPATH=src python examples/quickstart.py [--steps 200]

Uses the public API end to end: config -> model -> optimizer -> micro-batched
train step (the paper's k-micro-batch gradient accumulation) -> checkpointing
-> restart.  The synthetic affine-chain token task is learnable, so the loss
falls well below the 6.2-nat random floor within a couple hundred steps.
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.data import TokenTaskConfig, token_batches
from repro.models import LM, LMConfig
from repro.parallel.steps import make_lm_train_step
from repro.training import adamw, checkpoint, cosine_schedule


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=4,
                    help="the paper's k (gradient accumulation)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_quickstart")
    args = ap.parse_args()

    cfg = LMConfig(name="quickstart-20m", num_layers=4, d_model=256,
                   n_heads=8, n_kv=4, d_ff=1024, vocab=2048,
                   dtype="float32")
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"model: {cfg.name}  {n_params/1e6:.1f}M params")

    opt = adamw(cosine_schedule(3e-3, warmup=20, total=args.steps),
                grad_clip=1.0)
    state = {"params": params, "opt_state": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}

    # fault-tolerant restart: pick up the newest checkpoint if present
    last = checkpoint.latest_step(args.ckpt_dir)
    if last is not None:
        state = checkpoint.restore(args.ckpt_dir, last, state)
        print(f"resumed from checkpoint step {last}")

    step_fn = jax.jit(make_lm_train_step(model, opt,
                                         microbatches=args.microbatches))
    data = token_batches(TokenTaskConfig(vocab=cfg.vocab), args.batch,
                         args.seq, seed=0)

    t0 = time.perf_counter()
    first_loss = None
    for i in range(int(state["step"]), args.steps):
        state, mets = step_fn(state, next(data))
        if first_loss is None:
            first_loss = float(mets["loss"])
        if (i + 1) % 25 == 0:
            print(f"step {i+1:4d}  loss {float(mets['loss']):.4f}  "
                  f"({time.perf_counter()-t0:.1f}s)", flush=True)
        if (i + 1) % 100 == 0:
            checkpoint.save(args.ckpt_dir, i + 1, state)
            checkpoint.prune(args.ckpt_dir)

    final = float(mets["loss"])
    print(f"\nloss: {first_loss:.3f} -> {final:.3f} "
          f"(random floor ~{jnp.log(jnp.asarray(float(cfg.vocab))):.2f})")
    assert final < first_loss, "training did not reduce the loss"
    print("OK")


if __name__ == "__main__":
    main()
