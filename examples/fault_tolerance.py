"""Fault tolerance walkthrough: checkpoint -> crash -> elastic restart,
plus straggler detection feeding the paper's own batch re-allocation.

    PYTHONPATH=src python examples/fault_tolerance.py
"""
import os
import shutil
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import TokenTaskConfig, token_batches
from repro.models import LM, LMConfig
from repro.parallel.steps import make_lm_train_step
from repro.training import adamw, checkpoint
from repro.training.fault import Watchdog, plan_rescale, rebalance_batches

CKPT = "/tmp/repro_fault_demo"


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    cfg = LMConfig(name="fault-demo", num_layers=2, d_model=128, n_heads=4,
                   n_kv=2, d_ff=256, vocab=512, dtype="float32")
    model = LM(cfg)
    opt = adamw(1e-3)
    params = model.init(jax.random.key(0))
    state = {"params": params, "opt_state": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    step = jax.jit(make_lm_train_step(model, opt))
    data = token_batches(TokenTaskConfig(vocab=cfg.vocab), 8, 32, seed=0)

    # --- phase 1: train, checkpoint every 5 steps, then "crash" ---
    for i in range(12):
        state, mets = step(state, next(data))
        if (i + 1) % 5 == 0:
            checkpoint.save(CKPT, i + 1, state)
            checkpoint.prune(CKPT)
    print(f"crashed at step 12, loss {float(mets['loss']):.4f}; "
          f"newest checkpoint: step {checkpoint.latest_step(CKPT)}")

    # --- phase 2: elastic restart after losing a pod ---
    old_mesh = {"pod": 2, "data": 16, "model": 16}
    new_mesh = plan_rescale(old_mesh, lost_pods=1)
    print(f"mesh after pod loss: {old_mesh} -> {new_mesh}")
    fresh = {"params": model.init(jax.random.key(99)),   # NOT the old values
             "opt_state": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    state = checkpoint.restore(CKPT, checkpoint.latest_step(CKPT), fresh)
    print(f"restored at step {int(state['step'])}; resuming")
    for i in range(int(state["step"]), 15):
        state, mets = step(state, next(data))
    print(f"step 15 reached, loss {float(mets['loss']):.4f}")

    # --- phase 3: straggler detection -> batch re-allocation ---
    wd = Watchdog(4, timeout_s=60.0)
    for w, t in enumerate([1.0, 1.05, 0.95, 3.2]):    # worker 3 straggles
        wd.heartbeat(w, step_time=t)
    stragglers = wd.stragglers(factor=1.5)
    b = rebalance_batches(wd.throughputs(), 128, multiple=4)
    print(f"stragglers: {stragglers}; re-balanced batch split: {b.tolist()}"
          f"  (the paper's P3 allocation applied to datacenter stragglers)")
    shutil.rmtree(CKPT, ignore_errors=True)
    print("OK")


if __name__ == "__main__":
    main()
