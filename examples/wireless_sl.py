"""The paper's scenario end to end: heterogeneous UEs + BS over a TDMA
cellular channel, joint (l, k, b, tau) optimization, then REAL C2P2SL split
training of ResNet-18 vs the PSL baseline.

    PYTHONPATH=src python examples/wireless_sl.py [--steps 60] [--ues 8]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core import algorithm1, resnet18_profile
from repro.core.schedule import Plan, simulate_c2p2sl, simulate_psl, task_times
from repro.data import image_batches
from repro.models import resnet
from repro.sl import (init_sl_state, make_c2p2sl_step, make_psl_step,
                      resnet_split, shard_batch)
from repro.training import sgd
from repro.wireless import sample_fleet


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--ues", type=int, default=8)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    prof = resnet18_profile()
    fleet = sample_fleet(args.ues, seed=args.seed)
    r_u, r_d = fleet.rates()
    print(f"fleet: {args.ues} UEs, uplink {r_u.min()/1e6:.0f}-"
          f"{r_u.max()/1e6:.0f} Mb/s, clocks "
          f"{fleet.ue_flops.min()/16e9:.2f}-{fleet.ue_flops.max()/16e9:.2f} "
          f"Gcycle/s")

    # --- Algorithm 1: joint split & allocation ---
    res = algorithm1(prof, fleet, batch=512)
    plan = res.plan
    print(f"AO plan: cut l={plan.l} ({prof.layer_names[plan.l-1]}), "
          f"k={plan.k} micro-batches, bubble rate {res.bubble:.3f}")
    print(f"  batch split: {plan.b.astype(int).tolist()}")

    t = task_times(prof, fleet, plan)
    ms_c2p2, _ = simulate_c2p2sl(t, plan.k)
    uni = Plan(l=plan.l, k=1, b=np.full(args.ues, 512 / args.ues),
               tau=np.full(args.ues, fleet.channel.frame_s / args.ues))
    ms_psl = simulate_psl(task_times(prof, fleet, uni))
    print(f"simulated batch time: C2P2SL {ms_c2p2:.3f}s vs PSL {ms_psl:.3f}s "
          f"(-{100*(1-ms_c2p2/ms_psl):.1f}%)")

    # --- real split training on the synthetic CIFAR-10 stand-in ---
    spec = resnet_split(plan.l)
    opt = sgd(0.05, momentum=0.9)
    params = resnet.init_resnet18(jax.random.key(args.seed))
    # scale the AO batch split to the demo batch, as multiples of k so
    # C2P2SL (micro-batched) and PSL see IDENTICAL samples (the paper's
    # equivalence requires equal effective batches)
    b_prop = np.maximum(1, np.round(
        plan.b / plan.b.sum() * args.batch)).astype(int)
    k = 1
    for cand in (8, 4, 2):
        if args.batch % cand == 0 and cand <= min(plan.k, b_prop.min()):
            k = cand
            break
    b_alloc = np.maximum(k, (b_prop // k) * k)
    while b_alloc.sum() > args.batch:
        b_alloc[np.argmax(b_alloc)] -= k
    while b_alloc.sum() < args.batch:
        b_alloc[np.argmin(b_alloc)] += k

    for name, maker, kk, per_batch in (
            ("C2P2SL", lambda: make_c2p2sl_step(spec, opt, k=k), k, ms_c2p2),
            ("PSL", lambda: make_psl_step(spec, opt), 1, ms_psl)):
        state = init_sl_state(spec, params, opt)
        tree = {"ue_params": state.ue_params, "bs_params": state.bs_params,
                "opt_state_ue": state.opt_state_ue,
                "opt_state_bs": state.opt_state_bs, "step": state.step}
        step = jax.jit(maker())
        gen = image_batches(args.batch, seed=args.seed)
        for i in range(args.steps):
            bt = next(gen)
            xs, ys = shard_batch(bt["images"], bt["labels"], b_alloc, kk)
            tree, mets = step(tree, xs, ys)
        merged = spec.merge_params(tree["ue_params"], tree["bs_params"])
        test = next(image_batches(256, seed=4242))
        acc = float((resnet.forward(merged, test["images"]).argmax(-1)
                     == test["labels"]).mean())
        print(f"{name:7s}: acc {acc:.3f} after {args.steps} rounds "
              f"~ {args.steps * per_batch:.0f}s simulated wall time")
    print("(per-step updates are identical to ~1e-7 — "
          "tests/test_equivalence.py; short-run accuracies drift by fp "
          "trajectory divergence, converging to parity as in Fig 3)")


if __name__ == "__main__":
    main()
