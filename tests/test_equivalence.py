"""The paper's equivalence claim (SII-C last paragraph): micro-batched
C2P2SL training with gradient accumulation produces the SAME update as
full-batch PSL — tested for the actual split trainer and for the generic
micro-batch substrate, per model family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import image_batches, lm_batch_for
from repro.models import LM, LMConfig, resnet
from repro.sl import (init_sl_state, make_c2p2sl_step, make_epsl_step,
                      make_psl_step, resnet_split, shard_batch)
from repro.training import adamw, sgd
from repro.training.microbatch import microbatched_value_and_grad

TOL = 2e-4


def tree_close(a, b, tol=TOL):
    d = jax.tree.map(lambda x, y: float(jnp.max(jnp.abs(x - y))), a, b)
    worst = max(jax.tree.leaves(d))
    assert worst < tol, f"max deviation {worst}"


def _sl_tree(state):
    return {"ue_params": state.ue_params, "bs_params": state.bs_params,
            "opt_state_ue": state.opt_state_ue,
            "opt_state_bs": state.opt_state_bs, "step": state.step}


def test_c2p2sl_equals_psl_update():
    """k=4 C2P2SL step == k=1 PSL step on the same ResNet batch."""
    params = resnet.init_resnet18(jax.random.key(0))
    spec = resnet_split(2)
    opt = adamw(1e-3)
    batch = next(image_batches(48, seed=0))
    b_alloc = np.array([16, 16, 16])

    out = []
    for k, maker in [(4, make_c2p2sl_step), (1, make_psl_step)]:
        tree = _sl_tree(init_sl_state(spec, params, opt))
        xs, ys = shard_batch(batch["images"], batch["labels"], b_alloc, k)
        step = maker(spec, opt, k) if maker is make_c2p2sl_step \
            else maker(spec, opt)
        tree, mets = jax.jit(step)(tree, xs, ys)
        out.append(tree)
    tree_close(out[0]["ue_params"], out[1]["ue_params"])
    tree_close(out[0]["bs_params"], out[1]["bs_params"])


def test_c2p2sl_equals_psl_unequal_allocation():
    """Equivalence also holds for heterogeneous b_i (the AO allocation).

    SGD (linear in the gradients) so the comparison reflects gradient
    equality; Adam's rsqrt at step 1 amplifies 1e-7 fp noise 10^4-fold."""
    params = resnet.init_resnet18(jax.random.key(0))
    spec = resnet_split(1)
    opt = sgd(0.05, momentum=0.9)
    batch = next(image_batches(64, seed=0))
    b_alloc = np.array([16, 8, 8, 8, 8, 8, 4, 4])

    out = []
    for k in (4, 1):
        tree = _sl_tree(init_sl_state(spec, params, opt))
        xs, ys = shard_batch(batch["images"], batch["labels"], b_alloc, k)
        step = make_c2p2sl_step(spec, opt, k)
        tree, _ = jax.jit(step)(tree, xs, ys)
        out.append(tree)
    tree_close(out[0]["ue_params"], out[1]["ue_params"])
    tree_close(out[0]["bs_params"], out[1]["bs_params"])


def test_epsl_differs():
    """EPSL's gradient aggregation is an approximation — it must NOT match
    the exact update (the accuracy cost in paper Fig 3)."""
    params = resnet.init_resnet18(jax.random.key(0))
    spec = resnet_split(2)
    opt = adamw(1e-3)
    batch = next(image_batches(48, seed=0))
    b_alloc = np.array([16, 16, 16])

    tree_c = _sl_tree(init_sl_state(spec, params, opt))
    tree_e = _sl_tree(init_sl_state(spec, params, opt))
    xs, ys = shard_batch(batch["images"], batch["labels"], b_alloc, 1)
    tree_c, _ = jax.jit(make_psl_step(spec, opt))(tree_c, xs, ys)
    tree_e, _ = jax.jit(make_epsl_step(spec, opt))(tree_e, xs, ys)
    d = jax.tree.map(lambda x, y: float(jnp.max(jnp.abs(x - y))),
                     tree_c["ue_params"], tree_e["ue_params"])
    assert max(jax.tree.leaves(d)) > 1e-6


FAMILY_CONFIGS = {
    "dense": LMConfig(name="t-dense", num_layers=2, d_model=32, n_heads=4,
                      n_kv=2, d_ff=64, vocab=64, dtype="float32"),
    # moe_capacity >= E/topk makes the dispatch provably drop-free
    # (capacity = ceil(T*topk*f/E) >= T bounds every expert's load), so
    # micro-batching is exactly equivalent; with the default 1.25 the
    # capacity-dropped token SETS differ between k=1 and k=4 dispatch
    # granularities and grads deviate by ~4e-2 (diagnosed: unbounded
    # capacity agrees to 7e-9) — that documented deviation is a capacity
    # property, not an accumulation one, and isn't what this test asserts.
    "moe": LMConfig(name="t-moe", num_layers=2, d_model=32, n_heads=4,
                    n_kv=2, d_ff=32, vocab=64, moe_experts=4, moe_topk=2,
                    moe_capacity=2.0, dtype="float32"),
    "hybrid": LMConfig(name="t-hyb", num_layers=3, d_model=32, n_heads=4,
                       n_kv=1, d_ff=64, vocab=64, window=8,
                       pattern=("rglru", "rglru", "local"), lru_width=32,
                       dtype="float32"),
    "ssm": LMConfig(name="t-rwkv", num_layers=2, d_model=32, n_heads=2,
                    n_kv=2, d_ff=64, vocab=64, pattern=("rwkv",) * 2,
                    rwkv_head_dim=16, rwkv_lora=8, dtype="float32"),
}


@pytest.mark.parametrize("family", sorted(FAMILY_CONFIGS))
def test_microbatch_grad_equivalence(family):
    """Accumulated micro-batch grads == full-batch grads per family.

    (MoE uses per-micro-batch router statistics for the aux loss — the known
    PP x MoE interaction, DESIGN.md §6 — so only xent participates there.)
    """
    cfg = FAMILY_CONFIGS[family]
    model = LM(cfg)
    params = model.init(jax.random.key(1))
    batch = lm_batch_for(cfg, 8, 16, seed=2)

    def loss_fn(p, b):
        loss, mets = model.forward(p, b)
        if family == "moe":
            return mets["xent"], mets
        return loss, mets

    vg1 = microbatched_value_and_grad(loss_fn, 1)
    vg4 = microbatched_value_and_grad(loss_fn, 4)
    (l1, _), g1 = jax.jit(vg1)(params, batch)
    (l4, _), g4 = jax.jit(vg4)(params, batch)
    # every family is exact here; moe runs drop-free (see FAMILY_CONFIGS)
    assert abs(float(l1) - float(l4)) < 1e-4
    tree_close(g1, g4, tol=1e-3)


def test_sgd_and_adam_updates_shapes():
    cfg = FAMILY_CONFIGS["dense"]
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    batch = lm_batch_for(cfg, 4, 8)
    for opt in (adamw(1e-3, weight_decay=0.1, grad_clip=1.0), sgd(0.1)):
        st = opt.init(params)
        g = jax.grad(lambda p: model.forward(p, batch)[0])(params)
        new_p, new_st = opt.update(g, st, params, jnp.int32(0))
        assert jax.tree.structure(new_p) == jax.tree.structure(params)
        moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                             new_p, params)
        assert max(jax.tree.leaves(moved)) > 0
