"""Greedy-divisible sharding policy invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch, param_specs
from repro.parallel.compat import abstract_mesh
from repro.parallel.sharding import ShardingPolicy, bytes_per_device

# an abstract 2x16x16 mesh — no devices needed for spec math
MESH = abstract_mesh((2, 16, 16), ("pod", "data", "model"))
SP = ShardingPolicy(MESH)
SP_PIPE = ShardingPolicy(MESH, pod_is_pipeline=True)


def axes_of(spec):
    out = []
    for e in spec:
        if e is None:
            continue
        out.extend(e if isinstance(e, tuple) else (e,))
    return out


@settings(deadline=None, max_examples=60)
@given(shape=st.lists(st.sampled_from([1, 2, 3, 5, 8, 16, 20, 24, 40, 96,
                                       128, 512, 2560, 49155, 151936]),
                      min_size=0, max_size=4))
def test_param_spec_always_divisible(shape):
    """Property: every assigned axis divides its dim; no axis repeats."""
    spec = SP.param_spec(tuple(shape))
    sizes = {"pod": 2, "data": 16, "model": 16}
    seen = []
    for dim, entry in zip(shape, tuple(spec)):
        if entry is None:
            continue
        for ax in (entry if isinstance(entry, tuple) else (entry,)):
            assert dim % sizes[ax] == 0, (shape, spec)
            seen.append(ax)
    assert len(seen) == len(set(seen))


def test_embed_vocab_parallel():
    """embed/head leaves get vocab over model (the 188 GiB lesson)."""
    spec = SP.param_spec((256_000, 2560), name="embed")
    assert tuple(spec)[0] == "model"
    spec = SP.param_spec((2560, 256_000), name="head")
    assert tuple(spec)[1] == "model"
    # indivisible vocab (granite): model falls back to d_model
    spec = SP.param_spec((49_155, 1536), name="embed")
    assert tuple(spec)[0] is None and tuple(spec)[1] == "model"


def test_cache_spec_finds_batch_dim():
    # stacked KV cache [L, B, S, H, dh]
    spec = SP.cache_spec((32, 128, 32768, 8, 128), batch=128)
    entries = tuple(spec)
    assert entries[1] == ("pod", "data")
    assert "model" in entries      # sequence dim sharded
    # batch=1 (long_500k): nothing shards on batch
    spec = SP.cache_spec((32, 1, 524288, 1, 256), batch=1)
    assert tuple(spec)[2] == "model"


def test_batch_spec_fallbacks():
    assert tuple(SP.batch_spec((256, 4096)))[0] == ("pod", "data")
    assert tuple(SP.batch_spec((16, 4096)))[0] == "data"   # 16 < 32
    assert tuple(SP.batch_spec((1, 1)))[0] is None


def test_pipeline_policy_blocks_over_pod():
    p = param_specs(get_arch("qwen1.5-4b").smoke)
    sh = SP_PIPE.param_shardings(p)
    blk = jax.tree.leaves(sh["blocks"])[0]
    assert tuple(blk.spec)[0] == "pod"
    # non-block params never use pod in pipeline mode
    assert "pod" not in axes_of(sh["embed"].spec)


def test_bytes_per_device():
    tree = {"w": jax.ShapeDtypeStruct((256, 512), jnp.float32)}
    sp = ShardingPolicy(abstract_mesh((16, 16), ("data", "model")))
    n = bytes_per_device(tree, sp)
    # greedy: model->512 (trailing), data->256: fully sharded 256-way
    assert n == 256 * 512 * 4 // 256


def test_hbm_feasibility_check():
    from repro.parallel.sharding import hbm_feasible
    small = {"w": jax.ShapeDtypeStruct((1024, 1024), jnp.float32)}
    sp = ShardingPolicy(abstract_mesh((16, 16), ("data", "model")))
    assert hbm_feasible(small, sp)


@pytest.mark.parametrize("arch", ["command-r-plus-104b", "qwen3-moe-30b-a3b"])
def test_full_state_fits_hbm(arch):
    """C2 on TPU: fp32 master + adam moments sharded on the single-pod mesh
    stay under the 16 GiB/chip budget for the largest assigned archs."""
    from repro.training.optim import adamw
    cfg = get_arch(arch).full
    p = param_specs(cfg)
    opt_s = jax.eval_shape(adamw(1e-4).init, p)
    sp = ShardingPolicy(abstract_mesh((16, 16), ("data", "model")))
    state = {"params": p, "opt_state": opt_s}
    per_dev = bytes_per_device(state, sp)
    assert per_dev < 8 * 1024**3, f"{arch}: {per_dev/2**30:.1f} GiB"
