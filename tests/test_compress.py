"""Gradient compression with error feedback (repro.training.compress)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.training.compress import (compress_grads, decompress_grads,
                                     dequantize, init_error_fb, quantize)


def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((1000,)), jnp.float32)
    q, s, shp = quantize(g)
    deq = dequantize(q, s, shp)
    # per-block max error <= scale/2 = max|block|/254
    assert float(jnp.max(jnp.abs(deq - g))) <= float(jnp.max(jnp.abs(g))) / 254 + 1e-7
    assert q.dtype == jnp.int8


@settings(deadline=None, max_examples=10)
@given(n=st.integers(1, 2000), seed=st.integers(0, 100))
def test_quantize_shapes_property(n, seed):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal((n,)), jnp.float32)
    q, s, shp = quantize(g)
    deq = dequantize(q, s, shp)
    assert deq.shape == g.shape
    assert float(jnp.max(jnp.abs(deq - g))) <= \
        float(jnp.max(jnp.abs(g))) / 200 + 1e-6


def test_error_feedback_invariant():
    """EF invariant: transmitted + new_error == grad + old_error exactly."""
    rng = np.random.default_rng(1)
    grads = {"a": jnp.asarray(rng.standard_normal((300,)), jnp.float32),
             "b": jnp.asarray(rng.standard_normal((4, 7)), jnp.float32)}
    efb = init_error_fb(grads)
    efb = jax.tree.map(lambda e: e + 0.01, efb)     # non-trivial carry
    qtree, new_efb = compress_grads(grads, efb)
    sent = decompress_grads(qtree)
    lhs = jax.tree.map(lambda s, e: s + e, sent, new_efb)
    rhs = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e, grads, efb)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), lhs, rhs)
    assert max(jax.tree.leaves(d)) < 1e-5


def test_error_feedback_preserves_convergence_direction():
    """Accumulated EF-compressed grads track the true gradient sum."""
    rng = np.random.default_rng(2)
    true_sum = jnp.zeros((500,))
    sent_sum = jnp.zeros((500,))
    efb = {"g": jnp.zeros((500,), jnp.float32)}
    for i in range(20):
        g = jnp.asarray(rng.standard_normal((500,)) * 0.1, jnp.float32)
        true_sum = true_sum + g
        qtree, efb_new = compress_grads({"g": g}, efb)
        sent_sum = sent_sum + decompress_grads(qtree)["g"]
        efb = efb_new
    # residual = current error carry, bounded (doesn't accumulate)
    resid = float(jnp.max(jnp.abs(true_sum - sent_sum)))
    assert resid == pytest.approx(float(jnp.max(jnp.abs(efb["g"]))),
                                  abs=1e-5)
    assert resid < 0.05
