"""Pipeline invariant auditor (repro.analysis.staticcheck) and its AST
lint pack.

Fast lane: the numpy-only detectors against seeded defects (each must
produce EXACTLY ONE violation of the right class), the mirror-sync
contracts pinning the auditor's numpy copies to the jax-side sources of
truth, the seeded corpus, the jaxpr-level audit of the live lowering,
and the report diff.  Slow lane: the compiled-HLO audit (8-device
subprocess) against the committed green baseline, and the fixture
regeneration helper."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.analysis import staticcheck
from repro.analysis.lint import RULES, lint_paths, lint_source

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
ROOT = os.path.join(os.path.dirname(__file__), "..")
CORPUS = os.path.join(os.path.dirname(__file__), "fixtures",
                      "staticcheck_corpus")


def run_sub(code: str, devices: int = 8, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def only(violations, cls):
    """Assert exactly one violation, of class ``cls``, and return it."""
    assert len(violations) == 1, [(v.cls, v.detail) for v in violations]
    assert violations[0].cls == cls, violations[0]
    return violations[0]


# ---------------------------------------------------------------------------
# mirror-sync contracts: the auditor's numpy copies == jax-side truth
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("s,v", [(1, 1), (2, 1), (2, 2), (4, 1), (4, 3)])
def test_expected_hop_perms_mirrors_pipeline(s, v):
    from repro.parallel.pipeline import PipelineSpec, hop_perms
    spec = PipelineSpec(num_stages=s, microbatches=s + 1, virtual_stages=v)
    assert hop_perms(spec) == staticcheck.expected_hop_perms(s, v)


def test_payload_hlo_dtype_mirrors_kernel_layer():
    from repro.kernels.wire_codec import PAYLOAD_HLO_DTYPE
    assert staticcheck.PAYLOAD_HLO_DTYPE == PAYLOAD_HLO_DTYPE


def test_hop_perms_shapes():
    fwd, bwd = staticcheck.expected_hop_perms(4, 1)
    assert fwd == ((0, 1), (1, 2), (2, 3)) and bwd[0] == (1, 0)
    fwd, bwd = staticcheck.expected_hop_perms(4, 2)
    assert (3, 0) in fwd and (0, 3) in bwd
    assert staticcheck.expected_hop_perms(1, 1) == ((), ())


# ---------------------------------------------------------------------------
# detector negatives: one seeded defect -> exactly one classified violation
# ---------------------------------------------------------------------------


def test_perm_bijection_detector():
    assert staticcheck.check_perm_bijection(((0, 1), (1, 0)), 2) == []
    only(staticcheck.check_perm_bijection(((0, 1), (1, 1)), 2),
         "ppermute-bijection")        # destination collision
    only(staticcheck.check_perm_bijection(((0, 1), (0, 2)), 4),
         "ppermute-bijection")        # duplicate source
    only(staticcheck.check_perm_bijection(((0, 5),), 4),
         "ppermute-bijection")        # endpoint off the axis


def test_perm_schedule_detector():
    assert staticcheck.check_perm_schedule(((0, 1), (1, 2)), 3, 1) == []
    assert staticcheck.check_perm_schedule(((1, 0), (2, 1)), 3, 1) == []
    cyc = ((0, 1), (1, 2), (2, 0))
    assert staticcheck.check_perm_schedule(cyc, 3, 2) == []
    # bijective but not the schedule's hop: v=1 must NOT wrap
    only(staticcheck.check_perm_schedule(cyc, 3, 1), "ppermute-schedule")


def test_payload_classifier_forged_f32():
    c = staticcheck.hop_contract("int8", "float32", 64)
    assert staticcheck.classify_hop_payload(c, "s8", (1, 16, 1, 64)) == []
    assert staticcheck.classify_hop_payload(c, "f32", (1, 16, 1, 1)) == []
    only(staticcheck.classify_hop_payload(c, "f32", (1, 16, 64)),
         "wire-payload-dtype")


def test_payload_classifier_index_dtype():
    c = staticcheck.hop_contract("int8+topk0.25", "float32", 64)
    assert c["idx_hlo"] == "s16" and c["kk"] == 16
    assert staticcheck.classify_hop_payload(c, "s16", (1, 16, 16)) == []
    only(staticcheck.classify_hop_payload(c, "s32", (1, 16, 16)),
         "wire-index-dtype")
    dense = staticcheck.hop_contract("int8", "float32", 64)
    only(staticcheck.classify_hop_payload(dense, "s16", (1, 16, 16)),
         "wire-index-dtype")          # indices on a dense hop


def test_payload_classifier_net_loss_fallback():
    # d=3 -> block 3 -> 1+4/3 > f16's 2 bytes: raw f16 is the declared
    # fallback, not a forgery
    c = staticcheck.hop_contract("int8", "float16", 3)
    assert c["net_loss"]
    assert staticcheck.classify_hop_payload(c, "f16", (4, 3)) == []


def test_byte_model_green_and_single_perturbation():
    assert staticcheck.audit_byte_model(act_bytes=4.0, d_model=2560) == []
    assert staticcheck.audit_byte_model(act_bytes=4.0, d_model=64) == []
    only(staticcheck.check_byte_model("int8", "fwd", payload_bytes=2.0),
         "wire-bytes-model")
    only(staticcheck.check_byte_model("int8+topk0.25", "bwd",
                                      d_model=2560, index_bytes=3.0),
         "wire-bytes-model")
    only(staticcheck.check_byte_model("fp8", "bwd", scale_bytes=5.0),
         "wire-bytes-model")


def test_record_honesty_roundtrip_and_planner_drift(monkeypatch):
    with open(os.path.join(ROOT, "tests", "fixtures",
                           "roofline_smoke.json")) as f:
        record = json.load(f)
    violations, stats = staticcheck.audit_record_honesty(record)
    assert violations == []
    assert stats["rebilled_pp_bytes"] == pytest.approx(
        stats["measured_pp_bytes"], rel=1e-9)
    assert stats["ticks0"] == staticcheck.expected_schedule_ticks(
        record["pipeline_k"], stats["num_stages"], stats["v0"])
    # simulate planner schedule-math drift (an off-by-one in the billed
    # tick count): the independent mirror must catch it
    from repro.analysis import autotune
    real = autotune.schedule_ticks
    monkeypatch.setattr(autotune, "schedule_ticks",
                        lambda k, s, v: real(k, s, v) + 1)
    violations, _ = staticcheck.audit_record_honesty(record)
    assert [v.cls for v in violations] == ["wire-bytes"]


# ---------------------------------------------------------------------------
# seeded HLO corpus
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fname,cls,checks,wire", [
    ("hlo_forged_f32_hop.txt", "wire-payload-dtype", ("payload",), "int8"),
    ("hlo_sharding_leak.txt", "sharding-leak", ("leak",), "none"),
    ("hlo_nonbijective.txt", "ppermute-bijection", ("perm",), "none"),
])
def test_seeded_hlo_corpus(fname, cls, checks, wire):
    with open(os.path.join(CORPUS, fname)) as f:
        text = f.read()
    violations, _ = staticcheck.audit_hlo_text(
        text, pod_size=4, num_stages=2, virtual_stages=1,
        wire_dtype=wire, d_model=64, checks=checks)
    only(violations, cls)


def test_hlo_byte_honesty_detects_missing_direction():
    """The forged-hop fixture carries only ONE f32 hop per tick; billing
    both directions of a 'none' wire over 1024 elements expects 8192 B
    but the text ships 4096 — the bytes check must fire (and reconcile
    when the expectation matches what is actually on the wire)."""
    with open(os.path.join(CORPUS, "hlo_forged_f32_hop.txt")) as f:
        text = f.read()
    violations, stats = staticcheck.audit_hlo_text(
        text, pod_size=4, num_stages=2, virtual_stages=1,
        wire_dtype="none", d_model=64, hop_elems=1024, checks=("bytes",))
    assert stats["hop_bytes_per_tick"] == 4096
    only(violations, "wire-bytes")
    violations, _ = staticcheck.audit_hlo_text(
        text, pod_size=4, num_stages=2, virtual_stages=1,
        wire_dtype="none", d_model=64, hop_elems=512, checks=("bytes",))
    assert violations == []


def test_within_pod_permute_is_a_reshard_not_a_hop():
    with open(os.path.join(CORPUS, "hlo_forged_f32_hop.txt")) as f:
        text = f.read()
    # shrink pods to 8 devices/pod: every pair is now within-pod -> no
    # hop CPs at all, nothing to audit
    violations, stats = staticcheck.audit_hlo_text(
        text, pod_size=8, num_stages=1, virtual_stages=1,
        wire_dtype="int8", d_model=64)
    assert stats["n_hop_cp"] == 0 and stats["n_local_cp"] == 1
    assert violations == []


# ---------------------------------------------------------------------------
# custom_vjp residual contract
# ---------------------------------------------------------------------------


def test_wire_custom_vjp_contracts_green():
    for wire in ("int8", "fp8", "int8+topk0.25"):
        assert staticcheck.audit_wire_custom_vjp(wire) == []


def test_broken_vjp_pair_fires():
    import jax
    import jax.numpy as jnp

    def bad_fwd(x):
        return x, jax.ShapeDtypeStruct(x.shape, "float32")

    def bad_bwd(res, g):
        return (g, jnp.zeros(res.shape, "bfloat16"))
    violations = staticcheck.audit_custom_vjp_pair(
        bad_fwd, bad_bwd, (jax.ShapeDtypeStruct((2, 8), "float32"),))
    only(violations, "vjp-residual-dtype")


# ---------------------------------------------------------------------------
# lint pack
# ---------------------------------------------------------------------------


def test_lint_corpus_fires_every_rule():
    violations = lint_paths([os.path.join(CORPUS, "lint_bad.py")])
    assert sorted({v.rule for v in violations}) == sorted(RULES)


def test_lint_real_tree_is_clean():
    assert lint_paths([os.path.join(SRC, "repro")]) == []


def test_lint_static_branches_not_flagged():
    src = """
import jax.numpy as jnp

def _tick_loop(spec, ef_t, v):
    if ef_t is not None:          # `is` test: exempt even on a tracer
        ef_t = ef_t + 1.0
    if v > 1:                     # parameter, never tainted
        v = v - 1
    y = jnp.ones((4,))
    if y.shape[0] > 2:            # static metadata projection
        v = v + 1
    return ef_t, v
"""
    assert lint_source(src) == []


def test_lint_tracer_branch_and_concretize_flagged():
    src = """
import numpy as np
import jax.numpy as jnp

def _tick_loop(x):
    y = jnp.sum(x)
    if y > 0:
        y = y + 1
    return np.asarray(y)
"""
    violations = lint_source(src)
    assert [v.rule for v in violations] == ["tracer-branch",
                                            "tracer-concretize"]


def test_lint_reachability_scopes_tracer_rules():
    # same defect in an unreachable function: tracer rules stay quiet
    src = """
import jax.numpy as jnp

def helper(x):
    y = jnp.sum(x)
    if y > 0:
        y = y + 1
    return y
"""
    assert lint_source(src) == []


# ---------------------------------------------------------------------------
# jaxpr-level audit of the live lowering + selftest + report diff
# ---------------------------------------------------------------------------


def test_jaxpr_audit_matrix_green():
    """Both hop directions x all four wire grammars x v in {1,2}, traced
    through the abstract mesh on THIS interpreter's shard_map lowering:
    zero violations, and every cell actually saw both hop directions."""
    violations, cells = staticcheck.audit_cells(level="jaxpr")
    assert violations == []
    keys = {c["cell"] for c in cells}
    for wire in staticcheck.AUDIT_WIRES:
        for v in staticcheck.AUDIT_VS:
            assert f"{wire}/v{v}" in keys
    for c in cells:
        if not c["cell"].startswith("vjp:"):
            assert set(c["stats"]["directions"]) == {"fwd", "bwd"}, c


def test_selftest_every_detector_fires():
    fired = staticcheck.selftest()
    assert len(fired) == 10


def test_diff_report():
    rep = {"ok": True, "by_class": {}, "cells": ["a", "b"]}
    assert staticcheck.diff_report(dict(rep), dict(rep)) == []
    tampered = {"ok": False, "by_class": {"wire-bytes": 1},
                "cells": ["a"]}
    fails = staticcheck.diff_report(tampered, rep)
    assert len(fails) == 3


def test_violation_class_is_closed():
    with pytest.raises(ValueError):
        staticcheck.Violation("not-a-class", "x", "y")


# ---------------------------------------------------------------------------
# slow lane: compiled-HLO audit + CLI + regen helper (8-device subprocess)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_full_cli_matches_committed_baseline():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)     # the CLI must set the device flag itself
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis.staticcheck",
         "--level", "full", "--report", "/tmp/staticcheck_ci.json",
         "--diff", os.path.join(ROOT, "benchmarks",
                                "STATICCHECK_baseline.json")],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=1200)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    with open("/tmp/staticcheck_ci.json") as f:
        report = json.load(f)
    assert report["ok"] and report["violations"] == []
    assert any(c.startswith("hlo:") for c in report["cells"])


@pytest.mark.slow
def test_hlo_audit_bytes_reconcile_in_process():
    out = run_sub("""
        from repro.analysis.staticcheck import audit_cells
        violations, cells = audit_cells(level='hlo',
                                        wires=('int8', 'int8+topk0.25'),
                                        vs=(1,))
        assert not violations, [(v.cls, v.detail) for v in violations]
        for c in cells:
            st = c['stats']
            if 'hop_bytes_per_tick' in st:
                assert st['hop_bytes_per_tick'] == st['billed_bytes_per_tick']
                print(c['cell'], st['hop_bytes_per_tick'])
    """)
    assert "int8/v1 2176" in out and "int8+topk0.25/v1 1920" in out


@pytest.mark.slow
def test_regen_helper_validates_this_leg():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    out = subprocess.run(
        [sys.executable,
         os.path.join(ROOT, "tests", "fixtures", "regen_hlo_fixtures.py"),
         "--check"],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "validates" in out.stdout
