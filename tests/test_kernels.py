"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (hypothesis) +
directed cases.  All kernels run in interpret mode on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_gmm import moe_gmm
from repro.kernels.ref import (attention_ref, moe_gmm_ref, rg_lru_ref,
                               wkv6_ref)
from repro.kernels.rglru import rglru_scan
from repro.kernels.rwkv6 import wkv6
from repro.models.recurrent import rg_lru_scan_chunked
from repro.models.rwkv import wkv6_chunked

RNG = np.random.default_rng(42)


def randn(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


# ---------------------------------------------------------------- flash

@settings(deadline=None, max_examples=12)
@given(
    b=st.integers(1, 3),
    hkv=st.sampled_from([1, 2]),
    rep=st.sampled_from([1, 2, 3]),
    s_blocks=st.integers(1, 3),
    dh=st.sampled_from([64, 128]),
    causal=st.booleans(),
)
def test_flash_attention_sweep(b, hkv, rep, s_blocks, dh, causal):
    s = 128 * s_blocks
    q = randn((b, hkv * rep, s, dh))
    k = randn((b, hkv, s, dh))
    v = randn((b, hkv, s, dh))
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_window():
    q = randn((1, 2, 256, 64))
    k = randn((1, 2, 256, 64))
    v = randn((1, 2, 256, 64))
    out = flash_attention(q, k, v, causal=True, window=64, interpret=True)
    ref = attention_ref(q, k, v, causal=True, window=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    q = randn((1, 2, 128, 64), jnp.bfloat16)
    k = randn((1, 2, 128, 64), jnp.bfloat16)
    v = randn((1, 2, 128, 64), jnp.bfloat16)
    out = flash_attention(q, k, v, interpret=True)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------- rglru

@settings(deadline=None, max_examples=10)
@given(
    b=st.integers(1, 3),
    s_chunks=st.integers(1, 3),
    r_blocks=st.integers(1, 2),
    with_h0=st.booleans(),
)
def test_rglru_sweep(b, s_chunks, r_blocks, with_h0):
    s, r = 256 * s_chunks, 128 * r_blocks
    x = randn((b, s, r))
    la = jnp.asarray(-np.exp(RNG.uniform(-5, 0, (b, s, r))), jnp.float32)
    h0 = randn((b, r), scale=0.2) if with_h0 else None
    h, last = rglru_scan(x, la, h0, interpret=True)
    h_ref, last_ref = rg_lru_ref(x, la, h0)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(last), np.asarray(last_ref),
                               rtol=2e-4, atol=2e-4)


def test_rglru_chunked_jnp_matches_ref():
    """The model's chunked scan (the kernel's oracle) matches sequential."""
    x = randn((2, 300, 64))
    la = jnp.asarray(-np.exp(RNG.uniform(-4, 0, (2, 300, 64))), jnp.float32)
    h, last = rg_lru_scan_chunked(x, la, chunk=128)
    h_ref, last_ref = rg_lru_ref(x, la)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- wkv6

@settings(deadline=None, max_examples=8)
@given(
    b=st.integers(1, 2),
    h=st.integers(1, 3),
    s_chunks=st.integers(1, 3),
    dh=st.sampled_from([32, 64]),
    strong_decay=st.booleans(),
)
def test_wkv6_sweep(b, h, s_chunks, dh, strong_decay):
    s = 64 * s_chunks
    r = randn((b, s, h, dh))
    k = randn((b, s, h, dh))
    v = randn((b, s, h, dh))
    lo = -3.0 if strong_decay else -6.0
    w = jnp.asarray(np.exp(-np.exp(RNG.uniform(lo, 0.5, (b, s, h, dh)))),
                    jnp.float32)
    u = randn((h, dh), scale=0.2)
    s0 = randn((b, h, dh, dh), scale=0.1)
    out, fin = wkv6(r, k, v, w, u, s0, interpret=True)
    out_ref, fin_ref = wkv6_ref(r, k, v, w, u, s0=s0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(fin_ref),
                               rtol=3e-4, atol=3e-4)


def test_wkv6_chunked_jnp_grads_match_scan():
    """The chunked formulation is the training path: grads must match the
    step-by-step recurrence."""
    b, s, h, dh = 1, 96, 2, 16
    r = randn((b, s, h, dh))
    k = randn((b, s, h, dh))
    v = randn((b, s, h, dh))
    w = jnp.asarray(np.exp(-np.exp(RNG.uniform(-3, 0.5, (b, s, h, dh)))),
                    jnp.float32)
    u = randn((h, dh), scale=0.2)
    from repro.models.rwkv import wkv6_scan
    g1 = jax.grad(lambda r: wkv6_scan(r, k, v, w, u)[0].sum())(r)
    g2 = jax.grad(lambda r: wkv6_chunked(r, k, v, w, u, chunk=32)[0].sum())(r)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------- moe gmm

@settings(deadline=None, max_examples=8)
@given(
    e=st.integers(1, 4),
    c_blocks=st.integers(1, 2),
    d=st.sampled_from([256, 512]),
    f=st.sampled_from([128, 256]),
)
def test_moe_gmm_sweep(e, c_blocks, d, f):
    c = 128 * c_blocks
    h = randn((e, c, d), scale=0.5)
    w = randn((e, d, f), scale=0.05)
    out = moe_gmm(h, w, interpret=True)
    ref = moe_gmm_ref(h, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_moe_gmm_bf16():
    h = randn((2, 128, 256), jnp.bfloat16)
    w = randn((2, 256, 128), jnp.bfloat16, scale=0.1)
    out = moe_gmm(h, w, interpret=True)
    ref = moe_gmm_ref(h, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_ops_wrappers_jit():
    """The public jit'd wrappers compile and run."""
    q = randn((1, 2, 128, 64))
    o = ops.flash_attention(q, q, q)
    assert o.shape == q.shape
    x = randn((1, 256, 128))
    la = -jnp.abs(randn((1, 256, 128))) - 0.01
    h, last = ops.rglru_scan(x, la)
    assert h.shape == x.shape and last.shape == (1, 128)
