"""Checkpointing: atomic commit, corruption fallback, pruning, restore."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training import checkpoint as ck


def make_tree(seed=0):
    k = jax.random.key(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 16)),
                   "blocks": (jnp.arange(4.0), jnp.ones((2, 3)))},
        "step": jnp.int32(7),
    }


def test_roundtrip(tmp_path):
    tree = make_tree()
    ck.save(str(tmp_path), 10, tree)
    assert ck.latest_step(str(tmp_path)) == 10
    restored = ck.restore(str(tmp_path), 10, jax.tree.map(jnp.zeros_like,
                                                          tree))
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     tree, restored)
    assert max(jax.tree.leaves(d)) == 0.0


def test_atomic_commit_no_tmp_visible(tmp_path):
    ck.save(str(tmp_path), 3, make_tree())
    names = os.listdir(tmp_path)
    assert "step_3" in names
    assert not any(n.endswith(".tmp") for n in names)


def test_corrupt_checkpoint_skipped(tmp_path):
    ck.save(str(tmp_path), 1, make_tree())
    ck.save(str(tmp_path), 2, make_tree())
    # corrupt the newest manifest: restart must fall back to step 1
    with open(tmp_path / "step_2" / "manifest.json", "w") as f:
        f.write("{not json")
    assert ck.latest_step(str(tmp_path)) == 1


def test_mid_save_crash_invisible(tmp_path):
    """A directory without atomic rename (simulated crash) is ignored."""
    ck.save(str(tmp_path), 1, make_tree())
    os.makedirs(tmp_path / "step_5.tmp")
    (tmp_path / "step_5.tmp" / "proc_0.npz").write_bytes(b"partial")
    assert ck.latest_step(str(tmp_path)) == 1


def test_prune_keeps_newest(tmp_path):
    for s in (1, 2, 3, 4, 5):
        ck.save(str(tmp_path), s, make_tree())
    ck.prune(str(tmp_path), keep=2)
    left = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert left == ["step_4", "step_5"]


def test_restore_shape_mismatch_raises(tmp_path):
    ck.save(str(tmp_path), 1, make_tree())
    bad = make_tree()
    bad["params"]["w"] = jnp.zeros((4, 4))
    with pytest.raises(ValueError, match="shape"):
        ck.restore(str(tmp_path), 1, bad)


def test_restore_with_shardings_resharding(tmp_path):
    """Elastic restore: checkpoint taken unsharded restores onto an explicit
    (single-device) sharding tree — the N->M mesh path exercised at the
    device counts this container has."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.parallel.compat import make_mesh
    tree = make_tree()
    ck.save(str(tmp_path), 1, tree)
    mesh = make_mesh((1,), ("data",))
    shardings = jax.tree.map(
        lambda x: NamedSharding(mesh, P()), tree)
    restored = ck.restore(str(tmp_path), 1, tree, shardings)
    assert restored["params"]["w"].sharding == NamedSharding(mesh, P())


def test_dtype_cast_on_restore(tmp_path):
    tree = {"w": jnp.ones((4,), jnp.float32)}
    ck.save(str(tmp_path), 1, tree)
    target = {"w": jnp.zeros((4,), jnp.bfloat16)}
    restored = ck.restore(str(tmp_path), 1, target)
    assert restored["w"].dtype == jnp.bfloat16
