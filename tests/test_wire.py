"""Quantized wire codec for the pipeline hop (repro.parallel.wire) and its
launcher/benchmark plumbing.

Fast lane: codec round-trip bounds, block selection, probe fitting, bench
diffing.  Slow lane (multi-device subprocess, like test_pipeline.py):
wire_dtype='none' bit-equality with the uncoded pipeline across S/v/ragged
k, quantized-pipeline closeness, convergence parity, and the ppermute
probe end-to-end."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.parallel import wire

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
ROOT = os.path.join(os.path.dirname(__file__), "..")


def run_sub(code: str, devices: int = 8, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------------------
# Codec round-trip (fast).
# ---------------------------------------------------------------------------


def test_wire_block_selection():
    """Largest divisor <= 256 of d_model; never padded."""
    assert wire.wire_block(4096) == 256
    assert wire.wire_block(256) == 256
    assert wire.wire_block(96) == 96
    assert wire.wire_block(32) == 32
    assert wire.wire_block(384) == 192          # 384 % 256 != 0
    assert wire.wire_block(257) == 1            # prime > 256
    for d in (8, 96, 256, 384, 4096):
        assert d % wire.wire_block(d) == 0


def test_int8_roundtrip_error_bound():
    """Per-block max error <= scale/2 = blockmax/254."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 7, 256)) * 3.0, jnp.float32)
    y = wire.roundtrip(x, "int8")
    assert y.dtype == x.dtype
    blockmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    bound = blockmax / 254.0 + 1e-7
    assert bool(jnp.all(jnp.abs(y - x) <= bound))


def test_fp8_roundtrip_error_bound():
    """fp8-e4m3 carries 3 mantissa bits: relative step 2^-3 per element
    after the block scale maps the max to 448 (well inside normals)."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((3, 5, 128)), jnp.float32)
    y = wire.roundtrip(x, "fp8")
    assert y.dtype == x.dtype
    # elementwise: |err| <= |x| / 16 (round-to-nearest of 3-bit mantissa)
    # + a tiny absolute term for values far below the block max
    blockmax = np.asarray(jnp.max(jnp.abs(x), axis=-1, keepdims=True))
    err = np.abs(np.asarray(y) - np.asarray(x))
    assert np.all(err <= np.abs(np.asarray(x)) / 16.0
                  + blockmax / 256.0 + 1e-7)


def test_roundtrip_zeros_and_payload_dtypes():
    z = jnp.zeros((2, 3, 64), jnp.bfloat16)
    assert float(jnp.max(jnp.abs(wire.roundtrip(z, "int8")))) == 0.0
    q, s = wire.encode(z, "int8")
    assert q.dtype == jnp.int8 and s.dtype == jnp.float32
    assert q.shape == (2, 3, 1, 64) and s.shape == (2, 3, 1, 1)
    q8, _ = wire.encode(z.astype(jnp.float32), "fp8")
    assert q8.dtype == jnp.float8_e4m3fn
    # decode restores the original trailing dim and requested dtype
    y = wire.decode(q, s, jnp.bfloat16)
    assert y.shape == (2, 3, 64) and y.dtype == jnp.bfloat16


def test_validate_wire_dtype():
    assert wire.validate_wire_dtype(None) == "none"
    assert wire.validate_wire_dtype(" INT8 ") == "int8"
    with pytest.raises(ValueError, match="wire_dtype"):
        wire.validate_wire_dtype("int4")


def test_coded_ppermute_vjp_quantizes_cotangent():
    """The custom_vjp backward rule codes the cotangent: under a 1-device
    identity permutation the forward IS roundtrip(x) and the pullback of
    g IS roundtrip(g) — the straight-through wire transpose, not g."""
    from repro.parallel import compat
    from repro.parallel.compat import PartitionSpec as P

    mesh = compat.make_mesh((1,), ("pod",))
    fn = compat.shard_map(
        lambda x: wire.coded_ppermute("int8", "pod", ((0, 0),), x),
        mesh, in_specs=(P(),), out_specs=P(), check=False)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((2, 64)), jnp.float32)
    gbar = jnp.asarray(rng.standard_normal((2, 64)), jnp.float32)
    y, vjp = jax.vjp(fn, x)
    (gx,) = vjp(gbar)
    assert np.array_equal(np.asarray(y),
                          np.asarray(wire.roundtrip(x, "int8")))
    assert np.array_equal(np.asarray(gx),
                          np.asarray(wire.roundtrip(gbar, "int8")))
    assert not np.array_equal(np.asarray(gx), np.asarray(gbar))


def test_pipeline_spec_normalizes_wire_at_construction():
    """Sloppy spellings must not slip past the coded-vs-raw branch: the
    spec normalizes at construction, so ' INT8 ' codes the hop and
    'NONE' takes the raw-ppermute branch."""
    from repro.parallel.pipeline import PipelineSpec

    assert PipelineSpec(wire_dtype=" INT8 ").wire_dtype == "int8"
    assert PipelineSpec(wire_dtype="NONE").wire_dtype == "none"
    assert PipelineSpec(wire_dtype=None).wire_dtype == "none"


def test_dryrun_skip_done_key_includes_all_knobs():
    """--skip-done identity must cover every compile-changing knob: a
    codec (or interleave) re-run of an already-lowered cell is NOT done.
    Records predating a knob read as its default."""
    from repro.launch.dryrun import cell_key

    base = cell_key("a", "s", "16x16", 8, 1, "none")
    # legacy record without the new fields == new run at the defaults
    assert cell_key("a", "s", "16x16", 8, None, None) == base
    assert cell_key("a", "s", "16x16", 8, 1, "int8") != base
    assert cell_key("a", "s", "16x16", 8, 2, "none") != base


def test_pipeline_spec_validates_wire():
    from repro.models import LM, LMConfig
    from repro.parallel.compat import make_mesh
    from repro.parallel.pipeline import PipelineSpec, make_pipelined_loss
    from repro.data import lm_batch_for

    cfg = LMConfig(name="t", num_layers=2, d_model=32, n_heads=4, n_kv=2,
                   d_ff=64, vocab=128, dtype="float32")
    m = LM(cfg)
    p = m.init(jax.random.key(0))
    batch = lm_batch_for(cfg, 4, 8)
    mesh = make_mesh((1,), ("pod",))
    spec = PipelineSpec(num_stages=1, microbatches=2, wire_dtype="int4")
    loss_fn = make_pipelined_loss(m, spec, mesh=mesh)
    with pytest.raises(ValueError, match="wire_dtype"):
        loss_fn(p, batch)


def test_s1_pipeline_ignores_codec():
    """S=1 has no ppermute, so every codec is a no-op there — the coded
    spec must reproduce the uncoded loss exactly."""
    from repro.data import lm_batch_for
    from repro.models import LM, LMConfig
    from repro.parallel.compat import make_mesh, mesh_context
    from repro.parallel.pipeline import PipelineSpec, make_pipelined_loss

    cfg = LMConfig(name="t", num_layers=2, d_model=32, n_heads=4, n_kv=2,
                   d_ff=64, vocab=128, dtype="float32")
    m = LM(cfg)
    p = m.init(jax.random.key(0))
    batch = lm_batch_for(cfg, 4, 8)
    mesh = make_mesh((1,), ("pod",))
    losses = {}
    for w in ("none", "int8"):
        spec = PipelineSpec(num_stages=1, microbatches=2, wire_dtype=w)
        with mesh_context(mesh):
            losses[w] = float(jax.jit(
                make_pipelined_loss(m, spec, mesh=mesh))(p, batch)[0])
    assert losses["none"] == losses["int8"]


# ---------------------------------------------------------------------------
# ppermute probe fitting + bench diff (fast).
# ---------------------------------------------------------------------------


def test_probe_fit_recovers_overhead_and_bw():
    sys.path.insert(0, ROOT)
    try:
        from benchmarks.ppermute_probe import fit_overhead
    finally:
        sys.path.remove(ROOT)
    bw, ovh = 2.5e9, 40e-6
    pts = [(b, ovh + b / bw) for b in (1e5, 1e6, 5e6, 2e7)]
    fit_ovh, fit_bw = fit_overhead(pts)
    assert fit_ovh == pytest.approx(ovh, rel=1e-6)
    assert fit_bw == pytest.approx(bw, rel=1e-6)
    # negative intercepts clamp to zero instead of going nonsensical
    fit_ovh, _ = fit_overhead([(b, b / bw) for b in (1e5, 1e6, 1e7)])
    assert fit_ovh >= 0.0
    with pytest.raises(ValueError, match="two"):
        fit_overhead([(1e6, 1e-3)])


def test_bench_diff_rows():
    sys.path.insert(0, ROOT)
    try:
        from benchmarks.run import diff_rows
    finally:
        sys.path.remove(ROOT)
    base = [{"name": "pipeline_plan",
             "result": {"chosen_wire": "int8", "wall": 1.0,
                        "by": {"a": [1, 2]}}},
            {"name": "only_in_base", "result": {"x": 1}}]
    good = [{"name": "pipeline_plan",
             "result": {"chosen_wire": "int8", "wall": 1.0 + 1e-9,
                        "by": {"a": [1, 2]}}}]
    assert diff_rows(base, good) == []
    bad = [{"name": "pipeline_plan",
            "result": {"chosen_wire": "fp8", "wall": 1.5,
                       "by": {"a": [1]}}}]
    fails = diff_rows(base, bad)
    assert len(fails) == 3
    assert any("chosen_wire" in f for f in fails)


def test_bench_diff_no_overlap_fails_loudly(tmp_path):
    """A drift gate that matched nothing must FAIL, not pass vacuously
    (renamed bench / --only drift would otherwise disarm the CI check)."""
    sys.path.insert(0, ROOT)
    try:
        from benchmarks.run import main as run_main
    finally:
        sys.path.remove(ROOT)
    baseline = tmp_path / "base.json"
    baseline.write_text(json.dumps(
        {"rows": [{"name": "renamed_bench", "result": {"x": 1}}]}))
    with pytest.raises(SystemExit) as exc:
        run_main(["--only", "pipeline_plan", "--diff", str(baseline)])
    assert exc.value.code == 1


def test_committed_bench_baseline_matches_current_planner():
    """The checked-in benchmarks/BENCH_pipeline.json must stay in sync
    with the live planner — the same guarantee the CI diff job enforces,
    asserted in tier-1 so a planner change cannot land without
    regenerating the baseline."""
    sys.path.insert(0, ROOT)
    try:
        from benchmarks.pipeline_plan import main as bench_main
        from benchmarks.run import diff_rows
    finally:
        sys.path.remove(ROOT)
    baseline_path = os.path.join(ROOT, "benchmarks", "BENCH_pipeline.json")
    with open(baseline_path) as f:
        base = json.load(f)
    result = json.loads(json.dumps(
        bench_main(quick=True),
        default=lambda o: o.tolist() if hasattr(o, "tolist") else str(o)))
    fails = diff_rows(base["rows"],
                      [{"name": "pipeline_plan", "result": result}])
    assert fails == [], fails
    assert result["link_shrink_int8"] >= 3.5
    assert result["link_shrink_fp8"] >= 1.9


# ---------------------------------------------------------------------------
# Multi-device subprocess lane (slow).
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_wire_none_bit_identical_across_s_v_ragged_k():
    """wire_dtype='none' must be BIT-identical to the uncoded (PR-4)
    pipeline — same loss, same grads, max|diff| == 0 exactly — across
    stage counts, interleave depths and ragged k."""
    out = run_sub("""
        import jax, json
        import jax.numpy as jnp
        from repro.models import LM, LMConfig
        from repro.data import lm_batch_for
        from repro.parallel.compat import make_mesh, mesh_context
        from repro.parallel.pipeline import PipelineSpec, make_pipelined_loss

        cfg = LMConfig(name='t', num_layers=8, d_model=32, n_heads=4, n_kv=2,
                       d_ff=64, vocab=128, dtype='float32')
        m = LM(cfg)
        p = m.init(jax.random.key(1))
        batch = lm_batch_for(cfg, 10, 16)
        results = {}
        for (S, v, k, dshape) in [(2, 1, 5, (2, 2, 2)),
                                  (2, 2, 4, (2, 2, 2)),
                                  (4, 2, 8, (4, 2, 1))]:
            mesh = make_mesh(dshape, ("pod", "data", "model"))
            outs = {}
            for w in ("none", "explicit-default"):
                if w == "none":
                    spec = PipelineSpec(num_stages=S, microbatches=k,
                                        virtual_stages=v, wire_dtype="none")
                else:
                    spec = PipelineSpec(num_stages=S, microbatches=k,
                                        virtual_stages=v)
                loss_fn = make_pipelined_loss(m, spec, mesh=mesh)
                with mesh_context(mesh):
                    loss, _ = jax.jit(loss_fn)(p, batch)
                    g = jax.jit(jax.grad(lambda p: loss_fn(p, batch)[0]))(p)
                outs[w] = (float(loss), g)
            la, ga = outs["none"]
            lb, gb = outs["explicit-default"]
            gd = max(jax.tree.leaves(jax.tree.map(
                lambda a, b: float(jnp.max(jnp.abs(a - b))), ga, gb)))
            results[f"S{S}v{v}k{k}"] = {"dl": la - lb, "gd": gd}
        print(json.dumps(results))
    """, devices=8)
    res = json.loads(out.strip().splitlines()[-1])
    for cell, r in res.items():
        assert r["dl"] == 0.0, cell
        assert r["gd"] == 0.0, cell


@pytest.mark.slow
@pytest.mark.parametrize("wdt", ["int8", "fp8"])
def test_quantized_pipeline_close_to_reference(wdt):
    """int8/fp8 wire: the loss tracks the unpipelined reference closely
    (block-quantization noise only) while the gradients provably went
    through the codec (non-zero deviation)."""
    out = run_sub(f"""
        import jax, json
        import jax.numpy as jnp
        from repro.models import LM, LMConfig
        from repro.data import lm_batch_for
        from repro.parallel.compat import make_mesh, mesh_context
        from repro.parallel.pipeline import PipelineSpec, make_pipelined_loss

        cfg = LMConfig(name='t', num_layers=8, d_model=32, n_heads=4, n_kv=2,
                       d_ff=64, vocab=128, dtype='float32')
        m = LM(cfg)
        p = m.init(jax.random.key(1))
        batch = lm_batch_for(cfg, 8, 16)
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        loss_ref, _ = m.forward(p, batch)
        g_ref = jax.grad(lambda p: m.forward(p, batch)[0])(p)
        spec = PipelineSpec(num_stages=2, microbatches=4, virtual_stages=2,
                            wire_dtype="{wdt}")
        loss_fn = make_pipelined_loss(m, spec, mesh=mesh)
        with mesh_context(mesh):
            loss_q, _ = jax.jit(loss_fn)(p, batch)
            g_q = jax.jit(jax.grad(lambda p: loss_fn(p, batch)[0]))(p)
        rel = jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(a - b))
                               / (jnp.max(jnp.abs(b)) + 1e-8)), g_q, g_ref)
        print(json.dumps({{"loss_ref": float(loss_ref),
                           "loss_q": float(loss_q),
                           "max_rel_gdiff": max(jax.tree.leaves(rel))}}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert abs(res["loss_q"] - res["loss_ref"]) < 5e-3 \
        * max(1.0, abs(res["loss_ref"]))
    assert 0.0 < res["max_rel_gdiff"] < 0.25


@pytest.mark.slow
def test_quantized_wire_convergence_parity():
    """30 adamw steps through the 2-stage pipeline: int8 and fp8 wire
    land within a whisker of the uncoded loss trajectory (the acceptance
    bar for shipping a lossy wire)."""
    out = run_sub("""
        import jax, json
        import jax.numpy as jnp
        from repro.data import TokenTaskConfig, token_batches
        from repro.models import LM, LMConfig
        from repro.parallel.compat import make_mesh, mesh_context
        from repro.parallel.pipeline import PipelineSpec, make_pipelined_loss
        from repro.parallel.steps import make_lm_train_step
        from repro.training.optim import adamw

        cfg = LMConfig(name='t', num_layers=4, d_model=32, n_heads=4, n_kv=2,
                       d_ff=64, vocab=128, dtype='float32')
        m = LM(cfg)
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        finals = {}
        for w in ("none", "int8", "fp8"):
            opt = adamw(1e-2)
            params = m.init(jax.random.key(0))
            state = {"params": params, "opt_state": opt.init(params),
                     "step": jnp.zeros((), jnp.int32)}
            spec = PipelineSpec(num_stages=2, microbatches=4, wire_dtype=w)
            step = jax.jit(make_lm_train_step(m, opt, pipeline=spec,
                                              mesh=mesh))
            it = token_batches(TokenTaskConfig(vocab=cfg.vocab), 8, 16,
                               seed=3)
            with mesh_context(mesh):
                first = None
                for _ in range(30):
                    state, mets = step(state, next(it))
                    if first is None:
                        first = float(mets["loss"])
            finals[w] = {"first": first, "final": float(mets["loss"])}
        print(json.dumps(finals))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    ref = res["none"]
    assert ref["final"] < ref["first"] - 0.5          # training actually moves
    for w in ("int8", "fp8"):
        assert res[w]["final"] < res[w]["first"] - 0.5, w
        assert abs(res[w]["final"] - ref["final"]) < 0.05 \
            * max(1.0, abs(ref["final"])), (w, res)


@pytest.mark.slow
def test_ppermute_probe_end_to_end(tmp_path):
    """The probe runs on forced host devices, emits planner_hints, and
    plan_inputs_from_record consumes them (hop_overhead_s + link bw)."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = SRC
    out_path = tmp_path / "probe.json"
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.ppermute_probe",
         "--sizes-kib", "64,512,2048", "--repeats", "3",
         "--out", str(out_path)],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=300)
    assert proc.returncode == 0, proc.stderr[-3000:]
    doc = json.loads(out_path.read_text())
    hints = doc["planner_hints"]
    assert hints["hop_overhead_s"] >= 0.0
    assert hints["link_bw_Bps"] > 0.0
    assert len(doc["points_bytes_seconds"]) == 3

    from repro.analysis.autotune import plan_inputs_from_record
    fixture = os.path.join(os.path.dirname(__file__), "fixtures",
                           "roofline_smoke.json")
    with open(fixture) as f:
        record = json.load(f)
    inp = plan_inputs_from_record(record, extra_hints=hints)
    assert inp.hop_overhead_s == pytest.approx(hints["hop_overhead_s"])
