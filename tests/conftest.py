import os
import sys

# Tests run single-device (the dry-run owns the 512-device XLA flag).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

# Offline fallback: when hypothesis isn't installed, serve the deterministic
# replay stub so the property-test modules still collect and run (see
# tests/_hypothesis_stub.py for the exact semantics).
try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies
