"""Per-architecture smoke tests: reduced same-family config, one forward /
train step on CPU, output shapes + finite values (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.data import lm_batch_for
from repro.models import LM
from repro.parallel.steps import (init_serve_state, make_decode_step,
                                  make_lm_train_step)
from repro.training import adamw

ARCH_NAMES = sorted(ARCHS)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_forward_and_train_step(name):
    spec = get_arch(name)
    cfg = spec.smoke
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    batch = lm_batch_for(cfg, 4, 16, seed=1)

    loss, mets = jax.jit(model.forward)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{name}: non-finite loss"

    opt = adamw(1e-3)
    state = {"params": params, "opt_state": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    step = jax.jit(make_lm_train_step(model, opt, microbatches=2))
    state, mets = step(state, batch)
    assert int(state["step"]) == 1
    for leaf in jax.tree.leaves(state["params"]):
        assert bool(jnp.all(jnp.isfinite(leaf))), f"{name}: NaN params"
    assert bool(jnp.isfinite(mets["loss"]))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_decode_step(name):
    spec = get_arch(name)
    cfg = spec.smoke
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    serve = init_serve_state(model, 2, 8, cache_dtype=jnp.float32)
    if cfg.enc_layers:
        frames = jnp.zeros((2, cfg.enc_seq, cfg.d_model), jnp.float32)
        enc_out = model._encode(params, frames)
        serve["cache"] = model.fill_cross_kv(params, enc_out, serve["cache"])
    decode = jax.jit(make_decode_step(model))
    tok = jnp.zeros((2, 1), jnp.int32)
    for _ in range(3):
        logits, serve = decode(params, serve, tok)
        tok = jnp.argmax(logits, -1, keepdims=True).astype(jnp.int32)
    assert logits.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{name}: NaN logits"
    assert int(serve["position"]) == 3


@pytest.mark.parametrize("name", ["qwen1.5-4b", "rwkv6-3b",
                                  "recurrentgemma-2b", "starcoder2-3b"])
def test_decode_matches_forward(name):
    """Teacher-forced decode must reproduce the training-forward logits —
    the KV-cache / recurrent-state bookkeeping is exactly consistent."""
    cfg = get_arch(name).smoke
    model = LM(cfg)
    params = model.init(jax.random.key(3))
    rng = np.random.default_rng(0)
    seq = 12
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, seq)), jnp.int32)

    h = model.hidden(params, {"tokens": tokens})
    from repro.models.common import apply_norm  # final logits by hand
    dt = h.dtype
    logits_fwd = (h[:, -1] @ model._head_w(params, dt))[:, :cfg.vocab]

    serve = init_serve_state(model, 2, seq + 1, cache_dtype=jnp.float32)
    decode = jax.jit(make_decode_step(model))
    logits = None
    for t in range(seq):
        logits, serve = decode(params, serve, tokens[:, t:t + 1])
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(logits_fwd, np.float32),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("name", ["qwen1.5-4b", "rwkv6-3b",
                                  "recurrentgemma-2b", "whisper-small"])
def test_chunked_prefill_matches_token_loop(name):
    """prefill_with_cache (one forward pass filling the cache) == feeding
    the prompt through decode_step token by token — including G continued
    decode steps from both states."""
    cfg = get_arch(name).smoke
    model = LM(cfg)
    params = model.init(jax.random.key(3))
    from repro.data import lm_batch_for
    S, G = 10, 4
    batch = lm_batch_for(cfg, 2, S + G, seed=7)
    prompt = {k: (v[:, :S] if k in ("tokens", "labels") else v)
              for k, v in batch.items() if k != "labels"}
    cache_len = S + G

    logits_a, serve_a = model.prefill_with_cache(
        params, prompt, cache_len, cache_dtype=jnp.float32)

    serve_b = init_serve_state(model, 2, cache_len, cache_dtype=jnp.float32)
    if cfg.family == "audio":
        enc_out = model._encode(params,
                                jnp.asarray(prompt["frames"], jnp.float32))
        serve_b["cache"] = model.fill_cross_kv(params, enc_out,
                                               serve_b["cache"])
    decode = jax.jit(make_decode_step(model))
    toks = jnp.asarray(prompt["tokens"], jnp.int32)
    logits_b = None
    for t in range(S):
        logits_b, serve_b = decode(params, serve_b, toks[:, t:t + 1])
    np.testing.assert_allclose(np.asarray(logits_a), np.asarray(logits_b),
                               rtol=3e-3, atol=3e-3)
    la, lb = logits_a, logits_b
    for _ in range(G):
        tok = jnp.argmax(la, -1, keepdims=True).astype(jnp.int32)
        la, serve_a = decode(params, serve_a, tok)
        lb, serve_b = decode(params, serve_b, tok)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                               rtol=3e-3, atol=3e-3)


def test_whisper_cross_kv_cache_equivalence():
    """Prefill-cached cross-attention K/V == per-step recompute
    (the whisper decode optimization, EXPERIMENTS.md §Perf bonus)."""
    cfg = get_arch("whisper-small").smoke
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    frames = jnp.asarray(rng.standard_normal((2, cfg.enc_seq, cfg.d_model)),
                         jnp.float32)
    enc_out = model._encode(params, frames)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 5)), jnp.int32)

    serve = init_serve_state(model, 2, 8, cache_dtype=jnp.float32)
    serve["cache"] = model.fill_cross_kv(params, enc_out, serve["cache"])
    decode = jax.jit(make_decode_step(model))
    la = None
    for t in range(5):
        la, serve = decode(params, serve, toks[:, t:t + 1])

    cache_b = model.init_cache(2, 8, jnp.float32)
    cache_b = {k: v for k, v in cache_b.items() if k not in ("ck", "cv")}
    lb = None
    for t in range(5):
        lb, cache_b = model.decode_step(params, toks[:, t:t + 1], cache_b,
                                        t, enc_out=enc_out)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                               rtol=2e-4, atol=2e-4)


def test_full_configs_match_assignment():
    """The exact published numbers from the assignment table."""
    expect = {
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256_000),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151_936),
        "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256_000),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49_152),
        "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92_416),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49_155),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151_936),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257_216),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65_536),
        "whisper-small": (12, 768, 12, 12, 3072, 51_865),
    }
    for name, (L, d, h, kv, f, v) in expect.items():
        cfg = get_arch(name).full
        assert (cfg.num_layers, cfg.d_model, cfg.n_heads, cfg.n_kv,
                cfg.d_ff, cfg.vocab) == (L, d, h, kv, f, v), name
    # MoE expert counts / top-k
    assert get_arch("granite-moe-3b-a800m").full.moe_experts == 40
    assert get_arch("granite-moe-3b-a800m").full.moe_topk == 8
    assert get_arch("qwen3-moe-30b-a3b").full.moe_experts == 128
    assert get_arch("qwen3-moe-30b-a3b").full.moe_topk == 8


def test_shape_skips_documented():
    """8 long_500k cells skip with a reason; ssm/hybrid run it."""
    skips = [a for a in ARCH_NAMES
             if get_arch(a).skip_reason("long_500k") is not None]
    runs = [a for a in ARCH_NAMES
            if get_arch(a).skip_reason("long_500k") is None]
    assert sorted(runs) == ["recurrentgemma-2b", "rwkv6-3b"]
    assert len(skips) == 8
    for a in skips:
        assert len(get_arch(a).skip_reason("long_500k")) > 10


def test_resnet_paper_model():
    params = resnet_init = None
    from repro.models import resnet
    params = resnet.init_resnet18(jax.random.key(0))
    x = jnp.zeros((2, 32, 32, 3), jnp.float32)
    logits = resnet.forward(params, x)
    assert logits.shape == (2, 10)
    loss, mets = resnet.loss_fn(params, {"images": x,
                                         "labels": jnp.zeros((2,), jnp.int32)})
    assert bool(jnp.isfinite(loss))


def test_moe_global_aux_recovers_full_batch_statistics():
    """ROADMAP item, quantified: the mean of per-shard auxes (the
    documented per-micro-batch/per-shard deviation) differs from the
    full-batch aux, while averaging the router STATISTICS first (what
    apply_moe(global_aux=True) psums across shards) recovers it exactly
    for equal shard sizes."""
    from repro.models.moe import _moe_local, router_aux

    rng = np.random.default_rng(0)
    d, e, topk, t = 16, 8, 2, 64
    x = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
    router = jnp.asarray(rng.standard_normal((d, e)), jnp.float32)
    w1 = jnp.asarray(rng.standard_normal((e, d, 32)) * 0.1, jnp.float32)
    w3 = jnp.asarray(rng.standard_normal((e, d, 32)) * 0.1, jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((e, 32, d)) * 0.1, jnp.float32)
    kw = dict(topk=topk, capacity=64, act="silu")

    _, aux_full, me_full, ce_full = _moe_local(
        x, router, w1, w3, w2, return_stats=True, **kw)

    shards = [x[: t // 2], x[t // 2:]]
    stats = [_moe_local(s, router, w1, w3, w2, return_stats=True, **kw)
             for s in shards]
    aux_mean = float(sum(s[1] for s in stats) / 2)          # per-shard aux
    me_g = sum(s[2] for s in stats) / 2                     # pmean'd stats
    ce_g = sum(s[3] for s in stats) / 2
    aux_global = float(router_aux(me_g, ce_g))

    assert aux_global == pytest.approx(float(aux_full), rel=1e-6)
    gap = abs(aux_mean - float(aux_full))
    assert gap > 1e-4, "deviation should be measurable on random routing"
    # the deviation the flag removes is real but bounded
    assert gap < 0.5 * float(aux_full)


def test_moe_global_aux_flag_noop_without_mesh():
    """Without a mesh the local aux already sees every token: the config
    flag must not change the loss."""
    from repro.models import LMConfig

    cfg = dict(name="t", num_layers=2, d_model=32, n_heads=4, n_kv=2,
               d_ff=32, vocab=128, moe_experts=4, moe_topk=2,
               dtype="float32")
    m1 = LM(LMConfig(**cfg))
    m2 = LM(LMConfig(moe_global_aux=True, **cfg))
    p = m1.init(jax.random.key(0))
    batch = lm_batch_for(m1.cfg, 4, 16)
    l1 = float(m1.forward(p, batch)[0])
    l2 = float(m2.forward(p, batch)[0])
    assert l1 == l2


def test_serve_emits_exactly_gen_tokens():
    """Regression for the serve decode-loop off-by-one: the old loop
    appended the PRE-decode token each iteration, so the output held the
    prefill argmax + the first gen-1 decodes and the final decode's
    sampled token was computed then silently discarded.  The emitted
    sequence must be exactly the --gen decode outputs, matching a
    hand-rolled greedy chain."""
    from repro.launch import serve

    gen, batch, plen, seed = 5, 2, 4, 3
    toks = serve.main(["--arch", "qwen1.5-4b", "--batch", str(batch),
                       "--prompt-len", str(plen), "--gen", str(gen),
                       "--seed", str(seed)])
    assert toks.shape == (batch, gen)

    cfg = get_arch("qwen1.5-4b").smoke
    model = LM(cfg)
    params = model.init(jax.random.key(seed))
    rng = np.random.default_rng(seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (batch, plen)),
                          jnp.int32)
    logits, ss = jax.jit(
        model.prefill_with_cache,
        static_argnames=("cache_len", "cache_dtype"))(
            params, {"tokens": prompts}, cache_len=plen + gen,
            cache_dtype=jnp.float32)
    decode = jax.jit(make_decode_step(model))
    tok = jnp.argmax(logits, -1, keepdims=True).astype(jnp.int32)
    ref = []
    for _ in range(gen):
        logits, ss = decode(params, ss, tok)
        tok = jnp.argmax(logits, -1, keepdims=True).astype(jnp.int32)
        ref.append(np.asarray(tok[:, 0]))
    np.testing.assert_array_equal(toks, np.stack(ref, axis=1))
