"""Continuous-batching serving engine + split-inference tests.

The load-bearing guarantee: continuous batching changes WHEN work runs
— requests join and leave the slot arena at arbitrary steps, slots are
reused, prefill is chunked — but never WHAT it computes.  Every
request's emitted tokens must equal its solo batch=1 run-to-completion
decode bit-for-bit, greedy and sampled.  The split-inference half pins
the same property across a real loopback socket plus the wire-honesty
contract (measured INFER payload bytes == planner billing within 1%).
"""
import asyncio
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import LM, LMConfig
from repro.runtime.qos import ServingQoS, percentile
from repro.serving import kv
from repro.serving.engine import (ServingEngine, convoy_units,
                                  make_sample_step, solo_decode)
from repro.serving.scheduler import Request, Scheduler

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CFG = LMConfig(name="serve-test", num_layers=2, d_model=32, n_heads=2,
               n_kv=1, d_ff=32, vocab=64, dtype="float32")


@pytest.fixture(scope="module")
def model_params():
    model = LM(CFG)
    return model, model.init(jax.random.key(0))


def _reqs(specs):
    rng = np.random.default_rng(11)
    return [Request(rid=i, prompt=rng.integers(0, CFG.vocab, plen),
                    max_new_tokens=gen)
            for i, (plen, gen) in enumerate(specs)]


# ---------------------------------------------------------------------------
# Engine bit-identity: staggered join/leave, slot reuse, chunked prefill.
# ---------------------------------------------------------------------------


def test_staggered_requests_bitexact_vs_solo(model_params):
    """Requests submitted mid-flight, ragged gens forcing slot churn on
    a 2-slot arena: every output equals the solo batch=1 decode."""
    model, params = model_params
    reqs = _reqs([(3, 4), (5, 2), (3, 6), (4, 3), (5, 5)])
    eng = ServingEngine(model, params, slots=2, cache_len=16)
    for r in reqs[:2]:
        assert eng.submit(r)
    for _ in range(3):                       # r1 (gen 2) frees its slot
        eng.step_once()
    for r in reqs[2:]:
        assert eng.submit(r)
    out = eng.run()
    assert set(out) == {r.rid for r in reqs}
    for r in reqs:
        ref = solo_decode(model, params, r.prompt, r.max_new_tokens,
                          cache_len=16)
        np.testing.assert_array_equal(out[r.rid], ref)
    stats = eng.stats()
    assert stats["qos"]["completed"] == len(reqs)
    # 2-slot arena, 5 tenants -> slots were reused
    assert stats["decode_steps"] * 2 >= sum(r.max_new_tokens for r in reqs)


def test_sampled_bitexact_and_slot_independent(model_params):
    """Temperature sampling inside the jitted step uses per-request
    fold_in keys: outputs equal the solo chain AND are invariant to the
    arena size / slot assignment."""
    model, params = model_params
    reqs = _reqs([(4, 5), (4, 3), (4, 6), (4, 4)])
    outs = {}
    for slots in (1, 3):
        eng = ServingEngine(model, params, slots=slots, cache_len=16,
                            temperature=0.7, seed=9)
        outs[slots] = eng.run(_reqs([(4, 5), (4, 3), (4, 6), (4, 4)]))
    for r in reqs:
        ref = solo_decode(model, params, r.prompt, r.max_new_tokens,
                          cache_len=16, temperature=0.7, seed=9,
                          rid=r.rid)
        np.testing.assert_array_equal(outs[1][r.rid], ref)
        np.testing.assert_array_equal(outs[3][r.rid], ref)


def test_prefill_chunk_budget_equivalence(model_params):
    """A tight prefill-chunk token budget splits admissions across many
    engine iterations; outputs are identical to an unconstrained run."""
    model, params = model_params
    specs = [(6, 3)] * 5
    outs = {}
    for budget in (6, 512):                  # 1 prompt/chunk vs all 5
        eng = ServingEngine(model, params, slots=5, cache_len=16,
                            prefill_chunk_tokens=budget)
        outs[budget] = eng.run(_reqs(specs))
    assert outs[6].keys() == outs[512].keys()
    for rid in outs[6]:
        np.testing.assert_array_equal(outs[6][rid], outs[512][rid])
    # and the constrained run really did chunk
    eng2 = ServingEngine(model, params, slots=5, cache_len=16,
                         prefill_chunk_tokens=6)
    eng2.run(_reqs(specs))
    assert eng2.prefill_chunks == 5


def test_engine_rejects_and_counts(model_params):
    model, params = model_params
    eng = ServingEngine(model, params, slots=2, cache_len=8, max_queue=2)
    ok = eng.submit(Request(rid=0, prompt=np.zeros(6, np.int32),
                            max_new_tokens=4))     # 6 + 4 > 8
    assert not ok
    assert eng.submit(Request(rid=1, prompt=np.zeros(2, np.int32),
                              max_new_tokens=2))
    assert eng.submit(Request(rid=2, prompt=np.zeros(2, np.int32),
                              max_new_tokens=2))
    assert not eng.submit(Request(rid=3, prompt=np.zeros(2, np.int32),
                                  max_new_tokens=2))   # queue full
    snap = eng.qos.snapshot()
    assert snap["rejected"] == 2 and snap["admitted"] == 0


# ---------------------------------------------------------------------------
# Fused decode+sample step (the static serve path).
# ---------------------------------------------------------------------------


def test_make_sample_step_greedy_matches_unfused(model_params):
    from repro.parallel.steps import make_decode_step
    model, params = model_params
    prompts = jnp.asarray(
        np.random.default_rng(2).integers(0, CFG.vocab, (2, 4)), jnp.int32)
    logits, ss = jax.jit(
        model.prefill_with_cache,
        static_argnames=("cache_len", "cache_dtype"))(
            params, {"tokens": prompts}, cache_len=10,
            cache_dtype=jnp.float32)
    _, ss_ref = jax.jit(
        model.prefill_with_cache,
        static_argnames=("cache_len", "cache_dtype"))(
            params, {"tokens": prompts}, cache_len=10,
            cache_dtype=jnp.float32)
    decode = jax.jit(make_decode_step(model))
    step = make_sample_step(model, 0.0)
    key = jax.random.key(0)
    tok = jnp.argmax(logits, -1, keepdims=True).astype(jnp.int32)
    tok_ref = tok
    for _ in range(4):
        tok, lg, ss, key = step(params, ss, tok, key)
        lg_ref, ss_ref = decode(params, ss_ref, tok_ref)
        tok_ref = jnp.argmax(lg_ref, -1, keepdims=True).astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(tok), np.asarray(tok_ref))
        np.testing.assert_array_equal(np.asarray(lg), np.asarray(lg_ref))


def test_serve_cli_sampled_matches_old_host_chain():
    """serve.main --temperature now samples INSIDE the jit; the carried
    key splits in the same order as the old host loop, so the emitted
    tokens are unchanged."""
    from repro.configs import get_arch
    from repro.launch import serve
    from repro.parallel.steps import make_decode_step

    gen, batch, plen, seed, temp = 4, 2, 3, 5, 0.8
    toks = serve.main(["--arch", "qwen1.5-4b", "--batch", str(batch),
                       "--prompt-len", str(plen), "--gen", str(gen),
                       "--seed", str(seed), "--temperature", str(temp)])
    cfg = get_arch("qwen1.5-4b").smoke
    model = LM(cfg)
    params = model.init(jax.random.key(seed))
    rng = np.random.default_rng(seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (batch, plen)),
                          jnp.int32)
    logits, ss = jax.jit(
        model.prefill_with_cache,
        static_argnames=("cache_len", "cache_dtype"))(
            params, {"tokens": prompts}, cache_len=plen + gen,
            cache_dtype=jnp.float32)
    decode = jax.jit(make_decode_step(model))
    key = jax.random.key(seed)
    tok = jnp.argmax(logits, -1, keepdims=True).astype(jnp.int32)
    ref = []
    for _ in range(gen):
        logits, ss = decode(params, ss, tok)
        key, sub = jax.random.split(key)
        tok = jax.random.categorical(
            sub, logits / temp, axis=-1)[:, None].astype(jnp.int32)
        ref.append(np.asarray(tok[:, 0]))
    np.testing.assert_array_equal(toks, np.stack(ref, axis=1))


# ---------------------------------------------------------------------------
# Slot arena + scheduler + freelist units.
# ---------------------------------------------------------------------------


def test_slot_axes_take_put_roundtrip(model_params):
    model, _ = model_params
    axes = kv.slot_axes(model, 8)
    cache = model.init_cache(3, 8, jnp.float32)
    cache = jax.tree.map(
        lambda a: jnp.arange(a.size, dtype=a.dtype).reshape(a.shape),
        cache)
    row = kv.take_slot(cache, axes, 1)
    back = kv.put_slot(cache, axes, row, 1)
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # expand/squeeze invert each other
    b1 = kv.expand_slot(row, axes)
    row2 = kv.squeeze_slot(b1, axes)
    for a, b in zip(jax.tree.leaves(row), jax.tree.leaves(row2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_freelist_lifo_and_double_free():
    fl = kv.FreeList(3)
    assert [fl.alloc(), fl.alloc()] == [0, 1]
    fl.free(0)
    assert fl.alloc() == 0                   # LIFO: immediate reuse
    fl.free(0)
    with pytest.raises(ValueError):
        fl.free(0)                           # double free
    with pytest.raises(ValueError):
        fl.free(7)                           # out of range


def test_scheduler_buckets_policy_and_rejects():
    s = Scheduler(cache_len=32, prefill_chunk_tokens=8,
                  policy="longest_first")
    assert not s.submit(Request(rid=0, prompt=np.zeros(30, np.int32),
                                max_new_tokens=4))       # cache overflow
    for rid, (plen, gen) in enumerate([(4, 2), (4, 9), (6, 5), (4, 9)],
                                      start=1):
        assert s.submit(Request(rid=rid, prompt=np.zeros(plen, np.int32),
                                max_new_tokens=gen))
    # LPT: head is rid=2 (gen 9, plen 4); same-length rid=4 joins; the
    # 8-token budget stops after those two; rid=3 (plen 6) is skipped
    chunk = s.next_chunk(free_slots=4)
    assert [r.rid for r in chunk] == [2, 4]
    chunk = s.next_chunk(free_slots=4)
    assert [r.rid for r in chunk] == [3]     # next-longest gen bucket
    assert [r.rid for r in s.next_chunk(4)] == [1]
    assert s.next_chunk(4) == [] and s.rejected == 1
    # head always admitted even over budget
    s2 = Scheduler(cache_len=64, prefill_chunk_tokens=4)
    s2.submit(Request(rid=0, prompt=np.zeros(10, np.int32),
                      max_new_tokens=1))
    assert [r.rid for r in s2.next_chunk(2)] == [0]
    with pytest.raises(ValueError):
        Scheduler(cache_len=8, policy="shortest_first")


def test_convoy_units():
    reqs = _reqs([(4, 8), (4, 2), (4, 2), (4, 2)])
    # batch 2: groups (8,2) and (2,2) -> 16 + 4*4 + 2*2*2
    assert convoy_units(reqs, 2) == 16 + 2 * 8 + 2 * 2


# ---------------------------------------------------------------------------
# ServingQoS latency percentiles (scripted clock).
# ---------------------------------------------------------------------------


def test_percentile_nearest_rank():
    xs = [10.0, 20.0, 30.0, 40.0]
    assert percentile(xs, 50) == 20.0
    assert percentile(xs, 99) == 40.0
    assert percentile(xs, 0) == 10.0
    assert percentile([], 50) is None
    with pytest.raises(ValueError):
        percentile(xs, 150)


def test_serving_qos_scripted_clock():
    t = {"now": 0.0}
    q = ServingQoS(clock=lambda: t["now"])
    for rid, (ttft, per_tok, n) in enumerate([(1.0, 0.5, 3),
                                              (2.0, 0.25, 5),
                                              (4.0, 1.0, 2)]):
        t["now"] = 0.0
        q.record_submit(rid)
        q.record_admit(rid, step=0)
        t["now"] = ttft
        q.record_token(rid, step=1)
        for i in range(1, n):
            t["now"] = ttft + i * per_tok
            q.record_token(rid, step=1 + i)
        q.record_done(rid, step=n)
    q.record_submit(99)                      # queued, never admitted
    q.record_submit(98)
    q.record_reject(98)
    snap = q.snapshot()
    assert snap["admitted"] == 3 and snap["completed"] == 3
    assert snap["rejected"] == 1 and snap["queued"] == 1
    assert snap["tokens_emitted"] == 10
    lat = snap["latency"]
    assert lat["p50_ttft_s"] == 2.0 and lat["p99_ttft_s"] == 4.0
    assert lat["p50_tok_s"] == 0.5 and lat["p99_tok_s"] == 1.0
    with pytest.raises(ValueError):
        q.record_submit(99)                  # duplicate submit
    with pytest.raises(KeyError):
        q.record_token(1234, step=0)


# ---------------------------------------------------------------------------
# Split inference: composition bit-identity + INFER wire honesty.
# ---------------------------------------------------------------------------


def test_split_decode_composition_bitexact(model_params):
    from repro.serving.infer import SplitDecode
    model, params = model_params
    prompts = jnp.asarray(
        np.random.default_rng(3).integers(0, CFG.vocab, (2, 5)), jnp.int32)
    split = SplitDecode(model, 1)
    ue_p, bs_p = split.split_params(params)
    acts, ue_c = split.ue_prefill(ue_p, prompts, cache_len=12)
    logits, bs_c = split.bs_prefill(bs_p, acts, cache_len=12)
    ml, ms = jax.jit(
        model.prefill_with_cache,
        static_argnames=("cache_len", "cache_dtype"))(
            params, {"tokens": prompts}, cache_len=12,
            cache_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(logits), np.asarray(ml))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    cache = ms["cache"]
    for pos in range(5, 8):
        a, ue_c = split.ue_decode(ue_p, tok, ue_c,
                                  jnp.asarray(pos, jnp.int32))
        lg, bs_c = split.bs_decode(bs_p, a, bs_c,
                                   jnp.asarray(pos, jnp.int32))
        mlg, cache = model.decode_step(params, tok, cache,
                                       jnp.asarray(pos, jnp.int32))
        np.testing.assert_array_equal(np.asarray(lg), np.asarray(mlg))
        tok = jnp.argmax(lg, -1).astype(jnp.int32)[:, None]


def test_split_decode_validation(model_params):
    from repro.serving.infer import SplitDecode, _require_dense
    model, _ = model_params
    with pytest.raises(ValueError):
        SplitDecode(model, 0)
    with pytest.raises(ValueError):
        SplitDecode(model, CFG.num_layers)
    with pytest.raises(ValueError):
        _require_dense("int8+topk0.25")      # INFER hop is forward-only
    assert _require_dense("fp8") == "fp8"


@pytest.mark.parametrize("wire", ["none", "int8", "fp8"])
def test_infer_loopback_wire_honesty(model_params, wire):
    """The INFER hop over a REAL loopback socket: measured payload bytes
    match the planner's billed_hop_bytes within 1%; 'none' tokens are
    bit-identical to the monolithic greedy chain (quantized codecs are
    lossy by design — shape and completion only)."""
    from repro.serving.infer import run_split_infer
    model, params = model_params
    prompts = np.random.default_rng(5).integers(
        0, CFG.vocab, (2, 4)).astype(np.int32)
    gen = 3
    res = run_split_infer(model, params, cut=1, prompts=prompts, gen=gen,
                          cache_len=8, wire_dtype=wire)
    assert res["tokens"].shape == (2, gen)
    rel = abs(res["measured_payload_bytes"] - res["billed_payload_bytes"]) \
        / res["billed_payload_bytes"]
    assert rel <= 0.01, (wire, res)
    # gen+1 uplink frames: 1 prefill + gen decode acts
    assert res["frames"] == gen + 1
    assert res["client_payload_bytes"] == res["measured_payload_bytes"]
    if wire == "none":
        ref = np.stack([solo_decode(model, params, prompts[i], gen,
                                    cache_len=8) for i in range(2)])
        np.testing.assert_array_equal(res["tokens"], ref)


# ---------------------------------------------------------------------------
# Serving planner objective (analysis/autotune).
# ---------------------------------------------------------------------------


def _serving_inputs(**kw):
    from repro.analysis.autotune import ServingInputs
    base = dict(decode_lane_s=1e-3, prefill_s_per_token=1e-3,
                arrival_hz=2.0, prompt_tokens=8.0, gen_tokens=32.0,
                step_overhead_s=5e-3)
    base.update(kw)
    return ServingInputs(**base)


def test_serving_wall_shape_and_overload():
    from repro.analysis.autotune import serving_wall
    inp = _serving_inputs()
    ev = serving_wall(inp, 8)
    assert ev["rho"] < 1 and np.isfinite(ev["p99_ttft_s"])
    assert ev["capacity_tokens_per_s"] > ev["tokens_per_s"] > 0
    # an undersized arena is overloaded -> infinite latency, not a raise
    over = serving_wall(_serving_inputs(arrival_hz=50.0), 1)
    assert over["p99_ttft_s"] == float("inf")
    # larger arenas pay more per step (fixed-shape computes every lane)
    assert serving_wall(inp, 32)["per_token_s"] \
        > serving_wall(inp, 4)["per_token_s"]
    with pytest.raises(ValueError):
        serving_wall(inp, 0)


def test_choose_serving_plan_interior_and_errors():
    from repro.analysis.autotune import choose_serving_plan, serving_wall
    inp = _serving_inputs()
    plan = choose_serving_plan(inp)
    assert plan.slots in inp.slot_candidates and plan.rho < 1
    # argmin property: no candidate beats the chosen p99
    for s in inp.slot_candidates:
        ev = serving_wall(inp, s)
        assert plan.p99_ttft_s <= ev["p99_ttft_s"] * (1 + 1e-8)
    with pytest.raises(ValueError):          # all overloaded
        choose_serving_plan(_serving_inputs(arrival_hz=1e6))
    with pytest.raises(ValueError):          # topk illegal on INFER hop
        choose_serving_plan(inp, wire_candidates=["int8+topk0.25"])


def test_serving_plan_split_hop_codec():
    """Split serving: a dense codec shrinks the INFER hop time, so at a
    tight link the coded plan strictly beats 'none'."""
    from repro.analysis.autotune import choose_serving_plan
    inp = _serving_inputs(d_model=256, act_bytes=4.0,
                          link_bw_Bps=2e6, hop_overhead_s=1e-4)
    plan = choose_serving_plan(inp, wire_candidates=["none", "int8",
                                                     "fp8"])
    assert plan.wire_dtype == "int8"
    none_plan = choose_serving_plan(inp.with_wire("none"))
    assert plan.p99_ttft_s < none_plan.p99_ttft_s


def test_plan_args_serve_flavor():
    import argparse

    from repro.launch.plan_args import add_plan_args
    ap = argparse.ArgumentParser()
    add_plan_args(ap, flavor="serve")
    args = ap.parse_args(["--wire-dtype", "int8",
                          "--plan-out", "plan.json"])
    assert args.wire_dtype == "int8" and args.plan_out == "plan.json"
    assert not hasattr(args, "pipeline_k")   # train-only flags absent
    with pytest.raises(ValueError):
        add_plan_args(argparse.ArgumentParser(), flavor="infer")


# ---------------------------------------------------------------------------
# Bench baseline sync (the CI diff-gate guarantee, in tier-1).
# ---------------------------------------------------------------------------


def test_committed_bench_baseline_matches_serve_bench():
    """benchmarks/BENCH_pipeline.json must stay in sync with the live
    serving engine — a cost-model or scheduler change cannot land
    without regenerating the baseline."""
    sys.path.insert(0, ROOT)
    try:
        from benchmarks.run import diff_rows
        from benchmarks.serve_bench import main as bench_main
    finally:
        sys.path.remove(ROOT)
    with open(os.path.join(ROOT, "benchmarks",
                           "BENCH_pipeline.json")) as f:
        base = json.load(f)
    result = json.loads(json.dumps(
        bench_main(quick=True),
        default=lambda o: o.tolist() if hasattr(o, "tolist") else str(o)))
    fails = diff_rows(base["rows"],
                      [{"name": "serve_bench", "result": result}])
    assert fails == [], fails
    assert result["modeled_speedup"] >= 1.5
    assert result["tokens_bitexact_vs_solo"]
    assert result["infer_wire_ok"]
