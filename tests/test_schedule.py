"""Pipeline schedule & bubble-rate accounting (paper SII-C, SIII-A)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.costs import lm_profile, resnet18_profile
from repro.core.schedule import (Plan, TaskTimes, bubble_rate,
                                 simulate_c2p2sl, simulate_epsl,
                                 simulate_psl, simulate_sl,
                                 steady_state_ok, task_times)
from repro.wireless.fleet import sample_fleet


def make_plan(n=4, batch=64, l=2, k=4, seed=0):
    fleet = sample_fleet(n, seed=seed)
    b = np.full(n, batch // n, dtype=float)
    tau = np.full(n, fleet.channel.frame_s / n)
    return fleet, Plan(l=l, k=k, b=b, tau=tau)


def test_table2_profile_matches_paper():
    prof = resnet18_profile()
    assert prof.num_layers == 6
    # Table II traffic column (MB) at each cut
    assert prof.cut_bytes(1) == pytest.approx(0.250 * 2**20)
    assert prof.cut_bytes(4) == pytest.approx(0.063 * 2**20)
    # FLOPs columns
    assert prof.ue_fwd(2) == pytest.approx((3.802 + 303.0) * 1e6)
    assert prof.bs_fwd(2) == pytest.approx((269.1 + 268.8 + 268.6 + 0.026) * 1e6)


def test_task_times_scale_with_k():
    prof = resnet18_profile()
    fleet, plan = make_plan(k=1)
    t1 = task_times(prof, fleet, plan)
    t4 = task_times(prof, fleet, Plan(l=plan.l, k=4, b=plan.b, tau=plan.tau))
    # eqs (7)-(12): every per-micro-batch time scales as 1/k
    np.testing.assert_allclose(t1.ue_fwd, 4 * t4.ue_fwd)
    np.testing.assert_allclose(t1.uplink, 4 * t4.uplink)
    assert t1.bs_fwd == pytest.approx(4 * t4.bs_fwd)
    assert t1.downlink[0] == pytest.approx(4 * t4.downlink[0])


def test_bubble_rate_definition():
    prof = resnet18_profile()
    fleet, plan = make_plan(k=4)
    t = task_times(prof, fleet, plan)
    br = bubble_rate(t, plan.k)
    t_idle = np.max(t.ue_fwd + t.uplink) + np.max(t.downlink + t.ue_bwd)
    t_work = plan.k * (t.bs_fwd + t.bs_bwd)
    assert br == pytest.approx(t_idle / (t_idle + t_work))
    assert 0.0 < br < 1.0


def test_bubble_rate_strictly_decreases_in_v():
    """Interleaving shrinks the idle term by v: BR(v+1) < BR(v)."""
    prof = resnet18_profile()
    fleet, plan = make_plan(k=4)
    t = task_times(prof, fleet, plan)
    rates = [bubble_rate(t, plan.k, v) for v in (1, 2, 3, 4, 8)]
    for hi, lo in zip(rates, rates[1:]):
        assert lo < hi
    # the (S-1)/v-style shrink: the idle term divides exactly by v
    t_idle = np.max(t.ue_fwd + t.uplink) + np.max(t.downlink + t.ue_bwd)
    t_work = plan.k * t.bs_work
    for v in (2, 4):
        assert bubble_rate(t, plan.k, v) == pytest.approx(
            (t_idle / v) / (t_idle / v + t_work))


def test_simulate_c2p2sl_interleaved_shrinks_makespan():
    """v > 1 = the same work at 1/v task granularity: the makespan never
    grows, strictly shrinks in the steady-state regime, and exactly
    equals the (t/v, k*v) re-granularized schedule."""
    prof = resnet18_profile()
    fleet, plan = make_plan(n=8, batch=512, l=1, k=8)
    t = task_times(prof, fleet, plan)
    ms1, _ = simulate_c2p2sl(t, plan.k)
    prev = ms1
    for v in (2, 4):
        msv, _ = simulate_c2p2sl(t, plan.k, virtual_stages=v)
        assert msv <= prev + 1e-9
        prev = msv
        tv = TaskTimes(ue_fwd=t.ue_fwd / v, uplink=t.uplink / v,
                       bs_fwd=t.bs_fwd / v, bs_bwd=t.bs_bwd / v,
                       downlink=t.downlink / v, ue_bwd=t.ue_bwd / v)
        ms_regran, _ = simulate_c2p2sl(tv, plan.k * v)
        assert msv == pytest.approx(ms_regran, rel=1e-12)
    if steady_state_ok(t, plan.k):
        ms2, _ = simulate_c2p2sl(t, plan.k, virtual_stages=2)
        assert ms2 < ms1


def test_plan_v_defaults_to_plain_1f1b():
    fleet, plan = make_plan()
    assert plan.v == 1
    t = task_times(resnet18_profile(), fleet, plan)
    assert bubble_rate(t, plan.k) == bubble_rate(t, plan.k, 1)
    assert simulate_c2p2sl(t, plan.k)[0] == pytest.approx(
        simulate_c2p2sl(t, plan.k, virtual_stages=1)[0])


def test_c2p2sl_beats_psl_with_pipelining():
    """The paper's core claim: micro-batch pipelining shrinks the makespan."""
    prof = resnet18_profile()
    fleet, plan = make_plan(n=8, batch=512, l=1, k=8)
    t = task_times(prof, fleet, plan)
    ms, _ = simulate_c2p2sl(t, plan.k)
    t1 = task_times(prof, fleet, Plan(l=plan.l, k=1, b=plan.b, tau=plan.tau))
    psl = simulate_psl(t1)
    assert ms < psl


def test_c2p2sl_k1_equals_psl():
    """k=1 C2P2SL degenerates exactly to PSL (no pipelining)."""
    prof = resnet18_profile()
    fleet, plan = make_plan(n=4, batch=64, l=2, k=1)
    t1 = task_times(prof, fleet, plan)
    ms, _ = simulate_c2p2sl(t1, 1)
    assert ms == pytest.approx(simulate_psl(t1), rel=1e-9)


def test_sl_slowest():
    """Sequential SL is the slowest scheme (paper Fig 4 ordering)."""
    prof = resnet18_profile()
    fleet, plan = make_plan(n=4, batch=64, l=2, k=4)
    t = task_times(prof, fleet, plan)
    ms_c2, _ = simulate_c2p2sl(t, plan.k)
    sl = simulate_sl(prof, fleet, plan)
    assert sl > ms_c2


def test_epsl_faster_than_psl():
    prof = resnet18_profile()
    fleet, plan = make_plan(n=4, batch=64, l=2, k=1)
    t1 = task_times(prof, fleet, plan)
    assert simulate_epsl(t1, fleet.n) < simulate_psl(t1)


@settings(deadline=None, max_examples=30)
@given(
    n=st.integers(2, 10),
    l=st.integers(1, 5),
    k=st.integers(1, 16),
    seed=st.integers(0, 100),
)
def test_makespan_lower_bound_property(n, l, k, seed):
    """Property: the makespan is never below the BS's pure work time, and
    the timeline is consistent (end >= start, tasks ordered per actor)."""
    prof = resnet18_profile()
    fleet = sample_fleet(n, seed=seed)
    b = np.full(n, 8.0 * k)
    tau = np.full(n, fleet.channel.frame_s / n)
    t = task_times(prof, fleet, Plan(l=l, k=k, b=b, tau=tau))
    ms, tl = simulate_c2p2sl(t, k, collect_timeline=True)
    assert ms >= k * (t.bs_fwd + t.bs_bwd) - 1e-12
    for actor in {e[0] for e in tl}:
        events = [e for e in tl if e[0] == actor]
        for (_, _, _, s, e) in events:
            assert e >= s - 1e-12


@settings(deadline=None, max_examples=25)
@given(n=st.integers(2, 8), l=st.integers(1, 5), k=st.integers(1, 31),
       v=st.integers(1, 7), seed=st.integers(0, 100))
def test_property_bubble_rate_monotone_in_k_and_v(n, l, k, v, seed):
    """Property (eqs 16-18 generalized): at fixed task times, BR is
    non-increasing in both k (more steady-state work amortizing the same
    idle) and v (the idle term divides by v), and stays in [0, 1)."""
    prof = resnet18_profile()
    fleet = sample_fleet(n, seed=seed)
    b = np.full(n, 64.0)
    tau = np.full(n, fleet.channel.frame_s / n)
    t = task_times(prof, fleet, Plan(l=l, k=k, b=b, tau=tau))
    br = bubble_rate(t, k, v)
    assert 0.0 <= br < 1.0
    assert bubble_rate(t, k + 1, v) <= br + 1e-12
    assert bubble_rate(t, k, v + 1) <= br + 1e-12


@settings(deadline=None, max_examples=20)
@given(k=st.integers(2, 32), seed=st.integers(0, 50))
def test_more_microbatches_never_hurt_when_steady(k, seed):
    """When C3/C4 hold, pipelining with k micro-batches beats k=1."""
    prof = resnet18_profile()
    fleet = sample_fleet(4, seed=seed)
    b = np.full(4, 16.0 * k)
    tau = np.full(4, fleet.channel.frame_s / 4)
    tk = task_times(prof, fleet, Plan(l=1, k=k, b=b, tau=tau))
    t1 = task_times(prof, fleet, Plan(l=1, k=1, b=b, tau=tau))
    if steady_state_ok(tk, k):
        ms_k, _ = simulate_c2p2sl(tk, k)
        ms_1, _ = simulate_c2p2sl(t1, 1)
        assert ms_k <= ms_1 + 1e-9
