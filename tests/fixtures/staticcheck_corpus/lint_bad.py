"""Seeded-violation corpus for the AST lint pack (``--selftest``).

Every rule in ``repro.analysis.lint.RULES`` must fire on this module —
one deliberate defect per rule, inside a function named ``_tick_loop``
so the reachability root matches.  NOT importable production code; the
ruff gate ignores it (per-file-ignores in ruff.toml) and the staticcheck
selftest asserts the exact rule set that fires.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tick_loop(state, steps):
    y = jnp.sum(state)
    if y > 0:                       # tracer-branch: python `if` on a tracer
        y = y + 1.0
    thr = float(y)                  # tracer-concretize: host round-trip
    step = jax.jit(lambda t: t + thr)   # nested-jit: retraces every tick
    for _ in range(steps):
        y = step(y)
    return y


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def run(x):
    # pallas-interpret: no `interpret` keyword plumbed
    return pl.pallas_call(_copy_kernel, out_shape=x)(x)
