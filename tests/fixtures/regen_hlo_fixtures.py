"""Regenerate/validate the checked-in HLO parser fixtures
(``tests/fixtures/hlo_*.txt``) from their probe programs, so fixture
drift is a script run instead of a manual capture.

Each JAX generation owns one fixture — the spelling is the point:

* ``hlo_legacy_0437.txt`` (fully-manual shard_map leg, jax 0.4.x):
  synchronous collectives, ``replica_groups={{...}}`` lists, f32;
* ``hlo_current.txt`` (partial-manual leg): async ``-start/-done``
  pairs, iota replica_groups, bf16, a scan lowered to a ``while`` with
  ``known_trip_count``.

Run on the matching interpreter (the CI staticcheck job runs ``--check``
on both legs)::

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python tests/fixtures/regen_hlo_fixtures.py --check
    ...                                                           --write

``--check`` regenerates this leg's text in memory and asserts (a) the
structural invariants the parser tests rely on hold on the FRESH text,
and (b) on the legacy leg — whose toolchain is pinned — that the parsed
collective-byte profile matches the committed fixture.  ``--write``
overwrites the fixture file with the fresh text.
"""
from __future__ import annotations

import argparse
import os
import sys

FIXDIR = os.path.dirname(os.path.abspath(__file__))


def _force_host_devices(n=4):
    if "jax" in sys.modules:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n}").strip()


def generate():
    """Compile this leg's probe program; returns (fixture_name, text)."""
    _force_host_devices(4)
    import jax
    import jax.numpy as jnp

    from repro.parallel import compat
    from repro.parallel.compat import PartitionSpec as P

    legacy = not compat.CAPS.partial_manual
    mesh = compat.make_mesh((2, 2), ("a", "b"))
    if legacy:
        # mirror of the committed hlo_legacy_0437.txt probe: one dot,
        # an 'a'-axis ppermute, a 'b'-axis psum, a tiled all_gather of w,
        # and a zero-weighted tail keeping every collective live
        def shmap_body(x, w):
            y = jnp.dot(x, w)
            p = jax.lax.ppermute(y, "a", ((0, 1), (1, 0)))
            r = jax.lax.psum(p, "b")
            g = jax.lax.all_gather(w, "b", axis=0, tiled=True)
            t = jnp.dot(jnp.dot(g[: x.shape[0]], jnp.transpose(w)), w)
            return p + 0.0 * r[:1] + 0.0 * t

        def body(x, w):
            return compat.shard_map(
                shmap_body, mesh,
                in_specs=(P("a", None), P(None, "b")),
                out_specs=P("a", "b"))(x, w)
        x = jnp.zeros((8, 16), jnp.float32)
        w = jnp.zeros((16, 16), jnp.float32)
        text = jax.jit(body).lower(x, w).compile().as_text()
        return "hlo_legacy_0437.txt", text
    # mirror of the committed hlo_current.txt probe: a 9-trip scan whose
    # body dots and ppermutes (lowers to a while with known_trip_count),
    # plus an entry-level all_gather and a closing psum

    def shmap_body(x, w):
        g = jax.lax.all_gather(w, "b", axis=0, tiled=True)

        def step(c, _):
            y = jnp.dot(c, w)
            return jax.lax.ppermute(y, "a", ((0, 1), (1, 0))), None
        out, _ = jax.lax.scan(step, x, None, length=9)
        return jax.lax.psum(out, "b") + 0.0 * g[: x.shape[0]]

    def train_step(x, w):
        return compat.shard_map(
            shmap_body, mesh,
            in_specs=(P("a", None), P(None, "b")),
            out_specs=P("a", "b"))(x, w)
    x = jnp.zeros((16, 64), jnp.bfloat16)
    w = jnp.zeros((64, 64), jnp.bfloat16)
    text = jax.jit(train_step).lower(x, w).compile().as_text()
    return "hlo_current.txt", text


def check(text: str, name: str):
    """Structural invariants the parser tests rely on, asserted on the
    FRESH text (both legs); parsed-profile equality with the committed
    fixture asserted on the legacy leg only (pinned toolchain)."""
    from repro.analysis.hlo_costs import (analyze, parse_hlo,
                                          source_target_pairs)
    comps = parse_hlo(text)
    assert comps, f"{name}: no computations parsed from fresh text"
    pairs = []
    for instrs in comps.values():
        for ins in instrs:
            if ins.opcode.startswith("collective-permute") \
                    and not ins.opcode.endswith("-done"):
                pairs = source_target_pairs(ins.rest)
    assert sorted(pairs) == [(0, 2), (1, 3), (2, 0), (3, 1)], (
        f"{name}: ppermute pairs {pairs} != the a-axis exchange on the "
        "2x2 probe mesh")
    res = analyze(text)
    assert res["coll_by_kind"]["collective-permute"] > 0
    assert res["coll_by_kind"]["all-gather"] > 0
    assert res["coll_by_kind"]["all-reduce"] > 0
    if name == "hlo_current.txt":
        assert res["n_while"] >= 1, (
            f"{name}: scan did not lower to a while — the trip-count "
            "invariant the parser tests pin is gone")
    else:
        from repro.analysis.roofline import collective_bytes_from_hlo
        with open(os.path.join(FIXDIR, name)) as f:
            committed = collective_bytes_from_hlo(f.read())
        fresh = collective_bytes_from_hlo(text)
        assert fresh == committed, (
            f"{name}: collective profile drifted — fresh {fresh} vs "
            f"committed {committed}; rerun with --write and re-derive "
            "the expectations in tests/test_hlo_fixtures.py")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--write", action="store_true",
                    help="overwrite this leg's fixture with fresh text")
    ap.add_argument("--check", action="store_true",
                    help="regenerate in memory and validate (default)")
    args = ap.parse_args(argv)
    name, text = generate()
    check(text, name)
    if args.write:
        with open(os.path.join(FIXDIR, name), "w") as f:
            f.write(text)
        print(f"wrote {name} ({len(text)} bytes)")
    else:
        print(f"{name}: fresh text validates ({len(text)} bytes)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
