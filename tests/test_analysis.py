"""The trip-count-aware HLO static analyzer (the roofline's data source)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo_costs import (analyze, computation_multipliers,
                                      flat_cost_analysis, parse_hlo)
from repro.analysis.roofline import HW, RooflineTerms, model_flops_for
from repro.configs import SHAPES, get_arch


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_scan_trip_count_correction():
    def body(x, w):
        return x @ w, None
    W = jnp.zeros((8, 256, 256), jnp.float32)
    x = jnp.zeros((4, 256), jnp.float32)
    c = _compile(lambda x, W: jax.lax.scan(body, x, W)[0], x, W)
    res = analyze(c.as_text())
    assert res["flops"] == pytest.approx(8 * 2 * 4 * 256 * 256)
    # the flat XLA number misses the trip count (the bug we correct);
    # flat_cost_analysis normalizes the list-vs-dict return across versions
    flat = float(flat_cost_analysis(c).get("flops", 0.0))
    assert flat < res["flops"] / 4


def test_nested_scan_multipliers():
    def body(x, w):
        return x @ w, None
    W = jnp.zeros((8, 256, 256), jnp.float32)
    x = jnp.zeros((4, 256), jnp.float32)

    def outer(x, W):
        def ob(x, _):
            return jax.lax.scan(body, x, W)[0], None
        return jax.lax.scan(ob, x, jnp.arange(3))[0]

    res = analyze(_compile(outer, x, W).as_text())
    assert res["flops"] == pytest.approx(3 * 8 * 2 * 4 * 256 * 256)


def test_dot_flops_with_contraction():
    a = jnp.zeros((32, 64), jnp.float32)
    b = jnp.zeros((64, 16), jnp.float32)
    res = analyze(_compile(lambda a, b: a @ b, a, b).as_text())
    assert res["flops"] == pytest.approx(2 * 32 * 16 * 64)


def test_traffic_counts_dot_operands():
    a = jnp.zeros((128, 256), jnp.float32)
    b = jnp.zeros((256, 128), jnp.float32)
    res = analyze(_compile(lambda a, b: a @ b, a, b).as_text())
    expect = (128 * 256 + 256 * 128 + 128 * 128) * 4
    assert res["bytes"] >= expect
    assert res["bytes"] <= 3 * expect


def test_roofline_terms_math():
    t = RooflineTerms(flops=197e12, hbm_bytes=819e9 * 2,
                      coll_bytes=50e9 * 3, coll_by_kind={},
                      model_flops=197e12 * 256 * 0.5, chips=256)
    assert t.t_compute == pytest.approx(1.0)
    assert t.t_memory == pytest.approx(2.0)
    assert t.t_collective == pytest.approx(3.0)
    assert t.bottleneck == "collective"
    assert t.t_bound == pytest.approx(3.0)
    assert t.mfu_bound == pytest.approx(0.5 / 3.0)


def test_model_flops_dense_vs_moe():
    dense = get_arch("qwen1.5-4b").full
    moe = get_arch("qwen3-moe-30b-a3b").full
    tr = SHAPES["train_4k"]
    f_dense = model_flops_for(dense, tr)
    assert f_dense == pytest.approx(
        6 * dense.param_count() * 256 * 4096, rel=1e-6)
    # MoE: active params only (top-8 of 128 experts)
    f_moe = model_flops_for(moe, tr)
    assert f_moe < 6 * moe.param_count() * 256 * 4096 * 0.35
    # decode counts one token per sequence, inference 2*N*D
    dec = SHAPES["decode_32k"]
    assert model_flops_for(dense, dec) == pytest.approx(
        2 * dense.param_count() * 128, rel=1e-6)


def test_collectives_parsed_from_sharded_program():
    """An explicitly psum'd shard_map program yields all-reduce bytes."""
    import os
    # single device: use a 1-axis mesh (still emits a (trivial) all-reduce
    # in SPMD only with >1 devices, so just parse text for robustness)
    txt = """
HloModule test

ENTRY %main (p: f32[16,128]) -> f32[16,128] {
  %p = f32[16,128]{1,0} parameter(0)
  ROOT %ar = f32[16,128]{1,0} all-reduce(%p), to_apply=%add
}
"""
    res = analyze(txt)
    assert res["coll_by_kind"]["all-reduce"] == 16 * 128 * 4
    assert res["coll_bytes"] == 2 * 16 * 128 * 4   # ring 2x weighting
