"""C2P2SL pod pipeline: numerical equivalence with the plain model.

Multi-device tests spawn a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count (never set globally —
smoke tests must see 1 device)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 8, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_interleaved_single_stage_matches_reference():
    """Fast in-process check of the interleaved tick loop: S=1 needs no
    extra devices, but v>1 still exercises the full interleaved schedule
    (sigma spacing, per-tick chunk gather, chunk-chain carry) plus the
    masked-row padding path (batch 6, k 4)."""
    import jax
    import jax.numpy as jnp
    from repro.data import lm_batch_for
    from repro.models import LM, LMConfig
    from repro.parallel.compat import make_mesh, mesh_context
    from repro.parallel.pipeline import PipelineSpec, make_pipelined_loss

    cfg = LMConfig(name="t", num_layers=4, d_model=32, n_heads=4, n_kv=2,
                   d_ff=64, vocab=128, dtype="float32")
    m = LM(cfg)
    p = m.init(jax.random.key(0))
    batch = lm_batch_for(cfg, 6, 16)
    mesh = make_mesh((1,), ("pod",))
    loss_ref, _ = m.forward(p, batch)
    g_ref = jax.grad(lambda p: m.forward(p, batch)[0])(p)
    for v in (1, 2, 4):
        spec = PipelineSpec(num_stages=1, microbatches=4, virtual_stages=v)
        loss_fn = make_pipelined_loss(m, spec, mesh=mesh)
        with mesh_context(mesh):
            loss_pipe, _ = jax.jit(loss_fn)(p, batch)
            g_pipe = jax.jit(jax.grad(lambda p: loss_fn(p, batch)[0]))(p)
        assert abs(float(loss_ref) - float(loss_pipe)) < 1e-5, f"v={v}"
        d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         g_ref, g_pipe)
        assert max(jax.tree.leaves(d)) < 1e-5, f"v={v}"


def test_split_stages_round_robin_and_divisibility():
    import jax.numpy as jnp
    import numpy as np
    from repro.parallel.pipeline import _sigma, _split_stages

    blocks = {"w": jnp.arange(8)[:, None] * jnp.ones((8, 3))}
    staged = _split_stages(blocks, 2, 2)            # S=2, v=2 -> 4 chunks
    # chunk c = j*S + s holds layers [c*2, c*2+2): stage s, virtual j
    w = np.asarray(staged["w"])
    assert w.shape == (2, 2, 2, 3)
    assert w[0, 0, :, 0].tolist() == [0, 1]         # chunk 0
    assert w[1, 0, :, 0].tolist() == [2, 3]         # chunk 1
    assert w[0, 1, :, 0].tolist() == [4, 5]         # chunk 2
    assert w[1, 1, :, 0].tolist() == [6, 7]         # chunk 3
    with pytest.raises(ValueError, match="not divisible"):
        _split_stages(blocks, 3, 2)
    # sigma: v=1 is the identity schedule; groups of S spaced S*v apart
    assert [_sigma(m, 2, 1) for m in range(4)] == [0, 1, 2, 3]
    assert [_sigma(m, 2, 2) for m in range(6)] == [0, 1, 4, 5, 8, 9]


@pytest.mark.slow
def test_pipeline_matches_plain_model():
    out = run_sub("""
        import jax, json
        import jax.numpy as jnp
        from repro.models import LM, LMConfig
        from repro.data import lm_batch_for
        from repro.parallel.compat import make_mesh, mesh_context
        from repro.parallel.pipeline import PipelineSpec, make_pipelined_loss

        cfg = LMConfig(name='t', num_layers=4, d_model=64, n_heads=4, n_kv=2,
                       d_ff=128, vocab=256, dtype='float32')
        m = LM(cfg)
        p = m.init(jax.random.key(0))
        batch = lm_batch_for(cfg, 8, 32)
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        loss_ref, _ = m.forward(p, batch)
        g_ref = jax.grad(lambda p: m.forward(p, batch)[0])(p)
        spec = PipelineSpec(num_stages=2, microbatches=4)
        loss_fn = make_pipelined_loss(m, spec, mesh=mesh)
        with mesh_context(mesh):
            loss_pipe, _ = jax.jit(loss_fn)(p, batch)
            g_pipe = jax.jit(jax.grad(lambda p: loss_fn(p, batch)[0]))(p)
        d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         g_ref, g_pipe)
        print(json.dumps({
            "loss_ref": float(loss_ref), "loss_pipe": float(loss_pipe),
            "gdiff": max(jax.tree.leaves(d))}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert abs(res["loss_ref"] - res["loss_pipe"]) < 1e-5
    assert res["gdiff"] < 1e-5


@pytest.mark.slow
def test_pipeline_four_stages():
    """S=4 stages x k=8 micro-batches on an 8-device pod axis."""
    out = run_sub("""
        import jax, json
        import jax.numpy as jnp
        from repro.models import LM, LMConfig
        from repro.data import lm_batch_for
        from repro.parallel.compat import make_mesh, mesh_context
        from repro.parallel.pipeline import PipelineSpec, make_pipelined_loss

        cfg = LMConfig(name='t', num_layers=8, d_model=32, n_heads=4, n_kv=2,
                       d_ff=64, vocab=128, dtype='float32')
        m = LM(cfg)
        p = m.init(jax.random.key(1))
        batch = lm_batch_for(cfg, 8, 16)
        mesh = make_mesh((4, 2, 1), ("pod", "data", "model"))
        loss_ref, _ = m.forward(p, batch)
        spec = PipelineSpec(num_stages=4, microbatches=8)
        loss_fn = make_pipelined_loss(m, spec, mesh=mesh)
        with mesh_context(mesh):
            loss_pipe, _ = jax.jit(loss_fn)(p, batch)
        print(json.dumps({"ref": float(loss_ref), "pipe": float(loss_pipe)}))
    """, devices=8)
    res = json.loads(out.strip().splitlines()[-1])
    assert abs(res["ref"] - res["pipe"]) < 1e-5


@pytest.mark.slow
@pytest.mark.parametrize("k", [4, 5])
def test_interleaved_pipeline_matches_v1_and_reference(k):
    """virtual_stages=2 gradients == the v=1 pipeline == the unpipelined
    model, for divisible (k=4) and ragged (k=5, batch 10) micro-batch
    counts, on whichever lowering the installed JAX selects."""
    out = run_sub(f"""
        import jax, json
        import jax.numpy as jnp
        from repro.models import LM, LMConfig
        from repro.data import lm_batch_for
        from repro.parallel.compat import make_mesh, mesh_context
        from repro.parallel.pipeline import PipelineSpec, make_pipelined_loss

        cfg = LMConfig(name='t', num_layers=8, d_model=32, n_heads=4, n_kv=2,
                       d_ff=64, vocab=128, dtype='float32')
        m = LM(cfg)
        p = m.init(jax.random.key(1))
        batch = lm_batch_for(cfg, 10, 16)
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        loss_ref, _ = m.forward(p, batch)
        g_ref = jax.grad(lambda p: m.forward(p, batch)[0])(p)
        grads = {{}}
        losses = {{}}
        for v in (1, 2):
            spec = PipelineSpec(num_stages=2, microbatches={k},
                                virtual_stages=v)
            loss_fn = make_pipelined_loss(m, spec, mesh=mesh)
            with mesh_context(mesh):
                loss_pipe, _ = jax.jit(loss_fn)(p, batch)
                grads[v] = jax.jit(
                    jax.grad(lambda p: loss_fn(p, batch)[0]))(p)
            losses[v] = float(loss_pipe)
        dmax = lambda a, b: max(jax.tree.leaves(jax.tree.map(
            lambda x, y: float(jnp.max(jnp.abs(x - y))), a, b)))
        print(json.dumps({{
            "loss_ref": float(loss_ref), "loss_v1": losses[1],
            "loss_v2": losses[2],
            "gdiff_v2_ref": dmax(grads[2], g_ref),
            "gdiff_v2_v1": dmax(grads[2], grads[1])}}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert abs(res["loss_ref"] - res["loss_v2"]) < 1e-5
    assert abs(res["loss_v1"] - res["loss_v2"]) < 1e-5
    assert res["gdiff_v2_ref"] < 1e-5
    assert res["gdiff_v2_v1"] < 1e-5


@pytest.mark.slow
def test_interleaved_four_stages_v2():
    """S=4 x v=2 (8 model chunks over 8 layers) on a 4-wide pod axis."""
    out = run_sub("""
        import jax, json
        import jax.numpy as jnp
        from repro.models import LM, LMConfig
        from repro.data import lm_batch_for
        from repro.parallel.compat import make_mesh, mesh_context
        from repro.parallel.pipeline import PipelineSpec, make_pipelined_loss

        cfg = LMConfig(name='t', num_layers=8, d_model=32, n_heads=4, n_kv=2,
                       d_ff=64, vocab=128, dtype='float32')
        m = LM(cfg)
        p = m.init(jax.random.key(1))
        batch = lm_batch_for(cfg, 8, 16)
        mesh = make_mesh((4, 2, 1), ("pod", "data", "model"))
        loss_ref, _ = m.forward(p, batch)
        g_ref = jax.grad(lambda p: m.forward(p, batch)[0])(p)
        spec = PipelineSpec(num_stages=4, microbatches=8, virtual_stages=2)
        loss_fn = make_pipelined_loss(m, spec, mesh=mesh)
        with mesh_context(mesh):
            loss_pipe, _ = jax.jit(loss_fn)(p, batch)
            g_pipe = jax.jit(jax.grad(lambda p: loss_fn(p, batch)[0]))(p)
        d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         g_ref, g_pipe)
        print(json.dumps({"ref": float(loss_ref), "pipe": float(loss_pipe),
                          "gdiff": max(jax.tree.leaves(d))}))
    """, devices=8)
    res = json.loads(out.strip().splitlines()[-1])
    assert abs(res["ref"] - res["pipe"]) < 1e-5
    assert res["gdiff"] < 1e-5


@pytest.mark.slow
def test_planner_chosen_plan_matches_reference():
    """Grad equivalence for an AUTO-picked plan: (S, k, v) comes from the
    checked-in roofline fixture via the auto-planner (the path train.py
    --pipeline-k auto --virtual-stages auto takes), not from hand flags —
    guarding the planner-to-pipeline plumbing the way the tests above
    guard hand-picked plans.  The fixture's interior optimum is a plan no
    hand-tuner would pick (k=13, v=2: ragged, interleaved)."""
    import json as _json

    from repro.analysis.autotune import plan_inputs_from_record
    from repro.parallel.pipeline import PipelineSpec

    fixture = os.path.join(os.path.dirname(__file__), "fixtures",
                           "roofline_smoke.json")
    with open(fixture) as f:
        record = _json.load(f)
    spec, plan = PipelineSpec.auto_plan(plan_inputs_from_record(record))
    assert spec.num_stages == 2 and spec.virtual_stages > 1
    assert spec.microbatches not in (1, 2, 4, 8, 16)   # not a hand pick
    out = run_sub(f"""
        import jax, json
        import jax.numpy as jnp
        from repro.models import LM, LMConfig
        from repro.data import lm_batch_for
        from repro.parallel.compat import make_mesh, mesh_context
        from repro.parallel.pipeline import PipelineSpec, make_pipelined_loss

        cfg = LMConfig(name='t', num_layers=8, d_model=32, n_heads=4, n_kv=2,
                       d_ff=64, vocab=128, dtype='float32')
        m = LM(cfg)
        p = m.init(jax.random.key(1))
        batch = lm_batch_for(cfg, 26, 16)
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        loss_ref, _ = m.forward(p, batch)
        g_ref = jax.grad(lambda p: m.forward(p, batch)[0])(p)
        spec = PipelineSpec(num_stages={spec.num_stages},
                            microbatches={spec.microbatches},
                            virtual_stages={spec.virtual_stages})
        loss_fn = make_pipelined_loss(m, spec, mesh=mesh)
        with mesh_context(mesh):
            loss_pipe, _ = jax.jit(loss_fn)(p, batch)
            g_pipe = jax.jit(jax.grad(lambda p: loss_fn(p, batch)[0]))(p)
        d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         g_ref, g_pipe)
        print(json.dumps({{"loss_ref": float(loss_ref),
                           "loss_pipe": float(loss_pipe),
                           "gdiff": max(jax.tree.leaves(d))}}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert abs(res["loss_ref"] - res["loss_pipe"]) < 1e-6
    assert res["gdiff"] < 1e-7


@pytest.mark.slow
def test_data_parallel_grads_match_single_device():
    """GSPMD DP run == single-device run for the same global batch."""
    out = run_sub("""
        import jax, json
        import jax.numpy as jnp
        from repro.models import LM, LMConfig
        from repro.data import lm_batch_for
        from repro.parallel.compat import make_mesh
        from repro.parallel.context import ParallelCtx, use_ctx
        from repro.parallel.sharding import ShardingPolicy

        cfg = LMConfig(name='t', num_layers=2, d_model=32, n_heads=4, n_kv=2,
                       d_ff=64, vocab=128, dtype='float32')
        m = LM(cfg)
        p = m.init(jax.random.key(0))
        batch = lm_batch_for(cfg, 8, 16)
        loss1 = float(m.forward(p, batch)[0])
        mesh = make_mesh((4, 2), ("data", "model"))
        policy = ShardingPolicy(mesh)
        psh = policy.param_shardings(p)
        bsh = policy.batch_shardings(batch)
        p_s = jax.device_put(p, psh)
        b_s = jax.device_put(batch, bsh)
        with use_ctx(ParallelCtx(mesh=mesh)):
            lossN = float(jax.jit(lambda p, b: m.forward(p, b)[0])(p_s, b_s))
        print(json.dumps({"l1": loss1, "lN": lossN}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert abs(res["l1"] - res["lN"]) < 2e-4


@pytest.mark.slow
def test_moe_sharded_matches_local():
    """The shard_map MoE dispatch == the single-device local path."""
    out = run_sub("""
        import jax, json
        import jax.numpy as jnp
        from repro.models import LM, LMConfig
        from repro.data import lm_batch_for
        from repro.parallel.compat import make_mesh, mesh_context
        from repro.parallel.context import ParallelCtx, use_ctx
        from repro.parallel.sharding import ShardingPolicy

        cfg = LMConfig(name='t', num_layers=2, d_model=32, n_heads=4, n_kv=2,
                       d_ff=32, vocab=128, moe_experts=4, moe_topk=2,
                       dtype='float32')
        m = LM(cfg)
        p = m.init(jax.random.key(0))
        batch = lm_batch_for(cfg, 8, 16)
        loss1 = float(m.forward(p, batch)[0])
        mesh = make_mesh((2, 4), ("data", "model"))
        with use_ctx(ParallelCtx(mesh=mesh)):
            with mesh_context(mesh):
                lossN = float(jax.jit(lambda p, b: m.forward(p, b)[0])(p, batch))
        print(json.dumps({"l1": loss1, "lN": lossN}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    # capacity buckets differ between 1-shard and 8-shard dispatch; the
    # (rare) dropped-token difference bounds the deviation
    assert abs(res["l1"] - res["lN"]) < 5e-3


@pytest.mark.slow
def test_moe_global_aux_sharded_matches_local_aux():
    """moe_global_aux=True: the data-sharded dispatch psums the router
    statistics, so the sharded AUX equals the single-device full-batch
    aux exactly (per-shard capacity drops only perturb outputs, never the
    pre-capacity statistics); with the flag off the per-shard aux mean
    deviates — the ROADMAP gap, quantified here on a real mesh."""
    out = run_sub("""
        import jax, json
        import jax.numpy as jnp
        from repro.models import LM, LMConfig
        from repro.data import lm_batch_for
        from repro.models.blocks import apply_block
        from repro.parallel.compat import make_mesh, mesh_context
        from repro.parallel.context import ParallelCtx, use_ctx
        from repro.models.moe import apply_moe

        cfg = LMConfig(name='t', num_layers=2, d_model=32, n_heads=4, n_kv=2,
                       d_ff=32, vocab=128, moe_experts=8, moe_topk=2,
                       dtype='float32')
        m = LM(cfg)
        p = m.init(jax.random.key(0))
        moe_p = jax.tree.map(lambda a: a[0], p["blocks"])["moe"]
        x = jax.random.normal(jax.random.key(1), (8, 16, 32), jnp.float32)
        kw = dict(topk=2, cap_factor=4.0, act=cfg.act)
        _, aux_local = apply_moe(moe_p, x, **kw)
        mesh = make_mesh((4, 2), ("data", "model"))
        with use_ctx(ParallelCtx(mesh=mesh)):
            with mesh_context(mesh):
                _, aux_off = jax.jit(
                    lambda x: apply_moe(moe_p, x, **kw))(x)
                _, aux_on = jax.jit(
                    lambda x: apply_moe(moe_p, x, global_aux=True, **kw))(x)
        print(json.dumps({"local": float(aux_local),
                          "sharded_off": float(aux_off),
                          "sharded_on": float(aux_on)}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["sharded_on"] == pytest.approx(res["local"], rel=1e-5)
    gap_off = abs(res["sharded_off"] - res["local"])
    gap_on = abs(res["sharded_on"] - res["local"])
    assert gap_off > 1e-4          # the documented deviation is real...
    assert gap_on < gap_off / 10   # ...and the psum'd aux removes it
