"""Synthetic data pipelines: determinism, sharding, learnability."""
import numpy as np

from repro.data import TokenTaskConfig, image_batches, token_batches


def test_token_determinism():
    cfg = TokenTaskConfig(vocab=97)
    a = next(token_batches(cfg, 8, 16, seed=5))
    b = next(token_batches(cfg, 8, 16, seed=5))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = next(token_batches(cfg, 8, 16, seed=6))
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_token_labels_follow_chain():
    cfg = TokenTaskConfig(vocab=101, noise=0.0)
    b = next(token_batches(cfg, 4, 32, seed=0))
    expect = (cfg.a * b["tokens"] + cfg.c) % cfg.vocab
    np.testing.assert_array_equal(b["labels"], expect)


def test_sharded_workers_disjoint_streams():
    cfg = TokenTaskConfig(vocab=97)
    s0 = next(token_batches(cfg, 16, 8, seed=1, shard=0, num_shards=2))
    s1 = next(token_batches(cfg, 16, 8, seed=1, shard=1, num_shards=2))
    assert s0["tokens"].shape == (8, 8)       # batch // num_shards
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_images_class_structure():
    gen = image_batches(64, seed=0, noise=0.0)
    b = next(gen)
    assert b["images"].shape == (64, 32, 32, 3)
    assert b["labels"].min() >= 0 and b["labels"].max() < 10
    # same-class images identical without noise; cross-class differ
    labs = b["labels"]
    for c in np.unique(labs)[:3]:
        idx = np.where(labs == c)[0]
        if len(idx) >= 2:
            np.testing.assert_allclose(b["images"][idx[0]],
                                       b["images"][idx[1]])
