"""Wireless channel model (paper SII-B, Table I)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.wireless.channel import (ChannelParams, pathloss_db, shannon_rate,
                                    ue_rates)
from repro.wireless.fleet import BS_FLOPS, K_BS, K_UE, sample_fleet


def test_pathloss_formula():
    # h(d, f) = 28 + 22 log10(d) + 20 log10(f)
    assert pathloss_db(100.0, 3.5) == pytest.approx(
        28.0 + 22.0 * 2.0 + 20.0 * np.log10(3.5))


def test_rate_monotonic_in_distance():
    ch = ChannelParams()
    r_near = shannon_rate(20.0, 100.0, ch)
    r_far = shannon_rate(20.0, 500.0, ch)
    assert r_near > r_far > 0


def test_rate_monotonic_in_power_and_bandwidth():
    ch100 = ChannelParams(bandwidth_hz=100e6)
    ch300 = ChannelParams(bandwidth_hz=300e6)
    assert shannon_rate(23.0, 200.0, ch100) > shannon_rate(13.0, 200.0, ch100)
    assert shannon_rate(20.0, 200.0, ch300) > shannon_rate(20.0, 200.0, ch100)


def test_downlink_faster_than_uplink():
    """BS transmits at 46 dBm vs UE 13-23 dBm => downlink rate is higher."""
    ch = ChannelParams()
    r_u, r_d = ue_rates(np.array([23.0]), np.array([300.0]), ch)
    assert r_d[0] > r_u[0]


def test_table1_compute_constants():
    assert K_UE == 16.0 and K_BS == 32.0
    assert BS_FLOPS == pytest.approx(32.0 * 80e9)


@settings(deadline=None, max_examples=20)
@given(n=st.integers(1, 16), seed=st.integers(0, 10_000))
def test_fleet_sampling_ranges(n, seed):
    fleet = sample_fleet(n, seed=seed)
    assert fleet.n == n
    for ue in fleet.ues:
        assert 1e9 <= ue.clock_hz <= 2e9          # Table I F_i
        assert 13.0 <= ue.p_tx_dbm <= 23.0        # p_i
        assert 100.0 <= ue.distance_m <= 500.0    # d_i
        assert 1e9 <= ue.storage_flops <= 2e9     # c_i
    r_u, r_d = fleet.rates()
    assert np.all(r_u > 0) and np.all(r_d > 0)
