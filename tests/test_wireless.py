"""Wireless channel model (paper SII-B, Table I)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.wireless.channel import (BandwidthTrace, ChannelParams,
                                    LinkShaper, bandwidth_step_trace,
                                    pathloss_db, shannon_rate, ue_rates)
from repro.wireless.fleet import BS_FLOPS, K_BS, K_UE, sample_fleet


def test_pathloss_formula():
    # h(d, f) = 28 + 22 log10(d) + 20 log10(f)
    assert pathloss_db(100.0, 3.5) == pytest.approx(
        28.0 + 22.0 * 2.0 + 20.0 * np.log10(3.5))


def test_rate_monotonic_in_distance():
    ch = ChannelParams()
    r_near = shannon_rate(20.0, 100.0, ch)
    r_far = shannon_rate(20.0, 500.0, ch)
    assert r_near > r_far > 0


def test_rate_monotonic_in_power_and_bandwidth():
    ch100 = ChannelParams(bandwidth_hz=100e6)
    ch300 = ChannelParams(bandwidth_hz=300e6)
    assert shannon_rate(23.0, 200.0, ch100) > shannon_rate(13.0, 200.0, ch100)
    assert shannon_rate(20.0, 200.0, ch300) > shannon_rate(20.0, 200.0, ch100)


def test_downlink_faster_than_uplink():
    """BS transmits at 46 dBm vs UE 13-23 dBm => downlink rate is higher."""
    ch = ChannelParams()
    r_u, r_d = ue_rates(np.array([23.0]), np.array([300.0]), ch)
    assert r_d[0] > r_u[0]


def test_table1_compute_constants():
    assert K_UE == 16.0 and K_BS == 32.0
    assert BS_FLOPS == pytest.approx(32.0 * 80e9)


# ---------------------------------------------------------------------------
# BandwidthTrace semantics (pre-history extension + change_points)
# ---------------------------------------------------------------------------


def test_trace_at_prehistory_extends_first_rate():
    """``bw_Bps[0]`` is in force BEFORE ``steps[0]`` too — ``at`` has no
    undefined region, and ``steps[0]`` is never itself a value change."""
    tr = BandwidthTrace(steps=(10, 20), bw_Bps=(4e6, 1e6))
    assert tr.at(0) == 4e6
    assert tr.at(9) == 4e6
    assert tr.at(10) == 4e6          # not a change: same rate before/after
    assert tr.at(19) == 4e6
    assert tr.at(20) == 1e6
    assert tr.at(10_000) == 1e6


def test_trace_change_points_steps0_positive():
    """Regression: the old positional ``out[1:]`` dropped the FIRST entry
    even when a later entry was the real change; with ``steps[0] > 0``
    the first entry is pre-history initial state, never a change."""
    tr = BandwidthTrace(steps=(10, 20), bw_Bps=(4e6, 1e6))
    assert tr.change_points == (20,)
    # an explicit steps[0]==0 spelling of the same trace is equivalent
    tr0 = BandwidthTrace(steps=(0, 20), bw_Bps=(4e6, 1e6))
    assert tr0.change_points == (20,)
    assert all(tr.at(s) == tr0.at(s) for s in range(0, 40))


def test_trace_change_points_match_at_semantics():
    """``change_points`` == {s : at(s) != at(s-1)} by definition,
    including duplicate consecutive rates (no spurious points)."""
    tr = BandwidthTrace(steps=(5, 10, 15, 25), bw_Bps=(2e6, 2e6, 8e5, 2e6))
    expected = tuple(s for s in range(0, 30) if tr.at(s) != tr.at(s - 1))
    assert tr.change_points == expected == (15, 25)


def test_trace_validation():
    with pytest.raises(ValueError, match="ascending"):
        BandwidthTrace(steps=(5, 5), bw_Bps=(1e6, 2e6))
    with pytest.raises(ValueError, match="ascending"):
        BandwidthTrace(steps=(10, 5), bw_Bps=(1e6, 2e6))
    with pytest.raises(ValueError, match="> 0"):
        BandwidthTrace(steps=(0,), bw_Bps=(0.0,))
    with pytest.raises(ValueError, match="non-empty"):
        BandwidthTrace(steps=(), bw_Bps=())


def test_bandwidth_step_trace_single_change():
    tr = bandwidth_step_trace(4e6, 1e6, at_step=50)
    assert tr.change_points == (50,)
    assert tr.at(49) == 4e6 and tr.at(50) == 1e6


# ---------------------------------------------------------------------------
# LinkShaper: loopback -> emulated wireless link
# ---------------------------------------------------------------------------


def test_link_shaper_delay_and_set_rate():
    sh = LinkShaper(1e6, latency_s=0.01)
    assert sh.delay_s(500_000) == pytest.approx(0.01 + 0.5)
    sh.set_rate(2e6)                       # latency untouched
    assert sh.delay_s(500_000) == pytest.approx(0.01 + 0.25)
    sh.set_rate(2e6, latency_s=0.0)
    assert sh.delay_s(0) == 0.0
    with pytest.raises(ValueError):
        sh.set_rate(0.0)
    with pytest.raises(ValueError):
        sh.set_rate(1e6, latency_s=-1.0)


def test_link_shaper_from_channel_matches_shannon():
    ch = ChannelParams()
    sh = LinkShaper.from_channel(ch, 23.0, 200.0, efficiency=0.5)
    rate_Bps = shannon_rate(23.0, 200.0, ch) / 8.0 * 0.5
    assert sh.bw_Bps == pytest.approx(rate_Bps)
    assert sh.delay_s(int(rate_Bps)) == pytest.approx(1.0, rel=1e-6)


@settings(deadline=None, max_examples=20)
@given(n=st.integers(1, 16), seed=st.integers(0, 10_000))
def test_fleet_sampling_ranges(n, seed):
    fleet = sample_fleet(n, seed=seed)
    assert fleet.n == n
    for ue in fleet.ues:
        assert 1e9 <= ue.clock_hz <= 2e9          # Table I F_i
        assert 13.0 <= ue.p_tx_dbm <= 23.0        # p_i
        assert 100.0 <= ue.distance_m <= 500.0    # d_i
        assert 1e9 <= ue.storage_flops <= 2e9     # c_i
    r_u, r_d = fleet.rates()
    assert np.all(r_u > 0) and np.all(r_d > 0)
