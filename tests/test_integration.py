"""End-to-end integration: train -> checkpoint -> elastic restart;
compressed-gradient training; Lemma-1 pipeline-k bridge."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ao import pipeline_k_auto
from repro.data import TokenTaskConfig, token_batches
from repro.models import LM, LMConfig
from repro.parallel.steps import make_lm_train_step
from repro.training import adamw, checkpoint
from repro.training.compress import init_error_fb

CFG = LMConfig(name="itest", num_layers=2, d_model=64, n_heads=4, n_kv=2,
               d_ff=128, vocab=256, dtype="float32")


def make_state(model, opt, compress=False):
    params = model.init(jax.random.key(0))
    state = {"params": params, "opt_state": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    if compress:
        state["error_fb"] = init_error_fb(params)
    return state


def test_train_checkpoint_restart_bitexact(tmp_path):
    """Crash/restart at step 6 reproduces the uninterrupted run exactly."""
    model = LM(CFG)
    opt = adamw(1e-3)
    step = jax.jit(make_lm_train_step(model, opt))
    data = lambda: token_batches(TokenTaskConfig(vocab=CFG.vocab), 8, 16,
                                 seed=3)

    # uninterrupted 10 steps
    st = make_state(model, opt)
    it = data()
    for _ in range(10):
        st, _ = step(st, next(it))

    # interrupted: 6 steps, checkpoint, "crash", restore, 4 more
    st2 = make_state(model, opt)
    it = data()
    for _ in range(6):
        st2, _ = step(st2, next(it))
    checkpoint.save(str(tmp_path), 6, st2)
    restored = checkpoint.restore(str(tmp_path), 6, make_state(model, opt))
    assert int(restored["step"]) == 6
    for _ in range(4):
        restored, _ = step(restored, next(it))

    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     st["params"], restored["params"])
    assert max(jax.tree.leaves(d)) == 0.0


def test_elastic_restore_after_shrink(tmp_path):
    """Checkpoint taken on one layout restores onto another target tree
    (the pod-loss shrink flow: fault.plan_rescale + re-shard restore)."""
    from repro.training.fault import plan_rescale
    model = LM(CFG)
    opt = adamw(1e-3)
    st = make_state(model, opt)
    checkpoint.save(str(tmp_path), 1, st)
    new_shape = plan_rescale({"pod": 2, "data": 2, "model": 2}, 1)
    assert new_shape["pod"] == 1
    # restore into a freshly-initialized (differently-seeded) state tree:
    # values must come from the checkpoint, not the init
    fresh = make_state(model, opt)
    fresh["params"] = jax.tree.map(lambda x: x + 1.0, fresh["params"])
    restored = checkpoint.restore(str(tmp_path), 1, fresh)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     restored["params"], st["params"])
    assert max(jax.tree.leaves(d)) == 0.0


def test_compressed_training_converges():
    """int8+EF compressed grads still reduce the loss (EPSL generalized)."""
    model = LM(CFG)
    opt = adamw(3e-3)
    step = jax.jit(make_lm_train_step(model, opt, compress=True))
    st = make_state(model, opt, compress=True)
    it = token_batches(TokenTaskConfig(vocab=CFG.vocab), 8, 16, seed=5)
    first = last = None
    for i in range(30):
        st, mets = step(st, next(it))
        if first is None:
            first = float(mets["loss"])
        last = float(mets["loss"])
    assert last < first - 0.1
    assert "error_fb" in st
    # error feedback carry is alive and bounded
    efb_max = max(float(jnp.max(jnp.abs(e)))
                  for e in jax.tree.leaves(st["error_fb"]))
    assert 0.0 < efb_max < 1.0


def test_pipeline_k_auto_lemma1():
    # compute-rich regime: k capped only by granularity
    assert pipeline_k_auto(10.0, 1.0, k_cap=16) == 16
    # comm-bound: eta = 0.5 -> k = floor(1/(1-0.5)) = 2
    assert pipeline_k_auto(1.0, 2.0, k_cap=16) == 2
    # eta -> 1 from below: k grows (1/(1-0.9) = 10)
    assert pipeline_k_auto(0.9, 1.0, k_cap=64) == 10
    # degenerate link
    assert pipeline_k_auto(1.0, 0.0, k_cap=8) == 8


def test_train_launcher_compress_grads_flag(tmp_path):
    """--compress-grads is a real launcher flag (the compress.py docstring
    used to promise it without wiring): two steps run, the state carries
    the error-feedback tree, and the loss is finite."""
    from repro.launch.train import main as train_main

    metrics = tmp_path / "m.json"
    history = train_main([
        "--arch", "qwen1.5-4b", "--size", "smoke", "--steps", "2",
        "--batch", "4", "--seq", "16", "--log-every", "1",
        "--compress-grads", "--metrics-out", str(metrics)])
    assert len(history) == 2
    assert np.isfinite(history[-1]["loss"])


def test_compress_grads_resumes_from_pre_flag_checkpoint(tmp_path):
    """Turning on --compress-grads must not brick resume: checkpoints
    saved without the flag carry no error_fb tree — the launcher
    restores everything else and restarts EF at zero."""
    from repro.launch.train import main as train_main

    ckpt = str(tmp_path / "ck")
    args = ["--arch", "qwen1.5-4b", "--size", "smoke", "--batch", "4",
            "--seq", "16", "--log-every", "1", "--ckpt-dir", ckpt,
            "--ckpt-every", "1"]
    train_main(args + ["--steps", "1"])                     # no flag
    history = train_main(args + ["--steps", "2", "--compress-grads"])
    assert len(history) == 1                                # resumed at 1
    assert history[-1]["step"] == 2
    assert np.isfinite(history[-1]["loss"])
