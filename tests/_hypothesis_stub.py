"""Deterministic offline stand-in for the ``hypothesis`` property-testing
library.

Installed into ``sys.modules`` by ``conftest.py`` ONLY when the real
``hypothesis`` is unavailable, so the property-test modules (test_ao,
test_compress, test_kernels, test_schedule, test_sharding, test_wireless)
collect and run in hermetic environments.  It covers exactly the API
surface those tests use:

    from hypothesis import given, settings, strategies as st
    st.integers / st.floats / st.sampled_from / st.booleans / st.lists

Semantics: ``@given`` turns the test into a zero-argument function that
replays ``max_examples`` (from ``@settings``, default 10) examples drawn
from a fixed-seed PRNG — deterministic across runs, no shrinking, no
example database.  This trades hypothesis' adaptive search for
reproducibility; with the real library installed the stub never loads.
"""
from __future__ import annotations

import random
import types

_SEED = 0xC2B25  # fixed: stub runs are reproducible by construction


class _Strategy:
    def __init__(self, sample):
        self._sample = sample

    def example(self, rng: random.Random):
        return self._sample(rng)


def integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))

def floats(min_value, max_value):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))

def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rng: rng.choice(elements))

def booleans():
    return _Strategy(lambda rng: rng.random() < 0.5)

def lists(elements, min_size: int = 0, max_size: int = 10):
    return _Strategy(lambda rng: [
        elements.example(rng)
        for _ in range(rng.randint(min_size, max_size))])


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = integers
strategies.floats = floats
strategies.sampled_from = sampled_from
strategies.booleans = booleans
strategies.lists = lists


class settings:
    """Decorator: records max_examples on the (given-wrapped) test."""

    def __init__(self, deadline=None, max_examples: int = 10, **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._stub_max_examples = self.max_examples
        return fn


def given(**named_strategies):
    """Replay-based ``@given``: deterministic example sweep.

    The wrapper takes no parameters (the strategy-bound arguments must be
    the test's only ones), so pytest does not mistake them for fixtures.
    """
    def deco(fn):
        def run():
            rng = random.Random(_SEED)
            n = getattr(run, "_stub_max_examples", 10)
            for _ in range(n):
                fn(**{name: s.example(rng)
                      for name, s in named_strategies.items()})
        run.__name__ = fn.__name__
        run.__doc__ = fn.__doc__
        run.__module__ = fn.__module__
        # settings may sit on either side of given (hypothesis allows
        # both orders): inherit a mark already stamped on the raw fn
        if hasattr(fn, "_stub_max_examples"):
            run._stub_max_examples = fn._stub_max_examples
        return run
    return deco
