"""Cut-layer splitting: UE/BS split == whole model; params roundtrip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import image_batches, lm_batch_for
from repro.models import LM, LMConfig, resnet
from repro.sl import lm_split, resnet_split


@pytest.mark.parametrize("l", [1, 2, 3, 4, 5])
def test_resnet_split_equals_full(l):
    params = resnet.init_resnet18(jax.random.key(0))
    spec = resnet_split(l)
    batch = next(image_batches(8, seed=0))
    ue, bs = spec.split_params(params)
    acts = spec.ue_fwd(ue, batch["images"])
    loss_split, mets = spec.bs_loss(bs, acts, batch["labels"])
    loss_full, _ = resnet.loss_fn(params, batch)
    assert float(loss_split) == pytest.approx(float(loss_full), rel=1e-6)


def test_resnet_split_params_partition():
    """Every param lands on exactly one side; merge restores the whole."""
    params = resnet.init_resnet18(jax.random.key(0))
    for l in range(1, 6):
        spec = resnet_split(l)
        ue, bs = spec.split_params(params)
        assert set(ue) | set(bs) == set(params)
        assert not (set(ue) & set(bs))
        merged = spec.merge_params(ue, bs)
        d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         merged, params)
        assert max(jax.tree.leaves(d)) == 0.0


def test_lm_split_equals_full():
    cfg = LMConfig(name="t", num_layers=4, d_model=32, n_heads=4, n_kv=2,
                   d_ff=64, vocab=64, dtype="float32")
    model = LM(cfg)
    params = model.init(jax.random.key(1))
    batch = lm_batch_for(cfg, 4, 16)
    spec = lm_split(model, 2)
    ue, bs = spec.split_params(params)
    acts = spec.ue_fwd(ue, batch["tokens"])
    loss_split, _ = spec.bs_loss(bs, acts, batch["labels"])
    loss_full, mets = model.forward(params, batch)
    assert float(loss_split) == pytest.approx(float(mets["xent"]), rel=1e-5)
    merged = spec.merge_params(ue, bs)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     merged, params)
    assert max(jax.tree.leaves(d)) == 0.0


def test_lm_split_grads_flow_both_sides():
    cfg = LMConfig(name="t", num_layers=4, d_model=32, n_heads=4, n_kv=2,
                   d_ff=64, vocab=64, dtype="float32")
    model = LM(cfg)
    params = model.init(jax.random.key(1))
    batch = lm_batch_for(cfg, 4, 16)
    spec = lm_split(model, 2)
    ue, bs = spec.split_params(params)

    def loss(ue, bs):
        acts = spec.ue_fwd(ue, batch["tokens"])
        return spec.bs_loss(bs, acts, batch["labels"])[0]

    gue, gbs = jax.grad(loss, argnums=(0, 1))(ue, bs)
    for g in jax.tree.leaves(gue) + jax.tree.leaves(gbs):
        assert bool(jnp.all(jnp.isfinite(g)))
    assert max(float(jnp.max(jnp.abs(g))) for g in jax.tree.leaves(gue)) > 0
