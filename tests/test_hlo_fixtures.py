"""Regression: HLO collective parsing against checked-in text from both
pipeline lowerings, so the roofline's data source can't silently drift
when JAX changes its HLO spelling.

* ``hlo_legacy_0437.txt`` — captured from jax 0.4.37 / jaxlib 0.4.36
  (the fully-manual shard_map path): synchronous collectives, explicit
  ``replica_groups={{...}}`` lists, f32.
* ``hlo_current.txt`` — the explicit-sharding generation's spelling
  (partial-manual path): async ``-start``/``-done`` pairs (whose result
  is a (operand, result) tuple), iota ``replica_groups=[n,m]<=[k]``
  (with and without a ``T(...)`` transpose), bf16, and a scan lowered to
  a ``while`` carrying ``known_trip_count`` in its backend_config.

The expected numbers are hand-derived from the shapes in the fixtures;
see the inline arithmetic.
"""
import os

import pytest

from repro.analysis.hlo_costs import analyze, parse_hlo
from repro.analysis.roofline import (collective_bytes_from_hlo,
                                     weighted_collective_bytes)

FIXDIR = os.path.join(os.path.dirname(__file__), "fixtures")


def _read(name):
    with open(os.path.join(FIXDIR, name)) as f:
        return f.read()


def test_legacy_0437_collective_bytes():
    """f32 program: ppermute f32[4,8] (128 B), all-reduce f32[4,8]
    (128 B), all-gather f32[32,8] (1024 B)."""
    by_kind = collective_bytes_from_hlo(_read("hlo_legacy_0437.txt"))
    assert by_kind["collective-permute"] == 4 * 8 * 4
    assert by_kind["all-reduce"] == 4 * 8 * 4
    assert by_kind["all-gather"] == 32 * 8 * 4
    assert by_kind["reduce-scatter"] == 0
    assert by_kind["all-to-all"] == 0
    # ring all-reduce weighted 2x
    assert weighted_collective_bytes(by_kind) == 2 * 128 + 128 + 1024


def test_legacy_0437_static_analysis():
    """Three f32 dots: [4,16]@[16,8], [4,8]@[16,8]^T, [4,16]@[16,8] —
    1024 FLOPs each; no while loops on this snippet."""
    res = analyze(_read("hlo_legacy_0437.txt"))
    assert res["flops"] == pytest.approx(3 * 2 * 4 * 8 * 16)
    assert res["n_while"] == 0
    assert res["coll_by_kind"]["collective-permute"] == 128.0
    assert res["coll_bytes"] == 2 * 128 + 128 + 1024


def test_current_collective_bytes():
    """bf16 + async spelling: the -start result tuple carries operand AND
    result buffers (64*64 + 128*64 halves = 24576 B all-gather); the
    -done lines must NOT be double-counted; ppermute/all-reduce
    bf16[8,64] = 1024 B each.  This parser is trip-count-unaware by
    design (it feeds the quick per-kind breakdown, not the roofline)."""
    by_kind = collective_bytes_from_hlo(_read("hlo_current.txt"))
    assert by_kind["all-gather"] == (64 * 64 + 128 * 64) * 2
    assert by_kind["collective-permute"] == 8 * 64 * 2
    assert by_kind["all-reduce"] == 8 * 64 * 2
    assert weighted_collective_bytes(by_kind) == 2 * 1024 + 24576 + 1024


def test_current_static_analysis_trip_counts():
    """The while's backend_config known_trip_count (9) multiplies the
    scan-body dot FLOPs and the in-loop ppermute bytes; entry-level
    collectives stay x1."""
    res = analyze(_read("hlo_current.txt"))
    assert res["n_while"] == 1
    assert res["flops"] == pytest.approx(9 * 2 * 8 * 64 * 64)
    assert res["coll_by_kind"]["collective-permute"] == 9 * 1024.0
    assert res["coll_by_kind"]["all-gather"] == 24576.0
    assert res["coll_by_kind"]["all-reduce"] == 1024.0
    assert res["coll_bytes"] == 2 * 1024 + 24576 + 9 * 1024


def test_current_fixture_parses_all_computations():
    comps = parse_hlo(_read("hlo_current.txt"))
    # entry first, then the add region, while cond + body
    names = list(comps)
    assert names[0].startswith("main")
    assert any("while_body" in n for n in names)
    assert any("while_cond" in n for n in names)


def test_iota_replica_groups_cross_pod_detection():
    """The iota form [2,2]<=[4] groups {0,1},{2,3}: crosses a pod
    boundary at pod_size=2, not at pod_size=4."""
    res2 = analyze(_read("hlo_current.txt"), pod_size=2)
    res4 = analyze(_read("hlo_current.txt"), pod_size=4)
    assert res2["coll_dcn_bytes"] > 0
    # at pod_size=4 all four devices share one pod -> nothing crosses
    assert res4["coll_dcn_bytes"] == 0
