"""Async multi-client streaming runtime (repro.runtime).

Four lanes:

* jax-free protocol tests — frame round-trip over every codec grammar
  (incl. the net-loss raw fallback at a degenerate block), header
  validation, billed-vs-measured byte math, gradient error feedback.
* host-vs-device codec parity — the numpy ``host_*`` entry points in
  ``parallel/wire.py`` against the jnp kernels (bit-exact for int8 and
  top-k; fp8 bounded by one quantization step, XLA:CPU's f32->f8
  convert rounds near-ties differently from ml_dtypes' RTNE).
* component tests on a real loopback socket — bounded-inbox
  backpressure, ragged-arrival order independence, wire honesty
  (measured socket payload bytes == ``autotune.wire_bytes_per_element``
  /``_bwd`` billing at 1% rtol) for none / int8 / fp8 / int8+topk0.25.
* slow lane — 4 UE clients x >= 20 steps over loopback matching joint
  full-batch training to tolerance (equal shards + elementwise AdamW
  make the streamed trajectory exact up to f32 reduction order; the
  in-process pipeline path equals that same joint step by
  tests/test_pipeline.py), and the re-planner AC: ``LinkEstimator``
  hints come from MEASURED socket hops and track a mid-run
  ``LinkShaper.set_rate`` change — no ``BandwidthTrace`` script in the
  loop.
"""
import asyncio
import json

import numpy as np
import pytest

from repro.runtime import protocol
from repro.runtime.qos import QoSMonitor

CODECS = ["none", "int8", "fp8", "int8+topk0.25"]


def _tiny_cfg(d_model=32, vocab=64, num_layers=4):
    from repro.models import LMConfig
    return LMConfig(name="t", num_layers=num_layers, d_model=d_model,
                    n_heads=4, n_kv=2, d_ff=64, vocab=vocab,
                    dtype="float32")


# ---------------------------------------------------------------------------
# Protocol: frame round-trip + validation (numpy only)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("wire", CODECS)
def test_act_frame_round_trip(wire):
    rng = np.random.default_rng(0)
    x = rng.standard_normal((4, 16, 32)).astype(np.float32)
    arrays, meta = protocol.encode_act_payload(x, wire)
    arrays["labels"] = rng.integers(0, 64, (4, 16)).astype(np.int32)
    buf = protocol.pack_frame(protocol.ACT, 3, 7, meta=meta, arrays=arrays)
    frame = protocol.unpack_frame(buf[4:], wire_nbytes=len(buf))
    assert (frame.ftype, frame.client, frame.step) == (protocol.ACT, 3, 7)
    assert frame.meta["codec"] == wire
    np.testing.assert_array_equal(frame.arrays["labels"],
                                  arrays["labels"])
    out = protocol.decode_act_payload(frame)
    assert out.dtype == x.dtype and out.shape == x.shape
    if wire == "none":
        np.testing.assert_array_equal(out, x)
    else:
        # dense 8-bit quantization: reconstruction within one quantizer
        # step of the per-block absmax (int8: amax/127; fp8-e4m3 has a
        # 3-bit mantissa, so its step near the clip point is ~amax/16)
        amax = float(np.max(np.abs(x)))
        tol = amax / 100 if wire.startswith("int8") else amax / 14
        assert float(np.max(np.abs(out - x))) < tol
    # payload vs aux split: labels are never billed codec bytes
    assert frame.aux_nbytes == arrays["labels"].nbytes
    assert frame.payload_nbytes == sum(
        a.nbytes for n, a in arrays.items() if n != "labels")


@pytest.mark.parametrize("wire", ["int8+topk0.25", "fp8+topk0.5"])
def test_grad_frame_round_trip_topk(wire):
    rng = np.random.default_rng(1)
    g = rng.standard_normal((8, 64)).astype(np.float32)
    arrays, meta, ef = protocol.encode_grad_payload(g, wire, None)
    assert meta["kind"] == "topk" and ef is not None
    buf = protocol.pack_frame(protocol.GRAD, 0, 0, meta=meta, arrays=arrays)
    frame = protocol.unpack_frame(buf[4:])
    out = protocol.decode_grad_payload(frame)
    assert out.shape == g.shape
    # exactly round(frac*d) nonzero entries per row survive
    from repro.parallel.wire import parse_wire_dtype
    _, frac = parse_wire_dtype(wire)
    kk = round(frac * g.shape[-1])
    assert int(np.count_nonzero(out)) <= kk * g.shape[0]
    # what was shipped + what EF retains == the input (telescoping)
    np.testing.assert_allclose(out.astype(np.float32) + ef, g, atol=1e-5)


def test_grad_error_feedback_telescopes_across_rounds():
    """dec1 + dec2 == g1 + g2 - ef2 exactly: no gradient mass is lost,
    only delayed — the streaming twin of ``coded_ppermute_ef``."""
    rng = np.random.default_rng(2)
    g1 = rng.standard_normal((4, 32)).astype(np.float32)
    g2 = rng.standard_normal((4, 32)).astype(np.float32)
    a1, m1, ef1 = protocol.encode_grad_payload(g1, "int8+topk0.25", None)
    d1 = protocol.decode_grad_payload(protocol.unpack_frame(
        protocol.pack_frame(protocol.GRAD, 0, 0, m1, a1)[4:]))
    a2, m2, ef2 = protocol.encode_grad_payload(g2, "int8+topk0.25", ef1)
    d2 = protocol.decode_grad_payload(protocol.unpack_frame(
        protocol.pack_frame(protocol.GRAD, 0, 1, m2, a2)[4:]))
    np.testing.assert_allclose(
        d1.astype(np.float32) + d2.astype(np.float32),
        g1 + g2 - ef2, atol=1e-5)


def test_net_loss_raw_fallback_on_wire():
    """Degenerate block (prime d > 256 -> block 1, 1+4/1 >= itemsize):
    the frame ships RAW and EF passes through unchanged, mirroring the
    in-process ``codec_net_loss`` rule."""
    from repro.parallel.wire import codec_net_loss
    d = 263
    assert codec_net_loss(d, 4)
    rng = np.random.default_rng(3)
    x = rng.standard_normal((2, d)).astype(np.float32)
    arrays, meta = protocol.encode_act_payload(x, "int8")
    assert meta["kind"] == "raw" and set(arrays) == {"raw"}
    frame = protocol.unpack_frame(protocol.pack_frame(
        protocol.ACT, 0, 0, meta, arrays)[4:])
    np.testing.assert_array_equal(protocol.decode_act_payload(frame), x)
    ef_in = np.ones_like(x)
    garrays, gmeta, ef_out = protocol.encode_grad_payload(
        x, "int8+topk0.25", ef_in)
    assert gmeta["kind"] == "raw"
    assert ef_out is ef_in
    gframe = protocol.unpack_frame(protocol.pack_frame(
        protocol.GRAD, 0, 0, gmeta, garrays)[4:])
    np.testing.assert_array_equal(protocol.decode_grad_payload(gframe), x)


def test_frame_header_validation():
    buf = protocol.pack_frame(protocol.HELLO, 1, 0, meta={"a": 1})
    body = bytearray(buf[4:])
    with pytest.raises(ValueError, match="magic"):
        protocol.unpack_frame(b"XXXX" + bytes(body[4:]))
    bad_ver = bytearray(body)
    bad_ver[4] = 99
    with pytest.raises(ValueError, match="version"):
        protocol.unpack_frame(bytes(bad_ver))
    with pytest.raises(ValueError, match="length mismatch"):
        protocol.unpack_frame(bytes(body) + b"\x00")
    # meta survives exactly (JSON-typed)
    frame = protocol.unpack_frame(bytes(body))
    assert frame.meta == {"a": 1}


def test_billed_hop_bytes_matches_hand_math():
    # d=64: block 64; int8 fwd: 1 + 4/64; int8+topk0.25 bwd:
    # 0.25*(1+2) + 4/64  (16 of 64 kept, int16 idx, one f32 row scale)
    n, d = 4 * 16 * 64, 64
    assert protocol.billed_hop_bytes(n, d, "none", 4.0) == 4.0 * n
    assert protocol.billed_hop_bytes(n, d, "int8", 4.0) == \
        pytest.approx((1 + 4 / 64) * n)
    assert protocol.billed_hop_bytes(n, d, "int8+topk0.25", 4.0,
                                     backward=True) == \
        pytest.approx((0.25 * 3 + 4 / 64) * n)


# ---------------------------------------------------------------------------
# Host codec parity vs the jnp kernels
# ---------------------------------------------------------------------------


def test_host_codec_matches_device_int8_exact():
    import jax.numpy as jnp
    from repro.parallel import wire
    rng = np.random.default_rng(4)
    x = rng.standard_normal((6, 16, 64)).astype(np.float32)
    hq, hs = wire.host_encode(x, "int8")
    dq, ds = wire.encode(jnp.asarray(x), "int8")
    np.testing.assert_array_equal(hq, np.asarray(dq))
    np.testing.assert_array_equal(hs, np.asarray(ds))
    hdec = wire.host_decode(hq, hs, np.float32)
    ddec = np.asarray(wire.decode(dq, ds, jnp.float32))
    np.testing.assert_array_equal(hdec, ddec)


def test_host_codec_matches_device_topk_exact():
    import jax.numpy as jnp
    from repro.parallel import wire
    rng = np.random.default_rng(5)
    g = rng.standard_normal((8, 64)).astype(np.float32)
    hq, hidx, hs = wire.host_topk_encode(g, "int8+topk0.25")
    dq, didx, ds = wire.topk_encode(jnp.asarray(g), "int8+topk0.25")
    np.testing.assert_array_equal(hidx, np.asarray(didx))
    np.testing.assert_array_equal(hq, np.asarray(dq))
    np.testing.assert_array_equal(hs, np.asarray(ds))
    hdec = wire.host_topk_decode(hq, hidx, hs, 64, np.float32)
    ddec = np.asarray(wire.topk_decode(dq, didx, ds, 64, jnp.float32))
    np.testing.assert_array_equal(hdec, ddec)


def test_host_codec_fp8_bounded():
    """XLA:CPU's f32->f8 convert rounds near-ties differently from
    ml_dtypes' round-to-nearest-even, so fp8 payloads may differ by one
    ULP; scales are exact and the reconstruction gap stays within one
    quantization step."""
    import jax.numpy as jnp
    from repro.parallel import wire
    rng = np.random.default_rng(6)
    x = rng.standard_normal((4, 16, 64)).astype(np.float32)
    hq, hs = wire.host_encode(x, "fp8")
    dq, ds = wire.encode(jnp.asarray(x), "fp8")
    np.testing.assert_array_equal(hs, np.asarray(ds))
    hdec = wire.host_decode(hq, hs, np.float32)
    ddec = np.asarray(wire.decode(dq, ds, jnp.float32))
    # one e4m3 ULP at the clip bin: 448 = 2^8 * 1.75, 3-bit mantissa
    # -> ULP = 2^8 / 8 = 32 quantizer units
    step = np.abs(hs).max() * 32
    assert float(np.max(np.abs(hdec - ddec))) <= float(step)
    assert float(np.max(np.abs(hdec - x))) < 0.2


# ---------------------------------------------------------------------------
# Dispatcher components over a real loopback socket
# ---------------------------------------------------------------------------


def _fake_split():
    """Minimal SplitSpec stand-in for transport-only dispatcher tests."""
    import jax.numpy as jnp
    import types

    def bs_loss(params, acts, labels):
        return jnp.sum(acts * params["w"]), {}

    return types.SimpleNamespace(bs_loss=bs_loss)


def test_bounded_inbox_backpressure():
    """A client pushing frames faster than the trainer drains them fills
    its bounded inbox: the QoS monitor counts the backpressure event and
    the reader stops enqueueing (inbox never exceeds queue_depth)."""
    import jax.numpy as jnp
    from repro.runtime.bs import BSDispatcher
    from repro.training.optim import adamw

    async def scenario():
        disp = BSDispatcher(_fake_split(), {"w": jnp.ones(())}, adamw(1e-3),
                            n_clients=1, queue_depth=1)
        await disp.start()
        reader, writer = await asyncio.open_connection(disp.host, disp.port)
        writer.write(protocol.pack_frame(protocol.HELLO, 0, 0))
        acts = np.zeros((1, 4, 8), np.float32)
        for step in range(3):
            arrays, meta = protocol.encode_act_payload(acts, "none")
            arrays["labels"] = np.zeros((1, 4), np.int32)
            writer.write(protocol.pack_frame(protocol.ACT, 0, step,
                                             meta, arrays))
        await writer.drain()
        await asyncio.sleep(0.3)        # let the reader hit the full inbox
        inbox, _w = disp._clients[0]
        assert inbox.qsize() == 1       # bounded: depth never exceeded
        assert disp.qos.clients[0].backpressure_events >= 1
        assert disp.qos.clients[0].queue_high_water == 1
        # draining one slot unblocks the reader and admits the next frame
        await inbox.get()
        await asyncio.sleep(0.2)
        assert inbox.qsize() == 1
        writer.close()
        await disp.close()

    asyncio.run(scenario())


async def _stream(cfg, *, shapers, steps, wire_dtype="none", lr=1e-3,
                  seed=0, bpc=2, seq=16, cut=2, queue_depth=2,
                  replanner=None, bs_shaper=None, on_started=None):
    """run_streaming with a PER-CLIENT shaper list (ragged arrivals)."""
    import jax
    from repro.models import LM
    from repro.runtime.bs import BSDispatcher
    from repro.runtime.driver import client_batches
    from repro.runtime.ue import UEClient, UESync
    from repro.sl import lm_split
    from repro.training.optim import adamw

    n = len(shapers)
    model = LM(cfg)
    params = model.init(jax.random.key(seed))
    spec = lm_split(model, cut)
    ue_params, bs_params = spec.split_params(params)
    disp = BSDispatcher(spec, bs_params, adamw(lr), n_clients=n,
                        wire_dtype=wire_dtype, queue_depth=queue_depth,
                        replanner=replanner, shaper=bs_shaper)
    sync = UESync(ue_params, adamw(lr), n)
    ue_fwd = jax.jit(spec.ue_fwd)

    def pullback(p, tokens, g):
        return jax.vjp(lambda q: spec.ue_fwd(q, tokens), p)[1](g)[0]

    ue_pb = jax.jit(pullback)
    clients = [UEClient(cid, spec,
                        client_batches(cfg, cid, n, bpc, seq, seed),
                        sync, wire_dtype=wire_dtype, shaper=shapers[cid],
                        ue_fwd=ue_fwd, ue_pullback=ue_pb)
               for cid in range(n)]
    host, port = await disp.start()
    if on_started is not None:
        on_started(disp, clients)
    try:
        await asyncio.gather(disp.train(steps),
                             *(c.run(host, port, steps) for c in clients))
    finally:
        await disp.close()
    return disp, sync, clients


def test_ragged_arrival_order_independence():
    """Slowing down a DIFFERENT client must not change the trained
    result: per-arrival micro-steps all use the pre-round params and the
    round reduction is in sorted-client order."""
    from repro.wireless import LinkShaper
    cfg = _tiny_cfg()
    slow, fast = LinkShaper(2e5), None
    d1, s1, _ = asyncio.run(_stream(cfg, shapers=[slow, fast, fast],
                                    steps=3))
    d2, s2, _ = asyncio.run(_stream(cfg, shapers=[fast, fast, slow],
                                    steps=3))
    np.testing.assert_allclose(d1.losses, d2.losses, rtol=0, atol=1e-6)
    import jax
    for a, b in zip(jax.tree.leaves(d1.bs_params),
                    jax.tree.leaves(d2.bs_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)


@pytest.mark.parametrize("wire", CODECS)
def test_wire_honesty_on_socket(wire):
    """Measured codec-payload bytes of every hop that crossed the REAL
    socket match the planner's ``wire_bytes_per_element(_bwd)`` billing
    at 1% rtol (byte-exact in practice; framing/labels are accounted
    separately as overhead, mirroring ``hop_overhead_s``)."""
    from repro.runtime.driver import run_streaming
    cfg = _tiny_cfg(d_model=64, vocab=64)
    res = asyncio.run(run_streaming(
        cfg, cut=2, n_clients=2, steps=2, batch_per_client=2, seq=16,
        wire_dtype=wire))
    assert all(np.isfinite(res["losses"]))
    honesty = res["wire_honesty"]
    assert honesty["uplink"] and honesty["downlink"]
    for direction, rows in honesty.items():
        for row in rows:
            assert row["ok"], (wire, direction, row)
    qos = res["qos"]
    json.dumps(qos)                      # snapshot is plain JSON
    assert qos["rounds"] == 2
    assert qos["totals"]["frames_in"] == 2 * 2
    assert sum(c["straggler_rounds"]
               for c in qos["clients"].values()) == qos["rounds"]


def test_client_batches_union_is_full_batch():
    from repro.data import lm_batch_for
    from repro.runtime.driver import client_batches
    cfg = _tiny_cfg()
    n, bpc, seq, seed = 3, 2, 16, 7
    iters = [client_batches(cfg, cid, n, bpc, seq, seed)
             for cid in range(n)]
    for step in range(2):
        shards = [next(it) for it in iters]
        ref = lm_batch_for(cfg, n * bpc, seq, seed=seed + step)
        np.testing.assert_array_equal(
            np.concatenate([t for t, _l in shards]), ref["tokens"])
        np.testing.assert_array_equal(
            np.concatenate([l for _t, l in shards]), ref["labels"])


# ---------------------------------------------------------------------------
# Slow lane: e2e parity + measured-hop re-planning
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_e2e_four_clients_matches_joint_training():
    """4 UE clients x 20 steps over loopback: finite losses, and the
    whole trajectory (losses AND final params) matches joint full-batch
    training of the unsplit objective to f32 reduction-order tolerance.
    The in-process pipeline path equals this same joint step
    (tests/test_pipeline.py), so this transitively pins streaming ==
    pipeline."""
    import jax
    import jax.numpy as jnp
    from repro.data import lm_batch_for
    from repro.models import LM
    from repro.runtime.driver import run_streaming
    from repro.sl import lm_split
    from repro.training.optim import adamw

    cfg = _tiny_cfg()
    STEPS, N, BPC, SEQ, SEED, LR, CUT = 20, 4, 2, 16, 0, 1e-3, 2
    res = asyncio.run(run_streaming(
        cfg, cut=CUT, n_clients=N, steps=STEPS, batch_per_client=BPC,
        seq=SEQ, seed=SEED, wire_dtype="none", lr=LR))
    assert len(res["losses"]) == STEPS
    assert all(np.isfinite(res["losses"]))
    # every client saw every round's loss
    for cid, cl in res["client_losses"].items():
        assert len(cl) == STEPS

    model = LM(cfg)
    params = model.init(jax.random.key(SEED))
    spec = lm_split(model, CUT)
    ue, bs = spec.split_params(params)
    opt = adamw(LR)
    opt_ue, opt_bs = opt.init(ue), opt.init(bs)

    def loss_fn(ue, bs, tokens, labels):
        return spec.bs_loss(bs, spec.ue_fwd(ue, tokens), labels)[0]

    grad = jax.jit(jax.value_and_grad(loss_fn, argnums=(0, 1)))
    upd = jax.jit(opt.update)
    ref_losses = []
    for step in range(STEPS):
        b = lm_batch_for(cfg, N * BPC, SEQ, seed=SEED + step)
        loss, (gue, gbs) = grad(ue, bs, b["tokens"], b["labels"])
        s = jnp.asarray(step, jnp.int32)
        ue, opt_ue = upd(gue, opt_ue, ue, s)
        bs, opt_bs = upd(gbs, opt_bs, bs, s)
        ref_losses.append(float(loss))

    np.testing.assert_allclose(res["losses"], ref_losses, atol=1e-5)
    for a, b_ in zip(jax.tree.leaves(res["params"]["ue"]),
                     jax.tree.leaves(ue)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   atol=1e-5)
    for a, b_ in zip(jax.tree.leaves(res["params"]["bs"]),
                     jax.tree.leaves(bs)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   atol=1e-5)


@pytest.mark.slow
def test_replanner_tracks_injected_delay_change():
    """AC: the re-planner's ``PlanInputs`` reflect a mid-run artificial
    delay change purely from MEASURED socket hops.  A ``LinkShaper`` at
    bw0 is dropped to bw0/4 after round 5; the ``LinkEstimator`` (fed
    only via ``observe_hop`` from frame timestamps — ``observe_bandwidth``
    is spied to prove no scripted feed) must show the bandwidth drop and
    ``refreshed_inputs().link_s`` must grow accordingly."""
    from repro.analysis.autotune import WIRE_AUTO, PlanInputs, choose_plan
    from repro.runtime.driver import run_streaming
    from repro.training.replan import (LinkEstimator, ReplanConfig,
                                       Replanner)
    from repro.wireless import LinkShaper

    cfg = _tiny_cfg()
    bw0 = 1e5
    shaper = LinkShaper(bw0)
    inp = PlanInputs(num_stages=2, stage_fwd_s=0.1, stage_bwd_s=0.2,
                     link_s=0.01, hop_overhead_s=0.002, k_cap=16,
                     v_cap=4, num_layers=8, act_bytes=2.0,
                     act_hop_bytes=4.0e8, d_model=1024)
    rp = Replanner(inp, choose_plan(inp, wire_candidates=WIRE_AUTO).plan,
                   ReplanConfig(every=5, hysteresis=0.1))
    # small window so the post-change samples dominate the fit quickly
    rp.link = LinkEstimator(ewma=0.7, window=8)
    scripted_calls = []
    orig_bw = rp.link.observe_bandwidth
    rp.link.observe_bandwidth = (
        lambda *a, **k: scripted_calls.append(a) or orig_bw(*a, **k))

    snaps = {}

    def on_started(disp, clients):
        async def watch():
            while len(disp.losses) < 5:
                await asyncio.sleep(0.01)
            snaps["link_s"] = rp.refreshed_inputs().link_s
            snaps["bw"] = rp.link.hints()["link_bw_Bps"]
            shaper.set_rate(bw0 / 4)
        asyncio.ensure_future(watch())

    asyncio.run(run_streaming(
        cfg, cut=2, n_clients=2, steps=10, batch_per_client=2, seq=16,
        seed=0, wire_dtype="none", lr=1e-3, shaper=shaper, replanner=rp,
        on_started=on_started))

    assert not scripted_calls            # nothing scripted fed the link
    assert len(rp.link._samples) > 0     # hops were measured
    bw_after = rp.link.hints()["link_bw_Bps"]
    link_s_after = rp.refreshed_inputs().link_s
    # a 4x rate drop must show through scheduling/compute noise
    assert bw_after < 0.5 * snaps["bw"], (bw_after, snaps["bw"])
    assert link_s_after > 2.0 * snaps["link_s"], \
        (link_s_after, snaps["link_s"])
    # and the fold-in really derives link_s from the measured bandwidth
    assert link_s_after == pytest.approx(inp.act_hop_bytes / bw_after)
