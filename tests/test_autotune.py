"""Roofline-driven auto-planner (repro.analysis.autotune).

Driven by the checked-in dry-run fixture tests/fixtures/roofline_smoke.json
— no GPU and no compile in tier-1.  The acceptance property: the planner's
chosen (k, v) beats or ties every neighboring (k±1, v/2, 2v) plan under
the repo's own evaluators (simulate_c2p2sl directly, and batch_wall_time
through the as_wireless bridge).
"""
import json
import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.analysis.autotune import (WIRE_AUTO, AutoPlan, PlanInputs,
                                     as_wireless, choose_plan, hop_ratio,
                                     load_record, neighbor_plans,
                                     plan_inputs_from_cfg,
                                     plan_inputs_from_record,
                                     plan_task_times, plan_wall_time,
                                     schedule_ticks, tick_wall_time,
                                     wire_bytes_per_element,
                                     wire_link_scale, wire_plan_sweep)
from repro.core.schedule import simulate_c2p2sl
from repro.sl import batch_wall_time

FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures",
                       "roofline_smoke.json")

# Golden plan for the checked-in fixture (interior in both k and v, so
# every neighbor is feasible and the dominance test is non-vacuous).
GOLD_K, GOLD_V = 13, 2


def fixture_record():
    with open(FIXTURE) as f:
        return json.load(f)


def fixture_inputs():
    return plan_inputs_from_record(fixture_record())


def test_record_extraction_round_trips():
    """The fixture encodes stage_fwd=0.1s, stage_bwd=0.2s, link=0.01s: the
    masked-tick compute normalization (k*v/ticks) and the ppermute-bytes
    inversion (pp * k / (2*ticks) / dcn_bw) must recover them exactly."""
    inp = fixture_inputs()
    assert inp.num_stages == 2
    assert inp.stage_fwd_s == pytest.approx(0.1)
    assert inp.stage_bwd_s == pytest.approx(0.2)
    assert inp.link_s == pytest.approx(0.01)
    assert inp.hop_overhead_s == pytest.approx(0.002)
    assert (inp.k_cap, inp.v_cap, inp.num_layers) == (16, 4, 8)


def test_record_extraction_includes_collective_term():
    """Collective-bound records: the intra-stage (ICI) collective time is
    stage work too — the stage time is the max of all three roofline
    terms, not just compute/memory."""
    rec = fixture_record()
    rec["roofline"]["t_collective_s"] = 0.9                 # 0.9 * 8/9 = 0.8
    inp = plan_inputs_from_record(rec)
    assert inp.stage_fwd_s + inp.stage_bwd_s == pytest.approx(0.8)


def test_record_extraction_uses_compiled_stage_count():
    """Re-targeting S must not corrupt extraction: the tick-schedule
    normalization always uses the stage count the record was COMPILED
    with (here 2); the target S only re-labels the inputs (stage wall
    time is S-invariant under a fixed chip budget)."""
    inp4 = plan_inputs_from_record(fixture_record(), num_stages=4)
    assert inp4.num_stages == 4
    assert inp4.stage_fwd_s == pytest.approx(0.1)     # NOT 9/11-skewed
    assert inp4.link_s == pytest.approx(0.01)


def test_fixture_golden_plan():
    plan = choose_plan(fixture_inputs())
    assert (plan.num_stages, plan.k, plan.v) == (2, GOLD_K, GOLD_V)
    assert plan.wall_s < plan.baseline_s          # pipelining pays
    assert plan.speedup > 1.9                     # ~2x on this fixture
    assert 0.0 < plan.bubble < 0.1


def test_chosen_beats_neighbors_under_simulate():
    """Acceptance: (k, v) never loses to (k±1, v/2, 2v) under the event
    simulator applied to each candidate's own hop-billed task times."""
    inp = fixture_inputs()
    plan = choose_plan(inp)
    neigh = neighbor_plans(inp, plan.k, plan.v)
    # interior optimum -> all four neighbors exist
    assert sorted(neigh) == sorted([(GOLD_K - 1, GOLD_V),
                                    (GOLD_K + 1, GOLD_V),
                                    (GOLD_K, 1), (GOLD_K, 2 * GOLD_V)])
    for k, v in neigh:
        ms, _ = simulate_c2p2sl(plan_task_times(inp, k, v), k,
                                virtual_stages=v)
        assert plan.wall_s <= ms * (1 + 1e-9), (k, v)


def test_chosen_beats_neighbors_under_batch_wall_time():
    """Same property through the wireless-side evaluator: as_wireless
    exports each candidate as (profile, fleet, plan) and batch_wall_time
    judges it."""
    inp = fixture_inputs()
    plan = choose_plan(inp)
    chosen = batch_wall_time(*as_wireless(inp, plan.k, plan.v))
    assert chosen == pytest.approx(plan.wall_s, rel=1e-12)
    for k, v in neighbor_plans(inp, plan.k, plan.v):
        assert chosen <= batch_wall_time(*as_wireless(inp, k, v)) \
            * (1 + 1e-9), (k, v)


def test_chosen_is_global_argmin():
    """Stronger than neighbors: exhaustive grid re-evaluation."""
    inp = fixture_inputs()
    plan = choose_plan(inp)
    for v in inp.feasible_v():
        for k in range(1, inp.k_cap + 1):
            assert plan.wall_s <= plan_wall_time(inp, k, v) * (1 + 1e-9), \
                (k, v)


def test_plan_wall_time_is_batch_wall_time():
    """The planner objective IS the repo's schedule-layer evaluator."""
    inp = fixture_inputs()
    for k, v in [(1, 1), (4, 1), (8, 2), (16, 4), (13, 2)]:
        assert plan_wall_time(inp, k, v) == pytest.approx(
            batch_wall_time(*as_wireless(inp, k, v)), rel=1e-12)


def test_hop_ratio_and_ticks():
    # plain 1F1B: S-1 hops; interleave: S*v - 1 (the chunk chain wraps)
    assert hop_ratio(2, 1) == 1.0
    assert hop_ratio(2, 2) == 3.0
    assert hop_ratio(4, 2) == pytest.approx(7.0 / 3.0)
    assert hop_ratio(1, 4) == 0.0                 # S=1: no ppermute at all
    # tick counts: k + S - 1 at v=1; sigma-spaced groups otherwise
    assert schedule_ticks(8, 2, 1) == 9
    assert schedule_ticks(8, 2, 2) == 16 + 1      # k*v + (S-1) for S | k
    assert schedule_ticks(1, 4, 1) == 4


def test_tick_model_s1_has_no_bubble():
    inp = PlanInputs(num_stages=1, stage_fwd_s=0.1, stage_bwd_s=0.2,
                     link_s=0.01, k_cap=8, v_cap=4)
    for k in (1, 3, 8):
        for v in (1, 2):
            assert tick_wall_time(inp, k, v) == pytest.approx(0.3)


def test_tick_model_v_trade():
    """Compute-bound: v shrinks the bubble; comm-bound: per-tick link
    time floors every tick, so v (more ticks) strictly hurts."""
    compute_bound = PlanInputs(num_stages=4, stage_fwd_s=1.0,
                               stage_bwd_s=2.0, link_s=1e-4, k_cap=8,
                               v_cap=4)
    assert tick_wall_time(compute_bound, 8, 2) \
        < tick_wall_time(compute_bound, 8, 1)
    comm_bound = PlanInputs(num_stages=4, stage_fwd_s=1e-4,
                            stage_bwd_s=2e-4, link_s=1.0, k_cap=8, v_cap=4)
    assert tick_wall_time(comm_bound, 8, 2) \
        > tick_wall_time(comm_bound, 8, 1)


def test_feasible_v_layer_divisibility():
    inp = fixture_inputs()                        # 8 layers, S=2, v_cap=4
    assert inp.feasible_v() == [1, 2, 4]
    inp6 = PlanInputs(num_stages=2, stage_fwd_s=0.1, stage_bwd_s=0.2,
                      link_s=0.01, k_cap=8, v_cap=4, num_layers=6)
    assert inp6.feasible_v() == [1, 3]            # 6 % (2*v) == 0


def test_choose_plan_pins():
    inp = fixture_inputs()
    plan = choose_plan(inp, k_fixed=4)
    assert plan.k == 4
    plan = choose_plan(inp, v_fixed=1)
    assert plan.v == 1
    # pinning both reproduces the hand plan's modeled time
    plan = choose_plan(inp, k_fixed=8, v_fixed=1)
    assert (plan.k, plan.v) == (8, 1)
    assert plan.wall_s == pytest.approx(plan_wall_time(inp, 8, 1))


def test_choose_plan_validates_pins():
    """Pinned values get the same validation as the auto search: no raw
    ZeroDivisionError for k=0, no un-runnable v emitted."""
    inp = fixture_inputs()                        # 8 layers, S=2
    with pytest.raises(ValueError, match=">= 1"):
        choose_plan(inp, k_fixed=0)
    with pytest.raises(ValueError, match=">= 1"):
        choose_plan(inp, v_fixed=-1)
    with pytest.raises(ValueError, match="no feasible"):
        choose_plan(inp, v_fixed=3)               # 8 % (2*3) != 0
    assert choose_plan(inp, v_fixed=4).v == 4     # 8 % (2*4) == 0


def test_choose_plan_stage_candidates():
    """Joint (S, k, v): under a fixed chip budget more stages only add
    hops and bubble, so the planner keeps the smallest feasible S."""
    inp = fixture_inputs()
    plan = choose_plan(inp, stage_candidates=[2, 4])
    assert plan.num_stages == 2
    # stage candidates violating the layer count are skipped
    plan = choose_plan(inp, stage_candidates=[3, 4])   # 8 % 3 != 0
    assert plan.num_stages == 4
    with pytest.raises(ValueError, match="no feasible"):
        choose_plan(inp, stage_candidates=[3])


def test_plan_inputs_from_cfg_estimate():
    from repro.configs import get_arch
    cfg = get_arch("qwen1.5-4b").smoke
    inp = plan_inputs_from_cfg(cfg, batch=16, seq=64, num_stages=2)
    assert inp.num_stages == 2
    assert inp.stage_bwd_s == pytest.approx(2 * inp.stage_fwd_s)
    assert inp.link_s > 0 and inp.hop_overhead_s > 0
    assert inp.k_cap == 16                        # min(batch, 64)
    assert inp.num_layers == cfg.num_layers
    plan = choose_plan(inp)                       # always plannable
    assert 1 <= plan.k <= inp.k_cap


def test_unpipelined_record_needs_hints():
    rec = fixture_record()
    rec["pipeline_k"] = 0
    rec.pop("planner_hints")
    with pytest.raises(ValueError, match="collective-permute"):
        plan_inputs_from_record(rec)
    rec["planner_hints"] = {"act_hop_bytes": 31e6}
    inp = plan_inputs_from_record(rec)
    assert inp.link_s == pytest.approx(0.01)


def test_cli_writes_plan_json(tmp_path):
    from repro.analysis.autotune import main
    out = tmp_path / "plan.json"
    plan = main(["--roofline", FIXTURE, "--out", str(out)])
    assert isinstance(plan, AutoPlan)
    doc = json.loads(out.read_text())
    assert doc["plan"]["k"] == GOLD_K
    assert doc["plan"]["v"] == GOLD_V
    assert doc["record"]["arch"] == "qwen1.5-4b"
    # load_record reads both bare-JSON and JSONL forms
    jl = tmp_path / "records.jsonl"
    with open(jl, "w") as f:
        f.write(json.dumps({"skip": "reason"}) + "\n")
        f.write(json.dumps(fixture_record()) + "\n")
    rec = load_record(str(jl))
    assert rec["arch"] == "qwen1.5-4b"


def test_pipeline_spec_auto_plan():
    from repro.parallel.pipeline import PipelineSpec
    spec, plan = PipelineSpec.auto_plan(fixture_record())
    assert (spec.num_stages, spec.microbatches, spec.virtual_stages) == \
        (2, GOLD_K, GOLD_V)
    assert plan.to_dict()["k"] == GOLD_K
    spec2, _ = PipelineSpec.auto_plan(fixture_inputs(), k_fixed=8, v_fixed=1)
    assert (spec2.microbatches, spec2.virtual_stages) == (8, 1)
    spec3, _ = PipelineSpec.auto_plan(plan)
    assert spec3 == spec
    # pins cannot silently re-shape an already-chosen plan
    with pytest.raises(ValueError, match="re-pin"):
        PipelineSpec.auto_plan(plan, k_fixed=8)


# ---------------------------------------------------------------------------
# Wire-codec awareness (parallel/wire.py's byte model in the planner).
# ---------------------------------------------------------------------------


def test_wire_byte_model():
    # uncoded: the raw element width travels
    assert wire_bytes_per_element("none", 2.0) == 2.0
    assert wire_bytes_per_element(None, 4.0) == 4.0
    # quantized: 1 payload byte + the amortized fp32 block scale
    assert wire_bytes_per_element("int8", 4.0) == pytest.approx(1 + 4 / 256)
    assert wire_bytes_per_element("fp8", 2.0) == pytest.approx(1 + 4 / 256)
    assert wire_link_scale("none", 4.0) == 1.0
    assert wire_link_scale("int8", 4.0) == pytest.approx(
        (1 + 4 / 256) / 4.0)
    with pytest.raises(ValueError, match="wire_dtype"):
        wire_bytes_per_element("int4", 2.0)


def test_wire_block_mirrors_codec_block():
    """The planner's byte model must charge the EFFECTIVE block the codec
    will actually use (largest divisor of d_model <= 256), not a flat
    256 — narrow models pay more scale overhead per element."""
    from repro.analysis.autotune import wire_block_for

    assert wire_block_for(4096) == 256
    assert wire_block_for(96) == 96
    assert wire_block_for(None) == 256            # unknown width: nominal
    # mirror of parallel.wire.wire_block on representative widths
    from repro.parallel.wire import wire_block
    for d in (8, 32, 96, 256, 384, 514, 4096):
        assert wire_block_for(d) == wire_block(d), d
    assert wire_bytes_per_element("int8", 2.0, block=32) == \
        pytest.approx(1 + 4 / 32)


def test_degenerate_block_makes_codec_a_net_loss():
    """d_model = 2 * prime -> block 2 -> 3 B/elem: quantizing a bf16 wire
    INFLATES it 1.5x, and joint enumeration must keep 'none'."""
    assert wire_link_scale("int8", 2.0, block=2) == pytest.approx(1.5)
    inp = PlanInputs(num_stages=2, stage_fwd_s=0.1, stage_bwd_s=0.2,
                     link_s=0.05, hop_overhead_s=1e-4, k_cap=16, v_cap=4,
                     num_layers=8, act_bytes=2.0, wire_block=2)
    plan = choose_plan(inp, wire_candidates=list(WIRE_AUTO))
    assert plan.wire_dtype == "none"
    assert plan.wall_s <= choose_plan(inp.with_wire("int8")).wall_s


def test_wire_bwd_byte_model():
    """Top-k backward bytes: frac*(1 + idx_bytes) + 4/d per element; the
    forward hop stays the dense base; dense codecs are symmetric."""
    from repro.analysis.autotune import (wire_bytes_per_element_bwd,
                                         wire_link_scale_bwd)

    assert wire_bytes_per_element_bwd("int8", 4.0) == \
        wire_bytes_per_element("int8", 4.0)
    assert wire_bytes_per_element_bwd("none", 4.0) == 4.0
    # d=2560: int16 indices, amortized per-row fp32 scale
    assert wire_bytes_per_element_bwd("int8+topk0.25", 4.0,
                                      d_model=2560) == \
        pytest.approx(0.25 * 3 + 4 / 2560)
    # wide rows need int32 indices — costlier than dense int8!
    assert wire_bytes_per_element_bwd("int8+topk0.25", 4.0,
                                      d_model=40000) == \
        pytest.approx(0.25 * 5 + 4 / 40000)
    # unknown width: int16 assumed, scale term dropped
    assert wire_bytes_per_element_bwd("int8+topk0.25", 4.0) == \
        pytest.approx(0.75)
    # forward model of a topk codec is its dense base
    assert wire_bytes_per_element("int8+topk0.25", 4.0) == \
        wire_bytes_per_element("int8", 4.0)
    assert wire_link_scale_bwd("int8+topk0.25", 4.0, d_model=2560) < \
        wire_link_scale("int8", 4.0)
    # topk >= 1 normalizes to the dense base
    assert wire_bytes_per_element_bwd("int8+topk1.0", 4.0, d_model=64) == \
        wire_bytes_per_element("int8", 4.0)
    with pytest.raises(ValueError, match="wire_dtype"):
        wire_bytes_per_element("none+topk0.5", 4.0)
    with pytest.raises(ValueError, match="wire_dtype"):
        wire_bytes_per_element("int8+topk0", 4.0)
    with pytest.raises(ValueError, match="wire_dtype"):
        wire_bytes_per_element("int8+sparse0.5", 4.0)


def test_degenerate_block_disarms_topk_saving():
    """At a degenerate block the runtime EF hop ships raw, so the planner
    must not advertise a top-k saving there — joint enumeration keeps
    'none' even with the topk candidate in the pool."""
    from repro.analysis.autotune import wire_bytes_per_element_bwd

    # block 2 on a bf16 wire: dense codec 3 B/elt >= 2 B raw -> net loss;
    # the bwd model bills the dense bytes, not the topk formula
    assert wire_bytes_per_element_bwd("int8+topk0.25", 2.0, block=2,
                                      d_model=514) == \
        wire_bytes_per_element("int8", 2.0, block=2)
    inp = PlanInputs(num_stages=2, stage_fwd_s=0.1, stage_bwd_s=0.2,
                     link_s=0.05, hop_overhead_s=1e-4, k_cap=16, v_cap=4,
                     num_layers=8, act_bytes=2.0, wire_block=2, d_model=514)
    plan = choose_plan(inp, wire_candidates=list(WIRE_AUTO))
    assert plan.wire_dtype == "none"


def test_codec_compute_billing_gates_the_codec():
    """A codec whose encode+decode compute exceeds its link-time saving
    must never be chosen: with an absurd codec_s_per_byte the planner
    keeps 'none', and every chosen codec's billed compute is smaller
    than the link seconds it saves."""
    import dataclasses

    inp = fixture_inputs()
    assert inp.codec_s_per_byte == pytest.approx(1e-12)
    assert inp.act_hop_bytes == pytest.approx(3.1e7)
    # fixture billing: 31 us of codec compute per hop vs ~7.5 ms saved
    assert inp.with_wire("int8").codec_s == pytest.approx(3.1e-5)
    assert inp.codec_s == 0.0                     # 'none' costs nothing
    slow = dataclasses.replace(inp, codec_s_per_byte=1e-9)  # ~31 ms/hop
    plan = choose_plan(slow, wire_candidates=list(WIRE_AUTO))
    assert plan.wire_dtype == "none"
    # the chosen codec on the real fixture saves more than it costs
    chosen = choose_plan(inp, wire_candidates=list(WIRE_AUTO))
    ci = chosen.inputs
    saved = (inp.link_s - ci.wire_link_s) \
        + (inp.link_s - ci.wire_link_bwd_s)
    assert ci.codec_s * 2 < saved


def test_as_wireless_rejects_directional_codec():
    """The wireless eq-(8) bridge has one cut-byte volume for both
    directions; a topk codec must raise, not silently average."""
    inp = fixture_inputs().with_wire("int8+topk0.25")
    with pytest.raises(ValueError, match="topk"):
        as_wireless(inp, 8, 1)
    # dense codecs still bridge exactly, codec compute included
    inp8 = fixture_inputs().with_wire("int8")
    assert batch_wall_time(*as_wireless(inp8, 8, 1)) == pytest.approx(
        plan_wall_time(inp8, 8, 1), rel=1e-12)


def test_record_d_model_sets_wire_block():
    rec = fixture_record()
    rec["d_model"] = 96
    inp = plan_inputs_from_record(rec)
    assert inp.wire_block == 96
    # explicit hint wins over the derived block
    rec["planner_hints"]["wire_block"] = 128
    assert plan_inputs_from_record(rec).wire_block == 128
    # codec-compiled records un-scale with the same effective block
    del rec["planner_hints"]["wire_block"]
    scale = wire_link_scale("int8", 4.0, block=96)
    rec["wire_dtype"] = "int8"
    rec["roofline"]["coll_by_kind"]["collective-permute"] *= scale
    assert plan_inputs_from_record(rec).link_s == pytest.approx(0.01)


def test_cfg_path_uses_model_width_block():
    from repro.configs import get_arch
    cfg = get_arch("qwen1.5-4b").smoke
    inp = plan_inputs_from_cfg(cfg, batch=16, seq=64, num_stages=2)
    from repro.parallel.wire import wire_block
    assert inp.wire_block == wire_block(cfg.d_model)


def test_fixture_act_bytes_and_wire_link_shrink():
    """Acceptance: on the checked-in fixture (f32 hop payload) the int8
    codec shrinks the planner's billed link_s >= 3.5x, fp8 >= 1.9x."""
    inp = fixture_inputs()
    assert inp.act_bytes == 4.0
    assert inp.wire_dtype == "none" and inp.wire_link_s == inp.link_s
    shrink_int8 = inp.link_s / inp.with_wire("int8").wire_link_s
    shrink_fp8 = inp.link_s / inp.with_wire("fp8").wire_link_s
    assert shrink_int8 >= 3.5
    assert shrink_fp8 >= 1.9


def test_codec_plan_strictly_improves_and_moves_argmin():
    """Acceptance: the codec-aware chosen plan's wall time strictly beats
    the uncoded plan, and the cheaper link MOVES the (k, v) argmin (codec
    enumeration is joint, not bolted on)."""
    inp = fixture_inputs()
    plan_none = choose_plan(inp)
    for w in ("int8", "fp8"):
        plan_w = choose_plan(inp.with_wire(w))
        assert plan_w.wall_s < plan_none.wall_s, w
        assert plan_w.wire_dtype == w
        assert (plan_w.k, plan_w.v) != (plan_none.k, plan_none.v), \
            "fixture should demonstrate the argmin moving under the codec"


def test_choose_plan_wire_candidates_joint():
    inp = fixture_inputs()
    plan = choose_plan(inp, wire_candidates=list(WIRE_AUTO))
    # the sparsified gradient hop wins the fixture argmin (strictly
    # cheaper downlink than dense int8 at the same compute billing)
    assert plan.wire_dtype == "int8+topk0.25"
    assert plan.wall_s <= choose_plan(inp).wall_s
    assert plan.to_dict()["wire_dtype"] == "int8+topk0.25"
    assert plan.inputs.wire_dtype == "int8+topk0.25"
    # dense-only enumeration keeps the PR-5 tie-break (int8 over fp8)
    dense = choose_plan(inp, wire_candidates=["none", "int8", "fp8"])
    assert dense.wire_dtype == "int8"
    # pins still compose with codec enumeration
    pinned = choose_plan(inp, k_fixed=8, wire_candidates=list(WIRE_AUTO))
    assert pinned.k == 8 and pinned.wire_dtype in WIRE_AUTO
    with pytest.raises(ValueError, match="wire_dtype"):
        choose_plan(inp, wire_candidates=["int4"])


def test_wire_plan_sweep_evidence():
    sweep = wire_plan_sweep(fixture_inputs())
    assert set(sweep["sweep"]) == set(WIRE_AUTO)
    assert sweep["chosen"]["wire_dtype"] == "int8+topk0.25"
    none_row = sweep["sweep"]["none"]
    int8_row = sweep["sweep"]["int8"]
    topk_row = sweep["sweep"]["int8+topk0.25"]
    assert none_row["wire_link_s"] / int8_row["wire_link_s"] >= 3.5
    assert int8_row["speedup_vs_none"] > 1.0
    assert none_row["speedup_vs_none"] == 1.0
    # the sparsified downlink is strictly cheaper than its dense uplink,
    # and the codec compute billing shows up in the evidence trail
    assert topk_row["wire_link_bwd_s"] < topk_row["wire_link_s"]
    assert topk_row["wall_s"] < int8_row["wall_s"]
    assert topk_row["codec_s"] == int8_row["codec_s"] > 0.0
    assert none_row["codec_s"] == 0.0


def test_record_with_codec_unscales_to_baseline_link():
    """A record COMPILED with a wire codec carries shrunk ppermute bytes;
    extraction must recover the uncompressed link_s so re-planning is
    fair across codecs."""
    rec = fixture_record()
    scale = wire_link_scale("int8", 4.0)
    rec["wire_dtype"] = "int8"
    rec["roofline"]["coll_by_kind"]["collective-permute"] *= scale
    inp = plan_inputs_from_record(rec)
    assert inp.link_s == pytest.approx(0.01)


def test_record_dtype_fallback_for_act_bytes():
    """Without the act_dtype_bytes hint, the record's dtype field sets the
    element width; without either, bf16 is assumed.  'bfloat16' — the
    config default every dryrun record carries — must resolve WITHOUT
    np.dtype (plain numpy rejects the name in the jax-free planner CLI),
    as must unknown strings (fall back, don't crash)."""
    rec = fixture_record()
    del rec["planner_hints"]["act_dtype_bytes"]
    rec["dtype"] = "float32"
    assert plan_inputs_from_record(rec).act_bytes == 4.0
    rec["dtype"] = "bfloat16"
    assert plan_inputs_from_record(rec).act_bytes == 2.0
    rec["dtype"] = "some_future_dtype"
    assert plan_inputs_from_record(rec).act_bytes == 2.0
    del rec["dtype"]
    assert plan_inputs_from_record(rec).act_bytes == 2.0


def test_extra_hints_overlay_record_hints():
    """Probe-measured hints overlay the record's own (explicit kwargs
    still win): hop_overhead_s and link_bw_Bps are the calibrated keys."""
    rec = fixture_record()
    hints = {"hop_overhead_s": 5e-4, "link_bw_Bps": 2.0 * 3.1e9}
    inp = plan_inputs_from_record(rec, extra_hints=hints)
    assert inp.hop_overhead_s == pytest.approx(5e-4)
    assert inp.link_s == pytest.approx(0.005)     # twice the bw, half the s
    inp = plan_inputs_from_record(rec, extra_hints=hints,
                                  hop_overhead_s=1e-3)
    assert inp.hop_overhead_s == pytest.approx(1e-3)


def test_plan_inputs_from_cfg_act_bytes_and_bw():
    from repro.configs import get_arch
    cfg = get_arch("qwen1.5-4b").smoke
    inp = plan_inputs_from_cfg(cfg, batch=16, seq=64, num_stages=2)
    assert inp.act_bytes == np.dtype(cfg.dtype).itemsize
    double = plan_inputs_from_cfg(cfg, batch=16, seq=64, num_stages=2,
                                  link_bw_Bps=2 * 3.1e9)
    assert double.link_s < inp.link_s


def test_pipeline_spec_auto_plan_wire():
    from repro.parallel.pipeline import PipelineSpec
    spec, plan = PipelineSpec.auto_plan(fixture_record(),
                                        wire_dtype="auto")
    assert spec.wire_dtype == plan.wire_dtype == "int8+topk0.25"
    spec2, _ = PipelineSpec.auto_plan(fixture_record(), wire_dtype="fp8")
    assert spec2.wire_dtype == "fp8"
    spec3, plan3 = PipelineSpec.auto_plan(fixture_record())
    assert spec3.wire_dtype == "none"
    with pytest.raises(ValueError, match="re-pin"):
        PipelineSpec.auto_plan(plan3, wire_dtype="int8")


# ---------------------------------------------------------------------------
# train.py arg resolution (the silent --pipeline-k 4 default fix).
# ---------------------------------------------------------------------------


def _smoke_cfg():
    from repro.configs import get_arch
    return get_arch("qwen1.5-4b").smoke


def test_resolve_no_pipeline():
    from repro.launch.train import resolve_pipeline_plan
    spec, info = resolve_pipeline_plan(
        pipeline_stages=0, pipeline_k=None, virtual_stages=None,
        cfg=_smoke_cfg(), batch=16, seq=64)
    assert spec is None and info == {"enabled": False}


def test_resolve_flag_values_logged_as_flag():
    from repro.launch.train import resolve_pipeline_plan
    spec, info = resolve_pipeline_plan(
        pipeline_stages=2, pipeline_k="4", virtual_stages="2",
        cfg=_smoke_cfg(), batch=16, seq=64)
    assert (spec.microbatches, spec.virtual_stages) == (4, 2)
    assert info["k_source"] == "flag" and info["v_source"] == "flag"
    assert info["plan"] is None                   # no planner run needed


def test_resolve_unset_k_is_planned_not_silent_4():
    """The old behaviour silently used k=4; now an unset k runs the
    planner and says so."""
    from repro.launch.train import resolve_pipeline_plan
    spec, info = resolve_pipeline_plan(
        pipeline_stages=2, pipeline_k=None, virtual_stages=None,
        cfg=_smoke_cfg(), batch=16, seq=64)
    assert info["k_source"] == "auto:default"
    assert info["v_source"] == "default"
    assert spec.virtual_stages == 1               # unset v stays 1
    assert info["plan"] is not None               # planner evidence logged
    assert spec.microbatches == info["plan"]["k"]


def test_resolve_auto_from_fixture_roofline():
    from repro.launch.train import resolve_pipeline_plan
    spec, info = resolve_pipeline_plan(
        pipeline_stages=2, pipeline_k="auto", virtual_stages="auto",
        cfg=_smoke_cfg(), batch=26, seq=64, plan_roofline=FIXTURE)
    assert info["k_source"] == "auto" and info["v_source"] == "auto"
    assert 1 <= spec.microbatches <= min(26, 16)  # k_cap clamped to batch
    # the model's real layer count overrides the fixture hint
    assert _smoke_cfg().num_layers % (2 * spec.virtual_stages) == 0


def test_resolve_rejects_bad_combinations():
    from repro.launch.train import resolve_pipeline_plan
    with pytest.raises(SystemExit, match="pipeline-stages"):
        resolve_pipeline_plan(pipeline_stages=0, pipeline_k="4",
                              virtual_stages=None, cfg=_smoke_cfg(),
                              batch=16, seq=64)
    with pytest.raises(SystemExit, match="virtual-stages"):
        resolve_pipeline_plan(pipeline_stages=1, pipeline_k=None,
                              virtual_stages="2", cfg=_smoke_cfg(),
                              batch=16, seq=64)
    with pytest.raises(SystemExit, match="integer or 'auto'"):
        resolve_pipeline_plan(pipeline_stages=2, pipeline_k="fast",
                              virtual_stages=None, cfg=_smoke_cfg(),
                              batch=16, seq=64)
    with pytest.raises(SystemExit, match=">= 1"):
        resolve_pipeline_plan(pipeline_stages=2, pipeline_k="0",
                              virtual_stages=None, cfg=_smoke_cfg(),
                              batch=16, seq=64)
    # auto-planned k with an un-runnable pinned v: a clear SystemExit,
    # not a reshape error deep inside jit
    with pytest.raises(SystemExit, match="no feasible"):
        resolve_pipeline_plan(pipeline_stages=2, pipeline_k=None,
                              virtual_stages="3", cfg=_smoke_cfg(),
                              batch=16, seq=64)


def test_resolve_bad_roofline_records_exit_cleanly(tmp_path):
    """Unreadable or unpipelined --plan-roofline records get the same
    SystemExit treatment as every other bad flag, not a traceback."""
    from repro.launch.train import resolve_pipeline_plan
    with pytest.raises(SystemExit, match="plan-roofline"):
        resolve_pipeline_plan(pipeline_stages=2, pipeline_k="auto",
                              virtual_stages=None, cfg=_smoke_cfg(),
                              batch=16, seq=64,
                              plan_roofline=str(tmp_path / "missing.json"))
    rec = fixture_record()
    rec["pipeline_k"] = 0                 # common un-pipelined dryrun output
    rec.pop("planner_hints")
    bad = tmp_path / "unpipelined.json"
    bad.write_text(json.dumps(rec))
    with pytest.raises(SystemExit, match="collective-permute"):
        resolve_pipeline_plan(pipeline_stages=2, pipeline_k="auto",
                              virtual_stages=None, cfg=_smoke_cfg(),
                              batch=16, seq=64, plan_roofline=str(bad))


def test_resolve_wire_flag_and_auto():
    from repro.launch.train import resolve_pipeline_plan
    # hand (k, v) + pinned codec: no planner run needed
    spec, info = resolve_pipeline_plan(
        pipeline_stages=2, pipeline_k="4", virtual_stages="2",
        cfg=_smoke_cfg(), batch=16, seq=64, wire_dtype="int8")
    assert spec.wire_dtype == "int8"
    assert info["wire_source"] == "flag" and info["plan"] is None
    # unset wire stays 'none' (source: default)
    spec, info = resolve_pipeline_plan(
        pipeline_stages=2, pipeline_k="4", virtual_stages=None,
        cfg=_smoke_cfg(), batch=16, seq=64)
    assert spec.wire_dtype == "none" and info["wire_source"] == "default"
    # wire 'auto' forces the planner even with hand (k, v), and the codec
    # decision rides the roofline evidence
    spec, info = resolve_pipeline_plan(
        pipeline_stages=2, pipeline_k="8", virtual_stages="1",
        cfg=_smoke_cfg(), batch=16, seq=64, wire_dtype="auto",
        plan_roofline=FIXTURE)
    assert (spec.microbatches, spec.virtual_stages) == (8, 1)
    assert info["wire_source"] == "auto"
    assert spec.wire_dtype == info["plan"]["wire_dtype"] == "int8+topk0.25"


def test_resolve_wire_rejects_bad_combinations():
    from repro.launch.train import resolve_pipeline_plan
    with pytest.raises(SystemExit, match="wire-dtype"):
        resolve_pipeline_plan(pipeline_stages=0, pipeline_k=None,
                              virtual_stages=None, cfg=_smoke_cfg(),
                              batch=16, seq=64, wire_dtype="int8")
    with pytest.raises(SystemExit, match="wire-dtype"):
        resolve_pipeline_plan(pipeline_stages=2, pipeline_k="4",
                              virtual_stages=None, cfg=_smoke_cfg(),
                              batch=16, seq=64, wire_dtype="int4")


def test_resolve_plan_hints_calibrate_overhead(tmp_path):
    """A ppermute-probe JSON fed via plan_hints overrides the HW latency
    constant in the planner evidence (the ROADMAP calibration item)."""
    from repro.launch.train import resolve_pipeline_plan
    hints = tmp_path / "probe.json"
    hints.write_text(json.dumps(
        {"kind": "ppermute_probe",
         "planner_hints": {"hop_overhead_s": 7e-4}}))
    _, info = resolve_pipeline_plan(
        pipeline_stages=2, pipeline_k="auto", virtual_stages=None,
        cfg=_smoke_cfg(), batch=16, seq=64, plan_roofline=FIXTURE,
        plan_hints=str(hints))
    assert info["plan"]["inputs"]["hop_overhead_s"] == pytest.approx(7e-4)
    # same calibration without a roofline record (config-estimate path)
    _, info = resolve_pipeline_plan(
        pipeline_stages=2, pipeline_k="auto", virtual_stages=None,
        cfg=_smoke_cfg(), batch=16, seq=64, plan_hints=str(hints))
    assert info["plan"]["inputs"]["hop_overhead_s"] == pytest.approx(7e-4)
    with pytest.raises(SystemExit, match="plan-hints"):
        resolve_pipeline_plan(
            pipeline_stages=2, pipeline_k="auto", virtual_stages=None,
            cfg=_smoke_cfg(), batch=16, seq=64,
            plan_hints=str(tmp_path / "missing.json"))


def test_cli_wire_auto(tmp_path):
    from repro.analysis.autotune import main
    out = tmp_path / "plan.json"
    plan = main(["--roofline", FIXTURE, "--wire", "auto",
                 "--out", str(out)])
    assert plan.wire_dtype == "int8+topk0.25"
    doc = json.loads(out.read_text())
    assert doc["plan"]["wire_dtype"] == "int8+topk0.25"
    # free-form --wire takes the grammar, including explicit topk names
    plan = main(["--roofline", FIXTURE, "--wire", "fp8+topk0.5"])
    assert plan.wire_dtype == "fp8+topk0.5"
    with pytest.raises(ValueError, match="wire_dtype"):
        main(["--roofline", FIXTURE, "--wire", "int4"])


# ---------------------------------------------------------------------------
# Property tests (deterministic via tests/_hypothesis_stub.py when the
# real hypothesis is absent).
# ---------------------------------------------------------------------------


def _random_inputs(stage_ms, link_ms, ovh_us, k_cap, v_cap, layers):
    return PlanInputs(num_stages=2,
                      stage_fwd_s=stage_ms / 1e3,
                      stage_bwd_s=2.0 * stage_ms / 1e3,
                      link_s=link_ms / 1e3,
                      hop_overhead_s=ovh_us / 1e6,
                      k_cap=k_cap, v_cap=v_cap, num_layers=layers)


@settings(deadline=None, max_examples=25)
@given(stage_ms=st.integers(1, 500), link_ms=st.integers(1, 200),
       ovh_us=st.integers(0, 5000), k_cap=st.integers(1, 24),
       v_cap=st.integers(1, 6),
       layers=st.sampled_from([2, 4, 6, 8, 12, 16, 24]))
def test_property_chosen_plan_dominates_neighbors(stage_ms, link_ms, ovh_us,
                                                  k_cap, v_cap, layers):
    """For ANY measured roofline, the chosen (k, v) is within caps,
    layer-divisible, never slower than the unpipelined baseline, and
    never loses to a neighboring plan under simulate_c2p2sl."""
    inp = _random_inputs(stage_ms, link_ms, ovh_us, k_cap, v_cap, layers)
    plan = choose_plan(inp)
    assert 1 <= plan.k <= k_cap
    assert plan.v in inp.feasible_v()
    assert layers % (2 * plan.v) == 0
    assert plan.wall_s <= plan.baseline_s * (1 + 1e-9)
    for k, v in neighbor_plans(inp, plan.k, plan.v):
        ms, _ = simulate_c2p2sl(plan_task_times(inp, k, v), k,
                                virtual_stages=v)
        assert plan.wall_s <= ms * (1 + 1e-9), (k, v)


@settings(deadline=None, max_examples=15)
@given(stage_ms=st.integers(1, 500), link_ms=st.integers(1, 200),
       ovh_us=st.integers(0, 5000), k=st.integers(1, 24),
       v=st.sampled_from([1, 2, 4]))
def test_property_wireless_bridge_exact(stage_ms, link_ms, ovh_us, k, v):
    """batch_wall_time over the as_wireless export equals the planner
    objective for every candidate, not just the chosen one."""
    inp = _random_inputs(stage_ms, link_ms, ovh_us, 24, 4, 8)
    assert batch_wall_time(*as_wireless(inp, k, v)) == pytest.approx(
        plan_wall_time(inp, k, v), rel=1e-12)


@settings(deadline=None, max_examples=15)
@given(stage_ms=st.integers(1, 300), link_ms=st.integers(1, 100),
       k_cap=st.integers(1, 16))
def test_property_baseline_is_k1_v1(stage_ms, link_ms, k_cap):
    inp = _random_inputs(stage_ms, link_ms, 100, k_cap, 4, 8)
    plan = choose_plan(inp)
    assert plan.baseline_s == pytest.approx(plan_wall_time(inp, 1, 1))


@settings(deadline=None, max_examples=15)
@given(stage_ms=st.integers(1, 500), link_ms=st.integers(1, 200),
       ovh_us=st.integers(0, 5000), k_cap=st.integers(1, 24),
       act_bytes=st.sampled_from([2.0, 4.0]))
def test_property_codec_enumeration_never_hurts(stage_ms, link_ms, ovh_us,
                                                k_cap, act_bytes):
    """For ANY measured roofline: enumerating the wire codec can only
    improve (or tie) the chosen wall time, and every per-codec best plan
    still dominates its own neighbors."""
    inp = PlanInputs(num_stages=2, stage_fwd_s=stage_ms / 1e3,
                     stage_bwd_s=2.0 * stage_ms / 1e3,
                     link_s=link_ms / 1e3, hop_overhead_s=ovh_us / 1e6,
                     k_cap=k_cap, v_cap=4, num_layers=8,
                     act_bytes=act_bytes)
    base = choose_plan(inp)
    joint = choose_plan(inp, wire_candidates=list(WIRE_AUTO))
    assert joint.wall_s <= base.wall_s * (1 + 1e-9)
    for wd in WIRE_AUTO:
        plan = choose_plan(inp.with_wire(wd))
        for k, v in neighbor_plans(inp, plan.k, plan.v):
            assert plan.wall_s <= plan_wall_time(
                inp.with_wire(wd), k, v) * (1 + 1e-9), (wd, k, v)


def test_task_times_are_finite_and_positive():
    inp = fixture_inputs()
    t = plan_task_times(inp, 5, 2)
    for arr in (t.ue_fwd, t.uplink, t.downlink, t.ue_bwd):
        assert np.all(np.isfinite(arr)) and np.all(arr > 0)
    assert t.bs_fwd > 0 and t.bs_bwd > 0
