"""Wire codec v2: fused Pallas kernels + top-k gradient sparsification.

Fast lane: fused-vs-jnp bit-parity under jit (interpret mode off-TPU),
the '<base>+topk<frac>' grammar, top-k payload format, the error-feedback
hop algebra on a 1-device identity permutation, EF boundedness under
iteration, the degenerate-block raw fallback, and the EF state plumbing
(wire_ef_zeros / needs_wire_ef / run.py's new-row diff note).

Slow lane (multi-device subprocess, like test_wire.py): the top-k + EF
pipeline end-to-end on the pod mesh — EF state threading through
make_lm_train_step and convergence parity with the dense wire.
"""
import json
import os
import subprocess
import sys
import textwrap
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.parallel import wire

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
ROOT = os.path.join(os.path.dirname(__file__), "..")


def run_sub(code: str, devices: int = 8, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def _bits_equal(a, b) -> bool:
    a, b = np.asarray(a), np.asarray(b)
    return (a.shape == b.shape and a.dtype == b.dtype
            and a.tobytes() == b.tobytes())


# ---------------------------------------------------------------------------
# Fused Pallas codec: bit-parity with the jnp reference (fast).
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("wdt", ["int8", "fp8"])
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize("shape", [(3, 5, 384),    # ragged lead, block 192
                                   (15, 96),       # block == d_model == 96
                                   (2, 4, 256)])   # block 256 regime
def test_fused_codec_bit_parity(wdt, dtype, shape):
    """The Pallas encode/decode (interpret mode off-TPU) must be BIT-
    identical to the jnp reference — same payload bytes, same fp32
    scales, same decode — under jit on both sides (eager XLA compiles
    the /qmax scale division as a reciprocal multiply, a ~1e-9 wobble
    that is a compiler artifact, not a codec property)."""
    rng = np.random.default_rng(hash((wdt, str(dtype), shape)) % (2 ** 31))
    x = jnp.asarray(rng.standard_normal(shape) * 2.0, dtype)
    enc_jnp = jax.jit(lambda x: wire.encode(x, wdt, impl="jnp"))
    enc_fused = jax.jit(lambda x: wire.encode(x, wdt, impl="fused"))
    qj, sj = enc_jnp(x)
    qf, sf = enc_fused(x)
    assert _bits_equal(qj, qf)
    assert _bits_equal(sj, sf)
    assert sj.dtype == jnp.float32
    dec_jnp = jax.jit(lambda q, s: wire.decode(q, s, dtype, impl="jnp"))
    dec_fused = jax.jit(lambda q, s: wire.decode(q, s, dtype, impl="fused"))
    yj, yf = dec_jnp(qj, sj), dec_fused(qj, sj)
    assert _bits_equal(yj, yf)
    assert yj.shape == shape and yj.dtype == jnp.dtype(dtype)


def test_fused_roundtrip_matches_reference_roundtrip():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((4, 7, 256)), jnp.float32)
    rt_jnp = jax.jit(lambda x: wire.roundtrip(x, "int8", "jnp"))
    rt_fused = jax.jit(lambda x: wire.roundtrip(x, "int8", "fused"))
    assert _bits_equal(rt_jnp(x), rt_fused(x))


# ---------------------------------------------------------------------------
# Codec grammar (fast).
# ---------------------------------------------------------------------------


def test_parse_wire_dtype_grammar():
    assert wire.parse_wire_dtype("int8+topk0.25") == ("int8", 0.25)
    assert wire.parse_wire_dtype(" FP8+TOPK0.5 ") == ("fp8", 0.5)
    assert wire.parse_wire_dtype("int8") == ("int8", None)
    assert wire.parse_wire_dtype(None) == ("none", None)
    # frac >= 1 keeps every entry: normalizes to the dense base codec
    assert wire.parse_wire_dtype("int8+topk1.0") == ("int8", None)
    assert wire.validate_wire_dtype("int8+topk1.0") == "int8"
    assert wire.validate_wire_dtype("int8+topk0.25") == "int8+topk0.25"
    assert wire.format_wire_dtype("int8", 0.25) == "int8+topk0.25"
    assert wire.has_topk("fp8+topk0.125")
    assert not wire.has_topk("fp8")
    for bad in ("none+topk0.25", "int8+topk0", "int8+topk-1",
                "int8+sparse0.2", "int8+topkx", "int4+topk0.25"):
        with pytest.raises(ValueError, match="wire_dtype"):
            wire.parse_wire_dtype(bad)


# ---------------------------------------------------------------------------
# Top-k payload format + EF hop algebra (fast).
# ---------------------------------------------------------------------------


def test_topk_payload_format():
    assert wire.topk_count(512, 0.25) == 128
    assert wire.topk_count(3, 0.1) == 1          # never ships zero entries
    assert wire.topk_index_dtype(2560) == jnp.int16
    assert wire.topk_index_dtype(40000) == jnp.int32
    rng = np.random.default_rng(4)
    g = jnp.asarray(rng.standard_normal((6, 512)), jnp.float32)
    q, idx, scale = wire.topk_encode(g, "int8+topk0.25")
    assert q.shape == (6, 128) and q.dtype == jnp.int8
    assert idx.shape == (6, 128) and idx.dtype == jnp.int16
    assert scale.shape == (6, 1) and scale.dtype == jnp.float32
    with pytest.raises(ValueError, match="top-k"):
        wire.topk_encode(g, "int8")


def test_topk_roundtrip_keeps_largest_drops_rest():
    rng = np.random.default_rng(5)
    g = np.asarray(rng.standard_normal((6, 512)), np.float32)
    q, idx, scale = wire.topk_encode(jnp.asarray(g), "int8+topk0.25")
    dec = np.asarray(wire.topk_decode(q, idx, scale, 512, jnp.float32))
    idx = np.asarray(idx, np.int64)
    kept = np.zeros_like(g, dtype=bool)
    np.put_along_axis(kept, idx, True, axis=-1)
    # dropped entries decode to EXACT zero; kept entries to their int8
    # quantization against the kept-row absmax
    assert np.all(dec[~kept] == 0.0)
    rowmax = np.abs(np.take_along_axis(g, idx, -1)).max(-1, keepdims=True)
    err = np.abs(dec - g)[kept].reshape(6, -1)
    assert np.all(err <= rowmax / 254.0 + 1e-7)
    # the kept set IS the top 25% by magnitude: every kept |entry| >=
    # every dropped |entry| within its row
    a = np.abs(g)
    assert np.all(np.where(kept, a, np.inf).min(-1)
                  >= np.where(kept, -np.inf, a).max(-1))


def test_topk_decode_zero_payload_is_zero():
    """Devices outside the permutation receive all-zero (payload, idx,
    scale) — the decode must be exactly zero (matching raw ppermute's
    zero fill), despite every index colliding at 0."""
    dec = wire.topk_decode(jnp.zeros((3, 16), jnp.int8),
                           jnp.zeros((3, 16), jnp.int16),
                           jnp.zeros((3, 1), jnp.float32), 64, jnp.float32)
    assert float(jnp.max(jnp.abs(dec))) == 0.0


def _identity_ef_hop(wdt, x, ef):
    """coded_ppermute_ef on a 1-device pod mesh with the identity
    permutation — a lossless link, isolating the codec math."""
    from repro.parallel import compat
    from repro.parallel.compat import PartitionSpec as P

    mesh = compat.make_mesh((1,), ("pod",))
    return compat.shard_map(
        lambda x, ef: wire.coded_ppermute_ef(wdt, "pod", ((0, 0),), x, ef),
        mesh, in_specs=(P(), P()), out_specs=P(), check=False)(x, ef)


def test_coded_ppermute_ef_hop_algebra():
    """Forward ships the DENSE base codec (same as coded_ppermute); the
    backward rule ships topk(g + ef) and returns the dropped mass as the
    new residual: new_ef == (g + ef) - decode(topk(g + ef))."""
    from repro.parallel import compat
    from repro.parallel.compat import PartitionSpec as P

    wdt = "int8+topk0.25"
    mesh = compat.make_mesh((1,), ("pod",))
    fn = compat.shard_map(
        lambda x, ef: wire.coded_ppermute_ef(wdt, "pod", ((0, 0),), x, ef),
        mesh, in_specs=(P(), P()), out_specs=P(), check=False)
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.standard_normal((2, 64)), jnp.float32)
    ef = jnp.asarray(rng.standard_normal((2, 64)) * 0.1, jnp.float32)
    gbar = jnp.asarray(rng.standard_normal((2, 64)), jnp.float32)

    y, vjp = jax.vjp(fn, x, ef)
    gx, new_ef = vjp(gbar)
    # forward: dense int8 round trip, independent of ef
    assert np.array_equal(np.asarray(y),
                          np.asarray(wire.roundtrip(x, "int8")))
    # backward: the identity hop receives exactly the local topk decode
    corrected = jnp.asarray(gbar, jnp.float32) + ef
    q, idx, scale = wire.topk_encode(corrected, wdt)
    dec = wire.topk_decode(q, idx, scale, 64, jnp.float32)
    assert np.array_equal(np.asarray(gx), np.asarray(dec))
    assert np.allclose(np.asarray(new_ef), np.asarray(corrected - dec),
                       atol=0.0)
    # EF contraction: the residual is strictly smaller than what was sent
    assert (float(jnp.linalg.norm(new_ef))
            < float(jnp.linalg.norm(corrected)))


def test_ef_residual_bounded_under_iteration():
    """Iterating the EF recursion ef <- (g + ef) - dec(topk(g + ef)) with
    a FIXED gradient must stay bounded (EF-SGD's compressor contraction)
    — it accumulates toward a steady state, it does NOT decay to zero."""
    rng = np.random.default_rng(7)
    g = jnp.asarray(rng.standard_normal((4, 256)), jnp.float32)
    gnorm = float(jnp.linalg.norm(g))
    ef = jnp.zeros_like(g)
    norms = []
    for _ in range(50):
        corrected = g + ef
        q, idx, scale = wire.topk_encode(corrected, "int8+topk0.25")
        ef = corrected - wire.topk_decode(q, idx, scale, 256, jnp.float32)
        norms.append(float(jnp.linalg.norm(ef)))
    # bounded: ||ef_t|| <= (1/delta)||g|| with delta the compressor
    # contraction factor; 4x is a loose ceiling for topk0.25 + int8
    assert max(norms) <= 4.0 * gnorm, max(norms)
    # and genuinely nonzero at steady state (the codec is lossy)
    assert norms[-1] > 0.01 * gnorm
    # long-run payloads deliver ~all the mass: mean of dec over steps ~ g
    # (first-order EF guarantee) — check the residual stopped growing
    assert abs(norms[-1] - norms[-10]) <= 0.2 * gnorm


def test_net_loss_fallback_warns_and_ships_raw():
    """Prime d_model forces block=1: 5 wire B/elt > raw.  encode must
    fall back to the raw payload with a one-time warning, and the EF
    backward hop must ship raw too, leaving the residual untouched."""
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.standard_normal((2, 257)), jnp.float32)
    wire._NET_LOSS_WARNED.clear()
    with pytest.warns(UserWarning, match="net loss"):
        q, s = wire.encode(x, "int8")
    assert s is None and _bits_equal(q, x)
    assert _bits_equal(wire.decode(q, s, x.dtype), x)
    with warnings.catch_warnings():
        warnings.simplefilter("error")      # second call: no re-warn
        wire.encode(x, "int8")

    # the EF hop: forward raw, backward raw, residual unchanged
    ef = jnp.asarray(rng.standard_normal((2, 257)) * 0.1, jnp.float32)
    gbar = jnp.asarray(rng.standard_normal((2, 257)), jnp.float32)
    y, vjp = jax.vjp(lambda x, ef: _identity_ef_hop("int8+topk0.25", x, ef),
                     x, ef)
    gx, new_ef = vjp(gbar)
    assert _bits_equal(y, x)
    assert _bits_equal(gx, gbar)
    assert _bits_equal(new_ef, ef)


# ---------------------------------------------------------------------------
# EF state plumbing (fast).
# ---------------------------------------------------------------------------


def test_wire_ef_zeros_shapes():
    from repro.models import LMConfig
    from repro.parallel.pipeline import (PipelineSpec, wire_ef_ticks,
                                         wire_ef_zeros)

    cfg = LMConfig(name="t", num_layers=4, d_model=32, n_heads=4, n_kv=2,
                   d_ff=64, vocab=128, dtype="float32")
    dense = PipelineSpec(num_stages=2, microbatches=4, wire_dtype="int8")
    assert wire_ef_zeros(cfg, dense, 8, 16) is None       # dense: no EF
    s1 = PipelineSpec(num_stages=1, microbatches=4,
                      wire_dtype="int8+topk0.25")
    assert wire_ef_zeros(cfg, s1, 8, 16) is None          # S=1: no hop
    spec = PipelineSpec(num_stages=2, microbatches=4, virtual_stages=2,
                        wire_dtype="int8+topk0.25")
    ef = wire_ef_zeros(cfg, spec, 10, 16)                 # ragged k: pad
    assert ef.dtype == jnp.float32
    assert ef.shape == (2, wire_ef_ticks(spec), 3, 16, 32)
    assert float(jnp.max(jnp.abs(ef))) == 0.0


def test_pipelined_loss_wire_ef_flag():
    """S=1 (no hop) and dense codecs must keep the two-arg loss signature
    — only a real topk pipeline grows the EF input (needs_wire_ef; the
    S>1 leg is exercised in the slow subprocess lane)."""
    from repro.data import lm_batch_for
    from repro.models import LM, LMConfig
    from repro.parallel.compat import make_mesh, mesh_context
    from repro.parallel.pipeline import PipelineSpec, make_pipelined_loss

    cfg = LMConfig(name="t", num_layers=2, d_model=32, n_heads=4, n_kv=2,
                   d_ff=64, vocab=128, dtype="float32")
    m = LM(cfg)
    p = m.init(jax.random.key(0))
    batch = lm_batch_for(cfg, 4, 8)
    mesh = make_mesh((1,), ("pod",))
    # S=1 normalizes away the EF plumbing entirely
    s1 = make_pipelined_loss(
        m, PipelineSpec(num_stages=1, microbatches=2,
                        wire_dtype="int8+topk0.25"), mesh=mesh)
    assert s1.needs_wire_ef is False
    with mesh_context(mesh):
        jax.jit(s1)(p, batch)  # two-arg signature still works
    dense = make_pipelined_loss(
        m, PipelineSpec(num_stages=1, microbatches=2, wire_dtype="int8"),
        mesh=mesh)
    assert dense.needs_wire_ef is False


def test_run_diff_notes_new_rows(tmp_path, capsys):
    """A bench added since the baseline was committed is reported as
    'not diffed' instead of silently skipped (and the gate still fails
    loudly when NOTHING overlaps — covered in test_wire.py)."""
    sys.path.insert(0, ROOT)
    try:
        from benchmarks.run import main as run_main
    finally:
        sys.path.remove(ROOT)
    with open(os.path.join(ROOT, "benchmarks", "BENCH_pipeline.json")) as f:
        doc = json.load(f)
    doc["rows"] = [r for r in doc["rows"] if r["name"] == "pipeline_plan"]
    baseline = tmp_path / "base.json"
    baseline.write_text(json.dumps(doc))
    run_main(["--only", "pipeline_plan,wire_codec",
              "--diff", str(baseline)])
    out = capsys.readouterr().out
    assert "not in baseline, not diffed: wire_codec" in out
    assert "bench diff vs" in out and "OK" in out


# ---------------------------------------------------------------------------
# Multi-device subprocess lane (slow).
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_topk_ef_pipeline_end_to_end():
    """int8+topk0.25 on the 2-stage pod pipeline: the EF buffer threads
    through make_lm_train_step, the loss tracks the dense int8 wire, and
    the residual is live (nonzero, finite, bounded) after two steps."""
    out = run_sub("""
        import jax, json
        import jax.numpy as jnp
        from repro.data import TokenTaskConfig, token_batches
        from repro.models import LM, LMConfig
        from repro.parallel.compat import make_mesh, mesh_context
        from repro.parallel.pipeline import (PipelineSpec,
                                             make_pipelined_loss,
                                             wire_ef_zeros)
        from repro.parallel.steps import make_lm_train_step
        from repro.training.optim import adamw

        cfg = LMConfig(name='t', num_layers=4, d_model=32, n_heads=4,
                       n_kv=2, d_ff=64, vocab=128, dtype='float32')
        m = LM(cfg)
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        it = token_batches(TokenTaskConfig(vocab=cfg.vocab), 8, 16, seed=5)
        b0 = next(it)
        losses = {}
        for w in ("int8", "int8+topk0.25"):
            opt = adamw(1e-2)
            params = m.init(jax.random.key(0))
            spec = PipelineSpec(num_stages=2, microbatches=4,
                                virtual_stages=2, wire_dtype=w)
            loss_fn = make_pipelined_loss(m, spec, mesh=mesh)
            state = {"params": params, "opt_state": opt.init(params),
                     "step": jnp.zeros((), jnp.int32)}
            ef = wire_ef_zeros(cfg, spec, 8, 16)
            if ef is not None:
                state["wire_ef"] = ef
            assert loss_fn.needs_wire_ef == (ef is not None), w
            step = jax.jit(make_lm_train_step(m, opt, pipeline=spec,
                                              mesh=mesh))
            with mesh_context(mesh):
                state, mets = step(state, b0)
                state, mets2 = step(state, b0)
            losses[w] = float(mets["loss"])
            if ef is not None:
                efn = float(jnp.linalg.norm(state["wire_ef"]))
                gnorm = max(float(jnp.linalg.norm(l)) for l in
                            jax.tree.leaves(state["params"]))
                print(json.dumps({"ef_norm": efn, "finite": bool(
                    jnp.isfinite(state["wire_ef"]).all())}))
        print(json.dumps(losses))
    """)
    lines = out.strip().splitlines()
    efrec = json.loads(lines[-2])
    losses = json.loads(lines[-1])
    assert efrec["finite"]
    assert 0.0 < efrec["ef_norm"] < 1e3
    # first-step loss: identical batch, EF starts at zero, so topk only
    # perturbs via the sparsified FIRST backward — same ballpark as dense
    assert abs(losses["int8+topk0.25"] - losses["int8"]) < 5e-2 \
        * max(1.0, abs(losses["int8"]))


@pytest.mark.slow
def test_topk_wire_convergence_parity():
    """30 adamw steps: topk0.5 + EF lands within a whisker of the
    uncoded trajectory (the acceptance bar for shipping a lossy gradient
    hop), and even topk0.25 — 8 of 32 entries per row on a hop carrying
    ALL inter-stage signal of this tiny model — still trains, just with
    the expected EF lag (same asymptote, slower constant)."""
    out = run_sub("""
        import jax, json
        import jax.numpy as jnp
        from repro.data import TokenTaskConfig, token_batches
        from repro.models import LM, LMConfig
        from repro.parallel.compat import make_mesh, mesh_context
        from repro.parallel.pipeline import PipelineSpec, wire_ef_zeros
        from repro.parallel.steps import make_lm_train_step
        from repro.training.optim import adamw

        cfg = LMConfig(name='t', num_layers=4, d_model=32, n_heads=4,
                       n_kv=2, d_ff=64, vocab=128, dtype='float32')
        m = LM(cfg)
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        finals = {}
        for w in ("none", "int8+topk0.5", "int8+topk0.25"):
            opt = adamw(1e-2)
            params = m.init(jax.random.key(0))
            spec = PipelineSpec(num_stages=2, microbatches=4, wire_dtype=w)
            state = {"params": params, "opt_state": opt.init(params),
                     "step": jnp.zeros((), jnp.int32)}
            ef = wire_ef_zeros(cfg, spec, 8, 16)
            if ef is not None:
                state["wire_ef"] = ef
            step = jax.jit(make_lm_train_step(m, opt, pipeline=spec,
                                              mesh=mesh))
            it = token_batches(TokenTaskConfig(vocab=cfg.vocab), 8, 16,
                               seed=3)
            with mesh_context(mesh):
                first = None
                for _ in range(30):
                    state, mets = step(state, next(it))
                    if first is None:
                        first = float(mets["loss"])
            finals[w] = {"first": first, "final": float(mets["loss"])}
        print(json.dumps(finals))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    ref = res["none"]
    assert ref["final"] < ref["first"] - 0.5           # training moves
    tk5 = res["int8+topk0.5"]
    assert tk5["final"] < tk5["first"] - 0.5
    assert abs(tk5["final"] - ref["final"]) < 0.08 \
        * max(1.0, abs(ref["final"])), res
    tk25 = res["int8+topk0.25"]
    assert tk25["final"] < tk25["first"] - 0.5, res
