"""shard_batch invariants: every sample used once, one entry per UE,
remainder redistribution (the b=[3,7], k=4 data-loss regression)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.sl import shard_batch


def flatten(xs):
    """All samples of a per-UE micro-batch list, in emission order."""
    return np.concatenate([m for ue in xs for m in ue], axis=0)


def check_invariants(batch_x, batch_y, b, k):
    xs, ys = shard_batch(batch_x, batch_y, np.asarray(b), k)
    n = batch_x.shape[0]
    # one entry per UE, k micro-batches each (position-aligned with Fleet)
    assert len(xs) == len(ys) == len(b)
    assert all(len(ue) == k for ue in xs + ys)
    # every sample of the host batch appears exactly once, in order
    np.testing.assert_array_equal(flatten(xs), batch_x)
    np.testing.assert_array_equal(flatten(ys), batch_y)
    # ragged sizes within a UE differ by at most 1 (balanced remainder)
    for ue in xs:
        sizes = [m.shape[0] for m in ue]
        assert max(sizes) - min(sizes) <= 1
        assert sorted(sizes, reverse=True) == sizes
    assert sum(m.shape[0] for ue in xs for m in ue) == n
    return xs, ys


def _batch(n, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal((n, 3)).astype(np.float32),
            rng.integers(0, 10, size=(n,)))


def test_remainder_not_dropped():
    """The confirmed seed bug: b=[3,7], k=4 over 10 samples trained on 8."""
    x, y = _batch(10)
    check_invariants(x, y, [3, 7], 4)


def test_zero_batch_ue_keeps_position():
    x, y = _batch(8)
    xs, ys = check_invariants(x, y, [0, 5, 3], 2)
    assert all(m.shape[0] == 0 for m in xs[0])
    assert [m.shape[0] for m in xs[1]] == [3, 2]
    assert [m.shape[0] for m in xs[2]] == [2, 1]


def test_bi_smaller_than_k():
    x, y = _batch(2)
    xs, _ = check_invariants(x, y, [2], 4)
    assert [m.shape[0] for m in xs[0]] == [1, 1, 0, 0]


def test_allocation_sum_mismatch_absorbed():
    """AO integer rounding: sum(b) != n is absorbed by the LARGEST
    allocation, nothing lost and zero-batch UEs stay empty."""
    x, y = _batch(12)
    xs, _ = check_invariants(x, y, [4, 4, 0], 3)    # deficit of 4
    assert all(m.shape[0] == 0 for m in xs[2])
    assert sum(m.shape[0] for m in xs[0]) == 8      # argmax took the slack
    x, y = _batch(6)
    xs, _ = check_invariants(x, y, [5, 5, 0], 2)    # surplus of 4
    assert all(m.shape[0] == 0 for m in xs[2])


def test_divisible_split_unchanged():
    """The classic layout: b_i multiples of k stay rectangular."""
    x, y = _batch(48)
    xs, _ = check_invariants(x, y, [16, 16, 16], 4)
    assert all(m.shape[0] == 4 for ue in xs for m in ue)


@settings(deadline=None, max_examples=60)
@given(n_ue=st.integers(1, 6), k=st.integers(1, 8),
       seed=st.integers(0, 10_000))
def test_property_random_b_k(n_ue, k, seed):
    """Property: any integer split uses every sample, one entry per UE."""
    rng = np.random.default_rng(seed)
    b = rng.integers(0, 12, size=n_ue)
    n = int(b.sum())
    if n == 0:
        return
    x, y = _batch(n, seed)
    check_invariants(x, y, b, k)


def test_negative_allocation_rejected():
    x, y = _batch(4)
    with pytest.raises(AssertionError, match="negative"):
        shard_batch(x, y, np.array([5, -1]), 2)
