"""Alternating optimization (paper SIII, Algorithm 1)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.ao import (algorithm1, feasible_l, lemma1_k, makespan_k,
                           pipeline_k_auto, solve_batch_p3, solve_tau_p5)
from repro.core.costs import resnet18_profile
from repro.core.schedule import Plan, bubble_rate, simulate_c2p2sl, task_times
from repro.wireless.fleet import sample_fleet

PROF = resnet18_profile()


def test_lemma1_matches_formula():
    fleet = sample_fleet(4, seed=3)
    b = np.full(4, 64.0)
    tau = np.full(4, fleet.channel.frame_s / 4)
    l = 2
    k = lemma1_k(PROF, fleet, l, b, tau)
    # recompute eta by hand from eqs (8)-(11)
    t1 = task_times(PROF, fleet, Plan(l=l, k=1, b=b, tau=tau))
    eta = t1.bs_work / float(np.min(t1.uplink + t1.downlink))
    if eta < 1:
        expect = int(np.floor(1.0 / (1.0 - eta)))
        assert k == max(1, min(expect, int(np.min(b))))
    else:
        assert k == int(np.min(b))  # capped by micro-batch granularity


def test_lemma1_k_divides_by_virtual_stages():
    """Interleaving streams k*v slices, so the steady-state k divides by
    v (ceil), while the sample-granularity cap min_i b_i does not."""
    fleet = sample_fleet(4, seed=3)
    b = np.full(4, 64.0)
    tau = np.full(4, fleet.channel.frame_s / 4)
    for l in (1, 2, 3):
        k1 = lemma1_k(PROF, fleet, l, b, tau)
        t1 = task_times(PROF, fleet, Plan(l=l, k=1, b=b, tau=tau))
        eta = t1.bs_work / float(np.min(t1.uplink + t1.downlink))
        for v in (2, 4):
            kv = lemma1_k(PROF, fleet, l, b, tau, virtual_stages=v)
            if eta < 1.0:
                want = -(-int(np.floor(1.0 / (1.0 - eta))) // v)
                assert kv == max(1, min(want, int(np.min(b))))
            else:
                assert kv == k1        # granularity-capped: v can't help


def test_pipeline_k_auto_virtual_stages():
    # eta = 0.9 -> k* = 10 at v=1; interleave divides the steady-state k
    assert pipeline_k_auto(0.9, 1.0, k_cap=64) == 10
    assert pipeline_k_auto(0.9, 1.0, k_cap=64, virtual_stages=2) == 5
    assert pipeline_k_auto(0.9, 1.0, k_cap=64, virtual_stages=3) == 4
    assert pipeline_k_auto(0.9, 1.0, k_cap=64, virtual_stages=16) == 1
    # compute-bound: k is the granularity cap regardless of v
    assert pipeline_k_auto(10.0, 1.0, k_cap=16, virtual_stages=4) == 16


def test_algorithm1_joint_v_trade():
    """v_cap=4 extends subproblem 1 to the joint (l, k, v) trade: the
    returned plan's interleave strictly beats running the SAME plan
    without it (simulate monotonicity), and the reported bubble shrinks
    accordingly.  The AO trajectories (b, tau differ across basins) are
    only compared loosely — the AO is a heuristic, not an exact solver."""
    from repro.core.schedule import simulate_c2p2sl as sim
    fleet = sample_fleet(8, seed=0)
    res1 = algorithm1(PROF, fleet, batch=512)
    resv = algorithm1(PROF, fleet, batch=512, v_cap=4)
    assert res1.plan.v == 1                  # default stays plain 1F1B
    assert 1 <= resv.plan.v <= 4
    tv = task_times(PROF, fleet, resv.plan)
    msv, _ = sim(tv, resv.plan.k, virtual_stages=resv.plan.v)
    ms_plain, _ = sim(tv, resv.plan.k)
    assert msv <= ms_plain + 1e-12
    if resv.plan.v > 1:
        assert msv < ms_plain
        assert resv.bubble < bubble_rate(tv, resv.plan.k, 1)
        assert resv.bubble < res1.bubble
    t1 = task_times(PROF, fleet, res1.plan)
    ms1, _ = sim(t1, res1.plan.k)
    assert msv <= ms1 * 1.05                 # same ballpark across basins


def test_p3_respects_constraints():
    # l=1 is the storage-feasible cut under Table I (c_i in [1,2] GFLOP
    # bounds b_i to ~2 samples for any deeper cut)
    fleet = sample_fleet(6, seed=1)
    tau = np.full(6, fleet.channel.frame_s / 6)
    b = solve_batch_p3(PROF, fleet, l=1, k=4, tau=tau, batch=256)
    assert b is not None
    assert int(b.sum()) == 256                        # C5
    assert np.all(b >= 0)
    assert np.all(PROF.ue_total(1) * b <= fleet.storage + 1e6)   # C2


def test_p3_infeasible_cut_returns_none():
    """Cuts violating the storage bound C2 for any split are rejected."""
    fleet = sample_fleet(6, seed=1)
    tau = np.full(6, fleet.channel.frame_s / 6)
    assert solve_batch_p3(PROF, fleet, l=4, k=4, tau=tau, batch=4096) is None


def test_p3_loads_fast_ues_more():
    """Batch allocation should favour faster-better-connected UEs."""
    fleet = sample_fleet(8, seed=5)
    tau = np.full(8, fleet.channel.frame_s / 8)
    b = solve_batch_p3(PROF, fleet, l=1, k=4, tau=tau, batch=512)
    t = task_times(PROF, fleet, Plan(l=1, k=4, b=b, tau=tau))
    # per-UE forward+uplink times should be roughly equalized:
    active = b > 0
    stage1 = (t.ue_fwd + t.uplink)[active]
    uniform = task_times(PROF, fleet,
                         Plan(l=1, k=4, b=np.full(8, 64.0), tau=tau))
    spread_opt = stage1.max() - stage1.min()
    spread_uni = (uniform.ue_fwd + uniform.uplink).max() - \
        (uniform.ue_fwd + uniform.uplink).min()
    assert spread_opt <= spread_uni + 1e-9


def test_p5_fits_frame():
    fleet = sample_fleet(5, seed=2)
    b = np.full(5, 64.0)
    tau = solve_tau_p5(PROF, fleet, l=2, k=4, b=b)
    assert tau.shape == (5,)
    assert np.all(tau > 0)
    assert tau.sum() <= fleet.channel.frame_s * (1 + 1e-9)       # C6


def test_algorithm1_converges_and_feasible():
    fleet = sample_fleet(8, seed=0)
    res = algorithm1(PROF, fleet, batch=512, eps=1e-4)
    assert 1 <= res.plan.l <= PROF.num_layers - 1                # C1
    assert res.plan.k >= 1
    assert int(res.plan.b.sum()) == 512
    assert 0.0 <= res.bubble < 1.0
    # Algorithm 1's stopping contract: |BR^m - BR^{m-1}| <= eps at exit
    # (BR itself may wobble between AO iterations since the (l, k)
    # subproblem accepts on makespan, the robust proxy; see repro.core.ao)
    if len(res.history) >= 2:
        assert abs(res.history[-1] - res.history[-2]) <= 1e-3


def test_algorithm1_beats_naive_plan():
    fleet = sample_fleet(8, seed=7)
    res = algorithm1(PROF, fleet, batch=512)
    naive = Plan(l=res.plan.l, k=1, b=np.full(8, 64.0),
                 tau=np.full(8, fleet.channel.frame_s / 8))
    t_opt = task_times(PROF, fleet, res.plan)
    t_nai = task_times(PROF, fleet, naive)
    ms_opt, _ = simulate_c2p2sl(t_opt, res.plan.k)
    ms_nai, _ = simulate_c2p2sl(t_nai, 1)
    assert ms_opt < ms_nai


@settings(deadline=None, max_examples=15)
@given(n=st.integers(2, 12), seed=st.integers(0, 1000))
def test_algorithm1_always_feasible(n, seed):
    """Property: AO returns a feasible plan for any fleet draw."""
    fleet = sample_fleet(n, seed=seed)
    res = algorithm1(PROF, fleet, batch=16 * n, max_iters=6)
    assert int(res.plan.b.sum()) == 16 * n
    assert np.all(res.plan.b >= 0)
    assert res.plan.tau.sum() <= fleet.channel.frame_s * (1 + 1e-6)
    assert np.isfinite(res.bubble)


@settings(deadline=None, max_examples=30)
@given(stage_ms=st.integers(0, 2000), link_ms=st.integers(1, 1000),
       k_cap=st.integers(1, 64), v=st.integers(1, 8))
def test_property_pipeline_k_auto_within_cap(stage_ms, link_ms, k_cap, v):
    """Property: the closed-form k is always in [1, k_cap] — the TPU
    granularity bound is never relaxed, by any eta regime or any
    interleave count."""
    k = pipeline_k_auto(stage_ms / 1e3, link_ms / 1e3, k_cap=k_cap,
                        virtual_stages=v)
    assert 1 <= k <= k_cap
    # interleaving never asks for MORE micro-batches
    assert k <= pipeline_k_auto(stage_ms / 1e3, link_ms / 1e3, k_cap=k_cap)


@settings(deadline=None, max_examples=10)
@given(n=st.integers(2, 10), seed=st.integers(0, 500),
       v_cap=st.sampled_from([1, 2, 4]))
def test_property_algorithm1_cut_is_storage_feasible(n, seed, v_cap):
    """Property: the AO's chosen cut respects the storage bound C2 for
    the batch split it ships (feasible_l), and v stays within v_cap."""
    fleet = sample_fleet(n, seed=seed)
    res = algorithm1(PROF, fleet, batch=16 * n, max_iters=4, v_cap=v_cap)
    assert res.plan.l in feasible_l(PROF, fleet, res.plan.b)
    assert 1 <= res.plan.v <= v_cap
    assert 1 <= res.plan.k <= max(int(np.min(res.plan.b[res.plan.b > 0])), 1)


def test_makespan_k_robust_fallback():
    fleet = sample_fleet(4, seed=9)
    b = np.full(4, 64.0)
    tau = np.full(4, fleet.channel.frame_s / 4)
    k, ms = makespan_k(PROF, fleet, 1, b, tau)
    assert k >= 1 and np.isfinite(ms)
