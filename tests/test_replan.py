"""Adaptive online re-planning (repro.training.replan) and the unified
``Plan`` currency it switches between.

Three lanes:

* jax-free unit/property tests of the decision machinery — ``Plan`` JSON
  round-trip + normalization, ``ReplanConfig`` parsing, the link
  estimator's affine fit, and the hysteresis gate's two defining
  properties (no flapping under stationary noise; exactly one switch
  under a single bandwidth step).
* in-process jax tests of the cheap-switch machinery (``PlanCellCache``
  keying, all four ``carry_state`` EF-buffer transitions) — single
  device, no subprocess.
* one slow-lane e2e: the real launcher on 8 host devices with a
  scripted mid-training bandwidth drop re-plans EXACTLY once, the loss
  stays finite through the switch, and training still converges.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.analysis.autotune import (WIRE_AUTO, Plan, PlanInputs,
                                     choose_plan)
from repro.training.replan import (LinkEstimator, PlanCellCache,
                                   ReplanConfig, Replanner, apply_hints,
                                   reachable_cells, reachable_plans)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def base_inputs(**kw):
    """A comm-bound two-stage cell with a codec-able hop (act_hop_bytes
    set so link_bw hints can be folded back into link_s)."""
    kw.setdefault("num_stages", 2)
    kw.setdefault("stage_fwd_s", 0.1)
    kw.setdefault("stage_bwd_s", 0.2)
    kw.setdefault("link_s", 0.01)
    kw.setdefault("hop_overhead_s", 0.002)
    kw.setdefault("k_cap", 16)
    kw.setdefault("v_cap", 4)
    kw.setdefault("num_layers", 8)
    kw.setdefault("act_bytes", 2.0)
    kw.setdefault("act_hop_bytes", 4.0e8)
    kw.setdefault("d_model", 1024)
    return PlanInputs(**kw)


# ---------------------------------------------------------------------------
# Plan: the single currency
# ---------------------------------------------------------------------------

WIRES = ["none", "int8", "fp8", "int8+topk0.25", "fp8+topk0.5"]


@settings(max_examples=60, deadline=None)
@given(stages=st.integers(1, 8), k=st.integers(1, 64),
       v=st.integers(1, 8), wire=st.sampled_from(WIRES))
def test_plan_json_round_trip(stages, k, v, wire):
    """to_json -> from_json is the identity, and the round-tripped plan
    hashes into the same compile-cache cell."""
    plan = Plan(stages=stages, k=k, v=v, wire_dtype=wire)
    doc = json.loads(json.dumps(plan.to_json()))   # through real JSON
    back = Plan.from_json(doc)
    assert back == plan
    assert back.cell() == plan.cell()
    assert hash(back) == hash(plan)
    assert doc["schema"] == 1


@settings(max_examples=60, deadline=None)
@given(frac=st.sampled_from([0.25, 0.5, 0.1, 0.75]),
       base=st.sampled_from(["int8", "fp8"]))
def test_plan_wire_normalization(frac, base):
    """Codec spellings canonicalize at construction: case, whitespace
    and trailing zeros cannot mint distinct cache cells."""
    canonical = Plan(stages=2, k=4, wire_dtype=f"{base}+topk{frac}")
    sloppy = Plan(stages=2, k=4,
                  wire_dtype=f"  {base.upper()}+TOPK{frac:.4f} ")
    assert sloppy == canonical
    assert sloppy.cell() == canonical.cell()


def test_plan_validation_rejects_garbage():
    with pytest.raises(ValueError):
        Plan(stages=0, k=1)
    with pytest.raises(ValueError):
        Plan(stages=2, k=1, v=-1)
    with pytest.raises(ValueError):
        Plan(stages=2, k="four")
    with pytest.raises(ValueError):
        Plan(stages=2, k=True)          # bools are not micro-batch counts
    with pytest.raises(ValueError):
        Plan(stages=2, k=1, wire_dtype="int3+topk0.5")


def test_plan_from_json_schema_gate():
    plan = Plan(stages=2, k=4, v=2, wire_dtype="int8")
    doc = plan.to_json()
    doc["schema"] = 99
    with pytest.raises(ValueError, match="schema"):
        Plan.from_json(doc)
    # missing schema reads as v1 (hand-written JSON stays usable)
    doc = plan.to_json()
    del doc["schema"]
    assert Plan.from_json(doc) == plan
    with pytest.raises(ValueError, match="missing"):
        Plan.from_json({"stages": 2})


# ---------------------------------------------------------------------------
# ReplanConfig: the --replan grammar
# ---------------------------------------------------------------------------


def test_replan_config_parse():
    for off in (None, "off", "none", "0", "false", " OFF "):
        assert ReplanConfig.parse(off) is None
    for on in ("on", "", "default"):
        assert ReplanConfig.parse(on) == ReplanConfig()
    cfg = ReplanConfig.parse("every:10,hysteresis:0.2,cooldown:5")
    assert (cfg.every, cfg.hysteresis, cfg.cooldown) == (10, 0.2, 5)
    with pytest.raises(ValueError, match="unknown"):
        ReplanConfig.parse("cadence:10")
    with pytest.raises(ValueError, match="key:value"):
        ReplanConfig.parse("every=10")
    with pytest.raises(ValueError):
        ReplanConfig.parse("every:0")
    with pytest.raises(ValueError):
        ReplanConfig.parse("hysteresis:1.5")
    # describe() round-trips through parse
    cfg = ReplanConfig(every=7, hysteresis=0.05, cooldown=3)
    assert ReplanConfig.parse(cfg.describe()) == cfg


@settings(max_examples=80, deadline=None)
@given(every=st.integers(1, 500),
       hysteresis=st.floats(0.0, 0.99),
       cooldown=st.integers(0, 200),
       ewma=st.floats(0.01, 0.99))
def test_replan_config_describe_round_trips_all_fields(
        every, hysteresis, cooldown, ewma):
    """``parse(describe()) == self`` over the WHOLE config space.

    Regression for the bug where ``describe()`` dropped a non-default
    ``ewma``, so a config logged from one run silently came back with
    the default link-estimator smoothing when replayed via ``--replan``.
    """
    cfg = ReplanConfig(every=every, hysteresis=hysteresis,
                       cooldown=cooldown, ewma=ewma)
    assert ReplanConfig.parse(cfg.describe()) == cfg


def test_replan_config_describe_keeps_nondefault_ewma():
    cfg = ReplanConfig(ewma=0.25)
    assert "ewma" in cfg.describe()
    assert ReplanConfig.parse(cfg.describe()).ewma == 0.25
    # defaults stay terse: the canonical spelling of the default config
    # doesn't enumerate fields nobody set
    assert ReplanConfig().describe() == "every:50,hysteresis:0.1"


# ---------------------------------------------------------------------------
# LinkEstimator: the in-loop ppermute probe
# ---------------------------------------------------------------------------


def test_link_estimator_affine_fit_recovers_overhead():
    """Samples at distinct sizes separate per-message overhead from
    bandwidth, exactly like benchmarks/ppermute_probe's fit."""
    bw, oh = 1e9, 2e-3
    est = LinkEstimator()
    for nbytes in (1e6, 4e6, 16e6, 64e6):
        est.observe(nbytes, oh + nbytes / bw)
    assert est.bw_Bps == pytest.approx(bw, rel=1e-6)
    assert est.overhead_s == pytest.approx(oh, rel=1e-6)
    hints = est.hints()
    assert hints["link_bw_Bps"] == pytest.approx(bw, rel=1e-6)
    assert hints["hop_overhead_s"] == pytest.approx(oh, rel=1e-6)


def test_link_estimator_single_size_degenerates_to_bandwidth():
    est = LinkEstimator()
    est.observe(1e6, 2e-3)
    est.observe(1e6, 2e-3)
    assert est.bw_Bps == pytest.approx(5e8)
    assert est.overhead_s is None          # can't separate without spread


def test_link_estimator_bandwidth_feed_is_ewma_smoothed():
    est = LinkEstimator(ewma=0.5)
    est.observe_bandwidth(1e9)
    est.observe_bandwidth(2e9)
    assert est.bw_Bps == pytest.approx(1.5e9)
    est.observe_bandwidth(0.0)             # junk readings are dropped
    assert est.bw_Bps == pytest.approx(1.5e9)


def test_apply_hints_folds_measurements():
    inp = base_inputs()
    # bandwidth hint re-derives link_s through act_hop_bytes
    out = apply_hints(inp, {"link_bw_Bps": 4.0e10})
    assert out.link_s == pytest.approx(4.0e8 / 4.0e10)
    # compute drift scales both stage times
    out = apply_hints(inp, {"stage_time_scale": 2.0})
    assert out.stage_fwd_s == pytest.approx(0.2)
    assert out.stage_bwd_s == pytest.approx(0.4)
    # direct overrides win over the scale
    out = apply_hints(inp, {"stage_time_scale": 2.0, "stage_fwd_s": 0.7})
    assert out.stage_fwd_s == pytest.approx(0.7)
    assert out.stage_bwd_s == pytest.approx(0.4)
    # unknown keys are ignored; no hints returns the inputs unchanged
    assert apply_hints(inp, {"step_time_ewma_s": 1.0}) is inp
    assert apply_hints(inp, {}) is inp


# ---------------------------------------------------------------------------
# The hysteresis gate: the two defining properties
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), noise=st.floats(0.01, 0.15))
def test_no_flap_under_stationary_noise(seed, noise):
    """THE no-flap property: a stationary-but-noisy channel must never
    open the gate.  Both walls in the comparison are computed on the
    same refreshed inputs, so multiplicative noise moves them together
    and the hysteresis margin only reacts to relative regime changes."""
    rng = np.random.default_rng(seed)
    inp = base_inputs()
    bw0 = inp.act_hop_bytes / inp.link_s
    rp = Replanner(inp, choose_plan(inp, wire_candidates=WIRE_AUTO).plan,
                   ReplanConfig(every=5, hysteresis=0.1))
    for step in range(1, 101):
        rp.observe_bandwidth(bw0 * (1.0 + noise * rng.standard_normal()))
        rp.maybe_replan(step)
    assert rp.evals == 20
    assert rp.switches == []


def test_single_drift_switches_exactly_once():
    """An 8x bandwidth drop: the planner must notice, switch once with a
    gain clearing the hysteresis margin, then hold the new plan — the
    EWMA's convergence tail after the step must NOT produce a second
    switch."""
    inp = base_inputs()
    bw0 = inp.act_hop_bytes / inp.link_s
    initial = choose_plan(inp, wire_candidates=WIRE_AUTO).plan
    rp = Replanner(inp, initial, ReplanConfig(every=5, hysteresis=0.1))
    for step in range(1, 201):
        rp.observe_bandwidth(bw0 if step < 80 else bw0 / 8.0)
        rp.maybe_replan(step)
    assert len(rp.switches) == 1
    sw = rp.switches[0]
    assert sw.step >= 80
    assert sw.gain > 0.1                      # cleared the margin
    assert sw.new == rp.current != initial
    assert sw.new.stages == initial.stages    # S is pinned
    # the switch log round-trips (train.py embeds it in --plan-out)
    doc = json.loads(json.dumps(rp.to_json()))
    assert len(doc["switches"]) == 1
    assert Plan.from_json(doc["switches"][0]["new"]) == sw.new
    assert Plan.from_json(doc["current"]) == rp.current


def test_cooldown_defers_the_switch():
    inp = base_inputs()
    bw0 = inp.act_hop_bytes / inp.link_s
    initial = choose_plan(inp, wire_candidates=WIRE_AUTO).plan

    def run(cooldown):
        rp = Replanner(inp, initial,
                       ReplanConfig(every=5, hysteresis=0.1,
                                    cooldown=cooldown))
        # force an early switch, then a second regime change
        for step in range(1, 201):
            bw = bw0 if step < 20 else bw0 / 8.0
            rp.observe_bandwidth(bw)
            rp.maybe_replan(step)
        return rp

    free = run(0)
    held = run(1000)
    assert len(free.switches) >= 1
    assert len(held.switches) == len(free.switches)  # first switch unaffected
    # a second drop inside the cooldown would be held; just assert the
    # bookkeeping: cooldown never creates switches
    assert held.switches[0].step == free.switches[0].step


def test_replanner_pins_the_stage_count():
    inp = base_inputs(num_stages=2)
    with pytest.raises(ValueError, match="S=4"):
        Replanner(inp, Plan(stages=4, k=8), ReplanConfig())


def test_watchdog_telemetry_feeds_stage_time_scale():
    """Step-time drift reaches the planner as a stage-time multiplier,
    anchored at the first healthy EWMA (so the anchor itself is not
    'drift')."""
    inp = base_inputs()
    rp = Replanner(inp, choose_plan(inp, wire_candidates=WIRE_AUTO).plan,
                   ReplanConfig(every=5))
    for _ in range(20):
        rp.observe_step(0, 0.1)
    first = rp.refreshed_inputs()           # calibrates the baseline
    assert first.stage_fwd_s == pytest.approx(inp.stage_fwd_s, rel=0.05)
    for _ in range(200):                    # compute slows down 3x
        rp.observe_step(0, 0.3)
    drifted = rp.refreshed_inputs()
    scale = drifted.stage_fwd_s / inp.stage_fwd_s
    assert 2.0 < scale <= 3.1               # EWMA-converged toward 3x
    assert drifted.stage_bwd_s / inp.stage_bwd_s == pytest.approx(scale)
    assert drifted.link_s == inp.link_s     # link is billed separately


# ---------------------------------------------------------------------------
# Reachable cells (the staticcheck contract) + the compile cache
# ---------------------------------------------------------------------------


def test_reachable_cells_match_staticcheck_audit_grid():
    """The invariant auditor must audit EXACTLY the lowering cells the
    re-planner can switch into — the grid is derived, not hand-listed."""
    from repro.analysis.staticcheck import AUDIT_CELLS, _CELL
    cells = reachable_cells(num_stages=_CELL["num_stages"],
                            num_layers=_CELL["num_layers"], v_cap=4)
    assert tuple(cells) == AUDIT_CELLS
    assert len(cells) == len(set(cells))
    # the audited codecs are the planner's candidate set, normalized
    assert {w for w, _v in cells} \
        == {Plan(stages=2, k=1, wire_dtype=w).wire_dtype for w in WIRE_AUTO}


def test_reachable_cells_dedupe_aliased_codecs():
    cells = reachable_cells(num_stages=2, num_layers=4, v_cap=2,
                            wire_candidates=("int8+topk0.25",
                                             "INT8+topk0.250"))
    assert cells == [("int8+topk0.25", 1), ("int8+topk0.25", 2)]


def test_reachable_plans_cover_the_feasible_grid():
    inp = base_inputs(num_layers=8, v_cap=4, k_cap=4)
    plans = reachable_plans(inp, wire_candidates=("none", "int8"))
    # v in feasible_v() (layers%(S*v)==0), k in 1..k_cap, 2 codecs
    assert len(plans) == 2 * len(inp.feasible_v()) * 4
    assert len({p.cell() for p in plans}) == len(plans)
    assert all(p.stages == 2 for p in plans)


def test_plan_cell_cache_keys_on_the_cell():
    built = []
    cache = PlanCellCache(lambda p: built.append(p) or f"step:{p}")
    a = Plan(stages=2, k=4, v=2, wire_dtype="int8+topk0.25")
    alias = Plan(stages=2, k=4, v=2, wire_dtype="INT8+TOPK0.250")
    other = Plan(stages=2, k=4, v=1, wire_dtype="int8+topk0.25")
    assert cache.get(a) == cache.get(alias)          # one build
    cache.get(other)
    cache.get(a)
    assert (cache.misses, cache.hits) == (2, 2)
    assert len(cache) == 2 and a in cache and alias in cache
    assert built == [a, other]


# ---------------------------------------------------------------------------
# carry_state: the four EF-buffer transitions (in-process jax, 1 device)
# ---------------------------------------------------------------------------


def _carry_fixture():
    import jax.numpy as jnp
    from repro.models import LMConfig
    cfg = LMConfig(name="t", num_layers=4, d_model=64, n_heads=4, n_kv=4,
                   d_ff=128, vocab=128)
    state = {"params": {"w": jnp.ones((2, 2))},
             "opt_state": {"m": jnp.zeros((2, 2))},
             "step": jnp.zeros((), jnp.int32)}
    return cfg, state, 6, 16                     # batch 6 -> ragged at k=4


def _with_ef(state, cfg, plan, batch, seq, fill=0.0):
    import jax.numpy as jnp
    from repro.parallel.pipeline import PipelineSpec, wire_ef_zeros
    ef = wire_ef_zeros(cfg, PipelineSpec.from_plan(plan), batch, seq)
    assert ef is not None
    state = dict(state)
    state["wire_ef"] = ef + fill if fill else ef
    return state, tuple(ef.shape)


def test_carry_state_dense_to_topk_creates_fresh_ef():
    from repro.training.replan import carry_state
    cfg, state, batch, seq = _carry_fixture()
    out = carry_state(state, Plan(stages=2, k=2, wire_dtype="int8+topk0.25"),
                      cfg=cfg, batch=batch, seq=seq)
    assert "wire_ef" in out
    assert float(np.abs(np.asarray(out["wire_ef"])).max()) == 0.0
    assert out["params"] is state["params"]      # everything else carried
    assert out["opt_state"] is state["opt_state"]


def test_carry_state_same_shape_carries_exactly():
    """Codec change at equal (k, v): the residual is un-flushed gradient
    mass and must survive the switch bit-for-bit."""
    from repro.training.replan import carry_state
    cfg, state, batch, seq = _carry_fixture()
    old = Plan(stages=2, k=3, wire_dtype="int8+topk0.25")
    state, shape = _with_ef(state, cfg, old, batch, seq, fill=1.5)
    out = carry_state(state, Plan(stages=2, k=3, wire_dtype="fp8+topk0.5"),
                      cfg=cfg, batch=batch, seq=seq)
    assert tuple(out["wire_ef"].shape) == shape
    assert float(np.asarray(out["wire_ef"]).min()) == 1.5


def test_carry_state_shape_change_resets_to_zero():
    """k moves (ragged: mb = ceil(6/3)=2 -> ceil(6/4)=2 but ticks move):
    the buffer is rebuilt at the new shape, zeroed."""
    from repro.training.replan import carry_state
    cfg, state, batch, seq = _carry_fixture()
    old = Plan(stages=2, k=3, wire_dtype="int8+topk0.25")
    state, old_shape = _with_ef(state, cfg, old, batch, seq, fill=1.5)
    out = carry_state(state, Plan(stages=2, k=4, wire_dtype="int8+topk0.25"),
                      cfg=cfg, batch=batch, seq=seq)
    new_shape = tuple(out["wire_ef"].shape)
    assert new_shape != old_shape
    assert float(np.abs(np.asarray(out["wire_ef"])).max()) == 0.0


def test_carry_state_topk_to_dense_drops_the_ef():
    from repro.training.replan import carry_state
    cfg, state, batch, seq = _carry_fixture()
    old = Plan(stages=2, k=3, wire_dtype="int8+topk0.25")
    state, _ = _with_ef(state, cfg, old, batch, seq, fill=1.5)
    out = carry_state(state, Plan(stages=2, k=3, wire_dtype="int8"),
                      cfg=cfg, batch=batch, seq=seq)
    assert "wire_ef" not in out
    assert out["params"] is state["params"]


# ---------------------------------------------------------------------------
# The shared CLI surface (launch/plan_args) and the legacy alias
# ---------------------------------------------------------------------------


def _parse(flavor, argv):
    import argparse
    from repro.launch.plan_args import add_plan_args
    ap = argparse.ArgumentParser()
    add_plan_args(ap, flavor=flavor)
    return ap.parse_args(argv)


def test_legacy_pipeline_v_alias_train_flavor():
    """--pipeline-v keeps working as a deprecated alias: both spellings
    bind to args.virtual_stages with identical semantics."""
    old = _parse("train", ["--pipeline-v", "2"])
    new = _parse("train", ["--virtual-stages", "2"])
    assert old.virtual_stages == new.virtual_stages == "2"
    assert _parse("train", []).virtual_stages is None


def test_legacy_pipeline_v_alias_lower_flavor():
    old = _parse("lower", ["--pipeline-v", "2", "--pipeline-k", "4"])
    new = _parse("lower", ["--virtual-stages", "2", "--pipeline-k", "4"])
    assert old.virtual_stages == new.virtual_stages == 2   # typed int here
    assert old.pipeline_k == 4
    assert _parse("lower", []).virtual_stages == 1


def test_replan_config_helper_exits_on_bad_spec():
    import argparse
    from repro.launch.plan_args import add_plan_args, replan_config
    ap = argparse.ArgumentParser()
    add_plan_args(ap, flavor="train")
    args = ap.parse_args(["--replan", "every:3,hysteresis:0.05"])
    cfg = replan_config(args)
    assert (cfg.every, cfg.hysteresis) == (3, 0.05)
    assert replan_config(ap.parse_args([])) is None
    with pytest.raises(SystemExit, match="--replan"):
        replan_config(ap.parse_args(["--replan", "bogus:1"]))


# ---------------------------------------------------------------------------
# Slow lane: the launcher re-plans across a real switch
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_e2e_bandwidth_drop_replans_once(tmp_path):
    """The full loop on 8 host devices: a scripted 20x bandwidth drop at
    step 6 makes the re-planner switch the live pipeline EXACTLY once
    (codec turns on), state carries across the switch (grads/loss stay
    finite every step), and training still converges end-to-end."""
    trace = tmp_path / "trace.json"
    plan_out = tmp_path / "plan.json"
    metrics = tmp_path / "metrics.json"
    trace.write_text(json.dumps({"steps": [0, 6], "bw_Bps": [4e10, 2e9]}))

    code = textwrap.dedent(f"""
        from repro.launch.train import main
        main(["--arch", "qwen1.5-4b", "--size", "smoke", "--steps", "12",
              "--batch", "4", "--seq", "16", "--log-every", "1",
              "--pipeline-stages", "2",
              "--replan", "every:3,hysteresis:0.05",
              "--replan-trace", {str(trace)!r},
              "--plan-out", {str(plan_out)!r},
              "--metrics-out", {str(metrics)!r}])
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]

    doc = json.loads(plan_out.read_text())
    replan = doc["replan"]
    assert len(replan["switches"]) == 1
    sw = replan["switches"][0]
    assert sw["step"] > 6                         # after the drop landed
    assert sw["gain"] > 0.05                      # cleared the margin
    old, new = Plan.from_json(sw["old"]), Plan.from_json(sw["new"])
    assert old.stages == new.stages == 2          # S pinned
    assert new != old
    assert Plan.from_json(replan["current"]) == new
    # the launcher really ran the switched cell: a post-switch compile
    # happened ("2 cell compile(s)") and every step's loss is finite
    assert "2 cell compile(s)" in out.stdout
    history = json.loads(metrics.read_text())
    assert len(history) == 12
    losses = [row["loss"] for row in history]
    assert np.all(np.isfinite(losses))
    # convergence survives the switch: strictly below the starting loss
    # at the end, and no post-switch blow-up above the pre-switch peak
    assert losses[-1] < losses[0]
    assert max(losses[sw["step"]:]) <= max(losses[:sw["step"]]) + 0.5
