"""Fault tolerance: watchdog, straggler rebalancing, elastic rescale."""
import numpy as np

from repro.core.costs import resnet18_profile
from repro.training.fault import Watchdog, plan_rescale, rebalance_batches


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_watchdog_detects_dead_worker():
    clock = FakeClock()
    wd = Watchdog(4, timeout_s=10.0, clock=clock)
    clock.t = 5.0
    for i in (0, 1, 2):
        wd.heartbeat(i)
    clock.t = 12.0
    assert wd.dead_workers() == [3]


def test_watchdog_straggler_detection():
    clock = FakeClock()
    wd = Watchdog(4, clock=clock)
    for i, t in enumerate([1.0, 1.1, 0.9, 5.0]):
        wd.heartbeat(i, step_time=t)
    assert wd.stragglers(factor=2.0) == [3]


def test_rebalance_proportional_to_speed():
    thr = np.array([1.0, 1.0, 4.0])     # worker 2 is 4x faster
    b = rebalance_batches(thr, 48, multiple=2)
    assert b.sum() == 48
    assert b[2] > b[0] and b[2] > b[1]
    assert np.all(b % 2 == 0)


def test_rebalance_after_straggler_cuts_makespan():
    """End-to-end: re-allocating batch away from a compute straggler
    reduces the simulated batch time (the paper's P3 as a straggler
    policy).  The fleet is crafted compute-bound (one 10x-slower UE,
    identical channels), the regime where speed-proportional re-balancing
    is provably right; comm-bound fleets instead go through the full LP
    (repro.core.ao.solve_batch_p3)."""
    from repro.core.schedule import Plan, simulate_c2p2sl, task_times
    from repro.wireless.channel import ChannelParams
    from repro.wireless.fleet import UE, Fleet
    prof = resnet18_profile()
    ch = ChannelParams(bandwidth_hz=1e9)      # fat pipe: compute-bound
    mk = lambda clock: UE(clock_hz=clock, p_tx_dbm=20.0, distance_m=150.0,
                          storage_flops=1e12)
    fleet = Fleet(ues=(mk(2e9), mk(2e9), mk(2e9), mk(0.2e9)), channel=ch)
    tau = np.full(4, ch.frame_s / 4)
    uniform = Plan(l=2, k=4, b=np.full(4, 32.0), tau=tau)
    t_uni = task_times(prof, fleet, uniform)
    ms_uni, _ = simulate_c2p2sl(t_uni, 4)
    thr = 1.0 / np.maximum(t_uni.ue_fwd + t_uni.uplink, 1e-9)
    b_new = rebalance_batches(thr, 128, multiple=4).astype(float)
    t_reb = task_times(prof, fleet, Plan(l=2, k=4, b=b_new, tau=tau))
    ms_reb, _ = simulate_c2p2sl(t_reb, 4)
    assert ms_reb < ms_uni


def test_plan_rescale():
    assert plan_rescale({"pod": 4, "data": 16, "model": 16}, 1) == \
        {"pod": 3, "data": 16, "model": 16}
    assert plan_rescale({"pod": 1, "data": 16, "model": 16}, 3)["pod"] == 1
