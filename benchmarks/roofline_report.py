"""Render the dry-run's roofline records (results/dryrun.jsonl) as the
EXPERIMENTS.md tables: per (arch x shape x mesh) the three terms, the
bottleneck, and MODEL_FLOPS / HLO_FLOPs."""
from __future__ import annotations

import json
import os


def load(path="results/dryrun.jsonl"):
    recs, skips = [], []
    if not os.path.exists(path):
        return recs, skips
    seen = {}
    for line in open(path):
        r = json.loads(line)
        if "skip" in r:
            skips.append(r)
        else:
            key = (r["arch"], r["shape"], r["mesh"], r.get("pipeline_k", 0))
            seen[key] = r          # newest record wins
    recs = [seen[k] for k in sorted(seen)]
    # dedupe skips
    uniq = {(s["arch"], s["shape"]): s for s in skips}
    return recs, list(uniq.values())


def table(recs, mesh="16x16"):
    lines = [
        "| arch | shape | t_compute s | t_memory s | t_collective s | "
        "bottleneck | bound-MFU | useful/HLO |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["mesh"] != mesh or r.get("pipeline_k"):
            continue
        rl = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rl['t_compute_s']:.4f} | "
            f"{rl['t_memory_s']:.4f} | {rl['t_collective_s']:.4f} | "
            f"{rl['bottleneck']} | {rl['mfu_bound']:.3f} | "
            f"{rl['useful_flops_frac']:.3f} |")
    return "\n".join(lines)


def main(quick=False):
    recs, skips = load()
    if not recs:
        print("no dry-run records; run: python -m repro.launch.dryrun")
        return {}
    n_single = sum(1 for r in recs if r["mesh"] == "16x16"
                   and not r.get("pipeline_k"))
    n_multi = sum(1 for r in recs if r["mesh"] == "2x16x16"
                  and not r.get("pipeline_k"))
    print(f"records: {n_single} single-pod + {n_multi} multi-pod cells, "
          f"{len(skips)} documented skips")
    print()
    print(table(recs, "16x16"))
    bnecks = {}
    for r in recs:
        if r["mesh"] == "16x16" and not r.get("pipeline_k"):
            b = r["roofline"]["bottleneck"]
            bnecks[b] = bnecks.get(b, 0) + 1
    print(f"\nbottleneck distribution (single-pod): {bnecks}")
    return {"cells": n_single + n_multi, "skips": len(skips),
            "bottlenecks": bnecks}


if __name__ == "__main__":
    main()
