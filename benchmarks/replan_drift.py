"""Replan-drift bench: static plan vs online re-planning under a
scripted mid-run bandwidth step.

The AC²P²SL premise made measurable: the codec-aware roofline plan that
is optimal at the pre-drift bandwidth is NOT optimal after the link
degrades, and the hysteresis-gated re-planner
(``repro.training.replan``) must (a) notice, (b) switch EXACTLY ONCE —
no flapping on the EWMA's convergence tail — and (c) beat the static
plan's cumulative wall time.  Deterministic and compile-free: the drift
is a ``wireless.channel.bandwidth_step_trace``, per-step walls come from
``autotune.plan_wall_time`` on the checked-in roofline fixture, and the
re-planner sees the same EWMA-smoothed bandwidth feed every run — which
is what lets CI diff the result against ``BENCH_pipeline.json``
(compile cost is not billed: the ``PlanCellCache`` makes a revisited
cell free, and a first visit is one compile amortized over the run).
"""
from __future__ import annotations

import json
import os

FIXTURE = os.path.join(os.path.dirname(__file__), "..", "tests",
                       "fixtures", "roofline_smoke.json")

STEPS = 200          # modeled training steps
DROP_AT = 80         # the bandwidth step lands here
DROP_FACTOR = 8.0    # link bandwidth divides by this


def main(quick: bool = True):
    from repro.analysis.autotune import (WIRE_AUTO, choose_plan,
                                         plan_inputs_from_record,
                                         plan_wall_time)
    from repro.training.replan import ReplanConfig, Replanner, apply_hints
    from repro.wireless.channel import bandwidth_step_trace

    with open(FIXTURE) as f:
        record = json.load(f)
    inp = plan_inputs_from_record(record)
    bw0 = inp.act_hop_bytes / inp.link_s     # implied pre-drift bandwidth
    trace = bandwidth_step_trace(bw0, bw0 / DROP_FACTOR, DROP_AT)

    static = choose_plan(inp, wire_candidates=WIRE_AUTO).plan
    rp = Replanner(inp, static,
                   ReplanConfig(every=10, hysteresis=0.1))

    static_s = replanned_s = 0.0
    post_static_s = post_replanned_s = 0.0
    for step in range(1, STEPS + 1):
        bw = trace.at(step)
        rp.observe_bandwidth(bw)                 # EWMA-smoothed feed
        rp.maybe_replan(step)
        # bill BOTH runs at the true instantaneous link, not the EWMA
        truth = apply_hints(inp, {"link_bw_Bps": bw})
        w_static = plan_wall_time(truth.with_wire(static.wire_dtype),
                                  static.k, static.v)
        cur = rp.current
        w_replan = plan_wall_time(truth.with_wire(cur.wire_dtype),
                                  cur.k, cur.v)
        static_s += w_static
        replanned_s += w_replan
        if step >= DROP_AT:
            post_static_s += w_static
            post_replanned_s += w_replan

    out = {
        "steps": STEPS,
        "drop_step": DROP_AT,
        "drop_factor": DROP_FACTOR,
        "static_plan": static.to_json(),
        "final_plan": rp.current.to_json(),
        "switches": len(rp.switches),
        "switch_step": rp.switches[0].step if rp.switches else None,
        "switch_gain": rp.switches[0].gain if rp.switches else None,
        "evals": rp.evals,
        "static_wall_s": static_s,
        "replanned_wall_s": replanned_s,
        "speedup_vs_static": static_s / replanned_s,
        "post_drop_speedup": post_static_s / post_replanned_s,
    }
    assert out["switches"] == 1, (
        f"expected exactly one plan switch under a single bandwidth "
        f"step, got {out['switches']} ({[s.to_json() for s in rp.switches]})")
    assert out["replanned_wall_s"] < out["static_wall_s"], (
        "re-planned run must beat the static plan under drift")
    print(f"  static    {static}: {static_s * 1e3:9.2f} ms total")
    print(f"  replanned {rp.current}: {replanned_s * 1e3:9.2f} ms total "
          f"(switch @ step {out['switch_step']}, "
          f"{out['switch_gain']:.0%} modeled gain)")
    print(f"  speedup vs static: {out['speedup_vs_static']:.4f}x "
          f"(post-drop {out['post_drop_speedup']:.4f}x)")
    return out


if __name__ == "__main__":
    main()
