"""§Perf hillclimb driver: lower one cell with optimization knobs, print
the roofline terms and the top HLO contributors, and append the iteration
to results/perf_iters.jsonl.

    PYTHONPATH=src python -m benchmarks.perf_iter \
        --arch command-r-plus-104b --shape train_4k --label it1 \
        --cast-gathers
"""
from __future__ import annotations

import argparse
import json
import os


def run_cell(arch, shape, multi=False, *, pipeline_k=0, pipeline_v=1,
             wire_dtype="none",
             cast_gathers=False, seq_shard=None, microbatches=1,
             master_fp32=False, pure_dp=False, tpu_model=False, top_n=10):
    from repro.launch.dryrun import lower_cell
    from repro.analysis.hlo_costs import analyze
    from repro.analysis.roofline import RooflineTerms
    rec, comp = lower_cell(arch, shape, multi, pipeline_k=pipeline_k,
                           pipeline_v=pipeline_v, wire_dtype=wire_dtype,
                           cast_gathers=cast_gathers, seq_shard=seq_shard,
                           microbatches=microbatches, master_fp32=master_fp32,
                           pure_dp=pure_dp)
    prof = analyze(comp.as_text(), top_n=top_n, tpu_model=tpu_model)
    if tpu_model:
        terms = RooflineTerms(
            flops=prof["flops"], hbm_bytes=prof["bytes"],
            coll_bytes=prof["coll_bytes"],
            coll_by_kind=prof["coll_by_kind"],
            coll_dcn_bytes=prof.get("coll_dcn_bytes", 0.0),
            model_flops=rec["roofline"]["model_flops"],
            chips=rec["chips"])
        rec["roofline"] = terms.to_dict()
    return rec, prof


def auto_plan_compare(rec, *, num_layers=None):
    """Hand-picked vs auto-picked plan for one lowered cell.

    Runs the codec-aware roofline planner on the record and evaluates
    BOTH plans under the same ``plan_wall_time`` model, so the comparison
    is apples-to-apples without re-lowering: the hand plan is billed with
    the codec the cell was actually compiled with, the auto plan may pick
    a different (k, v, wire).  Returns the dict stored under
    ``rec['auto_plan_compare']``.
    """
    from repro.analysis.autotune import (WIRE_AUTO, choose_plan,
                                         plan_inputs_from_record,
                                         plan_wall_time)
    # num_stages comes from the record's own pod mesh axis; raises
    # ValueError on single-pod records (callers validate flags up front
    # so this never fires after an expensive compile)
    inp = plan_inputs_from_record(rec, num_layers=num_layers)
    plan = choose_plan(inp, wire_candidates=list(WIRE_AUTO))
    hand_k = int(rec.get("pipeline_k", 0) or 1)
    hand_v = int(rec.get("pipeline_v", 1) or 1)
    hand_wire = rec.get("wire_dtype", "none") or "none"
    hand_wall = plan_wall_time(inp.with_wire(hand_wire), hand_k, hand_v)
    return {
        "hand": {"k": hand_k, "v": hand_v, "wire": hand_wire,
                 "wall_s": hand_wall},
        "auto": plan.to_dict(),
        "auto_vs_hand": hand_wall / plan.wall_s if plan.wall_s > 0 else 1.0,
    }


def show(rec, prof, label=""):
    rl = rec["roofline"]
    m = rec["memory"]
    print(f"[{label}] {rec['arch']} x {rec['shape']} x {rec['mesh']}"
          f"{' pipeline-k=' + str(rec['pipeline_k']) if rec['pipeline_k'] else ''}")
    print(f"  t_compute {rl['t_compute_s']:.4f}s  t_memory "
          f"{rl['t_memory_s']:.4f}s  t_coll(ici) {rl['t_collective_s']:.4f}s"
          f"  t_coll(dcn) {rl.get('t_collective_dcn_s', 0.0):.4f}s"
          f"  -> {rl['bottleneck']}")
    print(f"  bound-MFU {rl['mfu_bound']:.3f}  useful/HLO "
          f"{rl['useful_flops_frac']:.3f}  temp/dev "
          f"{m['temp_bytes']/2**30:.2f} GiB")
    if "top_coll" in prof:
        print("  top collectives:")
        for b, op, t, md in prof["top_coll"][:6]:
            print(f"    {b/1e9:9.2f} GB  {op:19s} {t:34s} ...{md[-60:]}")
        print("  top traffic:")
        for b, op, t, md in prof["top_traffic"][:6]:
            print(f"    {b/1e9:9.2f} GB  {op:19s} {t:34s} ...{md[-60:]}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    from repro.launch.plan_args import add_plan_args
    add_plan_args(ap, flavor="lower", plan_out=False)
    ap.add_argument("--pipeline-auto", action="store_true",
                    help="run the roofline auto-planner on the lowered "
                         "cell and record hand-picked vs auto-picked "
                         "(k, v) under the same wall-time model")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--cast-gathers", action="store_true")
    ap.add_argument("--master-fp32", action="store_true",
                    help="bf16 model params + fp32 master in opt state")
    ap.add_argument("--tpu-model", action="store_true",
                    help="correct CPU-backend dtype/attention artifacts "
                         "(native bf16 MXU + Pallas flash kernel)")
    ap.add_argument("--pure-dp", action="store_true",
                    help="ZeRO-3 pure data parallelism over all chips "
                         "(attention-free regime)")
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--label", default="iter")
    ap.add_argument("--out", default="results/perf_iters.jsonl")
    args = ap.parse_args()

    if args.pipeline_auto and (args.mesh != "multi" or not args.pipeline_k):
        # fail BEFORE the expensive lower+compile: the planner extracts
        # its link time from the pipelined record's ppermute bytes
        raise SystemExit("--pipeline-auto needs --mesh multi and a "
                         "--pipeline-k cell (the planner reads the pod "
                         "pipeline's collective-permute bytes)")
    seq = None
    if args.no_seq_shard:
        seq = False
    if args.seq_shard:
        seq = True
    rec, prof = run_cell(args.arch, args.shape, args.mesh == "multi",
                         pipeline_k=args.pipeline_k,
                         pipeline_v=args.virtual_stages,
                         wire_dtype=args.wire_dtype,
                         cast_gathers=args.cast_gathers, seq_shard=seq,
                         microbatches=args.microbatches,
                         master_fp32=args.master_fp32,
                         pure_dp=args.pure_dp,
                         tpu_model=args.tpu_model)
    show(rec, prof, args.label)
    if args.pipeline_auto:
        from repro.configs import get_arch
        try:
            cmp = auto_plan_compare(
                rec, num_layers=get_arch(args.arch).full.num_layers)
        except ValueError as e:
            # never discard the compiled record over a planner hiccup
            rec["auto_plan_compare"] = {"error": str(e)}
            print(f"  auto plan FAILED: {e}")
        else:
            rec["auto_plan_compare"] = cmp
            a = cmp["auto"]
            print(f"  auto plan: k={a['k']} v={a['v']} "
                  f"wire={a.get('wire_dtype', 'none')}  "
                  f"{a['wall_s'] * 1e3:.2f} ms/batch vs hand "
                  f"k={cmp['hand']['k']} v={cmp['hand']['v']} "
                  f"wire={cmp['hand']['wire']} "
                  f"{cmp['hand']['wall_s'] * 1e3:.2f} ms "
                  f"({cmp['auto_vs_hand']:.2f}x)")
    rec["label"] = args.label
    rec["knobs"] = {"cast_gathers": args.cast_gathers, "seq_shard": seq,
                    "pipeline_k": args.pipeline_k,
                    "pipeline_v": args.virtual_stages,
                    "wire_dtype": args.wire_dtype,
                    "pipeline_auto": args.pipeline_auto,
                    "microbatches": args.microbatches,
                    "master_fp32": args.master_fp32,
                    "pure_dp": args.pure_dp,
                    "tpu_model": args.tpu_model}
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "a") as f:
        f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
