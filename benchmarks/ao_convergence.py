"""Algorithm 1 diagnostics: bubble-rate descent + Lemma 1 behaviour."""
from __future__ import annotations

import numpy as np

from repro.core.ao import algorithm1
from repro.core.costs import resnet18_profile
from repro.wireless.fleet import sample_fleet


def run(quick=False):
    prof = resnet18_profile()
    out = []
    seeds = range(3 if quick else 10)
    for seed in seeds:
        fleet = sample_fleet(8, seed=seed)
        res = algorithm1(prof, fleet, batch=512)
        out.append({
            "seed": seed,
            "l": res.plan.l,
            "k": res.plan.k,
            "bubble": res.bubble,
            "descent": res.history[0] - res.history[-1],
            "iters": len(res.history),
        })
    return out


def main(quick=False):
    rows = run(quick=quick)
    print(f"{'seed':>4s} {'l':>3s} {'k':>4s} {'bubble':>8s} "
          f"{'descent':>9s} {'iters':>6s}")
    for r in rows:
        print(f"{r['seed']:4d} {r['l']:3d} {r['k']:4d} {r['bubble']:8.4f} "
              f"{r['descent']:+9.4f} {r['iters']:6d}")
    bubbles = [r["bubble"] for r in rows]
    print(f"mean bubble rate {np.mean(bubbles):.4f} "
          f"(all descents >= 0: {all(r['descent'] >= -1e-9 for r in rows)})")
    return {"mean_bubble": float(np.mean(bubbles))}


if __name__ == "__main__":
    main()
