"""Continuous-batching serving bench — deterministic makespan + honesty.

One ``repro.serving`` engine run over a seeded ragged request mix
(prompt length 8, generation budgets cycled from {8, 32, 128}) against
the static run-to-completion convoy at ``--batch 8``, reduced to what is
bit-reproducible:

* **modeled makespan** in lane-token units: the engine bills ``slots``
  per fixed-shape decode step plus exact prefill tokens
  (``ServingEngine.engine_units``); the convoy bills ``batch *
  max(gen)`` per group (``convoy_units``).  The gated win is the ratio
  (>= 1.5x on this mix);
* **bit-identity**: every request's emitted tokens equal its solo
  batch=1 run-to-completion decode under the engine's sampling contract
  — continuous batching changes WHEN work runs, never WHAT it computes;
* **INFER wire honesty**: the split-serving loopback
  (``serving/infer.py``) for each dense codec, measured payload bytes
  vs ``protocol.billed_hop_bytes`` (<= 1% rel).

No timings in the result dict — wall clock belongs to the CSV row, not
to the ``BENCH_pipeline.json`` diff gate this feeds.
"""
from __future__ import annotations

import numpy as np

SEED = 0
PROMPT_LEN = 8
GEN_MIX = (8, 32, 128)
SLOTS = 8
CONVOY_BATCH = 8
POLICY = "longest_first"
INFER_CODECS = ("none", "int8", "fp8")


def _cfg():
    from repro.models import LMConfig
    return LMConfig(name="serve-bench", num_layers=4, d_model=64,
                    n_heads=4, n_kv=2, d_ff=64, vocab=64, dtype="float32")


def _requests(cfg, n):
    from repro.serving.scheduler import Request
    rng = np.random.default_rng(SEED)
    gens = np.asarray([GEN_MIX[i % len(GEN_MIX)] for i in range(n)])
    rng.shuffle(gens)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, PROMPT_LEN),
                    max_new_tokens=int(gens[i]))
            for i in range(n)]


def _solo_outputs(model, params, requests, cache_len):
    """Batch-1 ground truth with jits shared across requests (all
    prompts are PROMPT_LEN, so one compile covers every request) —
    the same chain as ``serving.engine.solo_decode``."""
    import jax
    import jax.numpy as jnp

    from repro.parallel.steps import make_decode_step
    decode = jax.jit(make_decode_step(model))
    prefill = jax.jit(model.prefill_with_cache,
                      static_argnames=("cache_len", "cache_dtype"))
    out = {}
    for req in requests:
        logits, state = prefill(params,
                                {"tokens": jnp.asarray(req.prompt[None])},
                                cache_len=cache_len,
                                cache_dtype=jnp.float32)
        tok = jnp.argmax(logits, axis=-1, keepdims=True).astype(jnp.int32)
        toks = []
        for _ in range(req.max_new_tokens):
            logits, state = decode(params, state, tok)
            tok = jnp.argmax(logits, axis=-1,
                             keepdims=True).astype(jnp.int32)
            toks.append(int(tok[0, 0]))
        out[req.rid] = np.asarray(toks, np.int32)
    return out


def _infer_honesty(model, params, cfg):
    from repro.serving.infer import run_split_infer
    rng = np.random.default_rng(SEED + 1)
    prompts = rng.integers(0, cfg.vocab, (2, PROMPT_LEN)).astype(np.int32)
    rows = {}
    for codec in INFER_CODECS:
        res = run_split_infer(model, params, cut=cfg.num_layers // 2,
                              prompts=prompts, gen=4,
                              cache_len=PROMPT_LEN + 4, wire_dtype=codec)
        rel = abs(res["measured_payload_bytes"]
                  - res["billed_payload_bytes"]) \
            / max(res["billed_payload_bytes"], 1e-9)
        rows[codec] = {
            "measured_bytes": int(res["measured_payload_bytes"]),
            "billed_bytes": float(res["billed_payload_bytes"]),
            "frames": int(res["frames"]),
            "ok": bool(rel <= 0.01),
        }
    return rows


def main(quick: bool = True):
    import jax

    from repro.models import LM
    from repro.serving.engine import ServingEngine, convoy_units

    n_requests = 24 if quick else 48
    cfg = _cfg()
    model = LM(cfg)
    params = model.init(jax.random.key(SEED))
    cache_len = PROMPT_LEN + max(GEN_MIX)
    requests = _requests(cfg, n_requests)

    engine = ServingEngine(model, params, slots=SLOTS,
                           cache_len=cache_len, seed=SEED, policy=POLICY)
    outputs = engine.run(requests)
    stats = engine.stats()

    solo = _solo_outputs(model, params, requests, cache_len)
    bitexact = all(np.array_equal(outputs[r.rid], solo[r.rid])
                   for r in requests)

    convoy = convoy_units(requests, CONVOY_BATCH)
    speedup = convoy / max(stats["engine_units"], 1)
    honesty = _infer_honesty(model, params, cfg)

    out = {
        "requests": n_requests,
        "prompt_len": PROMPT_LEN,
        "gen_mix": list(GEN_MIX),
        "slots": SLOTS,
        "policy": POLICY,
        "convoy_batch": CONVOY_BATCH,
        "decode_steps": stats["decode_steps"],
        "prefill_chunks": stats["prefill_chunks"],
        "engine_units": stats["engine_units"],
        "convoy_units": convoy,
        "modeled_speedup": float(speedup),
        "occupancy_mean": stats["occupancy_mean"],
        "tokens_bitexact_vs_solo": bool(bitexact),
        "completed": stats["qos"]["completed"],
        "infer_wire": honesty,
        "infer_wire_ok": bool(all(r["ok"] for r in honesty.values())),
    }
    assert out["completed"] == n_requests, stats["qos"]
    assert bitexact, "continuous-batching outputs diverged from solo"
    assert speedup >= 1.5, \
        f"modeled speedup {speedup:.2f}x < 1.5x vs convoy"
    assert out["infer_wire_ok"], honesty
    print(f"  {n_requests} requests (gen mix {list(GEN_MIX)}) on "
          f"{SLOTS} slots [{POLICY}]: engine {out['engine_units']} vs "
          f"convoy {convoy} lane-tokens -> {speedup:.2f}x, "
          f"occupancy {out['occupancy_mean']:.2f}")
    print(f"  bit-identical to solo decode: {bitexact}; INFER honesty: "
          + ", ".join(f"{c} {r['measured_bytes']}B"
                      for c, r in honesty.items()))
    return out


if __name__ == "__main__":
    main()
