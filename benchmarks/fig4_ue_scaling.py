"""Paper Fig. 4: training convergence time vs number of UEs.

Claim: C2P2SL averages ~53% reduction vs PSL across UE counts, and the
time is roughly constant in n (fixed total dataset).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import averaged

UE_COUNTS = (4, 8, 12, 16)


def run(seeds=range(8), quick=False):
    seeds = range(3) if quick else seeds
    rows = []
    for n in UE_COUNTS:
        r = averaged(n, seeds)
        r["n"] = n
        r["reduction_vs_psl"] = 1.0 - r["C2P2SL"] / r["PSL"]
        rows.append(r)
    avg_red = float(np.mean([r["reduction_vs_psl"] for r in rows]))
    return rows, avg_red


def main(quick=False):
    rows, avg_red = run(quick=quick)
    print(f"{'n':>3s} {'SL':>10s} {'PSL':>10s} {'EPSL':>10s} "
          f"{'C2P2SL':>10s} {'vs PSL':>8s}")
    for r in rows:
        print(f"{r['n']:3d} {r['SL']:10.3f} {r['PSL']:10.3f} "
              f"{r['EPSL']:10.3f} {r['C2P2SL']:10.3f} "
              f"{100 * r['reduction_vs_psl']:7.1f}%")
    print(f"average reduction vs PSL: {100 * avg_red:.1f}% "
          f"(paper claims ~53%)")
    return {"avg_reduction_vs_psl": avg_red,
            "per_n": {r["n"]: r["reduction_vs_psl"] for r in rows}}


if __name__ == "__main__":
    main()
