"""Pipeline-plan bench: the codec-aware planner trajectory on the
checked-in roofline fixture.

This is the same measurement -> (S, k, v, wire) path ``perf_iter.py
--pipeline-auto`` and ``dryrun.py`` attach to freshly-compiled cells
(``autotune.plan_inputs_from_record`` + ``wire_plan_sweep``), run on
``tests/fixtures/roofline_smoke.json`` so it is deterministic and
compile-free — which is what lets CI diff every run against the committed
``benchmarks/BENCH_pipeline.json`` baseline (``benchmarks/run.py --diff``)
and catch any silent drift in the planner objective, the codec byte
model, or the extraction math.
"""
from __future__ import annotations

import json
import os

FIXTURE = os.path.join(os.path.dirname(__file__), "..", "tests",
                       "fixtures", "roofline_smoke.json")


def main(quick: bool = True):
    from repro.analysis.autotune import (WIRE_AUTO, plan_inputs_from_record,
                                         wire_plan_sweep)
    with open(FIXTURE) as f:
        record = json.load(f)
    inp = plan_inputs_from_record(record)
    res = wire_plan_sweep(inp, wire_candidates=WIRE_AUTO)
    chosen, sweep = res["chosen"], res["sweep"]

    none_link = sweep["none"]["wire_link_s"]
    out = {
        "cells": len(sweep),
        "chosen_wire": chosen["wire_dtype"],
        "chosen_k": chosen["k"],
        "chosen_v": chosen["v"],
        "chosen_wall_ms": chosen["wall_s"] * 1e3,
        "speedup_vs_unpipelined": chosen["speedup"],
        "bubble": chosen["bubble"],
        "link_shrink_int8": none_link / sweep["int8"]["wire_link_s"],
        "link_shrink_fp8": none_link / sweep["fp8"]["wire_link_s"],
        "wall_ms_by_wire": {w: row["wall_s"] * 1e3
                            for w, row in sweep.items()},
        "plan_by_wire": {w: (row["k"], row["v"])
                         for w, row in sweep.items()},
    }
    for w, row in sweep.items():
        print(f"  wire={w:5s} k={row['k']:3d} v={row['v']} "
              f"link {row['wire_link_s'] * 1e3:7.3f} ms "
              f"wall {row['wall_s'] * 1e3:8.3f} ms "
              f"({row['speedup_vs_none']:.4f}x vs uncoded)")
    print(f"  chosen: wire={out['chosen_wire']} k={out['chosen_k']} "
          f"v={out['chosen_v']}  int8 link shrink "
          f"{out['link_shrink_int8']:.2f}x")
    return out


if __name__ == "__main__":
    main()
