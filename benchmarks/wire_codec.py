"""Wire-codec microbench: fused Pallas encode/decode vs the jnp reference.

Two jobs:

  1. a deterministic ``result`` dict for ``run.py --diff``: fused-vs-jnp
     bit-parity booleans under jit, payload shapes/dtypes, and the
     planner's wire byte model per case — no timings (those are
     machine-dependent, and ``--diff`` compares the whole dict);
  2. measured throughput on stdout for both impls, ending in a
     ``codec_s_per_byte`` planner hint — the encode+decode seconds per
     payload byte that ``autotune.plan_inputs_from_record`` bills against
     a codec's link saving (paste it into ``planner_hints`` /
     ``--plan-hints``; see docs/autotune.md).

The parity contract is jit-vs-jit: both paths run under ``jax.jit`` (the
fused wrappers in ``kernels/ops.py`` are jitted already) because eager
XLA compiles the ``/qmax`` scale division differently (reciprocal
multiply) than the jitted kernel — a ~1e-9 scale wobble that is not a
codec bug.  Off-TPU the fused kernels run in Pallas interpret mode.
"""
from __future__ import annotations

import time

import numpy as np


def _bits_equal(a, b) -> bool:
    a, b = np.asarray(a), np.asarray(b)
    return (a.shape == b.shape and a.dtype == b.dtype
            and a.tobytes() == b.tobytes())


def _time_codec(fn, x, repeats: int) -> float:
    """Best-of-N seconds for one encode+decode round trip of ``x``."""
    fn(x)  # compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(x)
        import jax
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def main(quick: bool = True):
    import jax
    import jax.numpy as jnp

    from repro.analysis.autotune import (wire_bytes_per_element,
                                         wire_bytes_per_element_bwd)
    from repro.parallel import wire

    try:
        wire.validate_wire_dtype("fp8")
        have_fp8 = True
    except NotImplementedError:
        have_fp8 = False

    # -- bit-parity + format evidence (the deterministic result) ----------
    cases = [("int8", jnp.bfloat16, (3, 5, 384)),
             ("int8", jnp.float32, (15, 2560))]
    if have_fp8:
        cases.append(("fp8", jnp.bfloat16, (4, 64, 256)))

    result = {"backend": jax.default_backend(), "cases": {}}
    for wd, dtype, shape in cases:
        key = f"{wd}/{np.dtype(dtype).name}/" + "x".join(map(str, shape))
        rng = np.random.default_rng(hash(key) % (2 ** 31))
        x = jnp.asarray(rng.standard_normal(shape), dtype)
        enc_jnp = jax.jit(lambda x, w=wd: wire.encode(x, w, impl="jnp"))
        enc_fused = jax.jit(lambda x, w=wd: wire.encode(x, w, impl="fused"))
        qj, sj = enc_jnp(x)
        qf, sf = enc_fused(x)
        dec_jnp = jax.jit(lambda q, s, d=dtype:
                          wire.decode(q, s, d, impl="jnp"))
        dec_fused = jax.jit(lambda q, s, d=dtype:
                            wire.decode(q, s, d, impl="fused"))
        d = shape[-1]
        itemsize = jnp.dtype(dtype).itemsize
        result["cases"][key] = {
            "wire_block": wire.wire_block(d),
            "payload_dtype": str(np.asarray(qj).dtype),
            "payload_shape": list(qj.shape),
            "scale_shape": list(sj.shape),
            "encode_parity": (_bits_equal(qj, qf) and _bits_equal(sj, sf)),
            "decode_parity": _bits_equal(dec_jnp(qj, sj), dec_fused(qj, sj)),
            "bytes_per_elt_fwd": wire_bytes_per_element(
                wd, itemsize, wire.wire_block(d)),
            "bytes_per_elt_bwd_topk0.25": wire_bytes_per_element_bwd(
                f"{wd}+topk0.25", itemsize, wire.wire_block(d), d_model=d),
        }
        print(f"  {key:26s} block {wire.wire_block(d):3d}  "
              f"parity enc={result['cases'][key]['encode_parity']} "
              f"dec={result['cases'][key]['decode_parity']}")

    # top-k payload format (backward-hop codec) on a fixed case
    d = 512
    rng = np.random.default_rng(7)
    g = jnp.asarray(rng.standard_normal((6, d)), jnp.float32)
    q, idx, scale = wire.topk_encode(g, "int8+topk0.25")
    dec = wire.topk_decode(q, idx, scale, d, jnp.float32)
    kept = np.take_along_axis(np.asarray(g), np.asarray(idx, np.int64), -1)
    result["topk"] = {
        "kk": int(q.shape[-1]),
        "idx_dtype": str(np.asarray(idx).dtype),
        "scale_shape": list(scale.shape),
        # the decode reproduces exactly the kept entries (quantized) and
        # nothing else: the dropped mass is what error feedback carries
        "kept_mass_frac_q01": round(
            float(np.linalg.norm(kept)) ** 2
            / float(np.linalg.norm(np.asarray(g))) ** 2, 1),
        "decode_support_matches": bool(
            (np.count_nonzero(np.asarray(dec), axis=-1)
             <= q.shape[-1]).all()),
    }
    print(f"  topk0.25 d={d}: kk={result['topk']['kk']} "
          f"idx={result['topk']['idx_dtype']}")

    # -- throughput + the codec_s_per_byte planner hint (stdout only) -----
    shape = (64, 128, 2560) if not quick else (16, 128, 2560)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(shape), jnp.bfloat16)
    nbytes = x.size * x.dtype.itemsize
    repeats = 10 if not quick else 3
    times = {}
    for impl in ("jnp", "fused"):
        rt = jax.jit(lambda x, i=impl: wire.roundtrip(x, "int8", i))
        times[impl] = _time_codec(rt, x, repeats)
        print(f"  int8 roundtrip [{impl:5s}] {shape}: "
              f"{times[impl] * 1e3:8.3f} ms  "
              f"({nbytes / times[impl] / 2 ** 30:6.2f} GiB/s)")
    # off-TPU the jnp path is what production runs (wire._impl('auto')),
    # so the hint follows the faster of the two — on TPU that is fused
    hint = min(times.values()) / nbytes
    print(f'  planner_hints: {{"codec_s_per_byte": {hint:.3e}}}')
    return result


if __name__ == "__main__":
    main()
