"""Shared benchmark plumbing: scheme timing under the wireless system model.

"Convergence time" follows the paper's accounting: SL / PSL / C2P2SL apply
mathematically equivalent updates (tests/test_equivalence.py), so the
convergence time ratio equals the per-round makespan ratio; EPSL converges
in more rounds at lower final accuracy (Fig 3) — reported separately by
fig3_accuracy.
"""
from __future__ import annotations

import numpy as np

from repro.core.ao import algorithm1, feasible_l
from repro.core.costs import LayerProfile, resnet18_profile
from repro.core.schedule import (Plan, simulate_c2p2sl, simulate_epsl,
                                 simulate_psl, simulate_sl, task_times)
from repro.wireless.channel import ChannelParams
from repro.wireless.fleet import sample_fleet


def scheme_round_times(n_ue: int, seed: int, *,
                       bandwidth_hz: float = 100e6,
                       batch: int = 512,
                       profile: LayerProfile | None = None) -> dict:
    """Per-batch makespan of each scheme on one sampled fleet.

    Baselines follow their papers: one shared cut layer (the best
    storage-feasible single cut under uniform allocation), uniform batch
    split and uniform TDMA slots.  C2P2SL jointly optimizes (l, k, b, tau)
    with Algorithm 1.
    """
    prof = profile or resnet18_profile()
    ch = ChannelParams(bandwidth_hz=bandwidth_hz)
    fleet = sample_fleet(n_ue, seed=seed, channel=ch)
    b_uni = np.full(n_ue, batch / n_ue)
    tau_uni = np.full(n_ue, ch.frame_s / n_ue)

    # baseline cut: best feasible single choice for PSL (fair baseline)
    best_l, best_psl = None, np.inf
    for l in feasible_l(prof, fleet, b_uni):
        t1 = task_times(prof, fleet, Plan(l=l, k=1, b=b_uni, tau=tau_uni))
        ms = simulate_psl(t1)
        if ms < best_psl:
            best_l, best_psl = l, ms
    t1 = task_times(prof, fleet, Plan(l=best_l, k=1, b=b_uni, tau=tau_uni))

    res = algorithm1(prof, fleet, batch=batch)
    t_opt = task_times(prof, fleet, res.plan)
    ms_c2p2, _ = simulate_c2p2sl(t_opt, res.plan.k,
                                 virtual_stages=res.plan.v)

    return {
        "SL": simulate_sl(prof, fleet, Plan(l=best_l, k=1, b=b_uni,
                                            tau=tau_uni)),
        "PSL": best_psl,
        "EPSL": simulate_epsl(t1, n_ue),
        "C2P2SL": ms_c2p2,
        "plan": res.plan,
        "bubble": res.bubble,
    }


def averaged(n_ue: int, seeds, **kw) -> dict:
    acc = {}
    for s in seeds:
        r = scheme_round_times(n_ue, s, **kw)
        for k in ("SL", "PSL", "EPSL", "C2P2SL"):
            acc.setdefault(k, []).append(r[k])
    return {k: float(np.mean(v)) for k, v in acc.items()}
