"""Measured ppermute microbenchmark -> planner hints.

The auto-planner's ``hop_overhead_s`` (per-micro-batch message cost of one
inter-stage hop) defaulted to the ``HW["dcn_latency_s"]`` constant; this
probe MEASURES it on the machine it runs on, by timing a jitted shard_map
``ppermute`` over a 2-wide 'pod' axis at several payload sizes and fitting

    t(bytes) = hop_overhead_s + bytes / link_bw_Bps

with least squares.  The output JSON carries a ``planner_hints`` dict in
exactly the shape ``autotune.plan_inputs_from_record`` consumes:

    PYTHONPATH=src python -m benchmarks.ppermute_probe \
        --out results/ppermute_probe.json
    PYTHONPATH=src python -m repro.launch.train ... \
        --pipeline-k auto --plan-hints results/ppermute_probe.json
    PYTHONPATH=src python -m repro.analysis.autotune \
        --roofline ... --hints results/ppermute_probe.json

Caveat (printed into the record): on a CPU host with forced devices the
"link" is loopback shared memory — useful for closing the plumbing and for
single-host pods, but the production calibration should run on the real
multi-pod slice, where the same command measures the actual DCN hop.
"""
from __future__ import annotations

import argparse
import json
import os
import time


def fit_overhead(points):
    """[(bytes, seconds), ...] -> (hop_overhead_s, link_bw_Bps).

    Ordinary least squares on t = a + b * bytes; the intercept is clamped
    at >= 0 (timer noise can drive it slightly negative on fast links)
    and a non-positive slope degenerates to an effectively infinite
    bandwidth (1e15 B/s) rather than a nonsensical negative one.
    """
    import numpy as np
    pts = np.asarray(points, dtype=np.float64)
    if pts.ndim != 2 or pts.shape[0] < 2:
        raise ValueError("need at least two (bytes, seconds) points to fit")
    x, y = pts[:, 0], pts[:, 1]
    a_mat = np.stack([np.ones_like(x), x], axis=1)
    (a, b), *_ = np.linalg.lstsq(a_mat, y, rcond=None)
    overhead = float(max(a, 0.0))
    bw = float(1.0 / b) if b > 0 else 1e15
    return overhead, bw


def _time_call(fn, x, repeats: int) -> float:
    """Best-of-N wall seconds of one jitted hop (min filters scheduler
    noise, the standard microbenchmark estimator)."""
    import jax
    jax.block_until_ready(fn(x))       # compile + warm cache
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x))
        best = min(best, time.perf_counter() - t0)
    return best


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="measure ppermute hop overhead + bandwidth -> "
                    "planner hints JSON")
    ap.add_argument("--devices", type=int, default=2,
                    help="pod-axis width; forced as host devices when the "
                         "process has fewer (must be set before jax init)")
    ap.add_argument("--sizes-kib", default="64,256,1024,4096,16384",
                    help="comma-separated per-device payload sizes (KiB)")
    ap.add_argument("--repeats", type=int, default=20)
    ap.add_argument("--out", default="results/ppermute_probe.json")
    args = ap.parse_args(argv)

    if "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")
    import jax
    import jax.numpy as jnp

    from repro.parallel import compat
    from repro.parallel.compat import PartitionSpec as P

    n = min(args.devices, len(jax.devices()))
    if n < 2:
        raise SystemExit(
            f"ppermute probe needs >= 2 devices, have {len(jax.devices())} "
            "(run the module fresh so it can set XLA_FLAGS, or run on a "
            "real slice)")
    mesh = compat.make_mesh((n,), ("pod",))
    perm = [(i, (i + 1) % n) for i in range(n)]

    def hop(x):
        return jax.lax.ppermute(x, "pod", perm)

    fn = jax.jit(compat.shard_map(hop, mesh, in_specs=(P("pod"),),
                                  out_specs=P("pod"), check=False))

    points = []
    sizes = [int(s) for s in args.sizes_kib.split(",") if s.strip()]
    for kib in sizes:
        elems = max(1, kib * 1024 // 2)            # bf16 payload
        x = jnp.zeros((n, elems), jnp.bfloat16)
        t = _time_call(fn, x, args.repeats)
        nbytes = elems * 2                          # per-device hop bytes
        points.append([nbytes, t])
        print(f"  {nbytes / 2 ** 20:8.2f} MiB/device  {t * 1e6:10.1f} us")

    overhead, bw = fit_overhead(points)
    doc = {
        "kind": "ppermute_probe",
        "backend": jax.default_backend(),
        "devices": n,
        "jax": jax.__version__,
        "points_bytes_seconds": points,
        "note": ("loopback measurement when backend=cpu with forced host "
                 "devices; calibrate on the real multi-pod slice for "
                 "production hints"),
        "planner_hints": {
            "hop_overhead_s": overhead,
            "link_bw_Bps": bw,
        },
    }
    print(f"fit: hop_overhead_s={overhead:.3e}  "
          f"link_bw={bw / 1e9:.2f} GB/s  "
          f"(HW constants: dcn_latency 2.5e-05, dcn_bw 3.10 GB/s)")
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"wrote {args.out} — feed it to train.py --plan-hints or "
          "autotune --hints")
    return doc


if __name__ == "__main__":
    main()
