"""Staticcheck gate bench: the device-free slice of the pipeline
invariant auditor as a deterministic bench row.

Runs the jaxpr-level audit over the full fixture matrix (both hop
directions x four wire grammars x v in {1,2}, on whichever shard_map
lowering this interpreter selects), the planner byte-model
reconciliation, the roofline-record honesty round-trip and the AST lint
pack — and returns counts that must be bit-stable, so the committed
``BENCH_pipeline.json`` row catches codec/planner/schedule drift through
the ordinary ``run.py --diff`` path as well as the dedicated CI
staticcheck job.  The compiled-HLO level needs forced host devices
before jax imports, so it lives in the ``staticcheck`` CI job
(``python -m repro.analysis.staticcheck --level full``), not here.
"""
from __future__ import annotations


def main(quick: bool = True):
    from repro.analysis import staticcheck
    from repro.analysis.lint import lint_paths

    violations, cells = staticcheck.audit_cells(level="jaxpr")
    model_violations = staticcheck.audit_byte_model(act_bytes=4.0,
                                                    d_model=2560)
    import json
    import os
    with open(staticcheck.ROOFLINE_FIXTURE) as f:
        record = json.load(f)
    rec_violations, rec_stats = staticcheck.audit_record_honesty(record)
    lint = lint_paths([os.path.join(os.path.dirname(__file__), "..",
                                    "src", "repro")])
    out = {
        "cells": len(cells),
        "violations": len(violations),
        "byte_model_cases": 2 * len(staticcheck.AUDIT_WIRES),
        "byte_model_violations": len(model_violations),
        "record_violations": len(rec_violations),
        "record_ticks": rec_stats.get("ticks0"),
        "record_pp_rebilled_ratio": (
            rec_stats["rebilled_pp_bytes"] / rec_stats["measured_pp_bytes"]
            if rec_stats.get("measured_pp_bytes") else None),
        "lint_violations": len(lint),
        "ok": not (violations or model_violations or rec_violations
                   or lint),
    }
    print(f"staticcheck gate: {out['cells']} cells, "
          f"{out['violations']} audit / {out['byte_model_violations']} "
          f"byte-model / {out['record_violations']} record / "
          f"{out['lint_violations']} lint violation(s)")
    return out


if __name__ == "__main__":
    main()
