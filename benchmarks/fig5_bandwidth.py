"""Paper Fig. 5: training convergence time vs system bandwidth (n=8).

Claim: >= 38% reduction vs PSL across B in [100, 300] MHz, with larger
gains in poorer channels.
"""
from __future__ import annotations


from benchmarks.common import averaged

BANDWIDTHS_MHZ = (100, 150, 200, 250, 300)


def run(seeds=range(8), quick=False):
    seeds = range(3) if quick else seeds
    rows = []
    for bw in BANDWIDTHS_MHZ:
        r = averaged(8, seeds, bandwidth_hz=bw * 1e6)
        r["bw_mhz"] = bw
        r["reduction_vs_psl"] = 1.0 - r["C2P2SL"] / r["PSL"]
        rows.append(r)
    return rows


def main(quick=False):
    rows = run(quick=quick)
    print(f"{'MHz':>4s} {'SL':>10s} {'PSL':>10s} {'EPSL':>10s} "
          f"{'C2P2SL':>10s} {'vs PSL':>8s}")
    for r in rows:
        print(f"{r['bw_mhz']:4d} {r['SL']:10.3f} {r['PSL']:10.3f} "
              f"{r['EPSL']:10.3f} {r['C2P2SL']:10.3f} "
              f"{100 * r['reduction_vs_psl']:7.1f}%")
    worst = min(r["reduction_vs_psl"] for r in rows)
    print(f"minimum reduction vs PSL: {100 * worst:.1f}% "
          f"(paper claims >= 38%)")
    return {"min_reduction_vs_psl": worst,
            "per_bw": {r["bw_mhz"]: r["reduction_vs_psl"] for r in rows}}


if __name__ == "__main__":
    main()
