"""Paper Fig. 3: test accuracy vs (simulated) training time, n=8 UEs.

Real JAX training of the paper's split ResNet-18 on the synthetic
CIFAR-10 stand-in (offline container), with per-round wall time taken from
the event-driven schedule simulator.  Claims validated:
  * C2P2SL accuracy tracks PSL/SL exactly (identical updates),
  * EPSL converges lower (gradient aggregation approximation),
  * C2P2SL reaches any accuracy threshold in the least simulated time.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import scheme_round_times
from repro.data import image_batches
from repro.models import resnet
from repro.sl import (init_sl_state, make_c2p2sl_step, make_epsl_step,
                      make_psl_step, make_sl_step, resnet_split, shard_batch)
from repro.training import sgd


def eval_acc(params, batches):
    accs = []
    for b in batches:
        logits = resnet.forward(params, b["images"])
        accs.append(float((logits.argmax(-1) == b["labels"]).mean()))
    return float(np.mean(accs))


def run(steps=120, batch=64, n_ue=8, eval_every=20, seed=0, quick=False):
    if quick:
        steps, batch = 40, 32
    times = scheme_round_times(n_ue, seed, batch=batch)
    plan = times["plan"]
    k = plan.k

    # split data per plan batch sizes (scaled to the benchmark batch)
    b_alloc = np.maximum(1, np.round(
        plan.b / plan.b.sum() * batch)).astype(int)
    b_alloc[np.argmax(b_alloc)] += batch - b_alloc.sum()
    k_run = int(min(k, np.min(b_alloc[b_alloc > 0])))

    gen = image_batches(batch, seed=seed)
    test_batches = [next(image_batches(64, seed=999 + i)) for i in range(4)]
    l = plan.l
    spec = resnet_split(l)
    opt = sgd(0.05, momentum=0.9)

    schemes = {
        "C2P2SL": (make_c2p2sl_step(spec, opt, k=k_run), times["C2P2SL"]),
        "PSL": (make_psl_step(spec, opt), times["PSL"]),
        "SL": (make_sl_step(spec, opt), times["SL"]),
        "EPSL": (make_epsl_step(spec, opt), times["EPSL"]),
    }

    curves = {}
    params0 = resnet.init_resnet18(jax.random.key(seed))
    for name, (step, round_s) in schemes.items():
        state = init_sl_state(spec, params0, opt)
        tree = {"ue_params": state.ue_params, "bs_params": state.bs_params,
                "opt_state_ue": state.opt_state_ue,
                "opt_state_bs": state.opt_state_bs, "step": state.step}
        jit_step = jax.jit(step)
        gen_s = image_batches(batch, seed=seed)
        curve = []
        kk = k_run if name == "C2P2SL" else 1
        for i in range(steps):
            bt = next(gen_s)
            xs, ys = shard_batch(bt["images"], bt["labels"], b_alloc, kk)
            tree, mets = jit_step(tree, xs, ys)
            if (i + 1) % eval_every == 0 or i == steps - 1:
                merged = spec.merge_params(tree["ue_params"],
                                           tree["bs_params"])
                curve.append(((i + 1) * round_s, eval_acc(merged,
                                                          test_batches)))
        curves[name] = curve
    return curves


def main(quick=False):
    curves = run(quick=quick)
    print(f"{'scheme':>8s} {'final acc':>10s} {'sim time (s)':>13s}")
    final = {}
    for name, curve in curves.items():
        t, acc = curve[-1]
        final[name] = (acc, t)
        print(f"{name:>8s} {acc:10.3f} {t:13.1f}")
    # threshold time: first time reaching 90% of PSL's final accuracy
    thr = 0.9 * final["PSL"][0]
    t_at = {}
    for name, curve in curves.items():
        hit = [t for t, a in curve if a >= thr]
        t_at[name] = min(hit) if hit else float("inf")
    out = {"final": final, "t_at_threshold": t_at}
    if np.isfinite(t_at["C2P2SL"]) and np.isfinite(t_at["PSL"]):
        speedup = 1 - t_at["C2P2SL"] / t_at["PSL"]
        print(f"time-to-{thr:.2f}-acc reduction vs PSL: {100*speedup:.1f}%")
        out["tta_reduction_vs_psl"] = speedup
    print(f"acc parity |C2P2SL - PSL| = "
          f"{abs(final['C2P2SL'][0] - final['PSL'][0]):.4f} (exact updates)")
    print(f"EPSL acc gap vs PSL = {final['PSL'][0] - final['EPSL'][0]:+.4f}")
    return out


if __name__ == "__main__":
    main()
