"""Streaming-runtime smoke bench: the async UE->BS loop over a REAL
loopback socket, reduced to its deterministic outputs.

One ``repro.runtime`` run — N UE client tasks, the BS dispatcher, the
``int8+topk0.25`` codec with per-client error feedback on the gradient
hop — reporting only what is bit-reproducible: the per-round loss
trajectory (arrival order cannot change it: per-arrival micro-steps use
the pre-round params and the round reduction is sorted), the measured
codec-payload bytes per hop against the planner's
``wire_bytes_per_element(_bwd)`` billing, and the frame counts.  No
timings, no QoS rates, no shaper — those are wall-clock-dependent and
belong to ``--qos-out`` sidecars, not to the ``BENCH_pipeline.json``
diff gate this row feeds.
"""
from __future__ import annotations

import asyncio

import numpy as np

WIRE = "int8+topk0.25"
CUT = 2
SEQ = 16
BATCH_PER_CLIENT = 2
SEED = 0


def main(quick: bool = True):
    from repro.models import LMConfig
    from repro.runtime.driver import run_streaming

    n_clients, steps = (2, 4) if quick else (4, 8)
    cfg = LMConfig(name="stream-smoke", num_layers=4, d_model=64,
                   n_heads=4, n_kv=2, d_ff=64, vocab=64, dtype="float32")
    res = asyncio.run(run_streaming(
        cfg, cut=CUT, n_clients=n_clients, steps=steps,
        batch_per_client=BATCH_PER_CLIENT, seq=SEQ, seed=SEED,
        wire_dtype=WIRE, lr=1e-3))

    losses = [float(x) for x in res["losses"]]
    qos = res["qos"]
    honesty = res["wire_honesty"]
    out = {
        "clients": n_clients,
        "steps": steps,
        "wire_dtype": WIRE,
        "losses": losses,
        "frames_in": qos["totals"]["frames_in"],
        "payload_bytes_in": qos["totals"]["payload_bytes_in"],
        "payload_bytes_out": qos["totals"]["payload_bytes_out"],
        "uplink": honesty["uplink"],
        "downlink": honesty["downlink"],
        "honesty_ok": bool(all(r["ok"] for rows in honesty.values()
                               for r in rows)),
    }
    assert all(np.isfinite(losses)), f"non-finite streamed loss: {losses}"
    assert out["frames_in"] == n_clients * steps
    assert out["honesty_ok"], honesty
    up = honesty["uplink"][0]
    dn = honesty["downlink"][0]
    print(f"  {n_clients} UE x {steps} rounds over loopback, wire={WIRE}: "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    print(f"  uplink  {up['measured_bytes']} B/hop measured vs "
          f"{up['billed_bytes']:.1f} billed")
    print(f"  downlink {dn['measured_bytes']} B/hop measured vs "
          f"{dn['billed_bytes']:.1f} billed (top-k + EF)")
    return out


if __name__ == "__main__":
    main()
