"""Benchmark aggregator — one entry per paper table/figure.

Prints ``name,seconds,derived`` CSV rows.  ``--full`` uses the paper-scale
seeds/steps; the default quick mode keeps the whole suite CPU-friendly.
``--only a,b`` restricts to a subset (the CI smoke job runs the
schedule-level benches) and ``--json-out`` writes the timing rows as JSON
so the ``BENCH_*.json`` trajectory can accumulate across CI runs.

``--diff BASELINE.json`` compares this run's result dicts against a
committed baseline (``benchmarks/BENCH_pipeline.json``) for every bench
present in both, within ``--diff-rtol``; a mismatch exits non-zero, so the
CI bench-smoke job catches silent drift in deterministic benches.  Timing
(``seconds``) is never diffed — only results.
"""
from __future__ import annotations

import argparse
import json
import platform
import time


def _diff_values(path, base, new, rtol, failures):
    """Recursive numeric/structural compare; appends mismatch strings."""
    if isinstance(base, dict) and isinstance(new, dict):
        for key in sorted(set(base) | set(new)):
            if key not in base or key not in new:
                failures.append(f"{path}.{key}: "
                                f"{'missing in new run' if key in base else 'not in baseline'}")
                continue
            _diff_values(f"{path}.{key}", base[key], new[key], rtol,
                         failures)
        return
    if isinstance(base, (list, tuple)) and isinstance(new, (list, tuple)):
        if len(base) != len(new):
            failures.append(f"{path}: length {len(base)} != {len(new)}")
            return
        for i, (b, n) in enumerate(zip(base, new)):
            _diff_values(f"{path}[{i}]", b, n, rtol, failures)
        return
    if isinstance(base, bool) or isinstance(new, bool) \
            or not isinstance(base, (int, float)) \
            or not isinstance(new, (int, float)):
        if base != new:
            failures.append(f"{path}: {base!r} != {new!r}")
        return
    tol = rtol * max(abs(base), abs(new), 1e-12)
    if abs(base - new) > tol:
        failures.append(f"{path}: {base} != {new} (rtol {rtol})")


def diff_rows(base_rows, new_rows, rtol=1e-6):
    """Compare bench result dicts for benches present in BOTH row lists;
    returns a list of mismatch descriptions (empty = clean)."""
    base = {r["name"]: r.get("result") for r in base_rows}
    new = {r["name"]: r.get("result") for r in new_rows}
    failures = []
    for name in sorted(set(base) & set(new)):
        if base[name] is None or new[name] is None:
            continue
        _diff_values(name, base[name], new[name], rtol, failures)
    return failures


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names to run "
                         "(default: all)")
    ap.add_argument("--json-out", default=None,
                    help="write timing rows to this JSON file")
    ap.add_argument("--diff", default=None,
                    help="baseline BENCH_*.json to compare results "
                         "against (benches present in both; non-zero "
                         "exit on mismatch)")
    ap.add_argument("--diff-rtol", type=float, default=1e-6)
    args = ap.parse_args(argv)
    quick = not args.full

    from benchmarks import (ao_convergence, fig3_accuracy, fig4_ue_scaling,
                            fig5_bandwidth, pipeline_plan, replan_drift,
                            roofline_report, serve_bench, staticcheck_gate,
                            streaming_smoke, wire_codec)

    benches = {
        "fig4_ue_scaling": fig4_ue_scaling.main,
        "fig5_bandwidth": fig5_bandwidth.main,
        "ao_convergence": ao_convergence.main,
        "fig3_accuracy": fig3_accuracy.main,
        "roofline_report": roofline_report.main,
        "pipeline_plan": pipeline_plan.main,
        "wire_codec": wire_codec.main,
        "replan_drift": replan_drift.main,
        "staticcheck_gate": staticcheck_gate.main,
        "streaming_smoke": streaming_smoke.main,
        "serve_bench": serve_bench.main,
    }
    selected = list(benches)
    if args.only:
        selected = [s.strip() for s in args.only.split(",") if s.strip()]
        unknown = [s for s in selected if s not in benches]
        if unknown:
            raise SystemExit(f"unknown benches {unknown}; "
                             f"available: {sorted(benches)}")

    rows = []
    for name in selected:
        t0 = time.perf_counter()
        print(f"=== {name} ===", flush=True)
        out = benches[name](quick=quick)
        dt = time.perf_counter() - t0
        rows.append((name, dt, out))
        print()

    print("name,seconds,derived")
    json_rows = []
    for name, dt, out in rows:
        derived = ""
        if isinstance(out, dict):
            for k in ("avg_reduction_vs_psl", "min_reduction_vs_psl",
                      "tta_reduction_vs_psl", "mean_bubble", "cells"):
                if k in out:
                    v = out[k]
                    derived = f"{k}={v:.4f}" if isinstance(v, float) \
                        else f"{k}={v}"
                    break
        print(f"{name},{dt:.1f},{derived}")
        json_rows.append({"name": name, "seconds": round(dt, 3),
                          "derived": derived,
                          "result": out if isinstance(out, dict) else None})

    if args.json_out:
        doc = {"mode": "full" if args.full else "quick",
               "python": platform.python_version(),
               "rows": json_rows}
        try:
            import jax
            doc["jax"] = jax.__version__
        except Exception:
            pass
        with open(args.json_out, "w") as f:
            # benches return numpy scalars/arrays in places; .tolist()
            # covers both without a per-bench schema
            json.dump(doc, f, indent=1,
                      default=lambda o: o.tolist()
                      if hasattr(o, "tolist") else str(o))
        print(f"wrote {args.json_out}")

    if args.diff:
        with open(args.diff) as f:
            base = json.load(f)
        # normalize this run's rows through the same JSON encoding the
        # baseline went through (tuples -> lists, numpy -> python)
        new_rows = json.loads(json.dumps(
            json_rows, default=lambda o: o.tolist()
            if hasattr(o, "tolist") else str(o)))
        failures = diff_rows(base.get("rows", []), new_rows,
                             rtol=args.diff_rtol)
        base_names = {r["name"] for r in base.get("rows", [])
                      if isinstance(r.get("result"), dict)}
        new_names = {r["name"] for r in new_rows
                     if isinstance(r.get("result"), dict)}
        shared = sorted(base_names & new_names)
        new_only = sorted(new_names - base_names)
        if new_only:
            # a bench added since the baseline was committed: fine (it
            # starts being diffed once the baseline is regenerated), but
            # say so — silence here would look like coverage it isn't
            print(f"note: not in baseline, not diffed: "
                  f"{', '.join(new_only)}")
        if not shared:
            # a drift gate that matched nothing is a broken gate, not a
            # passing one (renamed bench, --only drift, non-dict result)
            print(f"bench diff vs {args.diff} FAILED: no overlapping "
                  "bench results to compare — the gate would be a no-op")
            raise SystemExit(1)
        if failures:
            print(f"bench diff vs {args.diff} FAILED "
                  f"({len(failures)} mismatches):")
            for fmsg in failures:
                print(f"  {fmsg}")
            raise SystemExit(1)
        print(f"bench diff vs {args.diff} OK ({', '.join(shared)})")


if __name__ == "__main__":
    main()
