"""Benchmark aggregator — one entry per paper table/figure.

Prints ``name,seconds,derived`` CSV rows.  ``--full`` uses the paper-scale
seeds/steps; the default quick mode keeps the whole suite CPU-friendly.
``--only a,b`` restricts to a subset (the CI smoke job runs the two
schedule-level benches) and ``--json-out`` writes the timing rows as JSON
so the ``BENCH_*.json`` trajectory can accumulate across CI runs.
"""
from __future__ import annotations

import argparse
import json
import platform
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names to run "
                         "(default: all)")
    ap.add_argument("--json-out", default=None,
                    help="write timing rows to this JSON file")
    args = ap.parse_args(argv)
    quick = not args.full

    from benchmarks import (ao_convergence, fig3_accuracy, fig4_ue_scaling,
                            fig5_bandwidth, roofline_report)

    benches = {
        "fig4_ue_scaling": fig4_ue_scaling.main,
        "fig5_bandwidth": fig5_bandwidth.main,
        "ao_convergence": ao_convergence.main,
        "fig3_accuracy": fig3_accuracy.main,
        "roofline_report": roofline_report.main,
    }
    selected = list(benches)
    if args.only:
        selected = [s.strip() for s in args.only.split(",") if s.strip()]
        unknown = [s for s in selected if s not in benches]
        if unknown:
            raise SystemExit(f"unknown benches {unknown}; "
                             f"available: {sorted(benches)}")

    rows = []
    for name in selected:
        t0 = time.perf_counter()
        print(f"=== {name} ===", flush=True)
        out = benches[name](quick=quick)
        dt = time.perf_counter() - t0
        rows.append((name, dt, out))
        print()

    print("name,seconds,derived")
    json_rows = []
    for name, dt, out in rows:
        derived = ""
        if isinstance(out, dict):
            for k in ("avg_reduction_vs_psl", "min_reduction_vs_psl",
                      "tta_reduction_vs_psl", "mean_bubble", "cells"):
                if k in out:
                    v = out[k]
                    derived = f"{k}={v:.4f}" if isinstance(v, float) \
                        else f"{k}={v}"
                    break
        print(f"{name},{dt:.1f},{derived}")
        json_rows.append({"name": name, "seconds": round(dt, 3),
                          "derived": derived,
                          "result": out if isinstance(out, dict) else None})

    if args.json_out:
        doc = {"mode": "full" if args.full else "quick",
               "python": platform.python_version(),
               "rows": json_rows}
        try:
            import jax
            doc["jax"] = jax.__version__
        except Exception:
            pass
        with open(args.json_out, "w") as f:
            # benches return numpy scalars/arrays in places; .tolist()
            # covers both without a per-bench schema
            json.dump(doc, f, indent=1,
                      default=lambda o: o.tolist()
                      if hasattr(o, "tolist") else str(o))
        print(f"wrote {args.json_out}")


if __name__ == "__main__":
    main()
