"""Benchmark aggregator — one entry per paper table/figure.

Prints ``name,seconds,derived`` CSV rows.  ``--full`` uses the paper-scale
seeds/steps; the default quick mode keeps the whole suite CPU-friendly.
"""
from __future__ import annotations

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    quick = not args.full

    from benchmarks import (ao_convergence, fig3_accuracy, fig4_ue_scaling,
                            fig5_bandwidth, roofline_report)

    rows = []

    def bench(name, fn):
        t0 = time.perf_counter()
        print(f"=== {name} ===", flush=True)
        out = fn(quick=quick)
        dt = time.perf_counter() - t0
        rows.append((name, dt, out))
        print()

    bench("fig4_ue_scaling", fig4_ue_scaling.main)
    bench("fig5_bandwidth", fig5_bandwidth.main)
    bench("ao_convergence", ao_convergence.main)
    bench("fig3_accuracy", fig3_accuracy.main)
    bench("roofline_report", roofline_report.main)

    print("name,seconds,derived")
    for name, dt, out in rows:
        derived = ""
        if isinstance(out, dict):
            for k in ("avg_reduction_vs_psl", "min_reduction_vs_psl",
                      "tta_reduction_vs_psl", "mean_bubble", "cells"):
                if k in out:
                    v = out[k]
                    derived = f"{k}={v:.4f}" if isinstance(v, float) \
                        else f"{k}={v}"
                    break
        print(f"{name},{dt:.1f},{derived}")


if __name__ == "__main__":
    main()
