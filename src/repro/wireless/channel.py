"""Wireless channel model of the paper (SII-B, Table I).

TDMA cellular system: all UEs share bandwidth ``B``; time is divided into
frames of length ``T`` subdivided into per-UE slots ``tau_i``.  Rates follow
Shannon's theorem under AWGN (eqs (5)-(6)); path loss is the 3GPP-style model
``h(d, f) = 28.0 + 22 log10(d) + 20 log10(f)`` dB used in SIV-A.
"""
from __future__ import annotations

import dataclasses
import numpy as np


@dataclasses.dataclass(frozen=True)
class ChannelParams:
    """System parameters; defaults are the paper's Table I."""

    bandwidth_hz: float = 100e6          # B
    carrier_ghz: float = 3.5             # f (GHz, enters path loss)
    frame_s: float = 10e-3               # T
    p_bs_dbm: float = 46.0               # downlink transmit power
    antenna_gain: float = 10.0           # G (linear)
    noise_psd_dbm_hz: float = -174.0     # N0

    @property
    def noise_w(self) -> float:
        return 10 ** (self.noise_psd_dbm_hz / 10) * 1e-3 * self.bandwidth_hz


def pathloss_db(d_m, f_ghz: float):
    """3GPP UMa-style LOS path loss (paper SIV-A), d in meters, f in GHz."""
    d = np.asarray(d_m, dtype=np.float64)
    return 28.0 + 22.0 * np.log10(d) + 20.0 * np.log10(f_ghz)


def shannon_rate(p_tx_dbm, d_m, ch: ChannelParams):
    """Achievable rate in bit/s over the full band (eqs (5)/(6))."""
    p_w = 10 ** (np.asarray(p_tx_dbm, dtype=np.float64) / 10) * 1e-3
    gain = 10 ** (-pathloss_db(d_m, ch.carrier_ghz) / 10)
    snr = ch.antenna_gain * p_w * gain / ch.noise_w
    return ch.bandwidth_hz * np.log2(1.0 + snr)


def ue_rates(p_ue_dbm, d_m, ch: ChannelParams):
    """(uplink, downlink) full-band rates in bit/s for each UE.

    Uplink uses the UE transmit power, downlink the BS power (eq (6)).
    """
    r_u = shannon_rate(p_ue_dbm, d_m, ch)
    r_d = shannon_rate(ch.p_bs_dbm, d_m, ch)
    return r_u, r_d
