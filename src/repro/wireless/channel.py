"""Wireless channel model of the paper (SII-B, Table I).

TDMA cellular system: all UEs share bandwidth ``B``; time is divided into
frames of length ``T`` subdivided into per-UE slots ``tau_i``.  Rates follow
Shannon's theorem under AWGN (eqs (5)-(6)); path loss is the 3GPP-style model
``h(d, f) = 28.0 + 22 log10(d) + 20 log10(f)`` dB used in SIV-A.
"""
from __future__ import annotations

import dataclasses
import numpy as np


@dataclasses.dataclass(frozen=True)
class ChannelParams:
    """System parameters; defaults are the paper's Table I."""

    bandwidth_hz: float = 100e6          # B
    carrier_ghz: float = 3.5             # f (GHz, enters path loss)
    frame_s: float = 10e-3               # T
    p_bs_dbm: float = 46.0               # downlink transmit power
    antenna_gain: float = 10.0           # G (linear)
    noise_psd_dbm_hz: float = -174.0     # N0

    @property
    def noise_w(self) -> float:
        return 10 ** (self.noise_psd_dbm_hz / 10) * 1e-3 * self.bandwidth_hz


def pathloss_db(d_m, f_ghz: float):
    """3GPP UMa-style LOS path loss (paper SIV-A), d in meters, f in GHz."""
    d = np.asarray(d_m, dtype=np.float64)
    return 28.0 + 22.0 * np.log10(d) + 20.0 * np.log10(f_ghz)


def shannon_rate(p_tx_dbm, d_m, ch: ChannelParams):
    """Achievable rate in bit/s over the full band (eqs (5)/(6))."""
    p_w = 10 ** (np.asarray(p_tx_dbm, dtype=np.float64) / 10) * 1e-3
    gain = 10 ** (-pathloss_db(d_m, ch.carrier_ghz) / 10)
    snr = ch.antenna_gain * p_w * gain / ch.noise_w
    return ch.bandwidth_hz * np.log2(1.0 + snr)


def ue_rates(p_ue_dbm, d_m, ch: ChannelParams):
    """(uplink, downlink) full-band rates in bit/s for each UE.

    Uplink uses the UE transmit power, downlink the BS power (eq (6)).
    """
    r_u = shannon_rate(p_ue_dbm, d_m, ch)
    r_d = shannon_rate(ch.p_bs_dbm, d_m, ch)
    return r_u, r_d


# ---------------------------------------------------------------------------
# Scripted link drift (the AC²P²SL premise: the channel is not constant).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BandwidthTrace:
    """Piecewise-constant link bandwidth as a function of training step.

    The deterministic drift driver for the online re-planner's tests and
    the ``replan_drift`` benchmark: ``at(step)`` returns the wire
    bandwidth in BYTES/s in force at that step.  ``steps`` are ascending;
    ``bw_Bps[i]`` applies from ``steps[i]`` (inclusive) until the next
    entry.

    ``steps[0] > 0`` is allowed and has EXPLICIT semantics: ``bw_Bps[0]``
    extends backward over the pre-history ``step < steps[0]`` as well
    (``at`` never has an undefined region).  Consequently ``steps[0]``
    itself is never a point where ``at`` changes value — which is why
    ``change_points`` is defined as "steps where ``at(s) != at(s - 1)``"
    rather than by position in ``steps``.  Construct with
    ``steps[0] == 0`` when you want the trace to spell its initial state
    explicitly; both forms are equivalent and covered by tests.
    """

    steps: tuple
    bw_Bps: tuple

    def __post_init__(self):
        if len(self.steps) != len(self.bw_Bps) or not self.steps:
            raise ValueError(
                f"BandwidthTrace needs matching non-empty steps/bw_Bps, "
                f"got {len(self.steps)} steps / {len(self.bw_Bps)} rates")
        if list(self.steps) != sorted(set(int(s) for s in self.steps)):
            raise ValueError(
                f"BandwidthTrace steps must be strictly ascending, got "
                f"{self.steps}")
        if any(not bw > 0 for bw in self.bw_Bps):
            raise ValueError(f"bandwidths must be > 0, got {self.bw_Bps}")
        object.__setattr__(self, "steps", tuple(int(s) for s in self.steps))
        object.__setattr__(self, "bw_Bps",
                           tuple(float(b) for b in self.bw_Bps))

    def at(self, step: int) -> float:
        """Bandwidth (B/s) in force at ``step``."""
        bw = self.bw_Bps[0]
        for s, b in zip(self.steps, self.bw_Bps):
            if step >= s:
                bw = b
        return bw

    @property
    def change_points(self) -> tuple:
        """Steps at which ``at`` actually changes value.

        Derived from the ``at`` semantics, not from position: ``prev``
        starts at ``bw_Bps[0]`` because that rate is already in force
        before ``steps[0]`` (pre-history extension, class docstring), so
        the first entry only appears here when a LATER entry moves the
        value — the old positional ``out[1:]`` slice encoded the same
        outcome by accident and broke the moment the initial-state and
        first-change entries were conflated.  Duplicate consecutive
        rates never produce a change point.
        """
        out, prev = [], self.bw_Bps[0]
        for s, b in zip(self.steps, self.bw_Bps):
            if b != prev:
                out.append(s)
            prev = b
        return tuple(out)


def bandwidth_step_trace(before_Bps: float, after_Bps: float,
                         at_step: int) -> BandwidthTrace:
    """The canonical drift scenario: one bandwidth step at ``at_step``."""
    return BandwidthTrace(steps=(0, int(at_step)),
                          bw_Bps=(before_Bps, after_Bps))


# ---------------------------------------------------------------------------
# Artificial-delay shaping (loopback socket -> emulated wireless link).
# ---------------------------------------------------------------------------


class LinkShaper:
    """Serialization-delay model for the streaming runtime's loopback
    transport: ``delay_s(nbytes) = latency_s + nbytes / bw_Bps``.

    ``runtime/`` sleeps this long before writing each frame, so a
    loopback socket behaves like a link sustaining ``bw_Bps`` — the
    dispatcher's `LinkEstimator` then *measures* the emulated channel
    from frame timestamps instead of reading a scripted
    ``BandwidthTrace``.  Deliberately mutable (``set_rate``): tests and
    fleet-churn scenarios re-tune the rate mid-run and assert the
    re-planner notices from measurements alone.  numpy/stdlib only — the
    sleep itself belongs to the caller's event loop.
    """

    def __init__(self, bw_Bps: float, latency_s: float = 0.0):
        self.set_rate(bw_Bps, latency_s)

    def set_rate(self, bw_Bps: float, latency_s: float | None = None):
        if not bw_Bps > 0:
            raise ValueError(f"LinkShaper bw_Bps={bw_Bps} must be > 0")
        if latency_s is not None and latency_s < 0:
            raise ValueError(
                f"LinkShaper latency_s={latency_s} must be >= 0")
        self.bw_Bps = float(bw_Bps)
        if latency_s is not None:
            self.latency_s = float(latency_s)

    def delay_s(self, nbytes: int) -> float:
        return self.latency_s + max(0, int(nbytes)) / self.bw_Bps

    @classmethod
    def from_channel(cls, ch: ChannelParams, p_tx_dbm: float, d_m: float,
                     efficiency: float = 1.0,
                     latency_s: float = 0.0) -> "LinkShaper":
        """Shape the loopback to the Shannon rate (eqs (5)-(6)) of a
        physical-layer configuration; ``efficiency`` derates the bound
        to a deliverable goodput, as in ``shannon_trace``."""
        rate_Bps = float(shannon_rate(p_tx_dbm, d_m, ch)) / 8.0 * efficiency
        return cls(rate_Bps, latency_s)

    def __repr__(self):
        return (f"LinkShaper(bw_Bps={self.bw_Bps:g}, "
                f"latency_s={self.latency_s:g})")


def shannon_trace(ch_by_step, p_tx_dbm: float, d_m: float,
                  efficiency: float = 1.0) -> BandwidthTrace:
    """Channel-model-driven trace: ``{step: ChannelParams}`` -> the wire
    bandwidth (BYTES/s) the Shannon rate (eqs (5)-(6)) sustains at each
    change point.  This is how a physical-layer event (bandwidth
    reallocation, a UE moving, interference raising the noise floor)
    becomes the piecewise link model the re-planner tracks; ``efficiency``
    derates the information-theoretic bound to a deliverable goodput.
    """
    steps = sorted(int(s) for s in ch_by_step)
    rates = [float(shannon_rate(p_tx_dbm, d_m, ch_by_step[s])) / 8.0
             * efficiency for s in steps]
    return BandwidthTrace(steps=tuple(steps), bw_Bps=tuple(rates))
