"""Wireless channel model of the paper (SII-B, Table I).

TDMA cellular system: all UEs share bandwidth ``B``; time is divided into
frames of length ``T`` subdivided into per-UE slots ``tau_i``.  Rates follow
Shannon's theorem under AWGN (eqs (5)-(6)); path loss is the 3GPP-style model
``h(d, f) = 28.0 + 22 log10(d) + 20 log10(f)`` dB used in SIV-A.
"""
from __future__ import annotations

import dataclasses
import numpy as np


@dataclasses.dataclass(frozen=True)
class ChannelParams:
    """System parameters; defaults are the paper's Table I."""

    bandwidth_hz: float = 100e6          # B
    carrier_ghz: float = 3.5             # f (GHz, enters path loss)
    frame_s: float = 10e-3               # T
    p_bs_dbm: float = 46.0               # downlink transmit power
    antenna_gain: float = 10.0           # G (linear)
    noise_psd_dbm_hz: float = -174.0     # N0

    @property
    def noise_w(self) -> float:
        return 10 ** (self.noise_psd_dbm_hz / 10) * 1e-3 * self.bandwidth_hz


def pathloss_db(d_m, f_ghz: float):
    """3GPP UMa-style LOS path loss (paper SIV-A), d in meters, f in GHz."""
    d = np.asarray(d_m, dtype=np.float64)
    return 28.0 + 22.0 * np.log10(d) + 20.0 * np.log10(f_ghz)


def shannon_rate(p_tx_dbm, d_m, ch: ChannelParams):
    """Achievable rate in bit/s over the full band (eqs (5)/(6))."""
    p_w = 10 ** (np.asarray(p_tx_dbm, dtype=np.float64) / 10) * 1e-3
    gain = 10 ** (-pathloss_db(d_m, ch.carrier_ghz) / 10)
    snr = ch.antenna_gain * p_w * gain / ch.noise_w
    return ch.bandwidth_hz * np.log2(1.0 + snr)


def ue_rates(p_ue_dbm, d_m, ch: ChannelParams):
    """(uplink, downlink) full-band rates in bit/s for each UE.

    Uplink uses the UE transmit power, downlink the BS power (eq (6)).
    """
    r_u = shannon_rate(p_ue_dbm, d_m, ch)
    r_d = shannon_rate(ch.p_bs_dbm, d_m, ch)
    return r_u, r_d


# ---------------------------------------------------------------------------
# Scripted link drift (the AC²P²SL premise: the channel is not constant).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BandwidthTrace:
    """Piecewise-constant link bandwidth as a function of training step.

    The deterministic drift driver for the online re-planner's tests and
    the ``replan_drift`` benchmark: ``at(step)`` returns the wire
    bandwidth in BYTES/s in force at that step.  ``steps`` are ascending
    change points; ``bw_Bps[i]`` applies from ``steps[i]`` (inclusive)
    until the next change point, ``bw_Bps[0]`` before ``steps[0]`` too
    when ``steps[0] > 0`` is not given — construct with ``steps[0] == 0``
    to be explicit.
    """

    steps: tuple
    bw_Bps: tuple

    def __post_init__(self):
        if len(self.steps) != len(self.bw_Bps) or not self.steps:
            raise ValueError(
                f"BandwidthTrace needs matching non-empty steps/bw_Bps, "
                f"got {len(self.steps)} steps / {len(self.bw_Bps)} rates")
        if list(self.steps) != sorted(set(int(s) for s in self.steps)):
            raise ValueError(
                f"BandwidthTrace steps must be strictly ascending, got "
                f"{self.steps}")
        if any(not bw > 0 for bw in self.bw_Bps):
            raise ValueError(f"bandwidths must be > 0, got {self.bw_Bps}")
        object.__setattr__(self, "steps", tuple(int(s) for s in self.steps))
        object.__setattr__(self, "bw_Bps",
                           tuple(float(b) for b in self.bw_Bps))

    def at(self, step: int) -> float:
        """Bandwidth (B/s) in force at ``step``."""
        bw = self.bw_Bps[0]
        for s, b in zip(self.steps, self.bw_Bps):
            if step >= s:
                bw = b
        return bw

    @property
    def change_points(self) -> tuple:
        """Steps at which the bandwidth actually changes value."""
        out, prev = [], None
        for s, b in zip(self.steps, self.bw_Bps):
            if prev is None or b != prev:
                out.append(s)
            prev = b
        return tuple(out[1:])   # the t=first entry is the initial state


def bandwidth_step_trace(before_Bps: float, after_Bps: float,
                         at_step: int) -> BandwidthTrace:
    """The canonical drift scenario: one bandwidth step at ``at_step``."""
    return BandwidthTrace(steps=(0, int(at_step)),
                          bw_Bps=(before_Bps, after_Bps))


def shannon_trace(ch_by_step, p_tx_dbm: float, d_m: float,
                  efficiency: float = 1.0) -> BandwidthTrace:
    """Channel-model-driven trace: ``{step: ChannelParams}`` -> the wire
    bandwidth (BYTES/s) the Shannon rate (eqs (5)-(6)) sustains at each
    change point.  This is how a physical-layer event (bandwidth
    reallocation, a UE moving, interference raising the noise floor)
    becomes the piecewise link model the re-planner tracks; ``efficiency``
    derates the information-theoretic bound to a deliverable goodput.
    """
    steps = sorted(int(s) for s in ch_by_step)
    rates = [float(shannon_rate(p_tx_dbm, d_m, ch_by_step[s])) / 8.0
             * efficiency for s in steps]
    return BandwidthTrace(steps=tuple(steps), bw_Bps=tuple(rates))
