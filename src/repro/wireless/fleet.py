"""Heterogeneous UE fleet sampling (paper SIV-A, Table I)."""
from __future__ import annotations

import dataclasses
import numpy as np

from repro.wireless.channel import ChannelParams, ue_rates

# Table I compute constants.
K_UE = 16.0   # FLOPs / cycle, UE
K_BS = 32.0   # FLOPs / cycle, BS
F_BS = 80e9   # BS clock, cycles/s
BS_FLOPS = K_UE * 0 + K_BS * F_BS  # = 2.56 TFLOP/s


@dataclasses.dataclass(frozen=True)
class UE:
    """One user equipment with its compute + radio capability."""

    clock_hz: float           # F_i
    p_tx_dbm: float           # p_i
    distance_m: float         # d_i
    storage_flops: float      # c_i: compute-load proxy for the memory bound (C2)

    @property
    def flops(self) -> float:
        """f_i = K_U * F_i (eq (2))."""
        return K_UE * self.clock_hz


@dataclasses.dataclass(frozen=True)
class Fleet:
    ues: tuple
    channel: ChannelParams

    @property
    def n(self) -> int:
        return len(self.ues)

    @property
    def ue_flops(self) -> np.ndarray:
        return np.array([u.flops for u in self.ues])

    @property
    def bs_flops(self) -> float:
        return BS_FLOPS

    def rates(self):
        """Full-band (uplink, downlink) rates per UE, bit/s."""
        p = np.array([u.p_tx_dbm for u in self.ues])
        d = np.array([u.distance_m for u in self.ues])
        return ue_rates(p, d, self.channel)

    @property
    def storage(self) -> np.ndarray:
        return np.array([u.storage_flops for u in self.ues])


def sample_fleet(n: int, seed: int = 0, channel: ChannelParams | None = None,
                 d_range=(100.0, 500.0), f_range=(1e9, 2e9),
                 p_range=(13.0, 23.0), c_range=(1e9, 2e9)) -> Fleet:
    """Sample ``n`` heterogeneous UEs per Table I.

    Note: the paper's text says clock in [0.5, 1.5] Gcycle/s while Table I
    says [1, 2]; we follow Table I (the table supersedes prose).
    """
    rng = np.random.default_rng(seed)
    ch = channel or ChannelParams()
    ues = tuple(
        UE(
            clock_hz=float(rng.uniform(*f_range)),
            p_tx_dbm=float(rng.uniform(*p_range)),
            distance_m=float(rng.uniform(*d_range)),
            storage_flops=float(rng.uniform(*c_range)),
        )
        for _ in range(n)
    )
    return Fleet(ues=ues, channel=ch)
