from repro.wireless.channel import (BandwidthTrace, ChannelParams, LinkShaper,
                                    bandwidth_step_trace, pathloss_db,
                                    shannon_rate, shannon_trace, ue_rates)
from repro.wireless.fleet import UE, Fleet, sample_fleet, BS_FLOPS, K_UE, K_BS, F_BS
