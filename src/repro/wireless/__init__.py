from repro.wireless.channel import ChannelParams, pathloss_db, shannon_rate, ue_rates
from repro.wireless.fleet import UE, Fleet, sample_fleet, BS_FLOPS, K_UE, K_BS, F_BS
