from repro.sl.split import SplitSpec, resnet_split, lm_split
from repro.sl.c2p2sl import (SLState, init_sl_state, make_c2p2sl_step,
                             shard_batch, batch_wall_time)
from repro.sl.baselines import make_psl_step, make_epsl_step, make_sl_step
