"""The C2P2SL trainer: actual split training with micro-batch pipelining.

This is the *faithful* runtime of the paper (SII-C): per micro-batch m and
UE i,
    UE FP:  a_{i,m} = f_ue(theta_ue, x_{i,m})           (+ vjp closure)
    UT:     a_{i,m}, y_{i,m} -> BS                      (timed by schedule)
    BS FP+BP (1F1B): loss over aggregated micro-batch; grads wrt
            (theta_bs, a_{.,m})
    DT:     da_{i,m} -> UE i
    UE BP:  pullback_{i,m}(da_{i,m}) -> dtheta_ue
Gradients are accumulated over the k micro-batches and applied once per
batch — mathematically identical to full-batch PSL (asserted in tests).

Computation is real JAX; *time* is the event-driven schedule simulator
(repro/core/schedule.py), since wall-clock on one CPU cannot reproduce a
radio network.  The trainer returns both.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.schedule import Plan, simulate_c2p2sl, task_times
from repro.sl.split import SplitSpec
from repro.training.optim import Optimizer


@dataclasses.dataclass
class SLState:
    ue_params: Any
    bs_params: Any
    opt_state_ue: Any
    opt_state_bs: Any
    step: jnp.ndarray


def init_sl_state(spec: SplitSpec, full_params, opt: Optimizer) -> SLState:
    ue, bs = spec.split_params(full_params)
    return SLState(ue_params=ue, bs_params=bs,
                   opt_state_ue=opt.init(ue), opt_state_bs=opt.init(bs),
                   step=jnp.zeros((), jnp.int32))


def make_c2p2sl_step(spec: SplitSpec, opt: Optimizer, k: int,
                     epsl_aggregate: bool = False):
    """Build one jitted C2P2SL batch step.

    inputs per call: state tree + per-UE micro-batch sequences (the
    ``shard_batch`` layout — possibly ragged, possibly empty):
      xs: [n_ue][k][b_{i,m}, ...]
      ys: [n_ue][k][b_{i,m}]
    ``epsl_aggregate=True`` switches on the EPSL baseline behaviour:
    activation gradients are mean-aggregated over the micro-batch dimension
    before the downlink (volume / n_samples), an approximation.
    """

    def batch_grads(ue_params, bs_params, xs, ys):
        n_ue = len(xs)
        ue_grad_acc = jax.tree.map(jnp.zeros_like, ue_params)
        bs_grad_acc = jax.tree.map(jnp.zeros_like, bs_params)
        loss_acc = jnp.float32(0.0)
        met_acc = None
        # micro-batch sizes may be ragged (shard_batch distributes the
        # remainder of b_i over k); weights come from actual sample counts
        total = float(sum(int(xs[i][m].shape[0])
                          for i in range(n_ue) for m in range(k)))

        for m in range(k):                       # micro-batch pipeline order
            # --- UE FP (all UEs, per paper in parallel) + vjp closures ---
            # zero-sized micro-batches (b_i < k or zero-batch UEs) are
            # skipped statically: they carry no samples and would feed
            # empty batches through batch-statistics layers.
            live = [i for i in range(n_ue) if xs[i][m].shape[0] > 0]
            if not live:
                continue
            acts, pullbacks = [], []
            for i in live:
                a, vjp = jax.vjp(lambda p, x=xs[i][m]: spec.ue_fwd(p, x),
                                 ue_params)
                acts.append(a)
                pullbacks.append(vjp)
            # --- UT: aggregate at BS ---
            agg = jnp.concatenate(acts, axis=0)
            labels = jnp.concatenate([ys[i][m] for i in live], axis=0)
            w_m = agg.shape[0] / total           # sample-weighted average

            # --- BS FP + BP (1F1B) ---
            def bs_fn(bp, a):
                loss, mets = spec.bs_loss(bp, a, labels)
                return loss, mets

            loss, bs_vjp, mets = jax.vjp(bs_fn, bs_params, agg, has_aux=True)
            dbs, dagg = bs_vjp(jnp.float32(1.0))
            bs_grad_acc = jax.tree.map(lambda g, d: g + d * w_m,
                                       bs_grad_acc, dbs)
            loss_acc = loss_acc + loss * w_m
            # metrics sample-weighted like the loss (a straight /k average
            # over-weights small micro-batches under ragged splits)
            mets_w = jax.tree.map(lambda v: v * w_m, mets)
            met_acc = mets_w if met_acc is None else jax.tree.map(
                jnp.add, met_acc, mets_w)

            # --- DT + UE BP ---
            offs = 0
            for j, i in enumerate(live):
                bi = acts[j].shape[0]
                da = dagg[offs:offs + bi]
                offs += bi
                if epsl_aggregate:
                    da = jnp.broadcast_to(da.mean(axis=0, keepdims=True),
                                          da.shape)
                (dui,) = pullbacks[j](da)
                ue_grad_acc = jax.tree.map(lambda g, d: g + d * w_m,
                                           ue_grad_acc, dui)

        return loss_acc, ue_grad_acc, bs_grad_acc, met_acc

    def step(state_tree, xs, ys):
        loss, dg_ue, dg_bs, mets = batch_grads(
            state_tree["ue_params"], state_tree["bs_params"], xs, ys)
        new_ue, opt_ue = opt.update(dg_ue, state_tree["opt_state_ue"],
                                    state_tree["ue_params"],
                                    state_tree["step"])
        new_bs, opt_bs = opt.update(dg_bs, state_tree["opt_state_bs"],
                                    state_tree["bs_params"],
                                    state_tree["step"])
        mets = dict(mets)
        mets["loss"] = loss
        return {"ue_params": new_ue, "bs_params": new_bs,
                "opt_state_ue": opt_ue, "opt_state_bs": opt_bs,
                "step": state_tree["step"] + 1}, mets

    return step


def shard_batch(batch_x, batch_y, b: np.ndarray, k: int):
    """Split a host batch into per-UE sequences of k micro-batches.

    Every sample of the host batch is used exactly once and the returned
    lists have one entry per UE in ``b``'s order (zero-batch UEs get k
    empty micro-batches), so UE indices stay aligned with ``Fleet``
    ordering.  Per-UE sizes b_i need not be multiples of k: the remainder
    ``b_i % k`` is spread one sample each over the first micro-batches
    (ragged micro-batches), instead of being silently dropped.  If
    ``sum(b) != len(batch_x)`` (AO rounding), the difference is absorbed
    by the largest allocations, never driving any b_i below zero.

    Returns ``(xs, ys)`` with ``xs[i]`` a list of k arrays shaped
    ``[b_{i,m}, ...]`` where ``sum_m b_{i,m} == b_i``.
    """
    assert k >= 1, f"micro-batch count k={k} must be >= 1"
    b = np.asarray(b, dtype=int).copy()
    assert (b >= 0).all(), f"negative UE allocation in {b}"
    n = batch_x.shape[0]
    diff = n - int(b.sum())
    while diff != 0:                       # absorb AO rounding slack onto
        i = int(np.argmax(b))              # the largest allocation (keeps
        step = diff if diff > 0 else max(-int(b[i]), diff)  # zero UEs zero)
        b[i] += step
        diff -= step
    xs, ys, off = [], [], 0
    for bi in b:
        base, rem = divmod(int(bi), k)
        sizes = [base + 1] * rem + [base] * (k - rem)
        xi, yi = [], []
        for s in sizes:
            xi.append(batch_x[off:off + s])
            yi.append(batch_y[off:off + s])
            off += s
        xs.append(xi)
        ys.append(yi)
    return xs, ys


def batch_wall_time(profile, fleet, plan: Plan) -> float:
    """Simulated wall time of one C2P2SL batch under the plan.

    Honors ``plan.v`` (interleaved virtual stages, AO-selected when
    ``algorithm1(..., v_cap>1)``): compute is v-independent — gradient
    accumulation over k micro-batches is identical math at any chunking
    — so only the simulated schedule time changes.
    """
    t = task_times(profile, fleet, plan)
    ms, _ = simulate_c2p2sl(t, plan.k, virtual_stages=plan.v)
    return ms
