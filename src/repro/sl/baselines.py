"""Baseline training schemes: SL [4], PSL [7], EPSL [8].

Computation semantics:
  * SL   — strictly sequential: UE i trains (with the BS) on its own batch,
           parameters update after EVERY UE's turn (n updates per round).
  * PSL  — all UEs in parallel on the shared BS model; one update per batch
           (identical update to C2P2SL with k=1 — C2P2SL's equivalence
           baseline).
  * EPSL — PSL with last-layer gradient aggregation: the downlink activation
           gradient is replaced by its per-UE batch mean (volume /b_i),
           which is the paper's accuracy-for-time tradeoff.

Timing comes from repro/core/schedule.py simulators.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sl.c2p2sl import make_c2p2sl_step
from repro.sl.split import SplitSpec
from repro.training.optim import Optimizer


def make_psl_step(spec: SplitSpec, opt: Optimizer):
    """PSL == C2P2SL with k=1 (no pipelining)."""
    return make_c2p2sl_step(spec, opt, k=1)


def make_epsl_step(spec: SplitSpec, opt: Optimizer, k: int = 1):
    return make_c2p2sl_step(spec, opt, k=k, epsl_aggregate=True)


def make_sl_step(spec: SplitSpec, opt: Optimizer):
    """Sequential SL: per-UE update, one UE after another."""

    def step(state_tree, xs, ys):
        ue_params = state_tree["ue_params"]
        bs_params = state_tree["bs_params"]
        opt_ue = state_tree["opt_state_ue"]
        opt_bs = state_tree["opt_state_bs"]
        stp = state_tree["step"]
        loss_last = jnp.float32(0.0)
        mets_last = None
        for i in range(len(xs)):
            # shard_batch yields k (possibly ragged/empty) micro-batches
            # per UE; sequential SL trains on the UE's whole allocation
            x = jnp.concatenate(list(xs[i]), axis=0)
            y = jnp.concatenate(list(ys[i]), axis=0)
            if x.shape[0] == 0:
                continue                 # zero-batch UE: no local update

            def loss_fn(both):
                ue, bs = both
                acts = spec.ue_fwd(ue, x)
                return spec.bs_loss(bs, acts, y)

            (loss, mets), (due, dbs) = jax.value_and_grad(
                loss_fn, has_aux=True)((ue_params, bs_params))
            ue_params, opt_ue = opt.update(due, opt_ue, ue_params, stp)
            bs_params, opt_bs = opt.update(dbs, opt_bs, bs_params, stp)
            loss_last = loss
            mets_last = mets
        out = dict(mets_last)
        out["loss"] = loss_last
        return {"ue_params": ue_params, "bs_params": bs_params,
                "opt_state_ue": opt_ue, "opt_state_bs": opt_bs,
                "step": stp + 1}, out

    return step
