"""Cut-layer model splitting: UE-side / BS-side submodels.

A ``SplitSpec`` turns one model into the two stage functions of split
learning.  ResNet-18 cuts at the Table II unit boundaries; LMs cut at a
transformer block index (embedding lives UE-side, head BS-side) — the same
abstraction the TPU pipeline (repro/parallel/pipeline.py) uses.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import resnet


@dataclasses.dataclass(frozen=True)
class SplitSpec:
    """ue_fwd(ue_params, batch_inputs) -> activations
    bs_loss(bs_params, activations, labels) -> (loss, metrics)"""
    ue_fwd: Callable
    bs_loss: Callable
    split_params: Callable      # full params -> (ue_params, bs_params)
    merge_params: Callable      # (ue, bs) -> full


def resnet_split(l: int) -> SplitSpec:
    """Cut ResNet-18 after Table II unit ``l`` (1..5)."""
    assert 1 <= l <= 5

    def ue_fwd(ue_params, images):
        return resnet.forward_cut(ue_params, images, 0, l)

    def bs_loss(bs_params, acts, labels):
        logits = resnet.forward_cut(bs_params, acts, l, 6)
        ll = jax.nn.log_softmax(logits.astype(jnp.float32))
        loss = -jnp.take_along_axis(ll, labels[:, None], axis=1).mean()
        acc = (logits.argmax(-1) == labels).mean()
        return loss, {"acc": acc}

    keys_ue, keys_bs = _resnet_key_split(l)

    def split_params(params):
        return ({k: params[k] for k in keys_ue if k in params},
                {k: params[k] for k in keys_bs if k in params})

    def merge_params(ue, bs):
        return {**ue, **bs}

    return SplitSpec(ue_fwd, bs_loss, split_params, merge_params)


def _resnet_key_split(l: int):
    all_keys = (["conv1", "g1w", "g1b"], ["stage0"], ["stage1"], ["stage2"],
                ["stage3"], ["fc_w", "fc_b"])
    ue, bs = [], []
    for u, ks in enumerate(all_keys):
        (ue if u < l else bs).extend(ks)
    return ue, bs


def lm_split(model, l: int) -> SplitSpec:
    """Cut an LM after block ``l``: UE = embed + blocks[:l]; BS = rest+head.

    Requires a homogeneous (scan-stacked) architecture.
    """
    cfg = model.cfg
    assert cfg.homogeneous, "lm_split requires a homogeneous layer stack"
    assert 1 <= l < cfg.num_layers

    def split_params(params):
        blocks = params["blocks"]
        take = lambda tree, sl: jax.tree.map(lambda a: a[sl], tree)
        ue = {"embed": params["embed"], "blocks": take(blocks, slice(0, l))}
        bs = {"blocks": take(blocks, slice(l, cfg.num_layers)),
              "final_norm": params["final_norm"]}
        if "head" in params:
            bs["head"] = params["head"]
        return ue, bs

    def merge_params(ue, bs):
        blocks = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=0),
                              ue["blocks"], bs["blocks"])
        out = {"embed": ue["embed"], "blocks": blocks,
               "final_norm": bs["final_norm"]}
        if "head" in bs:
            out["head"] = bs["head"]
        return out

    def ue_fwd(ue_params, tokens):
        dt = jnp.dtype(cfg.dtype)
        x = model._embed({"embed": ue_params["embed"]}, tokens, dt)
        positions = jnp.arange(x.shape[1])
        from repro.models.blocks import apply_block

        def body(carry, layer_params):
            y, _ = apply_block(layer_params, carry, cfg, cfg.layer_kinds[0],
                               positions=positions)
            return y, None

        x, _ = jax.lax.scan(body, x, ue_params["blocks"])
        return x

    def bs_loss(bs_params, acts, labels):
        from repro.models.blocks import apply_block
        from repro.models.common import apply_norm
        positions = jnp.arange(acts.shape[1])

        def body(carry, layer_params):
            y, _ = apply_block(layer_params, carry, cfg, cfg.layer_kinds[0],
                               positions=positions)
            return y, None

        x, _ = jax.lax.scan(body, acts, bs_params["blocks"])
        x = apply_norm(x, bs_params["final_norm"], cfg.norm)
        if cfg.tie_embeddings:
            raise ValueError("tied embeddings cannot be split at the head")
        loss = model.xent(bs_params, x, labels)
        return loss, {"xent": loss}

    return SplitSpec(ue_fwd, bs_loss, split_params, merge_params)
