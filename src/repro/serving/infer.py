"""Split-inference serving: UE runs the sub-cut layers, BS the rest.

The training runtime (``repro/runtime/``) already ships coded cut
ACTIVATIONS up and gradients down over a real loopback socket; this
module carries the same wire to the serving path — millions of users is
inference traffic, and EPSL-style parallel SL serves the same split
model for both learning and inference:

* ``SplitDecode`` cuts a homogeneous decoder-only LM after block ``l``
  into a UE half (embed + blocks[:l], with its OWN slice of the decode
  cache) and a BS half (blocks[l:] + final norm + head, with the other
  cache slice) — composing the two halves is the monolithic
  ``prefill_with_cache`` / ``decode_step`` exactly (same scan, split in
  two), which the tests pin.
* ``run_split_infer`` drives a real asyncio loopback socket: the UE
  prefills its half, ships the coded cut activation of the WHOLE prompt
  as one INFER frame (``parallel/wire.py`` dense grammar — none / int8
  / fp8; activations are forward-only, so no top-k and no error
  feedback), then per decode step ships one coded ``[B, 1, d]``
  activation and receives the sampled token back.  The BS replies with
  the token as an aux (un-billed) section, samples greedily, and audits
  every uplink's measured payload bytes against
  ``protocol.billed_hop_bytes`` — the planner's
  ``autotune.wire_bytes_per_element`` billing, held to 1% on the real
  socket.
"""
from __future__ import annotations

import asyncio

import numpy as np


def _require_dense(wire_dtype: str) -> str:
    """INFER hops carry forward activations only: dense codecs."""
    from repro.parallel.wire import parse_wire_dtype
    base, frac = parse_wire_dtype(wire_dtype)
    if frac is not None:
        raise ValueError(
            f"wire_dtype {wire_dtype!r}: the INFER hop is forward-only "
            "(no gradient, no error feedback) — top-k sparsification "
            "would silently corrupt activations; use 'none', 'int8' or "
            "'fp8'")
    return base


class SplitDecode:
    """Cut a homogeneous decoder-only LM after block ``l`` for serving.

    UE = embed + blocks[:l]; BS = blocks[l:] + final_norm + head.  Both
    halves hold THEIR OWN layers' slice of the decode cache; composing
    ``ue_*`` then ``bs_*`` reproduces the monolithic serving step (same
    per-layer ops in the same order — the split is only in who holds
    which scan segment).
    """

    def __init__(self, model, l: int):
        import jax

        cfg = model.cfg
        if not cfg.homogeneous:
            raise ValueError("SplitDecode requires a homogeneous stack")
        if cfg.tie_embeddings:
            raise ValueError("tied embeddings cannot be split at the head")
        if getattr(cfg, "enc_layers", 0) or cfg.family in ("audio", "vlm"):
            raise ValueError(
                f"SplitDecode serves decoder-only token LMs, not "
                f"{cfg.family}")
        if not 1 <= l < cfg.num_layers:
            raise ValueError(
                f"cut l={l} must be in [1, {cfg.num_layers})")
        self.model = model
        self.cfg = cfg
        self.l = int(l)
        self.kind = cfg.layer_kinds[0]
        self._jax = jax

    def split_params(self, params):
        jax = self._jax
        l, cfg = self.l, self.cfg
        take = lambda tree, sl: jax.tree.map(lambda a: a[sl], tree)
        ue = {"embed": params["embed"],
              "blocks": take(params["blocks"], slice(0, l))}
        bs = {"blocks": take(params["blocks"], slice(l, cfg.num_layers)),
              "final_norm": params["final_norm"],
              "head": params["head"]}
        return ue, bs

    # -- cache ---------------------------------------------------------------

    def _half_cache(self, n_layers, batch, cache_len, dtype):
        import jax.numpy as jnp

        from repro.models.blocks import init_block_state
        one = init_block_state(self.cfg, self.kind, batch, cache_len,
                               dtype)
        return self._jax.tree.map(
            lambda a: jnp.broadcast_to(a[None],
                                       (n_layers,) + a.shape), one)

    # -- UE half -------------------------------------------------------------

    def ue_prefill(self, ue_params, tokens, *, cache_len,
                   cache_dtype=None):
        """tokens [B, S] -> (cut activations [B, S, d], ue cache)."""
        import jax
        import jax.numpy as jnp

        from repro.models.blocks import apply_block_prefill
        cfg, kind = self.cfg, self.kind
        cache_dtype = cache_dtype or jnp.float32
        dt = jnp.dtype(cfg.dtype)
        x = self.model._embed({"embed": ue_params["embed"]}, tokens, dt)
        b, s = x.shape[0], x.shape[1]
        positions = jnp.arange(s)
        template = jax.eval_shape(
            lambda: self._half_cache(self.l, b, cache_len, cache_dtype))

        def body(carry, layer_params):
            y, _aux, st = apply_block_prefill(
                layer_params, carry, cfg, kind, positions=positions,
                cache_len=cache_len, use_rope=(kind != "rwkv"))
            return y, st

        x, states = jax.lax.scan(body, x, ue_params["blocks"])
        cache = jax.tree.map(lambda st, t: st.astype(t.dtype), states,
                             template)
        return x, cache

    def ue_decode(self, ue_params, tok, cache, position):
        """tok [B, 1] -> (cut activation [B, 1, d], new ue cache)."""
        import jax
        import jax.numpy as jnp

        from repro.models.blocks import apply_block_decode
        cfg, kind = self.cfg, self.kind
        dt = jnp.dtype(cfg.dtype)
        x = self.model._embed({"embed": ue_params["embed"]}, tok, dt)

        def body(carry, inp):
            layer_params, st = inp
            y, st_new = apply_block_decode(
                layer_params, carry, st, cfg, kind, position=position,
                use_rope=(kind != "rwkv"))
            return y, st_new

        x, new_cache = jax.lax.scan(body, x, (ue_params["blocks"], cache))
        return x, new_cache

    # -- BS half -------------------------------------------------------------

    def bs_prefill(self, bs_params, acts, *, cache_len,
                   cache_dtype=None):
        """Cut activations [B, S, d] -> (last-position logits [B, V],
        bs cache)."""
        import jax
        import jax.numpy as jnp

        from repro.models.blocks import apply_block_prefill
        from repro.models.common import apply_norm
        from repro.models.lm import _softcap
        cfg, kind = self.cfg, self.kind
        cache_dtype = cache_dtype or jnp.float32
        dt = jnp.dtype(cfg.dtype)
        x = acts.astype(dt)
        b, s = x.shape[0], x.shape[1]
        positions = jnp.arange(s)
        n_bs = cfg.num_layers - self.l
        template = jax.eval_shape(
            lambda: self._half_cache(n_bs, b, cache_len, cache_dtype))

        def body(carry, layer_params):
            y, _aux, st = apply_block_prefill(
                layer_params, carry, cfg, kind, positions=positions,
                cache_len=cache_len, use_rope=(kind != "rwkv"))
            return y, st

        x, states = jax.lax.scan(body, x, bs_params["blocks"])
        cache = jax.tree.map(lambda st, t: st.astype(t.dtype), states,
                             template)
        x = apply_norm(x, bs_params["final_norm"], cfg.norm)
        logits = _softcap(x[:, -1] @ bs_params["head"].astype(dt),
                          cfg.logit_softcap)
        return logits[:, :cfg.vocab].astype(jnp.float32), cache

    def bs_decode(self, bs_params, act, cache, position):
        """Cut activation [B, 1, d] -> (logits [B, V], new bs cache)."""
        import jax
        import jax.numpy as jnp

        from repro.models.blocks import apply_block_decode
        from repro.models.common import apply_norm
        from repro.models.lm import _softcap
        cfg, kind = self.cfg, self.kind
        dt = jnp.dtype(cfg.dtype)
        x = act.astype(dt)

        def body(carry, inp):
            layer_params, st = inp
            y, st_new = apply_block_decode(
                layer_params, carry, st, cfg, kind, position=position,
                use_rope=(kind != "rwkv"))
            return y, st_new

        x, new_cache = jax.lax.scan(body, x, (bs_params["blocks"], cache))
        x = apply_norm(x, bs_params["final_norm"], cfg.norm)
        logits = _softcap(x[:, 0] @ bs_params["head"].astype(dt),
                          cfg.logit_softcap)
        return logits[:, :cfg.vocab].astype(jnp.float32), new_cache


# ---------------------------------------------------------------------------
# Loopback split-inference serving (INFER frames on a real socket).
# ---------------------------------------------------------------------------


class BSInferServer:
    """BS side: receives coded cut activations, runs blocks[l:], samples
    greedily, replies the token; audits wire honesty per uplink frame."""

    def __init__(self, split: SplitDecode, bs_params, *, cache_len: int,
                 wire_dtype: str = "none", shaper=None, qos=None,
                 host: str = "127.0.0.1", port: int = 0):
        import jax
        _require_dense(wire_dtype)
        self.split = split
        self.bs_params = bs_params
        self.cache_len = int(cache_len)
        self.wire_dtype = str(wire_dtype)
        self.shaper = shaper
        self.qos = qos
        self.host, self.port = host, int(port)
        self._server = None
        # (measured payload bytes, billed bytes) per uplink frame
        self.audit: list[tuple] = []
        self._prefill = jax.jit(
            lambda p, a: split.bs_prefill(p, a, cache_len=self.cache_len))
        self._decode = jax.jit(split.bs_decode)

    async def start(self):
        self._server = await asyncio.start_server(
            self._handle, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        return self.host, self.port

    async def close(self):
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()

    async def _reply_tok(self, writer, cid, step, tok):
        from repro.runtime import protocol
        frame = protocol.pack_frame(
            protocol.INFER, cid, step, meta={"phase": "tok"},
            arrays={"tok": np.asarray(tok, np.int32)})
        if self.shaper is not None:
            await asyncio.sleep(self.shaper.delay_s(len(frame)))
        writer.write(frame)
        await writer.drain()

    def _audit_uplink(self, frame) -> None:
        from repro.runtime import protocol
        shape = frame.meta["shape"]
        n_elements = int(np.prod(shape))
        act_bytes = np.dtype(frame.meta["dtype"]).itemsize
        billed = protocol.billed_hop_bytes(
            n_elements, shape[-1], frame.meta["codec"], act_bytes)
        self.audit.append((frame.payload_nbytes, billed))
        if self.qos is not None:
            self.qos.record_arrival(frame.client, frame.wire_nbytes,
                                    frame.payload_nbytes,
                                    frame.aux_nbytes)

    async def _handle(self, reader, writer):
        import jax.numpy as jnp

        from repro.runtime import protocol
        hello = await protocol.read_frame(reader)
        if hello.ftype != protocol.HELLO:
            writer.close()
            raise ValueError(
                f"handshake must be HELLO, got ftype={hello.ftype}")
        if hello.meta.get("wire_dtype", self.wire_dtype) != self.wire_dtype:
            writer.close()
            raise ValueError(
                f"client codec {hello.meta.get('wire_dtype')!r} != server "
                f"{self.wire_dtype!r}")
        cid = hello.client
        cache = None
        position = None
        try:
            while True:
                frame = await protocol.read_frame(reader)
                if frame.ftype == protocol.BYE:
                    break
                if frame.ftype != protocol.INFER:
                    raise ValueError(
                        f"expected INFER frame, got ftype={frame.ftype}")
                self._audit_uplink(frame)
                acts = jnp.asarray(protocol.decode_act_payload(frame))
                if frame.meta["phase"] == "prefill":
                    logits, cache = self._prefill(self.bs_params, acts)
                    position = acts.shape[1]
                else:
                    logits, cache = self._decode(
                        self.bs_params, acts, cache,
                        jnp.asarray(position, jnp.int32))
                    position += 1
                tok = np.asarray(jnp.argmax(logits, axis=-1),
                                 np.int32)[:, None]
                await self._reply_tok(writer, cid, frame.step, tok)
        finally:
            writer.close()


class UEInferClient:
    """UE side: prefills blocks[:l], then streams one coded cut
    activation per decode step and feeds the returned token back."""

    def __init__(self, client_id: int, split: SplitDecode, ue_params, *,
                 cache_len: int, wire_dtype: str = "none", shaper=None):
        import jax
        _require_dense(wire_dtype)
        self.client_id = int(client_id)
        self.split = split
        self.ue_params = ue_params
        self.cache_len = int(cache_len)
        self.wire_dtype = str(wire_dtype)
        self.shaper = shaper
        self.sent_payload_bytes = 0
        self._prefill = jax.jit(
            lambda p, t: split.ue_prefill(p, t, cache_len=self.cache_len))
        self._decode = jax.jit(split.ue_decode)

    async def _send(self, writer, payload: bytes):
        if self.shaper is not None:
            await asyncio.sleep(self.shaper.delay_s(len(payload)))
        writer.write(payload)
        await writer.drain()

    async def run(self, host: str, port: int, prompts, gen: int):
        """prompts [B, L] int32 -> emitted tokens [B, gen] (the BS's
        greedy chain; the prefill seed token is fed, not emitted)."""
        import jax.numpy as jnp

        from repro.runtime import protocol
        prompts = np.asarray(prompts, np.int32)
        reader, writer = await asyncio.open_connection(host, port)
        cid = self.client_id
        try:
            await self._send(writer, protocol.pack_frame(
                protocol.HELLO, cid, 0,
                meta={"wire_dtype": self.wire_dtype, "mode": "infer"}))
            acts, cache = self._prefill(self.ue_params,
                                        jnp.asarray(prompts))
            position = prompts.shape[1]
            arrays, meta = protocol.encode_act_payload(
                np.asarray(acts), self.wire_dtype)
            frame = protocol.pack_frame(
                protocol.INFER, cid, 0, meta=dict(meta, phase="prefill"),
                arrays=arrays)
            self.sent_payload_bytes += sum(
                a.nbytes for k, a in arrays.items()
                if k in protocol.PAYLOAD_SECTIONS)
            await self._send(writer, frame)
            out = []
            for step in range(1, gen + 1):
                reply = await protocol.read_frame(reader)
                if reply.ftype != protocol.INFER \
                        or reply.meta.get("phase") != "tok":
                    raise ValueError(f"expected tok reply, got {reply}")
                tok = reply.arrays["tok"].astype(np.int32)
                if step > 1:
                    out.append(tok[:, 0])
                act, cache = self._decode(
                    self.ue_params, jnp.asarray(tok), cache,
                    jnp.asarray(position, jnp.int32))
                position += 1
                arrays, meta = protocol.encode_act_payload(
                    np.asarray(act), self.wire_dtype)
                self.sent_payload_bytes += sum(
                    a.nbytes for k, a in arrays.items()
                    if k in protocol.PAYLOAD_SECTIONS)
                await self._send(writer, protocol.pack_frame(
                    protocol.INFER, cid, step,
                    meta=dict(meta, phase="decode"), arrays=arrays))
            # one reply is still in flight: the token of the last decode
            reply = await protocol.read_frame(reader)
            out.append(reply.arrays["tok"][:, 0].astype(np.int32))
            await self._send(writer, protocol.pack_frame(
                protocol.BYE, cid, gen))
            return np.stack(out, axis=1)
        finally:
            writer.close()


async def _run_split_infer(model, params, *, cut, prompts, gen,
                           cache_len, wire_dtype="none", shaper=None,
                           qos=None):
    split = SplitDecode(model, cut)
    ue_params, bs_params = split.split_params(params)
    server = BSInferServer(split, bs_params, cache_len=cache_len,
                           wire_dtype=wire_dtype, shaper=shaper, qos=qos)
    host, port = await server.start()
    client = UEInferClient(0, split, ue_params, cache_len=cache_len,
                           wire_dtype=wire_dtype, shaper=shaper)
    try:
        tokens = await client.run(host, port, prompts, gen)
    finally:
        await server.close()
    measured = sum(m for m, _ in server.audit)
    billed = sum(b for _, b in server.audit)
    return {"tokens": tokens,
            "measured_payload_bytes": int(measured),
            "billed_payload_bytes": float(billed),
            "frames": len(server.audit),
            "client_payload_bytes": int(client.sent_payload_bytes)}


def run_split_infer(model, params, *, cut: int, prompts, gen: int,
                    cache_len: int, wire_dtype: str = "none",
                    shaper=None, qos=None) -> dict:
    """Serve ``prompts`` for ``gen`` greedy tokens through the split
    UE->BS loopback; returns tokens + the wire-honesty audit sums."""
    return asyncio.run(_run_split_infer(
        model, params, cut=cut, prompts=prompts, gen=gen,
        cache_len=cache_len, wire_dtype=wire_dtype, shaper=shaper,
        qos=qos))
