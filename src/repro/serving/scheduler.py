"""Request queue + admission policy for the continuous-batching engine.

The scheduler owns everything host-side about WHICH work runs next; the
engine owns HOW it runs (the jitted programs).  Per engine iteration the
scheduler hands back at most one **prefill chunk**: a length-bucketed
group of queued requests (identical prompt length -> one fixed-shape
``prefill_with_cache`` call, no padding, bit-identical to each
request's solo prefill) bounded by

* the number of free slots, and
* ``prefill_chunk_tokens`` — the token budget one chunk may spend, so a
  burst of long prompts cannot stall in-flight decodes for many steps
  (decode steps interleave between chunks).

Admission control is part of the same surface: a request whose
``prompt + max_new_tokens`` cannot fit the arena's ``cache_len`` is
REJECTED (counted by the QoS monitor), and an optional ``max_queue``
bounds the backlog the engine will accept.

Policies: ``fifo`` (arrival order) and ``longest_first`` (longest
declared generation first — LPT scheduling; drains ragged gen mixes
with a shorter idle tail, which is what ``benchmarks/serve_bench.py``
runs).
"""
from __future__ import annotations

import dataclasses

import numpy as np

POLICIES = ("fifo", "longest_first")


@dataclasses.dataclass
class Request:
    """One serving request: a prompt and a declared generation budget."""

    rid: int
    prompt: np.ndarray            # [L] int32 token ids
    max_new_tokens: int

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size < 1:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(
                f"request {self.rid}: max_new_tokens="
                f"{self.max_new_tokens} must be >= 1")

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.size)


class Scheduler:
    def __init__(self, *, cache_len: int, prefill_chunk_tokens: int = 256,
                 policy: str = "fifo", max_queue: int | None = None):
        if policy not in POLICIES:
            raise ValueError(f"policy {policy!r} not in {POLICIES}")
        if prefill_chunk_tokens < 1:
            raise ValueError("prefill_chunk_tokens must be >= 1")
        self.cache_len = int(cache_len)
        self.prefill_chunk_tokens = int(prefill_chunk_tokens)
        self.policy = policy
        self.max_queue = None if max_queue is None else int(max_queue)
        self._queue: list[Request] = []
        self.submitted = 0
        self.rejected = 0

    def __len__(self):
        return len(self._queue)

    @property
    def pending(self) -> tuple:
        return tuple(self._queue)

    def submit(self, req: Request) -> bool:
        """Queue a request; False = rejected (does not fit the arena's
        cache, or the backlog is at ``max_queue``)."""
        self.submitted += 1
        if req.prompt_len + req.max_new_tokens > self.cache_len:
            self.rejected += 1
            return False
        if self.max_queue is not None and len(self._queue) >= self.max_queue:
            self.rejected += 1
            return False
        self._queue.append(req)
        return True

    def _order(self) -> list[Request]:
        if self.policy == "longest_first":
            # stable: ties keep arrival order
            return sorted(self._queue, key=lambda r: -r.max_new_tokens)
        return list(self._queue)

    def next_chunk(self, free_slots: int) -> list[Request]:
        """Pop the next length-bucketed prefill chunk.

        The bucket length is the head-of-line request's prompt length
        (under the active policy); further queued requests join the
        chunk only if they share that exact length, while slots and the
        token budget last.  The head request is always admitted even
        when its prompt alone exceeds the budget — a long prompt must
        not starve.
        """
        if free_slots < 1 or not self._queue:
            return []
        ordered = self._order()
        bucket_len = ordered[0].prompt_len
        chunk: list[Request] = []
        spent = 0
        for req in ordered:
            if len(chunk) >= free_slots:
                break
            if req.prompt_len != bucket_len:
                continue
            if chunk and spent + req.prompt_len > self.prefill_chunk_tokens:
                break
            chunk.append(req)
            spent += req.prompt_len
        for req in chunk:
            self._queue.remove(req)
        return chunk
