"""Slot-based KV / recurrent-state cache arena for continuous batching.

The decode state of every model family (KV buffers, local-window ring
buffers, rglru recurrent states, rwkv shift/state tensors) is a pytree
whose leaves all carry ONE batch axis — but not the SAME axis: a
homogeneous scan-stacked cache puts layers first (``[L, B, cache_len,
...]``), a heterogeneous tuple-of-dicts cache puts batch first.  The
arena treats that axis as the SLOT axis: a fixed-shape
``[.., slots, ..]`` arena that requests are written into when admitted
and freed from when they complete, so the decode step stays one jitted
fixed-shape program while requests join and leave at arbitrary steps.

``slot_axes`` discovers the per-leaf slot axis structurally (two
``eval_shape`` probes at coprime batch sizes — the axis that moved is
the batch axis), so the arena works for every family without a
per-model axis table.  All mutation helpers are pure jax functions of
``(tree, axes)`` — the engine jits them once; ``FreeList`` is the
host-side slot allocator.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Probe batch sizes for slot-axis discovery.  Coprime and unequal to any
# plausible static cache dimension pair ratio — the ONLY leaf axis that
# differs between the two probes is the batch axis.
_PROBE_A, _PROBE_B = 5, 7


def slot_axes(model, cache_len: int, cache_dtype=jnp.float32):
    """Per-leaf slot (batch) axis of ``model.init_cache``'s pytree.

    Returns a pytree of ints with the same structure as the cache.
    Structural, not positional: two ``eval_shape`` probes at batch sizes
    5 and 7 — the unique axis whose extent changed is the batch axis.
    """
    a = jax.eval_shape(lambda: model.init_cache(_PROBE_A, cache_len,
                                                cache_dtype))
    b = jax.eval_shape(lambda: model.init_cache(_PROBE_B, cache_len,
                                                cache_dtype))

    def one(x, y):
        diff = [i for i, (p, q) in enumerate(zip(x.shape, y.shape))
                if p != q]
        if len(diff) != 1:
            raise ValueError(
                f"cache leaf {x.shape} -> {y.shape} has no unique batch "
                f"axis (changed axes: {diff}); the slot arena needs "
                "exactly one per leaf")
        return diff[0]

    return jax.tree.map(one, a, b)


def take_slot(tree, axes, index):
    """Index one slot out of an arena (or one row out of a prefill
    batch): every leaf loses its slot axis.  ``index`` may be traced."""
    return jax.tree.map(
        lambda a, ax: jax.lax.dynamic_index_in_dim(a, index, ax,
                                                   keepdims=False),
        tree, axes)


def put_slot(tree, axes, row, index):
    """Write a slot-axis-free ``row`` (from ``take_slot``) into slot
    ``index`` of the arena.  ``index`` may be traced."""
    return jax.tree.map(
        lambda a, r, ax: jax.lax.dynamic_update_index_in_dim(
            a, r.astype(a.dtype), index, ax),
        tree, row, axes)


def expand_slot(row, axes):
    """Re-insert a size-1 slot axis so a ``take_slot`` row can be fed to
    the model's batch-shaped decode step (batch = 1 lane)."""
    return jax.tree.map(lambda a, ax: jnp.expand_dims(a, ax), row, axes)


def squeeze_slot(tree, axes):
    """Inverse of ``expand_slot``."""
    return jax.tree.map(lambda a, ax: jnp.squeeze(a, ax), tree, axes)


def where_slots(mask, new, old, axes):
    """Per-slot masked write: leaf ``ax``-indexed rows keep ``new`` where
    ``mask`` is True, ``old`` otherwise — the merge that makes inactive
    slots inert inside the fixed-shape decode step."""
    def one(n, o, ax):
        shape = [1] * n.ndim
        shape[ax] = mask.shape[0]
        return jnp.where(mask.reshape(shape), n, o)
    return jax.tree.map(one, new, old, axes)


class FreeList:
    """Host-side slot allocator: LIFO free list over ``n`` slots.

    LIFO on purpose — a freed slot is re-used as soon as possible, which
    is exactly the reuse pattern the continuous-batching equivalence
    tests pin (a stale cache row must never leak into the next tenant).
    """

    def __init__(self, n: int):
        if n < 1:
            raise ValueError(f"FreeList needs >= 1 slot, got {n}")
        self.n = int(n)
        self._free = list(range(self.n - 1, -1, -1))   # pop() -> slot 0 first

    def __len__(self):
        return len(self._free)

    def alloc(self) -> int:
        if not self._free:
            raise IndexError("no free slots")
        return self._free.pop()

    def free(self, slot: int) -> None:
        slot = int(slot)
        if not 0 <= slot < self.n:
            raise ValueError(f"slot {slot} out of range [0, {self.n})")
        if slot in self._free:
            raise ValueError(f"slot {slot} double-freed")
        self._free.append(slot)
