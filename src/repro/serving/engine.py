"""Continuous-batching serving engine: one jitted fixed-shape decode.

The engine owns a ``kv`` slot arena of ``slots`` lanes and runs ONE
jitted decode-plus-sample program per step regardless of which requests
occupy which slots:

* each lane decodes its own slot at its own position (a ``vmap`` of the
  batch-1 ``model.decode_step`` over the arena's slot axes — bit-exact
  vs a solo batch-1 decode for f32 dense/rwkv stacks, which is what the
  equivalence tests pin);
* temperature sampling runs INSIDE the jit with per-request keys
  (``fold_in(fold_in(key(seed), rid), token_index)``) — reproducible
  and independent of slot assignment and batch composition;
* inactive lanes are inert: masked cache writes, held positions, held
  tokens — a freed slot decodes garbage that is never observed and is
  fully overwritten at the next admit.

Prefill is chunked through the scheduler: one length-bucketed chunk
(``LM.prefill_with_cache`` at the bucket's exact prompt length — no
padding, bit-identical to each request's solo prefill) is interleaved
with decode steps under the chunk token budget, so long prompt bursts
do not stall in-flight decodes.

Modeled cost accounting (the deterministic CI metric): a decode step
bills ``slots`` lane-tokens (the fixed-shape program computes every
lane), a prefill chunk bills its exact token count.  The
run-to-completion convoy baseline bills ``batch * max_gen`` per group —
``convoy_units`` prices it for the same request set, which is what
``benchmarks/serve_bench.py`` gates the >= 1.5x win on.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.qos import ServingQoS
from repro.serving import kv
from repro.serving.scheduler import Request, Scheduler


def _check_servable(cfg):
    if getattr(cfg, "enc_layers", 0) or cfg.family in ("audio", "vlm"):
        raise ValueError(
            f"continuous batching serves decoder-only token LMs; "
            f"{cfg.name} (family={cfg.family}) carries encoder state "
            "the slot arena does not manage")


def make_sample_step(model, temperature: float):
    """decode + sample fused into ONE jitted program (the static serve
    path's per-token step — sampling used to run un-jitted on
    host-synced logits each token).

    ``step(params, serve_state, tok, key) -> (next_tok, logits,
    serve_state, key)``.  Greedy (``temperature == 0``) is a traced
    argmax; temperature sampling splits the carried key inside the jit
    exactly like the old host loop did, so both paths are bit-identical
    to the pre-fusion behaviour.
    """
    from repro.parallel.steps import make_decode_step
    decode = make_decode_step(model)
    temperature = float(temperature)

    def step(params, serve_state, tok, key):
        logits, serve_state = decode(params, serve_state, tok)
        if temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(
                sub, logits / temperature, axis=-1)[:, None]
            nxt = nxt.astype(jnp.int32)
        else:
            nxt = jnp.argmax(logits, axis=-1,
                             keepdims=True).astype(jnp.int32)
        return nxt, logits, serve_state, key

    return jax.jit(step)


class ServingEngine:
    """Slot-based continuous batching over one decoder-only LM."""

    def __init__(self, model, params, *, slots: int, cache_len: int,
                 temperature: float = 0.0, seed: int = 0,
                 prefill_chunk_tokens: int = 256, policy: str = "fifo",
                 max_queue: int | None = None, cache_dtype=jnp.float32,
                 qos: ServingQoS | None = None):
        _check_servable(model.cfg)
        if slots < 1:
            raise ValueError(f"slots={slots} must be >= 1")
        self.model = model
        self.params = params
        self.slots = int(slots)
        self.cache_len = int(cache_len)
        self.temperature = float(temperature)
        self.seed = int(seed)
        self.cache_dtype = cache_dtype
        self.qos = qos or ServingQoS()
        self.axes = kv.slot_axes(model, self.cache_len, cache_dtype)
        self.scheduler = Scheduler(
            cache_len=self.cache_len,
            prefill_chunk_tokens=prefill_chunk_tokens,
            policy=policy, max_queue=max_queue)
        self.freelist = kv.FreeList(self.slots)

        # device arena + host-side lane registers
        self.cache = model.init_cache(self.slots, self.cache_len,
                                      cache_dtype)
        self.positions = np.zeros(self.slots, np.int32)
        self.active = np.zeros(self.slots, bool)
        self.tokens = np.zeros(self.slots, np.int32)
        self.req_seed = np.zeros(self.slots, np.int32)
        self.tok_idx = np.zeros(self.slots, np.int32)
        self._tenant: dict[int, Request] = {}     # slot -> request
        self.outputs: dict[int, list] = {}        # rid -> emitted tokens
        self.done: dict[int, np.ndarray] = {}

        # accounting
        self.decode_steps = 0
        self.prefill_chunks = 0
        self.engine_units = 0                     # modeled lane-tokens
        self.occupancy_trace: list[int] = []

        self._step = jax.jit(self._build_step())
        self._prefill = jax.jit(self._prefill_bucket)
        self._take_row = jax.jit(
            lambda tree, i: kv.take_slot(tree, self.axes, i))
        self._put_row = jax.jit(
            lambda tree, row, s: kv.put_slot(tree, self.axes, row, s))

    # -- jitted programs -----------------------------------------------------

    def _build_step(self):
        model, axes = self.model, self.axes
        temperature, seed = self.temperature, self.seed

        def step(params, cache, positions, active, tokens, req_seed,
                 tok_idx):
            def lane(row, pos, tok, rs, ti):
                cache_b = kv.expand_slot(row, axes)
                logits, new_cache = model.decode_step(
                    params, tok[None, None], cache_b, pos)
                logits = logits[0]
                if temperature > 0:
                    key = jax.random.fold_in(
                        jax.random.fold_in(jax.random.key(seed), rs), ti)
                    nxt = jax.random.categorical(
                        key, logits / temperature, axis=-1)
                else:
                    nxt = jnp.argmax(logits, axis=-1)
                return (kv.squeeze_slot(new_cache, axes),
                        nxt.astype(jnp.int32))

            new_cache, nxt = jax.vmap(
                lane, in_axes=(axes, 0, 0, 0, 0),
                out_axes=(axes, 0))(cache, positions, tokens, req_seed,
                                    tok_idx)
            new_cache = kv.where_slots(active, new_cache, cache, axes)
            nxt = jnp.where(active, nxt, tokens)
            return new_cache, nxt

        return step

    def _prefill_bucket(self, params, tokens):
        """Bucket prefill + greedy seed token (argmax of the prefill
        logits — fed to the first decode, never emitted, matching the
        static serve path)."""
        logits, serve_state = self.model.prefill_with_cache(
            params, {"tokens": tokens}, cache_len=self.cache_len,
            cache_dtype=self.cache_dtype)
        tok0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return serve_state["cache"], tok0

    # -- request intake ------------------------------------------------------

    def submit(self, req: Request) -> bool:
        self.qos.record_submit(req.rid)
        ok = self.scheduler.submit(req)
        if not ok:
            self.qos.record_reject(req.rid)
        return ok

    # -- engine iterations ---------------------------------------------------

    def _admit_chunk(self, chunk: list) -> None:
        prompts = jnp.asarray(np.stack([r.prompt for r in chunk]),
                              jnp.int32)
        bucket_cache, tok0 = self._prefill(self.params, prompts)
        tok0 = np.asarray(tok0)
        self.prefill_chunks += 1
        self.engine_units += int(prompts.size)
        for i, req in enumerate(chunk):
            slot = self.freelist.alloc()
            row = self._take_row(bucket_cache, i)
            self.cache = self._put_row(self.cache, row, slot)
            self.positions[slot] = req.prompt_len
            self.active[slot] = True
            self.tokens[slot] = tok0[i]
            self.req_seed[slot] = req.rid
            self.tok_idx[slot] = 0
            self._tenant[slot] = req
            self.outputs[req.rid] = []
            self.qos.record_admit(req.rid, self.decode_steps)

    def _decode_once(self) -> None:
        self.cache, nxt = self._step(
            self.params, self.cache, jnp.asarray(self.positions),
            jnp.asarray(self.active), jnp.asarray(self.tokens),
            jnp.asarray(self.req_seed), jnp.asarray(self.tok_idx))
        nxt = np.asarray(nxt)
        self.decode_steps += 1
        self.engine_units += self.slots
        self.occupancy_trace.append(int(self.active.sum()))
        finished = []
        for slot, req in self._tenant.items():
            if not self.active[slot]:
                continue
            self.outputs[req.rid].append(int(nxt[slot]))
            self.qos.record_token(req.rid, self.decode_steps)
            self.positions[slot] += 1
            self.tok_idx[slot] += 1
            self.tokens[slot] = nxt[slot]
            if len(self.outputs[req.rid]) >= req.max_new_tokens:
                finished.append(slot)
        for slot in finished:
            req = self._tenant.pop(slot)
            self.active[slot] = False
            self.freelist.free(slot)
            self.done[req.rid] = np.asarray(self.outputs[req.rid],
                                            np.int32)
            self.qos.record_done(req.rid, self.decode_steps)

    def step_once(self) -> bool:
        """One engine iteration: at most one prefill chunk, then one
        decode step.  Returns False when fully idle."""
        chunk = self.scheduler.next_chunk(len(self.freelist))
        if chunk:
            self._admit_chunk(chunk)
        if self.active.any():
            self._decode_once()
            return True
        return bool(chunk)

    def run(self, requests=None, max_steps: int | None = None) -> dict:
        """Drain: submit ``requests`` (optional), iterate until idle.
        Returns ``{rid: np.ndarray of emitted tokens}``."""
        for req in (requests or []):
            self.submit(req)
        guard = max_steps if max_steps is not None else 10_000_000
        while (len(self.scheduler) or self.active.any()) and guard > 0:
            if not self.step_once():
                break
            guard -= 1
        if guard <= 0:
            raise RuntimeError(f"engine did not drain in {max_steps} steps")
        return dict(self.done)

    def stats(self) -> dict:
        occ = self.occupancy_trace
        return {
            "decode_steps": self.decode_steps,
            "prefill_chunks": self.prefill_chunks,
            "engine_units": self.engine_units,
            "occupancy_mean": (float(np.mean(occ)) if occ else 0.0),
            "occupancy_trace_sum": int(np.sum(occ)) if occ else 0,
            "qos": self.qos.snapshot(),
        }


# ---------------------------------------------------------------------------
# References: the solo decode the equivalence tests compare against, and
# the convoy cost model the bench gates the speedup on.
# ---------------------------------------------------------------------------


def solo_decode(model, params, prompt, max_new_tokens: int, *,
                cache_len: int, temperature: float = 0.0, seed: int = 0,
                rid: int = 0, cache_dtype=jnp.float32) -> np.ndarray:
    """Batch-1 run-to-completion decode with the ENGINE's sampling
    contract (greedy seed from the prefill logits; per-request
    ``fold_in`` keys at temperature > 0) — the ground truth every
    continuously-batched request must match bit-for-bit."""
    from repro.parallel.steps import make_decode_step
    decode = jax.jit(make_decode_step(model))
    prompt = jnp.asarray(np.asarray(prompt, np.int32).reshape(1, -1))
    logits, state = jax.jit(
        model.prefill_with_cache,
        static_argnames=("cache_len", "cache_dtype"))(
            params, {"tokens": prompt}, cache_len=cache_len,
            cache_dtype=cache_dtype)
    tok = jnp.argmax(logits, axis=-1, keepdims=True).astype(jnp.int32)
    out = []
    for i in range(max_new_tokens):
        logits, state = decode(params, state, tok)
        if temperature > 0:
            key = jax.random.fold_in(
                jax.random.fold_in(jax.random.key(seed), rid), i)
            nxt = jax.random.categorical(
                key, logits[0] / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits[0], axis=-1)
        tok = nxt.astype(jnp.int32)[None, None]
        out.append(int(tok[0, 0]))
    return np.asarray(out, np.int32)


def convoy_units(requests, batch: int) -> int:
    """Modeled lane-token cost of the static run-to-completion baseline:
    groups of ``batch`` in submission order; each group prefills its
    exact prompt tokens, then decodes ``batch * max(gen in group)``
    lane-tokens — everyone waits for the longest generation (the convoy
    tax continuous batching removes)."""
    reqs = list(requests)
    total = 0
    for i in range(0, len(reqs), batch):
        group = reqs[i:i + batch]
        total += sum(r.prompt_len for r in group)
        total += batch * max(r.max_new_tokens for r in group)
    return total
