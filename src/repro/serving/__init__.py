"""Continuous-batching split-inference serving engine.

``scheduler`` is numpy-only and imports eagerly; the jax-backed pieces
(the slot arena, the engine's jitted decode+sample step, and the
split-inference loopback) load lazily so request/queue plumbing stays
importable without an accelerator stack.
"""
from repro.serving.scheduler import POLICIES, Request, Scheduler

__all__ = [
    "BSInferServer", "FreeList", "POLICIES", "Request", "Scheduler",
    "ServingEngine", "SplitDecode", "UEInferClient", "convoy_units",
    "make_sample_step", "run_split_infer", "slot_axes", "solo_decode",
]

_LAZY = {
    "BSInferServer": "repro.serving.infer",
    "FreeList": "repro.serving.kv",
    "ServingEngine": "repro.serving.engine",
    "SplitDecode": "repro.serving.infer",
    "UEInferClient": "repro.serving.infer",
    "convoy_units": "repro.serving.engine",
    "make_sample_step": "repro.serving.engine",
    "run_split_infer": "repro.serving.infer",
    "slot_axes": "repro.serving.kv",
    "solo_decode": "repro.serving.engine",
}


def __getattr__(name):
    if name in _LAZY:
        import importlib
        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
