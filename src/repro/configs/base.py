"""Architecture registry plumbing: shapes, specs, input stand-ins.

Every assigned architecture contributes an ``ArchSpec`` with
  * ``full``   — the exact published config (dry-run / roofline only),
  * ``smoke``  — a reduced same-family config (CPU tests),
  * ``shapes`` — which of the assigned input shapes apply (with skip reasons).

``input_specs`` builds ``jax.ShapeDtypeStruct`` stand-ins for every model
input of a (config, shape) cell — weak-type-correct, shardable, and never
allocating device memory (the dry-run contract).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import LMConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input shape."""

    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    name: str
    full: LMConfig
    smoke: LMConfig
    # shape name -> None (runs) | str (skip reason)
    skips: dict

    def applicable(self, shape: str) -> bool:
        return self.skips.get(shape) is None

    def skip_reason(self, shape: str) -> str | None:
        return self.skips.get(shape)


FULL_ATTN_SKIP = ("long_500k needs sub-quadratic attention; this arch is "
                  "pure full/global attention (DESIGN.md §Shape-skips)")
WHISPER_LONG_SKIP = ("whisper decoder context is architecturally 448; the "
                     "encoder is fixed-length — no 500k variant exists")


def no_skips() -> dict:
    return {s: None for s in SHAPES}


def full_attn_skips() -> dict:
    d = no_skips()
    d["long_500k"] = FULL_ATTN_SKIP
    return d


def token_struct(batch: int, seq: int):
    return jax.ShapeDtypeStruct((batch, seq), jnp.int32)


def input_specs(cfg: LMConfig, shape: ShapeSpec, cache_dtype=jnp.bfloat16):
    """ShapeDtypeStruct stand-ins for the step inputs of one cell.

    * train:   {tokens, labels} (+ stub frontend embeddings)
    * prefill: {tokens} (+ frontend)
    * decode:  {tokens [B,1], position scalar} (+ enc_out for enc-dec);
               the KV cache is part of the serve state, built by
               ``cache_specs`` below.
    """
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        out = {"tokens": token_struct(b, s), "labels": token_struct(b, s)}
    elif shape.kind == "prefill":
        out = {"tokens": token_struct(b, s)}
    else:  # decode: one new token against a cache of seq_len
        out = {"tokens": token_struct(b, 1)}
    if cfg.family == "vlm" and shape.kind != "decode":
        out["patch_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.num_patches, cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        # stub conv frontend: precomputed frame embeddings
        out["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.enc_seq, cfg.d_model), jnp.float32)
    return out


def cache_specs(cfg: LMConfig, shape: ShapeSpec, cache_dtype=jnp.bfloat16):
    """ShapeDtypeStructs of the decode cache (KV / recurrent state)."""
    from repro.models.lm import LM
    model = LM(cfg)
    return jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                 cache_dtype))


def param_specs(cfg: LMConfig):
    """ShapeDtypeStructs of the parameter tree (no allocation)."""
    from repro.models.lm import LM
    model = LM(cfg)
    return jax.eval_shape(lambda: model.init(jax.random.key(0)))
