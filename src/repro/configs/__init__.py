"""Architecture registry: ``--arch <id>`` resolves through here."""
from __future__ import annotations

from repro.configs.base import (SHAPES, ArchSpec, ShapeSpec, cache_specs,
                                input_specs, param_specs)
from repro.configs import (codeqwen15_7b, command_r_plus_104b, granite_moe_3b,
                           paligemma_3b, qwen15_4b, qwen3_moe_30b,
                           recurrentgemma_2b, resnet18_cifar10, rwkv6_3b,
                           starcoder2_3b, whisper_small)

ARCHS = {
    spec.name: spec
    for spec in (
        recurrentgemma_2b.SPEC,
        qwen15_4b.SPEC,
        command_r_plus_104b.SPEC,
        starcoder2_3b.SPEC,
        codeqwen15_7b.SPEC,
        granite_moe_3b.SPEC,
        qwen3_moe_30b.SPEC,
        paligemma_3b.SPEC,
        rwkv6_3b.SPEC,
        whisper_small.SPEC,
    )
}


def get_arch(name: str) -> ArchSpec:
    if name not in ARCHS:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def all_cells():
    """Every assigned (arch, shape) cell with its skip status."""
    for arch in ARCHS.values():
        for shape in SHAPES.values():
            yield arch, shape, arch.skip_reason(shape.name)
