"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1:2 ratio.

26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000 [arXiv:2402.19427].
Griffin layer pattern: (rglru, rglru, local-attn) repeating — one local
attention layer per two recurrent layers; window 2048.
"""
from repro.configs.base import ArchSpec, no_skips
from repro.models.config import LMConfig


def _pattern(n: int) -> tuple:
    base = ("rglru", "rglru", "local")
    return tuple(base[i % 3] for i in range(n))


FULL = LMConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv=1,
    d_ff=7680,
    vocab=256_000,
    pattern=_pattern(26),
    window=2048,
    lru_width=2560,
    conv_width=4,
    act="gelu",
    norm="rmsnorm",
    embed_scale=True,
    tie_embeddings=True,
    logit_softcap=30.0,
)

SMOKE = LMConfig(
    name="recurrentgemma-smoke",
    family="hybrid",
    num_layers=3,
    d_model=64,
    n_heads=4,
    n_kv=1,
    d_ff=128,
    vocab=512,
    pattern=_pattern(3),
    window=8,
    lru_width=64,
    conv_width=4,
    act="gelu",
    norm="rmsnorm",
    embed_scale=True,
    tie_embeddings=True,
    logit_softcap=30.0,
    dtype="float32",
)

# Hybrid with local attention (window 2048) + recurrent state: long_500k runs.
SPEC = ArchSpec(name="recurrentgemma-2b", full=FULL, smoke=SMOKE,
                skips=no_skips())
