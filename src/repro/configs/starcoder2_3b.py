"""starcoder2-3b [dense] — 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152, GQA + RoPE [arXiv:2402.19173].

starcoder2 uses layernorm + non-gated gelu MLP with biases everywhere.
"""
from repro.configs.base import ArchSpec, full_attn_skips
from repro.models.config import LMConfig

FULL = LMConfig(
    name="starcoder2-3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv=2,
    d_ff=12288,
    vocab=49_152,
    act="gelu",
    norm="layernorm",
    mlp_gated=False,
    mlp_bias=True,
    qkv_bias=True,
    rope_theta=100_000.0,
)

SMOKE = LMConfig(
    name="starcoder2-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=2,
    d_ff=256,
    vocab=512,
    act="gelu",
    norm="layernorm",
    mlp_gated=False,
    mlp_bias=True,
    qkv_bias=True,
    dtype="float32",
)

SPEC = ArchSpec(name="starcoder2-3b", full=FULL, smoke=SMOKE,
                skips=full_attn_skips())
