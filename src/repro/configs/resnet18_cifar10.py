"""resnet18-cifar10 — the paper's own experimental model (SIV-A, Table II).

Not part of the assigned LM pool; this is the faithful-reproduction config
used by the wireless C2P2SL runtime, benchmarks (Fig 3/4/5) and the
equivalence tests.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    name: str = "resnet18-cifar10"
    num_classes: int = 10
    image_size: int = 32
    batch: int = 512             # paper Table I: b = 512
    cut_units: int = 6           # Table II rows (conv1, block1..4, pool+fc)


FULL = ResNetConfig()
SMOKE = ResNetConfig(name="resnet18-smoke", batch=32)
