"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40 experts top-8 [hf:ibm-granite family].

40 experts do NOT divide the 16-wide model axis — the greedy sharding policy
therefore shards within-expert dims (d_model / d_ff) instead of the expert
dim (DESIGN.md §7).  d_ff here is the per-expert width.
"""
from repro.configs.base import ArchSpec, full_attn_skips
from repro.models.config import LMConfig

FULL = LMConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv=8,
    d_ff=512,
    vocab=49_155,
    moe_experts=40,
    moe_topk=8,
    act="silu",
    norm="rmsnorm",
    tie_embeddings=True,
)

SMOKE = LMConfig(
    name="granite-moe-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=2,
    d_ff=32,
    vocab=512,
    moe_experts=5,          # deliberately indivisible, like the full config
    moe_topk=2,
    act="silu",
    norm="rmsnorm",
    tie_embeddings=True,
    dtype="float32",
)

SPEC = ArchSpec(name="granite-moe-3b-a800m", full=FULL, smoke=SMOKE,
                skips=full_attn_skips())
