"""paligemma-3b [vlm] — gemma-2b backbone: 18L d_model=2048 8H (GQA kv=1,
head_dim=256) d_ff=16384 vocab=257216 [arXiv:2407.07726].

The SigLIP vision tower is a STUB per the assignment: ``input_specs()``
provides 256 precomputed patch embeddings which are prepended to the token
sequence with bidirectional (prefix-LM) masking.
"""
from repro.configs.base import ArchSpec, full_attn_skips
from repro.models.config import LMConfig

FULL = LMConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv=1,
    head_dim=256,
    d_ff=16384,
    vocab=257_216,
    num_patches=256,
    act="gelu",
    norm="rmsnorm",
    embed_scale=True,
    tie_embeddings=True,
)

SMOKE = LMConfig(
    name="paligemma-smoke",
    family="vlm",
    num_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=1,
    head_dim=16,
    d_ff=256,
    vocab=512,
    num_patches=8,
    act="gelu",
    norm="rmsnorm",
    embed_scale=True,
    tie_embeddings=True,
    dtype="float32",
)

SPEC = ArchSpec(name="paligemma-3b", full=FULL, smoke=SMOKE,
                skips=full_attn_skips())
