"""whisper-small [audio] — enc-dec: 12L decoder d_model=768 12H (kv=12)
d_ff=3072 vocab=51865, conv frontend STUB [arXiv:2212.04356].

``input_specs()`` provides 1500 precomputed frame embeddings (the output of
the stub conv frontend) consumed by a 12-layer bidirectional encoder; the
12 decoder layers interleave self- and cross-attention ("xattn" blocks).

vocab 51865 is padded to 51872 (x16) for embedding sharding — the only
padded dimension in the zoo (DESIGN.md §7).

Skips: whisper's decoder context is architecturally 448, so long_500k does
not exist for this family; decode_32k is lowered as specified (32k decode
against the fixed 1500-frame encoder memory) per the assignment note.
"""
from repro.configs.base import (ArchSpec, WHISPER_LONG_SKIP, no_skips)
from repro.models.config import LMConfig

FULL = LMConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,
    d_model=768,
    n_heads=12,
    n_kv=12,
    d_ff=3072,
    vocab=51_865,
    vocab_pad_multiple=16,
    pattern=("xattn",) * 12,
    enc_layers=12,
    enc_seq=1500,
    act="gelu",
    norm="layernorm",
    mlp_gated=False,
    mlp_bias=True,
    qkv_bias=True,
    tie_embeddings=False,
)

SMOKE = LMConfig(
    name="whisper-smoke",
    family="audio",
    num_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=4,
    d_ff=128,
    vocab=512,
    pattern=("xattn",) * 2,
    enc_layers=2,
    enc_seq=16,
    act="gelu",
    norm="layernorm",
    mlp_gated=False,
    mlp_bias=True,
    qkv_bias=True,
    dtype="float32",
)


def _skips():
    d = no_skips()
    d["long_500k"] = WHISPER_LONG_SKIP
    return d


SPEC = ArchSpec(name="whisper-small", full=FULL, smoke=SMOKE, skips=_skips())
