"""qwen3-moe-30b-a3b [moe] — 48L d_model=2048 32H (GQA kv=4, head_dim=128)
d_ff=768 (per expert) vocab=151936, MoE 128 experts top-8
[hf:Qwen/Qwen3-30B-A3B]."""
from repro.configs.base import ArchSpec, full_attn_skips
from repro.models.config import LMConfig

FULL = LMConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv=4,
    head_dim=128,
    d_ff=768,
    vocab=151_936,
    moe_experts=128,
    moe_topk=8,
    act="silu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
)

SMOKE = LMConfig(
    name="qwen3-moe-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=2,
    head_dim=32,
    d_ff=32,
    vocab=512,
    moe_experts=8,
    moe_topk=2,
    act="silu",
    norm="rmsnorm",
    dtype="float32",
)

SPEC = ArchSpec(name="qwen3-moe-30b-a3b", full=FULL, smoke=SMOKE,
                skips=full_attn_skips())
