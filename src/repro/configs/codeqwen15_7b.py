"""codeqwen1.5-7b [dense] — 32L d_model=4096 32H (GQA kv=32) d_ff=13440
vocab=92416, qwen1.5-arch (QKV bias) [hf:Qwen/CodeQwen1.5-7B]."""
from repro.configs.base import ArchSpec, full_attn_skips
from repro.models.config import LMConfig

FULL = LMConfig(
    name="codeqwen1.5-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=32,
    d_ff=13440,
    vocab=92_416,
    qkv_bias=True,
    act="silu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
)

SMOKE = LMConfig(
    name="codeqwen1.5-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=4,
    d_ff=192,
    vocab=512,
    qkv_bias=True,
    act="silu",
    norm="rmsnorm",
    dtype="float32",
)

SPEC = ArchSpec(name="codeqwen1.5-7b", full=FULL, smoke=SMOKE,
                skips=full_attn_skips())
