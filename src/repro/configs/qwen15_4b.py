"""qwen1.5-4b [dense] — 40L d_model=2560 20H (GQA kv=20) d_ff=6912
vocab=151936, QKV bias [hf:Qwen/Qwen1.5-0.5B family]."""
from repro.configs.base import ArchSpec, full_attn_skips
from repro.models.config import LMConfig

FULL = LMConfig(
    name="qwen1.5-4b",
    family="dense",
    num_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv=20,
    d_ff=6912,
    vocab=151_936,
    qkv_bias=True,
    act="silu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
)

SMOKE = LMConfig(
    name="qwen1.5-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=4,
    d_ff=128,
    vocab=512,
    qkv_bias=True,
    act="silu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    dtype="float32",
)

SPEC = ArchSpec(name="qwen1.5-4b", full=FULL, smoke=SMOKE,
                skips=full_attn_skips())
