"""command-r-plus-104b [dense] — 64L d_model=12288 96H (GQA kv=8)
d_ff=33792 vocab=256000, no-bias [hf:CohereForAI/c4ai-command-r-v01].

The flagship memory-pressure arch: at 104B parameters the balanced 2-stage
C2P2SL split is what makes the multi-pod mesh fit (DESIGN.md §6).
"""
from repro.configs.base import ArchSpec, full_attn_skips
from repro.models.config import LMConfig

FULL = LMConfig(
    name="command-r-plus-104b",
    family="dense",
    num_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv=8,
    d_ff=33792,
    vocab=256_000,
    act="silu",
    norm="layernorm",
    mlp_gated=True,
    rope_theta=75_000_000.0,
    tie_embeddings=True,     # command-r family ties input/output embeddings
)

SMOKE = LMConfig(
    name="command-r-smoke",
    family="dense",
    num_layers=2,
    d_model=96,
    n_heads=6,
    n_kv=2,
    d_ff=256,
    vocab=512,
    act="silu",
    norm="layernorm",
    mlp_gated=True,
    tie_embeddings=True,
    dtype="float32",
)

SPEC = ArchSpec(name="command-r-plus-104b", full=FULL, smoke=SMOKE,
                skips=full_attn_skips())
