"""rwkv6-3b [ssm] — "Finch", attention-free: 32L d_model=2560 d_ff=8960
vocab=65536, data-dependent decay WKV [arXiv:2404.05892].

Attention-free with O(1) decode state: long_500k runs (the recurrent state
replaces the KV cache entirely).
"""
from repro.configs.base import ArchSpec, no_skips
from repro.models.config import LMConfig

FULL = LMConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    n_heads=40,              # d_model / rwkv_head_dim
    n_kv=40,
    d_ff=8960,
    vocab=65_536,
    pattern=("rwkv",) * 32,
    rwkv_head_dim=64,
    rwkv_lora=64,
    act="sqrelu",
    norm="layernorm",
)

SMOKE = LMConfig(
    name="rwkv6-smoke",
    family="ssm",
    num_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=4,
    d_ff=224,
    vocab=512,
    pattern=("rwkv",) * 2,
    rwkv_head_dim=16,
    rwkv_lora=8,
    act="sqrelu",
    norm="layernorm",
    dtype="float32",
)

SPEC = ArchSpec(name="rwkv6-3b", full=FULL, smoke=SMOKE, skips=no_skips())
