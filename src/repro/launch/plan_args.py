"""Shared CLI surface for the pipeline plan.

One argparse group used by every launcher that constructs a pipeline —
``repro.launch.train``, ``repro.launch.dryrun``,
``benchmarks/perf_iter.py`` — so the plan flags cannot drift apart
again (they had: three hand-rolled copies with different types,
defaults and help text, and ``train.py`` spelled the interleave flag
``--virtual-stages`` while the other two said ``--pipeline-v``).

Two flavors:

* ``add_plan_args(ap, flavor="train")`` — the training driver: values
  may be ``'auto'`` (the roofline planner picks), the codec accepts
  ``auto``, and the planner-evidence flags (``--plan-roofline``,
  ``--plan-hints``, ``--plan-out``) plus the online re-planner flag
  (``--replan``) are included.
* ``add_plan_args(ap, flavor="lower")`` — the lower/compile drivers
  (dryrun, perf_iter): plain integers (0 = no pipeline), no ``auto``
  (a lowered record must pin its cell).
* ``add_plan_args(ap, flavor="serve")`` — the serving driver: only the
  flags that mean something for inference — ``--wire-dtype`` (the INFER
  uplink codec; the serving hop is forward-only, so dense codecs only)
  and ``--plan-out`` (the resolved ``ServingPlan`` evidence).

``--virtual-stages`` is the canonical interleave spelling everywhere;
``--pipeline-v`` keeps working as a deprecated alias (both bind to
``args.virtual_stages``).
"""
from __future__ import annotations

import argparse


_WIRE_HELP = ("wire codec for the pipeline's cut-activation hop "
              "(parallel/wire.py): int8/fp8 block-quantize the ppermute "
              "payload both directions; '<base>+topk<frac>' (e.g. "
              "int8+topk0.25) additionally sparsifies the gradient hop "
              "with error feedback")


def add_plan_args(ap: argparse.ArgumentParser, *, flavor: str = "train",
                  plan_out: bool = True) -> argparse._ArgumentGroup:
    """Attach the shared pipeline-plan flag group; returns the group."""
    if flavor not in ("train", "lower", "serve"):
        raise ValueError(
            f"flavor must be 'train', 'lower' or 'serve', got {flavor!r}")
    if flavor == "serve":
        g = ap.add_argument_group(
            "serving plan",
            "the serving cell — slots via --slots [auto] "
            "(repro.analysis.autotune.choose_serving_plan), INFER-hop "
            "codec via the shared --wire-dtype spelling")
        g.add_argument("--wire-dtype", default="none",
                       help="codec for the split-serving INFER uplink "
                            "(parallel/wire.py grammar, dense only — the "
                            "serving hop is forward-only): none | int8 | "
                            "fp8")
        if plan_out:
            g.add_argument("--plan-out", default=None,
                           help="write the resolved serving plan + its "
                                "evidence (autotune.ServingPlan) as JSON")
        return g
    g = ap.add_argument_group(
        "pipeline plan",
        "the (stages, k, v, wire) plan cell — one Plan currency "
        "(repro.analysis.autotune.Plan) across train/dryrun/perf_iter")
    if flavor == "train":
        g.add_argument("--pipeline-stages", type=int, default=0,
                       help="S>1: run the block stack as a C2P2SL pipeline "
                            "over a pod axis of S local devices")
        g.add_argument("--pipeline-k", default=None,
                       help="micro-batches per pipelined batch: an integer, "
                            "or 'auto' to let the roofline planner pick "
                            "(unset also auto-plans — no more silent k=4)")
        g.add_argument("--virtual-stages", "--pipeline-v",
                       dest="virtual_stages", default=None,
                       help="v>1: interleaved virtual stages — each "
                            "pipeline stage holds v round-robin model "
                            "chunks, shrinking the bubble to (S-1)/v ticks "
                            "per direction at the same k; 'auto' lets the "
                            "planner trade the extra ppermute volume "
                            "against the bubble shrink (unset: 1). "
                            "(--pipeline-v is a deprecated alias)")
        g.add_argument("--wire-dtype", default="none",
                       help=_WIRE_HELP + "; 'auto' lets the roofline "
                            "planner enumerate the codec jointly with "
                            "(k, v)")
        g.add_argument("--plan-roofline", default=None,
                       help="dry-run record (JSON/JSONL) driving the "
                            "auto-planner; default: compile-free config "
                            "estimate (repro.analysis.autotune)")
        g.add_argument("--plan-hints", default=None,
                       help="measured planner hints JSON "
                            "(benchmarks/ppermute_probe.py) overlaid on "
                            "the record hints — calibrates hop_overhead_s "
                            "and link bandwidth from a real ppermute "
                            "instead of the HW constants")
        g.add_argument("--replan", default=None, metavar="SPEC",
                       help="online re-planning (training/replan.py): "
                            "'every:N,hysteresis:F' re-evaluates the plan "
                            "every N steps and switches when the modeled "
                            "wall-time gain beats F (also accepts "
                            "cooldown:N, ewma:F, bare 'on'); 'off' or "
                            "unset disables")
    else:
        g.add_argument("--pipeline-k", type=int, default=0,
                       help="enable the C2P2SL pod pipeline with k "
                            "micro-batches (multi-pod train only; 0 = no "
                            "pipeline)")
        g.add_argument("--virtual-stages", "--pipeline-v",
                       dest="virtual_stages", type=int, default=1,
                       help="interleaved virtual stages per pipeline "
                            "stage (--pipeline-v is a deprecated alias)")
        g.add_argument("--wire-dtype", default="none",
                       help=_WIRE_HELP + "; records carry it so the "
                            "planner can un-scale the ppermute bytes")
    if plan_out:
        g.add_argument("--plan-out", default=None,
                       help="write the resolved plan (train: the plan + "
                            "its evidence; dryrun: the cells' roofline "
                            "auto-plans) as JSON")
    return g


def replan_config(args):
    """``args.replan`` -> ``ReplanConfig | None`` (None = disabled)."""
    from repro.training.replan import ReplanConfig
    try:
        return ReplanConfig.parse(getattr(args, "replan", None))
    except ValueError as e:
        raise SystemExit(f"--replan: {e}")
