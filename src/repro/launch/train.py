"""End-to-end training driver.

Examples
--------
# laptop-scale smoke training (CPU, reduced config):
PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-4b --size smoke \
    --steps 200 --batch 16 --seq 64

# the paper's C2P2SL k-microbatch gradient accumulation:
... --microbatches 8

# production mesh shapes are exercised by dryrun.py; this driver trains
# for real on whatever devices exist.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data import TokenTaskConfig, token_batches
from repro.models.lm import LM
from repro.parallel.steps import make_lm_train_step
from repro.training import checkpoint as ckpt_lib
from repro.training.optim import adamw, cosine_schedule


def build_batch_iter(cfg, batch: int, seq: int, seed: int = 0):
    task = TokenTaskConfig(vocab=cfg.vocab)
    gen = token_batches(task, batch, seq, seed=seed)
    if cfg.family == "vlm":
        rng = np.random.default_rng(seed + 1)
        def it():
            for b in gen:
                b["patch_embeds"] = rng.standard_normal(
                    (batch, cfg.num_patches, cfg.d_model)).astype(np.float32)
                yield b
        return it()
    if cfg.family == "audio":
        rng = np.random.default_rng(seed + 2)
        def it():
            for b in gen:
                b["frames"] = rng.standard_normal(
                    (batch, cfg.enc_seq, cfg.d_model)).astype(np.float32)
                yield b
        return it()
    return gen


def _parse_auto_int(value, flag: str):
    """'auto' | int-string | int | None -> 'auto' | int | None."""
    if value is None or isinstance(value, int):
        return value
    s = str(value).strip().lower()
    if s == "auto":
        return "auto"
    try:
        return int(s)
    except ValueError:
        raise SystemExit(
            f"{flag} must be an integer or 'auto', got {value!r}")


def _load_plan_hints(plan_hints):
    """Measured planner hints (benchmarks/ppermute_probe.py JSON) -> dict."""
    if not plan_hints:
        return None
    try:
        with open(plan_hints) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise SystemExit(f"--plan-hints {plan_hints}: {e}")
    hints = doc.get("planner_hints", doc)
    if not isinstance(hints, dict):
        raise SystemExit(
            f"--plan-hints {plan_hints}: expected a JSON object with a "
            "planner_hints dict (see benchmarks/ppermute_probe.py)")
    return hints


def plan_inputs_for(*, cfg, batch: int, seq: int, pipeline_stages: int,
                    plan_roofline: str | None = None,
                    plan_hints: str | None = None):
    """Base ``PlanInputs`` for this run: the dry-run record's measured
    costs when ``--plan-roofline`` names one, else the compile-free
    config estimate; ``--plan-hints`` overlays either.  Returns
    ``(inputs, source_label)`` — also the calibration anchor the online
    re-planner (``--replan``) drifts from."""
    import dataclasses as _dc

    from repro.analysis import autotune
    extra_hints = _load_plan_hints(plan_hints)
    if plan_roofline:
        try:
            record = autotune.load_record(plan_roofline)
            inp = autotune.plan_inputs_from_record(
                record, num_stages=pipeline_stages,
                num_layers=cfg.num_layers, extra_hints=extra_hints)
        except (OSError, ValueError) as e:   # unreadable / unpipelined record
            raise SystemExit(f"--plan-roofline {plan_roofline}: {e}")
        inp_src = plan_roofline
    else:
        hints = extra_hints or {}
        inp = autotune.plan_inputs_from_cfg(
            cfg, batch=batch, seq=seq, num_stages=pipeline_stages,
            hop_overhead_s=hints.get("hop_overhead_s"),
            link_bw_Bps=hints.get("link_bw_Bps"))
        inp_src = "config estimate (no --plan-roofline)"
    # a micro-batch needs at least one sample row
    return _dc.replace(inp, k_cap=max(1, min(inp.k_cap, batch))), inp_src


def resolve_pipeline_plan(*, pipeline_stages: int, pipeline_k,
                          virtual_stages, cfg, batch: int, seq: int,
                          plan_roofline: str | None = None,
                          wire_dtype: str = "none",
                          plan_hints: str | None = None):
    """Resolve the (S, k, v, wire) pipeline decision from flags + planner.

    Returns ``(PipelineSpec | None, info)``.  ``info`` records where each
    value came from — ``flag`` (hand-supplied), ``auto`` (the roofline
    planner, asked for explicitly), ``auto:default`` (k was unset: the
    planner picks it, replacing the old silent k=4 default), or
    ``default`` (v unset stays 1; wire unset stays 'none').  The
    resolved cell rides ``info["plan_cell"]`` as the versioned
    ``autotune.Plan`` JSON (the single plan currency; ``spec.plan``
    round-trips it); when the planner runs, ``info`` additionally
    carries the full ``AutoPlan`` evidence under ``"plan"``.
    ``plan_hints`` overlays measured planner hints (the ppermute-probe
    calibration) on the record's own.
    """
    k_arg = _parse_auto_int(pipeline_k, "--pipeline-k")
    v_arg = _parse_auto_int(virtual_stages, "--virtual-stages")
    wire = "none" if wire_dtype is None else str(wire_dtype).strip().lower()
    if wire != "auto":
        from repro.parallel import wire as wire_mod
        try:
            wire = wire_mod.validate_wire_dtype(wire)
        except (ValueError, NotImplementedError) as e:
            raise SystemExit(f"--wire-dtype: {e}")
    if pipeline_stages <= 1:
        if v_arg not in (None, 1):
            raise SystemExit(
                "--virtual-stages requires --pipeline-stages > 1 "
                "(interleaving subdivides pipeline stages)")
        if k_arg is not None:
            raise SystemExit(
                "--pipeline-k requires --pipeline-stages > 1 "
                "(use --microbatches for plain gradient accumulation)")
        if wire != "none":
            raise SystemExit(
                "--wire-dtype requires --pipeline-stages > 1 (the codec "
                "compresses the inter-stage pipeline hop)")
        return None, {"enabled": False}
    if isinstance(k_arg, int) and k_arg < 1:
        raise SystemExit(f"--pipeline-k {k_arg} must be >= 1")
    if isinstance(v_arg, int) and v_arg < 1:
        raise SystemExit(f"--virtual-stages {v_arg} must be >= 1")
    k_src = "flag" if isinstance(k_arg, int) \
        else ("auto" if k_arg == "auto" else "auto:default")
    v_src = "flag" if isinstance(v_arg, int) \
        else ("auto" if v_arg == "auto" else "default")
    wire_src = "auto" if wire == "auto" \
        else ("flag" if wire != "none" else "default")

    from repro.analysis.autotune import Plan
    from repro.parallel.pipeline import PipelineSpec
    if isinstance(k_arg, int) and (isinstance(v_arg, int) or v_arg is None) \
            and wire != "auto":
        try:
            cell = Plan(stages=pipeline_stages, k=k_arg,
                        v=v_arg if v_arg else 1, wire_dtype=wire)
        except ValueError as e:
            raise SystemExit(str(e))
        spec = PipelineSpec.from_plan(cell)
        return spec, {"enabled": True, "k": spec.microbatches,
                      "v": spec.virtual_stages, "wire": spec.wire_dtype,
                      "k_source": k_src, "v_source": v_src,
                      "wire_source": wire_src,
                      "plan_cell": cell.to_json(), "plan": None}

    inp, inp_src = plan_inputs_for(
        cfg=cfg, batch=batch, seq=seq, pipeline_stages=pipeline_stages,
        plan_roofline=plan_roofline, plan_hints=plan_hints)
    try:
        spec, plan = PipelineSpec.auto_plan(
            inp,
            k_fixed=k_arg if isinstance(k_arg, int) else None,
            v_fixed=v_arg if isinstance(v_arg, int)
            else (1 if v_arg is None else None),
            wire_dtype=wire)
    except ValueError as e:               # e.g. S*v does not divide layers
        raise SystemExit(str(e))
    return spec, {"enabled": True, "k": spec.microbatches,
                  "v": spec.virtual_stages, "wire": spec.wire_dtype,
                  "k_source": k_src, "v_source": v_src,
                  "wire_source": wire_src, "roofline": inp_src,
                  "plan_cell": spec.plan.to_json(),
                  "plan": plan.to_dict()}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--size", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1,
                    help="the paper's k (gradient accumulation)")
    from repro.launch.plan_args import add_plan_args, replan_config
    add_plan_args(ap, flavor="train")
    ap.add_argument("--replan-trace", default=None,
                    help="scripted link drift for --replan: JSON with "
                         "{'steps': [...], 'bw_Bps': [...]} (a "
                         "wireless.channel.BandwidthTrace) fed to the "
                         "re-planner as per-step bandwidth observations "
                         "— the deterministic drift driver for tests "
                         "and the replan_drift benchmark")
    ap.add_argument("--compress-grads", action="store_true",
                    help="int8 block-quantized gradients with error "
                         "feedback before the optimizer update "
                         "(training/compress.py; EPSL's BP-payload "
                         "compression generalized to the DP axis)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args(argv)

    spec = get_arch(args.arch)
    cfg = spec.smoke if args.size == "smoke" else spec.full
    model = LM(cfg)
    params = model.init(jax.random.key(args.seed))
    opt = adamw(cosine_schedule(args.lr, warmup=20, total=args.steps),
                grad_clip=1.0)
    state = {"params": params, "opt_state": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    if args.compress_grads:
        from repro.training.compress import init_error_fb
        state["error_fb"] = init_error_fb(params)

    pipeline, plan_info = resolve_pipeline_plan(
        pipeline_stages=args.pipeline_stages,
        pipeline_k=args.pipeline_k,
        virtual_stages=args.virtual_stages,
        cfg=cfg, batch=args.batch, seq=args.seq,
        plan_roofline=args.plan_roofline,
        wire_dtype=args.wire_dtype,
        plan_hints=args.plan_hints)
    if pipeline is not None:
        from repro.parallel.pipeline import wire_ef_zeros
        ef = wire_ef_zeros(cfg, pipeline, args.batch, args.seq)
        if ef is not None:     # top-k wire codec: EF rides the train state
            state["wire_ef"] = ef

    # resume-from-checkpoint (fault-tolerance entry point)
    if args.ckpt_dir:
        last = ckpt_lib.latest_step(args.ckpt_dir)
        if last is not None:
            # checkpoints taken BEFORE --compress-grads / a top-k wire
            # codec carry no error-feedback entry; restore everything
            # else and let the residual restart from zero (its natural
            # initial state)
            fresh = {}
            while True:
                try:
                    state = ckpt_lib.restore(args.ckpt_dir, last, state)
                    break
                except KeyError as e:
                    missing = [key for key in ("error_fb", "wire_ef")
                               if key in state and key in str(e)]
                    if not missing:
                        raise
                    fresh[missing[0]] = state.pop(missing[0])
                    print(f"checkpoint predates {missing[0]} — "
                          "error feedback restarts at zero")
            state.update(fresh)
            print(f"resumed from step {last}")

    mesh = None
    if pipeline is not None:
        if args.microbatches != 1:
            raise SystemExit(
                "--microbatches (gradient accumulation) and "
                "--pipeline-stages are mutually exclusive: the pipeline "
                "micro-batches with --pipeline-k instead")
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(pod=args.pipeline_stages)
        line = (f"pipeline: S={pipeline.num_stages} "
                f"k={pipeline.microbatches} [{plan_info['k_source']}] "
                f"v={pipeline.virtual_stages} [{plan_info['v_source']}] "
                f"wire={pipeline.wire_dtype} [{plan_info['wire_source']}]")
        if plan_info.get("plan"):
            p = plan_info["plan"]
            line += (f"  modeled {p['wall_s'] * 1e3:.1f} ms/batch, "
                     f"{p['speedup']:.2f}x vs unpipelined, "
                     f"bubble {p['bubble']:.3f}")
        print(line, flush=True)
    if args.plan_out:
        with open(args.plan_out, "w") as f:
            json.dump(plan_info, f, indent=1)

    replan_cfg = replan_config(args)
    replanner = cell_cache = trace = None
    if replan_cfg is not None:
        if pipeline is None:
            raise SystemExit("--replan requires --pipeline-stages > 1 "
                             "(the re-planner moves the pipeline plan "
                             "cell; there is no cell without a pipeline)")
        from repro.parallel.pipeline import PipelineSpec
        from repro.training.replan import (PlanCellCache, Replanner,
                                           carry_state)
        inp, _ = plan_inputs_for(
            cfg=cfg, batch=args.batch, seq=args.seq,
            pipeline_stages=args.pipeline_stages,
            plan_roofline=args.plan_roofline, plan_hints=args.plan_hints)
        replanner = Replanner(inp, pipeline.plan, replan_cfg)
        if args.replan_trace:
            from repro.wireless.channel import BandwidthTrace
            try:
                with open(args.replan_trace) as f:
                    doc = json.load(f)
                trace = BandwidthTrace(steps=tuple(doc["steps"]),
                                       bw_Bps=tuple(doc["bw_Bps"]))
            except (OSError, KeyError, ValueError,
                    json.JSONDecodeError) as e:
                raise SystemExit(f"--replan-trace {args.replan_trace}: {e}")
        # jitted train step per plan cell: re-entering a cell is a cache
        # hit, so a switch costs one compile at most once per cell
        cell_cache = PlanCellCache(lambda p: jax.jit(make_lm_train_step(
            model, opt, microbatches=1,
            pipeline=PipelineSpec.from_plan(p), mesh=mesh,
            compress=args.compress_grads)))
        print(f"replan: {replan_cfg.describe()}"
              + (f" trace={args.replan_trace}" if trace else ""),
              flush=True)
        step_fn = cell_cache.get(pipeline.plan)
    else:
        step_fn = jax.jit(make_lm_train_step(model, opt,
                                             microbatches=args.microbatches,
                                             pipeline=pipeline, mesh=mesh,
                                             compress=args.compress_grads))
    it = build_batch_iter(cfg, args.batch, args.seq, args.seed)

    history = []
    t0 = time.perf_counter()
    start = int(state["step"])
    warm = False       # first step after a (re)compile is not a sample
    for i in range(start, args.steps):
        ts = time.perf_counter()
        state, mets = step_fn(state, next(it))
        if replanner is not None:
            jax.block_until_ready(mets["loss"])
            if warm:   # drop compile-tainted samples from the EWMA feed
                replanner.observe_step(0, time.perf_counter() - ts)
            warm = True
            if trace is not None:
                replanner.observe_bandwidth(trace.at(i + 1))
            switch = replanner.maybe_replan(i + 1)
            if switch is not None:
                print(f"replan @ step {switch.step}: {switch.old} -> "
                      f"{switch.new}  modeled "
                      f"{switch.old_wall_s * 1e3:.1f} -> "
                      f"{switch.new_wall_s * 1e3:.1f} ms/batch "
                      f"({switch.gain:.0%} gain)", flush=True)
                state = carry_state(state, switch.new, cfg=cfg,
                                    batch=args.batch, seq=args.seq)
                step_fn = cell_cache.get(switch.new)
                warm = False
        if args.log_every and (i + 1) % args.log_every == 0:
            row = {k: float(v) for k, v in mets.items()}
            row.update(step=i + 1, wall_s=time.perf_counter() - t0)
            history.append(row)
            print(f"step {i+1:5d}  loss {row['loss']:.4f}  "
                  f"wall {row['wall_s']:.1f}s", flush=True)
        if args.ckpt_dir and args.ckpt_every \
                and (i + 1) % args.ckpt_every == 0:
            ckpt_lib.save(args.ckpt_dir, i + 1, state)
            ckpt_lib.prune(args.ckpt_dir)
    if replanner is not None:
        print(f"replan: {replanner.evals} evals, "
              f"{len(replanner.switches)} switch(es), "
              f"{cell_cache.misses} cell compile(s); "
              f"final {replanner.current}", flush=True)
        if args.plan_out:     # re-write with the switch log appended
            plan_info["replan"] = replanner.to_json()
            with open(args.plan_out, "w") as f:
                json.dump(plan_info, f, indent=1)
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(history, f, indent=1)
    return history


if __name__ == "__main__":
    main()
