"""End-to-end training driver.

Examples
--------
# laptop-scale smoke training (CPU, reduced config):
PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-4b --size smoke \
    --steps 200 --batch 16 --seq 64

# the paper's C2P2SL k-microbatch gradient accumulation:
... --microbatches 8

# production mesh shapes are exercised by dryrun.py; this driver trains
# for real on whatever devices exist.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data import TokenTaskConfig, token_batches
from repro.models.lm import LM
from repro.parallel.steps import make_lm_train_step
from repro.training import checkpoint as ckpt_lib
from repro.training.optim import adamw, cosine_schedule


def build_batch_iter(cfg, batch: int, seq: int, seed: int = 0):
    task = TokenTaskConfig(vocab=cfg.vocab)
    gen = token_batches(task, batch, seq, seed=seed)
    if cfg.family == "vlm":
        rng = np.random.default_rng(seed + 1)
        def it():
            for b in gen:
                b["patch_embeds"] = rng.standard_normal(
                    (batch, cfg.num_patches, cfg.d_model)).astype(np.float32)
                yield b
        return it()
    if cfg.family == "audio":
        rng = np.random.default_rng(seed + 2)
        def it():
            for b in gen:
                b["frames"] = rng.standard_normal(
                    (batch, cfg.enc_seq, cfg.d_model)).astype(np.float32)
                yield b
        return it()
    return gen


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--size", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1,
                    help="the paper's k (gradient accumulation)")
    ap.add_argument("--pipeline-stages", type=int, default=0,
                    help="S>1: run the block stack as a C2P2SL pipeline "
                         "over a pod axis of S local devices")
    ap.add_argument("--pipeline-k", type=int, default=4,
                    help="micro-batches per pipelined batch")
    ap.add_argument("--virtual-stages", type=int, default=1,
                    help="v>1: interleaved virtual stages — each pipeline "
                         "stage holds v round-robin model chunks, "
                         "shrinking the bubble to (S-1)/v ticks per "
                         "direction at the same k")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args(argv)

    spec = get_arch(args.arch)
    cfg = spec.smoke if args.size == "smoke" else spec.full
    model = LM(cfg)
    params = model.init(jax.random.key(args.seed))
    opt = adamw(cosine_schedule(args.lr, warmup=20, total=args.steps),
                grad_clip=1.0)
    state = {"params": params, "opt_state": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}

    # resume-from-checkpoint (fault-tolerance entry point)
    if args.ckpt_dir:
        last = ckpt_lib.latest_step(args.ckpt_dir)
        if last is not None:
            state = ckpt_lib.restore(args.ckpt_dir, last, state)
            print(f"resumed from step {last}")

    pipeline = None
    mesh = None
    if args.pipeline_stages > 1:
        if args.microbatches != 1:
            raise SystemExit(
                "--microbatches (gradient accumulation) and "
                "--pipeline-stages are mutually exclusive: the pipeline "
                "micro-batches with --pipeline-k instead")
        from repro.launch.mesh import make_host_mesh
        from repro.parallel.pipeline import PipelineSpec
        mesh = make_host_mesh(pod=args.pipeline_stages)
        pipeline = PipelineSpec(num_stages=args.pipeline_stages,
                                microbatches=args.pipeline_k,
                                virtual_stages=args.virtual_stages)
    elif args.virtual_stages > 1:
        raise SystemExit("--virtual-stages requires --pipeline-stages > 1 "
                         "(interleaving subdivides pipeline stages)")
    step_fn = jax.jit(make_lm_train_step(model, opt,
                                         microbatches=args.microbatches,
                                         pipeline=pipeline, mesh=mesh))
    it = build_batch_iter(cfg, args.batch, args.seq, args.seed)

    history = []
    t0 = time.perf_counter()
    start = int(state["step"])
    for i in range(start, args.steps):
        state, mets = step_fn(state, next(it))
        if args.log_every and (i + 1) % args.log_every == 0:
            row = {k: float(v) for k, v in mets.items()}
            row.update(step=i + 1, wall_s=time.perf_counter() - t0)
            history.append(row)
            print(f"step {i+1:5d}  loss {row['loss']:.4f}  "
                  f"wall {row['wall_s']:.1f}s", flush=True)
        if args.ckpt_dir and args.ckpt_every \
                and (i + 1) % args.ckpt_every == 0:
            ckpt_lib.save(args.ckpt_dir, i + 1, state)
            ckpt_lib.prune(args.ckpt_dir)
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(history, f, indent=1)
    return history


if __name__ == "__main__":
    main()
