"""Production mesh construction.

A function, not a module-level constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax init).  All
version differences (axis types existing or not) live in parallel/compat.py.
"""
from __future__ import annotations

import jax

from repro.parallel.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 1):
    """Small mesh over whatever local devices exist (tests, examples)."""
    n = len(jax.devices())
    want = data * model * pod
    assert want <= n, f"need {want} devices, have {n}"
    if pod > 1:
        return make_mesh((pod, data, model), ("pod", "data", "model"))
    return make_mesh((data, model), ("data", "model"))
