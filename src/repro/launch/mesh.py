"""Production mesh construction.

A function, not a module-level constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax init).
"""
from __future__ import annotations

import jax


def _auto(n: int):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(shape)))


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 1):
    """Small mesh over whatever local devices exist (tests, examples)."""
    n = len(jax.devices())
    want = data * model * pod
    assert want <= n, f"need {want} devices, have {n}"
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"),
                             axis_types=_auto(3))
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=_auto(2))
