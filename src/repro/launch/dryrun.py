import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    # CPU-only pass that crashes on the SPMD partitioner's replicate-as-
    # last-resort all-reduce (reduction computation = copy).  The pass does
    # not exist on the TPU target; disabling it only affects this CPU
    # dry-run's bf16 all-reduce numerics, which we never execute.
    "--xla_disable_hlo_passes=all-reduce-promotion")

"""Multi-pod dry-run: prove every (arch x shape x mesh) cell lowers,
compiles, and fits — without any real hardware.

For each cell this script:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. assembles ShapeDtypeStruct stand-ins for every step input,
  3. ``jax.jit(step, in_shardings=..., out_shardings=...).lower(...)``
     then ``.compile()``,
  4. prints ``memory_analysis()`` (fits?) and ``cost_analysis()`` (FLOPs /
     bytes for §Roofline), plus the HLO-parsed collective bytes,
  5. appends a JSON record to ``--out`` for EXPERIMENTS.md / benchmarks.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out results/dryrun.jsonl
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.analysis.hlo_costs import flat_cost_analysis
from repro.analysis.roofline import model_flops_for, roofline_from_compiled
from repro.configs import SHAPES, ARCHS, get_arch, input_specs, param_specs
from repro.launch.mesh import make_production_mesh
from repro.models.lm import LM
from repro.parallel.context import ParallelCtx, use_ctx
from repro.parallel.sharding import ShardingPolicy, bytes_per_device
from repro.parallel.steps import (make_decode_step, make_lm_train_step,
                                  make_prefill_step)
from repro.training.optim import adamw


def lower_cell(arch_name: str, shape_name: str, multi_pod: bool,
               *, pipeline_k: int = 0, pipeline_v: int = 1,
               wire_dtype: str = "none",
               microbatches: int = 1,
               cast_gathers: bool = False, seq_shard: bool | None = None,
               master_fp32: bool = False, pure_dp: bool = False):
    """Lower + compile one cell; returns (record, compiled)."""
    if pipeline_v > 1 and not pipeline_k:
        raise ValueError(
            "pipeline_v > 1 requires pipeline_k (interleaving subdivides "
            "pipeline stages; without the pipeline the record would claim "
            "an interleave that never ran)")
    if wire_dtype not in (None, "none") and not pipeline_k:
        raise ValueError(
            "wire_dtype requires pipeline_k (the codec compresses the "
            "pipeline hop; without the pipeline the record would claim a "
            "codec that never ran)")
    arch = get_arch(arch_name)
    shape = SHAPES[shape_name]
    cfg = arch.full
    model = LM(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    policy = ShardingPolicy(mesh, pod_is_pipeline=bool(pipeline_k),
                            pure_dp=pure_dp)
    pod_axes = ("pod",) if (multi_pod and not pipeline_k) else ()
    # Sequence parallelism for the attention families in train/prefill:
    # shards the residual-stream carries that dominate backward memory.
    # Recurrent families (ssm/hybrid) keep the sequence dim local — their
    # scans run along it (DESIGN.md §7).
    if seq_shard is None:
        seq_shard = (cfg.family in ("dense", "moe", "vlm", "audio")
                     and shape.kind in ("train", "prefill") and not pure_dp)
    seq_axes = ("model",) if seq_shard else ()
    data_axes = ("data", "model") if pure_dp else ("data",)
    model_axes = () if pure_dp else ("model",)
    ctx = ParallelCtx(mesh=mesh, pod_axes=pod_axes, seq_axes=seq_axes,
                      data_axes=data_axes, model_axes=model_axes,
                      cast_gathers=cast_gathers)

    t0 = time.time()
    with use_ctx(ctx):
        if shape.kind == "train":
            opt = adamw(3e-4)
            p_structs = param_specs(cfg)
            p_model = p_structs
            if master_fp32:
                from repro.models.lm import cast_gather_weights
                from repro.training.optim import mixed_precision
                dt = jnp.dtype(cfg.dtype)
                cast = lambda tree: cast_gather_weights(tree, dt)
                opt = mixed_precision(opt, cast)
                p_model = jax.eval_shape(cast, p_structs)
            state = {"params": p_model,
                     "opt_state": jax.eval_shape(opt.init, p_structs),
                     "step": jax.ShapeDtypeStruct((), jnp.int32)}
            state_sh = policy.train_state_shardings(state)
            batch = input_specs(cfg, shape)
            batch_sh = policy.batch_shardings(batch)
            pipeline = None
            if pipeline_k:
                from repro.analysis.autotune import Plan
                from repro.parallel.pipeline import (PipelineSpec,
                                                     wire_ef_zeros)
                assert multi_pod, "the C2P2SL pipeline runs over the pod axis"
                pipeline = PipelineSpec.from_plan(
                    Plan(stages=mesh.shape["pod"], k=pipeline_k,
                         v=pipeline_v, wire_dtype=wire_dtype or "none"))
                ef = jax.eval_shape(
                    lambda: wire_ef_zeros(cfg, pipeline, shape.global_batch,
                                          shape.seq_len))
                if ef is not None:
                    # top-k wire codec: the EF residual rides the train
                    # state, stage-sharded like the pipeline's xs buffer.
                    # (policy's path rules don't know this 5-D buffer,
                    # so pin its sharding explicitly.)
                    state["wire_ef"] = ef
                    state_sh["wire_ef"] = jax.sharding.NamedSharding(
                        mesh, jax.sharding.PartitionSpec("pod"))
            step = make_lm_train_step(model, opt, microbatches=microbatches,
                                      pipeline=pipeline)
            jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None))
            lowered = jitted.lower(state, batch)
            state_bytes = bytes_per_device(state, policy)
        elif shape.kind == "prefill":
            p_structs = param_specs(cfg)
            p_sh = policy.param_shardings(p_structs)
            batch = input_specs(cfg, shape)
            batch_sh = policy.batch_shardings(batch)
            step = make_prefill_step(model)
            jitted = jax.jit(step, in_shardings=(p_sh, batch_sh))
            lowered = jitted.lower(p_structs, batch)
            state_bytes = bytes_per_device(p_structs, policy)
        else:  # decode
            from repro.parallel.steps import init_serve_state
            p_structs = param_specs(cfg)
            p_sh = policy.param_shardings(p_structs)
            serve = jax.eval_shape(
                lambda: init_serve_state(model, shape.global_batch,
                                         shape.seq_len))
            serve_sh = policy.cache_shardings(serve, shape.global_batch)
            batch = input_specs(cfg, shape)
            batch_sh = policy.batch_shardings(batch)
            step = make_decode_step(model)
            jitted = jax.jit(step,
                             in_shardings=(p_sh, serve_sh, batch_sh["tokens"]),
                             out_shardings=(None, serve_sh))
            lowered = jitted.lower(p_structs, serve, batch["tokens"])
            state_bytes = (bytes_per_device(p_structs, policy)
                           + bytes_per_device(
                               serve, policy,
                               spec_fn=lambda s: policy.cache_spec(
                                   s, shape.global_batch)))

        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    ca_flat = flat_cost_analysis(compiled)
    terms = roofline_from_compiled(
        compiled, chips=chips, model_flops=model_flops_for(cfg, shape),
        hlo_text=hlo)
    record = {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "kind": shape.kind,
        "dtype": cfg.dtype,
        "d_model": cfg.d_model,
        "pipeline_k": pipeline_k,
        "pipeline_v": pipeline_v,
        "wire_dtype": wire_dtype or "none",
        # the compiled cell as the versioned single plan currency
        # (autotune.Plan.to_json; null for unpipelined cells)
        "plan": pipeline.plan.to_json() if pipeline is not None else None,
        "microbatches": microbatches,
        "compile_s": round(time.time() - t0, 1),
        "state_bytes_per_device": state_bytes,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": (mem.argument_size_in_bytes
                           + mem.temp_size_in_bytes),
        },
        "roofline": terms.to_dict(),
        # flat (trip-count-unaware) XLA numbers, for reference
        "cost_analysis_flat": {
            "flops": float(ca_flat.get("flops", 0.0)),
            "bytes_accessed": float(ca_flat.get("bytes accessed", 0.0)),
        },
    }
    if pipeline_k and shape.kind == "train":
        # Machine-readable auto-plan: what (k, v, wire codec) the roofline
        # planner would pick for this cell (feeds train.py
        # --plan-roofline and benchmarks/perf_iter.py --pipeline-auto).
        # ``wire_sweep`` keeps the per-codec evidence — which codec won
        # and by how much — next to the chosen plan.
        from repro.analysis.autotune import (plan_inputs_from_record,
                                             wire_plan_sweep)
        try:
            inp = plan_inputs_from_record(
                record, num_stages=mesh.shape["pod"],
                k_cap=max(1, shape.global_batch // mesh.shape["data"]),
                num_layers=cfg.num_layers)
            sweep = wire_plan_sweep(inp)
            record["auto_plan"] = sweep["chosen"]
            record["auto_plan"]["wire_sweep"] = sweep["sweep"]
        except (ValueError, KeyError) as e:
            record["auto_plan"] = {"error": str(e)}
    return record, compiled


def cell_key(arch, shape, mesh, pipeline_k, pipeline_v, wire_dtype):
    """--skip-done identity of a cell: EVERY knob that changes what gets
    compiled must be in here, or re-runs with a new knob value are
    silently skipped as already done.  Records from before a knob
    existed read as its default (v=1, wire 'none')."""
    return (arch, shape, mesh, int(pipeline_k or 0), int(pipeline_v or 1),
            wire_dtype or "none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    from repro.launch.plan_args import add_plan_args
    add_plan_args(ap, flavor="lower")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--skip-done", action="store_true",
                    help="skip cells already present in --out")
    args = ap.parse_args()

    archs = sorted(ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if args.skip_done and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add(cell_key(r["arch"], r["shape"], r["mesh"],
                                      r.get("pipeline_k", 0),
                                      r.get("pipeline_v", 1),
                                      r.get("wire_dtype", "none")))
                except (json.JSONDecodeError, KeyError):
                    pass

    n_ok = n_skip = n_fail = 0
    plans = []
    for arch_name in archs:
        arch = get_arch(arch_name)
        for shape_name in shapes:
            reason = arch.skip_reason(shape_name)
            if reason is not None:
                print(f"SKIP  {arch_name} x {shape_name}: {reason}")
                with open(args.out, "a") as f:
                    f.write(json.dumps({"arch": arch_name,
                                        "shape": shape_name,
                                        "skip": reason}) + "\n")
                n_skip += 1
                continue
            for multi in meshes:
                mesh_name = "2x16x16" if multi else "16x16"
                key = cell_key(arch_name, shape_name, mesh_name,
                               args.pipeline_k, args.virtual_stages,
                               args.wire_dtype)
                if key in done:
                    print(f"done  {key}")
                    continue
                print(f"LOWER {arch_name} x {shape_name} x {mesh_name} ...",
                      flush=True)
                try:
                    rec, compiled = lower_cell(
                        arch_name, shape_name, multi,
                        pipeline_k=args.pipeline_k,
                        pipeline_v=args.virtual_stages,
                        wire_dtype=args.wire_dtype,
                        microbatches=args.microbatches)
                    mem = rec["memory"]
                    rl = rec["roofline"]
                    print(f"  ok in {rec['compile_s']}s  "
                          f"state/dev {rec['state_bytes_per_device']/2**30:.2f} GiB  "
                          f"temp/dev {mem['temp_bytes']/2**30:.2f} GiB  "
                          f"t_comp {rl['t_compute_s']:.4f}s "
                          f"t_mem {rl['t_memory_s']:.4f}s "
                          f"t_coll {rl['t_collective_s']:.4f}s "
                          f"-> {rl['bottleneck']}", flush=True)
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")
                    if "auto_plan" in rec:
                        ap_rec = rec["auto_plan"]
                        plans.append({"arch": arch_name, "shape": shape_name,
                                      "mesh": mesh_name, "plan": ap_rec})
                        if "k" in ap_rec:
                            print(f"  auto plan: k={ap_rec['k']} "
                                  f"v={ap_rec['v']} "
                                  f"wire={ap_rec.get('wire_dtype', 'none')} "
                                  f"({ap_rec['speedup']:.2f}x vs "
                                  f"unpipelined)", flush=True)
                    n_ok += 1
                    del compiled
                except Exception:
                    n_fail += 1
                    print(f"  FAIL {arch_name} x {shape_name} x {mesh_name}")
                    traceback.print_exc()
    if args.plan_out:
        with open(args.plan_out, "w") as f:
            json.dump(plans, f, indent=1)
        print(f"wrote {len(plans)} auto-plan records to {args.plan_out}")
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped (documented), "
          f"{n_fail} failed")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
