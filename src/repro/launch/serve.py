"""Batched serving driver: prefill a prompt batch, then decode tokens.

PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --size smoke \
    --batch 8 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models.lm import LM
from repro.parallel.steps import init_serve_state, make_decode_step


def prefill_into_cache(decode, params, tokens, serve_state):
    """Token-by-token prompt feed (reference path, any family).

    The production path is ``LM.prefill_with_cache`` — one full-sequence
    forward that fills the cache directly (equivalence proven in
    tests/test_models.py::test_chunked_prefill_matches_token_loop).
    """
    logits = None
    for t in range(tokens.shape[1]):
        logits, serve_state = decode(params, serve_state, tokens[:, t:t + 1])
    return logits, serve_state


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--size", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--token-loop-prefill", action="store_true",
                    help="reference prefill path (token by token) instead "
                         "of the chunked one-pass prefill")
    args = ap.parse_args(argv)

    spec = get_arch(args.arch)
    cfg = spec.smoke if args.size == "smoke" else spec.full
    model = LM(cfg)
    params = model.init(jax.random.key(args.seed))
    cache_len = args.cache_len or (args.prompt_len + args.gen)

    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len))
    prompts = jnp.asarray(prompts, jnp.int32)
    frames = None
    if cfg.enc_layers:        # enc-dec: stub frames -> encoder memory
        frames = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.enc_seq, cfg.d_model)), jnp.float32)

    decode = jax.jit(make_decode_step(model))

    t0 = time.perf_counter()
    if args.token_loop_prefill or cfg.family == "vlm":
        serve_state = init_serve_state(model, args.batch, cache_len,
                                       cache_dtype=jnp.float32)
        if frames is not None:
            enc_out = model._encode(params, frames)
            serve_state["cache"] = model.fill_cross_kv(
                params, enc_out, serve_state["cache"])
        logits, serve_state = prefill_into_cache(decode, params, prompts,
                                                 serve_state)
    else:
        prompt_batch = {"tokens": prompts}
        if frames is not None:
            prompt_batch["frames"] = frames
        logits, serve_state = jax.jit(
            model.prefill_with_cache,
            static_argnames=("cache_len", "cache_dtype"))(
                params, prompt_batch, cache_len=cache_len,
                cache_dtype=jnp.float32)
    t_prefill = time.perf_counter() - t0

    key = jax.random.key(args.seed)
    out_tokens = []
    # The prefill logits' argmax seeds the first decode; each decode's
    # sampled output token is appended AFTER that decode runs, so all
    # ``--gen`` decode steps land in the output (the old loop appended
    # the pre-decode token and silently discarded the final decode's).
    tok = jnp.argmax(logits, axis=-1, keepdims=True).astype(jnp.int32)
    t0 = time.perf_counter()
    for i in range(args.gen):
        logits, serve_state = decode(params, serve_state, tok)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits / args.temperature, axis=-1)[:, None]
            tok = tok.astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, axis=-1, keepdims=True).astype(jnp.int32)
        out_tokens.append(np.asarray(tok[:, 0]))
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0

    toks = np.stack(out_tokens, axis=1)
    print(f"prefill: {args.prompt_len} toks x {args.batch} seqs "
          f"in {t_prefill:.2f}s")
    print(f"decode:  {args.gen} toks x {args.batch} seqs in {t_decode:.2f}s "
          f"({args.gen * args.batch / max(t_decode, 1e-9):.1f} tok/s)")
    print("sample:", toks[0, :16].tolist())
    return toks


if __name__ == "__main__":
    main()
