"""Serving driver — thin CLI over ``repro.serving``.

Three modes, one flag surface:

* **static** (default): prefill a prompt batch, then run the fused
  decode+sample jit (``serving.engine.make_sample_step``) for ``--gen``
  steps — the original batched convoy path, kept as the baseline.
* ``--continuous``: the continuous-batching engine
  (``serving.engine.ServingEngine``) — a slot arena of ``--slots``
  lanes (or ``--slots auto``: ``analysis/autotune.choose_serving_plan``
  on measured step costs), chunked prefill interleaved with one jitted
  fixed-shape decode step, per-request QoS latency percentiles.
* ``--split-cut L``: split inference — the UE half (embed + blocks[:L])
  ships coded cut activations over a real loopback socket as INFER
  frames (``--wire-dtype`` none/int8/fp8) to the BS half, which samples
  and replies; prints the measured-vs-billed wire-honesty audit.

PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --size smoke \
    --batch 8 --prompt-len 32 --gen 32
PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-4b \
    --continuous --requests 24 --gen-mix 8,32,128 --slots 8
PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-4b \
    --split-cut 2 --wire-dtype int8
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.launch.plan_args import add_plan_args
from repro.models.lm import LM
from repro.parallel.steps import init_serve_state, make_decode_step
from repro.serving.scheduler import POLICIES, Request


def prefill_into_cache(decode, params, tokens, serve_state):
    """Token-by-token prompt feed (reference path, any family).

    The production path is ``LM.prefill_with_cache`` — one full-sequence
    forward that fills the cache directly (equivalence proven in
    tests/test_models.py::test_chunked_prefill_matches_token_loop).
    """
    logits = None
    for t in range(tokens.shape[1]):
        logits, serve_state = decode(params, serve_state, tokens[:, t:t + 1])
    return logits, serve_state


def _static_serve(model, params, prompts, frames, args, cache_len):
    """Batched convoy serving: one prefill, ``--gen`` fused
    decode+sample steps.  Returns emitted tokens [batch, gen]."""
    from repro.serving.engine import make_sample_step

    t0 = time.perf_counter()
    if args.token_loop_prefill or model.cfg.family == "vlm":
        decode = jax.jit(make_decode_step(model))
        serve_state = init_serve_state(model, args.batch, cache_len,
                                       cache_dtype=jnp.float32)
        if frames is not None:
            enc_out = model._encode(params, frames)
            serve_state["cache"] = model.fill_cross_kv(
                params, enc_out, serve_state["cache"])
        logits, serve_state = prefill_into_cache(decode, params, prompts,
                                                 serve_state)
    else:
        prompt_batch = {"tokens": prompts}
        if frames is not None:
            prompt_batch["frames"] = frames
        logits, serve_state = jax.jit(
            model.prefill_with_cache,
            static_argnames=("cache_len", "cache_dtype"))(
                params, prompt_batch, cache_len=cache_len,
                cache_dtype=jnp.float32)
    t_prefill = time.perf_counter() - t0

    step = make_sample_step(model, args.temperature)
    key = jax.random.key(args.seed)
    out_tokens = []
    # The prefill logits' argmax seeds the first decode; each decode's
    # sampled output token is appended AFTER that decode runs, so all
    # ``--gen`` decode steps land in the output (the old loop appended
    # the pre-decode token and silently discarded the final decode's).
    tok = jnp.argmax(logits, axis=-1, keepdims=True).astype(jnp.int32)
    t0 = time.perf_counter()
    for _ in range(args.gen):
        tok, logits, serve_state, key = step(params, serve_state, tok, key)
        out_tokens.append(np.asarray(tok[:, 0]))
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0

    toks = np.stack(out_tokens, axis=1)
    print(f"prefill: {args.prompt_len} toks x {args.batch} seqs "
          f"in {t_prefill:.2f}s")
    print(f"decode:  {args.gen} toks x {args.batch} seqs in {t_decode:.2f}s "
          f"({args.gen * args.batch / max(t_decode, 1e-9):.1f} tok/s)")
    print("sample:", toks[0, :16].tolist())
    return toks


def _request_mix(cfg, args) -> list:
    """Deterministic request set: ``--requests`` prompts of
    ``--prompt-len`` tokens, generation budgets cycled from ``--gen-mix``
    through a seeded shuffle (ragged on purpose — the convoy tax)."""
    rng = np.random.default_rng(args.seed)
    mix = [int(g) for g in str(args.gen_mix).split(",") if g]
    gens = np.asarray([mix[i % len(mix)] for i in range(args.requests)])
    rng.shuffle(gens)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, args.prompt_len),
                    max_new_tokens=int(gens[i]))
            for i in range(args.requests)]


def _measure_serving_inputs(model, params, args, cache_len):
    """Two-point measurement of the engine's step cost for ``--slots
    auto``: decode steps at two arena sizes give the per-lane slope and
    the fixed overhead; one prefill gives the per-token cost."""
    from repro.serving.engine import ServingEngine

    def step_s(slots):
        eng = ServingEngine(model, params, slots=slots,
                            cache_len=cache_len, seed=args.seed)
        for r in range(slots):
            eng.submit(Request(rid=r, prompt=np.zeros(1, np.int32),
                               max_new_tokens=cache_len - 1))
        eng.step_once()                      # admit + first (compile) step
        t0 = time.perf_counter()
        for _ in range(4):
            eng._decode_once()
        return (time.perf_counter() - t0) / 4

    t1, t4 = step_s(1), step_s(4)
    lane_s = max((t4 - t1) / 3, 1e-9)
    prompts = jnp.zeros((1, args.prompt_len), jnp.int32)
    pf = jax.jit(model.prefill_with_cache,
                 static_argnames=("cache_len", "cache_dtype"))
    jax.block_until_ready(pf(params, {"tokens": prompts},
                             cache_len=cache_len,
                             cache_dtype=jnp.float32)[0])
    t0 = time.perf_counter()
    jax.block_until_ready(pf(params, {"tokens": prompts},
                             cache_len=cache_len,
                             cache_dtype=jnp.float32)[0])
    prefill_tok_s = (time.perf_counter() - t0) / args.prompt_len
    from repro.analysis.autotune import ServingInputs
    return ServingInputs(
        decode_lane_s=lane_s, step_overhead_s=max(t1 - lane_s, 0.0),
        prefill_s_per_token=prefill_tok_s,
        arrival_hz=args.arrival_hz, prompt_tokens=float(args.prompt_len),
        gen_tokens=float(np.mean([int(g) for g in
                                  str(args.gen_mix).split(",") if g])),
        wire_dtype=args.wire_dtype, act_bytes=4.0,
        d_model=model.cfg.d_model)


def _resolve_slots(model, params, args, cache_len):
    """``--slots`` -> (slot count, ServingPlan evidence | None)."""
    if str(args.slots) != "auto":
        n = int(args.slots) or args.batch
        return n, None
    if not args.arrival_hz > 0:
        raise SystemExit("--slots auto needs --arrival-hz (the offered "
                         "load the serving planner optimizes for)")
    from repro.analysis.autotune import choose_serving_plan
    inp = _measure_serving_inputs(model, params, args, cache_len)
    plan = choose_serving_plan(inp)
    print(f"serving plan: slots={plan.slots} wire={plan.wire_dtype} "
          f"p99_ttft={plan.p99_ttft_s * 1e3:.2f} ms "
          f"({plan.tokens_per_s:.1f} tok/s, rho={plan.rho:.2f})")
    return plan.slots, plan


def _continuous_serve(model, params, args, cache_len):
    """Continuous batching: the slot-arena engine over a ragged request
    mix.  Returns ``{rid: emitted tokens}``."""
    from repro.serving.engine import ServingEngine, convoy_units

    slots, plan = _resolve_slots(model, params, args, cache_len)
    requests = _request_mix(model.cfg, args)
    engine = ServingEngine(
        model, params, slots=slots, cache_len=cache_len,
        temperature=args.temperature, seed=args.seed,
        prefill_chunk_tokens=args.prefill_chunk_tokens,
        policy=args.policy)
    t0 = time.perf_counter()
    outputs = engine.run(requests)
    wall = time.perf_counter() - t0
    stats = engine.stats()
    emitted = sum(len(v) for v in outputs.values())
    convoy = convoy_units(requests, args.batch)
    print(f"continuous: {len(outputs)}/{len(requests)} requests, "
          f"{emitted} tokens in {wall:.2f}s "
          f"({emitted / max(wall, 1e-9):.1f} tok/s)")
    print(f"engine units {stats['engine_units']} vs convoy(batch="
          f"{args.batch}) {convoy} -> modeled speedup "
          f"{convoy / max(stats['engine_units'], 1):.2f}x; "
          f"occupancy {stats['occupancy_mean']:.2f}/{slots}")
    lat = stats["qos"]["latency"]
    if lat["p50_ttft_s"] is not None:
        print(f"latency: p50 ttft {lat['p50_ttft_s'] * 1e3:.1f} ms, "
              f"p99 ttft {lat['p99_ttft_s'] * 1e3:.1f} ms")
    if args.plan_out:
        doc = {"mode": "continuous", "slots": slots,
               "wire_dtype": args.wire_dtype,
               "plan": plan.to_dict() if plan is not None else None,
               "stats": stats}
        with open(args.plan_out, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"wrote {args.plan_out}")
    return outputs


def _split_serve(model, params, prompts, args, cache_len):
    """Split inference over the loopback socket; prints the wire-honesty
    audit.  Returns emitted tokens [batch, gen]."""
    from repro.serving.infer import run_split_infer

    res = run_split_infer(model, params, cut=args.split_cut,
                          prompts=np.asarray(prompts), gen=args.gen,
                          cache_len=cache_len,
                          wire_dtype=args.wire_dtype)
    rel = abs(res["measured_payload_bytes"] - res["billed_payload_bytes"]) \
        / max(res["billed_payload_bytes"], 1e-9)
    print(f"split-infer: cut={args.split_cut} wire={args.wire_dtype} "
          f"{res['frames']} INFER frames, measured "
          f"{res['measured_payload_bytes']} B vs billed "
          f"{res['billed_payload_bytes']:.0f} B (rel {rel:.2e})")
    print("sample:", res["tokens"][0, :16].tolist())
    if args.plan_out:
        doc = {"mode": "split", "cut": args.split_cut,
               "wire_dtype": args.wire_dtype,
               "measured_payload_bytes": res["measured_payload_bytes"],
               "billed_payload_bytes": res["billed_payload_bytes"],
               "frames": res["frames"]}
        with open(args.plan_out, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"wrote {args.plan_out}")
    return res["tokens"]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--size", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--token-loop-prefill", action="store_true",
                    help="reference prefill path (token by token) instead "
                         "of the chunked one-pass prefill")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous-batching engine instead of the "
                         "static convoy loop")
    ap.add_argument("--requests", type=int, default=24,
                    help="continuous mode: request count")
    ap.add_argument("--gen-mix", default="8,32,128",
                    help="continuous mode: generation budgets, cycled "
                         "through a seeded shuffle")
    ap.add_argument("--slots", default="0",
                    help="continuous mode: slot-arena size (0 = --batch; "
                         "'auto' runs the serving planner — needs "
                         "--arrival-hz)")
    ap.add_argument("--arrival-hz", type=float, default=0.0,
                    help="offered request rate for --slots auto")
    ap.add_argument("--policy", default="fifo", choices=list(POLICIES),
                    help="continuous mode: admission order")
    ap.add_argument("--prefill-chunk-tokens", type=int, default=256,
                    help="continuous mode: prefill-chunk token budget")
    ap.add_argument("--split-cut", type=int, default=0,
                    help="L>0: split inference — UE runs blocks[:L], "
                         "ships coded INFER frames over loopback")
    add_plan_args(ap, flavor="serve")
    args = ap.parse_args(argv)

    spec = get_arch(args.arch)
    cfg = spec.smoke if args.size == "smoke" else spec.full
    model = LM(cfg)
    params = model.init(jax.random.key(args.seed))
    cache_len = args.cache_len or (args.prompt_len + args.gen)
    if args.continuous:
        cache_len = args.cache_len or (
            args.prompt_len + max(int(g) for g in
                                  str(args.gen_mix).split(",") if g))
        return _continuous_serve(model, params, args, cache_len)

    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len))
    prompts = jnp.asarray(prompts, jnp.int32)
    if args.split_cut:
        return _split_serve(model, params, prompts, args, cache_len)
    frames = None
    if cfg.enc_layers:        # enc-dec: stub frames -> encoder memory
        frames = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.enc_seq, cfg.d_model)), jnp.float32)
    return _static_serve(model, params, prompts, frames, args, cache_len)


if __name__ == "__main__":
    main()
