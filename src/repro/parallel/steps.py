"""Step builders: the jit-able train / prefill / decode functions.

These are what the launcher jits and the dry-run lowers; all distribution
is expressed through in/out shardings (GSPMD) plus the optional C2P2SL
pipeline (repro/parallel/pipeline.py) over the ``pod`` axis.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.lm import LM
from repro.training.microbatch import microbatched_value_and_grad
from repro.training.optim import Optimizer


def make_lm_loss(model: LM):
    def loss_fn(params, batch):
        loss, mets = model.forward(params, batch)
        return loss, mets
    return loss_fn


def make_lm_train_step(model: LM, opt: Optimizer, *, microbatches: int = 1,
                       pipeline=None, mesh=None, compress: bool = False):
    """Build ``train_step(state_tree, batch) -> (state_tree, metrics)``.

    ``microbatches`` is the paper's k — gradient accumulation over k
    micro-batches (mathematically equivalent update).  ``pipeline`` is an
    optional PipelineSpec that routes the block stack through the C2P2SL
    S-stage pipeline over the pod axis instead (``mesh`` pins the pipeline
    mesh; defaults to the ambient parallel context's).  ``compress``
    applies int8 block-quantized gradients with error feedback before the
    update — the EPSL volume-reduction idea generalized to the DP axis
    (the state tree then carries an ``error_fb`` entry; see
    training/compress.py).
    """
    needs_wire_ef = False
    if pipeline is not None:
        from repro.parallel.pipeline import make_pipelined_loss
        loss_fn = make_pipelined_loss(model, pipeline, mesh=mesh)
        needs_wire_ef = getattr(loss_fn, "needs_wire_ef", False)
        if needs_wire_ef:
            # top-k wire codec: the EF buffer is a third loss input whose
            # gradient IS the updated buffer (pipeline.py) — pull it out
            # alongside the weight grads and write it back to the state.
            vg = jax.value_and_grad(loss_fn, argnums=(0, 2), has_aux=True)
        else:
            vg = jax.value_and_grad(loss_fn, has_aux=True)
    else:
        vg = microbatched_value_and_grad(make_lm_loss(model), microbatches)

    def train_step(state_tree, batch):
        params = state_tree["params"]
        new_state = {}
        if needs_wire_ef:
            (loss, mets), (grads, new_ef) = vg(params, batch,
                                               state_tree["wire_ef"])
            new_state["wire_ef"] = new_ef
        else:
            (loss, mets), grads = vg(params, batch)
        if compress:
            from repro.training.compress import (compress_grads,
                                                 decompress_grads)
            qtree, new_efb = compress_grads(
                jax.tree.map(lambda g: g.astype(jnp.float32), grads),
                state_tree["error_fb"])
            grads = decompress_grads(qtree)
            new_state["error_fb"] = new_efb
        new_params, new_opt = opt.update(grads, state_tree["opt_state"],
                                         params, state_tree["step"])
        mets = dict(mets)
        mets["loss"] = loss
        new_state.update(params=new_params, opt_state=new_opt,
                         step=state_tree["step"] + 1)
        return new_state, mets

    return train_step


def make_prefill_step(model: LM):
    """prefill(params, batch) -> last-position logits [B, V].

    (The serving path computes hidden states for the whole prompt; emitting
    only the final logits keeps the output small — the cache-filling prefill
    variant lives in serve.py.)
    """
    def prefill(params, batch):
        h = model.hidden(params, batch)
        dt = h.dtype
        logits = h[:, -1] @ model._head_w(params, dt)
        return logits[:, :model.cfg.vocab].astype(jnp.float32)

    return prefill


def make_decode_step(model: LM):
    """decode(params, serve_state, tokens) -> (logits, new serve_state).

    serve_state = {"cache": pytree, "position": int32 scalar}
    (+ "enc_out" for enc-dec models, computed once at prefill).
    """
    def decode(params, serve_state, tokens):
        enc_out = serve_state.get("enc_out")
        logits, new_cache = model.decode_step(
            params, tokens, serve_state["cache"], serve_state["position"],
            enc_out=enc_out)
        new_state = dict(serve_state)
        new_state["cache"] = new_cache
        new_state["position"] = serve_state["position"] + 1
        return logits, new_state

    return decode


def init_serve_state(model: LM, batch: int, cache_len: int,
                     cache_dtype=jnp.bfloat16) -> dict[str, Any]:
    """Decode state: KV caches / recurrent states + position.

    Enc-dec models carry precomputed cross-attention K/V inside the cache
    (fill with ``model.fill_cross_kv(params, enc_out, cache)`` after
    encoding) — the encoder memory itself is NOT needed at decode time.
    """
    return {"cache": model.init_cache(batch, cache_len, cache_dtype),
            "position": jnp.zeros((), jnp.int32)}
