from repro.parallel.context import (ParallelCtx, get_ctx, set_ctx, use_ctx,
                                    constrain)
