"""C2P2SL as a TPU pipeline: micro-batch pipelining over the ``pod`` axis.

This is the paper's core insight transplanted to pods (DESIGN.md §3/§4):
the slow link is no longer a TDMA radio channel but the inter-pod DCN/ICI
boundary.  The first ``l`` layers ("UE-side model") live on pod 0, the rest
("BS-side model") on pod 1; each batch is split into ``k`` micro-batches
that stream through the stages.  The mapping:

    UE FP            -> stage-0 block scan on micro-batch m
    uplink (UT)      -> ppermute stage0 -> stage1 of the cut activations
    BS FP + BP 1F1B  -> stage-1 compute; jax.grad through the scan gives
                        the reverse pipeline
    downlink (DT)    -> the autodiff transpose of the forward ppermute
    gradient accumulation over k micro-batches -> the scan's grad sum

Implementation: a ``shard_map`` manual over ``pod`` only (data/model axes
stay GSPMD-auto), with a ``lax.scan`` over ``k + S - 1`` pipeline ticks.
At tick t, stage s processes micro-batch ``t - s``; outputs move to stage
``s+1`` via ``ppermute`` — XLA's latency-hiding scheduler overlaps the
transfer with the next tick's compute, which is exactly the paper's
communication/computation overlap.

Embedding and LM head run replicated across pods (negligible FLOP share);
the ppermuted tensor is the cut-layer activation — the paper's ``s_l``.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.blocks import apply_block
from repro.models.common import apply_norm
from repro.parallel.context import ParallelCtx, use_ctx


@dataclasses.dataclass(frozen=True)
class PipelineSpec:
    num_stages: int = 2          # S: UE-side / BS-side (extensible)
    microbatches: int = 4        # k — pick with repro.core.ao.lemma1_k
    axis: str = "pod"


def _split_stages(blocks, num_stages: int):
    """[L, ...] stacked block params -> [S, L/S, ...]."""
    def r(a):
        l = a.shape[0]
        assert l % num_stages == 0, (
            f"num_layers {l} not divisible by {num_stages} stages")
        return a.reshape((num_stages, l // num_stages) + a.shape[1:])
    return jax.tree.map(r, blocks)


def pipeline_blocks(cfg, blocks, xs, positions, spec: PipelineSpec, *,
                    mesh, prefix_len: int = 0, enc_outs=None):
    """Run the stacked homogeneous block stack as a pipeline.

    blocks: stacked params, leaves [L, ...]
    xs:     [k, mb, seq, d] micro-batched activations (embedded)
    enc_outs: optional [k, mb, enc_seq, d] (whisper cross-attention memory)
    Returns (hidden [k, mb, seq, d], aux_loss scalar).
    """
    kind = cfg.layer_kinds[0]
    k = xs.shape[0]
    s_stages = spec.num_stages
    ticks = k + s_stages - 1
    staged = _split_stages(blocks, s_stages)

    from jax.sharding import AxisType, NamedSharding
    # constraint mesh view: pod is Manual inside this region, rest Auto
    abs_mesh = mesh.abstract_mesh.update(axis_types=tuple(
        AxisType.Manual if n == spec.axis else AxisType.Auto
        for n in mesh.shape))
    # micro-batch over data; seq deliberately NOT model-sharded inside the
    # stage: per-micro-batch SP re-gathers the stage weights and re-reduces
    # weight grads k times (refuted, EXPERIMENTS.md §Perf pipeline it2) —
    # without SP, GSPMD defers the weight-grad reduction across ticks.
    data_spec = NamedSharding(abs_mesh, P("data"))

    def pin(x):
        """Anchor the micro-batch dim to the data axis INSIDE the manual-
        over-pod region — without this GSPMD replicates the micro-batch
        across the 16-wide data axis (16x redundant compute; EXPERIMENTS.md
        §Perf, pipeline iteration 1)."""
        return jax.lax.with_sharding_constraint(x, data_spec)

    def stage_scan(blocks_local, x, enc_out):
        """One stage's block scan on one micro-batch."""
        def body(carry, layer_params):
            y, aux = apply_block(layer_params, carry, cfg, kind,
                                 positions=positions, prefix_len=prefix_len,
                                 enc_out=enc_out,
                                 use_rope=(kind != "rwkv"))
            return pin(y), aux
        y, auxes = jax.lax.scan(jax.checkpoint(body), pin(x), blocks_local)
        return y, auxes.sum()

    def per_stage(blocks_stage, xs_full, enc_full):
        # manual over 'pod': blocks_stage leaves [1, L/S, ...]
        blocks_local = jax.tree.map(lambda a: a[0], blocks_stage)
        stage = jax.lax.axis_index(spec.axis)
        # carries differ per stage -> mark them varying over the pod axis
        state = jax.lax.pcast(jnp.zeros(xs_full.shape[1:], xs_full.dtype),
                              (spec.axis,), to="varying")
        aux0 = jax.lax.pcast(jnp.float32(0.0), (spec.axis,), to="varying")
        perm = [(i, i + 1) for i in range(s_stages - 1)]

        def tick(carry, t):
            state, aux_acc = carry
            m = jnp.clip(t - stage, 0, k - 1)      # this stage's micro-batch
            inp0 = jax.lax.dynamic_index_in_dim(xs_full, m, 0, keepdims=False)
            cur = jnp.where(stage == 0, inp0, state)
            enc = None
            if enc_full is not None:
                enc = jax.lax.dynamic_index_in_dim(enc_full, m, 0,
                                                   keepdims=False)
            y, aux = stage_scan(blocks_local, cur, enc)
            nxt = jax.lax.ppermute(y, spec.axis, perm)
            live = (t >= stage) & (t < stage + k)
            aux_acc = aux_acc + jnp.where(live, aux, 0.0)
            return (nxt, aux_acc), y

        (_, aux_acc), ys = jax.lax.scan(
            tick, (state, aux0), jnp.arange(ticks))
        # last stage's outputs live at ticks [S-1, S-1+k)
        out = jax.lax.dynamic_slice_in_dim(ys, s_stages - 1, k, axis=0)
        # stack a stage axis so out_specs=P('pod') can concatenate
        return out[None], aux_acc[None]

    fn = jax.shard_map(
        per_stage, mesh=mesh,
        in_specs=(P(spec.axis), P(), P()),
        out_specs=(P(spec.axis), P(spec.axis)),
        axis_names={spec.axis}, check_vma=True)
    outs, auxes = fn(staged, xs, enc_outs)
    return outs[-1], auxes[-1]          # the last stage's real outputs


def make_pipelined_loss(model, spec: PipelineSpec, mesh=None):
    """loss_fn(params, batch) with the block stack pipelined over pods.

    Requires a homogeneous (scan-stacked) architecture; the heterogeneous
    recurrentgemma pattern keeps the pod-as-DP path (DESIGN.md §7).
    """
    cfg = model.cfg
    assert cfg.homogeneous, (
        "pipeline mode needs a homogeneous layer stack; "
        f"{cfg.name} has a mixed pattern — use pod-as-data-parallel")
    k = spec.microbatches

    def loss_fn(params, batch):
        # Plain-JAX context inside: data/model axes are GSPMD-auto, the
        # pipeline shard_map is manual over 'pod' only.
        from repro.parallel.context import get_ctx
        use_mesh = mesh if mesh is not None else get_ctx().mesh
        with use_ctx(ParallelCtx()):
            dt = jnp.dtype(cfg.dtype)
            tokens = batch["tokens"]
            labels = batch["labels"]
            prefix_len = 0
            enc_flat = None

            x = model._embed(params, tokens, dt)
            if cfg.family == "vlm":
                patches = batch["patch_embeds"].astype(dt)
                x = jnp.concatenate([patches, x], axis=1)
                prefix_len = patches.shape[1]
                pad = jnp.full(patches.shape[:2], -1, labels.dtype)
                labels = jnp.concatenate([pad, labels], axis=1)
            if cfg.family == "audio":
                enc_flat = model._encode(params, batch["frames"].astype(dt))

            b, seq = x.shape[0], x.shape[1]
            assert b % k == 0, f"batch {b} not divisible by k={k}"
            mb = b // k
            xs = x.reshape(k, mb, seq, x.shape[-1])
            enc_outs = None
            if enc_flat is not None:
                enc_outs = enc_flat.reshape(k, mb, enc_flat.shape[1],
                                            enc_flat.shape[2])
            positions = jnp.arange(seq)

            out, aux = pipeline_blocks(cfg, params["blocks"], xs, positions,
                                       spec, mesh=use_mesh,
                                       prefix_len=prefix_len,
                                       enc_outs=enc_outs)
            h = out.reshape(b, seq, x.shape[-1])
            h = apply_norm(h, params["final_norm"], cfg.norm)
            loss = model.xent(params, h, labels)
            total = loss + 0.01 * aux
            return total, {"xent": loss, "aux": aux}

    return loss_fn
