"""C2P2SL as a TPU pipeline: micro-batch pipelining over the ``pod`` axis.

This is the paper's core insight transplanted to pods (DESIGN.md §3/§4):
the slow link is no longer a TDMA radio channel but the inter-pod DCN/ICI
boundary.  The first ``l`` layers ("UE-side model") live on pod 0, the rest
("BS-side model") on pod 1; each batch is split into ``k`` micro-batches
that stream through the stages.  The mapping:

    UE FP            -> stage-0 block scan on micro-batch m
    uplink (UT)      -> ppermute stage0 -> stage1 of the cut activations
    BS FP + BP 1F1B  -> stage-1 compute; jax.grad through the scan gives
                        the reverse pipeline
    downlink (DT)    -> the autodiff transpose of the forward ppermute
    gradient accumulation over k micro-batches -> the scan's grad sum

Implementation: a ``shard_map`` over the ``spec.axis`` ('pod') with a
``lax.scan`` over the pipeline ticks.  With ``virtual_stages == 1`` this
is the plain 1F1B schedule: ``k + S - 1`` ticks, stage s processes
micro-batch ``t - s`` at tick t; outputs move to stage ``s+1`` via
``ppermute`` — XLA's latency-hiding scheduler overlaps the transfer with
the next tick's compute, which is exactly the paper's
communication/computation overlap.

Interleaved (virtual-stage) scheduling generalizes this: with
``virtual_stages = v`` the layer stack splits into ``S*v`` chunks and
chunk c lives on physical stage ``c % S`` (round-robin), so each stage
owns v non-contiguous model chunks of ``L/(S*v)`` layers.  Micro-batch m
enters the pipeline at tick ``sigma(m) = (m // S)*S*v + (m % S)`` and
chunk c of micro-batch m runs at tick ``sigma(m) + c`` — the standard
interleaved spacing, provably collision-free on every stage (two chunks
of one stage differ by a multiple of S; two start offsets never do
unless they differ by >= S*v).  A tick now costs 1/v of a stage pass, so
the warm-up/drain bubble shrinks from ``(S-1)`` stage-passes to
``(S-1)/v`` per direction at the same k, at the price of v-1 extra
cut-activation hops per micro-batch (the chunk boundary wraps from stage
S-1 back to stage 0, hence the cyclic ppermute when v > 1).  The reverse
(backward) interleaved pipeline still falls out of ``jax.grad`` through
the scan — the transpose of a cyclic ppermute is the reverse cyclic
ppermute, and the transpose of the per-tick chunk gather is the
scatter-add into the right chunk's weight gradient.

Version portability (all probing in ``parallel/compat.py``):

  * On explicit-sharding JAX the region is Manual over 'pod' ONLY —
    data/model axes stay GSPMD-auto inside the stage, with an explicit
    constraint anchoring the micro-batch to the data axis (without it
    GSPMD replicates the micro-batch across the 16-wide data axis — 16x
    redundant compute; EXPERIMENTS.md §Perf, pipeline iteration 1).
  * On legacy JAX (0.4.x) Manual-over-a-subset aborts inside the XLA SPMD
    partitioner, so the region is fully manual: the micro-batch dim is
    explicitly sharded over 'data' (when divisible) and stage weights are
    replicated over the remaining axes.  Numerically identical; the model
    axis does redundant compute inside pipeline stages on that generation.

Embedding and LM head run replicated across pods (negligible FLOP share);
the ppermuted tensor is the cut-layer activation — the paper's ``s_l``.

``PipelineSpec.wire_dtype`` selects the wire codec for that hop
(``parallel/wire.py``): ``"int8"`` / ``"fp8"`` block-quantize the cut
activation before each forward ppermute and the activation gradient on
the transposed backward ppermute — EPSL's payload compression applied to
the pod boundary — while ``"none"`` keeps the raw ppermute bit-for-bit.
The codec wraps the hop only; both shard_map lowerings share it through
``_tick_loop``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.blocks import apply_block
from repro.models.common import apply_norm
from repro.parallel import compat, wire
from repro.parallel.compat import PartitionSpec as P
from repro.parallel.context import ParallelCtx, use_ctx


@dataclasses.dataclass(frozen=True)
class PipelineSpec:
    num_stages: int = 2          # S: UE-side / BS-side (extensible)
    microbatches: int = 4        # k — pick with repro.core.ao.lemma1_k
    virtual_stages: int = 1      # v: interleaved model chunks per stage
    wire_dtype: str = "none"     # hop codec: none | int8 | fp8 (wire.py)
    axis: str = "pod"

    def __post_init__(self):
        # normalize the codec name at construction so every consumer
        # (the tick loop's coded-vs-raw branch, planners, logs) sees one
        # spelling; membership/availability is validated when the
        # pipeline actually runs (pipeline_blocks)
        norm = "none" if self.wire_dtype is None \
            else str(self.wire_dtype).strip().lower()
        object.__setattr__(self, "wire_dtype", norm)

    @classmethod
    def from_plan(cls, plan, *, axis: str = "pod") -> "PipelineSpec":
        """The sanctioned ``Plan -> PipelineSpec`` constructor.

        ``plan`` is the single plan currency (``analysis/autotune.Plan``)
        or its ``to_json()`` dict; every launcher builds its pipeline
        through here so a plan that changes mid-run (training/replan.py)
        and a plan fixed at launch construct identically.
        """
        from repro.analysis.autotune import Plan
        if isinstance(plan, dict):
            plan = Plan.from_json(plan)
        if not isinstance(plan, Plan):
            raise TypeError(
                f"from_plan expects an autotune.Plan (or its to_json() "
                f"dict), got {type(plan).__name__} — build one with "
                "Plan(stages=..., k=..., v=..., wire_dtype=...)")
        return cls(num_stages=plan.stages, microbatches=plan.k,
                   virtual_stages=plan.v, wire_dtype=plan.wire_dtype,
                   axis=axis)

    @property
    def plan(self):
        """This spec as the single plan currency (inverse of
        ``from_plan``; the pod axis name is runtime context, not plan)."""
        from repro.analysis.autotune import Plan
        return Plan(stages=self.num_stages, k=self.microbatches,
                    v=self.virtual_stages, wire_dtype=self.wire_dtype)

    @classmethod
    def auto_k(cls, stage_compute_s: float, link_s: float, *,
               num_stages: int = 2, virtual_stages: int = 1,
               k_cap: int = 16, axis: str = "pod"):
        """Spec with k chosen by the paper's Lemma 1 closed form
        (repro.core.ao.pipeline_k_auto) from per-stage compute time and
        inter-stage link time; interleaving (v > 1) divides the k needed
        to reach the steady state."""
        from repro.core.ao import pipeline_k_auto
        k = pipeline_k_auto(stage_compute_s, link_s, k_cap=k_cap,
                            virtual_stages=virtual_stages)
        return cls(num_stages=num_stages, microbatches=k,
                   virtual_stages=virtual_stages, axis=axis)

    @classmethod
    def auto_plan(cls, source, *, num_stages: int | None = None,
                  k_fixed: int | None = None, v_fixed: int | None = None,
                  wire_dtype: str | None = None,
                  axis: str = "pod", **extract_kwargs):
        """Spec with (k, v[, wire codec]) chosen by the roofline planner.

        ``source`` is a dry-run record dict (launch/dryrun.py JSONL), a
        ``repro.analysis.autotune.PlanInputs``, or an already-chosen
        ``AutoPlan``.  ``k_fixed`` / ``v_fixed`` pin one coordinate (a
        hand flag overriding half of an auto plan).  ``wire_dtype`` pins
        the hop codec ('none'/'int8'/'fp8'); ``'auto'`` asks the planner
        to enumerate the codec jointly with (k, v) — a smaller wire moves
        the argmin.  Returns ``(spec, AutoPlan)`` so callers can
        log/record the evidence.
        """
        from repro.analysis import autotune
        if isinstance(source, autotune.AutoPlan):
            if k_fixed is not None or v_fixed is not None \
                    or wire_dtype is not None:
                raise ValueError(
                    "k_fixed/v_fixed/wire_dtype cannot re-pin an "
                    "already-chosen AutoPlan — pass its PlanInputs "
                    "(plan.inputs) to re-plan with pins")
            plan = source
        else:
            inp = source
            if isinstance(source, dict):
                inp = autotune.plan_inputs_from_record(
                    source, num_stages=num_stages, **extract_kwargs)
            elif num_stages is not None and num_stages != inp.num_stages:
                inp = inp.with_stages(num_stages)
            wire_candidates = None
            if wire_dtype == "auto":
                wire_candidates = list(autotune.WIRE_AUTO)
            elif wire_dtype is not None:
                inp = inp.with_wire(wire_dtype)
            plan = autotune.choose_plan(inp, k_fixed=k_fixed,
                                        v_fixed=v_fixed,
                                        wire_candidates=wire_candidates)
        return cls.from_plan(plan.plan, axis=axis), plan


def _split_stages(blocks, num_stages: int, virtual_stages: int = 1):
    """[L, ...] stacked block params -> [S, v, L/(S*v), ...].

    Chunk ``c = j*S + s`` (layers ``[c*Lc, (c+1)*Lc)``) lands at
    ``out[s, j]`` — the round-robin placement of interleaved scheduling;
    ``v == 1`` degenerates to the contiguous S-way split.
    """
    chunks = num_stages * virtual_stages

    def r(a):
        l = a.shape[0]
        if l % chunks != 0:
            raise ValueError(
                f"num_layers {l} not divisible by num_stages x "
                f"virtual_stages = {num_stages} x {virtual_stages} = "
                f"{chunks} model chunks — pick S*v dividing the layer "
                "count")
        a = a.reshape((virtual_stages, num_stages, l // chunks)
                      + a.shape[1:])
        return jnp.swapaxes(a, 0, 1)
    return jax.tree.map(r, blocks)


def _sigma(m: int, num_stages: int, virtual_stages: int) -> int:
    """Pipeline-entry tick of micro-batch m (interleaved spacing).

    Consecutive micro-batches within a group of S enter back-to-back;
    groups are spaced S*v ticks apart so that no two chunks of one stage
    ever need the same tick (their chunk offsets differ by a multiple of
    S but less than S*v).  For v == 1 this is simply ``sigma(m) = m``.
    """
    return (m // num_stages) * num_stages * virtual_stages \
        + (m % num_stages)


def hop_perms(spec: PipelineSpec):
    """The tick schedule's inter-stage hop permutations on the pod axis:
    ``(forward, backward)`` tuples of ``(src, dst)`` pairs.

    This is the single source of truth the tick loop ships on — acyclic
    chain for v == 1 (the last stage has no successor), cyclic for v > 1
    (the chunk chain wraps from stage S-1 back to stage 0) — and the
    backward permutation is the transpose (reversed pairs), which is what
    ``wire.coded_ppermute``'s custom_vjp codes the gradient hop with.
    ``repro.analysis.staticcheck.expected_hop_perms`` mirrors it
    numpy-only so the auditor can verify lowered jaxpr/HLO against the
    schedule without importing this (jax-importing) module.
    """
    s = spec.num_stages
    if s <= 1:
        return (), ()
    if spec.virtual_stages > 1:
        fwd = tuple((i, (i + 1) % s) for i in range(s))
    else:
        fwd = tuple((i, i + 1) for i in range(s - 1))
    return fwd, tuple((dst, src) for src, dst in fwd)


def _check_mesh(mesh, spec: PipelineSpec):
    if spec.axis not in mesh.shape:
        raise ValueError(
            f"pipeline axis {spec.axis!r} not in mesh axes "
            f"{tuple(mesh.shape)} — build the mesh with a "
            f"{spec.axis!r} axis (launch/mesh.py)")
    if mesh.shape[spec.axis] != spec.num_stages:
        raise ValueError(
            f"num_stages={spec.num_stages} must equal the {spec.axis!r} "
            f"mesh axis size {mesh.shape[spec.axis]} (one stage per "
            f"{spec.axis} shard)")


def wire_ef_ticks(spec: PipelineSpec) -> int:
    """Tick count of one batch's schedule — the EF buffer's slot axis."""
    return _sigma(spec.microbatches - 1, spec.num_stages,
                  spec.virtual_stages) + spec.num_stages * spec.virtual_stages


def wire_ef_zeros(cfg, spec: PipelineSpec, batch: int, seq: int):
    """Zero-initialized error-feedback buffer for a top-k wire codec:
    f32 [S, ticks, mb, seq_total, d_model], one residual slot per
    (stage, tick) of the static schedule.  ``batch`` / ``seq`` are the
    RAW batch dims — padding (ragged k) and the vlm patch prefix are
    accounted for here exactly as ``make_pipelined_loss`` shapes the
    micro-batches.  Returns None when the codec carries no top-k (or
    S=1, where there is no hop)."""
    if spec.num_stages <= 1 or not wire.has_topk(spec.wire_dtype):
        return None
    k = spec.microbatches
    mb = (batch + (-batch) % k) // k
    seq_total = seq + (cfg.num_patches if cfg.family == "vlm" else 0)
    return jnp.zeros((spec.num_stages, wire_ef_ticks(spec), mb, seq_total,
                      cfg.d_model), jnp.float32)


def pipeline_blocks(cfg, blocks, xs, positions, spec: PipelineSpec, *,
                    mesh, prefix_len: int = 0, enc_outs=None, wire_ef=None):
    """Run the stacked homogeneous block stack as a pipeline.

    blocks: stacked params, leaves [L, ...]
    xs:     [k, mb, seq, d] micro-batched activations (embedded)
    enc_outs: optional [k, mb, enc_seq, d] (whisper cross-attention memory)
    wire_ef: [S, ticks, mb, seq, d] f32 error-feedback buffer, REQUIRED
             for top-k wire codecs at S > 1 (see ``wire_ef_zeros``); its
             gradient is the updated buffer.
    Returns (hidden [k, mb, seq, d], aux_loss scalar).

    The aux loss is the per-layer sum averaged over the k micro-batches —
    the same normalization as the plain (full-batch) forward, up to the
    documented per-micro-batch router-statistics deviation (DESIGN.md §6).
    """
    _check_mesh(mesh, spec)
    if spec.virtual_stages < 1:
        raise ValueError(
            f"virtual_stages={spec.virtual_stages} must be >= 1")
    wire.validate_wire_dtype(spec.wire_dtype)
    k = xs.shape[0]
    needs_ef = spec.num_stages > 1 and wire.has_topk(spec.wire_dtype)
    if needs_ef:
        if wire_ef is None:
            raise ValueError(
                f"wire_dtype {spec.wire_dtype!r} sparsifies the gradient "
                "hop with error feedback — build the EF buffer with "
                "pipeline.wire_ef_zeros and thread it through the loss "
                "(make_pipelined_loss / make_lm_train_step do this)")
        want = (spec.num_stages, wire_ef_ticks(spec)) + xs.shape[1:]
        if tuple(wire_ef.shape) != want:
            raise ValueError(
                f"wire_ef shape {tuple(wire_ef.shape)} != expected {want} "
                "([S, ticks, mb, seq, d] — rebuild with wire_ef_zeros "
                "after changing the spec or batch shape)")
    else:
        wire_ef = None
    staged = _split_stages(blocks, spec.num_stages, spec.virtual_stages)
    run = (_pipeline_partial_manual if compat.CAPS.partial_manual
           else _pipeline_full_manual)
    outs, auxes = run(cfg, staged, xs, positions, spec, mesh,
                      prefix_len, enc_outs, wire_ef)
    # last stage's real outputs; aux summed over stages (each owns its own
    # layers' aux), averaged over micro-batches
    return outs[-1], auxes.sum() / k


def _stage_scan_fn(cfg, spec, positions, prefix_len):
    """One stage's block scan on one micro-batch (shared by both paths)."""
    kind = cfg.layer_kinds[0]

    def stage_scan(blocks_local, x, enc_out, pin):
        def body(carry, layer_params):
            y, aux = apply_block(layer_params, carry, cfg, kind,
                                 positions=positions, prefix_len=prefix_len,
                                 enc_out=enc_out,
                                 use_rope=(kind != "rwkv"))
            return pin(y), aux
        y, auxes = jax.lax.scan(jax.checkpoint(body), pin(x), blocks_local)
        return y, auxes.sum()

    return stage_scan


def _tick_loop(spec, stage, k, xs_full, enc_full, state0, aux0, run_stage,
               wire_ef=None):
    """The (interleaved) 1F1B tick schedule shared by both shard_map
    flavours.

    ``wire_ef`` (top-k codecs only) is this stage's error-feedback buffer
    [ticks, mb, seq, d] f32, entering the scan as per-tick xs so each
    hop's custom_vjp sees exactly its (stage, tick) slot; the scan's
    transpose reassembles the updated buffer as the gradient w.r.t. this
    input (parallel/wire.py::coded_ppermute_ef).

    At tick t stage s inverts the interleaved timetable: with
    ``t' = t - s``, ``p = t' mod S``, ``q = (t' - p) / S``, the live
    work item is micro-batch ``m = (q // v)*S + p`` on virtual chunk
    ``j = q mod v`` (global chunk ``j*S + s``), executing at its scheduled
    tick ``sigma(m) + j*S + s``.  Idle ticks (warm-up/drain, ragged k)
    compute on clipped indices and are masked by ``live`` — masked values
    are never consumed by a live tick because a live chunk's producer
    chunk was itself live one tick earlier.  Outputs move one stage
    forward via ``ppermute``; with v > 1 the chunk chain wraps from stage
    S-1 back to stage 0, so the permutation is cyclic.  Works for any
    S >= 1, v >= 1 and k >= 1 — ``pipeline_k_auto``-chosen k needs no
    divisibility with the stage count.
    """
    s_stages = spec.num_stages
    v = spec.virtual_stages
    ticks = _sigma(k - 1, s_stages, v) + s_stages * v
    fwd_perm, _ = hop_perms(spec)
    coded = spec.wire_dtype not in (None, "none")
    base_wire = spec.wire_dtype
    if coded:
        base_wire, _frac = wire.parse_wire_dtype(spec.wire_dtype)
        if _frac is None:
            wire_ef = None      # dense codec: no EF state to thread

    def hop(y, perm, ef_t):
        """One inter-stage hop: the raw ppermute (bit-identical to the
        uncoded pipeline), or the quantized wire round trip whose
        custom_vjp codes the transposed backward hop the same way —
        top-k + error feedback on that backward hop when ``ef_t`` rides
        along."""
        if not coded:
            return jax.lax.ppermute(y, spec.axis, perm)
        if ef_t is not None:
            return wire.coded_ppermute_ef(spec.wire_dtype, spec.axis,
                                          perm, y, ef_t)
        return wire.coded_ppermute(base_wire, spec.axis, perm, y)

    def tick(carry, xt):
        state, aux_acc = carry
        t, ef_t = xt if wire_ef is not None else (xt, None)
        tpr = t - stage
        p = jnp.mod(tpr, s_stages)
        q = (tpr - p) // s_stages
        j = jnp.mod(q, v)                      # this stage's virtual chunk
        m = (q // v) * s_stages + p            # this stage's micro-batch
        live = (tpr >= 0) & (m >= 0) & (m < k)
        m_idx = jnp.clip(m, 0, k - 1)
        j_idx = jnp.clip(j, 0, v - 1)
        inp0 = jax.lax.dynamic_index_in_dim(xs_full, m_idx, 0,
                                            keepdims=False)
        # only global chunk 0 (stage 0, virtual chunk 0) takes fresh
        # micro-batch input; every other chunk consumes the carried state
        cur = jnp.where((stage == 0) & (j_idx == 0), inp0, state)
        enc = None
        if enc_full is not None:
            enc = jax.lax.dynamic_index_in_dim(enc_full, m_idx, 0,
                                               keepdims=False)
        y, aux = run_stage(cur, enc, j_idx)
        if s_stages == 1:
            nxt = y                            # chunk chain stays local
        else:
            nxt = hop(y, fwd_perm, ef_t)
        aux_acc = aux_acc + jnp.where(live, aux, 0.0)
        return (nxt, aux_acc), y

    xs_scan = jnp.arange(ticks) if wire_ef is None \
        else (jnp.arange(ticks), wire_ef)
    (_, aux_acc), ys = jax.lax.scan(tick, (state0, aux0), xs_scan)
    # micro-batch m leaves the last chunk (on stage S-1) at tick
    # sigma(m) + S*v - 1; for v == 1 these are the contiguous ticks
    # [S-1, S-1+k) of the plain schedule
    out_ticks = jnp.asarray(
        [_sigma(m, s_stages, v) + s_stages * v - 1 for m in range(k)])
    out = jnp.take(ys, out_ticks, axis=0)
    return out, aux_acc


def _chunk_picker(blocks_local, virtual_stages: int):
    """``j -> one chunk's layer stack`` from [v, L/(S*v), ...] leaves.

    v == 1 resolves the (sole) chunk statically; v > 1 gathers the traced
    chunk index per tick — its autodiff transpose scatter-adds each
    tick's weight gradient into the right chunk.
    """
    if virtual_stages == 1:
        chunk0 = jax.tree.map(lambda a: a[0], blocks_local)
        return lambda j: chunk0
    return lambda j: jax.tree.map(
        lambda a: jax.lax.dynamic_index_in_dim(a, j, 0, keepdims=False),
        blocks_local)


def _pipeline_partial_manual(cfg, staged, xs, positions, spec, mesh,
                             prefix_len, enc_outs, wire_ef=None):
    """Explicit-sharding JAX: Manual over 'pod' only, data/model auto."""
    k = xs.shape[0]
    # micro-batch over data; seq deliberately NOT model-sharded inside the
    # stage: per-micro-batch SP re-gathers the stage weights and re-reduces
    # weight grads k times (refuted, EXPERIMENTS.md §Perf pipeline it2) —
    # without SP, GSPMD defers the weight-grad reduction across ticks.
    data_sharding = compat.auto_axes_sharding(mesh, spec.axis, P("data"))

    def pin(x):
        """Anchor the micro-batch dim to the data axis INSIDE the manual-
        over-pod region — without this GSPMD replicates the micro-batch
        across the data axis (EXPERIMENTS.md §Perf, pipeline iteration 1)."""
        return jax.lax.with_sharding_constraint(x, data_sharding)

    stage_scan = _stage_scan_fn(cfg, spec, positions, prefix_len)

    def per_stage(blocks_stage, xs_full, enc_full, ef_full):
        # manual over 'pod': blocks_stage leaves [1, v, L/(S*v), ...]
        blocks_local = jax.tree.map(lambda a: a[0], blocks_stage)
        pick = _chunk_picker(blocks_local, spec.virtual_stages)
        stage = jax.lax.axis_index(spec.axis)
        # carries differ per stage -> mark them varying over the pod axis
        state = compat.mark_varying(
            jnp.zeros(xs_full.shape[1:], xs_full.dtype), (spec.axis,))
        aux0 = compat.mark_varying(jnp.float32(0.0), (spec.axis,))
        ef_local = None
        if ef_full is not None:
            # this stage's [ticks, mb, seq, d] slice; anchor the
            # micro-batch dim to the data axis like every other carry
            ef_local = jax.lax.with_sharding_constraint(
                ef_full[0],
                compat.auto_axes_sharding(mesh, spec.axis, P(None, "data")))
        out, aux_acc = _tick_loop(
            spec, stage, k, xs_full, enc_full, state, aux0,
            lambda cur, enc, j: stage_scan(pick(j), cur, enc, pin),
            wire_ef=ef_local)
        # stack a stage axis so out_specs=P('pod') can concatenate
        return out[None], aux_acc[None]

    args = [staged, xs]
    in_specs = [P(spec.axis), P()]
    if enc_outs is not None:
        args.append(enc_outs)
        in_specs.append(P())
    if wire_ef is not None:
        args.append(wire_ef)
        in_specs.append(P(spec.axis))

    def body(*a):
        i = 2
        enc_full = ef_full = None
        if enc_outs is not None:
            enc_full = a[i]
            i += 1
        if wire_ef is not None:
            ef_full = a[i]
        return per_stage(a[0], a[1], enc_full, ef_full)

    fn = compat.shard_map(
        body, mesh,
        in_specs=tuple(in_specs),
        out_specs=(P(spec.axis), P(spec.axis)),
        manual_axes={spec.axis}, check=True)
    return fn(*args)


def _pipeline_full_manual(cfg, staged, xs, positions, spec, mesh,
                          prefix_len, enc_outs, wire_ef=None):
    """Legacy JAX: fully-manual region (partial-manual aborts in the 0.4.x
    SPMD partitioner).

    The micro-batch dim is explicitly sharded over 'data' when divisible
    (each data shard runs the same pipeline on its slice; weight grads are
    psum'ed by the shard_map transpose); otherwise — and over the 'model'
    axis always — compute inside stages is replicated.  The stage index
    arrives as a pod-sharded ``arange`` input because ``axis_index``
    lowers to an SPMD-unsupported partition-id on this generation.
    """
    k, mb = xs.shape[0], xs.shape[1]
    other_axes = tuple(n for n in mesh.axis_names if n != spec.axis)
    n_data = mesh.shape.get("data", 1)
    data_axis = "data" if ("data" in mesh.shape and n_data > 1
                           and mb % n_data == 0) else None
    mb_spec = P(None, data_axis)   # [k, mb, ...] leaves

    stage_scan = _stage_scan_fn(cfg, spec, positions, prefix_len)

    def per_stage(stage_ids, blocks_stage, xs_full, pos, enc_full,
                  ef_full):
        del pos  # replicated copy of ``positions`` (kept as an explicit
        # argument: legacy shard_map cannot close over traced values)
        blocks_local = jax.tree.map(lambda a: a[0], blocks_stage)
        pick = _chunk_picker(blocks_local, spec.virtual_stages)
        stage = stage_ids[0]
        state = jnp.zeros(xs_full.shape[1:], xs_full.dtype)
        aux0 = jnp.float32(0.0)
        ef_local = None if ef_full is None else ef_full[0]
        out, aux_acc = _tick_loop(
            spec, stage, k, xs_full, enc_full, state, aux0,
            lambda cur, enc, j: stage_scan(pick(j), cur, enc,
                                           lambda y: y),
            wire_ef=ef_local)
        if other_axes:
            # per-data-slice aux -> batch mean (replicated axes unchanged)
            aux_acc = jax.lax.pmean(aux_acc, other_axes)
        return out[None], aux_acc[None]

    stage_ids = jnp.arange(spec.num_stages, dtype=jnp.int32)
    args = [stage_ids, staged, xs, positions]
    in_specs = [P(spec.axis), P(spec.axis), mb_spec, P()]
    if enc_outs is not None:
        args.append(enc_outs)
        in_specs.append(mb_spec)
    if wire_ef is not None:
        # [S, ticks, mb, seq, d]: stage dim manual over pod, micro-batch
        # dim sharded over data exactly like the xs micro-batches
        args.append(wire_ef)
        in_specs.append(P(spec.axis, None, data_axis))

    def body(*a):
        i = 4
        enc_full = ef_full = None
        if enc_outs is not None:
            enc_full = a[i]
            i += 1
        if wire_ef is not None:
            ef_full = a[i]
        return per_stage(a[0], a[1], a[2], a[3], enc_full, ef_full)

    fn = compat.shard_map(
        body, mesh,
        in_specs=tuple(in_specs),
        out_specs=(P(spec.axis, None, data_axis), P(spec.axis)),
        check=False)
    return fn(*args)


def make_pipelined_loss(model, spec: PipelineSpec, mesh=None):
    """loss_fn(params, batch) with the block stack pipelined over pods.

    Requires a homogeneous (scan-stacked) architecture; the heterogeneous
    recurrentgemma pattern keeps the pod-as-DP path (DESIGN.md §7).

    Batches whose size is not divisible by k are padded with zero-
    embedding rows up to ``k * ceil(b / k)`` so ``pipeline_k_auto``-chosen
    k never needs batch-divisibility; pad rows are sliced off before the
    loss, so the xent is exactly the unpadded batch's for per-row
    architectures.  Caveat: MoE layers see the pad tokens (they shift the
    aux statistics and occupy shared capacity slots), one more facet of
    the documented per-micro-batch router deviation (DESIGN.md §6).
    """
    cfg = model.cfg
    assert cfg.homogeneous, (
        "pipeline mode needs a homogeneous layer stack; "
        f"{cfg.name} has a mixed pattern — use pod-as-data-parallel")
    k = spec.microbatches
    assert k >= 1, f"microbatches k={k} must be >= 1"

    def _loss(params, batch, wire_ef):
        # Plain-JAX context inside: data/model axes are GSPMD-auto (or
        # replicated on legacy JAX), the pipeline shard_map owns 'pod'.
        from repro.parallel.context import get_ctx
        use_mesh = mesh if mesh is not None else get_ctx().mesh
        with use_ctx(ParallelCtx()):
            dt = jnp.dtype(cfg.dtype)
            tokens = batch["tokens"]
            labels = batch["labels"]
            prefix_len = 0
            enc_flat = None

            x = model._embed(params, tokens, dt)
            if cfg.family == "vlm":
                patches = batch["patch_embeds"].astype(dt)
                x = jnp.concatenate([patches, x], axis=1)
                prefix_len = patches.shape[1]
                pad = jnp.full(patches.shape[:2], -1, labels.dtype)
                labels = jnp.concatenate([pad, labels], axis=1)
            if cfg.family == "audio":
                enc_flat = model._encode(params, batch["frames"].astype(dt))

            b, seq = x.shape[0], x.shape[1]
            pad_rows = (-b) % k
            if pad_rows:
                x = jnp.concatenate(
                    [x, jnp.zeros((pad_rows,) + x.shape[1:], x.dtype)])
                if enc_flat is not None:
                    enc_flat = jnp.concatenate(
                        [enc_flat, jnp.zeros((pad_rows,) + enc_flat.shape[1:],
                                             enc_flat.dtype)])
            mb = (b + pad_rows) // k
            xs = x.reshape(k, mb, seq, x.shape[-1])
            enc_outs = None
            if enc_flat is not None:
                enc_outs = enc_flat.reshape(k, mb, enc_flat.shape[1],
                                            enc_flat.shape[2])
            positions = jnp.arange(seq)

            out, aux = pipeline_blocks(cfg, params["blocks"], xs, positions,
                                       spec, mesh=use_mesh,
                                       prefix_len=prefix_len,
                                       enc_outs=enc_outs, wire_ef=wire_ef)
            h = out.reshape(b + pad_rows, seq, x.shape[-1])[:b]
            h = apply_norm(h, params["final_norm"], cfg.norm)
            loss = model.xent(params, h, labels)
            total = loss + 0.01 * aux
            return total, {"xent": loss, "aux": aux}

    needs_ef = spec.num_stages > 1 and wire.has_topk(spec.wire_dtype)
    if needs_ef:
        # 3-arg loss: the EF buffer is an input whose GRADIENT is the
        # updated buffer (the hops' custom_vjp emits the new residuals as
        # the cotangent) — the train step extracts it with
        # value_and_grad(argnums=(0, 2)) and writes it back to state.
        def loss_fn(params, batch, wire_ef):
            return _loss(params, batch, wire_ef)
    else:
        def loss_fn(params, batch):
            return _loss(params, batch, None)
    loss_fn.needs_wire_ef = needs_ef
    return loss_fn
