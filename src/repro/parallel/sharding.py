"""Greedy-divisible sharding policy (DESIGN.md §7).

Parameters (and mirrored optimizer state) are sharded ZeRO-3-style: for each
tensor, mesh axes are greedily assigned to the largest array dims they
divide, preferring the trailing (output-feature) dim for the ``model`` axis
and any remaining large dim for ``data``/``pod``.  Nothing is ever padded by
the policy — a dim that no axis divides is simply replicated (this is what
makes granite's 40 experts and qwen's 20 heads work on a 16-wide axis
without config surgery).

Activations / batches / KV caches use explicit rules, not the greedy rule:
  * token batches:  batch dim over (pod, data)
  * hidden states:  [B, S, D] — batch over (pod, data); model axis unused
    (attention/MLP internals are sharded through the weights)
  * KV caches:      [B, S, H, dh] — batch over data, sequence over model
    (sequence-parallel attention in the decode regime)
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

# sharding types come from the compat choke point (parallel/compat.py):
# the policy itself is spec math and works with concrete and abstract
# meshes on every supported JAX.
from repro.parallel.compat import Mesh, NamedSharding, PartitionSpec as P


def _greedy_spec(shape, axis_sizes: dict, axis_order, prefer_trailing) -> P:
    """Assign each mesh axis to the best unassigned divisible dim."""
    assign = [None] * len(shape)
    for axis in axis_order:
        size = axis_sizes[axis]
        if size <= 1:
            continue
        best = None
        # candidate dims, preference order
        idxs = range(len(shape) - 1, -1, -1) if prefer_trailing[axis] \
            else sorted(range(len(shape)), key=lambda i: -shape[i])
        for i in idxs:
            if assign[i] is None and shape[i] % size == 0 and shape[i] >= size:
                best = i
                break
        if best is not None:
            assign[best] = axis
    return P(*assign)


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """Produces NamedShardings for a given mesh."""

    mesh: Mesh
    shard_params_over_pod: bool = True   # ZeRO across pods too
    pod_is_pipeline: bool = False        # C2P2SL mode: pod = stage axis
    pure_dp: bool = False                # attention-free regime: both mesh
                                         # axes act as data parallelism with
                                         # ZeRO-3 params (no TP collectives;
                                         # EXPERIMENTS.md §Perf rwkv it3)

    @property
    def axes(self) -> dict:
        return dict(self.mesh.shape)

    @property
    def has_pod(self) -> bool:
        return ("pod" in self.mesh.shape and self.mesh.shape["pod"] > 1
                and not self.pod_is_pipeline)

    @property
    def batch_axes(self) -> tuple:
        if self.pure_dp:
            return (("pod", "data", "model") if self.has_pod
                    else ("data", "model"))
        return (("pod", "data") if self.has_pod else ("data",))

    # ---------------- params ----------------

    # down-projections: the SECOND matmul of each Megatron pair.  model
    # must sit on their CONTRACTION dim (d_ff / heads) to pair with the
    # up-projection's column-parallel output — otherwise GSPMD all-gathers
    # the full [B, S, d_ff] activation over the model axis every layer
    # (EXPERIMENTS.md §Perf, rwkv iteration 2).
    DOWN_PROJ = ("w2", "o", "out", "w_v", "w_o")

    def param_spec(self, shape, name: str = "") -> P:
        if len(shape) == 0:
            return P()
        n_model = self.axes.get("model", 1)
        n_data = self.axes.get("data", 1)
        if self.pure_dp:
            # ZeRO-3 over the flattened (data x model) axes: one combined
            # shard dim per tensor, largest divisible dim wins.
            combo = self.batch_axes
            n_combo = int(np.prod([self.axes[a] for a in combo]))
            for axes, k in ((combo, n_combo), (("data",), n_data),
                            (("model",), n_model)):
                cands = sorted(range(len(shape)), key=lambda i: -shape[i])
                for i in cands:
                    if len(shape) >= 3 and i == 0:
                        continue          # keep the stacked layer dim whole
                    if shape[i] % k == 0 and shape[i] >= k:
                        spec = [None] * len(shape)
                        spec[i] = axes if len(axes) > 1 else axes[0]
                        return P(*spec)
            return P(*([None] * len(shape)))
        if name in self.DOWN_PROJ and len(shape) >= 2:
            c_dim = len(shape) - 2            # contraction dim (row-parallel)
            o_dim = len(shape) - 1
            spec = [None] * len(shape)
            if shape[c_dim] % n_model == 0 and shape[c_dim] >= n_model:
                spec[c_dim] = "model"
                if shape[o_dim] % n_data == 0 and shape[o_dim] >= n_data:
                    spec[o_dim] = "data"
                return P(*spec)
            # fall through to the greedy rule when indivisible
        if name in ("embed", "head") and len(shape) == 2:
            # Vocab-parallel embedding/head: the [B,S,V] logits tensor must
            # be model-sharded or the xent chunk is vocab-replicated (the
            # 188 GiB/device pathology — EXPERIMENTS.md §Perf iteration 0).
            v_dim = 0 if shape[0] > shape[1] else 1
            d_dim = 1 - v_dim
            spec = [None, None]
            if shape[v_dim] % n_model == 0:
                spec[v_dim] = "model"
                if shape[d_dim] % self.axes.get("data", 1) == 0:
                    spec[d_dim] = "data"
            else:                      # indivisible vocab (granite 49155)
                if shape[d_dim] % n_model == 0:
                    spec[d_dim] = "model"
            return P(*spec)
        order = ["model", "data"]
        if self.has_pod and self.shard_params_over_pod:
            order.append("pod")
        prefer_trailing = {"model": True, "data": False, "pod": False}
        return _greedy_spec(tuple(shape), self.axes, order, prefer_trailing)

    def _path_spec(self, path, shape) -> P:
        name = ""
        in_blocks = False
        for p in path:
            if hasattr(p, "key"):
                k = str(p.key)
                if k in ("blocks", "enc_blocks"):
                    in_blocks = True
                if k not in ("m", "v", "mom"):
                    name = k
        if len(shape) == 0:
            return P()
        if self.pod_is_pipeline and in_blocks and len(shape) >= 1:
            # C2P2SL: the stacked layer dim IS the stage split — shard it
            # over 'pod' so each pod holds its own stage's layers.
            rest = self.param_spec(shape[1:], name)
            return P("pod", *tuple(rest))
        return self.param_spec(shape, name)

    def param_shardings(self, param_tree):
        """Pytree of ShapeDtypeStructs/arrays -> pytree of NamedSharding."""
        return jax.tree_util.tree_map_with_path(
            lambda path, x: NamedSharding(self.mesh,
                                          self._path_spec(path, x.shape)),
            param_tree)

    # ---------------- activations / data ----------------

    def batch_spec(self, shape) -> P:
        """Token / label / frontend batches: leading dim over (pod, data),
        falling back to data-only / replicated when not divisible
        (long_500k has global_batch=1)."""
        ndim = len(shape)
        if ndim == 0:
            return P()
        n_all = int(np.prod([self.axes[a] for a in self.batch_axes]))
        if shape[0] % n_all == 0 and shape[0] >= n_all:
            return P(self.batch_axes, *([None] * (ndim - 1)))
        n_data = self.axes.get("data", 1)
        if shape[0] % n_data == 0 and shape[0] >= n_data:
            return P("data", *([None] * (ndim - 1)))
        return P(*([None] * ndim))

    def batch_shardings(self, batch_tree):
        return jax.tree.map(
            lambda x: NamedSharding(self.mesh, self.batch_spec(x.shape)),
            batch_tree)

    # ---------------- decode caches ----------------

    def cache_spec(self, shape, batch: int | None = None) -> P:
        """Decode-state sharding.

        Leaves are shaped [B, ...] or layer-stacked [L, B, ...]; the batch
        dim is located by value (``batch``) within the two leading dims and
        sharded over (pod,) data; the widest remaining divisible dim —
        the sequence dim for KV caches — goes to ``model``
        (sequence-parallel attention in the decode regime).
        """
        if len(shape) == 0:
            return P()
        assign = [None] * len(shape)
        n_batch = int(np.prod([self.axes[a] for a in self.batch_axes]))
        b_dim = None
        for i in range(min(2, len(shape))):
            if batch is not None and shape[i] != batch:
                continue
            if shape[i] % n_batch == 0 and shape[i] >= n_batch:
                assign[i] = self.batch_axes
                b_dim = i
                break
            if shape[i] % self.axes.get("data", 1) == 0 \
                    and shape[i] >= self.axes.get("data", 1):
                assign[i] = "data"
                b_dim = i
                break
        n_model = self.axes.get("model", 1)
        cands = sorted(range(len(shape)), key=lambda i: -shape[i])
        for i in cands:
            if i == b_dim or assign[i] is not None:
                continue
            if shape[i] % n_model == 0 and shape[i] >= n_model:
                assign[i] = "model"
                break
        return P(*assign)

    def cache_shardings(self, cache_tree, batch: int | None = None):
        return jax.tree.map(
            lambda x: NamedSharding(self.mesh,
                                    self.cache_spec(x.shape, batch)),
            cache_tree)

    # ---------------- state assembly ----------------

    def train_state_shardings(self, state_tree):
        """{'params':…, 'opt_state':…, 'step':…} — opt state mirrors params."""
        return jax.tree_util.tree_map_with_path(
            lambda path, x: NamedSharding(self.mesh,
                                          self._path_spec(path, x.shape)),
            state_tree)


# ---------------- feasibility (the paper's C2, datacenter form) ----------


def bytes_per_device(tree, policy: ShardingPolicy, spec_fn=None) -> int:
    """Max per-device bytes of a pytree under the policy (storage bound)."""
    spec_fn = spec_fn or policy.param_spec
    total = 0
    for leaf in jax.tree.leaves(tree):
        shape = tuple(leaf.shape)
        spec = spec_fn(shape)
        shard = 1
        for dim, ax in zip(shape, tuple(spec) + (None,) * len(shape)):
            if ax is None:
                shard *= dim
            else:
                axes = ax if isinstance(ax, tuple) else (ax,)
                k = int(np.prod([policy.axes[a] for a in axes]))
                shard *= dim // k
        total += shard * jax.numpy.dtype(leaf.dtype).itemsize
    return total


HBM_PER_CHIP = 16 * 1024 ** 3          # TPU v5e: 16 GiB


def hbm_feasible(tree, policy: ShardingPolicy, budget: float = 0.9) -> bool:
    """C2 on TPU: sharded state must fit per-device HBM (DESIGN.md §3)."""
    return bytes_per_device(tree, policy) <= budget * HBM_PER_CHIP
