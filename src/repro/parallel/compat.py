"""Version-portable parallel runtime primitives — the single choke point.

Every JAX-version probe in this repo lives HERE.  Model / launch / analysis
code never does ``hasattr(jax, ...)``; it imports from this module and either
gets the new-API behaviour, a semantically-equivalent fallback, or a
``CompatError`` naming the missing capability.

Supported range (see docs/compat.md):

  * **legacy** — jax 0.4.3x: ``shard_map`` lives in ``jax.experimental``,
    meshes have no axis types, ``Compiled.cost_analysis()`` returns a *list*
    of per-program dicts, and there is no ``pcast``/``set_mesh``.  Crucially,
    Manual-over-a-subset-of-axes shard_map (``auto=...``) aborts inside the
    XLA SPMD partitioner on this generation, so the pipeline runs its
    fully-manual path (see ``parallel/pipeline.py``).
  * **explicit-sharding** — jax >= 0.6/0.7: top-level ``jax.shard_map`` with
    ``axis_names``/``check_vma``, ``jax.sharding.AxisType`` meshes,
    ``jax.set_mesh``, ``jax.lax.pcast`` varying marking, dict-valued
    ``cost_analysis()``.

The probe is attribute-based and runs once at import; nothing here touches
device state (the dry-run sets XLA_FLAGS before any jax init).
"""
from __future__ import annotations

import dataclasses

import jax
# Re-exported so parallel/launch modules have one import site for sharding
# types (keeps the version boundary in this file).
from jax.sharding import Mesh, NamedSharding, PartitionSpec  # noqa: F401


class CompatError(NotImplementedError):
    """A genuinely unsupported path on the installed JAX."""


@dataclasses.dataclass(frozen=True)
class Capabilities:
    """What the installed JAX can do (probed once at import)."""

    jax_version: tuple
    axis_types: bool          # jax.sharding.AxisType / make_mesh(axis_types=)
    toplevel_shard_map: bool  # jax.shard_map (vs jax.experimental.shard_map)
    set_mesh: bool            # jax.set_mesh context manager
    pcast: bool               # jax.lax.pcast varying-axis marking
    partial_manual: bool      # shard_map Manual over a SUBSET of mesh axes
                              # with GSPMD-auto on the rest


def _version_tuple(v: str) -> tuple:
    parts = []
    for p in v.split("."):
        digits = "".join(ch for ch in p if ch.isdigit())
        if not digits:
            break
        parts.append(int(digits))
    return tuple(parts)


def _probe() -> Capabilities:
    axis_types = hasattr(jax.sharding, "AxisType")
    toplevel = hasattr(jax, "shard_map")
    set_mesh = hasattr(jax, "set_mesh")
    pcast = hasattr(jax.lax, "pcast")
    # Partial-manual needs the whole explicit-sharding stack: the legacy
    # shard_map has an ``auto=`` escape hatch, but on the 0.4.x partitioner
    # it hard-aborts (Check failed: sharding.IsManualSubgroup()), so we gate
    # on the API generation rather than the keyword's existence.
    partial_manual = toplevel and axis_types and pcast
    return Capabilities(
        jax_version=_version_tuple(jax.__version__),
        axis_types=axis_types,
        toplevel_shard_map=toplevel,
        set_mesh=set_mesh,
        pcast=pcast,
        partial_manual=partial_manual,
    )


CAPS = _probe()


def require(flag: bool, feature: str, hint: str = "") -> None:
    if not flag:
        msg = (f"{feature} is not supported on installed jax "
               f"{jax.__version__}")
        if hint:
            msg += f" — {hint}"
        raise CompatError(msg)


# ---------------- mesh construction / entry ----------------


def auto_axis_types(n: int):
    """axis_types tuple for an all-Auto mesh, or None when meshes are
    untyped on this JAX."""
    if CAPS.axis_types:
        return (jax.sharding.AxisType.Auto,) * n
    return None


def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    """``jax.make_mesh`` that tolerates untyped (legacy) meshes.

    ``axis_types`` defaults to all-Auto on JAX that has axis types and is
    ignored (with no semantic change: untyped meshes are GSPMD-auto) on
    legacy JAX.
    """
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if CAPS.axis_types:
        if axis_types is None:
            axis_types = auto_axis_types(len(tuple(axis_shapes)))
        kwargs["axis_types"] = axis_types
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def abstract_mesh(axis_shapes, axis_names, *, axis_types=None):
    """Device-free mesh for spec/feasibility math.

    New JAX: ``AbstractMesh(shapes, names[, axis_types])``.  Legacy JAX
    takes a single tuple of ``(name, size)`` pairs and has no axis types.
    """
    shapes = tuple(axis_shapes)
    names = tuple(axis_names)
    if CAPS.axis_types:
        if axis_types is None:
            axis_types = auto_axis_types(len(shapes))
        return jax.sharding.AbstractMesh(shapes, names,
                                         axis_types=axis_types)
    return jax.sharding.AbstractMesh(tuple(zip(names, shapes)))


def mesh_context(mesh):
    """Enter a mesh context: ``jax.set_mesh`` on new JAX, the ``Mesh``
    context manager on legacy JAX (both make the mesh ambient for
    spec-only sharding annotations)."""
    if CAPS.set_mesh:
        return jax.set_mesh(mesh)
    return mesh  # legacy Mesh is itself a context manager


# ---------------- shard_map ----------------


def shard_map(f, mesh, in_specs, out_specs, *, manual_axes=None,
              check: bool = False):
    """Portable ``shard_map``.

    ``manual_axes``: iterable of mesh axis names to run Manual over; None
    means all axes (the classic fully-manual region).  Partial-manual
    (a strict subset) is only available on explicit-sharding JAX — callers
    must branch on ``CAPS.partial_manual`` and restructure to fully-manual
    on legacy JAX (see ``parallel/pipeline.py`` for the pattern).

    ``check``: replication/varying checking (``check_vma`` on new JAX).
    Legacy shard_map always runs with ``check_rep=False`` because the code
    written against this wrapper cannot mark varying axes (no ``pcast``).
    """
    if CAPS.toplevel_shard_map:
        kwargs = {"check_vma": check}
        if manual_axes is not None:
            kwargs["axis_names"] = set(manual_axes)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)
    if manual_axes is not None and set(manual_axes) != set(mesh.axis_names):
        raise CompatError(
            f"partial-manual shard_map over {sorted(manual_axes)} (mesh axes "
            f"{sorted(mesh.axis_names)}) is unsupported on installed jax "
            f"{jax.__version__}; restructure to a fully-manual region "
            "(branch on compat.CAPS.partial_manual)")
    from jax.experimental.shard_map import shard_map as _legacy_shard_map
    return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=False)


# ---------------- varying-axis marking ----------------


def mark_varying(x, axes):
    """Mark ``x`` as varying over manual ``axes`` (new-JAX ``pcast``).

    Legacy shard_map runs with replication checking off, where every value
    is implicitly per-device — marking is a no-op there.
    """
    if CAPS.pcast:
        return jax.lax.pcast(x, tuple(axes), to="varying")
    return x


def match_vma(val, ref):
    """Give ``val`` (e.g. a freshly-created scan carry) the same
    varying-manual-axes as ``ref`` — required inside partial-manual
    shard_map regions where zero-initialized carries are otherwise
    'unvarying' and scan rejects the carry-type mismatch.  No-op on
    legacy JAX (no varying types)."""
    if not CAPS.pcast:
        return val
    try:
        want = set(jax.typeof(ref).vma)
        have = set(jax.typeof(val).vma)
        missing = tuple(sorted(want - have))
        if missing:
            return jax.lax.pcast(val, missing, to="varying")
    except (AttributeError, TypeError, ValueError):
        pass
    return val


def auto_axes_sharding(mesh, manual_axes, spec):
    """A NamedSharding usable for ``with_sharding_constraint`` INSIDE a
    partial-manual region: the mesh view has ``manual_axes`` Manual and
    everything else Auto.  Only meaningful (and only constructible) on
    explicit-sharding JAX."""
    require(CAPS.partial_manual, "constraints inside a partial-manual region",
            "legacy pipelines shard explicitly instead (see pipeline.py)")
    manual = set(manual_axes) if not isinstance(manual_axes, str) \
        else {manual_axes}
    AxisType = jax.sharding.AxisType
    abs_mesh = mesh.abstract_mesh.update(axis_types=tuple(
        AxisType.Manual if n in manual else AxisType.Auto
        for n in mesh.shape))
    return NamedSharding(abs_mesh, spec)


# ---------------- compiled-executable introspection ----------------


def cost_analysis_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` normalized to one flat dict.

    Legacy JAX returns a *list* of per-program dicts (usually length 1);
    new JAX returns the dict directly.  Numeric entries are summed across
    list elements; missing/unavailable analysis yields ``{}``.
    """
    try:
        ca = compiled.cost_analysis()
    except Exception:  # backend without cost analysis
        return {}
    if ca is None:
        return {}
    if isinstance(ca, dict):
        return dict(ca)
    out: dict = {}
    if isinstance(ca, (list, tuple)):
        for entry in ca:
            if not isinstance(entry, dict):
                continue
            for key, val in entry.items():
                if isinstance(val, (int, float)) and isinstance(
                        out.get(key, 0.0), (int, float)):
                    out[key] = out.get(key, 0.0) + float(val)
                else:
                    out[key] = val
    return out
