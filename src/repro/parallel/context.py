"""Global parallel context: mesh + logical axis mapping.

Model code never hard-codes mesh axes; it asks the active ``ParallelCtx``.
With no context set (unit tests, single host), every helper degrades to a
no-op and the models run as plain single-device JAX.
"""
from __future__ import annotations

import contextlib
import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    mesh: Mesh | None = None
    data_axes: tuple = ("data",)    # shard batch / tokens
    model_axes: tuple = ("model",)  # shard d_ff / experts / vocab
    pod_axes: tuple = ()            # extra outer axis (multi-pod)
    seq_axes: tuple = ()            # sequence parallelism: shard the
                                    # residual stream's seq dim (Megatron-SP
                                    # style); empty = replicated seq
    cast_gathers: bool = False      # pre-cast matmul weights to the compute
                                    # dtype BEFORE the per-layer FSDP
                                    # all-gather (halves gather payloads;
                                    # EXPERIMENTS.md §Perf iteration 1)

    @property
    def batch_axes(self) -> tuple:
        """All axes usable for batch sharding (pod acts as extra DP)."""
        return tuple(self.pod_axes) + tuple(self.data_axes)

    @property
    def hidden_spec(self):
        """PartitionSpec of the [B, S, D] residual stream."""
        return P(self.batch_axes, self.seq_axes or None)

    def axis_size(self, axes) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n


_CTX = ParallelCtx()


def get_ctx() -> ParallelCtx:
    return _CTX


def set_ctx(ctx: ParallelCtx) -> None:
    global _CTX
    _CTX = ctx


@contextlib.contextmanager
def use_ctx(ctx: ParallelCtx):
    global _CTX
    prev = _CTX
    _CTX = ctx
    try:
        yield ctx
    finally:
        _CTX = prev


def constrain(x, spec: P):
    """with_sharding_constraint that no-ops without a mesh."""
    ctx = get_ctx()
    if ctx.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))
