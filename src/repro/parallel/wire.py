"""Quantized wire codec for the inter-stage pipeline hop (codec v2).

The pod pipeline's wall time is gated by moving the cut-layer activation
``s_l`` (forward hop) and its gradient (the transposed backward hop) across
the slow inter-pod link — exactly the payload EPSL shrinks on the wireless
uplink.  This module compresses that payload on the wire only: each hop

    encode (block-quantize)  ->  ppermute payload + scales  ->  decode

so the stages themselves keep computing in the model dtype and the
schedule/autodiff structure of ``parallel/pipeline.py`` is untouched.  The
whole round trip is wrapped in a ``custom_vjp`` whose backward rule applies
a codec to the activation-gradient payload on the reversed permutation —
the downlink pays the same wire discount as the uplink.

Codec grammar (``parse_wire_dtype``):

    "none" | "int8" | "fp8" | "<base>+topk<frac>"   e.g. "int8+topk0.25"

The plain names are the PR-5 dense block codec.  The ``+topk`` suffix adds
top-k sparsification WITH error feedback on the BACKWARD hop only: the
forward hop still ships the dense base codec (every element of the cut
activation feeds the next stage — dropping entries there starves the
forward compute), while the gradient hop keeps only the ``frac*d`` largest-
magnitude entries per row and feeds the dropped mass back into the next
step's gradient at the same (stage, tick) slot.  EF is sound on the
gradient hop and NOT on the activation hop because the pipeline schedule
is static: tick t of stage s carries the same micro-batch slot every
batch, so the residual buffer keyed per (stage, tick) re-meets "its"
payload each step — the EF-SGD contraction argument applies — whereas the
forward activation at a tick is a fresh function of the current weights
with no persistent error to correct (docs/wire.md).  ``topk>=1`` keeps
every entry and normalizes to the dense base codec at parse time.

Dense codec format (shared quantizer with ``training/compress.py``):

  * blocks are taken along the LAST axis (d_model) so the leading
    micro-batch/sequence dims — the dims GSPMD shards over ``data`` inside
    the partial-manual lowering — are never mixed across devices by a
    reshape;
  * block size is the largest divisor of d_model <= 256 (no padding;
    canonical ``wire_block`` lives in ``kernels/wire_codec.py`` — the
    fused Pallas implementation of this codec — and is re-exported here);
  * per-block fp32 absmax scales: payload = int8 (block max -> 127) or
    fp8-e4m3 (block max -> 448), ~``1 + 4/block`` bytes/element on the
    wire vs 2 (bf16) / 4 (fp32) uncompressed;
  * degenerate blocks are a NET LOSS (a prime d_model forces block=1:
    5 bytes/element > raw) — ``encode`` detects this and falls back to
    the raw payload with a one-time warning instead of silently
    inflating the wire (``codec_net_loss``);
  * ``impl='fused'`` routes encode/decode through the fused Pallas
    kernels (``kernels/ops.wire_encode``/``wire_decode``); the default
    ``'auto'`` picks fused on a TPU backend and the jnp reference path
    elsewhere — the two are bit-identical under jit (tested).

Top-k payload format (backward hop only): per row of ``d`` entries the
wire carries ``kk = round(frac*d)`` base-quantized values, their int16
indices (int32 when d > 32767), and one fp32 per-row scale —
``frac*(1 + idx_bytes) + 4/d`` bytes/element, e.g. 0.75 B at frac=0.25
vs 1.016 B dense int8.

``wire_dtype="none"`` never enters this module from the pipeline — the
tick loop keeps the raw ``ppermute`` path bit-for-bit identical to the
uncoded pipeline.

Devices outside the permutation (the last stage of an acyclic v=1 hop)
receive zero payloads AND zero scales, decoding to exact zeros — matching
the raw ppermute's zero-fill semantics, so warm-up/drain ticks behave
identically under every codec.
"""
from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp

from repro.kernels.wire_codec import wire_block  # noqa: F401  (canonical)
from repro.training.compress import (dequantize_blocks, payload_dtype,
                                     quantize_blocks)

WIRE_DTYPES = ("none", "int8", "fp8")   # base codecs


def parse_wire_dtype(wire_dtype):
    """Codec name -> ``(base, topk_frac | None)``.

    Accepts 'none' / 'int8' / 'fp8' and '<base>+topk<frac>' (e.g.
    'int8+topk0.25').  ``frac >= 1`` keeps every entry, so it normalizes
    to the dense base codec (frac None) — 'int8+topk1.0' IS 'int8'.
    """
    w = "none" if wire_dtype is None else str(wire_dtype).strip().lower()
    base, sep, suffix = w.partition("+")
    frac = None
    if sep:
        if not suffix.startswith("topk"):
            raise ValueError(
                f"wire_dtype {wire_dtype!r}: unknown modifier {suffix!r} "
                "(expected '<base>+topk<frac>', e.g. 'int8+topk0.25')")
        try:
            frac = float(suffix[len("topk"):])
        except ValueError:
            raise ValueError(
                f"wire_dtype {wire_dtype!r}: top-k fraction "
                f"{suffix[len('topk'):]!r} is not a number")
        if not frac > 0.0:
            raise ValueError(
                f"wire_dtype {wire_dtype!r}: top-k fraction must be > 0")
        if frac >= 1.0:
            frac = None           # keeps everything == dense base codec
    if base not in WIRE_DTYPES:
        raise ValueError(
            f"wire_dtype {wire_dtype!r} base {base!r} not in {WIRE_DTYPES} "
            "— 'none' ships the raw activation, 'int8'/'fp8' block-"
            "quantize the hop, '<base>+topk<frac>' adds top-k + error "
            "feedback on the gradient hop")
    if frac is not None and base == "none":
        raise ValueError(
            f"wire_dtype {wire_dtype!r}: top-k rides a quantized payload "
            "— use 'int8+topk<frac>' or 'fp8+topk<frac>'")
    return base, frac


def format_wire_dtype(base: str, frac) -> str:
    return base if frac is None else f"{base}+topk{frac:g}"


def has_topk(wire_dtype) -> bool:
    """True when the codec sparsifies the gradient hop (needs the EF
    buffer threaded through the tick loop)."""
    return parse_wire_dtype(wire_dtype)[1] is not None


def validate_wire_dtype(wire_dtype) -> str:
    """Normalize + validate a codec name; returns the canonical spelling
    ('int8+topk1.0' normalizes to 'int8')."""
    base, frac = parse_wire_dtype(wire_dtype)
    if base == "fp8":
        payload_dtype("fp8")  # raises on JAX without float8_e4m3fn
    return format_wire_dtype(base, frac)


# ---------------------------------------------------------------------------
# Dense base codec (forward hop; PR-5 format + fused dispatch + net-loss
# fallback).
# ---------------------------------------------------------------------------


def codec_net_loss(d: int, act_itemsize: int) -> bool:
    """True when the dense codec would INFLATE the wire for this width:
    ``1 + 4/block`` bytes/element >= the raw element width (block=1 at a
    prime d_model costs 5 B/elt — worse than bf16 or fp32)."""
    b = wire_block(int(d))
    return (1.0 + 4.0 / b) >= float(act_itemsize)


_NET_LOSS_WARNED: set = set()


def _warn_net_loss_once(wire_dtype, d: int, dtype):
    key = (str(wire_dtype), int(d), str(dtype))
    if key in _NET_LOSS_WARNED:
        return
    _NET_LOSS_WARNED.add(key)
    b = wire_block(int(d))
    warnings.warn(
        f"wire codec {wire_dtype!r} is a net loss at d_model={d}: block "
        f"{b} costs {1.0 + 4.0 / b:.2f} wire bytes/element vs "
        f"{jnp.dtype(dtype).itemsize} raw ({dtype}) — shipping the raw "
        "activation instead (pick a d_model with a divisor <= 256, or "
        "wire_dtype='none')")


def _impl(impl: str) -> str:
    if impl == "auto":
        return "fused" if jax.default_backend() == "tpu" else "jnp"
    if impl not in ("fused", "jnp"):
        raise ValueError(f"codec impl {impl!r} not in ('auto','fused','jnp')")
    return impl


def encode(x, wire_dtype: str, impl: str = "auto"):
    """[..., d] activation -> (payload [..., d/b, b], fp32 scales
    [..., d/b, 1]) for a quantized codec.

    Degenerate blocks (``codec_net_loss``) fall back to the raw payload:
    returns ``(x, None)`` with a one-time warning, which ``decode``
    passes through unchanged — the hop then ships exactly the raw bytes.
    """
    d = x.shape[-1]
    if codec_net_loss(d, jnp.dtype(x.dtype).itemsize):
        _warn_net_loss_once(wire_dtype, d, x.dtype)
        return x, None
    if _impl(impl) == "fused":
        from repro.kernels import ops
        return ops.wire_encode(x, wire_dtype=wire_dtype)
    b = wire_block(d)
    blocks = x.astype(jnp.float32).reshape(x.shape[:-1] + (d // b, b))
    return quantize_blocks(blocks, wire_dtype)


def decode(payload, scale, out_dtype, impl: str = "auto"):
    """Inverse of ``encode``: back to [..., d] at the activation dtype.
    ``scale=None`` is the raw net-loss fallback — passthrough."""
    if scale is None:
        return payload.astype(out_dtype)
    if _impl(impl) == "fused":
        from repro.kernels import ops
        return ops.wire_decode(payload, scale,
                               out_dtype=jnp.dtype(out_dtype))
    x = dequantize_blocks(payload, scale)
    return x.reshape(
        x.shape[:-2] + (x.shape[-2] * x.shape[-1],)).astype(out_dtype)


def roundtrip(x, wire_dtype: str, impl: str = "auto"):
    """encode->decode without the permute — the codec's numerical identity
    (what a stage receives when the link is lossless)."""
    q, s = encode(x, wire_dtype, impl)
    return decode(q, s, x.dtype, impl)


def _wire_ppermute(q, axis_name, perm):
    """ppermute a quantized payload at its declared wire width.

    One-byte FLOAT payloads (fp8-e4m3) ride the collective bitcast to
    int8: backends without f8 collective support (XLA:CPU today)
    otherwise legalize the ppermute by upcasting to f16 — silently
    doubling the hop bytes the planner billed.  The bitcast is free on
    both ends and pins the wire to exactly one byte per element, which
    is the invariant ``repro.analysis.staticcheck`` audits in compiled
    HLO (PAYLOAD_HLO_DTYPE: every coded payload spells ``s8`` on the
    wire)."""
    dt = q.dtype
    if jnp.issubdtype(dt, jnp.floating) and dt.itemsize == 1:
        raw = jax.lax.bitcast_convert_type(q, jnp.int8)
        raw = jax.lax.ppermute(raw, axis_name, perm)
        return jax.lax.bitcast_convert_type(raw, dt)
    return jax.lax.ppermute(q, axis_name, perm)


def _coded_hop(wire_dtype, axis_name, perm, x):
    q, s = encode(x, wire_dtype)
    q = _wire_ppermute(q, axis_name, perm)
    if s is not None:
        s = jax.lax.ppermute(s, axis_name, perm)
    return decode(q, s, x.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def coded_ppermute(wire_dtype, axis_name, perm, x):
    """Quantize -> ppermute -> dequantize, with the transposed backward
    hop coded the same way.

    ``perm`` must be a hashable tuple of ``(src, dst)`` pairs.  The VJP is
    deliberately NOT the true linearization of the forward round trip
    (quantization is piecewise-constant; its exact derivative is zero
    almost everywhere): it is the straight-through wire transpose — the
    cotangent rides the reversed permutation through the same
    encode/decode, which is precisely "the downlink payload is quantized
    like the uplink payload" (EPSL's BP compression).
    """
    return _coded_hop(wire_dtype, axis_name, perm, x)


def _coded_fwd(wire_dtype, axis_name, perm, x):
    return _coded_hop(wire_dtype, axis_name, perm, x), None


def _coded_bwd(wire_dtype, axis_name, perm, _res, g):
    rev = tuple((dst, src) for src, dst in perm)
    return (_coded_hop(wire_dtype, axis_name, rev, g),)


coded_ppermute.defvjp(_coded_fwd, _coded_bwd)


# ---------------------------------------------------------------------------
# Top-k sparsified gradient hop with error feedback.
# ---------------------------------------------------------------------------


def topk_count(d: int, frac: float) -> int:
    """Entries kept per row of ``d`` under a top-k fraction (>= 1)."""
    return max(1, min(int(d), int(round(frac * d))))


def topk_index_dtype(d: int):
    """int16 wire indices whenever they fit (d <= 32767) — int32 indices
    would make topk0.25 COST more than dense int8 (1.25 vs 1.016 B/elt)."""
    return jnp.int16 if int(d) <= 32767 else jnp.int32


def topk_encode(x, wire_dtype: str):
    """f32 [..., d] -> (payload [..., kk], indices [..., kk] int16/int32,
    fp32 per-row scale [..., 1]) keeping the ``frac*d`` largest-magnitude
    entries per row, base-quantized against the row absmax."""
    base, frac = parse_wire_dtype(wire_dtype)
    if frac is None:
        raise ValueError(
            f"wire_dtype {wire_dtype!r} has no top-k fraction — use the "
            "dense encode/decode")
    d = x.shape[-1]
    kk = topk_count(d, frac)
    xf = x.astype(jnp.float32)
    _, idx = jax.lax.top_k(jnp.abs(xf), kk)
    vals = jnp.take_along_axis(xf, idx, axis=-1)
    q, scale = quantize_blocks(vals, base)   # one "block" = the kept row
    return q, idx.astype(topk_index_dtype(d)), scale


def topk_decode(q, idx, scale, d: int, out_dtype):
    """Scatter the kept entries back into dense [..., d] rows."""
    vals = dequantize_blocks(q, scale)
    lead = q.shape[:-1]
    kk = q.shape[-1]
    rows = 1
    for n in lead:
        rows *= int(n)
    v2 = vals.reshape(rows, kk)
    i2 = idx.astype(jnp.int32).reshape(rows, kk)
    rowids = jnp.arange(rows, dtype=jnp.int32)[:, None]
    # .add, not .set: top-k indices are unique per row, so this equals a
    # scatter-set but stays deterministic for the all-zero payloads of
    # devices outside the permutation (idx collides at 0 there).
    out = jnp.zeros((rows, int(d)), jnp.float32).at[rowids, i2].add(v2)
    return out.reshape(lead + (int(d),)).astype(out_dtype)


# ---------------------------------------------------------------------------
# Host-side (numpy) entry points — the streaming runtime's codec.
#
# The asyncio UE/BS runtime (repro/runtime/) moves the SAME payload format
# over a real socket instead of a ppermute, from host memory, per frame —
# tracing a jit per frame would dominate the hop.  These mirrors compute
# the codec with numpy (+ the ml_dtypes float8 numpy already knows via
# jax) on plain arrays; parity with the jnp path is tested elementwise in
# tests/test_streaming.py, and byte counts are identical by construction
# (same payload/scale/index shapes and dtypes).
# ---------------------------------------------------------------------------


def _host_quantize_blocks(blocks, base: str):
    """numpy twin of ``training.compress.quantize_blocks``."""
    import numpy as np
    amax = np.max(np.abs(blocks), axis=-1, keepdims=True)
    from repro.training.compress import qmax_for
    scale = np.maximum(amax / np.float32(qmax_for(base)),
                       np.float32(1e-12)).astype(np.float32)
    scaled = (blocks / scale).astype(np.float32)
    if base == "int8":
        q = np.clip(np.round(scaled), -127, 127).astype(np.int8)
    else:
        q = scaled.astype(np.dtype(payload_dtype(base)))
    return q, scale


def host_encode(x, wire_dtype: str):
    """Dense FORWARD-hop codec on a host numpy array.

    np [..., d] -> (payload, fp32 scales) in exactly the ``encode``
    format; 'none' and the degenerate-block net-loss condition return
    ``(x, None)`` — the raw passthrough the socket then ships verbatim,
    matching the in-process fallback (and the planner's billing).
    """
    import numpy as np
    base, _frac = parse_wire_dtype(wire_dtype)
    x = np.asarray(x)
    d = x.shape[-1]
    if base == "none":
        return x, None
    if codec_net_loss(d, x.dtype.itemsize):
        _warn_net_loss_once(wire_dtype, d, x.dtype)
        return x, None
    b = wire_block(d)
    blocks = x.astype(np.float32).reshape(x.shape[:-1] + (d // b, b))
    return _host_quantize_blocks(blocks, base)


def host_decode(payload, scale, out_dtype):
    """Inverse of ``host_encode`` (scale=None = raw passthrough)."""
    import numpy as np
    payload = np.asarray(payload)
    if scale is None:
        return payload.astype(out_dtype)
    x = payload.astype(np.float32) * np.asarray(scale)
    return x.reshape(
        x.shape[:-2] + (x.shape[-2] * x.shape[-1],)).astype(out_dtype)


def host_topk_encode(x, wire_dtype: str):
    """Top-k BACKWARD-hop codec on a host numpy array: f32 [..., d] ->
    (payload [..., kk], indices [..., kk] int16/int32, fp32 per-row
    scale [..., 1]) in the ``topk_encode`` wire format.  Selection
    mirrors ``jax.lax.top_k`` (descending |x|, ties broken toward the
    lower index) so the two paths keep identical support sets."""
    import numpy as np
    base, frac = parse_wire_dtype(wire_dtype)
    if frac is None:
        raise ValueError(
            f"wire_dtype {wire_dtype!r} has no top-k fraction — use the "
            "dense host_encode/host_decode")
    x = np.asarray(x)
    d = x.shape[-1]
    kk = topk_count(d, frac)
    xf = x.astype(np.float32)
    idx = np.argsort(-np.abs(xf), axis=-1, kind="stable")[..., :kk]
    vals = np.take_along_axis(xf, idx, axis=-1)
    q, scale = _host_quantize_blocks(vals, base)
    idx_dt = np.int16 if int(d) <= 32767 else np.int32
    return q, idx.astype(idx_dt), scale


def host_topk_decode(q, idx, scale, d: int, out_dtype):
    """Scatter a host top-k payload back into dense [..., d] rows."""
    import numpy as np
    q = np.asarray(q)
    vals = q.astype(np.float32) * np.asarray(scale)
    lead = q.shape[:-1]
    out = np.zeros(lead + (int(d),), np.float32)
    np.put_along_axis(out, np.asarray(idx).astype(np.int64), vals, axis=-1)
    return out.astype(out_dtype)


def _topk_hop(wire_dtype, axis_name, perm, g):
    """One top-k-coded hop of a (pre-corrected) f32 gradient payload:
    returns (received dense f32, locally-decoded dense f32).  The local
    decode is what THIS device's receiver will reconstruct — the term
    the error-feedback residual is computed against."""
    d = g.shape[-1]
    q, idx, scale = topk_encode(g, wire_dtype)
    dec_local = topk_decode(q, idx, scale, d, jnp.float32)
    q = _wire_ppermute(q, axis_name, perm)
    idx = jax.lax.ppermute(idx, axis_name, perm)
    scale = jax.lax.ppermute(scale, axis_name, perm)
    return topk_decode(q, idx, scale, d, jnp.float32), dec_local


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def coded_ppermute_ef(wire_dtype, axis_name, perm, x, ef):
    """The top-k codec's hop: dense base-coded FORWARD, top-k + error
    feedback BACKWARD.

    ``ef`` is the f32 residual of this (stage, tick) slot from the
    previous batch; it is a differentiable input whose returned
    "cotangent" IS the new residual — that is how the EF state escapes
    the backward pass (``jax.value_and_grad(loss, argnums=(0, 2))`` in
    ``parallel/steps.py`` picks it up next to the weight grads).  The
    backward rule ships ``topk(g + ef)`` on the reversed permutation and
    returns ``(g + ef) - decode(topk(g + ef))`` as the residual — plain
    EF-SGD on the gradient payload, sound here because the static
    schedule re-meets the same slot every batch (module docstring).
    """
    base, _ = parse_wire_dtype(wire_dtype)
    return _coded_hop(base, axis_name, perm, x)


def _coded_ef_fwd(wire_dtype, axis_name, perm, x, ef):
    base, _ = parse_wire_dtype(wire_dtype)
    return _coded_hop(base, axis_name, perm, x), ef


def _coded_ef_bwd(wire_dtype, axis_name, perm, ef, g):
    # the cotangent dtype equals the primal activation dtype, so the
    # net-loss check matches the forward hop's fallback decision
    rev = tuple((dst, src) for src, dst in perm)
    d = g.shape[-1]
    if codec_net_loss(d, jnp.dtype(g.dtype).itemsize):
        # the forward hop fell back to raw (degenerate block) — keep the
        # backward raw too and carry the residual unchanged
        return jax.lax.ppermute(g, axis_name, rev), ef
    corrected = g.astype(jnp.float32) + ef
    gx, dec_local = _topk_hop(wire_dtype, axis_name, rev, corrected)
    return gx.astype(g.dtype), corrected - dec_local


coded_ppermute_ef.defvjp(_coded_ef_fwd, _coded_ef_bwd)
