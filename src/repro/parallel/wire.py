"""Quantized wire codec for the inter-stage pipeline hop.

The pod pipeline's wall time is gated by moving the cut-layer activation
``s_l`` (forward hop) and its gradient (the transposed backward hop) across
the slow inter-pod link — exactly the payload EPSL shrinks on the wireless
uplink.  This module compresses that payload on the wire only: each hop

    encode (block-quantize)  ->  ppermute payload + scales  ->  decode

so the stages themselves keep computing in the model dtype and the
schedule/autodiff structure of ``parallel/pipeline.py`` is untouched.  The
whole round trip is wrapped in a ``custom_vjp`` whose backward rule applies
the SAME codec to the activation-gradient payload on the reversed
permutation — the downlink pays the same wire discount as the uplink.

Codec format (shared quantizer with ``training/compress.py``):

  * blocks are taken along the LAST axis (d_model) so the leading
    micro-batch/sequence dims — the dims GSPMD shards over ``data`` inside
    the partial-manual lowering — are never mixed across devices by a
    reshape;
  * block size is the largest divisor of d_model that is <= 256 (no
    padding: the wire never carries bytes the activation doesn't have);
  * per-block fp32 absmax scales: payload = int8 (block max -> 127) or
    fp8-e4m3 (block max -> 448), ~``1 + 4/block`` bytes/element on the
    wire vs 2 (bf16) / 4 (fp32) uncompressed;
  * NO error feedback on this path: every tick quantizes a different
    micro-batch's activation, so there is no persistent tensor a residual
    could be fed back into (docs/wire.md discusses the EF/no-EF choice).

``wire_dtype="none"`` never enters this module from the pipeline — the
tick loop keeps the raw ``ppermute`` path bit-for-bit identical to the
uncoded pipeline.

Devices outside the permutation (the last stage of an acyclic v=1 hop)
receive zero payloads AND zero scales, decoding to exact zeros — matching
the raw ppermute's zero-fill semantics, so warm-up/drain ticks behave
identically under every codec.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.training.compress import (dequantize_blocks, payload_dtype,
                                     quantize_blocks)

WIRE_DTYPES = ("none", "int8", "fp8")


def validate_wire_dtype(wire_dtype: str) -> str:
    """Normalize + validate a codec name ('none' | 'int8' | 'fp8')."""
    w = "none" if wire_dtype is None else str(wire_dtype).strip().lower()
    if w not in WIRE_DTYPES:
        raise ValueError(
            f"wire_dtype {wire_dtype!r} not in {WIRE_DTYPES} — 'none' ships "
            "the raw activation, 'int8'/'fp8' block-quantize the hop")
    if w == "fp8":
        payload_dtype("fp8")  # raises on JAX without float8_e4m3fn
    return w


def wire_block(dim: int, block: int = 256) -> int:
    """Largest block size <= ``block`` dividing ``dim`` (no padding)."""
    b = min(block, max(dim, 1))
    while dim % b:
        b -= 1
    return b


def encode(x, wire_dtype: str):
    """[..., d] activation -> (payload [..., d/b, b], fp32 scales
    [..., d/b, 1]) for a quantized codec."""
    d = x.shape[-1]
    b = wire_block(d)
    blocks = x.astype(jnp.float32).reshape(x.shape[:-1] + (d // b, b))
    return quantize_blocks(blocks, wire_dtype)


def decode(payload, scale, out_dtype):
    """Inverse of ``encode``: back to [..., d] at the activation dtype."""
    x = dequantize_blocks(payload, scale)
    return x.reshape(
        x.shape[:-2] + (x.shape[-2] * x.shape[-1],)).astype(out_dtype)


def roundtrip(x, wire_dtype: str):
    """encode->decode without the permute — the codec's numerical identity
    (what a stage receives when the link is lossless)."""
    q, s = encode(x, wire_dtype)
    return decode(q, s, x.dtype)


def _coded_hop(wire_dtype, axis_name, perm, x):
    q, s = encode(x, wire_dtype)
    q = jax.lax.ppermute(q, axis_name, perm)
    s = jax.lax.ppermute(s, axis_name, perm)
    return decode(q, s, x.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def coded_ppermute(wire_dtype, axis_name, perm, x):
    """Quantize -> ppermute -> dequantize, with the transposed backward
    hop coded the same way.

    ``perm`` must be a hashable tuple of ``(src, dst)`` pairs.  The VJP is
    deliberately NOT the true linearization of the forward round trip
    (quantization is piecewise-constant; its exact derivative is zero
    almost everywhere): it is the straight-through wire transpose — the
    cotangent rides the reversed permutation through the same
    encode/decode, which is precisely "the downlink payload is quantized
    like the uplink payload" (EPSL's BP compression).
    """
    return _coded_hop(wire_dtype, axis_name, perm, x)


def _coded_fwd(wire_dtype, axis_name, perm, x):
    return _coded_hop(wire_dtype, axis_name, perm, x), None


def _coded_bwd(wire_dtype, axis_name, perm, _res, g):
    rev = tuple((dst, src) for src, dst in perm)
    return (_coded_hop(wire_dtype, axis_name, rev, g),)


coded_ppermute.defvjp(_coded_fwd, _coded_bwd)
