"""Micro-batched gradient accumulation — the paper's equivalence primitive.

C2P2SL splits each batch into k micro-batches and accumulates gradients; the
paper asserts (SII-C, last paragraph) that the accumulated update is
mathematically equivalent to the full-batch computation.  This module is
that statement as code, and tests/test_equivalence.py asserts it to float
tolerance for every model family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def split_batch(batch, k: int):
    """Reshape every leaf [B, ...] -> [k, B//k, ...]."""
    def r(x):
        b = x.shape[0]
        assert b % k == 0, f"batch {b} not divisible by k={k}"
        return x.reshape((k, b // k) + x.shape[1:])
    return jax.tree.map(r, batch)


def microbatched_value_and_grad(loss_fn, k: int):
    """value_and_grad with gradient accumulation over k micro-batches.

    ``loss_fn(params, micro_batch) -> (loss, metrics)``.  Returns a function
    ``(params, batch) -> ((loss, metrics), grads)`` where loss/metrics/grads
    are averaged over micro-batches (identical semantics to full batch when
    the loss is a per-sample mean).
    """
    vg = jax.value_and_grad(loss_fn, has_aux=True)

    if k <= 1:
        return lambda params, batch: vg(params, batch)

    def run(params, batch):
        micro = split_batch(batch, k)

        def body(carry, mb):
            (loss, mets), grads = vg(params, mb)
            acc_loss, acc_mets, acc_grads = carry
            acc = jax.tree.map(jnp.add, acc_grads, grads)
            mets_sum = jax.tree.map(jnp.add, acc_mets, mets)
            return (acc_loss + loss, mets_sum, acc), None

        zero_like = lambda t: jax.tree.map(
            lambda x: jnp.zeros(x.shape, x.dtype), t)
        # peek structure with eval_shape (no compute)
        (l0, m0), g0 = jax.eval_shape(vg, params,
                                      jax.tree.map(lambda x: x[0], micro))
        init = (jnp.zeros(l0.shape, l0.dtype), zero_like(m0), zero_like(g0))
        (loss, mets, grads), _ = jax.lax.scan(body, init, micro)
        inv = 1.0 / k
        return ((loss * inv, jax.tree.map(lambda x: x * inv, mets)),
                jax.tree.map(lambda g: g * inv, grads))

    return run
