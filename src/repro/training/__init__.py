from repro.training.optim import Optimizer, adamw, sgd, cosine_schedule
from repro.training.loop import (TrainState, init_state, make_train_step, fit,
                                 resume_or_init)
from repro.training.microbatch import microbatched_value_and_grad, split_batch
from repro.training import checkpoint, compress, fault
