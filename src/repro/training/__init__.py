"""Training package.

Lazy exports (PEP 562): the optimizer/loop modules import jax at module
scope, but some consumers — ``analysis/staticcheck`` deriving its audit
grid from ``training.replan.reachable_cells``, the jax-free planner
paths — must be importable before any accelerator stack exists (and
before ``XLA_FLAGS`` is pinned).  Importing a submodule or a re-exported
name resolves on first attribute access instead of at package import.
"""

_LAZY = {
    "Optimizer": "repro.training.optim",
    "adamw": "repro.training.optim",
    "sgd": "repro.training.optim",
    "cosine_schedule": "repro.training.optim",
    "TrainState": "repro.training.loop",
    "init_state": "repro.training.loop",
    "make_train_step": "repro.training.loop",
    "fit": "repro.training.loop",
    "resume_or_init": "repro.training.loop",
    "microbatched_value_and_grad": "repro.training.microbatch",
    "split_batch": "repro.training.microbatch",
}
_SUBMODULES = ("checkpoint", "compress", "fault", "loop", "microbatch",
               "optim", "replan")


def __getattr__(name):
    import importlib
    if name in _LAZY:
        return getattr(importlib.import_module(_LAZY[name]), name)
    if name in _SUBMODULES:
        return importlib.import_module(f"repro.training.{name}")
    raise AttributeError(f"module 'repro.training' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_LAZY) | set(_SUBMODULES))
