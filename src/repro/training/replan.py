"""Adaptive online re-planning: channel-tracking plan switches.

The roofline planner (``analysis/autotune``) freezes ``(stages, k, v,
wire_dtype)`` once from a dry-run record, but the premise of C2P2SL over
wireless links is that the channel is NOT constant: AC²P²SL shows the
plan must track link quality, and a codec choice (EPSL-style) only pays
off while the link it was chosen for persists.  This module closes the
loop at runtime:

    measured step times (Watchdog EWMAs) ─┐
    measured hop times  (LinkEstimator) ──┼─> apply_hints ─> PlanInputs
    scripted/physical channel traces ─────┘        │
                                             choose_plan every N steps
                                                   │
                            hysteresis gate: switch only when the
                            projected wall-time gain clears the margin

Plan switches are cheap at scale: ``PlanCellCache`` memoizes the jitted
train step (plus ``eval_shape``'d state templates) per plan **cell**
``(stages, k, v, wire_dtype)`` so revisiting a plan never recompiles,
and ``carry_state`` moves training state across a switch without a
checkpoint round-trip.

EF-buffer carry-over rules (``carry_state``)
--------------------------------------------
The top-k wire codec threads an error-feedback residual ``wire_ef`` of
shape ``[S, ticks, mb, seq_total, d_model]`` through the loss; ticks
depends on (k, v) and mb on (batch, k), so the buffer's shape is a
function of the plan cell.  Across a switch:

* **same shape** (e.g. only the top-k fraction changed, or the codec
  base flipped int8<->fp8 at equal k/v): the residual is carried over
  EXACTLY — it is un-flushed gradient mass and remains valid error
  feedback under the new codec.
* **shape change** (k or v changed, incl. ragged-k transitions where
  ``mb = ceil(batch/k)`` moves): the residual is RESET to zeros.  This
  drops at most one micro-batch's worth of compressed-away gradient —
  the same semantics as resuming a pre-top-k checkpoint — and the new
  buffer is rebuilt with ``wire_ef_zeros`` so padding stays exact.
* **topk -> dense**: the buffer is dropped (dense hops carry no EF).
* **dense -> topk**: a fresh zero buffer is created.

Nothing else in the state depends on the plan cell: params, optimizer
state and the step counter transfer unchanged (re-sharding, when a mesh
is in play, is a ``device_put`` against the target shardings — jit
would re-shard lazily anyway; doing it eagerly keeps the first
post-switch step honest in profiles).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.analysis.autotune import (Plan, PlanInputs, WIRE_AUTO,
                                     choose_plan, plan_wall_time)
from repro.training.fault import Watchdog


# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ReplanConfig:
    """Knobs of the online re-planner (CLI grammar: ``--replan
    every:N,hysteresis:F`` or ``--replan off``)."""

    every: int = 50          # re-evaluate the plan every N steps
    hysteresis: float = 0.1  # switch only if new wall < (1-h) * current
    cooldown: int = 0        # extra steps to hold after a switch
    #                          (0 = the `every` cadence is the cooldown)
    ewma: float = 0.7        # smoothing for link-bandwidth observations

    def __post_init__(self):
        if self.every < 1:
            raise ValueError(f"replan every={self.every} must be >= 1")
        if not 0.0 <= self.hysteresis < 1.0:
            raise ValueError(
                f"replan hysteresis={self.hysteresis} must be in [0, 1)")
        if self.cooldown < 0:
            raise ValueError(f"replan cooldown={self.cooldown} must be >= 0")
        if not 0.0 <= self.ewma < 1.0:
            raise ValueError(f"replan ewma={self.ewma} must be in [0, 1)")

    @classmethod
    def parse(cls, text: str | None) -> "ReplanConfig | None":
        """Parse the ``--replan`` flag value.

        ``None``/``"off"`` -> None (re-planning disabled).  Otherwise a
        comma-separated ``key:value`` list over {every, hysteresis,
        cooldown, ewma}; bare ``on`` gives the defaults.
        """
        if text is None:
            return None
        text = text.strip().lower()
        if text in ("off", "none", "0", "false"):
            return None
        if text in ("on", "", "default"):
            return cls()
        kwargs = {}
        for item in text.split(","):
            if ":" not in item:
                raise ValueError(
                    f"--replan items must be key:value, got {item!r} "
                    f"(full value {text!r})")
            key, _, val = item.partition(":")
            key = key.strip()
            if key in ("every", "cooldown"):
                kwargs[key] = int(val)
            elif key in ("hysteresis", "ewma"):
                kwargs[key] = float(val)
            else:
                raise ValueError(
                    f"unknown --replan key {key!r}; expected one of "
                    "every, hysteresis, cooldown, ewma (or 'off')")
        return cls(**kwargs)

    def describe(self) -> str:
        """Canonical ``--replan`` spelling: ``parse(describe()) == self``.

        Non-default fields are all included (a dropped ``ewma`` used to
        make switch logs / ``--plan-out`` records misreport the active
        smoothing) and floats use ``repr`` — shortest exact round-trip,
        so ``describe`` never loses precision ``parse`` would keep.
        """
        out = f"every:{self.every},hysteresis:{self.hysteresis!r}"
        if self.cooldown:
            out += f",cooldown:{self.cooldown}"
        if self.ewma != type(self).ewma:
            out += f",ewma:{self.ewma!r}"
        return out


# ---------------------------------------------------------------------------
# Link estimation (in-loop ppermute-probe)
# ---------------------------------------------------------------------------


class LinkEstimator:
    """Online estimate of the stage-boundary link from in-loop samples.

    Two feeds, either of which alone is enough:

    * ``observe(nbytes, seconds)`` — a timed hop (the in-loop analogue
      of ``benchmarks/ppermute_probe.py``).  With samples at >= 2
      distinct sizes a least-squares fit ``t = overhead + bytes/bw``
      separates per-message overhead from bandwidth, exactly like the
      probe's affine fit; single-size samples yield bandwidth only.
    * ``observe_bandwidth(bw_Bps)`` — a direct reading (a channel
      telemetry API, or a scripted ``wireless.channel.BandwidthTrace``
      in tests/benchmarks), EWMA-smoothed.

    ``hints()`` exports the current estimate as the planner-hint overlay
    ``apply_hints`` consumes.
    """

    def __init__(self, ewma: float = 0.7, window: int = 64):
        self.ewma = ewma
        self.window = window
        self._samples: list = []       # (bytes, seconds) probe samples
        self._bw_Bps: float | None = None
        self._overhead_s: float | None = None

    def observe(self, nbytes: float, seconds: float) -> None:
        if nbytes <= 0 or seconds <= 0:
            return
        self._samples.append((float(nbytes), float(seconds)))
        del self._samples[:-self.window]
        self._refit()

    # The streaming runtime's per-frame feed (runtime/bs.py times every
    # socket hop and calls this) — same sample stream as ``observe``,
    # under the name the transport layer uses.
    observe_hop = observe

    def observe_bandwidth(self, bw_Bps: float,
                          overhead_s: float | None = None) -> None:
        if bw_Bps <= 0:
            return
        self._bw_Bps = (bw_Bps if self._bw_Bps is None
                        else self.ewma * self._bw_Bps
                        + (1 - self.ewma) * bw_Bps)
        if overhead_s is not None:
            self._overhead_s = (overhead_s if self._overhead_s is None
                                else self.ewma * self._overhead_s
                                + (1 - self.ewma) * overhead_s)

    def _refit(self) -> None:
        b = np.array([s[0] for s in self._samples])
        t = np.array([s[1] for s in self._samples])
        if len(set(b.tolist())) >= 2:
            # affine fit t = a + b/bw, as in ppermute_probe
            coeff = np.polyfit(b, t, 1)
            slope, intercept = float(coeff[0]), float(coeff[1])
            if slope > 0:
                self._bw_Bps = 1.0 / slope
                self._overhead_s = max(0.0, intercept)
                return
        # degenerate fit: bill everything to bandwidth
        bw = float(b.sum() / t.sum())
        self._bw_Bps = bw

    @property
    def bw_Bps(self) -> float | None:
        return self._bw_Bps

    @property
    def overhead_s(self) -> float | None:
        return self._overhead_s

    def hints(self) -> dict:
        out = {}
        if self._bw_Bps is not None:
            out["link_bw_Bps"] = self._bw_Bps
        if self._overhead_s is not None:
            out["hop_overhead_s"] = self._overhead_s
        return out


def apply_hints(inputs: PlanInputs, hints: dict) -> PlanInputs:
    """Fold a measurement overlay into ``PlanInputs``.

    Recognized keys (unknown keys are ignored, so watchdog telemetry and
    planner hints can share one dict):

    * ``link_bw_Bps`` — re-derives ``link_s = act_hop_bytes / bw`` (the
      inverse of ``plan_inputs_from_dryrun``); needs ``act_hop_bytes``.
    * ``hop_overhead_s``, ``codec_s_per_byte`` — direct replacements.
    * ``stage_time_scale`` — multiplies ``stage_fwd_s``/``stage_bwd_s``
      (compute drift, e.g. a thermal throttle or a straggler pod).
    * ``stage_fwd_s``, ``stage_bwd_s`` — direct replacements (win over
      ``stage_time_scale`` if both are present).
    """
    kw = {}
    bw = hints.get("link_bw_Bps")
    if bw and bw > 0 and inputs.act_hop_bytes > 0:
        kw["link_s"] = float(inputs.act_hop_bytes) / float(bw)
    for key in ("hop_overhead_s", "codec_s_per_byte"):
        if hints.get(key) is not None:
            kw[key] = float(hints[key])
    scale = hints.get("stage_time_scale")
    if scale and scale > 0:
        kw["stage_fwd_s"] = inputs.stage_fwd_s * float(scale)
        kw["stage_bwd_s"] = inputs.stage_bwd_s * float(scale)
    for key in ("stage_fwd_s", "stage_bwd_s"):
        if hints.get(key) is not None:
            kw[key] = float(hints[key])
    return dataclasses.replace(inputs, **kw) if kw else inputs


# ---------------------------------------------------------------------------
# The re-planner
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PlanSwitch:
    """One logged plan switch, with the evidence it was decided on."""

    step: int
    old: Plan
    new: Plan
    old_wall_s: float      # current plan's modeled wall on FRESH inputs
    new_wall_s: float      # winner's modeled wall on the same inputs

    @property
    def gain(self) -> float:
        """Fractional projected wall-time saving (0.25 = 25% faster)."""
        return 1.0 - self.new_wall_s / self.old_wall_s \
            if self.old_wall_s > 0 else 0.0

    def to_json(self) -> dict:
        return {"step": self.step, "old": self.old.to_json(),
                "new": self.new.to_json(), "old_wall_s": self.old_wall_s,
                "new_wall_s": self.new_wall_s, "gain": self.gain}


class Replanner:
    """Hysteresis-gated online re-planner over a fixed stage count.

    Every ``config.every`` steps, re-runs ``choose_plan`` on the base
    ``PlanInputs`` refreshed with current measurements and switches to
    the winner only when its projected wall time beats the CURRENT
    plan's wall time *on the same fresh inputs* by more than the
    hysteresis margin::

        new_wall < (1 - hysteresis) * current_wall

    Both sides of the comparison use the refreshed inputs, so steady
    measurement noise moves both walls together and the gate only opens
    on a real relative regime change (no flapping; see the stationarity
    property test).  The stage count is pinned — the pod axis is a
    hardware fact — so switches only move (k, v, wire_dtype).
    """

    def __init__(self, inputs: PlanInputs, initial: Plan,
                 config: ReplanConfig | None = None,
                 watchdog: Watchdog | None = None,
                 wire_candidates=WIRE_AUTO):
        config = config or ReplanConfig()
        if initial.stages != inputs.num_stages:
            raise ValueError(
                f"initial plan has S={initial.stages} but inputs model "
                f"S={inputs.num_stages}; the re-planner never moves the "
                "stage count")
        self.base_inputs = inputs
        self.config = config
        self.current = initial
        self.watchdog = watchdog
        self.wire_candidates = tuple(wire_candidates)
        self.link = LinkEstimator(ewma=config.ewma)
        self.extra_hints: dict = {}
        self.switches: list = []        # PlanSwitch log
        self.evals = 0                  # choose_plan invocations
        self._last_eval_step = None
        self._last_switch_step = None
        self._baseline_step_s = None    # watchdog calibration anchor

    # -- measurement feeds ---------------------------------------------------

    def observe_step(self, worker: int, step_time_s: float) -> None:
        """Per-step wall time feed (goes to the Watchdog EWMAs)."""
        if self.watchdog is None:
            self.watchdog = Watchdog(n_workers=worker + 1)
        if worker not in self.watchdog.workers:
            from repro.training.fault import WorkerState
            self.watchdog.workers[worker] = WorkerState(
                last_beat=self.watchdog.clock())
        self.watchdog.heartbeat(worker, step_time=step_time_s)

    def observe_hop(self, nbytes: float, seconds: float) -> None:
        self.link.observe(nbytes, seconds)

    def observe_bandwidth(self, bw_Bps: float,
                          overhead_s: float | None = None) -> None:
        self.link.observe_bandwidth(bw_Bps, overhead_s)

    # -- planning ------------------------------------------------------------

    def refreshed_inputs(self) -> PlanInputs:
        """Base inputs with every current measurement folded in."""
        hints = dict(self.link.hints())
        if self.watchdog is not None:
            tel = self.watchdog.telemetry()
            med = tel.median_s
            if med > 0:
                if self._baseline_step_s is None:
                    # calibrate: the first healthy EWMA anchors "no
                    # compute drift"; later medians scale stage times
                    # relative to it.  Link drift is billed separately
                    # by the LinkEstimator, so the anchor deliberately
                    # does NOT chase bandwidth-induced step-time moves.
                    self._baseline_step_s = med
                hints.update(tel.extra_hints(self._baseline_step_s))
        hints.pop("step_time_ewma_s", None)   # informational only
        hints.update(self.extra_hints)
        return apply_hints(self.base_inputs, hints)

    def maybe_replan(self, step: int) -> PlanSwitch | None:
        """Run the re-plan cadence at ``step``.

        Returns the ``PlanSwitch`` if the hysteresis gate opened, else
        None (also None on off-cadence steps).  Call once per step.
        """
        if self._last_eval_step is not None \
                and step - self._last_eval_step < self.config.every:
            return None
        self._last_eval_step = step
        self.evals += 1
        inp = self.refreshed_inputs()
        cur = self.current
        cur_wall = plan_wall_time(inp.with_wire(cur.wire_dtype),
                                  cur.k, cur.v)
        best = choose_plan(inp, wire_candidates=self.wire_candidates)
        new = best.plan
        if new == cur:
            return None
        if self._last_switch_step is not None and self.config.cooldown \
                and step - self._last_switch_step < self.config.cooldown:
            return None
        if not best.wall_s < (1.0 - self.config.hysteresis) * cur_wall:
            return None
        switch = PlanSwitch(step=step, old=cur, new=new,
                            old_wall_s=float(cur_wall),
                            new_wall_s=float(best.wall_s))
        self.current = new
        self.switches.append(switch)
        self._last_switch_step = step
        return switch

    def to_json(self) -> dict:
        """Run summary for dryrun-style records / logs."""
        return {
            "config": dataclasses.asdict(self.config),
            "current": self.current.to_json(),
            "evals": self.evals,
            "switches": [s.to_json() for s in self.switches],
        }


# ---------------------------------------------------------------------------
# Reachable cells (for the staticcheck auditor)
# ---------------------------------------------------------------------------


def reachable_cells(*, num_stages: int, num_layers: int | None = None,
                    v_cap: int = 4,
                    wire_candidates=WIRE_AUTO) -> list:
    """Every ``(wire_dtype, v)`` lowering cell the re-planner can switch
    into, for the invariant auditor (``analysis/staticcheck``).

    The auditor's lowering grammar depends on the codec and the
    interleave factor; k only changes shapes (padding is exercised by
    the fixture's ragged k), so cells collapse over k.  Feasibility
    mirrors ``choose_plan``: v ranges over ``PlanInputs.feasible_v`` and
    the codec over ``wire_candidates``, each normalized through
    ``Plan`` so aliases cannot smuggle in duplicate cells.
    """
    probe = PlanInputs(num_stages=num_stages, stage_fwd_s=1.0,
                       stage_bwd_s=2.0, link_s=0.1, v_cap=v_cap,
                       num_layers=num_layers)
    seen, cells = set(), []
    for wd in wire_candidates:
        norm = Plan(stages=num_stages, k=1, wire_dtype=wd).wire_dtype
        for v in probe.feasible_v():
            cell = (norm, v)
            if cell not in seen:
                seen.add(cell)
                cells.append(cell)
    return cells


def reachable_plans(inputs: PlanInputs,
                    wire_candidates=WIRE_AUTO) -> list:
    """Full ``Plan`` set a ``Replanner`` over ``inputs`` can reach
    (cartesian feasible grid; used by tests and capacity estimates —
    the compile cache's worst case is one entry per element)."""
    out = []
    for wd in wire_candidates:
        for v in inputs.feasible_v():
            for k in range(1, max(1, inputs.k_cap) + 1):
                out.append(Plan(stages=inputs.num_stages, k=k, v=v,
                                wire_dtype=wd))
    return out


# ---------------------------------------------------------------------------
# Compile cache + state carry-over (the cheap-switch machinery)
# ---------------------------------------------------------------------------


class PlanCellCache:
    """Memoizes expensive per-plan artifacts by plan **cell**.

    ``build(plan)`` is the caller's factory — typically returning the
    jitted train step for that cell (``launch/train.py`` passes its
    ``make_step``).  Re-entering a previously visited cell is a dict
    hit: no re-trace, no re-compile.  ``state_template`` additionally
    memoizes ``jax.eval_shape`` results per cell, so shape/dtype
    bookkeeping for a candidate plan (e.g. sizing the EF buffer before
    committing to a switch) costs no FLOPs.
    """

    def __init__(self, build):
        self._build = build
        self._entries: dict = {}
        self._templates: dict = {}
        self.hits = 0
        self.misses = 0

    def get(self, plan: Plan):
        key = plan.cell()
        if key in self._entries:
            self.hits += 1
        else:
            self.misses += 1
            self._entries[key] = self._build(plan)
        return self._entries[key]

    def state_template(self, plan: Plan, fn, *args, **kwargs):
        """``jax.eval_shape(fn, *args)`` memoized under this plan's
        cell (``fn`` must be cell-deterministic)."""
        key = plan.cell()
        if key not in self._templates:
            import jax
            self._templates[key] = jax.eval_shape(fn, *args, **kwargs)
        return self._templates[key]

    def __len__(self):
        return len(self._entries)

    def __contains__(self, plan: Plan):
        return plan.cell() in self._entries


def carry_state(state: dict, new_plan: Plan, *, cfg, batch: int,
                seq: int, axis: str = "pod",
                shardings: dict | None = None) -> dict:
    """Move training state across a plan switch, checkpoint-free.

    Params/opt-state/step transfer unchanged; the ``wire_ef`` buffer is
    rebuilt for the new cell under the carry-over rules in the module
    docstring (exact carry when the shape is unchanged, zero reset when
    k/v move it, drop/create on topk<->dense).  ``shardings`` (a pytree
    of target shardings keyed like ``state``) triggers an eager
    ``device_put`` re-shard; with None, jit re-shards lazily on the
    first post-switch step.
    """
    from repro.parallel.pipeline import PipelineSpec, wire_ef_zeros
    new_state = dict(state)
    old_ef = new_state.pop("wire_ef", None)
    spec = PipelineSpec.from_plan(new_plan, axis=axis)
    new_ef = wire_ef_zeros(cfg, spec, batch, seq)
    if new_ef is not None:
        if old_ef is not None and tuple(old_ef.shape) == tuple(new_ef.shape):
            new_ef = old_ef            # exact carry-over
        new_state["wire_ef"] = new_ef
    if shardings:
        import jax
        new_state = {k: jax.device_put(v, shardings[k])
                     if k in shardings else v
                     for k, v in new_state.items()}
    return new_state
