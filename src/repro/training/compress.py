"""Gradient compression with error feedback (distributed-optimization trick).

int8 block-quantized all-reduce payload: each gradient tensor is quantized
per 256-element block to int8 with an fp32 scale (~4x volume reduction on
the data-parallel reduce).  The quantization error is fed back into the next
step's gradient (error-feedback / EF-SGD), which keeps convergence intact —
tests assert the error-feedback invariant, and the quickstart exposes it via
``--compress-grads``.

This generalizes what EPSL [8] does for split learning (shrink the BP
payload) to the datacenter DP axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_len(n: int) -> int:
    return (BLOCK - n % BLOCK) % BLOCK


def quantize(g):
    """fp32 tensor -> (int8 payload, fp32 scales per block, orig shape)."""
    flat = g.reshape(-1).astype(jnp.float32)
    pad = _pad_len(flat.shape[0])
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, g.shape


def dequantize(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compress_grads(grads, error_fb):
    """Apply EF: g' = Q(g + e); new e = (g + e) - deq(Q(...)).

    Returns (quantized_grads_tree, new_error_fb_tree).  The quantized tree
    holds (q, scale, shape) triples — what would travel the wire.
    """
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s, shp = quantize(corrected)
        deq = dequantize(q, s, shp)
        return (q, s, shp), corrected - deq

    pairs = jax.tree.map(one, grads, error_fb)
    is_pair = lambda x: isinstance(x, tuple) and len(x) == 2 and \
        isinstance(x[0], tuple)
    qtree = jax.tree.map(lambda p: p[0], pairs, is_leaf=is_pair)
    etree = jax.tree.map(lambda p: p[1], pairs, is_leaf=is_pair)
    return qtree, etree


def decompress_grads(qtree):
    is_triple = lambda x: isinstance(x, tuple) and len(x) == 3
    return jax.tree.map(lambda t: dequantize(*t), qtree, is_leaf=is_triple)


def init_error_fb(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
