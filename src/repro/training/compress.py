"""Gradient compression with error feedback (distributed-optimization trick).

int8 block-quantized all-reduce payload: each gradient tensor is quantized
per 256-element block to int8 with an fp32 scale (~4x volume reduction on
the data-parallel reduce).  The quantization error is fed back into the next
step's gradient (error-feedback / EF-SGD), which keeps convergence intact —
tests assert the error-feedback invariant, and ``launch/train.py
--compress-grads`` turns it on end-to-end (the step builder plumbing is
``parallel.steps.make_lm_train_step(compress=True)``).

This generalizes what EPSL [8] does for split learning (shrink the BP
payload) to the datacenter DP axis.  The block quantizer itself
(``quantize_blocks`` / ``dequantize_blocks``, int8 or fp8-e4m3) is shared
with the pipeline-hop wire codec (``parallel/wire.py``), which applies the
same scheme to the cut-activation payload WITHOUT error feedback — on the
activation path every tick carries a different micro-batch, so there is no
persistent tensor to feed the error back into (docs/wire.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256

# Largest representable magnitude per payload dtype: int8 keeps the
# symmetric [-127, 127] range; fp8 uses e4m3 (max 448) — enough mantissa
# for activations/gradients once block scales absorb the dynamic range.
_QMAX = {"int8": 127.0, "fp8": 448.0}


def payload_dtype(wire_dtype: str):
    """The jnp dtype a codec puts on the wire."""
    if wire_dtype == "int8":
        return jnp.int8
    if wire_dtype == "fp8":
        if not hasattr(jnp, "float8_e4m3fn"):
            raise NotImplementedError(
                "fp8 wire codec needs jnp.float8_e4m3fn, missing on "
                f"installed jax {jax.__version__} — use int8 or none")
        return jnp.float8_e4m3fn
    raise ValueError(
        f"unknown quantized codec {wire_dtype!r} (expected 'int8' or 'fp8')")


def qmax_for(wire_dtype: str) -> float:
    """Largest representable payload magnitude for a quantized codec —
    the constant the block quantizer and the fused Pallas wire codec
    (kernels/wire_codec.py) must share for bit parity."""
    if wire_dtype not in _QMAX:
        payload_dtype(wire_dtype)  # raise the canonical error
    return _QMAX[wire_dtype]


def quantize_blocks(blocks, wire_dtype: str = "int8"):
    """[..., B] fp32 blocks -> (payload int8/fp8-e4m3, fp32 scales [..., 1]).

    Per-block absmax scaling: the block maximum maps to the payload
    dtype's max magnitude.  All-zero blocks keep a clamped tiny scale so
    decode returns exact zeros.
    """
    amax = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True)
    qmax = _QMAX[wire_dtype] if wire_dtype in _QMAX else None
    if qmax is None:
        payload_dtype(wire_dtype)  # raise the canonical error
    scale = jnp.maximum(amax / qmax, 1e-12)
    scaled = blocks / scale
    if wire_dtype == "int8":
        q = jnp.clip(jnp.round(scaled), -127, 127).astype(jnp.int8)
    else:
        q = scaled.astype(payload_dtype("fp8"))
    return q, scale


def dequantize_blocks(q, scale):
    """Inverse of ``quantize_blocks`` (fp32 output)."""
    return q.astype(jnp.float32) * scale


def _pad_len(n: int) -> int:
    return (BLOCK - n % BLOCK) % BLOCK


def quantize(g, wire_dtype: str = "int8"):
    """fp32 tensor -> (payload, fp32 scales per block, orig shape)."""
    flat = g.reshape(-1).astype(jnp.float32)
    pad = _pad_len(flat.shape[0])
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    q, scale = quantize_blocks(blocks, wire_dtype)
    return q, scale, g.shape


def dequantize(q, scale, shape):
    flat = dequantize_blocks(q, scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compress_grads(grads, error_fb):
    """Apply EF: g' = Q(g + e); new e = (g + e) - deq(Q(...)).

    Returns (quantized_grads_tree, new_error_fb_tree).  The quantized tree
    holds (q, scale, shape) triples — what would travel the wire.
    """
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s, shp = quantize(corrected)
        deq = dequantize(q, s, shp)
        return (q, s, shp), corrected - deq

    pairs = jax.tree.map(one, grads, error_fb)
    is_pair = lambda x: isinstance(x, tuple) and len(x) == 2 and \
        isinstance(x[0], tuple)
    qtree = jax.tree.map(lambda p: p[0], pairs, is_leaf=is_pair)
    etree = jax.tree.map(lambda p: p[1], pairs, is_leaf=is_pair)
    return qtree, etree


def decompress_grads(qtree):
    is_triple = lambda x: isinstance(x, tuple) and len(x) == 3
    return jax.tree.map(lambda t: dequantize(*t), qtree, is_leaf=is_triple)


def init_error_fb(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
