"""Sharded, atomic, elastic checkpointing.

Design (scales to many hosts; exercised single-host here):
  * each process writes ONLY its addressable shards to
    ``<dir>/step_<n>/proc_<p>.npz`` (keyed by flattened param path);
  * process 0 writes ``manifest.json`` (step, tree structure, global shapes,
    process count) and then atomically renames ``step_<n>.tmp -> step_<n>``
    — a half-written checkpoint is never visible;
  * ``restore`` takes the TARGET sharding tree: arrays are assembled from
    whichever shard files exist and re-sharded with ``jax.device_put``,
    so a checkpoint taken on mesh A restores onto any mesh B (elastic
    rescale after node loss);
  * ``latest_step`` skips corrupt/incomplete directories, so restart after
    a mid-save crash falls back to the previous good checkpoint.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}, treedef


def save(ckpt_dir: str, step: int, tree, process_index: int = 0,
         num_processes: int = 1) -> str:
    flat, _ = _flatten(tree)
    tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
    final = os.path.join(ckpt_dir, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)

    local = {}
    meta = {}
    for name, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        local[name] = arr
        meta[name] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
    np.savez(os.path.join(tmp, f"proc_{process_index}.npz"), **local)

    if process_index == 0:
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "num_processes": num_processes,
                       "arrays": meta}, f)
        os.replace(tmp, final)       # atomic commit
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if not name.startswith("step_") or name.endswith(".tmp"):
            continue
        manifest = os.path.join(ckpt_dir, name, "manifest.json")
        try:
            with open(manifest) as f:
                meta = json.load(f)
            steps.append(int(meta["step"]))
        except (OSError, ValueError, KeyError):
            continue            # incomplete/corrupt — ignore
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, target_tree, shardings=None):
    """Restore into the structure of ``target_tree``.

    ``shardings``: optional pytree (same structure) of jax.sharding.Sharding;
    arrays are placed with those shardings (elastic re-shard).
    """
    path = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        meta = json.load(f)
    data = {}
    for fname in sorted(os.listdir(path)):
        if fname.startswith("proc_") and fname.endswith(".npz"):
            with np.load(os.path.join(path, fname)) as z:
                for k in z.files:
                    data[k] = z[k]

    flat_t, treedef = _flatten(target_tree)
    flat_s = _flatten(shardings)[0] if shardings is not None else None
    out = []
    for name, tgt in flat_t.items():
        if name not in data:
            raise KeyError(f"checkpoint missing array {name}")
        arr = data[name]
        if list(arr.shape) != list(tgt.shape):
            raise ValueError(
                f"{name}: checkpoint shape {arr.shape} != target {tgt.shape}")
        arr = arr.astype(tgt.dtype)
        if flat_s is not None:
            arr = jax.device_put(arr, flat_s[name])
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def prune(ckpt_dir: str, keep: int = 3) -> None:
    """Delete all but the newest ``keep`` complete checkpoints."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                steps.append(int(name.split("_", 1)[1]))
            except ValueError:
                continue
    for s in sorted(steps)[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"),
                      ignore_errors=True)
