"""Fault tolerance & straggler mitigation for 1000+ node deployments.

Three mechanisms, all exercised by tests with simulated failures:

1. **Heartbeat watchdog** — every worker stamps a heartbeat each step; the
   coordinator declares a worker dead after ``timeout_steps`` missed beats
   and triggers the elastic-restart flow (shrink to healthy workers,
   restore the last checkpoint re-sharded onto the smaller mesh —
   ``checkpoint.restore`` already re-shards).

2. **Straggler re-balancing** — the paper's OWN batch-allocation machinery
   (P3) doubles as a straggler policy: per-worker step-time EWMAs feed the
   same LP that allocates per-UE batch sizes, shifting micro-batch load away
   from slow workers.  This is the C2P2SL heterogeneity optimization applied
   to datacenter stragglers (DESIGN.md §8).

3. **Elastic rescale** — ``plan_rescale`` maps an old (pod, data, model)
   mesh to a degraded one after pod loss; restore happens through the
   sharding-agnostic checkpoint path.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np


@dataclasses.dataclass
class WorkerState:
    last_beat: float
    step_time_ewma: float = 0.0


@dataclasses.dataclass(frozen=True)
class WatchdogTelemetry:
    """Typed snapshot of the watchdog's per-worker step-time EWMAs.

    The supported way for other subsystems (the online re-planner,
    re-balancing, dashboards) to read the watchdog — callers used to poke
    ``WorkerState.step_time_ewma`` directly, which coupled them to the
    internal dict layout.  ``step_time_ewma`` is ordered by worker id;
    workers that have not reported yet read 0.0.
    """

    step_time_ewma: tuple        # seconds, one entry per worker
    workers: tuple               # the matching worker ids

    @property
    def median_s(self) -> float:
        """Median over workers that have reported (0.0 if none have)."""
        t = np.array(self.step_time_ewma)
        t = t[t > 0]
        return float(np.median(t)) if t.size else 0.0

    @property
    def max_s(self) -> float:
        return float(max(self.step_time_ewma, default=0.0))

    def extra_hints(self, baseline_step_s: float | None = None) -> dict:
        """Planner-hint overlay for the online re-planner
        (``training/replan.py``): the measured step time, plus — when the
        caller knows what step time the current plan was *modeled* at —
        the ``stage_time_scale`` drift factor the re-planner multiplies
        into ``PlanInputs.stage_fwd_s/stage_bwd_s``."""
        med = self.median_s
        hints = {"step_time_ewma_s": med} if med > 0 else {}
        if baseline_step_s and baseline_step_s > 0 and med > 0:
            hints["stage_time_scale"] = med / baseline_step_s
        return hints


class Watchdog:
    """Coordinator-side liveness + straggler tracking."""

    def __init__(self, n_workers: int, timeout_s: float = 60.0,
                 ewma: float = 0.9, clock=time.monotonic):
        self.clock = clock
        self.timeout_s = timeout_s
        self.ewma = ewma
        now = clock()
        self.workers = {i: WorkerState(last_beat=now) for i in range(n_workers)}

    def heartbeat(self, worker: int, step_time: float | None = None):
        st = self.workers[worker]
        st.last_beat = self.clock()
        if step_time is not None:
            st.step_time_ewma = (self.ewma * st.step_time_ewma
                                 + (1 - self.ewma) * step_time
                                 if st.step_time_ewma else step_time)

    def dead_workers(self) -> list:
        now = self.clock()
        return [i for i, st in self.workers.items()
                if now - st.last_beat > self.timeout_s]

    def stragglers(self, factor: float = 1.5) -> list:
        times = np.array([st.step_time_ewma for st in self.workers.values()])
        if not times.any():
            return []
        med = np.median(times[times > 0])
        return [i for i, st in self.workers.items()
                if st.step_time_ewma > factor * med]

    def telemetry(self) -> WatchdogTelemetry:
        """Typed per-worker EWMA snapshot (see ``WatchdogTelemetry``) —
        use this instead of reading ``workers[i].step_time_ewma``."""
        ids = tuple(sorted(self.workers))
        return WatchdogTelemetry(
            step_time_ewma=tuple(self.workers[i].step_time_ewma
                                 for i in ids),
            workers=ids)

    def throughputs(self) -> np.ndarray:
        """Relative worker speeds (1/step-time), for re-balancing."""
        t = np.array([st.step_time_ewma or 1.0 for st in self.workers.values()])
        return 1.0 / t


def rebalance_batches(throughputs: np.ndarray, global_batch: int,
                      multiple: int = 1) -> np.ndarray:
    """Proportional-to-speed batch split (the degenerate P3: no comm terms).

    With wireless comm terms, use repro.core.ao.solve_batch_p3 instead; on a
    homogeneous datacenter fabric the LP reduces to this proportional rule.
    """
    w = throughputs / throughputs.sum()
    b = np.floor(w * global_batch / multiple) * multiple
    rem = global_batch - int(b.sum())
    order = np.argsort(-w)
    i = 0
    while rem > 0:
        b[order[i % len(order)]] += multiple
        rem -= multiple
        i += 1
    return b.astype(int)


def plan_rescale(old_shape: dict, lost_pods: int) -> dict:
    """New mesh shape after losing ``lost_pods`` pods (elastic shrink)."""
    new = dict(old_shape)
    if "pod" in new:
        new["pod"] = max(1, new["pod"] - lost_pods)
    return new
