"""Optimizers from scratch (no optax in this environment).

Params are stored fp32 (master); model code casts to the compute dtype at
use sites, so this is standard mixed-precision training.  State layout is a
pytree mirroring params, kept shardable (same sharding as the parameter).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable     # (grads, state, params, step) -> (new_params, new_state)


def adamw(lr: float | Callable = 1e-3, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0,
          grad_clip: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if grad_clip > 0:
            gsq = sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads))
            scale = jnp.minimum(1.0, grad_clip * jax.lax.rsqrt(gsq + 1e-12))
            grads = jax.tree.map(lambda g: g * scale, grads)
        t = step + 1
        lr_t = lr_fn(step)
        c1 = 1.0 - b1 ** t.astype(jnp.float32)
        c2 = 1.0 - b2 ** t.astype(jnp.float32)

        new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g,
                             state["m"], grads)
        new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g),
                             state["v"], grads)

        def upd(p, m, v):
            step_ = (m / c1) / (jnp.sqrt(v / c2) + eps)
            new_p = p.astype(jnp.float32) - lr_t * (step_ + weight_decay
                                                    * p.astype(jnp.float32))
            return new_p.astype(p.dtype)

        new_params = jax.tree.map(upd, params, new_m, new_v)
        return new_params, {"m": new_m, "v": new_v}

    return Optimizer(init=init, update=update)


def sgd(lr: float | Callable = 0.1, momentum: float = 0.9,
        weight_decay: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def init(params):
        return {"mom": jax.tree.map(
            lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)}

    def update(grads, state, params, step):
        lr_t = lr_fn(step)
        new_m = jax.tree.map(
            lambda m, g, p: momentum * m + g.astype(jnp.float32)
            + weight_decay * p.astype(jnp.float32),
            state["mom"], grads, params)
        new_params = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - lr_t * m).astype(p.dtype),
            params, new_m)
        return new_params, {"mom": new_m}

    return Optimizer(init=init, update=update)


def mixed_precision(opt: Optimizer, cast_fn) -> Optimizer:
    """True mixed precision: bf16 model params + fp32 master in opt state.

    The resident train-step params are ALREADY bf16 (``cast_fn`` of the
    fp32 master), so every FSDP weight all-gather genuinely moves bf16 —
    unlike a use-site ``astype``, which XLA's partitioner reorders past the
    gather (EXPERIMENTS.md §Perf, command-r iteration 1: refuted).  The
    fp32 master is touched only by the elementwise optimizer update and
    never gathered.
    """
    def init(params_f32):
        return {"master": params_f32, "inner": opt.init(params_f32)}

    def update(grads, state, params, step):
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        new_master, new_inner = opt.update(g32, state["inner"],
                                           state["master"], step)
        new_params = cast_fn(new_master)
        return new_params, {"master": new_master, "inner": new_inner}

    return Optimizer(init=init, update=update)


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.0):
    def lr(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = peak_lr * (s + 1) / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + 0.5 * (peak_lr - floor) * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, cos)
    return lr
