"""Generic train loop: state, step builder, checkpointing hooks."""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.training import checkpoint as ckpt_lib
from repro.training.microbatch import microbatched_value_and_grad
from repro.training.optim import Optimizer


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jnp.ndarray

    def tree(self):
        return {"params": self.params, "opt_state": self.opt_state,
                "step": self.step}

    @classmethod
    def from_tree(cls, t):
        return cls(params=t["params"], opt_state=t["opt_state"],
                   step=t["step"])


def init_state(params, opt: Optimizer) -> TrainState:
    return TrainState(params=params, opt_state=opt.init(params),
                      step=jnp.zeros((), jnp.int32))


def make_train_step(loss_fn: Callable, opt: Optimizer,
                    microbatches: int = 1):
    """Build ``step(state_tree, batch) -> (state_tree, metrics)``.

    ``microbatches`` > 1 turns on the paper's gradient-accumulation path
    (mathematically identical update; see tests/test_equivalence.py).
    """
    vg = microbatched_value_and_grad(loss_fn, microbatches)

    def step(state_tree, batch):
        params = state_tree["params"]
        (loss, mets), grads = vg(params, batch)
        new_params, new_opt = opt.update(grads, state_tree["opt_state"],
                                         params, state_tree["step"])
        new_state = {"params": new_params, "opt_state": new_opt,
                     "step": state_tree["step"] + 1}
        mets = dict(mets)
        mets["loss"] = loss
        return new_state, mets

    return step


def fit(state: TrainState, step_fn, data_iter, *, steps: int,
        ckpt_dir: str | None = None, ckpt_every: int = 0,
        log_every: int = 50, metrics_cb=None):
    """Run the loop on host; jit the step; checkpoint periodically."""
    jit_step = jax.jit(step_fn)
    tree = state.tree()
    history = []
    t0 = time.perf_counter()
    for i in range(steps):
        batch = next(data_iter)
        tree, mets = jit_step(tree, batch)
        if log_every and (i + 1) % log_every == 0:
            mets_host = {k: float(v) for k, v in mets.items()}
            mets_host["step"] = i + 1
            mets_host["wall_s"] = time.perf_counter() - t0
            history.append(mets_host)
            if metrics_cb:
                metrics_cb(mets_host)
        if ckpt_dir and ckpt_every and (i + 1) % ckpt_every == 0:
            ckpt_lib.save(ckpt_dir, i + 1, tree)
            ckpt_lib.prune(ckpt_dir)
    return TrainState.from_tree(tree), history


def resume_or_init(state: TrainState, ckpt_dir: str | None,
                   shardings=None) -> TrainState:
    """Restart-from-last-checkpoint flow (fault tolerance entry point)."""
    if not ckpt_dir:
        return state
    step = ckpt_lib.latest_step(ckpt_dir)
    if step is None:
        return state
    tree = ckpt_lib.restore(ckpt_dir, step, state.tree(), shardings)
    return TrainState.from_tree(tree)
