"""Async multi-client streaming runtime: UE clients -> BS dispatcher
over a real (loopback) socket, with measured per-hop link feeds.

``protocol`` and ``qos`` are stdlib+numpy only and import eagerly; the
jax-backed pieces (``UEClient``/``UESync``/``BSDispatcher``/
``run_streaming``) load lazily so the frame format and QoS accounting
stay importable on machines without an accelerator stack.
"""
from repro.runtime import protocol, qos
from repro.runtime.protocol import Frame, pack_frame, read_frame, unpack_frame
from repro.runtime.qos import ClientStats, QoSMonitor

__all__ = [
    "BSDispatcher", "ClientStats", "Frame", "QoSMonitor", "UEClient",
    "UESync", "client_batches", "pack_frame", "protocol", "qos",
    "read_frame", "run_streaming", "unpack_frame",
]

_LAZY = {
    "BSDispatcher": "repro.runtime.bs",
    "UEClient": "repro.runtime.ue",
    "UESync": "repro.runtime.ue",
    "client_batches": "repro.runtime.driver",
    "run_streaming": "repro.runtime.driver",
}


def __getattr__(name):
    if name in _LAZY:
        import importlib
        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
