"""Orchestration + CLI for the async multi-client streaming runtime.

``run_streaming`` wires the whole loop together in one process:

    N ``UEClient`` tasks  --loopback TCP-->  one ``BSDispatcher``

Each round every client runs its sub-cut shard, ships the coded cut
activation over a REAL socket (optionally shaped to a Shannon-rate link
by ``wireless.LinkShaper``), and gets the coded cut-activation gradient
back; the dispatcher micro-steps per arrival (pipelining over ragged
uplinks) and every hop's measured (bytes, seconds) feeds the online
re-planner's ``LinkEstimator``.

With the default equal shards, no gradient clipping, and codec 'none',
the streamed run computes EXACTLY the same parameter trajectory as
joint full-batch training of the unsplit model — the per-arrival BS
micro-steps average to the full-batch gradient (mean of equal-shard
means) and AdamW is elementwise.  tests/test_streaming.py holds the
runtime to that.

CLI::

    python -m repro.runtime.driver --clients 4 --steps 20 \
        --wire-dtype int8+topk0.25 --bw-Bps 2e6 --qos-out qos.json
"""
from __future__ import annotations

import argparse
import asyncio
import json
import sys


def client_batches(cfg, client_id: int, n_clients: int,
                   batch_per_client: int, seq: int, seed: int = 0):
    """Client ``client_id``'s shard of the deterministic global stream.

    Round ``step`` draws ``lm_batch_for(cfg, n_clients * batch_per_client,
    seq, seed + step)`` and takes rows ``[cid*b, (cid+1)*b)`` — so the
    union over clients of one round IS the full-batch reference batch,
    which is what makes exact loss parity with joint training testable.
    """
    from repro.data import lm_batch_for
    step = 0
    while True:
        batch = lm_batch_for(cfg, n_clients * batch_per_client, seq,
                             seed=seed + step)
        sl = slice(client_id * batch_per_client,
                   (client_id + 1) * batch_per_client)
        yield batch["tokens"][sl], batch["labels"][sl]
        step += 1


async def run_streaming(cfg, *, cut: int, n_clients: int, steps: int,
                        batch_per_client: int, seq: int, seed: int = 0,
                        wire_dtype: str = "none", lr: float = 1e-3,
                        shaper=None, replanner=None, queue_depth: int = 2,
                        stall_after_s: float = 0.25,
                        qos=None, on_started=None) -> dict:
    """Run the full streaming loop on loopback; returns a summary dict.

    ``shaper`` (a ``wireless.LinkShaper`` or anything with
    ``delay_s(nbytes)``) shapes BOTH directions; ``replanner`` is either
    a ``training.replan.Replanner`` or a bare ``LinkEstimator`` — the
    dispatcher only calls ``observe_hop``.  ``on_started(dispatcher,
    clients)`` fires after the server binds, before clients run — the
    hook tests use to mutate the link mid-run.
    """
    import jax

    from repro.models import LM
    from repro.runtime.bs import BSDispatcher
    from repro.runtime.ue import UEClient, UESync
    from repro.sl import lm_split
    from repro.training.optim import adamw

    model = LM(cfg)
    params = model.init(jax.random.key(seed))
    spec = lm_split(model, cut)
    ue_params, bs_params = spec.split_params(params)

    dispatcher = BSDispatcher(
        spec, bs_params, adamw(lr), n_clients=n_clients,
        wire_dtype=wire_dtype, queue_depth=queue_depth,
        replanner=replanner, shaper=shaper, qos=qos,
        stall_after_s=stall_after_s)
    sync = UESync(ue_params, adamw(lr), n_clients)

    ue_fwd = jax.jit(spec.ue_fwd)

    def pullback(p, tokens, g):
        _, vjp = jax.vjp(lambda q: spec.ue_fwd(q, tokens), p)
        return vjp(g)[0]

    ue_pullback = jax.jit(pullback)
    clients = [
        UEClient(cid, spec,
                 client_batches(cfg, cid, n_clients, batch_per_client,
                                seq, seed),
                 sync, wire_dtype=wire_dtype, shaper=shaper,
                 ue_fwd=ue_fwd, ue_pullback=ue_pullback)
        for cid in range(n_clients)]

    host, port = await dispatcher.start()
    if on_started is not None:
        on_started(dispatcher, clients)
    try:
        results = await asyncio.gather(
            dispatcher.train(steps),
            *(c.run(host, port, steps) for c in clients))
    finally:
        await dispatcher.close()
    losses = results[0]

    out = {
        "losses": losses,
        "final_loss": losses[-1] if losses else None,
        "steps": steps,
        "n_clients": n_clients,
        "wire_dtype": wire_dtype,
        "qos": dispatcher.qos.snapshot(),
        "wire_honesty": dispatcher.wire_honesty(),
        "params": {"ue": sync.params, "bs": dispatcher.bs_params},
        "spec": spec,
        "client_losses": {c.client_id: c.losses for c in clients},
    }
    if replanner is not None and hasattr(replanner, "hints"):
        out["link_hints"] = replanner.hints()
    elif replanner is not None and hasattr(replanner, "link"):
        out["link_hints"] = replanner.link.hints()
    return out


def main(argv=None) -> dict:
    from repro.models import LMConfig
    from repro.training.replan import LinkEstimator
    from repro.wireless import LinkShaper

    ap = argparse.ArgumentParser(
        description="async multi-client streaming SL over loopback TCP")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=64)
    ap.add_argument("--n-heads", type=int, default=4)
    ap.add_argument("--n-kv", type=int, default=2)
    ap.add_argument("--d-ff", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=128)
    ap.add_argument("--cut", type=int, default=2,
                    help="UE-side depth l: embed + blocks[:l]")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch-per-client", type=int, default=2)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--wire-dtype", default="none",
                    help="none | int8 | fp8 | <base>+topk<frac>")
    ap.add_argument("--bw-Bps", type=float, default=0.0,
                    help="emulated link rate; 0 = unshaped loopback")
    ap.add_argument("--latency-s", type=float, default=0.0)
    ap.add_argument("--queue-depth", type=int, default=2)
    ap.add_argument("--stall-after-s", type=float, default=0.25)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--qos-out", default=None,
                    help="write the QoS snapshot JSON here")
    args = ap.parse_args(argv)

    cfg = LMConfig(name="stream", num_layers=args.layers,
                   d_model=args.d_model, n_heads=args.n_heads,
                   n_kv=args.n_kv, d_ff=args.d_ff, vocab=args.vocab,
                   dtype="float32")
    shaper = (LinkShaper(args.bw_Bps, latency_s=args.latency_s)
              if args.bw_Bps > 0 else None)
    estimator = LinkEstimator()

    result = asyncio.run(run_streaming(
        cfg, cut=args.cut, n_clients=args.clients, steps=args.steps,
        batch_per_client=args.batch_per_client, seq=args.seq,
        seed=args.seed, wire_dtype=args.wire_dtype, lr=args.lr,
        shaper=shaper, replanner=estimator,
        queue_depth=args.queue_depth, stall_after_s=args.stall_after_s))

    print(f"streaming: {args.clients} UE x {args.steps} steps "
          f"wire={args.wire_dtype} "
          f"loss {result['losses'][0]:.4f} -> {result['losses'][-1]:.4f}")
    hints = result.get("link_hints") or {}
    if hints:
        bw = hints.get("link_bw_Bps")
        oh = hints.get("hop_overhead_s")
        print("  measured link: "
              + (f"bw {bw:.3g} B/s" if bw else "bw n/a")
              + (f", overhead {oh * 1e3:.3g} ms" if oh else ""))
    honesty = result["wire_honesty"]
    for direction, rows in honesty.items():
        bad = [r for r in rows if not r["ok"]]
        print(f"  wire honesty {direction}: "
              f"{len(rows) - len(bad)}/{len(rows)} hops within 1%")
    if args.qos_out:
        with open(args.qos_out, "w") as f:
            json.dump(result["qos"], f, indent=2, sort_keys=True)
        print(f"  qos snapshot -> {args.qos_out}")
    return result


if __name__ == "__main__":
    main(sys.argv[1:])
