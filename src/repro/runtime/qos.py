"""QoS monitor for the streaming runtime (stdlib only).

Per-UE counters the in-process simulator cannot express — measured
arrival rates, queue occupancy, backpressure and straggler behaviour of
a real transport:

* **arrival rate** — EWMA of 1/inter-arrival time per client;
* **queue depth** — the BS-side bounded inbox occupancy (current and
  high-water) per client;
* **backpressure events** — arrivals that found the inbox full (the
  reader then blocks on ``put``, which stops draining the socket and
  pushes TCP backpressure down to the UE's ``drain()``);
* **stalls / stragglers** — rounds where the aggregator waited longer
  than ``stall_after_s`` on a client (stall), and which client closed
  each aggregation round (straggler).

``snapshot()`` returns a plain-JSON dict (the ``--qos-out`` payload and
the ``streaming_smoke`` bench's non-deterministic sidecar).
"""
from __future__ import annotations

import dataclasses
import time


@dataclasses.dataclass
class ClientStats:
    frames_in: int = 0
    frames_out: int = 0
    wire_bytes_in: int = 0          # full frames incl. prefix/header/meta
    wire_bytes_out: int = 0
    payload_bytes_in: int = 0       # codec payload only (billed hop bytes)
    payload_bytes_out: int = 0
    aux_bytes_in: int = 0           # labels/control sections
    last_arrival_t: float | None = None
    arrival_rate_hz: float | None = None
    queue_depth: int = 0
    queue_high_water: int = 0
    backpressure_events: int = 0
    stalls: int = 0
    straggler_rounds: int = 0


class QoSMonitor:
    def __init__(self, ewma: float = 0.7, stall_after_s: float = 0.25,
                 clock=time.monotonic):
        self.ewma = float(ewma)
        self.stall_after_s = float(stall_after_s)
        self.clock = clock
        self.clients: dict = {}
        self.rounds = 0

    def _c(self, client: int) -> ClientStats:
        if client not in self.clients:
            self.clients[client] = ClientStats()
        return self.clients[client]

    # -- feeds ---------------------------------------------------------------

    def record_arrival(self, client: int, wire_nbytes: int,
                       payload_nbytes: int, aux_nbytes: int = 0) -> None:
        c = self._c(client)
        now = self.clock()
        c.frames_in += 1
        c.wire_bytes_in += int(wire_nbytes)
        c.payload_bytes_in += int(payload_nbytes)
        c.aux_bytes_in += int(aux_nbytes)
        if c.last_arrival_t is not None:
            dt = max(now - c.last_arrival_t, 1e-9)
            rate = 1.0 / dt
            c.arrival_rate_hz = (rate if c.arrival_rate_hz is None
                                 else self.ewma * c.arrival_rate_hz
                                 + (1.0 - self.ewma) * rate)
        c.last_arrival_t = now

    def record_send(self, client: int, wire_nbytes: int,
                    payload_nbytes: int) -> None:
        c = self._c(client)
        c.frames_out += 1
        c.wire_bytes_out += int(wire_nbytes)
        c.payload_bytes_out += int(payload_nbytes)

    def record_queue_depth(self, client: int, depth: int) -> None:
        c = self._c(client)
        c.queue_depth = int(depth)
        c.queue_high_water = max(c.queue_high_water, int(depth))

    def record_backpressure(self, client: int) -> None:
        self._c(client).backpressure_events += 1

    def record_stall(self, client: int) -> None:
        self._c(client).stalls += 1

    def record_round(self, straggler: int | None) -> None:
        self.rounds += 1
        if straggler is not None:
            self._c(straggler).straggler_rounds += 1

    # -- export --------------------------------------------------------------

    def totals(self) -> dict:
        out = {"frames_in": 0, "frames_out": 0, "wire_bytes_in": 0,
               "wire_bytes_out": 0, "payload_bytes_in": 0,
               "payload_bytes_out": 0, "aux_bytes_in": 0,
               "backpressure_events": 0, "stalls": 0}
        for c in self.clients.values():
            for k in out:
                out[k] += getattr(c, k)
        return out

    def snapshot(self) -> dict:
        return {
            "rounds": self.rounds,
            "totals": self.totals(),
            "clients": {str(cid): dataclasses.asdict(c)
                        for cid, c in sorted(self.clients.items())},
        }
