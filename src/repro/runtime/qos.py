"""QoS monitor for the streaming runtime (stdlib only).

Per-UE counters the in-process simulator cannot express — measured
arrival rates, queue occupancy, backpressure and straggler behaviour of
a real transport:

* **arrival rate** — EWMA of 1/inter-arrival time per client;
* **queue depth** — the BS-side bounded inbox occupancy (current and
  high-water) per client;
* **backpressure events** — arrivals that found the inbox full (the
  reader then blocks on ``put``, which stops draining the socket and
  pushes TCP backpressure down to the UE's ``drain()``);
* **stalls / stragglers** — rounds where the aggregator waited longer
  than ``stall_after_s`` on a client (stall), and which client closed
  each aggregation round (straggler).

``snapshot()`` returns a plain-JSON dict (the ``--qos-out`` payload and
the ``streaming_smoke`` bench's non-deterministic sidecar).
"""
from __future__ import annotations

import dataclasses
import time


@dataclasses.dataclass
class ClientStats:
    frames_in: int = 0
    frames_out: int = 0
    wire_bytes_in: int = 0          # full frames incl. prefix/header/meta
    wire_bytes_out: int = 0
    payload_bytes_in: int = 0       # codec payload only (billed hop bytes)
    payload_bytes_out: int = 0
    aux_bytes_in: int = 0           # labels/control sections
    last_arrival_t: float | None = None
    arrival_rate_hz: float | None = None
    queue_depth: int = 0
    queue_high_water: int = 0
    backpressure_events: int = 0
    stalls: int = 0
    straggler_rounds: int = 0


class QoSMonitor:
    def __init__(self, ewma: float = 0.7, stall_after_s: float = 0.25,
                 clock=time.monotonic):
        self.ewma = float(ewma)
        self.stall_after_s = float(stall_after_s)
        self.clock = clock
        self.clients: dict = {}
        self.rounds = 0

    def _c(self, client: int) -> ClientStats:
        if client not in self.clients:
            self.clients[client] = ClientStats()
        return self.clients[client]

    # -- feeds ---------------------------------------------------------------

    def record_arrival(self, client: int, wire_nbytes: int,
                       payload_nbytes: int, aux_nbytes: int = 0) -> None:
        c = self._c(client)
        now = self.clock()
        c.frames_in += 1
        c.wire_bytes_in += int(wire_nbytes)
        c.payload_bytes_in += int(payload_nbytes)
        c.aux_bytes_in += int(aux_nbytes)
        if c.last_arrival_t is not None:
            dt = max(now - c.last_arrival_t, 1e-9)
            rate = 1.0 / dt
            c.arrival_rate_hz = (rate if c.arrival_rate_hz is None
                                 else self.ewma * c.arrival_rate_hz
                                 + (1.0 - self.ewma) * rate)
        c.last_arrival_t = now

    def record_send(self, client: int, wire_nbytes: int,
                    payload_nbytes: int) -> None:
        c = self._c(client)
        c.frames_out += 1
        c.wire_bytes_out += int(wire_nbytes)
        c.payload_bytes_out += int(payload_nbytes)

    def record_queue_depth(self, client: int, depth: int) -> None:
        c = self._c(client)
        c.queue_depth = int(depth)
        c.queue_high_water = max(c.queue_high_water, int(depth))

    def record_backpressure(self, client: int) -> None:
        self._c(client).backpressure_events += 1

    def record_stall(self, client: int) -> None:
        self._c(client).stalls += 1

    def record_round(self, straggler: int | None) -> None:
        self.rounds += 1
        if straggler is not None:
            self._c(straggler).straggler_rounds += 1

    # -- export --------------------------------------------------------------

    def totals(self) -> dict:
        out = {"frames_in": 0, "frames_out": 0, "wire_bytes_in": 0,
               "wire_bytes_out": 0, "payload_bytes_in": 0,
               "payload_bytes_out": 0, "aux_bytes_in": 0,
               "backpressure_events": 0, "stalls": 0}
        for c in self.clients.values():
            for k in out:
                out[k] += getattr(c, k)
        return out

    def snapshot(self) -> dict:
        return {
            "rounds": self.rounds,
            "totals": self.totals(),
            "clients": {str(cid): dataclasses.asdict(c)
                        for cid, c in sorted(self.clients.items())},
        }


# ---------------------------------------------------------------------------
# Serving-side QoS: per-request latency percentiles + admission counters.
# ---------------------------------------------------------------------------


def percentile(samples, q: float) -> float | None:
    """Nearest-rank percentile (q in [0, 100]) of a sample list.

    Deterministic and schema-stable (no interpolation): the value
    returned is always one of the samples.  None on an empty list.
    """
    if not samples:
        return None
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile q={q} must be in [0, 100]")
    xs = sorted(float(x) for x in samples)
    rank = max(1, int(-(-q * len(xs) // 100)))     # ceil(q/100 * n), >= 1
    return xs[min(rank, len(xs)) - 1]


@dataclasses.dataclass
class RequestTimeline:
    """Latency stamps of one serving request (wall clock + engine step)."""

    submit_t: float
    admit_t: float | None = None
    first_token_t: float | None = None
    done_t: float | None = None
    admit_step: int | None = None
    first_token_step: int | None = None
    done_step: int | None = None
    tokens: int = 0

    @property
    def ttft_s(self) -> float | None:
        """Submit -> first emitted token (queue wait + prefill + the
        first decode)."""
        if self.first_token_t is None:
            return None
        return self.first_token_t - self.submit_t

    @property
    def per_token_s(self) -> float | None:
        """Mean decode seconds per emitted token after the first."""
        if self.done_t is None or self.first_token_t is None \
                or self.tokens < 2:
            return None
        return (self.done_t - self.first_token_t) / (self.tokens - 1)


class ServingQoS:
    """Per-request latency percentiles + admission/reject counters for
    the continuous-batching serving engine (``repro.serving.engine``).

    The engine stamps submit/admit/first-token/done per request; the
    snapshot derives p50/p99 TTFT and per-token latency (nearest-rank,
    over COMPLETED requests) next to the admission counters.  ``clock``
    is injectable so tests can drive a scripted clock and pin exact
    percentile values.
    """

    def __init__(self, clock=time.monotonic):
        self.clock = clock
        self.requests: dict = {}
        self.admitted = 0
        self.rejected = 0
        self.completed = 0

    def _r(self, rid: int) -> RequestTimeline:
        if rid not in self.requests:
            raise KeyError(f"request {rid} was never submitted")
        return self.requests[rid]

    def record_submit(self, rid: int) -> None:
        if rid in self.requests:
            raise ValueError(f"request {rid} submitted twice")
        self.requests[rid] = RequestTimeline(submit_t=self.clock())

    def record_reject(self, rid: int) -> None:
        self.rejected += 1
        self.requests.pop(rid, None)

    def record_admit(self, rid: int, step: int) -> None:
        self.admitted += 1
        r = self._r(rid)
        r.admit_t = self.clock()
        r.admit_step = int(step)

    def record_token(self, rid: int, step: int) -> None:
        r = self._r(rid)
        r.tokens += 1
        if r.first_token_t is None:
            r.first_token_t = self.clock()
            r.first_token_step = int(step)

    def record_done(self, rid: int, step: int) -> None:
        self.completed += 1
        r = self._r(rid)
        r.done_t = self.clock()
        r.done_step = int(step)

    def latency_percentiles(self) -> dict:
        done = [r for r in self.requests.values() if r.done_t is not None]
        ttft = [r.ttft_s for r in done if r.ttft_s is not None]
        per_tok = [r.per_token_s for r in done if r.per_token_s is not None]
        return {
            "p50_ttft_s": percentile(ttft, 50),
            "p99_ttft_s": percentile(ttft, 99),
            "p50_tok_s": percentile(per_tok, 50),
            "p99_tok_s": percentile(per_tok, 99),
        }

    def snapshot(self) -> dict:
        return {
            "admitted": self.admitted,
            "rejected": self.rejected,
            "completed": self.completed,
            "in_flight": sum(1 for r in self.requests.values()
                             if r.admit_t is not None and r.done_t is None),
            "queued": sum(1 for r in self.requests.values()
                          if r.admit_t is None),
            "latency": self.latency_percentiles(),
            "tokens_emitted": sum(r.tokens for r in self.requests.values()),
        }
