"""Length-prefixed frame protocol for the UE -> BS streaming runtime.

One frame on the wire::

    u32 total_len | header (20 B) | meta (JSON) | payload sections

    header = !4s B B H I I I
             magic 'C2P2' | version | ftype | client_id
             | step | meta_len | payload_len

``payload`` is the concatenation of named binary sections; the meta JSON
carries a ``sections`` table ``[[name, dtype, shape], ...]`` so the
receiver can slice it back into numpy arrays with zero copies of the
section bytes.  Sections named in ``PAYLOAD_SECTIONS`` are codec payload
(what the planner bills as hop bytes); everything else (``labels``,
control fields) is aux traffic the QoS monitor accounts separately —
the same split ``analysis/staticcheck`` audits in compiled HLO, kept
honest here on a real socket (tests/test_streaming.py asserts measured
payload bytes match ``autotune.wire_bytes_per_element(_bwd)`` billing).

The activation/gradient payload encodings are the host-side
(``parallel/wire.py host_*``) twins of the in-process wire codec: dense
base codec on the forward (activation) hop, ``+topk<frac>`` sparsification
with per-client error feedback on the backward (gradient) hop, raw
passthrough for 'none' and for the degenerate-block net-loss fallback.
"""
from __future__ import annotations

import dataclasses
import json
import struct

import numpy as np

MAGIC = b"C2P2"
VERSION = 1

HELLO = 1     # client -> server: join (meta: wire_dtype, shapes)
ACT = 2       # client -> server: coded cut activations + labels
GRAD = 3      # server -> client: coded cut-activation gradient
STATS = 4     # either direction: QoS/telemetry snapshot
BYE = 5       # client -> server: clean shutdown
INFER = 6     # serving: UE->BS coded cut activation (phase=prefill/decode),
              # BS->UE sampled token reply (phase=tok, aux section, un-billed)

_HEADER = struct.Struct("!4sBBHIII")
_LEN = struct.Struct("!I")

# section names whose bytes are CODEC PAYLOAD (billed hop bytes); the
# rest of the frame (length prefix, header, meta JSON, aux sections such
# as labels) is per-message overhead — the planner bills that separately
# as hop_overhead_s, never as link bytes.
PAYLOAD_SECTIONS = ("q", "scale", "idx", "raw")

MAX_FRAME_BYTES = 1 << 30


def _np_dtype(name: str) -> np.dtype:
    """Resolve a section dtype name, including the ml_dtypes names
    (float8_e4m3fn, bfloat16) numpy alone does not know."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


@dataclasses.dataclass
class Frame:
    """A decoded frame: typed header + meta dict + named numpy sections."""

    ftype: int
    client: int
    step: int
    meta: dict
    arrays: dict
    wire_nbytes: int        # total bytes on the socket (prefix included)

    @property
    def payload_nbytes(self) -> int:
        """Codec-payload bytes only (the billed hop traffic)."""
        return sum(a.nbytes for name, a in self.arrays.items()
                   if name in PAYLOAD_SECTIONS)

    @property
    def aux_nbytes(self) -> int:
        return sum(a.nbytes for name, a in self.arrays.items()
                   if name not in PAYLOAD_SECTIONS)


def pack_frame(ftype: int, client: int, step: int, meta: dict | None = None,
               arrays: dict | None = None) -> bytes:
    meta = dict(meta or {})
    arrays = arrays or {}
    sections = []
    chunks = []
    for name, arr in arrays.items():
        arr = np.ascontiguousarray(arr)
        sections.append([name, arr.dtype.name, list(arr.shape)])
        chunks.append(arr.tobytes())
    meta["sections"] = sections
    meta_b = json.dumps(meta, separators=(",", ":")).encode()
    payload = b"".join(chunks)
    header = _HEADER.pack(MAGIC, VERSION, int(ftype), int(client),
                          int(step), len(meta_b), len(payload))
    body = header + meta_b + payload
    return _LEN.pack(len(body)) + body


def unpack_frame(body: bytes, *, wire_nbytes: int | None = None) -> Frame:
    """Decode a frame body (everything after the length prefix)."""
    magic, version, ftype, client, step, meta_len, payload_len = \
        _HEADER.unpack_from(body)
    if magic != MAGIC:
        raise ValueError(f"bad frame magic {magic!r} (expected {MAGIC!r})")
    if version != VERSION:
        raise ValueError(f"frame version {version} != {VERSION}")
    if _HEADER.size + meta_len + payload_len != len(body):
        raise ValueError(
            f"frame length mismatch: header says "
            f"{_HEADER.size + meta_len + payload_len}, body is {len(body)}")
    meta = json.loads(body[_HEADER.size:_HEADER.size + meta_len])
    payload = body[_HEADER.size + meta_len:]
    arrays = {}
    off = 0
    for name, dtype, shape in meta.pop("sections", []):
        dt = _np_dtype(dtype)
        n = int(np.prod(shape, dtype=np.int64)) if shape else 1
        nb = n * dt.itemsize
        arrays[name] = np.frombuffer(
            payload[off:off + nb], dtype=dt).reshape(shape)
        off += nb
    if off != payload_len:
        raise ValueError(
            f"payload sections cover {off} bytes, header says {payload_len}")
    return Frame(ftype=ftype, client=client, step=step, meta=meta,
                 arrays=arrays,
                 wire_nbytes=(wire_nbytes if wire_nbytes is not None
                              else _LEN.size + len(body)))


async def read_frame(reader) -> Frame:
    """Read one length-prefixed frame from an asyncio StreamReader."""
    prefix = await reader.readexactly(_LEN.size)
    (n,) = _LEN.unpack(prefix)
    if n > MAX_FRAME_BYTES:
        raise ValueError(f"frame of {n} bytes exceeds MAX_FRAME_BYTES")
    body = await reader.readexactly(n)
    return unpack_frame(body, wire_nbytes=_LEN.size + n)


# ---------------------------------------------------------------------------
# Activation / gradient payload codecs (host twins of parallel/wire.py).
# ---------------------------------------------------------------------------


def encode_act_payload(x, wire_dtype: str):
    """Cut activation [..., d] -> (arrays, meta fields) for an ACT frame.

    Dense base codec ('none' and the net-loss condition ship raw) — the
    forward hop never sparsifies, exactly like the in-process pipeline.
    """
    from repro.parallel import wire
    x = np.asarray(x)
    q, scale = wire.host_encode(x, wire_dtype)
    meta = {"codec": str(wire_dtype), "shape": list(x.shape),
            "dtype": x.dtype.name}
    if scale is None:
        return {"raw": q}, dict(meta, kind="raw")
    return {"q": q, "scale": scale}, dict(meta, kind="dense")


def decode_act_payload(frame: Frame) -> np.ndarray:
    out_dtype = _np_dtype(frame.meta["dtype"])
    if frame.meta["kind"] == "raw":
        return frame.arrays["raw"].astype(out_dtype)
    from repro.parallel import wire
    return wire.host_decode(frame.arrays["q"], frame.arrays["scale"],
                            out_dtype)


def encode_grad_payload(g, wire_dtype: str, ef=None):
    """Cut-activation gradient [..., d] -> (arrays, meta, new_ef).

    ``+topk<frac>`` codecs sparsify this reverse hop with per-client
    error feedback: the BS keeps one f32 residual per client, adds it
    before selection and carries the un-shipped mass forward — the
    streaming twin of ``wire.coded_ppermute_ef``'s backward rule
    (including its raw fallback at a degenerate block, where the
    residual passes through unchanged).  Dense codecs are
    direction-symmetric and carry no EF.
    """
    from repro.parallel import wire
    g = np.asarray(g)
    base, frac = wire.parse_wire_dtype(wire_dtype)
    d = g.shape[-1]
    meta = {"codec": str(wire_dtype), "shape": list(g.shape),
            "dtype": g.dtype.name}
    if frac is None:
        arrays, m = encode_act_payload(g, wire_dtype)
        return arrays, dict(meta, kind=m["kind"]), ef
    if wire.codec_net_loss(d, g.dtype.itemsize):
        return {"raw": g}, dict(meta, kind="raw"), ef
    corrected = g.astype(np.float32) + (0.0 if ef is None else ef)
    q, idx, scale = wire.host_topk_encode(corrected, wire_dtype)
    dec_local = wire.host_topk_decode(q, idx, scale, d, np.float32)
    return ({"q": q, "idx": idx, "scale": scale},
            dict(meta, kind="topk"), corrected - dec_local)


def decode_grad_payload(frame: Frame) -> np.ndarray:
    out_dtype = _np_dtype(frame.meta["dtype"])
    kind = frame.meta["kind"]
    if kind == "raw":
        return frame.arrays["raw"].astype(out_dtype)
    from repro.parallel import wire
    if kind == "dense":
        return wire.host_decode(frame.arrays["q"], frame.arrays["scale"],
                                out_dtype)
    d = frame.meta["shape"][-1]
    return wire.host_topk_decode(frame.arrays["q"], frame.arrays["idx"],
                                 frame.arrays["scale"], d, out_dtype)


def billed_hop_bytes(n_elements: int, d_model: int, wire_dtype: str,
                     act_bytes: float, *, backward: bool = False) -> float:
    """What the planner bills this hop: ``autotune.wire_bytes_per_element``
    (or ``_bwd``) x elements, at the effective block for this width —
    the number the measured ``Frame.payload_nbytes`` must match (1% rtol
    acceptance; the discrete ``round(frac*d)`` top-k count is the only
    divergence from the planner's continuous ``frac``)."""
    from repro.analysis import autotune
    block = autotune.wire_block_for(int(d_model))
    if backward:
        per = autotune.wire_bytes_per_element_bwd(
            wire_dtype, act_bytes, block, d_model=int(d_model))
    else:
        per = autotune.wire_bytes_per_element(wire_dtype, act_bytes, block)
    return float(per) * int(n_elements)
