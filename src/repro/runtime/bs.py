"""BS-side dispatcher: socket server, bounded inboxes, micro-batch
aggregation, and the measured-hop feed into the online re-planner.

Data path per training round (the C2P2SL server pipeline):

* every UE's ACT frame lands in that client's BOUNDED inbox
  (``asyncio.Queue(maxsize=queue_depth)``).  A full inbox blocks the
  per-connection reader coroutine, which stops draining the socket —
  TCP backpressure then throttles the UE's ``drain()``.  Clients may
  run ahead of the trainer by at most ``queue_depth`` rounds.
* the aggregator takes exactly ONE frame per client per round, in
  ARRIVAL order: each arrival immediately runs the BS-side micro step
  (forward + backward of blocks[l:] on that client's shard) and ships
  the coded cut-activation gradient straight back — server compute
  overlaps the stragglers' uplinks, which is the pipeline-parallel
  schedule of the paper, event-driven instead of simulated.
* the optimizer update applies once per round on the sorted-client mean
  of the per-shard grads, so the result is independent of arrival
  order (tested).

Every hop is measured: uplink frames carry ``t_send`` (one host, one
monotonic clock), downlink times are measured by the UE and reported in
its next frame; both feed ``Replanner.observe_hop`` /
``LinkEstimator.observe_hop`` — the re-planner's ``PlanInputs`` then
track the REAL transport (or the ``LinkShaper``-emulated channel), with
no scripted ``BandwidthTrace`` anywhere in the loop.
"""
from __future__ import annotations

import asyncio
import time

import numpy as np

from repro.runtime import protocol
from repro.runtime.qos import QoSMonitor


class BSDispatcher:
    def __init__(self, split, bs_params, opt, *, n_clients: int,
                 wire_dtype: str = "none", queue_depth: int = 2,
                 replanner=None, shaper=None, qos: QoSMonitor | None = None,
                 stall_after_s: float = 0.25,
                 host: str = "127.0.0.1", port: int = 0):
        import jax
        import jax.numpy as jnp
        self.split = split
        self.bs_params = bs_params
        self.opt = opt
        self.opt_state = opt.init(bs_params)
        self.n_clients = int(n_clients)
        self.wire_dtype = str(wire_dtype)
        self.queue_depth = int(queue_depth)
        self.replanner = replanner
        self.shaper = shaper
        self.qos = qos or QoSMonitor(stall_after_s=stall_after_s)
        self.stall_after_s = float(stall_after_s)
        self.host, self.port = host, int(port)
        self._server = None
        self._clients: dict = {}          # cid -> (inbox, writer)
        self._all_joined = asyncio.Event()
        self._ef: dict = {}               # cid -> per-client EF residual
        self.losses: list = []
        # wire-honesty audit: (payload_bytes, n_elements, d, act_itemsize)
        self.hop_audit = {"uplink": set(), "downlink": set()}
        self._jnp = jnp

        def micro(bs_params, acts, labels):
            (loss, _mets), (bs_g, act_g) = jax.value_and_grad(
                split.bs_loss, argnums=(0, 1), has_aux=True)(
                    bs_params, acts, labels)
            return loss, bs_g, act_g

        self._micro = jax.jit(micro)

        def mean_update(grads_list, opt_state, params, step):
            mean = jax.tree.map(
                lambda *gs: sum(gs[1:], gs[0]) / len(gs), *grads_list)
            return opt.update(mean, opt_state, params, step)

        self._mean_update = jax.jit(mean_update)

    # -- transport -----------------------------------------------------------

    async def start(self):
        self._server = await asyncio.start_server(
            self._handle_client, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        return self.host, self.port

    async def close(self):
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()

    def _observe_hop(self, nbytes, seconds):
        if self.replanner is not None and nbytes and seconds \
                and seconds > 0:
            self.replanner.observe_hop(float(nbytes), float(seconds))

    def _observe_frame(self, frame: protocol.Frame, t_recv: float) -> None:
        t_send = frame.meta.get("t_send")
        if t_send is not None:
            self._observe_hop(frame.wire_nbytes, t_recv - float(t_send))
        # the UE piggybacks its measurement of our PREVIOUS downlink
        self._observe_hop(frame.meta.get("dl_nbytes"),
                          frame.meta.get("dl_s"))

    async def _handle_client(self, reader, writer):
        hello = await protocol.read_frame(reader)
        if hello.ftype != protocol.HELLO:
            writer.close()
            raise ValueError(
                f"client handshake must be HELLO, got ftype={hello.ftype}")
        cid = hello.client
        inbox = asyncio.Queue(maxsize=self.queue_depth)
        self._clients[cid] = (inbox, writer)
        if len(self._clients) >= self.n_clients:
            self._all_joined.set()
        while True:
            try:
                frame = await protocol.read_frame(reader)
            except (asyncio.IncompleteReadError, ConnectionError):
                break
            t_recv = time.monotonic()
            self._observe_frame(frame, t_recv)
            if frame.ftype == protocol.BYE:
                break
            if frame.ftype != protocol.ACT:
                continue                   # STATS etc.: telemetry only
            shape = frame.meta["shape"]
            self.hop_audit["uplink"].add(
                (frame.payload_nbytes,
                 int(np.prod(shape, dtype=np.int64)), int(shape[-1]),
                 int(protocol._np_dtype(frame.meta["dtype"]).itemsize)))
            self.qos.record_arrival(cid, frame.wire_nbytes,
                                    frame.payload_nbytes, frame.aux_nbytes)
            if inbox.full():
                self.qos.record_backpressure(cid)
            await inbox.put(frame)
            self.qos.record_queue_depth(cid, inbox.qsize())

    async def _send_grad(self, cid: int, step: int, act_grad, loss) -> None:
        _inbox, writer = self._clients[cid]
        g = np.asarray(act_grad)
        arrays, meta, new_ef = protocol.encode_grad_payload(
            g, self.wire_dtype, self._ef.get(cid))
        self._ef[cid] = new_ef
        meta["loss"] = float(loss)
        meta["t_send"] = time.monotonic()
        frame = protocol.pack_frame(protocol.GRAD, cid, step,
                                    meta=meta, arrays=arrays)
        payload_nbytes = sum(a.nbytes for n, a in arrays.items()
                             if n in protocol.PAYLOAD_SECTIONS)
        self.hop_audit["downlink"].add(
            (payload_nbytes, int(g.size), int(g.shape[-1]),
             int(g.dtype.itemsize)))
        if self.shaper is not None:
            await asyncio.sleep(self.shaper.delay_s(len(frame)))
        writer.write(frame)
        await writer.drain()
        self.qos.record_send(cid, len(frame), payload_nbytes)

    # -- training ------------------------------------------------------------

    async def train(self, steps: int):
        """Run ``steps`` aggregation rounds; returns per-round losses."""
        await self._all_joined.wait()
        for step in range(steps):
            per_client: dict = {}
            pending = {
                asyncio.ensure_future(inbox.get()): cid
                for cid, (inbox, _w) in self._clients.items()}
            straggler = None
            while pending:
                done, _ = await asyncio.wait(
                    pending, timeout=self.stall_after_s,
                    return_when=asyncio.FIRST_COMPLETED)
                if not done:
                    for cid in pending.values():
                        self.qos.record_stall(cid)
                    continue
                for task in done:
                    cid = pending.pop(task)
                    frame = task.result()
                    inbox, _w = self._clients[cid]
                    self.qos.record_queue_depth(cid, inbox.qsize())
                    acts = protocol.decode_act_payload(frame)
                    labels = frame.arrays["labels"]
                    loss, bs_g, act_g = self._micro(
                        self.bs_params, acts, labels)
                    per_client[cid] = (float(loss), bs_g)
                    straggler = cid
                    # 1F1B, event-driven: the gradient leaves NOW, while
                    # other clients' uplinks are still in flight
                    await self._send_grad(cid, step, act_g, loss)
            ordered = sorted(per_client)
            grads_list = [per_client[c][1] for c in ordered]
            step_arr = self._jnp.asarray(step, self._jnp.int32)
            self.bs_params, self.opt_state = self._mean_update(
                grads_list, self.opt_state, self.bs_params, step_arr)
            self.losses.append(
                float(np.mean([per_client[c][0] for c in ordered])))
            self.qos.record_round(straggler)
        return self.losses

    # -- audits --------------------------------------------------------------

    def wire_honesty(self, rtol: float = 0.01) -> dict:
        """Measured socket payload bytes per hop vs planner billing.

        Returns per-direction rows of (measured, billed, ok); ``ok``
        within ``rtol`` is the off-simulator honesty acceptance gate.
        """
        out = {}
        for direction, rows in self.hop_audit.items():
            ent = []
            for payload_nbytes, n_el, d, itemsize in sorted(rows):
                billed = protocol.billed_hop_bytes(
                    n_el, d, self.wire_dtype, float(itemsize),
                    backward=(direction == "downlink"))
                ent.append({
                    "measured_bytes": int(payload_nbytes),
                    "billed_bytes": billed,
                    "ok": bool(abs(payload_nbytes - billed)
                               <= rtol * max(billed, 1.0)),
                })
            out[direction] = ent
        return out
