"""UE-side client of the streaming runtime.

Each client task owns one UE's data shard and runs the SUB-CUT layers
(``sl.split.lm_split``: embedding + blocks[:l]) locally, per round:

    fwd sub-cut -> host-encode activation (dense base codec)
      -> ACT frame over the socket (shaped by the LinkShaper)
      -> await GRAD frame (the BS's coded cut-activation gradient)
      -> decode -> vjp through the sub-cut -> per-round client sync

The client also TIMES the downlink hop (GRAD frame ``t_send`` -> local
receive) and reports it in the next ACT frame's meta, so the BS-side
``LinkEstimator`` sees measured samples of BOTH directions.

``UESync`` is the per-round aggregation of client-side gradients
(C2P2SL keeps every UE's sub-model synchronized each round — the
FedAvg-style client-model update of parallel split learning).  It runs
in-process because all UE tasks share this driver process; the SL wire
hops — activations up, gradients down — are what crosses the socket and
what the paper's communication model bills.
"""
from __future__ import annotations

import asyncio
import time

import numpy as np

from repro.runtime import protocol


class UESync:
    """Round barrier + mean of per-client UE-side grads + one optimizer.

    All clients hold the SAME ue_params; ``apply`` blocks until every
    client of the round has contributed, applies the mean update once,
    and releases them all with the new params.  The mean is reduced in
    sorted-client order, so the result is independent of arrival order.
    """

    def __init__(self, params, opt, n_clients: int):
        import jax
        import jax.numpy as jnp
        self.params = params
        self.opt = opt
        self.opt_state = opt.init(params)
        self.n_clients = int(n_clients)
        self.round = 0
        self._grads: dict = {}
        self._cond = asyncio.Condition()
        self._jnp = jnp

        def mean_update(grads_list, opt_state, params, step):
            mean = jax.tree.map(
                lambda *gs: sum(gs[1:], gs[0]) / len(gs), *grads_list)
            return opt.update(mean, opt_state, params, step)

        self._mean_update = jax.jit(mean_update)

    async def apply(self, client: int, grads):
        async with self._cond:
            self._grads[client] = grads
            if len(self._grads) == self.n_clients:
                ordered = [self._grads[c] for c in sorted(self._grads)]
                step = self._jnp.asarray(self.round, self._jnp.int32)
                self.params, self.opt_state = self._mean_update(
                    ordered, self.opt_state, self.params, step)
                self._grads = {}
                self.round += 1
                self._cond.notify_all()
            else:
                target = self.round + 1
                await self._cond.wait_for(lambda: self.round >= target)
            return self.params


class UEClient:
    """One UE: connects, then streams ``steps`` rounds of SL hops."""

    def __init__(self, client_id: int, split, data_iter, sync: UESync, *,
                 wire_dtype: str = "none", shaper=None, ue_fwd=None,
                 ue_pullback=None):
        import jax
        self.client_id = int(client_id)
        self.split = split
        self.data_iter = data_iter
        self.sync = sync
        self.wire_dtype = str(wire_dtype)
        self.shaper = shaper
        # jitted sub-cut forward and pullback; shareable across clients
        # (identical shapes -> the driver passes one pair to all four)
        self.ue_fwd = ue_fwd or jax.jit(split.ue_fwd)
        if ue_pullback is None:
            def pullback(params, tokens, g):
                _, vjp = jax.vjp(lambda p: split.ue_fwd(p, tokens), params)
                return vjp(g)[0]
            ue_pullback = jax.jit(pullback)
        self.ue_pullback = ue_pullback
        self.steps_done = 0
        self.losses: list = []

    async def _send(self, writer, payload: bytes):
        if self.shaper is not None:
            await asyncio.sleep(self.shaper.delay_s(len(payload)))
        writer.write(payload)
        await writer.drain()

    async def run(self, host: str, port: int, steps: int):
        reader, writer = await asyncio.open_connection(host, port)
        cid = self.client_id
        try:
            hello = protocol.pack_frame(
                protocol.HELLO, cid, 0,
                meta={"wire_dtype": self.wire_dtype})
            await self._send(writer, hello)
            dl_report = {}
            for step in range(steps):
                tokens, labels = next(self.data_iter)
                params = self.sync.params
                acts = np.asarray(self.ue_fwd(params, tokens))
                arrays, meta = protocol.encode_act_payload(
                    acts, self.wire_dtype)
                arrays["labels"] = np.asarray(labels, np.int32)
                meta.update(dl_report)
                meta["t_send"] = time.monotonic()
                frame = protocol.pack_frame(protocol.ACT, cid, step,
                                            meta=meta, arrays=arrays)
                # t_send is stamped before the shaper sleep on purpose:
                # the emulated serialization delay IS hop time, exactly
                # what the BS-side LinkEstimator should measure
                await self._send(writer, frame)

                grad_frame = await protocol.read_frame(reader)
                t_recv = time.monotonic()
                assert grad_frame.ftype == protocol.GRAD
                assert grad_frame.step == step
                dl_report = {
                    "dl_nbytes": grad_frame.wire_nbytes,
                    "dl_s": t_recv - grad_frame.meta["t_send"],
                }
                g = protocol.decode_grad_payload(grad_frame).astype(
                    acts.dtype)
                ue_grads = self.ue_pullback(params, tokens, g)
                self.losses.append(float(grad_frame.meta["loss"]))
                await self.sync.apply(cid, ue_grads)
                self.steps_done += 1
            bye = protocol.pack_frame(protocol.BYE, cid, steps,
                                      meta=dl_report)
            await self._send(writer, bye)
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass
