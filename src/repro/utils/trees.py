"""Small pytree utilities used across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_count(tree) -> int:
    """Total number of scalar elements in a pytree of arrays."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    """Total bytes of a pytree of arrays / ShapeDtypeStructs."""
    return sum(
        int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree.leaves(tree)
    )


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_cast(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)
