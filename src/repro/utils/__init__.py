from repro.utils.trees import tree_bytes, tree_count, tree_zeros_like, tree_cast
