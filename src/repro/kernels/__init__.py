"""Pallas TPU kernels for the zoo's compute hot-spots.

The paper itself contributes no kernels (its insight is a schedule);
these cover the hot loops of the ASSIGNED architectures, each with an
explicit BlockSpec VMEM tiling, a jit'd wrapper (ops.py) and a pure-jnp
oracle (ref.py) asserted allclose in tests/test_kernels.py:

  flash_attention.py  blocked online-softmax attention (GQA via index map)
  rglru.py            fused RG-LRU linear recurrence (recurrentgemma)
  rwkv6.py            chunked data-dependent-decay WKV as MXU matmuls
  moe_gmm.py          grouped expert matmul with f32 VMEM accumulator

On CPU (this container) the wrappers run interpret=True; on a TPU backend
the same calls compile to Mosaic.
"""
from repro.kernels import ops
from repro.kernels import ref
