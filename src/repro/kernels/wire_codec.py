"""Fused Pallas wire-codec kernels for the pipeline hop payload.

PR 5's wire codec (``parallel/wire.py``) block-quantizes the cut
activation with separate jnp ops — absmax reduce, scale clamp, divide,
round/clip, cast — each a round trip through HBM.  These kernels fuse the
whole encode (and decode) into one ``pallas_call`` per direction: a row
tile of the activation is loaded into VMEM once, per-block scales are
computed and the quantized payload + fp32 scales are written out, at
~memory-bandwidth cost (the bench: benchmarks/wire_codec.py, which also
feeds the measured ``codec_s_per_byte`` planner hint).

Layout contract (identical to the jnp reference path):

    x [..., d]  ->  payload [..., d/b, b] int8|fp8-e4m3, scales [..., d/b, 1]

with ``b = wire_block(d)`` — the largest divisor of d_model <= 256, so
the wire never carries padding bytes.  The kernel body mirrors
``training.compress.quantize_blocks`` op for op (astype f32 -> blocked
absmax -> ``max(amax/qmax, 1e-12)`` -> divide -> round/clip/cast), so
interpret mode is BIT-IDENTICAL to the jnp path — the parity contract
tests/test_wire_codec.py locks.  On a TPU backend the same body compiles
to Mosaic; off-TPU callers (``kernels/ops.py``) run ``interpret=True``.

``wire_block`` lives here (the kernel layer owns its blocking);
``parallel/wire.py`` re-exports it.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.training.compress import payload_dtype, qmax_for


# On-wire HLO element type of the quantized payload each base codec puts
# on the hop (what the ppermute'd buffer must spell in compiled HLO).
# fp8 payloads are ``s8`` too: ``wire._wire_ppermute`` bitcasts 1-byte
# float payloads to int8 around the collective so no backend
# legalization can re-inflate the wire (XLA:CPU upcasts f8 collectives
# to f16).  The kernel layer owns the payload format, so the canonical
# mapping lives here; ``repro.analysis.staticcheck`` mirrors it
# numpy-only (this module imports jax/pallas) and a tier-1 test pins the
# two copies together — change one without the other and the auditor's
# contract test fails.
PAYLOAD_HLO_DTYPE = {"int8": "s8", "fp8": "s8"}


def wire_block(dim: int, block: int = 256) -> int:
    """Largest block size <= ``block`` dividing ``dim`` (no padding)."""
    b = min(block, max(dim, 1))
    while dim % b:
        b -= 1
    return b


def _row_tile(rows: int, cap: int = 128) -> int:
    """Largest divisor of ``rows`` <= ``cap`` — the per-grid-step row
    count (full rows only: blocks never straddle a tile)."""
    t = min(cap, max(rows, 1))
    while rows % t:
        t -= 1
    return t


def _encode_kernel(x_ref, q_ref, s_ref, *, nb: int, b: int, wire_dtype: str):
    # Mirror of training.compress.quantize_blocks, op for op, on one
    # [rt, d] row tile resident in VMEM.
    x = x_ref[...].astype(jnp.float32)
    blocks = x.reshape(x.shape[0], nb, b)
    amax = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True)
    scale = jnp.maximum(amax / qmax_for(wire_dtype), 1e-12)
    scaled = blocks / scale
    if wire_dtype == "int8":
        q = jnp.clip(jnp.round(scaled), -127, 127).astype(jnp.int8)
    else:
        q = scaled.astype(payload_dtype("fp8"))
    q_ref[...] = q
    s_ref[...] = scale


def _decode_kernel(q_ref, s_ref, o_ref, *, out_dtype):
    x = q_ref[...].astype(jnp.float32) * s_ref[...]
    o_ref[...] = x.reshape(x.shape[0], -1).astype(out_dtype)


def encode_fused(x, wire_dtype: str, *, interpret: bool = False):
    """[..., d] -> (payload [..., d/b, b], fp32 scales [..., d/b, 1]) in
    one fused pass; bit-identical to the jnp reference in interpret mode."""
    d = x.shape[-1]
    b = wire_block(d)
    nb = d // b
    lead = x.shape[:-1]
    rows = max(1, math.prod(lead))
    x2 = x.reshape(rows, d)
    rt = _row_tile(rows)
    qdt = payload_dtype(wire_dtype)
    q, s = pl.pallas_call(
        functools.partial(_encode_kernel, nb=nb, b=b, wire_dtype=wire_dtype),
        grid=(rows // rt,),
        in_specs=[pl.BlockSpec((rt, d), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((rt, nb, b), lambda i: (i, 0, 0)),
            pl.BlockSpec((rt, nb, 1), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, nb, b), qdt),
            jax.ShapeDtypeStruct((rows, nb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x2)
    return q.reshape(lead + (nb, b)), s.reshape(lead + (nb, 1))


def decode_fused(q, scale, out_dtype, *, interpret: bool = False):
    """(payload [..., d/b, b], scales [..., d/b, 1]) -> [..., d] at
    ``out_dtype``; the fused inverse of ``encode_fused``."""
    nb, b = q.shape[-2], q.shape[-1]
    lead = q.shape[:-2]
    rows = max(1, math.prod(lead))
    odt = jnp.dtype(out_dtype)
    q2 = q.reshape(rows, nb, b)
    s2 = scale.reshape(rows, nb, 1)
    rt = _row_tile(rows)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, out_dtype=odt),
        grid=(rows // rt,),
        in_specs=[
            pl.BlockSpec((rt, nb, b), lambda i: (i, 0, 0)),
            pl.BlockSpec((rt, nb, 1), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((rt, nb * b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, nb * b), odt),
        interpret=interpret,
    )(q2, s2)
    return out.reshape(lead + (nb * b,))
