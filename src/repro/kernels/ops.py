"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) every kernel runs in ``interpret=True`` mode —
the kernel body executes as traced JAX ops, which is what the tests
validate against the ``ref.py`` oracles.  On a real TPU backend the same
calls compile to Mosaic.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import moe_gmm as _gmm
from repro.kernels import rglru as _rglru
from repro.kernels import rwkv6 as _rwkv6
from repro.kernels import wire_codec as _wc


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0):
    """q [B,Hq,S,dh], k/v [B,Hkv,S,dh] -> [B,Hq,S,dh]."""
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               interpret=_interpret())


@jax.jit
def rglru_scan(x_gated, log_a, h0=None):
    """[B,S,R] fused RG-LRU -> (h [B,S,R], h_last [B,R])."""
    return _rglru.rglru_scan(x_gated, log_a, h0, interpret=_interpret())


@jax.jit
def wkv6(r, k, v, w, u, s0=None):
    """[B,S,H,dh] chunked WKV6 -> (out, final_state [B,H,dh,dh])."""
    return _rwkv6.wkv6(r, k, v, w, u, s0, interpret=_interpret())


@jax.jit
def moe_gmm(h, w):
    """Grouped matmul h [E,C,D] @ w [E,D,F] -> [E,C,F]."""
    return _gmm.moe_gmm(h, w, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("wire_dtype",))
def wire_encode(x, *, wire_dtype: str = "int8"):
    """Fused wire-codec encode: [..., d] -> (payload, fp32 scales).
    Bit-identical to parallel.wire's jnp reference path (tested)."""
    return _wc.encode_fused(x, wire_dtype, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("out_dtype",))
def wire_decode(q, scale, *, out_dtype="bfloat16"):
    """Fused wire-codec decode: (payload, scales) -> [..., d]."""
    return _wc.decode_fused(q, scale, out_dtype, interpret=_interpret())
