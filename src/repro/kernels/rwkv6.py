"""Pallas TPU chunked WKV6: the RWKV6 recurrence as MXU matmuls.

Same math as ``repro.models.rwkv.wkv6_chunked`` (the oracle): the sequence
is cut into chunks of C; within a chunk the data-dependent-decay recurrence
becomes a lower-triangular [C, C] attention-like product (two MXU matmuls)
plus a state term; the [dh, dh] state advances once per chunk.

Grid: (batch, heads, chunks) — chunks minor; the f32 state matrix lives in
VMEM scratch across the sequential chunk steps.  Block shapes: [C, dh]
tiles for r/k/v/w and a [dh, dh] state tile; with C = dh = 64..128 the
matmuls are MXU-shaped.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 64


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sout_ref,
                 s_ref, *, chunk: int, n_chunks: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        s_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    rc = r_ref[0, 0, 0].astype(jnp.float32)    # [C, dh]
    kc = k_ref[0, 0, 0].astype(jnp.float32)
    vc = v_ref[0, 0, 0].astype(jnp.float32)
    wc = w_ref[0, 0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)           # [dh]

    logw = jnp.log(jnp.maximum(wc, 1e-30))
    lcum = jnp.cumsum(logw, axis=0)            # L_{t+1}
    l_t = lcum - logw                          # L_t (exclusive cumsum)
    l_total = lcum[-1:]                        # L_C  [1, dh]
    m = 0.5 * l_total

    r_t = rc * jnp.exp(l_t - m)
    k_j = kc * jnp.exp(m - lcum)
    att = r_t @ k_j.T                          # [C, C] (MXU)
    causal = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), -1)
    att = att * causal
    diag = jnp.sum(rc * (u[None] * kc), axis=1)  # [C]
    state = s_ref[...]
    o = att @ vc + diag[:, None] * vc \
        + (rc * jnp.exp(l_t)) @ state          # [C, dh] (MXU)

    k_hat = kc * jnp.exp(l_total - lcum)
    s_ref[...] = jnp.exp(l_total[0])[:, None] * state + k_hat.T @ vc
    o_ref[0, 0, 0] = o.astype(o_ref.dtype)

    @pl.when(c == n_chunks - 1)
    def _last():
        sout_ref[0, 0] = s_ref[...].astype(sout_ref.dtype)


def wkv6(r, k, v, w, u, s0=None, *, chunk: int = DEFAULT_CHUNK,
         interpret: bool = False):
    """r,k,v,w: [B, S, H, dh]; u: [H, dh]; s0: [B, H, dh, dh] f32.

    Returns (out [B, S, H, dh], final_state [B, H, dh, dh] f32).
    """
    b, s, h, dh = r.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk
    if s0 is None:
        s0 = jnp.zeros((b, h, dh, dh), jnp.float32)

    # [B, S, H, dh] -> [B, H, n_chunks, C, dh] block-friendly layout
    def prep(a):
        return jnp.moveaxis(a, 2, 1).reshape(b, h, n_chunks, chunk, dh)

    rs, ks, vs, ws = map(prep, (r, k, v, w))

    grid = (b, h, n_chunks)
    kernel = functools.partial(_wkv6_kernel, chunk=chunk, n_chunks=n_chunks)
    o, s_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, dh),
                         lambda b_, h_, c: (b_, h_, c, 0, 0))
            for _ in range(4)
        ] + [
            pl.BlockSpec((1, dh), lambda b_, h_, c: (h_, 0)),
            pl.BlockSpec((1, 1, dh, dh), lambda b_, h_, c: (b_, h_, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, chunk, dh),
                         lambda b_, h_, c: (b_, h_, c, 0, 0)),
            pl.BlockSpec((1, 1, dh, dh), lambda b_, h_, c: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, n_chunks, chunk, dh), r.dtype),
            jax.ShapeDtypeStruct((b, h, dh, dh), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dh, dh), jnp.float32)],
        interpret=interpret,
    )(rs, ks, vs, ws, u, s0)
    o = jnp.moveaxis(o.reshape(b, h, s, dh), 1, 2)
    return o, s_out
