"""Pallas TPU flash attention (forward): blocked online softmax.

Grid: (batch, q_heads, q_blocks, kv_blocks) — kv minor, so each (b, h, i)
program sequence walks kv blocks left to right accumulating the online
softmax in VMEM scratch; the output block is written on the last kv step.

BlockSpecs keep one (bq, dh) query tile, one (bk, dh) key/value tile, and
the f32 accumulator in VMEM.  GQA is handled in the index map (kv head =
q head // group), so grouped K/V are never materialized per q-head.
Causal and sliding-window masking are positional (no mask tensor in HBM).

The MXU sees two matmuls per tile: [bq, dh] @ [dh, bk] and [bq, bk] @
[bk, dh] — both dims multiples of 128 for the production block sizes.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

DEFAULT_BQ = 128
DEFAULT_BK = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int, bq: int, bk: int,
                  n_kv: int):
    i = pl.program_id(2)          # q block
    j = pl.program_id(3)          # kv block

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale        # [bq, dh]
    k = k_ref[0, 0].astype(jnp.float32)                # [bk, dh]
    v = v_ref[0, 0].astype(jnp.float32)

    s = q @ k.T                                        # [bq, bk]
    q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window > 0:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                # [bq]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_new

    @pl.when(j == n_kv - 1)
    def _finish():
        l = l_ref[...]
        o_ref[0, 0, ...] = (acc_ref[...]
                            / jnp.maximum(l, 1e-30)[:, None]
                            ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    interpret: bool = False):
    """q: [B, Hq, Sq, dh]; k, v: [B, Hkv, Skv, dh] -> [B, Hq, Sq, dh]."""
    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0
    rep = hq // hkv
    bq_ = min(bq, sq)
    bk_ = min(bk, skv)
    assert sq % bq_ == 0 and skv % bk_ == 0, (sq, skv, bq_, bk_)
    n_q = sq // bq_
    n_kv = skv // bk_
    scale = 1.0 / math.sqrt(dh)

    grid = (b, hq, n_q, n_kv)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        bq=bq_, bk=bk_, n_kv=n_kv)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq_, dh), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bk_, dh),
                         lambda b_, h, i, j, rep=rep: (b_, h // rep, j, 0)),
            pl.BlockSpec((1, 1, bk_, dh),
                         lambda b_, h, i, j, rep=rep: (b_, h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq_, dh),
                               lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_, dh), jnp.float32),   # online-softmax acc
            pltpu.VMEM((bq_,), jnp.float32),      # running max m
            pltpu.VMEM((bq_,), jnp.float32),      # running denom l
        ],
        interpret=interpret,
    )(q, k, v)
