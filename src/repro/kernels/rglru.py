"""Pallas TPU RG-LRU scan: fused single-HBM-pass linear recurrence.

The RG-LRU is elementwise (VPU work, memory-bound): the kernel's job is to
stream [S, R] once through VMEM instead of XLA's multi-pass log-depth
associative scan.  Grid: (batch, r_blocks, chunks) — chunks minor, so the
carry h lives in VMEM scratch across sequential chunk steps; inside a chunk
a fori_loop advances ``chunk`` time steps on a [r_block] vector held in
registers/VMEM.

Block sizes: chunk x r_block tiles of the [B, S, R] inputs; r_block is a
lane multiple (128) so the VPU is fully occupied.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 256
DEFAULT_RBLOCK = 128


def _rglru_kernel(x_ref, la_ref, h0_ref, h_out_ref, hlast_ref, h_ref, *,
                  chunk: int, n_chunks: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)        # [chunk, rb]
    la = la_ref[0].astype(jnp.float32)
    a = jnp.exp(la)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * x

    def step(t, carry):
        h, out = carry
        h = a[t] * h + b[t]
        out = jax.lax.dynamic_update_index_in_dim(out, h, t, 0)
        return h, out

    h0 = h_ref[...]
    h_fin, outs = jax.lax.fori_loop(
        0, chunk, step, (h0, jnp.zeros((chunk, x.shape[1]), jnp.float32)))
    h_ref[...] = h_fin
    h_out_ref[0] = outs.astype(h_out_ref.dtype)

    @pl.when(c == n_chunks - 1)
    def _last():
        hlast_ref[0] = h_fin.astype(hlast_ref.dtype)


def rglru_scan(x_gated, log_a, h0=None, *, chunk: int = DEFAULT_CHUNK,
               r_block: int = DEFAULT_RBLOCK, interpret: bool = False):
    """x_gated, log_a: [B, S, R] -> (h [B, S, R], h_last [B, R])."""
    b, s, r = x_gated.shape
    chunk = min(chunk, s)
    r_block = min(r_block, r)
    assert s % chunk == 0 and r % r_block == 0, (s, r, chunk, r_block)
    n_chunks = s // chunk
    if h0 is None:
        h0 = jnp.zeros((b, r), x_gated.dtype)

    grid = (b, r // r_block, n_chunks)
    kernel = functools.partial(_rglru_kernel, chunk=chunk, n_chunks=n_chunks)
    h_all, h_last = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, r_block), lambda b_, rb, c: (b_, c, rb)),
            pl.BlockSpec((1, chunk, r_block), lambda b_, rb, c: (b_, c, rb)),
            pl.BlockSpec((1, r_block), lambda b_, rb, c: (b_, rb)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, r_block), lambda b_, rb, c: (b_, c, rb)),
            pl.BlockSpec((1, r_block), lambda b_, rb, c: (b_, rb)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, r), x_gated.dtype),
            jax.ShapeDtypeStruct((b, r), x_gated.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((r_block,), jnp.float32)],
        interpret=interpret,
    )(x_gated, log_a, h0)
    return h_all, h_last
