"""Pallas TPU grouped matmul for MoE expert compute.

h [E, C, D] @ w [E, D, F] -> [E, C, F]: one MXU matmul per (expert,
capacity-block, f-block) grid cell, accumulating over D blocks in a f32
VMEM scratch tile.  Grid: (E, C/bc, F/bf, D/bd) — D minor so the
accumulator persists across the contraction steps.

This is the dispatch-side hot loop of ``repro.models.moe`` (the capacity-
bucketed expert forward); block shapes are MXU-aligned (128 multiples).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BC = 128
DEFAULT_BF = 128
DEFAULT_BD = 512


def _gmm_kernel(h_ref, w_ref, o_ref, acc_ref, *, n_d: int):
    d = pl.program_id(3)

    @pl.when(d == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    h = h_ref[0].astype(jnp.float32)          # [bc, bd]
    w = w_ref[0].astype(jnp.float32)          # [bd, bf]
    acc_ref[...] += h @ w

    @pl.when(d == n_d - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def moe_gmm(h, w, *, bc: int = DEFAULT_BC, bf: int = DEFAULT_BF,
            bd: int = DEFAULT_BD, interpret: bool = False):
    """h: [E, C, D], w: [E, D, F] -> [E, C, F]."""
    e, c, d = h.shape
    _, _, f = w.shape
    bc_, bf_, bd_ = min(bc, c), min(bf, f), min(bd, d)
    assert c % bc_ == 0 and f % bf_ == 0 and d % bd_ == 0, (c, f, d)
    grid = (e, c // bc_, f // bf_, d // bd_)
    kernel = functools.partial(_gmm_kernel, n_d=d // bd_)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc_, bd_), lambda e_, i, j, k: (e_, i, k)),
            pl.BlockSpec((1, bd_, bf_), lambda e_, i, j, k: (e_, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bc_, bf_), lambda e_, i, j, k: (e_, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), h.dtype),
        scratch_shapes=[pltpu.VMEM((bc_, bf_), jnp.float32)],
        interpret=interpret,
    )(h, w)
