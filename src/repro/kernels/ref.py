"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function mirrors one kernel's semantics exactly; tests sweep shapes
and dtypes asserting allclose between kernel (interpret=True on CPU) and
these references.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q [B,Hq,S,dh], k/v [B,Hkv,S,dh] — dense softmax attention."""
    b, hq, sq, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    rep = hq // hkv
    qg = q.reshape(b, hkv, rep, sq, dh).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkrqd,bksd->bkrqs", qg, kf) / math.sqrt(dh)
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window > 0:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkrqs,bksd->bkrqd", p, vf)
    return o.reshape(b, hq, sq, dh).astype(q.dtype)


def rg_lru_ref(x_gated, log_a, h0=None):
    """Sequential RG-LRU: h_t = a_t h_{t-1} + sqrt(1-a_t^2) x_t."""
    b, s, r = x_gated.shape
    h = jnp.zeros((b, r), jnp.float32) if h0 is None else \
        h0.astype(jnp.float32)
    a = jnp.exp(log_a.astype(jnp.float32))
    bx = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) \
        * x_gated.astype(jnp.float32)

    def step(h, inp):
        at, bt = inp
        h = at * h + bt
        return h, h

    hs_final, hs = jax.lax.scan(
        step, h, (jnp.moveaxis(a, 1, 0), jnp.moveaxis(bx, 1, 0)))
    return jnp.moveaxis(hs, 0, 1).astype(x_gated.dtype), hs_final


def wkv6_ref(r, k, v, w, u, s0=None):
    """Step-by-step WKV6 (same semantics as models.rwkv.wkv6_scan)."""
    from repro.models.rwkv import wkv6_scan
    return wkv6_scan(r, k, v, w, u, s0=s0)


def moe_gmm_ref(h, w):
    """Grouped matmul: h [E, C, D] @ w [E, D, F] -> [E, C, F]."""
    return jnp.einsum("ecd,edf->ecf", h.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(h.dtype)
