"""Deterministic synthetic data pipelines (offline environment — no CIFAR).

Both generators produce *learnable* structure so convergence experiments are
meaningful, and both are shard-aware: a worker constructs only its shard
from (seed, shard_index) — no data redistribution at scale.

* ``token_batches`` — affine-chain language: next = (a*tok + c) mod V with
  noise epsilon.  A model that learns the chain reaches loss ~ -log(1-eps).
* ``image_batches`` — 10-class blob images (class-dependent spatial pattern
  + noise), stand-in for CIFAR-10 in the paper's experiments.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenTaskConfig:
    vocab: int
    a: int = 31
    c: int = 17
    noise: float = 0.05


def token_batches(cfg: TokenTaskConfig, batch: int, seq: int, *,
                  seed: int = 0, shard: int = 0, num_shards: int = 1):
    """Yield {'tokens', 'labels'} int32 batches forever (labels = next tok)."""
    rng = np.random.default_rng((seed, shard))
    b_local = batch // num_shards
    while True:
        toks = np.empty((b_local, seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab, size=b_local)
        for t in range(seq):
            nxt = (cfg.a * toks[:, t] + cfg.c) % cfg.vocab
            flip = rng.random(b_local) < cfg.noise
            nxt = np.where(flip, rng.integers(0, cfg.vocab, b_local), nxt)
            toks[:, t + 1] = nxt
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def image_batches(batch: int, *, num_classes: int = 10, size: int = 32,
                  noise: float = 0.3, seed: int = 0, shard: int = 0,
                  num_shards: int = 1):
    """Yield {'images' [B,H,W,3] f32, 'labels' [B]} with class-specific blobs."""
    rng = np.random.default_rng((seed, shard))
    b_local = batch // num_shards
    yy, xx = np.mgrid[0:size, 0:size].astype(np.float32) / size

    # fixed per-class pattern parameters
    prng = np.random.default_rng(1234)
    centers = prng.random((num_classes, 2)).astype(np.float32)
    freqs = (prng.integers(1, 4, size=(num_classes, 3))).astype(np.float32)

    while True:
        labels = rng.integers(0, num_classes, size=b_local).astype(np.int32)
        imgs = np.empty((b_local, size, size, 3), np.float32)
        for ci in range(3):
            cy = centers[labels, 0][:, None, None]
            cx = centers[labels, 1][:, None, None]
            f = freqs[labels, ci][:, None, None]
            r2 = (yy[None] - cy) ** 2 + (xx[None] - cx) ** 2
            imgs[..., ci] = np.cos(2 * np.pi * f * np.sqrt(r2 + 1e-6)) * \
                np.exp(-4.0 * r2)
        imgs += noise * rng.standard_normal(imgs.shape).astype(np.float32)
        yield {"images": imgs, "labels": labels}


def lm_batch_for(cfg, batch: int, seq: int, seed: int = 0):
    """One host batch matching an LMConfig's input structure (for tests)."""
    rng = np.random.default_rng(seed)
    out = {
        "tokens": rng.integers(0, cfg.vocab, (batch, seq)).astype(np.int32),
        "labels": rng.integers(0, cfg.vocab, (batch, seq)).astype(np.int32),
    }
    if cfg.family == "vlm":
        out["patch_embeds"] = rng.standard_normal(
            (batch, cfg.num_patches, cfg.d_model)).astype(np.float32)
    if cfg.family == "audio":
        out["frames"] = rng.standard_normal(
            (batch, cfg.enc_seq, cfg.d_model)).astype(np.float32)
    return out
