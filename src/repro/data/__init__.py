from repro.data.synthetic import (token_batches, image_batches,
                                  lm_batch_for, TokenTaskConfig)
