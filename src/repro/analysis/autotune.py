"""Roofline-driven auto-planner: choose ``(pipeline_stages, k, v[, wire])``.

Closes the loop from measurement to execution (ROADMAP auto-tuning items):

    dryrun compile -> roofline record -> PlanInputs -> choose_plan
        -> PipelineSpec.auto_plan / train.py --pipeline-k auto

``plan_inputs_from_record`` extracts the two quantities the paper's Lemma 1
needs — per-stage compute time per batch and the inter-stage link time of
one cut-activation hop — from a dry-run record (``launch/dryrun.py``): the
compute/memory roofline terms give the stage time (per-chip HLO seconds ARE
the per-stage wall time, since each chip computes its 1/chips share either
way), and the partitioned HLO's ``collective-permute`` bytes invert the tick
schedule to recover the per-hop activation volume.

The ``v > 1`` trade is modeled explicitly, unlike ``core/schedule.py``'s
free-comm idealization: interleaving shrinks the warm-up/drain bubble from
``(S-1)`` to ``(S-1)/v`` stage-passes per direction, but the chunk chain
wraps cyclically through every stage, so a micro-batch pays ``S*v - 1``
cut-activation hops instead of ``S - 1`` — volume AND per-message overhead
scale with ``v``.  ``choose_plan`` evaluates every candidate ``(k, v)``
under the repo's own event simulator (``simulate_c2p2sl`` — for S=2 the
2-actor wireless model is the exact pod topology; ``as_wireless`` exports
the same candidate as a (profile, fleet, plan) triple so
``repro.sl.batch_wall_time`` reproduces the objective bit-for-bit) and
returns the argmin, so the chosen plan beats-or-ties every neighboring
``(k±1, v/2, 2v)`` plan by construction — the property the test suite
locks in (tests/test_autotune.py).

The planner is also **codec-aware**: the pipeline hop can ship the cut
activation block-quantized (``parallel/wire.py``), and the wire byte
model here (``wire_bytes_per_element`` / ``PlanInputs.wire_link_s``)
scales the billed link time accordingly; ``choose_plan(...,
wire_candidates=WIRE_AUTO)`` enumerates the codec jointly with (k, v)
since a 2-4x smaller ``link_s`` moves the argmin.

Everything here is jax-free (numpy + the scipy that repro.core already
depends on; no jax import): the planner must run in the CI planner-smoke
step before any accelerator stack exists.

CLI:
    PYTHONPATH=src python -m repro.analysis.autotune \
        --roofline tests/fixtures/roofline_smoke.json --out PLAN_smoke.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import numpy as np

from repro.analysis.roofline import HW
from repro.core import schedule as _sched
from repro.core.costs import LayerProfile
from repro.core.schedule import TaskTimes, bubble_rate, simulate_c2p2sl


def _sigma(m: int, num_stages: int, virtual_stages: int) -> int:
    """Pipeline-entry tick of micro-batch m — mirror of
    ``parallel.pipeline._sigma`` (kept numpy-only; the pipeline module
    imports jax)."""
    return (m // num_stages) * num_stages * virtual_stages + (m % num_stages)


def schedule_ticks(k: int, num_stages: int, virtual_stages: int) -> int:
    """Total tick count of the interleaved 1F1B schedule (one direction)."""
    return _sigma(k - 1, num_stages, virtual_stages) \
        + num_stages * virtual_stages


def hop_ratio(num_stages: int, virtual_stages: int) -> float:
    """Cut-activation volume of a ``v``-interleaved micro-batch relative to
    plain 1F1B: ``(S*v - 1) / (S - 1)`` boundary hops (the chunk chain
    wraps from stage S-1 back to stage 0).  0 for S=1 (no ppermute)."""
    if num_stages <= 1:
        return 0.0
    return (num_stages * virtual_stages - 1.0) / (num_stages - 1.0)


# ---------------------------------------------------------------------------
# Wire-codec byte model (mirror of parallel/wire.py, kept numpy-only: the
# planner must run in CI before any accelerator stack exists).
# ---------------------------------------------------------------------------

# Codec enumeration order for ``wire_dtype='auto'``: ties keep the first
# entry, so an uncoded hop wins unless quantizing strictly pays, int8
# (better-conditioned with block scales) wins a tie against fp8, and the
# sparsified gradient hop must STRICTLY beat every dense codec to be
# chosen (it is lossier and carries EF state).
WIRE_AUTO = ("none", "int8", "fp8", "int8+topk0.25")

# Nominal quantization block (parallel/wire.py picks the largest divisor
# of d_model <= this); the fp32 per-block scale amortizes to 4/block
# bytes per element on the wire.
WIRE_BLOCK = 256


def wire_block_for(d_model, block: int = WIRE_BLOCK) -> int:
    """Effective codec block for a model width — mirror of
    ``parallel.wire.wire_block`` (kept numpy-only; that module imports
    jax): the largest divisor of ``d_model`` that is <= ``block``.
    Unknown ``d_model`` assumes the nominal block."""
    if d_model is None or int(d_model) <= 0:
        return block
    d = int(d_model)
    b = min(block, d)
    while d % b:
        b -= 1
    return b


def _parse_wire(wire_dtype):
    """Codec name -> ``(base, topk_frac | None)`` — numpy-only mirror of
    ``parallel.wire.parse_wire_dtype`` (that module imports jax; the
    planner must run before any accelerator stack exists).  Same grammar,
    same normalization: ``frac >= 1`` IS the dense base codec."""
    w = "none" if wire_dtype is None else str(wire_dtype).strip().lower()
    base, sep, suffix = w.partition("+")
    frac = None
    if sep:
        if not suffix.startswith("topk"):
            raise ValueError(
                f"unknown wire_dtype {wire_dtype!r} (expected "
                "'<base>+topk<frac>', e.g. 'int8+topk0.25')")
        try:
            frac = float(suffix[len("topk"):])
        except ValueError:
            raise ValueError(
                f"wire_dtype {wire_dtype!r}: top-k fraction "
                f"{suffix[len('topk'):]!r} is not a number")
        if not frac > 0.0:
            raise ValueError(
                f"wire_dtype {wire_dtype!r}: top-k fraction must be > 0")
        if frac >= 1.0:
            frac = None
    if base not in ("none", "int8", "fp8"):
        raise ValueError(
            f"unknown wire_dtype {wire_dtype!r} (expected one of "
            f"('none', 'int8', 'fp8') or '<base>+topk<frac>')")
    if frac is not None and base == "none":
        raise ValueError(
            f"wire_dtype {wire_dtype!r}: top-k rides a quantized payload "
            "— use 'int8+topk<frac>' or 'fp8+topk<frac>'")
    return base, frac


def wire_bytes_per_element(wire_dtype: str, act_bytes: float,
                           block: int = WIRE_BLOCK) -> float:
    """Wire bytes one activation element costs on the FORWARD hop.

    ``act_bytes`` is the uncompressed element width (2 for bf16, 4 for
    fp32 — what the raw ppermute ships).  Both quantized codecs put one
    byte per element plus the per-block fp32 scale on the wire;
    ``block`` is the EFFECTIVE codec block (``wire_block_for(d_model)``
    — a d_model not divisible by 256 pays more scale overhead, and a
    degenerate block can make quantizing a net loss, which the planner
    must see).  A ``+topk`` codec sparsifies only the BACKWARD hop
    (``wire_bytes_per_element_bwd``); its forward hop ships the dense
    base payload, which is what this function prices.
    """
    base, _frac = _parse_wire(wire_dtype)
    if base == "none":
        return float(act_bytes)
    return 1.0 + 4.0 / max(1, int(block))


def wire_bytes_per_element_bwd(wire_dtype: str, act_bytes: float,
                               block: int = WIRE_BLOCK,
                               d_model=None) -> float:
    """Wire bytes one activation-GRADIENT element costs on the backward
    hop.  Dense codecs are direction-symmetric; a ``+topk<frac>`` codec
    ships ``frac*d`` base-quantized values + their int16 indices (int32
    above 32767 columns) + one fp32 per-row scale:
    ``frac*(1 + idx_bytes) + 4/d`` bytes/element.  Unknown ``d_model``
    assumes int16 indices and drops the (tiny) amortized-scale term.

    At a DEGENERATE block (dense codec >= raw, the runtime's
    ``wire.codec_net_loss`` condition) the EF hop falls back to the raw
    payload on both directions, so the top-k saving never materializes —
    bill the dense bytes there (same pessimism as the forward model), so
    joint enumeration keeps 'none'."""
    base, frac = _parse_wire(wire_dtype)
    dense = wire_bytes_per_element(wire_dtype, act_bytes, block)
    if frac is None or dense >= float(act_bytes):
        return dense
    d = None if d_model is None or int(d_model) <= 0 else int(d_model)
    idx_bytes = 2.0 if d is None or d <= 32767 else 4.0
    scale_amort = 4.0 / d if d else 0.0
    return frac * (1.0 + idx_bytes) + scale_amort


def wire_link_scale(wire_dtype: str, act_bytes: float,
                    block: int = WIRE_BLOCK) -> float:
    """Multiplier on the uncompressed FORWARD link time under a codec
    (< 1 for int8/fp8 at healthy blocks; exactly 1 for 'none'; can exceed
    1 for degenerate blocks, where the planner should keep 'none')."""
    return wire_bytes_per_element(wire_dtype, act_bytes, block) \
        / float(act_bytes)


def wire_link_scale_bwd(wire_dtype: str, act_bytes: float,
                        block: int = WIRE_BLOCK, d_model=None) -> float:
    """Backward-hop counterpart of ``wire_link_scale`` (smaller than the
    forward scale under a ``+topk`` codec; identical for dense ones)."""
    return wire_bytes_per_element_bwd(wire_dtype, act_bytes, block,
                                      d_model) / float(act_bytes)


# ---------------------------------------------------------------------------
# The plan currency: one frozen value object for "which pipeline cell".
# ---------------------------------------------------------------------------

#: Version of the ``Plan.to_json`` schema.  Bump ONLY with a loader shim
#: in ``Plan.from_json`` — dryrun records and ``--plan-out`` files embed
#: this schema, and the re-planner (training/replan.py) round-trips it.
PLAN_SCHEMA = 1


@dataclasses.dataclass(frozen=True)
class Plan:
    """The single plan currency: one pipeline execution cell.

    Everything that decides *how the pipeline runs* — and nothing else —
    lives here: ``stages`` (S, the pod-axis split), ``k`` (micro-batches
    per batch), ``v`` (interleaved virtual stages), ``wire_dtype`` (the
    hop codec, ``parallel/wire.py`` grammar).  Replaces the
    tuple/kwargs sprawl that used to flow separately through
    ``PipelineSpec``, ``train.py``, ``dryrun.py`` and ``perf_iter.py``;
    ``PipelineSpec.from_plan`` is the only sanctioned way launchers turn
    a plan into a runnable spec, and the online re-planner
    (``training/replan.py``) switches between ``Plan`` values mid-run.

    Frozen + normalized at construction (the codec name canonicalizes
    through the wire grammar, so ``" INT8+topk0.50 "`` and
    ``"int8+topk0.5"`` are the same plan and hash identically — the
    compile cache keys on ``cell()``).  Not to be confused with the
    wireless-side ``core.schedule.Plan`` (the paper's (l, k, b, tau)
    allocation); this is the pod-pipeline execution plan.
    """

    stages: int
    k: int
    v: int = 1
    wire_dtype: str = "none"

    def __post_init__(self):
        for name in ("stages", "k", "v"):
            val = getattr(self, name)
            if not isinstance(val, (int, np.integer)) or isinstance(val, bool):
                raise ValueError(f"Plan.{name} must be an int, got {val!r}")
            if val < 1:
                raise ValueError(f"Plan.{name}={val} must be >= 1")
            object.__setattr__(self, name, int(val))
        base, frac = _parse_wire(self.wire_dtype)   # validates the grammar
        norm = base if frac is None else f"{base}+topk{frac:g}"
        object.__setattr__(self, "wire_dtype", norm)

    def cell(self) -> tuple:
        """Hashable compile-cache key — the full cell identity."""
        return (self.stages, self.k, self.v, self.wire_dtype)

    def to_json(self) -> dict:
        """Stable, versioned wire schema (dryrun records, ``--plan-out``,
        re-planner switch logs)."""
        return {"schema": PLAN_SCHEMA, "stages": self.stages, "k": self.k,
                "v": self.v, "wire_dtype": self.wire_dtype}

    @classmethod
    def from_json(cls, doc: dict) -> "Plan":
        """Inverse of ``to_json``.  Unknown schema versions fail loudly
        (forward compatibility is a decision, not an accident); missing
        ``schema`` reads as version 1 so hand-written JSON stays usable."""
        if not isinstance(doc, dict):
            raise ValueError(f"Plan.from_json expects a dict, got {doc!r}")
        schema = doc.get("schema", 1)
        if schema != PLAN_SCHEMA:
            raise ValueError(
                f"Plan schema {schema!r} not supported (this build reads "
                f"schema {PLAN_SCHEMA}) — regenerate the plan JSON")
        missing = [key for key in ("stages", "k") if key not in doc]
        if missing:
            raise ValueError(f"Plan JSON missing {missing}: {doc!r}")
        return cls(stages=doc["stages"], k=doc["k"], v=doc.get("v", 1),
                   wire_dtype=doc.get("wire_dtype", "none"))

    def __str__(self):
        return (f"Plan(S={self.stages}, k={self.k}, v={self.v}, "
                f"wire={self.wire_dtype})")


@dataclasses.dataclass(frozen=True)
class PlanInputs:
    """Measured (or estimated) costs of one pipeline cell, per batch.

    ``stage_fwd_s`` / ``stage_bwd_s``: wall seconds for ONE stage to push
    the WHOLE batch through its layer share (forward / backward) — the
    paper's t_b^F / t_b^B transplanted to pods.  ``link_s``: seconds for
    one UNCOMPRESSED full-batch cut-activation hop across the stage
    boundary at v=1 (per direction; the paper's t^U == t^D).
    ``hop_overhead_s``: fixed per-micro-batch-message cost of one hop
    (DCN latency, or a measured value from benchmarks/ppermute_probe.py)
    — the term that makes large k and large v non-free and gives the
    planner an interior optimum.  ``wire_dtype`` / ``act_bytes`` model
    the hop codec: the billed link time is ``wire_link_s`` =
    ``link_s * wire_link_scale(wire_dtype, act_bytes)`` on the forward
    hop and ``wire_link_bwd_s`` on the backward one (smaller under a
    ``+topk`` codec).  ``codec_s_per_byte`` (measured by
    benchmarks/wire_codec.py) bills the encode+decode COMPUTE of a coded
    hop: ``codec_s = act_hop_bytes * codec_s_per_byte`` seconds per
    full-batch hop are added to every coded comm leg, so a codec is only
    chosen when its link-time saving exceeds its compute cost.
    """

    num_stages: int
    stage_fwd_s: float
    stage_bwd_s: float
    link_s: float
    hop_overhead_s: float = 0.0
    k_cap: int = 32
    v_cap: int = 4
    num_layers: int | None = None
    # True (dry-run records): the chip budget is fixed, so the per-stage
    # wall time is S-independent (half the layers on half the chips).
    # False (single-chip-per-stage estimates): stage time = total / S.
    fixed_chip_budget: bool = True
    wire_dtype: str = "none"     # hop codec billed by the objective
    act_bytes: float = 2.0       # uncompressed element width (bf16 default)
    wire_block: int = WIRE_BLOCK  # effective codec block (wire_block_for)
    codec_s_per_byte: float = 0.0  # encode+decode seconds per payload byte
    act_hop_bytes: float = 0.0   # uncompressed full-batch hop volume (B)
    d_model: int | None = None   # hop row width (top-k index/scale model)

    @property
    def wire_link_s(self) -> float:
        """Link seconds of one full-batch FORWARD hop as billed under the
        codec (a ``+topk`` codec's forward hop is its dense base)."""
        return self.link_s * wire_link_scale(self.wire_dtype,
                                             self.act_bytes,
                                             self.wire_block)

    @property
    def wire_link_bwd_s(self) -> float:
        """Link seconds of one full-batch BACKWARD (gradient) hop —
        smaller than ``wire_link_s`` under a ``+topk`` codec."""
        return self.link_s * wire_link_scale_bwd(self.wire_dtype,
                                                 self.act_bytes,
                                                 self.wire_block,
                                                 self.d_model)

    @property
    def codec_s(self) -> float:
        """Encode+decode compute seconds of one full-batch coded hop
        (0 for 'none', and 0 when no throughput was measured)."""
        base, _frac = _parse_wire(self.wire_dtype)
        if base == "none":
            return 0.0
        return float(self.act_hop_bytes) * float(self.codec_s_per_byte)

    def with_stages(self, num_stages: int) -> "PlanInputs":
        if num_stages == self.num_stages:
            return self
        scale = 1.0 if self.fixed_chip_budget \
            else self.num_stages / num_stages
        return dataclasses.replace(
            self, num_stages=num_stages,
            stage_fwd_s=self.stage_fwd_s * scale,
            stage_bwd_s=self.stage_bwd_s * scale)

    def with_wire(self, wire_dtype: str) -> "PlanInputs":
        base, frac = _parse_wire(wire_dtype)   # validate + normalize
        w = base if frac is None else f"{base}+topk{frac:g}"
        if w == self.wire_dtype:
            return self
        return dataclasses.replace(self, wire_dtype=w)

    def feasible_v(self) -> list:
        """Interleave counts admissible under the layer-divisibility
        constraint of ``parallel.pipeline._split_stages``."""
        out = []
        for v in range(1, max(1, self.v_cap) + 1):
            if self.num_layers is not None \
                    and self.num_layers % (self.num_stages * v) != 0:
                continue
            out.append(v)
        return out or [1]

    def to_dict(self) -> dict:
        return {
            "num_stages": self.num_stages,
            "stage_fwd_s": self.stage_fwd_s,
            "stage_bwd_s": self.stage_bwd_s,
            "link_s": self.link_s,
            "wire_dtype": self.wire_dtype,
            "act_bytes": self.act_bytes,
            "wire_block": self.wire_block,
            "wire_link_s": self.wire_link_s,
            "wire_link_bwd_s": self.wire_link_bwd_s,
            "codec_s_per_byte": self.codec_s_per_byte,
            "codec_s": self.codec_s,
            "act_hop_bytes": self.act_hop_bytes,
            "d_model": self.d_model,
            "hop_overhead_s": self.hop_overhead_s,
            "k_cap": self.k_cap,
            "v_cap": self.v_cap,
            "num_layers": self.num_layers,
        }


def plan_task_times(inp: PlanInputs, k: int, v: int) -> TaskTimes:
    """The candidate plan as per-micro-batch ``TaskTimes`` (2-actor view:
    stage 0 is the "UE", stage 1 the "BS" — exact for S=2).

    The uplink/downlink legs carry the v-interleave hop inflation: a
    micro-batch crosses the boundary ``S*v - 1`` times instead of
    ``S - 1``, each hop paying bandwidth (codec-billed volume / k, per
    direction — a ``+topk`` codec's downlink is cheaper than its uplink)
    plus the fixed per-message overhead plus the codec's encode+decode
    compute share (``codec_s / k``) — the term that stops the planner
    from picking a codec whose compute costs more than its link saving.
    """
    h = hop_ratio(inp.num_stages, v)
    codec = inp.codec_s / k
    up = h * (inp.wire_link_s / k + inp.hop_overhead_s + codec)
    down = h * (inp.wire_link_bwd_s / k + inp.hop_overhead_s + codec)
    return TaskTimes(
        ue_fwd=np.array([inp.stage_fwd_s / k]),
        uplink=np.array([up]),
        bs_fwd=inp.stage_fwd_s / k,
        bs_bwd=inp.stage_bwd_s / k,
        downlink=np.array([down]),
        ue_bwd=np.array([inp.stage_bwd_s / k]),
    )


def as_wireless(inp: PlanInputs, k: int, v: int):
    """Export a candidate plan as ``(profile, fleet, plan)`` such that
    ``repro.sl.batch_wall_time(profile, fleet, plan)`` reproduces
    ``plan_wall_time(inp, k, v)`` exactly (S=2 only).

    Construction: one UE with f=1 FLOP/s, unit frame/slot/rates, batch
    ``B = k``; per-sample costs are the batch costs / B, and the cut
    bytes fold in the candidate's hop inflation ``h*(U + k*ovh + codec)``
    so the eq-(8) uplink comes out to the hop-billed leg.  This is the
    bridge that lets the wireless-side evaluator judge pod-pipeline
    plans.  The wireless model has ONE cut-byte volume for both
    directions, so direction-asymmetric (``+topk``) codecs cannot be
    expressed — this raises for them rather than silently averaging.
    """
    if inp.num_stages != 2:
        raise ValueError(
            f"as_wireless maps the 2-stage (UE/BS) pipeline; got "
            f"num_stages={inp.num_stages}")
    if _parse_wire(inp.wire_dtype)[1] is not None:
        raise ValueError(
            f"as_wireless cannot express wire_dtype {inp.wire_dtype!r}: "
            "the wireless eq-(8) model ships the same cut bytes up and "
            "down, but a '+topk' codec sparsifies only the downlink — "
            "evaluate with plan_wall_time instead")
    B = float(max(k, 1))
    h = hop_ratio(2, v)
    cut_bytes = h * (inp.wire_link_s + k * inp.hop_overhead_s
                     + inp.codec_s) / (8.0 * B)
    profile = LayerProfile(
        name="pod-roofline",
        layer_names=("ue_stage", "bs_stage"),
        fwd_flops=np.array([inp.stage_fwd_s / B, inp.stage_fwd_s / B]),
        bwd_flops=np.array([inp.stage_bwd_s / B, inp.stage_bwd_s / B]),
        act_bytes=np.array([cut_bytes, 4.0]),
        label_bytes=0.0,
    )
    plan = _sched.Plan(l=1, k=k, b=np.array([B]), tau=np.array([1.0]), v=v)
    return profile, _POD_FLEET, plan


@dataclasses.dataclass(frozen=True)
class _UnitChannel:
    frame_s: float = 1.0


@dataclasses.dataclass(frozen=True)
class _PodFleet:
    """Duck-typed ``wireless.Fleet`` stand-in: one unit-rate UE."""

    channel: _UnitChannel = _UnitChannel()
    n: int = 1
    bs_flops: float = 1.0

    def rates(self):
        return np.ones(1), np.ones(1)

    @property
    def ue_flops(self) -> np.ndarray:
        return np.ones(1)

    @property
    def storage(self) -> np.ndarray:
        return np.full(1, 1e30)


_POD_FLEET = _PodFleet()


def tick_wall_time(inp: PlanInputs, k: int, v: int) -> float:
    """Analytic tick model for any S: ``ticks x per-tick cost`` with the
    cyclic ppermute overlapped against the next tick's chunk compute
    (XLA latency hiding), per direction.  Used as the objective when
    S != 2 (where the 2-actor simulator is not the true topology)."""
    ticks = schedule_ticks(k, inp.num_stages, v)
    comm_f = comm_b = 0.0
    if inp.num_stages > 1:
        codec = inp.codec_s / k
        comm_f = inp.wire_link_s / k + inp.hop_overhead_s + codec
        comm_b = inp.wire_link_bwd_s / k + inp.hop_overhead_s + codec
    comp_f = inp.stage_fwd_s / (k * v)
    comp_b = inp.stage_bwd_s / (k * v)
    return ticks * (max(comp_f, comm_f) + max(comp_b, comm_b))


def plan_wall_time(inp: PlanInputs, k: int, v: int) -> float:
    """Modeled wall seconds of one batch under candidate ``(k, v)``.

    S=2 runs the event simulator on the hop-billed task times — the same
    number ``batch_wall_time(*as_wireless(inp, k, v))`` returns; other
    stage counts use the analytic tick model.
    """
    if inp.num_stages == 2:
        ms, _ = simulate_c2p2sl(plan_task_times(inp, k, v), k,
                                virtual_stages=v)
        return float(ms)
    return tick_wall_time(inp, k, v)


def plan_bubble(inp: PlanInputs, k: int, v: int) -> float:
    """Bubble rate consistent with whichever wall-time model scores the
    plan: the eq-(16) definition on the 2-actor task times for S=2, the
    schedule's idle-tick fraction ``(ticks - k*v) / ticks`` otherwise."""
    if inp.num_stages == 2:
        return bubble_rate(plan_task_times(inp, k, v), k, v)
    ticks = schedule_ticks(k, inp.num_stages, v)
    return (ticks - k * v) / ticks


@dataclasses.dataclass(frozen=True)
class AutoPlan:
    """A planner decision plus the evidence it was made on."""

    num_stages: int
    k: int
    v: int
    wall_s: float          # modeled batch time at (S, k, v)
    baseline_s: float      # modeled batch time at (S, 1, 1) — no pipelining
    bubble: float
    inputs: PlanInputs
    wire_dtype: str = "none"   # hop codec the chosen plan is billed with

    @property
    def speedup(self) -> float:
        return self.baseline_s / self.wall_s if self.wall_s > 0 else 1.0

    @property
    def plan(self) -> Plan:
        """The decision as the single plan currency (evidence stripped)."""
        return Plan(stages=self.num_stages, k=self.k, v=self.v,
                    wire_dtype=self.wire_dtype)

    def to_dict(self) -> dict:
        return {
            "num_stages": self.num_stages,
            "k": self.k,
            "v": self.v,
            "plan": self.plan.to_json(),
            "wire_dtype": self.wire_dtype,
            "wall_s": self.wall_s,
            "baseline_s": self.baseline_s,
            "speedup": self.speedup,
            "bubble": self.bubble,
            "inputs": self.inputs.to_dict(),
        }


# Relative slack under which two candidate wall times count as a tie; the
# first-enumerated (smallest S, then smallest v, then smallest k)
# candidate wins ties.
_TIE_RTOL = 1e-9


def neighbor_plans(inp: PlanInputs, k: int, v: int) -> list:
    """Feasible ``(k', v')`` neighbors of a plan: k±1 within [1, k_cap],
    v/2 and 2v within the layer-divisible interleave set."""
    vs = set(inp.feasible_v())
    out = []
    for kk in (k - 1, k + 1):
        if 1 <= kk <= max(1, inp.k_cap):
            out.append((kk, v))
    for vv in (v // 2, v * 2):
        if vv >= 1 and vv != v and vv in vs:
            out.append((k, vv))
    return out


def choose_plan(inp: PlanInputs, *, stage_candidates=None,
                k_fixed: int | None = None,
                v_fixed: int | None = None,
                wire_candidates=None) -> AutoPlan:
    """Exhaustive argmin of ``plan_wall_time`` over the feasible grid.

    ``stage_candidates`` extends the search to the joint (S, k, v) trade;
    by default S is pinned (the pod axis size is a hardware fact).
    ``wire_candidates`` extends it to the hop codec (e.g. ``WIRE_AUTO``)
    — a 2-4x smaller ``link_s`` moves the (S, k, v) argmin, so the codec
    is enumerated jointly rather than bolted on after; by default the
    codec is pinned to ``inp.wire_dtype``.  ``k_fixed`` / ``v_fixed``
    pin one coordinate (a hand flag overriding half of an auto plan);
    pins are validated for positivity and for the layer-divisibility the
    schedule requires, but deliberately NOT clamped to ``k_cap`` — a
    hand k beyond the planner's cap is a legitimate override (the
    pipeline pads ragged batches).  Deterministic: ties (equal wall time
    within tolerance) keep the first-enumerated candidate — smallest S,
    then the earlier wire candidate, then smallest v, then smallest k.
    """
    if k_fixed is not None and k_fixed < 1:
        raise ValueError(f"k={k_fixed} must be >= 1")
    if v_fixed is not None and v_fixed < 1:
        raise ValueError(f"virtual_stages={v_fixed} must be >= 1")
    stages = list(stage_candidates) if stage_candidates \
        else [inp.num_stages]
    wires = list(wire_candidates) if wire_candidates \
        else [inp.wire_dtype]
    for w_cand in wires:
        wire_bytes_per_element(w_cand, inp.act_bytes)   # validate early
    best = None
    for S in sorted(stages):
        if S < 1:
            raise ValueError(f"stage candidate {S} must be >= 1")
        inp_s = inp.with_stages(S)
        if inp_s.num_layers is not None and inp_s.num_layers % S != 0:
            continue
        if v_fixed is not None:
            if inp_s.num_layers is not None \
                    and inp_s.num_layers % (S * v_fixed) != 0:
                # un-runnable: _split_stages needs S*v | num_layers
                continue
            vs = [v_fixed]
        else:
            vs = inp_s.feasible_v()
        ks = [k_fixed] if k_fixed is not None \
            else range(1, max(1, inp_s.k_cap) + 1)
        for wd in wires:
            inp_sw = inp_s.with_wire(wd)
            for v in vs:
                for k in ks:
                    w = plan_wall_time(inp_sw, k, v)
                    if best is None or w < best[0] * (1.0 - _TIE_RTOL):
                        best = (w, k, v, S, inp_sw)
    if best is None:
        raise ValueError(
            f"no feasible (S, k, v): stages {stages}"
            + (f" x v={v_fixed}" if v_fixed is not None else "")
            + f" incompatible with num_layers={inp.num_layers} "
            "(the pipeline needs S*v dividing the layer count)")
    w, k, v, S, inp_sw = best
    return AutoPlan(num_stages=S, k=k, v=v, wall_s=w,
                    baseline_s=plan_wall_time(inp_sw, 1, 1),
                    bubble=plan_bubble(inp_sw, k, v), inputs=inp_sw,
                    wire_dtype=inp_sw.wire_dtype)


def wire_plan_sweep(inp: PlanInputs, wire_candidates=WIRE_AUTO,
                    **choose_kwargs) -> dict:
    """Per-codec best plans plus the joint winner — the evidence trail a
    dry-run record stores so ``auto_plan`` shows which codec won and why.

    Returns ``{"chosen": AutoPlan dict, "sweep": {codec: {k, v, wall_s,
    wire_link_s, speedup_vs_none}}}``; ``speedup_vs_none`` is each
    codec's best wall time relative to the uncoded best.
    """
    sweep = {}
    for wd in wire_candidates:
        p = choose_plan(inp.with_wire(wd), **choose_kwargs)
        sweep[wd] = {"k": p.k, "v": p.v, "wall_s": p.wall_s,
                     "wire_link_s": p.inputs.wire_link_s,
                     "wire_link_bwd_s": p.inputs.wire_link_bwd_s,
                     "codec_s": p.inputs.codec_s}
    none_wall = sweep.get("none", {}).get("wall_s")
    for row in sweep.values():
        row["speedup_vs_none"] = (none_wall / row["wall_s"]
                                  if none_wall and row["wall_s"] > 0
                                  else 1.0)
    chosen = choose_plan(inp, wire_candidates=list(wire_candidates),
                         **choose_kwargs)
    return {"chosen": chosen.to_dict(), "sweep": sweep}


# ---------------------------------------------------------------------------
# Serving objective: slot count (+ INFER-hop codec) for the continuous-
# batching engine (repro.serving.engine) under an offered request load.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServingInputs:
    """Measured (or estimated) costs of one continuous-batching serving
    cell plus the offered load it must absorb.

    The engine's decode step is ONE fixed-shape jitted program over the
    whole slot arena, so its cost is ``step_overhead_s + slots *
    decode_lane_s`` — per-lane compute plus the fixed dispatch cost —
    and every step advances every ACTIVE lane one token.  Prefill
    (``prefill_s_per_token``) time-shares the same engine, so the
    fraction ``f = arrival_hz * prompt_tokens * prefill_s_per_token`` of
    wall time is unavailable to decode.  Split serving adds the INFER
    uplink to each step: ``slots * d_model`` cut-activation elements at
    the codec's ``wire_bytes_per_element`` over ``link_bw_Bps`` plus the
    per-frame ``hop_overhead_s`` (``link_bw_Bps=None`` = co-located, no
    hop).  ``arrival_hz`` / ``prompt_tokens`` / ``gen_tokens`` describe
    the mean offered mix (e.g. from ``ServingQoS`` snapshots).
    """

    decode_lane_s: float           # decode seconds one slot lane adds/step
    prefill_s_per_token: float     # prefill engine seconds per prompt token
    arrival_hz: float              # mean request arrival rate (1/s)
    prompt_tokens: float           # mean prompt length
    gen_tokens: float              # mean generated tokens per request
    step_overhead_s: float = 0.0   # fixed per-decode-step dispatch cost
    slot_candidates: tuple = (1, 2, 4, 8, 16, 32, 64)
    wire_dtype: str = "none"       # INFER uplink codec (split serving)
    act_bytes: float = 2.0         # uncompressed activation element width
    d_model: int | None = None     # cut width (split serving hop volume)
    link_bw_Bps: float | None = None   # None = co-located UE+BS (no hop)
    hop_overhead_s: float = 0.0    # per-INFER-frame fixed cost

    def with_wire(self, wire_dtype: str) -> "ServingInputs":
        base, frac = _parse_wire(wire_dtype)
        w = base if frac is None else f"{base}+topk{frac:g}"
        if w == self.wire_dtype:
            return self
        return dataclasses.replace(self, wire_dtype=w)

    def hop_s(self, n_tokens: float) -> float:
        """INFER uplink seconds for ``n_tokens`` cut rows (one frame)."""
        if self.link_bw_Bps is None:
            return 0.0
        if self.d_model is None:
            raise ValueError(
                "ServingInputs: split serving (link_bw_Bps set) needs "
                "d_model for the INFER hop volume")
        block = wire_block_for(self.d_model)
        per = wire_bytes_per_element(self.wire_dtype, self.act_bytes,
                                     block)
        return (float(n_tokens) * self.d_model * per
                / float(self.link_bw_Bps)) + self.hop_overhead_s

    def step_s(self, slots: int) -> float:
        """Wall seconds of one engine decode step at an arena size: the
        fixed-shape program computes ALL lanes plus one INFER frame of
        ``slots`` cut rows when split."""
        return (self.step_overhead_s + slots * self.decode_lane_s
                + self.hop_s(slots))


# ln(100): the p99 quantile of an exponential residual wait.
_P99_EXP = 4.605170185988092


def serving_wall(inp: ServingInputs, slots: int) -> dict:
    """Score one slot-arena size under the offered load.

    Returns the serving twin of ``plan_wall_time``'s evidence: modeled
    ``tokens_per_s`` throughput, mean slot ``occupancy`` (Little's law:
    arrivals x per-request decode residency), utilization ``rho``
    against the arena's token capacity, and a ``p99_ttft_s`` estimate —
    prefill + first decode step plus an M/M/1-flavored queueing residual
    ``residency * rho / (1 - rho)`` at its exponential p99 quantile.
    An overloaded arena (``rho >= 1``, or prefill alone over-committing
    the engine) scores infinite latency rather than raising, so the
    argmin search can skip it.  Larger arenas trade the other way: the
    fixed-shape step computes every lane, so per-token latency grows
    with ``slots`` — the interior optimum ``choose_serving_plan`` finds.
    """
    if slots < 1:
        raise ValueError(f"slots={slots} must be >= 1")
    step_s = inp.step_s(slots)
    # engine-time fraction prefill steals (each prompt token also rides
    # one INFER prefill frame when split, amortized per token)
    prefill_req_s = inp.prompt_tokens * inp.prefill_s_per_token \
        + inp.hop_s(inp.prompt_tokens)
    f = inp.arrival_hz * prefill_req_s
    demand = inp.arrival_hz * inp.gen_tokens        # decode tokens/s
    if f >= 1.0:
        return {"slots": int(slots), "tokens_per_s": 0.0,
                "capacity_tokens_per_s": 0.0, "occupancy": float(slots),
                "rho": float("inf"), "p99_ttft_s": float("inf"),
                "per_token_s": float("inf"), "step_s": step_s}
    capacity = slots * (1.0 - f) / step_s
    # a lane's decode steps dilate by 1/(1-f): prefill chunks interleave
    per_token_s = step_s / (1.0 - f)
    residency_s = inp.gen_tokens * per_token_s      # one request's decode
    occupancy = inp.arrival_hz * residency_s        # mean busy slots
    rho = demand / capacity if capacity > 0 else float("inf")
    if rho >= 1.0:
        p99 = float("inf")
        served = capacity
    else:
        wait_s = residency_s * rho / (1.0 - rho)
        p99 = prefill_req_s + per_token_s + _P99_EXP * wait_s
        served = demand
    return {"slots": int(slots), "tokens_per_s": float(served),
            "capacity_tokens_per_s": float(capacity),
            "occupancy": float(occupancy), "rho": float(rho),
            "p99_ttft_s": float(p99), "per_token_s": float(per_token_s),
            "step_s": float(step_s)}


@dataclasses.dataclass(frozen=True)
class ServingPlan:
    """A serving-planner decision plus the evidence it was made on."""

    slots: int
    wire_dtype: str
    p99_ttft_s: float
    tokens_per_s: float
    occupancy: float
    rho: float
    inputs: ServingInputs

    def to_dict(self) -> dict:
        return {"slots": self.slots, "wire_dtype": self.wire_dtype,
                "p99_ttft_s": self.p99_ttft_s,
                "tokens_per_s": self.tokens_per_s,
                "occupancy": self.occupancy, "rho": self.rho}


def choose_serving_plan(inp: ServingInputs,
                        wire_candidates=None) -> ServingPlan:
    """Argmin of ``serving_wall``'s p99 latency over the slot candidates
    (and, optionally, the INFER-hop codec — only dense codecs are legal
    on the forward-only serving hop, so '+topk' candidates raise).

    Deterministic: ties keep the first-enumerated candidate (earlier
    wire candidate, then smaller arena).  Raises if EVERY candidate is
    overloaded — an infinite-latency plan is not a plan.
    """
    wires = list(wire_candidates) if wire_candidates \
        else [inp.wire_dtype]
    for w_cand in wires:
        if _parse_wire(w_cand)[1] is not None:
            raise ValueError(
                f"serving wire candidate {w_cand!r}: the INFER hop is "
                "forward-only — dense codecs only (none/int8/fp8)")
    best = None
    for wd in wires:
        inp_w = inp.with_wire(wd)
        for slots in inp.slot_candidates:
            ev = serving_wall(inp_w, int(slots))
            key = ev["p99_ttft_s"]
            if np.isfinite(key) \
                    and (best is None or key < best[0] * (1.0 - _TIE_RTOL)):
                best = (key, ev, inp_w)
    if best is None:
        raise ValueError(
            f"every serving candidate is overloaded (arrival_hz="
            f"{inp.arrival_hz}, gen_tokens={inp.gen_tokens}) — no slot "
            f"count in {tuple(inp.slot_candidates)} keeps rho < 1")
    _key, ev, inp_w = best
    return ServingPlan(slots=ev["slots"], wire_dtype=inp_w.wire_dtype,
                       p99_ttft_s=ev["p99_ttft_s"],
                       tokens_per_s=ev["tokens_per_s"],
                       occupancy=ev["occupancy"], rho=ev["rho"],
                       inputs=inp_w)


# ---------------------------------------------------------------------------
# Extraction: dry-run record / model config -> PlanInputs.
# ---------------------------------------------------------------------------


# Element widths for the dtype strings dryrun records carry.  Resolved
# WITHOUT np.dtype: this module stays jax-free, and plain numpy does not
# understand 'bfloat16'/'float8_*' unless ml_dtypes has been imported —
# which the planner-smoke CLI deliberately never does.
_DTYPE_BYTES = {
    "float64": 8.0, "float32": 4.0, "float16": 2.0, "bfloat16": 2.0,
    "float8_e4m3fn": 1.0, "float8_e5m2": 1.0,
}


def _dtype_bytes(dtype_name, default: float = 2.0) -> float:
    """Record dtype string -> element bytes (bf16 default when absent or
    unrecognized)."""
    if dtype_name is None:
        return default
    name = str(dtype_name)
    if name in _DTYPE_BYTES:
        return _DTYPE_BYTES[name]
    try:
        return float(np.dtype(name).itemsize)
    except TypeError:
        return default


def _pod_stages_from_mesh(mesh_name: str) -> int:
    """'2x16x16' -> 2 (pod axis is leading on the multi-pod mesh)."""
    dims = [int(d) for d in str(mesh_name).split("x") if d]
    if len(dims) == 3:
        return dims[0]
    raise ValueError(
        f"mesh {mesh_name!r} has no pod axis — the pipeline planner needs "
        "a multi-pod record (or pass num_stages explicitly)")


def plan_inputs_from_record(record: dict, *, num_stages: int | None = None,
                            k_cap: int | None = None,
                            v_cap: int | None = None,
                            num_layers: int | None = None,
                            hop_overhead_s: float | None = None,
                            bwd_fwd_ratio: float = 2.0,
                            wire_dtype: str | None = None,
                            extra_hints: dict | None = None) -> PlanInputs:
    """Extract planner inputs from one dry-run record (dryrun.py JSONL).

    * Stage time: ``max(t_compute, t_memory, t_collective)`` — the
      per-chip roofline seconds of the compiled step, which equal the
      per-stage wall time at any S under a fixed chip budget.  The ICI
      collective term belongs to the stage (intra-stage data/model-axis
      gathers and reduces are work the stage does per batch); the DCN
      term is exactly the inter-stage hop this extraction prices
      separately via the ppermute bytes.  Records compiled WITH the
      pipeline include the masked warm-up/drain ticks in their HLO
      FLOPs, so the raw terms are normalized by ``k*v / ticks``.
    * Link time: the per-chip ``collective-permute`` bytes are
      ``2 * ticks * (hop_bytes / k)`` (one micro-batch payload per tick,
      forward + backward), inverted for ``hop_bytes`` and billed at the
      link bandwidth (``planner_hints.link_bw_Bps`` — e.g. measured by
      benchmarks/ppermute_probe.py — else the HW DCN constant; the
      pipeline axis crosses pods).  Records compiled WITH a wire codec
      (``record["wire_dtype"]``) carry already-shrunk ppermute bytes;
      the extraction un-scales them so ``PlanInputs.link_s`` is always
      the uncompressed hop and codecs can be re-enumerated fairly.
      Un-pipelined records carry no ppermute: provide
      ``planner_hints.act_hop_bytes`` or use ``plan_inputs_from_cfg``.
    * ``act_bytes``: uncompressed element width of the hop payload, from
      ``planner_hints.act_dtype_bytes``, else the record's ``dtype``,
      else bf16.  ``wire_dtype`` sets the codec the returned inputs are
      BILLED with (default 'none'); pass ``wire_candidates`` to
      ``choose_plan`` to enumerate instead.

    Per-key defaults come from an optional ``planner_hints`` dict in the
    record (how the checked-in fixture stays self-describing), overlaid
    by ``extra_hints`` (e.g. a ppermute-probe JSON); explicit keyword
    arguments win.  ``num_stages`` requests a TARGET stage count: the
    tick-schedule normalization below always uses the stage count the
    record was actually COMPILED with (hints / pod mesh axis) — only
    then is the result re-targeted via ``with_stages``.
    """
    rl = record.get("roofline", record)
    hints = dict(record.get("planner_hints", {}))
    if extra_hints:
        hints.update(extra_hints)
    rec_stages = hints.get("num_stages")
    if rec_stages is None:
        try:
            rec_stages = _pod_stages_from_mesh(record.get("mesh", ""))
        except ValueError:
            if num_stages is None:
                raise
            rec_stages = num_stages   # no mesh info: trust the caller
    rec_stages = int(rec_stages)
    k0 = int(record.get("pipeline_k", 0) or 0)
    v0 = int(record.get("pipeline_v", 1) or 1)

    stage_s = max(float(rl["t_compute_s"]), float(rl["t_memory_s"]),
                  float(rl.get("t_collective_s", 0.0)))
    ticks0 = schedule_ticks(k0, rec_stages, v0) if k0 else 0
    if k0:
        stage_s *= (k0 * v0) / ticks0     # drop the masked idle-tick compute

    act_bytes = hints.get("act_dtype_bytes")
    if act_bytes is None:
        act_bytes = _dtype_bytes(record.get("dtype"))
    act_bytes = float(act_bytes)
    d_model = record.get("d_model", hints.get("d_model"))
    d_model = int(d_model) if d_model is not None else None
    wblock = hints.get("wire_block")
    if wblock is None:
        wblock = wire_block_for(d_model)
    wblock = int(wblock)

    pp_bytes = float(rl.get("coll_by_kind", {}).get("collective-permute", 0.0))
    if k0 and pp_bytes > 0:
        hop_bytes = pp_bytes * k0 / (2.0 * ticks0)
        # records compiled WITH a codec ship shrunk payloads; recover the
        # uncompressed hop so the planner prices every codec from one
        # base.  The HLO bytes cover forward AND backward hops equally,
        # so a direction-asymmetric (+topk) record un-scales by the MEAN
        # of the two directions' scales.
        rec_wire = record.get("wire_dtype", "none")
        hop_bytes /= 0.5 * (
            wire_link_scale(rec_wire, act_bytes, wblock)
            + wire_link_scale_bwd(rec_wire, act_bytes, wblock, d_model))
    elif "act_hop_bytes" in hints:
        hop_bytes = float(hints["act_hop_bytes"])
    else:
        raise ValueError(
            "record has no pipeline collective-permute bytes to derive the "
            "link time from — re-run dryrun with --pipeline-k, add "
            "planner_hints.act_hop_bytes, or use plan_inputs_from_cfg")
    link_s = hop_bytes / float(hints.get("link_bw_Bps", HW["dcn_bw"]))

    if hop_overhead_s is None:
        hop_overhead_s = float(hints.get("hop_overhead_s",
                                         HW["dcn_latency_s"]))
    if k_cap is None:
        k_cap = int(hints.get("k_cap", 32))
    if v_cap is None:
        v_cap = int(hints.get("v_cap", 4))
    if num_layers is None:
        num_layers = hints.get("num_layers")

    ratio = 1.0 + bwd_fwd_ratio
    inp = PlanInputs(
        num_stages=rec_stages,
        stage_fwd_s=stage_s / ratio,
        stage_bwd_s=stage_s * bwd_fwd_ratio / ratio,
        link_s=link_s,
        hop_overhead_s=hop_overhead_s,
        k_cap=k_cap, v_cap=v_cap,
        num_layers=int(num_layers) if num_layers is not None else None,
        fixed_chip_budget=True,
        act_bytes=act_bytes,
        wire_block=wblock,
        codec_s_per_byte=float(hints.get("codec_s_per_byte", 0.0)),
        act_hop_bytes=hop_bytes,
        d_model=d_model,
    )
    if wire_dtype is not None:
        inp = inp.with_wire(wire_dtype)
    if num_stages is not None and int(num_stages) != rec_stages:
        inp = inp.with_stages(int(num_stages))
    return inp


def plan_inputs_from_cfg(cfg, *, batch: int, seq: int, num_stages: int,
                         k_cap: int | None = None, v_cap: int = 4,
                         hop_overhead_s: float | None = None,
                         bwd_fwd_ratio: float = 2.0,
                         link_bw_Bps: float | None = None,
                         codec_s_per_byte: float = 0.0) -> PlanInputs:
    """Compile-free planner inputs estimated from a model config.

    Used by ``train.py --pipeline-k auto`` when no dry-run record is
    supplied: 2N FLOPs/token forward, one chip per stage, the cut
    activation ``batch*seq*d_model`` at the config dtype over DCN (or a
    measured ``link_bw_Bps``, e.g. from benchmarks/ppermute_probe.py).
    The absolute scale is TPU-flavored (HW constants) but only the
    compute/link/overhead ratios steer the chosen (k, v).
    """
    n_params = float(cfg.param_count())
    tokens = float(batch) * float(seq)
    total_fwd_s = 2.0 * n_params * tokens / HW["peak_flops_bf16"]
    elt_bytes = float(np.dtype(cfg.dtype).itemsize)
    act_bytes = float(batch) * float(seq) * float(cfg.d_model) * elt_bytes
    return PlanInputs(
        num_stages=num_stages,
        stage_fwd_s=total_fwd_s / num_stages,
        stage_bwd_s=bwd_fwd_ratio * total_fwd_s / num_stages,
        link_s=act_bytes / (HW["dcn_bw"] if link_bw_Bps is None
                            else float(link_bw_Bps)),
        hop_overhead_s=HW["dcn_latency_s"] if hop_overhead_s is None
        else hop_overhead_s,
        k_cap=max(1, min(batch, 64)) if k_cap is None else k_cap,
        v_cap=v_cap,
        num_layers=cfg.num_layers,
        fixed_chip_budget=False,
        act_bytes=elt_bytes,
        wire_block=wire_block_for(cfg.d_model),
        codec_s_per_byte=codec_s_per_byte,
        act_hop_bytes=act_bytes,
        d_model=int(cfg.d_model),
    )


# ---------------------------------------------------------------------------
# CLI — the CI planner-smoke entry point.
# ---------------------------------------------------------------------------


def load_record(path: str, index: int = -1) -> dict:
    """Load one record from a dry-run JSON / JSONL file (records without a
    roofline — skip markers — are ignored)."""
    with open(path) as f:
        text = f.read()
    try:
        doc = json.loads(text)
        records = doc if isinstance(doc, list) else [doc]
    except json.JSONDecodeError:
        records = [json.loads(line) for line in text.splitlines()
                   if line.strip()]
    records = [r for r in records if "roofline" in r or "t_compute_s" in r]
    if not records:
        raise SystemExit(f"no roofline records in {path}")
    return records[index]


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Pick (S, k, v) from a dry-run roofline record")
    ap.add_argument("--roofline", required=True,
                    help="dry-run record (JSON or JSONL; see launch/dryrun)")
    ap.add_argument("--record-index", type=int, default=-1)
    ap.add_argument("--num-stages", type=int, default=0,
                    help="pin S (default: record hints / pod mesh axis)")
    ap.add_argument("--stage-candidates", default=None,
                    help="comma-separated S values for the joint trade")
    ap.add_argument("--k-cap", type=int, default=0)
    ap.add_argument("--v-cap", type=int, default=0)
    ap.add_argument("--layers", type=int, default=0,
                    help="layer count for the S*v divisibility constraint")
    ap.add_argument("--hop-overhead", type=float, default=None,
                    help="per-hop message overhead seconds "
                         "(default: HW dcn latency / record hints)")
    ap.add_argument("--wire", default="none",
                    help="hop codec to bill the plan with: none | int8 | "
                         "fp8 | '<base>+topk<frac>' (e.g. int8+topk0.25); "
                         "'auto' enumerates the codec jointly with (k, v)")
    ap.add_argument("--hints", default=None,
                    help="JSON with measured planner_hints (e.g. the "
                         "benchmarks/ppermute_probe.py output) overlaid "
                         "on the record's own hints")
    ap.add_argument("--out", default=None,
                    help="write the chosen plan as JSON")
    args = ap.parse_args(argv)

    extra_hints = None
    if args.hints:
        with open(args.hints) as f:
            doc = json.load(f)
        extra_hints = doc.get("planner_hints", doc)
    record = load_record(args.roofline, args.record_index)
    inp = plan_inputs_from_record(
        record,
        num_stages=args.num_stages or None,
        k_cap=args.k_cap or None,
        v_cap=args.v_cap or None,
        num_layers=args.layers or None,
        hop_overhead_s=args.hop_overhead,
        wire_dtype=None if args.wire == "auto" else args.wire,
        extra_hints=extra_hints)
    cands = None
    if args.stage_candidates:
        cands = [int(s) for s in args.stage_candidates.split(",") if s]
    plan = choose_plan(
        inp, stage_candidates=cands,
        wire_candidates=list(WIRE_AUTO) if args.wire == "auto" else None)
    print(f"auto plan: S={plan.num_stages} k={plan.k} v={plan.v} "
          f"wire={plan.wire_dtype}  "
          f"wall {plan.wall_s * 1e3:.3f} ms/batch  "
          f"({plan.speedup:.2f}x vs unpipelined, "
          f"bubble {plan.bubble:.3f})")
    if args.out:
        doc = {
            "source": args.roofline,
            "record": {key: record.get(key) for key in
                       ("arch", "shape", "mesh", "chips",
                        "pipeline_k", "pipeline_v", "wire_dtype")},
            "plan": plan.to_dict(),
        }
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"wrote {args.out}")
    return plan


if __name__ == "__main__":
    main()
