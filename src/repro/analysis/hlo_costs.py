"""Trip-count-aware static cost analysis of optimized (partitioned) HLO.

``compiled.cost_analysis()`` counts every while-loop body ONCE (verified in
EXPERIMENTS.md §Roofline-methodology), which under-counts a scanned L-layer
transformer by ~L.  This module re-derives the three roofline inputs from
``compiled.as_text()`` with loop multipliers:

  * parse computations and their instructions (result shapes from defs),
  * read each ``while`` op's ``backend_config known_trip_count``,
  * propagate multipliers through the call graph
    (body/condition/calls/to_apply),
  * FLOPs   = sum over ``dot`` ops of 2 * prod(result) * prod(contracting)
              x multiplier  (+ convolutions via the same formula on their
              metadata when present),
  * bytes   = sum over materializing ops of (operands + result) bytes
              x multiplier — the fusion-boundary traffic proxy,
  * collective bytes = result bytes of collective ops x multiplier
              (all-reduce weighted 2x: ring = reduce-scatter + all-gather).
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
    "u4": 1, "s4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\([^)]*\)|\w+\[[\d,]*\]\S*)\s+"
    r"([\w\-]+)\((.*)$")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CALL_ONE_RE = re.compile(
    r"(?:body|condition|calls|to_apply|true_computation|false_computation)"
    r"=%([\w\.\-]+)")
_CALL_LIST_RE = re.compile(r"(?:calls|branch_computations)=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops that don't touch HBM / carry no payload of their own
_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "while", "conditional", "call", "after-all",
               "partition-id", "replica-id", "iota"}


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _type_bytes(t: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(t):
        nb = _DTYPE_BYTES.get(dt, 0)
        total += _shape_elems(dims) * nb
    return total


def _type_elems(t: str) -> int:
    m = _SHAPE_RE.search(t)
    return _shape_elems(m.group(2)) if m else 0


@dataclasses.dataclass
class Instr:
    name: str
    rtype: str
    opcode: str
    rest: str        # operand list + attrs (raw tail of the line)

    def operand_names(self) -> list:
        # operands come before the first "),": cut at the matching paren —
        # heuristically the first ")," or trailing ")"
        depth = 0
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    head = self.rest[:i]
                    break
                depth -= 1
        else:
            head = self.rest
        return _OPERAND_RE.findall(head)

    def called_computations(self) -> list:
        out = [m.group(1) for m in _CALL_ONE_RE.finditer(self.rest)]
        for m in _CALL_LIST_RE.finditer(self.rest):
            out.extend(c.strip().lstrip("%") for c in m.group(1).split(","))
        return out

    def trip_count(self) -> int | None:
        m = _TRIP_RE.search(self.rest)
        return int(m.group(1)) if m else None


def parse_hlo(text: str) -> dict:
    """HLO text -> {computation_name: [Instr, ...]}; first key is entry."""
    comps: dict = {}
    cur = None
    entry = None
    for line in text.splitlines():
        if line.endswith("{") and ("->" in line or line.startswith("ENTRY")):
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                if line.startswith("ENTRY"):
                    entry = cur
                continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            comps[cur].append(Instr(m.group(1), m.group(2), m.group(3),
                                    m.group(4)))
    if entry and entry in comps:
        comps = {entry: comps[entry],
                 **{k: v for k, v in comps.items() if k != entry}}
    return comps


def while_reachable(comps: dict) -> set:
    """Names of computations that execute INSIDE some ``while`` op — the
    bodies/conditions of every while plus everything they transitively
    call.  This is the scope the pipeline auditor
    (``repro.analysis.staticcheck``) restricts itself to: collectives at
    entry (replicated embedding/LM-head grad reductions, GSPMD input
    reshards) are legitimate; inside the tick loop only the pipeline hop
    may touch the wire."""
    seeds: list = []
    for instrs in comps.values():
        for ins in instrs:
            if ins.opcode == "while":
                seeds.extend(ins.called_computations())
    reach = set()
    frontier = [c for c in seeds if c in comps]
    while frontier:
        name = frontier.pop()
        if name in reach:
            continue
        reach.add(name)
        for ins in comps[name]:
            frontier.extend(c for c in ins.called_computations()
                            if c in comps and c not in reach)
    return reach


def result_shape(rtype: str):
    """First ``(dtype, dims)`` of a result type string.

    For sync collectives this is the result itself; for the async
    ``-start`` spelling, whose result is a ``(operand, result, ...)``
    tuple, it is the operand — either way exactly ONE wire copy of the
    payload, which is what byte-honesty accounting needs (``_type_bytes``
    on the full tuple would double-count).
    """
    m = _SHAPE_RE.search(rtype)
    if not m:
        return None
    return m.group(1), tuple(int(d) for d in m.group(2).split(",") if d)


def source_target_pairs(rest: str):
    """``source_target_pairs={{0,2},{1,3}}`` -> [(0, 2), (1, 3)] (empty
    list when the attribute is absent)."""
    m = _STP_RE.search(rest)
    if not m:
        return []
    pairs = []
    for chunk in m.group(1).split("},{"):
        ids = [int(x) for x in chunk.replace("{", "").replace("}", "")
               .split(",") if x.strip()]
        if len(ids) == 2:
            pairs.append((ids[0], ids[1]))
    return pairs


def computation_multipliers(comps: dict) -> dict:
    """Propagate loop trip counts down the call graph."""
    mult = {name: 0.0 for name in comps}
    entry = next(iter(comps))
    mult[entry] = 1.0
    # topological-ish fixed point (call graphs are shallow)
    for _ in range(64):
        changed = False
        for name, instrs in comps.items():
            m = mult.get(name, 0.0)
            if m == 0.0:
                continue
            for ins in instrs:
                called = ins.called_computations()
                if not called:
                    continue
                k = m
                if ins.opcode == "while":
                    trip = ins.trip_count() or 1
                    k = m * trip
                for c in called:
                    if c in mult and mult[c] < k:
                        mult[c] = k
                        changed = True
        if not changed:
            break
    return mult


_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_METADATA_RE = re.compile(r'op_name="([^"]*)"')
_RG_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_RG_LIST_RE = re.compile(r"replica_groups=\{\{([\d,{}\s]*)\}\}")
# lazy up to the closing "}}" so EVERY pair is captured ("{0,2},{1,3"),
# not just the text before the first "}" (which would drop all but the
# first pair and blind any per-pair analysis of multi-pair permutes)
_STP_RE = re.compile(r"source_target_pairs=\{(.*?)\}\}")


def _crosses_pod(rest: str, pod_size: int) -> bool:
    """Does this collective's group structure span a pod boundary?

    Device ids are pod-major on our meshes (id // pod_size = pod index).
    """
    import numpy as np
    m = _RG_IOTA_RE.search(rest)
    if m:
        n_groups, group_size = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        total = int(np.prod(dims))
        ids = np.arange(total).reshape(dims)
        if m.group(4):
            perm = [int(p) for p in m.group(4).split(",")]
            ids = ids.transpose(perm)
        groups = ids.reshape(n_groups, group_size)
        pods = groups // pod_size
        return bool(np.any(pods.min(axis=1) != pods.max(axis=1)))
    m = _RG_LIST_RE.search(rest)
    if m:
        for grp in m.group(1).split("},{"):
            ids = [int(x) for x in grp.replace("{", "").replace("}", "")
                   .split(",") if x.strip()]
            if ids and min(ids) // pod_size != max(ids) // pod_size:
                return True
        return False
    m = _STP_RE.search(rest)
    if m:
        for pair in m.group(1).split("},{"):
            ids = [int(x) for x in pair.replace("{", "").replace("}", "")
                   .split(",") if x.strip()]
            if len(ids) == 2 and ids[0] // pod_size != ids[1] // pod_size:
                return True
        return False
    return False

# ops whose result is genuinely materialized to HBM on TPU (fusion-optimal
# traffic model: elementwise chains fuse into their matmul/reduce consumers
# and are "free"; what must move is matmul operands/results, reshuffles,
# and collective payloads)
_GATHERISH = {"dynamic-slice", "gather", "scatter",
              "copy", "transpose", "reshape"}


def analyze(text: str, top_n: int = 0, pod_size: int = 256,
            tpu_model: bool = False) -> dict:
    """Static roofline inputs -> {flops, bytes, coll_bytes, coll_by_kind,
    n_while, top_traffic, top_coll}.

    The memory term is the FUSION-OPTIMAL HBM traffic (roofline spirit:
    best-case time per resource): dot/convolution operands + results,
    gather/scatter/copy payloads, and collective payloads — all x loop
    multiplier.  Elementwise ops are assumed fused (free).

    ``tpu_model=True`` corrects two CPU-backend lowering artifacts that the
    TPU target does not have (EXPERIMENTS.md §Perf methodology):
      * XLA:CPU float-normalization upcasts every bf16 dot to f32 and
        hoists the weight converts out of the layer loop, so semantically-
        bf16 weight gathers / grad reduce payloads appear as f32 — billed
        at half width (native MXU bf16);
      * the jnp attention fallback materializes the [.., G, S] probability
        tensor with layout copies; the production path is the Pallas flash
        kernel (repro/kernels/flash_attention.py) where it never leaves
        VMEM — attention-internal einsum traffic (op_name containing the
        'bkrg' einsum labels) is dropped (FLOPs kept).
    """
    comps = parse_hlo(text)
    mult = computation_multipliers(comps)

    flops = 0.0
    traffic = 0.0
    coll = {k: 0.0 for k in COLLECTIVES}
    coll_dcn = 0.0        # bytes of collectives whose groups cross pods
    n_while = 0
    contrib_t: list = []
    contrib_c: list = []

    def note(lst, ins, nb, m):
        if top_n:
            md = _METADATA_RE.search(ins.rest)
            lst.append((nb * m, ins.opcode, ins.rtype[:48],
                        md.group(1)[-120:] if md else ""))

    def op_name(ins):
        md = _METADATA_RE.search(ins.rest)
        return md.group(1) if md else ""

    def attn_internal(ins):
        """Inner-kernel traffic: attention / WKV / LRU chunk-loop bodies.

        These live inside a second while level (layer scan x chunk scan);
        on TPU the Pallas kernels keep them VMEM-resident.  FLOPs are
        still counted — only HBM traffic is dropped.
        """
        if not tpu_model:
            return False
        name = op_name(ins)
        return "bkrg" in name or name.count("while/body") >= 2

    def f32_discount(ins):
        """0.5 for f32 payloads that are semantically bf16 on TPU
        (weight gathers / activation-grad reduces of bf16 params; XLA:CPU
        float-normalization upcasts them)."""
        if tpu_model and "f32[" in ins.rtype and "bf16[" not in ins.rtype:
            return 0.5
        return 1.0

    for name, instrs in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        symbols = {ins.name: ins.rtype for ins in instrs}
        for ins in instrs:
            if ins.opcode == "while":
                n_while += 1
            if ins.opcode in ("dot", "dot-general"):
                res_elems = _type_elems(ins.rtype)
                cm = _CONTRACT_RE.search(ins.rest)
                k_elems = 1
                ops = ins.operand_names()
                if cm and ops:
                    lhs_t = symbols.get(ops[0], "")
                    sm = _SHAPE_RE.search(lhs_t)
                    if sm:
                        dims = [int(d) for d in sm.group(2).split(",") if d]
                        for ci in cm.group(1).split(","):
                            if ci and int(ci) < len(dims):
                                k_elems *= dims[int(ci)]
                flops += 2.0 * res_elems * k_elems * m
                if not attn_internal(ins):
                    nb = (_type_bytes(ins.rtype) + sum(
                        _type_bytes(symbols.get(op, ""))
                        for op in ops[:2])) * f32_discount(ins)
                    traffic += nb * m
                    note(contrib_t, ins, nb, m)
            elif ins.opcode == "convolution":
                # 2 * out_elems * (kernel receptive field x c_in)
                res_elems = _type_elems(ins.rtype)
                rm = _SHAPE_RE.search(ins.rtype)
                c_out = int(rm.group(2).split(",")[-1]) if rm and rm.group(2) \
                    else 1
                ops = ins.operand_names()
                k_elems = 1
                if len(ops) > 1:
                    k_elems = max(1, _type_elems(symbols.get(ops[1], "")))
                flops += 2.0 * res_elems * (k_elems / max(c_out, 1)) * m
                nb = _type_bytes(ins.rtype) + sum(
                    _type_bytes(symbols.get(op, "")) for op in ops[:2])
                traffic += nb * m
                note(contrib_t, ins, nb, m)
            elif ins.opcode in _GATHERISH:
                if not attn_internal(ins):
                    nb = 2.0 * _type_bytes(ins.rtype) * f32_discount(ins)
                    traffic += nb * m
                    note(contrib_t, ins, nb, m)
            elif ins.opcode == "dynamic-update-slice":
                # in-place on TPU: traffic = the update slice, not the
                # full result buffer (a KV-cache insert writes one token)
                ops = ins.operand_names()
                upd = _type_bytes(symbols.get(ops[1], "")) if len(ops) > 1 \
                    else 0
                nb = 2.0 * upd
                traffic += nb * m
                note(contrib_t, ins, nb, m)
            elif ins.opcode == "reduce":
                ops = ins.operand_names()
                nb = sum(_type_bytes(symbols.get(op, "")) for op in ops[:1])
                traffic += nb * m
                note(contrib_t, ins, nb, m)
            for kind in COLLECTIVES:
                if ins.opcode == kind or ins.opcode == kind + "-start":
                    nb = _type_bytes(ins.rtype) * f32_discount(ins)
                    w = 2.0 if kind == "all-reduce" else 1.0
                    coll[kind] += nb * m
                    traffic += 2.0 * nb * m
                    if _crosses_pod(ins.rest, pod_size):
                        coll_dcn += w * nb * m
                    note(contrib_c, ins, nb, m)

    out = {
        "flops": flops,
        "bytes": traffic,
        "coll_by_kind": coll,
        "coll_bytes": (2.0 * coll["all-reduce"] + coll["all-gather"]
                       + coll["reduce-scatter"] + coll["all-to-all"]
                       + coll["collective-permute"]),
        "coll_dcn_bytes": coll_dcn,
        "n_while": n_while,
    }
    if top_n:
        out["top_traffic"] = sorted(contrib_t, reverse=True)[:top_n]
        out["top_coll"] = sorted(contrib_c, reverse=True)[:top_n]
    return out


def flat_cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as one flat dict, across JAX versions.

    These are the trip-count-UNAWARE numbers (each while body counted
    once) that ``analyze`` corrects; they're retained in dry-run records
    for reference.  Legacy JAX returns a list of per-program dicts, new
    JAX a dict — normalization lives in parallel/compat.py.
    """
    from repro.parallel.compat import cost_analysis_dict
    return cost_analysis_dict(compiled)
