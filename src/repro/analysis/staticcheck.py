"""Pipeline invariant auditor: jaxpr/HLO static analysis of the C2P2SL
pipeline's collectives, sharding leaks, and wire-byte honesty.

The pipeline's correctness rests on invariants nothing at runtime checks:

  * the 1F1B tick schedule must lower to collision-free BIJECTIVE
    ``ppermute``s whose permutation is exactly the schedule's hop
    (``pipeline.hop_perms`` forward, its transpose backward);
  * the wire codec (PR 5/6) must keep the coded hop at its declared
    element width — a single GSPMD reshard can silently re-inflate an
    int8 payload to f32 and void the planner's byte model;
  * no all-gather/all-reduce may cross the pod boundary INSIDE the tick
    loop (entry-level replicated-grad reductions are legitimate);
  * the planner's ``autotune.wire_bytes_per_element(_bwd)`` must equal
    what the compiled HLO actually ships per hop ("billed bytes ==
    compiled bytes") — the precondition for trustworthy adaptive
    re-planning (ROADMAP).

Three layers, composable and individually callable (tests exercise each
detector in isolation so one seeded defect yields exactly one violation):

  * **jaxpr audit** (``audit_jaxpr`` / ``audit_cells(level='jaxpr')``):
    traces ``make_pipelined_loss`` grads through ``compat.abstract_mesh``
    — device-free, works on BOTH shard_map lowerings — and walks every
    (sub-)jaxpr for ppermute bijectivity/schedule, payload/index dtype
    contract, and pod-axis collective leaks.
  * **HLO audit** (``audit_hlo_text`` / ``audit_cells(level='hlo')``):
    parses compiled module text (``repro.analysis.hlo_costs``) scoped to
    while-reachable computations (the tick loops), checks device-level
    permutation bijectivity + pod-lifted schedule match, payload dtypes,
    cross-pod leaks, and reconciles per-tick hop bytes against the
    planner byte model.
  * **AST lint pack** (``repro.analysis.lint``): repo-specific rules ruff
    cannot express — tracer branching / concretization in
    ``_tick_loop``-reachable code, nested ``jax.jit``, ``pallas_call``
    without the ``interpret`` plumbing idiom.

CLI (the CI ``staticcheck`` job runs this on both JAX legs)::

    python -m repro.analysis.staticcheck                 # jaxpr + lint + model
    python -m repro.analysis.staticcheck --level full    # + compiled-HLO audit
    python -m repro.analysis.staticcheck --lint [paths]  # lint only
    python -m repro.analysis.staticcheck --selftest      # seeded-violation corpus
    python -m repro.analysis.staticcheck --report out.json --diff \
        benchmarks/STATICCHECK_baseline.json

This module imports numpy only at module scope (jax lazily, inside the
audit functions) so ``--lint`` and the byte-model checks run before any
accelerator stack exists — same discipline as ``analysis/autotune.py``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

from repro.analysis import autotune, hlo_costs

#: Violation taxonomy (docs/staticcheck.md catalogs each class).
VIOLATION_CLASSES = (
    "ppermute-bijection",   # hop permutation is not a bijection
    "ppermute-schedule",    # hop permutation != the tick schedule's hop
    "sharding-leak",        # cross-pod collective inside the tick loop
    "wire-payload-dtype",   # coded-hop payload width != declared codec
    "wire-index-dtype",     # top-k index dtype != declared codec
    "vjp-residual-dtype",   # custom_vjp fwd/bwd residual contract broken
    "wire-bytes",           # compiled hop bytes != planner byte model
    "wire-bytes-model",     # autotune byte model != payload contract
    "lint",                 # AST rule pack finding (rule id in detail)
)

#: Canonical HLO spelling of each base codec's on-wire payload dtype —
#: numpy-only mirror of ``repro.kernels.wire_codec.PAYLOAD_HLO_DTYPE``
#: (that module imports jax/pallas); a tier-1 test pins the two copies.
#: fp8 payloads spell ``s8`` too: ``wire._wire_ppermute`` bitcasts
#: 1-byte float payloads to int8 around the collective precisely so a
#: backend without f8 collectives cannot re-inflate the hop to f16.
PAYLOAD_HLO_DTYPE = {"int8": "s8", "fp8": "s8"}

#: numpy/jax dtype name -> HLO element type (payload classification).
NP_TO_HLO_DTYPE = {
    "int8": "s8", "int16": "s16", "int32": "s32", "int64": "s64",
    "uint8": "u8", "uint16": "u16", "uint32": "u32",
    "float8_e4m3fn": "f8e4m3fn", "float8_e5m2": "f8e5m2",
    "bfloat16": "bf16", "float16": "f16", "float32": "f32",
    "float64": "f64", "bool": "pred",
}

_HLO_DTYPE_BYTES = dict(hlo_costs._DTYPE_BYTES)


@dataclasses.dataclass(frozen=True)
class Violation:
    """One classified invariant violation."""
    cls: str        # one of VIOLATION_CLASSES
    where: str      # cell / computation / file:line the finding anchors to
    detail: str     # human-readable defect statement

    def __post_init__(self):
        if self.cls not in VIOLATION_CLASSES:
            raise ValueError(
                f"unknown violation class {self.cls!r} — add it to "
                f"staticcheck.VIOLATION_CLASSES {VIOLATION_CLASSES}")

    def to_dict(self) -> dict:
        return {"class": self.cls, "where": self.where,
                "detail": self.detail}


def by_class(violations) -> dict:
    out: dict = {}
    for v in violations:
        out[v.cls] = out.get(v.cls, 0) + 1
    return out


# ---------------------------------------------------------------------------
# Schedule-level expectations (numpy-only mirror of pipeline.hop_perms).
# ---------------------------------------------------------------------------


def expected_hop_perms(num_stages: int, virtual_stages: int):
    """``(forward, backward)`` hop permutations of the tick schedule on
    the pod axis — numpy-only mirror of ``parallel.pipeline.hop_perms``
    (that module imports jax; a tier-1 test pins the two)."""
    s = int(num_stages)
    if s <= 1:
        return (), ()
    if int(virtual_stages) > 1:
        fwd = tuple((i, (i + 1) % s) for i in range(s))
    else:
        fwd = tuple((i, i + 1) for i in range(s - 1))
    return fwd, tuple((dst, src) for src, dst in fwd)


def check_perm_bijection(perm, axis_size: int, where: str = "perm"):
    """A hop permutation must be a partial bijection on [0, axis_size):
    unique sources, unique destinations, every endpoint in range.
    Returns at most ONE violation (the first defect found) so a seeded
    non-bijective permutation maps to exactly one finding."""
    pairs = [tuple(p) for p in perm]
    srcs = [s for s, _ in pairs]
    dsts = [d for _, d in pairs]
    for s, d in pairs:
        if not (0 <= s < axis_size and 0 <= d < axis_size):
            return [Violation(
                "ppermute-bijection", where,
                f"pair ({s}, {d}) outside the axis [0, {axis_size})")]
    if len(set(srcs)) != len(srcs):
        dup = sorted(s for s in set(srcs) if srcs.count(s) > 1)
        return [Violation(
            "ppermute-bijection", where,
            f"duplicate source(s) {dup}: two sends from one device "
            f"collide — perm {tuple(pairs)} is not injective")]
    if len(set(dsts)) != len(dsts):
        dup = sorted(d for d in set(dsts) if dsts.count(d) > 1)
        return [Violation(
            "ppermute-bijection", where,
            f"duplicate destination(s) {dup}: two payloads land on one "
            f"device — perm {tuple(pairs)} is not a bijection")]
    return []


def check_perm_schedule(perm, num_stages: int, virtual_stages: int,
                        where: str = "perm"):
    """The permutation (as a set of pairs) must be the schedule's forward
    hop or its transpose (the backward hop).  Returns at most one
    violation."""
    fwd, bwd = expected_hop_perms(num_stages, virtual_stages)
    got = frozenset(tuple(p) for p in perm)
    if got in (frozenset(fwd), frozenset(bwd)):
        return []
    return [Violation(
        "ppermute-schedule", where,
        f"perm {sorted(got)} matches neither the schedule's forward hop "
        f"{sorted(fwd)} nor its transpose {sorted(bwd)} "
        f"(S={num_stages}, v={virtual_stages})")]


# ---------------------------------------------------------------------------
# Wire payload contract (what a codec is allowed to put on the wire).
# ---------------------------------------------------------------------------


def hop_contract(wire_dtype: str, act_dtype: str = "float32",
                 d_model: int = 0) -> dict:
    """The on-wire contract of one hop under ``wire_dtype`` for an
    activation of HLO/numpy dtype ``act_dtype`` and row width ``d_model``:
    which element types may ride the ppermute, the top-k index dtype, and
    whether the net-loss raw fallback applies."""
    base, frac = autotune._parse_wire(wire_dtype)
    act_hlo = NP_TO_HLO_DTYPE.get(act_dtype, act_dtype)
    act_bytes = _HLO_DTYPE_BYTES.get(act_hlo, 0)
    d = int(d_model)
    block = autotune.wire_block_for(d)
    net_loss = base != "none" and (1.0 + 4.0 / block) >= float(act_bytes)
    kk = max(1, min(d, int(round(frac * d)))) if frac and d else None
    idx_hlo = None
    if frac is not None:
        idx_hlo = "s16" if d <= 32767 else "s32"
    return {
        "wire_dtype": wire_dtype, "base": base, "frac": frac,
        "act_hlo": act_hlo, "act_bytes": act_bytes,
        "payload_hlo": PAYLOAD_HLO_DTYPE.get(base),
        "idx_hlo": idx_hlo, "kk": kk,
        "d_model": d, "block": block, "net_loss": net_loss,
    }


def classify_hop_payload(contract: dict, hlo_dtype: str, dims,
                         where: str = "hop"):
    """Violations for one buffer riding a hop ppermute under
    ``contract`` (built by ``hop_contract``).

    Legitimate buffers: the raw activation ('none' codec, or a declared
    net-loss fallback), the base codec's quantized payload, trailing-dim-1
    f32 scales, and (top-k only) the declared index dtype.  A full-width
    float payload under a quantized codec is the "forged f32 hop" the
    auditor exists to catch.
    """
    dims = tuple(dims)
    c = contract
    if c["base"] == "none":
        if hlo_dtype != c["act_hlo"]:
            return [Violation(
                "wire-payload-dtype", where,
                f"raw hop ships {hlo_dtype}{list(dims)} but the "
                f"activation is {c['act_hlo']} — wire_dtype='none' must "
                "be bit-for-bit the uncoded pipeline")]
        return []
    if hlo_dtype == c["payload_hlo"]:
        return []
    if hlo_dtype in ("s16", "s32"):
        if c["frac"] is None:
            return [Violation(
                "wire-index-dtype", where,
                f"index payload {hlo_dtype}{list(dims)} on a dense "
                f"{c['wire_dtype']!r} hop — only '+topk' codecs ship "
                "indices")]
        if hlo_dtype != c["idx_hlo"]:
            return [Violation(
                "wire-index-dtype", where,
                f"top-k indices are {hlo_dtype} but d_model="
                f"{c['d_model']} declares {c['idx_hlo']} "
                "(wire.topk_index_dtype)")]
        return []
    if hlo_dtype == "f32" and dims and dims[-1] == 1:
        return []     # per-block / per-row scales
    if hlo_dtype == c["act_hlo"] and c["net_loss"]:
        return []     # documented codec_net_loss raw fallback
    return [Violation(
        "wire-payload-dtype", where,
        f"{hlo_dtype}{list(dims)} payload on a {c['wire_dtype']!r} hop — "
        f"declared codec ships {c['payload_hlo']} payloads"
        + ("" if c["frac"] is None else f" + {c['idx_hlo']} indices")
        + " + trailing-dim-1 f32 scales (a full-width float here is a "
        "re-inflated hop that voids the planner byte model)")]


# ---------------------------------------------------------------------------
# Planner byte-model honesty (autotune vs the payload contract).
# ---------------------------------------------------------------------------


def expected_schedule_ticks(k: int, num_stages: int,
                            virtual_stages: int) -> int:
    """One-direction tick count of the interleaved 1F1B schedule,
    re-derived here from the schedule definition (``sigma(m) =
    (m//S)*S*v + m%S``; last entry plus the S*v-tick drain) —
    independent of ``autotune.schedule_ticks`` so drift in the planner's
    copy of the schedule math is detectable."""
    s, v = int(num_stages), int(virtual_stages)
    sigma_last = ((k - 1) // s) * s * v + ((k - 1) % s)
    return sigma_last + s * v


def check_byte_model(wire_dtype: str, direction: str = "fwd", *,
                     act_bytes: float = 4.0, d_model: int = 2560,
                     payload_bytes: float = 1.0, scale_bytes: float = 4.0,
                     index_bytes: float | None = None,
                     rtol: float = 1e-9):
    """Reconcile ``autotune.wire_bytes_per_element(_bwd)`` against the
    wire format's first-principles byte count for one (codec, direction).

    The expectation is derived HERE, independently, from the payload
    contract: dense hop = 1 payload byte/element + 4 scale bytes per
    block; top-k backward hop = ``frac*(1 + idx)`` + 4 bytes per row of
    d.  The ``payload_bytes``/``scale_bytes``/``index_bytes`` knobs exist
    so tests can perturb one constant by 1 and prove the detector fires
    with exactly one classified violation; production calls leave the
    defaults (the real wire format).
    """
    base, frac = autotune._parse_wire(wire_dtype)
    block = autotune.wire_block_for(d_model)
    d = int(d_model)
    where = f"byte-model:{wire_dtype}:{direction}"
    if base == "none":
        want = float(act_bytes)
    else:
        dense = float(payload_bytes) + float(scale_bytes) / block
        if direction == "fwd" or frac is None or dense >= float(act_bytes):
            want = dense
        else:
            idx = index_bytes
            if idx is None:
                idx = 2.0 if d <= 32767 else 4.0
            want = frac * (float(payload_bytes) + idx) \
                + float(scale_bytes) / d
    if direction == "fwd":
        got = autotune.wire_bytes_per_element(wire_dtype, act_bytes, block)
    else:
        got = autotune.wire_bytes_per_element_bwd(wire_dtype, act_bytes,
                                                  block, d)
    if abs(got - want) > rtol * max(abs(got), abs(want), 1e-12):
        return [Violation(
            "wire-bytes-model", where,
            f"autotune bills {got:.6g} B/element but the wire format "
            f"costs {want:.6g} (act_bytes={act_bytes}, block={block}, "
            f"d_model={d}) — codec and planner drifted apart")]
    return []


def audit_byte_model(*, act_bytes: float = 4.0, d_model: int = 2560,
                     wires=autotune.WIRE_AUTO, **knobs):
    """Byte-model reconciliation over every codec x direction."""
    out = []
    for w in wires:
        for direction in ("fwd", "bwd"):
            out += check_byte_model(w, direction, act_bytes=act_bytes,
                                    d_model=d_model, **knobs)
    return out


def audit_record_honesty(record: dict, *, rtol: float = 1e-6, **knobs):
    """Planner honesty on a dry-run record (e.g. the checked-in
    ``tests/fixtures/roofline_smoke.json``): (1) re-billing the extracted
    uncompressed hop through the byte model must reproduce the record's
    measured per-chip collective-permute bytes (drift in the tick/sigma
    schedule math or the extraction inversion fires here), and (2) the
    byte model itself must match the payload contract at the record's
    act_bytes / block / d_model (``audit_byte_model``).

    Returns ``(violations, stats)``.
    """
    rl = record.get("roofline", record)
    hints = record.get("planner_hints", {})
    inp = autotune.plan_inputs_from_record(record)
    k0 = int(record.get("pipeline_k", 0) or 0)
    v0 = int(record.get("pipeline_v", 1) or 1)
    s0 = int(hints.get("num_stages", inp.num_stages))
    pp = float(rl.get("coll_by_kind", {}).get("collective-permute", 0.0))
    violations = []
    stats = {"k0": k0, "v0": v0, "num_stages": s0,
             "act_hop_bytes": inp.act_hop_bytes,
             "measured_pp_bytes": pp}
    if k0 and pp > 0:
        ticks0 = autotune.schedule_ticks(k0, s0, v0)
        want_ticks = expected_schedule_ticks(k0, s0, v0)
        if ticks0 != want_ticks:
            violations.append(Violation(
                "wire-bytes", f"record:{record.get('arch', '?')}",
                f"autotune.schedule_ticks bills {ticks0} ticks but the "
                f"1F1B schedule definition gives {want_ticks} "
                f"(k={k0}, S={s0}, v={v0}) — the planner's schedule "
                "math drifted from the tick loop's"))
        rec_wire = record.get("wire_dtype", "none")
        mean_scale = 0.5 * (
            autotune.wire_link_scale(rec_wire, inp.act_bytes,
                                     inp.wire_block)
            + autotune.wire_link_scale_bwd(rec_wire, inp.act_bytes,
                                           inp.wire_block, inp.d_model))
        rebilled = 2.0 * ticks0 / k0 * inp.act_hop_bytes * mean_scale
        stats.update(ticks0=ticks0, rebilled_pp_bytes=rebilled)
        if abs(rebilled - pp) > rtol * max(pp, 1e-12):
            violations.append(Violation(
                "wire-bytes", f"record:{record.get('arch', '?')}",
                f"re-billing the extracted hop gives {rebilled:.6g} "
                f"collective-permute B/chip vs the record's {pp:.6g} — "
                "the schedule/extraction math no longer round-trips"))
    violations += audit_byte_model(act_bytes=inp.act_bytes,
                                   d_model=inp.d_model or 0, **knobs)
    return violations, stats


# ---------------------------------------------------------------------------
# jaxpr-level audit (device-free; both lowerings via abstract mesh).
# ---------------------------------------------------------------------------

# pod-axis collectives that are NOT the pipeline hop: any of these inside
# the shard_map-over-pod region means a stage is secretly gathering or
# reducing across the stage boundary.
_LEAK_PRIMS = ("psum", "psum2", "all_gather", "all_to_all",
               "reduce_scatter", "pmax", "pmin", "allreduce")


def _sub_jaxprs(v):
    if hasattr(v, "eqns"):
        return [v]
    if hasattr(v, "jaxpr"):
        return [v.jaxpr]
    if isinstance(v, (list, tuple)):
        return [s for item in v for s in _sub_jaxprs(item)]
    return []


_LOOP_PRIMS = ("scan", "while", "while_loop")


def iter_jaxpr_eqns(jaxpr, in_loop: bool = False):
    """Yield ``(eqn, in_loop)`` for every eqn of a (Closed)Jaxpr
    recursively, sub-jaxprs included (scan/while bodies, shard_map
    regions, custom_vjp calls).  ``in_loop`` is True once the walk has
    descended through a scan/while — the jaxpr-level analogue of the HLO
    audit's while-reachable scoping: collectives at entry level (e.g.
    the shard_map transpose's replicated-param grad psum) are
    legitimate; the same collective inside the tick loop is a leak."""
    for sub in _sub_jaxprs(jaxpr):
        for eqn in sub.eqns:
            yield eqn, in_loop
            inner = in_loop or eqn.primitive.name in _LOOP_PRIMS
            for v in eqn.params.values():
                yield from iter_jaxpr_eqns(v, inner)


def _eqn_axes(eqn):
    ax = eqn.params.get("axes", eqn.params.get("axis_name", ()))
    if isinstance(ax, str):
        return (ax,)
    try:
        return tuple(ax)
    except TypeError:
        return (ax,)


def audit_jaxpr(closed_jaxpr, *, num_stages: int, virtual_stages: int,
                wire_dtype: str, d_model: int,
                act_dtype: str = "float32", axis: str = "pod"):
    """Audit one traced pipeline loss/grad jaxpr.  Returns
    ``(violations, stats)``."""
    fwd, bwd = expected_hop_perms(num_stages, virtual_stages)
    contract = hop_contract(wire_dtype, act_dtype, d_model)
    violations = []
    n_pp = 0
    n_eqn = 0
    dirs_seen = set()
    payload_dirs = set()
    idx_dirs = set()
    for eqn, in_loop in iter_jaxpr_eqns(closed_jaxpr):
        n_eqn += 1
        name = eqn.primitive.name
        if name == "ppermute":
            if axis not in _eqn_axes(eqn):
                continue
            n_pp += 1
            perm = tuple(tuple(p) for p in eqn.params["perm"])
            aval = eqn.invars[0].aval
            dt = NP_TO_HLO_DTYPE.get(str(aval.dtype), str(aval.dtype))
            where = f"jaxpr:ppermute#{n_pp}:{dt}{list(aval.shape)}"
            violations += check_perm_bijection(perm, num_stages, where)
            violations += check_perm_schedule(perm, num_stages,
                                              virtual_stages, where)
            # direction by pair set; for S=2 cyclic schedules fwd and its
            # transpose are the SAME set — such a hop satisfies both
            got = frozenset(perm)
            dirs = tuple(d for d, p in (("fwd", fwd), ("bwd", bwd))
                         if got == frozenset(p)) or ("?",)
            dirs_seen.update(dirs)
            pv = classify_hop_payload(contract, dt, aval.shape, where)
            violations += pv
            if not pv and dt == contract["payload_hlo"]:
                payload_dirs.update(dirs)
            if not pv and dt in ("s16", "s32"):
                idx_dirs.add("bwd")  # only the gradient hop ships indices
        elif any(name.startswith(p) for p in _LEAK_PRIMS):
            if in_loop and axis in _eqn_axes(eqn):
                violations.append(Violation(
                    "sharding-leak", f"jaxpr:{name}",
                    f"{name} over the {axis!r} axis inside the tick "
                    "loop — only the hop ppermute may cross the stage "
                    "boundary (entry-level replicated-grad reductions "
                    "are fine)"))
    # completeness: every direction of the schedule must actually hop,
    # and a coded hop must actually put coded payloads on the wire
    if num_stages > 1:
        for direction, perm in (("fwd", fwd), ("bwd", bwd)):
            if direction not in dirs_seen:
                violations.append(Violation(
                    "ppermute-schedule", f"jaxpr:{direction}",
                    f"no ppermute with the schedule's {direction} hop "
                    f"{sorted(frozenset(perm))} was traced — the "
                    f"{direction} hop is missing"))
        if contract["base"] != "none" and not contract["net_loss"]:
            for direction in ("fwd", "bwd"):
                if direction not in payload_dirs:
                    violations.append(Violation(
                        "wire-payload-dtype", f"jaxpr:{direction}",
                        f"declared codec {wire_dtype!r} but no "
                        f"{contract['payload_hlo']} payload rides the "
                        f"{direction} hop — the codec was compiled away"))
            if contract["frac"] is not None and "bwd" not in idx_dirs:
                violations.append(Violation(
                    "wire-index-dtype", "jaxpr:bwd",
                    f"declared top-k codec {wire_dtype!r} but no "
                    f"{contract['idx_hlo']} index payload rides the "
                    "backward hop"))
    stats = {"n_eqns": n_eqn, "n_ppermute": n_pp,
             "directions": sorted(dirs_seen)}
    return violations, stats


def audit_custom_vjp_pair(fwd_fn, bwd_fn, primal_avals, *,
                          where: str = "custom_vjp",
                          ef_dtype: str = "float32"):
    """Residual-dtype consistency of a custom_vjp (fwd, bwd) pair under
    abstract evaluation: residuals the fwd rule saves must come back from
    the bwd rule with the same shape/dtype (the EF buffer contract), and
    the cotangent returned for the primal must keep the primal's dtype
    (the straight-through wire transpose contract).

    ``fwd_fn(*primals) -> (out, res)``; ``bwd_fn(res, g) -> (gx, ...)``
    with ``g`` shaped like ``out``.  Returns a violation list.
    """
    import jax

    violations = []
    out, res = jax.eval_shape(fwd_fn, *primal_avals)
    grads = jax.eval_shape(bwd_fn, res, out)
    grads = tuple(grads) if isinstance(grads, (tuple, list)) else (grads,)
    x = primal_avals[0]
    gx = grads[0]
    if str(gx.dtype) != str(x.dtype) or tuple(gx.shape) != tuple(x.shape):
        violations.append(Violation(
            "vjp-residual-dtype", where,
            f"bwd returns cotangent {gx.dtype}{list(gx.shape)} for primal "
            f"{x.dtype}{list(x.shape)} — the straight-through transpose "
            "must keep the primal's aval"))
    if res is not None:
        res_leaves = jax.tree_util.tree_leaves(res)
        new_leaves = jax.tree_util.tree_leaves(grads[1:])
        for i, r in enumerate(res_leaves):
            if str(r.dtype) != ef_dtype:
                violations.append(Violation(
                    "vjp-residual-dtype", where,
                    f"fwd residual #{i} is {r.dtype} — the error-feedback "
                    f"state contract is {ef_dtype} (wire.coded_ppermute_ef)"))
        for i, (r, n) in enumerate(zip(res_leaves, new_leaves)):
            if str(n.dtype) != str(r.dtype) \
                    or tuple(n.shape) != tuple(r.shape):
                violations.append(Violation(
                    "vjp-residual-dtype", where,
                    f"bwd returns residual #{i} as {n.dtype}{list(n.shape)}"
                    f" but fwd saved {r.dtype}{list(r.shape)} — the EF "
                    "buffer would change aval across steps"))
    return violations


def audit_wire_custom_vjp(wire_dtype: str, *, d_model: int = 64,
                          act_dtype: str = "float32"):
    """Apply ``audit_custom_vjp_pair`` to the live wire codec's
    custom_vjp rules (identity permutation on a 1-wide abstract pod
    axis — dtype/shape flow only, no devices)."""
    import jax

    from repro.parallel import compat, wire
    from repro.parallel.compat import PartitionSpec as P

    base, frac = autotune._parse_wire(wire_dtype)
    if base == "none":
        return []
    mesh = compat.abstract_mesh((1,), ("pod",))
    perm = ((0, 0),)
    x = jax.ShapeDtypeStruct((2, 4, d_model), act_dtype)
    where = f"wire:{wire_dtype}"
    if frac is None:
        def fwd(xx):
            return wire._coded_fwd(wire_dtype, "pod", perm, xx)

        def bwd(res, g):
            return wire._coded_bwd(wire_dtype, "pod", perm, res, g)
        sm_fwd = compat.shard_map(fwd, mesh, in_specs=(P(),),
                                  out_specs=(P(), P()))

        def sm_bwd(res, g):
            return compat.shard_map(
                lambda gg: bwd(res, gg), mesh, in_specs=(P(),),
                out_specs=(P(),))(g)
        # dense codec: no residual state (res is None) — wrap so the
        # shard_map out_specs stay a plain pytree
        import jax as _jax
        out, _ = _jax.eval_shape(sm_fwd, x)
        grads = _jax.eval_shape(lambda g: sm_bwd(None, g), out)
        violations = []
        gx = grads[0]
        if str(gx.dtype) != str(x.dtype) \
                or tuple(gx.shape) != tuple(x.shape):
            violations.append(Violation(
                "vjp-residual-dtype", where,
                f"bwd cotangent {gx.dtype}{list(gx.shape)} != primal "
                f"{x.dtype}{list(x.shape)}"))
        return violations
    ef = jax.ShapeDtypeStruct((2, 4, d_model), "float32")

    def fwd(xx, ee):
        return wire._coded_ef_fwd(wire_dtype, "pod", perm, xx, ee)

    def bwd(res, g):
        return wire._coded_ef_bwd(wire_dtype, "pod", perm, res, g)
    sm_fwd = compat.shard_map(fwd, mesh, in_specs=(P(), P()),
                              out_specs=(P(), P()))

    def sm_bwd(res, g):
        return compat.shard_map(bwd, mesh, in_specs=(P(), P()),
                                out_specs=(P(), P()))(res, g)
    return audit_custom_vjp_pair(
        lambda xx, ee: sm_fwd(xx, ee),
        sm_bwd, (x, ef), where=where)


# ---------------------------------------------------------------------------
# HLO-level audit (compiled text; scoped to while-reachable computations).
# ---------------------------------------------------------------------------


def audit_hlo_text(text: str, *, pod_size: int, num_stages: int,
                   virtual_stages: int, wire_dtype: str, d_model: int,
                   act_dtype: str = "float32", hop_elems: int | None = None,
                   bytes_rtol: float = 0.01,
                   checks=("perm", "payload", "leak", "bytes")):
    """Audit one compiled module's text.  Returns ``(violations, stats)``.

    Scope: computations reachable through a ``while`` (the tick loops) —
    entry-level collectives (replicated-grad reductions, GSPMD input
    reshards) are legitimate and ignored.  ``pod_size`` is devices per
    pod (= total devices / num_stages on our pod-major meshes);
    ``hop_elems`` is the PER-DEVICE element count of one hop payload
    (micro-batch-shard x seq x d_model), enabling the byte-honesty
    reconciliation against ``autotune.wire_bytes_per_element(_bwd)``.
    """
    comps = hlo_costs.parse_hlo(text)
    in_loop = hlo_costs.while_reachable(comps)
    mult = hlo_costs.computation_multipliers(comps)
    contract = hop_contract(wire_dtype, act_dtype, d_model)
    fwd, bwd = expected_hop_perms(num_stages, virtual_stages)
    fwd_bwd = frozenset(fwd) | frozenset(bwd)
    ticks = autotune.schedule_ticks(1, num_stages, virtual_stages)  # dummy
    violations = []
    n_cp = 0
    n_local_cp = 0
    group_bytes: dict = {}     # comp -> [per-tick cross-pod hop bytes]
    group_kinds: dict = {}     # comp -> set of payload dtypes seen
    for name in in_loop:
        for ins in comps[name]:
            op = ins.opcode
            is_cp = op in ("collective-permute", "collective-permute-start")
            if not is_cp:
                for kind in hlo_costs.COLLECTIVES:
                    if kind == "collective-permute":
                        continue
                    if op in (kind, kind + "-start") and "leak" in checks \
                            and hlo_costs._crosses_pod(ins.rest, pod_size):
                        violations.append(Violation(
                            "sharding-leak", f"hlo:{name}:{ins.name}",
                            f"cross-pod {kind} {ins.rtype} inside the "
                            "tick loop — stage-internal collectives must "
                            "stay within the pod; only the hop ppermute "
                            "crosses the boundary"))
                continue
            pairs = hlo_costs.source_target_pairs(ins.rest)
            cross = [(s, t) for s, t in pairs
                     if s // pod_size != t // pod_size]
            if not cross:
                n_local_cp += 1    # within-pod reshard, not a hop
                continue
            n_cp += 1
            shape = hlo_costs.result_shape(ins.rtype)
            dt, dims = shape if shape else ("?", ())
            where = f"hlo:{name}:{ins.name}:{dt}{list(dims)}"
            if "perm" in checks:
                violations += check_perm_bijection(
                    pairs, pod_size * num_stages, where)
                lifted = set()
                bad_lift = False
                for s, t in cross:
                    if s % pod_size != t % pod_size:
                        bad_lift = True
                    lifted.add((s // pod_size, t // pod_size))
                if bad_lift:
                    violations.append(Violation(
                        "ppermute-schedule", where,
                        f"hop pairs {cross} do not preserve the in-pod "
                        "rank — the device permutation is not the pod "
                        "hop lifted over the pod"))
                elif not lifted <= fwd_bwd:
                    violations.append(Violation(
                        "ppermute-schedule", where,
                        f"pod-lifted pairs {sorted(lifted)} not within "
                        f"the schedule's hops {sorted(fwd_bwd)} "
                        f"(S={num_stages}, v={virtual_stages})"))
            if "payload" in checks:
                violations += classify_hop_payload(contract, dt, dims,
                                                   where)
            nb = _HLO_DTYPE_BYTES.get(dt, 0)
            for d_ in dims:
                nb *= d_
            group_bytes.setdefault(name, []).append(nb)
            group_kinds.setdefault(name, set()).add(dt)
    stats = {"n_hop_cp": n_cp, "n_local_cp": n_local_cp,
             "loop_comps_with_hops": sorted(group_bytes)}
    if "bytes" in checks and hop_elems and num_stages > 1:
        block = autotune.wire_block_for(d_model)
        w_f = autotune.wire_bytes_per_element(
            wire_dtype, contract["act_bytes"], block)
        w_b = autotune.wire_bytes_per_element_bwd(
            wire_dtype, contract["act_bytes"], block, d_model)
        obs = sum(sum(v) for v in group_bytes.values())
        want = hop_elems * (w_f + w_b)
        stats.update(hop_bytes_per_tick=obs,
                     billed_bytes_per_tick=want,
                     bytes_per_element=obs / hop_elems if hop_elems else 0,
                     billed_per_element=w_f + w_b)
        if abs(obs - want) > bytes_rtol * max(want, 1e-12):
            violations.append(Violation(
                "wire-bytes", "hlo:bytes",
                f"compiled hop ships {obs} B/tick/device but the planner "
                f"bills {want:.6g} (w_fwd={w_f:.4g} + w_bwd={w_b:.4g} "
                f"B/element x {hop_elems} elements) — billed bytes != "
                "compiled bytes"))
        del ticks
    return violations, stats


# ---------------------------------------------------------------------------
# Fixture cells: both lowerings x the re-planner's reachable cell set.
# ---------------------------------------------------------------------------

# the fixture cell (mirrors the tier-1 tiny config; float32 so the
# CPU-backend float-normalization upcast cannot blur byte accounting)
_CELL = dict(num_stages=2, microbatches=3, batch=6, seq=16,
             num_layers=4,
             mesh_shape=(2, 2, 2), axis_names=("pod", "data", "model"))

# The audit grid is no longer hand-picked: it is the ONLINE RE-PLANNER's
# reachable (wire, v) cell set for the fixture cell — every lowering a
# ``training.replan.Replanner`` over the default ``WIRE_AUTO``
# candidates can switch into mid-run must stay green here, or a plan
# switch could land on a cell the auditor never saw.  (k moves shapes,
# not the lowering grammar, so cells collapse over k; the fixture's
# ragged k=3 over batch=6 exercises padding.)
from repro.training.replan import reachable_cells as _reachable_cells

AUDIT_CELLS = tuple(_reachable_cells(num_stages=_CELL["num_stages"],
                                     num_layers=_CELL["num_layers"],
                                     v_cap=4))
AUDIT_WIRES = tuple(dict.fromkeys(w for w, _v in AUDIT_CELLS))
AUDIT_VS = tuple(sorted(dict.fromkeys(v for _w, v in AUDIT_CELLS)))


def _cell_model():
    from repro.models import LM, LMConfig
    cfg = LMConfig(name="audit", num_layers=_CELL["num_layers"],
                   d_model=64, n_heads=4,
                   n_kv=2, d_ff=128, vocab=256, dtype="float32")
    return LM(cfg)


def _cell_fns(wire: str, v: int, mesh):
    """(grad_fn, example_args, meta) for one audit cell on ``mesh``
    (abstract for jaxpr tracing, concrete for compilation)."""
    import jax

    from repro.data import lm_batch_for
    from repro.parallel.pipeline import (PipelineSpec, make_pipelined_loss,
                                         wire_ef_zeros)
    model = _cell_model()
    cfg = model.cfg
    spec = PipelineSpec(num_stages=_CELL["num_stages"],
                        microbatches=_CELL["microbatches"],
                        virtual_stages=v, wire_dtype=wire)
    params = model.init(jax.random.key(0))
    batch = lm_batch_for(cfg, _CELL["batch"], _CELL["seq"])
    loss = make_pipelined_loss(model, spec, mesh=mesh)
    n_data = _CELL["mesh_shape"][1]
    mb = _CELL["batch"] // _CELL["microbatches"]
    mb_local = mb // n_data if mb % n_data == 0 else mb
    meta = {
        "wire": spec.wire_dtype, "v": v,
        "num_stages": spec.num_stages, "k": spec.microbatches,
        "d_model": cfg.d_model, "act_dtype": cfg.dtype,
        "pod_size": (_CELL["mesh_shape"][1] * _CELL["mesh_shape"][2]),
        "hop_elems": mb_local * _CELL["seq"] * cfg.d_model,
    }
    if loss.needs_wire_ef:
        ef = wire_ef_zeros(cfg, spec, _CELL["batch"], _CELL["seq"])

        def fn(p, e):
            return loss(p, batch, e)[0]
        grad_fn = jax.value_and_grad(fn, argnums=(0, 1))
        return grad_fn, (params, ef), meta

    def fn(p):
        return loss(p, batch)[0]
    return jax.value_and_grad(fn), (params,), meta


def audit_cells(level: str = "jaxpr", wires=None, vs=None,
                bytes_rtol: float = 0.01, cells=None):
    """Run the auditor over the re-planner's reachable cell set.

    By default the grid is ``AUDIT_CELLS`` — the (wire, v) set a
    ``training.replan.Replanner`` can switch into on the fixture cell.
    ``wires``/``vs`` restrict to a sub-product (their cross product);
    ``cells`` pins an explicit ``[(wire, v), ...]`` list and wins over
    both.  ``level``:

      * ``'jaxpr'`` — abstract-mesh tracing, zero devices needed (works
        on both JAX generations; audits whichever shard_map lowering
        ``compat.CAPS`` selects on this interpreter);
      * ``'hlo'`` — compiles each cell (requires
        ``mesh_shape`` devices, e.g. XLA_FLAGS
        --xla_force_host_platform_device_count=8) and audits the
        optimized module text, including byte honesty.

    Returns ``(violations, cells)`` where ``cells`` is a list of per-cell
    stat dicts keyed leg-independently (``wire/v``).
    """
    import jax

    from repro.parallel import compat

    if cells is None:
        cells = [(w, v) for w in (AUDIT_WIRES if wires is None else wires)
                 for v in (AUDIT_VS if vs is None else vs)]
    else:
        cells = list(cells)
    violations = []
    out_cells = []
    lowering = "partial-manual" if compat.CAPS.partial_manual \
        else "full-manual"
    for wire, v in cells:
        key = f"{wire}/v{v}"
        if level == "jaxpr":
            mesh = compat.abstract_mesh(_CELL["mesh_shape"],
                                        _CELL["axis_names"])
            grad_fn, args, meta = _cell_fns(wire, v, mesh)
            jaxpr = jax.make_jaxpr(grad_fn)(*args)
            vio, stats = audit_jaxpr(
                jaxpr, num_stages=meta["num_stages"],
                virtual_stages=v, wire_dtype=meta["wire"],
                d_model=meta["d_model"], act_dtype=meta["act_dtype"])
        elif level == "hlo":
            ndev = 1
            for n in _CELL["mesh_shape"]:
                ndev *= n
            if len(jax.devices()) < ndev:
                raise RuntimeError(
                    f"HLO-level audit needs {ndev} devices (set "
                    "XLA_FLAGS=--xla_force_host_platform_device_count="
                    f"{ndev} before importing jax; the CLI does this)")
            mesh = compat.make_mesh(_CELL["mesh_shape"],
                                    _CELL["axis_names"])
            grad_fn, args, meta = _cell_fns(wire, v, mesh)
            text = jax.jit(grad_fn).lower(*args).compile().as_text()
            vio, stats = audit_hlo_text(
                text, pod_size=meta["pod_size"],
                num_stages=meta["num_stages"], virtual_stages=v,
                wire_dtype=meta["wire"], d_model=meta["d_model"],
                act_dtype=meta["act_dtype"],
                hop_elems=meta["hop_elems"], bytes_rtol=bytes_rtol)
        else:
            raise ValueError(f"unknown audit level {level!r}")
        vio = [dataclasses.replace(x, where=f"{key}:{x.where}")
               for x in vio]
        violations += vio
        out_cells.append({"cell": key, "level": level,
                          "lowering": lowering,
                          "violations": len(vio), "stats": stats})
    # the custom_vjp residual contract is cell-independent — audit once
    # per coded grammar
    for wire in dict.fromkeys(w for w, _v in cells):
        if autotune._parse_wire(wire)[0] != "none":
            vio = audit_wire_custom_vjp(wire)
            violations += vio
            out_cells.append({"cell": f"vjp:{wire}", "level": "jaxpr",
                              "lowering": lowering,
                              "violations": len(vio), "stats": {}})
    return violations, out_cells


# ---------------------------------------------------------------------------
# Report / diff / CLI.
# ---------------------------------------------------------------------------

ROOFLINE_FIXTURE = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "tests", "fixtures",
    "roofline_smoke.json")


def build_report(level: str = "jaxpr", lint_paths=None,
                 record_path: str | None = None) -> dict:
    """Run every layer the ``level`` admits and assemble the JSON
    violation report the CI job uploads.  Leg-independent fields only in
    the diffable core (``ok``/``by_class``/``cells`` keys): lowering and
    eqn counts live in per-cell stats, which ``diff_report`` ignores."""
    from repro.analysis import lint as lint_pack

    violations = []
    levels = ("jaxpr",) if level == "jaxpr" else ("jaxpr", "hlo")
    cells = []
    for lv in levels:
        vio, cl = audit_cells(level=lv)
        if lv != levels[0]:       # vjp cells repeat per level — keep one
            cl = [c for c in cl if not c["cell"].startswith("vjp:")]
            vio = [v for v in vio if not v.where.startswith("wire:")]
        violations += vio
        cells += cl
    rec_path = record_path or ROOFLINE_FIXTURE
    rec_stats: dict = {}
    if os.path.exists(rec_path):
        with open(rec_path) as f:
            record = json.load(f)
        vio, rec_stats = audit_record_honesty(record)
        violations += vio
    lint_violations = lint_pack.lint_paths(lint_paths or
                                           [_default_lint_root()])
    violations += [Violation("lint", f"{v.path}:{v.line}",
                             f"{v.rule}: {v.detail}")
                   for v in lint_violations]
    return {
        "schema": 1,
        "level": level,
        "ok": not violations,
        "by_class": by_class(violations),
        "cells": sorted(f"{c['level']}:{c['cell']}" for c in cells),
        "violations": [v.to_dict() for v in violations],
        "cell_stats": cells,
        "record_honesty": rec_stats,
    }


def _default_lint_root() -> str:
    return os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))


def diff_report(new: dict, baseline: dict):
    """Leg-independent comparison of a fresh report against the committed
    green baseline (``benchmarks/STATICCHECK_baseline.json``).  Returns a
    list of mismatch strings (empty = clean)."""
    fails = []
    if bool(new.get("ok")) != bool(baseline.get("ok")):
        fails.append(f"ok: {new.get('ok')} != baseline {baseline.get('ok')}")
    if dict(new.get("by_class", {})) != dict(baseline.get("by_class", {})):
        fails.append(f"by_class: {new.get('by_class')} != baseline "
                     f"{baseline.get('by_class')}")
    nc, bc = list(new.get("cells", [])), list(baseline.get("cells", []))
    if sorted(nc) != sorted(bc):
        fails.append(f"cells: {sorted(nc)} != baseline {sorted(bc)}")
    return fails


# ---------------------------------------------------------------------------
# Seeded-violation corpus: prove every detector fires (--selftest).
# ---------------------------------------------------------------------------

CORPUS_DIR = os.path.join(os.path.dirname(ROOFLINE_FIXTURE),
                          "staticcheck_corpus")


def selftest(corpus_dir: str | None = None) -> dict:
    """Run every detector against its seeded violation and assert it
    fires with the right class — the auditor auditing itself.  Returns
    ``{detector: fired_class}``; raises AssertionError on any silent
    detector."""
    from repro.analysis import lint as lint_pack

    corpus = corpus_dir or CORPUS_DIR
    fired: dict = {}

    def expect(name, violations, cls, n=1):
        got = [v for v in violations if v.cls == cls]
        assert len(got) == n and len(violations) == n, (
            f"selftest {name}: expected exactly {n} {cls!r} violation, "
            f"got {[(v.cls, v.detail) for v in violations]}")
        fired[name] = cls

    # 1. non-bijective permutation (duplicate destination)
    expect("perm-bijection",
           check_perm_bijection(((0, 1), (1, 1)), 2), "ppermute-bijection")
    # 2. bijective but off-schedule permutation
    expect("perm-schedule",
           check_perm_schedule(((0, 1), (1, 0)), 4, 1), "ppermute-schedule")
    # 3. forged f32 payload on a declared-int8 hop
    c = hop_contract("int8", "float32", 64)
    expect("payload-forged-f32",
           classify_hop_payload(c, "f32", (1, 16, 64)), "wire-payload-dtype")
    # 4. int32 indices where d_model declares int16
    ct = hop_contract("int8+topk0.25", "float32", 64)
    expect("index-dtype",
           classify_hop_payload(ct, "s32", (1, 16, 16)), "wire-index-dtype")
    # 5. planner byte-model constant perturbed by 1
    expect("byte-model-perturbed",
           check_byte_model("int8", "fwd", payload_bytes=2.0),
           "wire-bytes-model")
    # 6. broken custom_vjp pair (bwd residual dtype drifts to bf16)
    import jax

    def bad_fwd(x):
        return x, jax.ShapeDtypeStruct(x.shape, "float32")

    def bad_bwd(res, g):
        import jax.numpy as jnp
        return (g, jnp.zeros(res.shape, "bfloat16"))
    expect("vjp-residual",
           audit_custom_vjp_pair(
               bad_fwd, bad_bwd,
               (jax.ShapeDtypeStruct((2, 8), "float32"),)),
           "vjp-residual-dtype")
    # 7-9. seeded HLO corpus files, one defect each
    hlo_cases = {
        "hlo-forged-f32-hop": ("hlo_forged_f32_hop.txt",
                               "wire-payload-dtype", ("payload",), "int8"),
        "hlo-sharding-leak": ("hlo_sharding_leak.txt",
                              "sharding-leak", ("leak",), "none"),
        "hlo-nonbijective": ("hlo_nonbijective.txt",
                             "ppermute-bijection", ("perm",), "none"),
    }
    for name, (fname, cls, checks, wire) in hlo_cases.items():
        path = os.path.join(corpus, fname)
        with open(path) as f:
            text = f.read()
        vio, _ = audit_hlo_text(
            text, pod_size=4, num_stages=2, virtual_stages=1,
            wire_dtype=wire, d_model=64, checks=checks)
        expect(name, vio, cls)
    # 10. lint rule pack on the seeded-bad corpus module
    bad_py = os.path.join(corpus, "lint_bad.py")
    lv = lint_pack.lint_paths([bad_py])
    got_rules = sorted({v.rule for v in lv})
    assert got_rules == sorted(lint_pack.RULES), (
        f"selftest lint: rules fired {got_rules} != all rules "
        f"{sorted(lint_pack.RULES)}")
    fired["lint-rules"] = ",".join(got_rules)
    return fired


def main(argv=None):
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.staticcheck",
        description="Pipeline invariant auditor (docs/staticcheck.md)")
    ap.add_argument("--level", choices=("jaxpr", "full"), default="jaxpr",
                    help="'jaxpr' = device-free trace audit; 'full' adds "
                         "the compiled-HLO audit (forces host devices)")
    ap.add_argument("--lint", nargs="*", metavar="PATH",
                    help="run ONLY the AST lint pack over PATHs "
                         "(default: src/repro)")
    ap.add_argument("--selftest", action="store_true",
                    help="run the seeded-violation corpus; every detector "
                         "must fire with its class")
    ap.add_argument("--report", default=None,
                    help="write the JSON violation report here")
    ap.add_argument("--diff", default=None,
                    help="compare the report against this committed "
                         "baseline (benchmarks/STATICCHECK_baseline.json)")
    ap.add_argument("--record", default=None,
                    help="dry-run record for the planner-honesty check "
                         "(default: tests/fixtures/roofline_smoke.json)")
    args = ap.parse_args(argv)

    if args.selftest:
        fired = selftest()
        for name, cls in sorted(fired.items()):
            print(f"  {name:24s} -> {cls}")
        print(f"selftest OK: {len(fired)} detectors fired")
        return 0

    if args.lint is not None:
        from repro.analysis import lint as lint_pack
        paths = args.lint or [_default_lint_root()]
        violations = lint_pack.lint_paths(paths)
        for v in violations:
            print(f"{v.path}:{v.line}: {v.rule}: {v.detail}")
        print(f"{len(violations)} lint finding(s) in {paths}")
        return 1 if violations else 0

    if args.level == "full" and "jax" not in sys.modules:
        # the HLO audit compiles the 8-device fixture mesh on CPU; the
        # flag must be set before the first jax import
        ndev = 1
        for n in _CELL["mesh_shape"]:
            ndev *= n
        flags = os.environ.get("XLA_FLAGS", "")
        if "host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count={ndev}"
            ).strip()

    report = build_report(level=args.level, record_path=args.record)
    if args.report:
        with open(args.report, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
        print(f"wrote {args.report}")
    for v in report["violations"]:
        print(f"VIOLATION [{v['class']}] {v['where']}: {v['detail']}")
    print(f"staticcheck level={report['level']}: "
          f"{len(report['cells'])} cells, "
          f"{len(report['violations'])} violation(s)")
    rc = 0 if report["ok"] else 1
    if args.diff:
        with open(args.diff) as f:
            baseline = json.load(f)
        fails = diff_report(report, baseline)
        for fmsg in fails:
            print(f"DIFF vs {args.diff}: {fmsg}")
        if fails:
            rc = rc or 2
        else:
            print(f"diff vs {args.diff}: clean")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
