from repro.analysis.roofline import (RooflineTerms, collective_bytes_from_hlo,
                                     roofline_from_compiled, HW)
# The auto-planner (repro.analysis.autotune) is imported by module path, not
# re-exported here: it doubles as the `python -m repro.analysis.autotune`
# CLI, and a package-level import would shadow runpy's module execution.
