"""AST rule pack: repo-specific jax discipline ruff has no rules for.

The rules target the failure modes that actually bit (or nearly bit)
this codebase:

  * ``tracer-branch`` — Python ``if``/``while`` on a value derived from
    ``jnp``/``lax`` inside code reachable from the pipeline's
    ``_tick_loop``.  Under ``lax.scan`` those are tracers; branching on
    one raises ``ConcretizationTypeError`` at trace time — or worse,
    silently bakes in one branch when the value happens to be static on
    the first trace.
  * ``tracer-concretize`` — ``float()``/``int()``/``bool()`` or
    ``np.asarray``/``np.array`` applied to a tracer-derived value in the
    same reachable scope (host round-trip that cannot lower).
  * ``nested-jit`` — a ``jax.jit`` call inside ``_tick_loop``-reachable
    code: jit-under-scan retraces per tick and defeats the single
    compiled tick loop the schedule costs assume.
  * ``pallas-interpret`` — a ``pallas_call`` invocation without an
    ``interpret`` keyword.  Every kernel in ``repro.kernels`` must
    plumb ``interpret=interpret`` so CPU/CI runs take the interpreter
    path (the repo's off-TPU contract, see kernels/ops.py).

Reachability is a deliberately simple over-approximation: a cross-module
call graph on *simple function names* (``f(...)`` or ``mod.f(...)`` both
edge to every ``def f``), BFS'd from ``_tick_loop``; nested ``def``s of a
reachable function are scanned as part of its subtree.  Taint is equally
conservative the other way: only names ASSIGNED from a ``jnp``/``lax``
(or ``jax.numpy``/``jax.lax``/``jax.nn``/``jax.random``) expression are
tracers — function parameters are not, ``x.shape``/``.dtype``/``.ndim``
projections are not, and ``is None`` tests are exempt — so the pack runs
clean on the real tick loop (branching on ``spec`` fields, ``ef_t is not
None``, static shape arithmetic) while still catching the seeded corpus.

Stdlib-only on purpose: the lint must run before any jax exists.
"""
from __future__ import annotations

import ast
import dataclasses
import os

RULES = ("tracer-branch", "tracer-concretize", "nested-jit",
         "pallas-interpret")

#: call-graph root: everything transitively callable from the tick loop
#: runs under ``lax.scan`` tracing.
REACHABILITY_ROOT = "_tick_loop"

#: module roots whose call results are tracers
_TAINT_ROOTS = {"jnp", "lax"}
_JAX_TAINT_SUBMODULES = {"numpy", "lax", "nn", "random"}

#: jnp/lax attributes that return static python values, not tracers
_STATIC_FUNCS = {"shape", "ndim", "size", "result_type", "dtype",
                 "issubdtype", "iinfo", "finfo", "can_cast"}

#: attribute projections of a tracer that are static metadata
_STATIC_ATTRS = {"shape", "dtype", "ndim", "size", "aval", "itemsize",
                 "sharding"}

_CONCRETIZERS = {"float", "int", "bool"}
_NP_ROOTS = {"np", "numpy", "onp"}


@dataclasses.dataclass(frozen=True)
class LintViolation:
    path: str
    line: int
    rule: str       # one of RULES
    detail: str


def _attr_chain(node):
    """``jax.lax.scan`` -> ("jax", "lax", "scan"); None if not a pure
    Name/Attribute chain."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return None


def _is_taint_call(func) -> bool:
    chain = _attr_chain(func)
    if not chain:
        return False
    root = chain[0]
    if root in _TAINT_ROOTS or (
            root == "jax" and len(chain) > 2
            and chain[1] in _JAX_TAINT_SUBMODULES):
        return chain[-1] not in _STATIC_FUNCS
    return False


class _Taint:
    """Per-function tracer taint: names assigned from jnp/lax-derived
    expressions (parameters deliberately untainted)."""

    def __init__(self):
        self.names: set = set()

    def expr_tainted(self, node) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.names
        if isinstance(node, ast.Attribute):
            if node.attr in _STATIC_ATTRS:
                return False
            return self.expr_tainted(node.value)
        if isinstance(node, ast.Call):
            if _is_taint_call(node.func):
                return True
            # indexing-style helpers (x.at[...].set) keep taint
            return self.expr_tainted(node.func)
        if isinstance(node, ast.Subscript):
            return self.expr_tainted(node.value)
        if isinstance(node, ast.BinOp):
            return (self.expr_tainted(node.left)
                    or self.expr_tainted(node.right))
        if isinstance(node, ast.UnaryOp):
            return self.expr_tainted(node.operand)
        if isinstance(node, ast.Compare):
            return (self.expr_tainted(node.left)
                    or any(self.expr_tainted(c) for c in node.comparators))
        if isinstance(node, ast.BoolOp):
            return any(self.expr_tainted(v) for v in node.values)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.expr_tainted(e) for e in node.elts)
        if isinstance(node, ast.IfExp):
            return (self.expr_tainted(node.body)
                    or self.expr_tainted(node.orelse))
        return False

    def assign(self, targets, value):
        if not self.expr_tainted(value):
            return
        for t in targets:
            for n in ast.walk(t):
                if isinstance(n, ast.Name):
                    self.names.add(n.id)


def _is_exempt_test(node) -> bool:
    """``x is None`` / ``x is not None`` are static even on tracers."""
    return isinstance(node, ast.Compare) and all(
        isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops)


def _called_names(fn_node):
    """Simple names this function's subtree calls (call-graph edges) —
    including bare-name references passed as arguments (higher-order
    plumbing like ``run_stage``)."""
    out = set()
    for node in ast.walk(fn_node):
        if isinstance(node, ast.Call):
            chain = _attr_chain(node.func)
            if chain:
                out.add(chain[-1])
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                if isinstance(arg, ast.Name):
                    out.add(arg.id)
    return out


def _scan_reachable_fn(path, fn_node, violations):
    """Apply the tracer-discipline rules to one reachable function's
    subtree (nested defs included — they trace in the same scan)."""
    taint = _Taint()
    for node in ast.walk(fn_node):
        if isinstance(node, ast.Assign):
            taint.assign(node.targets, node.value)
        elif isinstance(node, ast.AugAssign):
            taint.assign([node.target], node.value)
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            taint.assign([node.target], node.value)
    for node in ast.walk(fn_node):
        if isinstance(node, (ast.If, ast.While)):
            test = node.test
            if not _is_exempt_test(test) and taint.expr_tainted(test):
                kw = "while" if isinstance(node, ast.While) else "if"
                violations.append(LintViolation(
                    path, node.lineno, "tracer-branch",
                    f"python `{kw}` on a jnp/lax-derived value inside "
                    f"{REACHABILITY_ROOT}-reachable `{fn_node.name}` — "
                    "use lax.cond/jnp.where"))
        elif isinstance(node, ast.Call):
            chain = _attr_chain(node.func)
            if not chain:
                continue
            if chain == ("jax", "jit") or chain[-1] == "jit" and \
                    chain[0] == "jax":
                violations.append(LintViolation(
                    path, node.lineno, "nested-jit",
                    f"jax.jit inside {REACHABILITY_ROOT}-reachable "
                    f"`{fn_node.name}` — jit-under-scan retraces every "
                    "tick; hoist it out of the tick loop"))
            elif (len(chain) == 1 and chain[0] in _CONCRETIZERS) or \
                    (len(chain) == 2 and chain[0] in _NP_ROOTS
                     and chain[1] in ("asarray", "array")):
                if any(taint.expr_tainted(a) for a in node.args):
                    violations.append(LintViolation(
                        path, node.lineno, "tracer-concretize",
                        f"{'.'.join(chain)}() on a jnp/lax-derived value "
                        f"inside {REACHABILITY_ROOT}-reachable "
                        f"`{fn_node.name}` — host concretization cannot "
                        "lower under scan"))


def _scan_pallas_calls(path, tree, violations):
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        chain = _attr_chain(node.func)
        if not chain or chain[-1] != "pallas_call":
            continue
        kws = {kw.arg for kw in node.keywords}
        if "interpret" not in kws and None not in kws:  # None = **kwargs
            violations.append(LintViolation(
                path, node.lineno, "pallas-interpret",
                "pallas_call without an `interpret` keyword — kernels "
                "must plumb interpret=interpret so off-TPU runs take "
                "the interpreter path (kernels/ops.py contract)"))


def _collect_py(paths):
    files = []
    for p in paths:
        if os.path.isfile(p):
            files.append(p)
        else:
            for root, _dirs, names in os.walk(p):
                if "__pycache__" in root:
                    continue
                files.extend(os.path.join(root, n) for n in sorted(names)
                             if n.endswith(".py"))
    return sorted(set(files))


def lint_sources(sources: dict):
    """Lint a ``{path: source_text}`` mapping as one corpus (reachability
    crosses file boundaries).  Returns a list of ``LintViolation``."""
    trees = {}
    violations = []
    for path, src in sorted(sources.items()):
        try:
            trees[path] = ast.parse(src, filename=path)
        except SyntaxError as e:
            violations.append(LintViolation(
                path, e.lineno or 0, "tracer-branch",
                f"unparseable: {e.msg}"))
    # def registry + call edges by simple name
    defs: dict = {}       # name -> [(path, fn_node)]
    for path, tree in trees.items():
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs.setdefault(node.name, []).append((path, node))
    reachable = []
    seen = set()
    frontier = [REACHABILITY_ROOT]
    while frontier:
        name = frontier.pop()
        if name in seen:
            continue
        seen.add(name)
        for path, fn in defs.get(name, ()):
            reachable.append((path, fn))
            frontier.extend(n for n in _called_names(fn) if n not in seen)
    scanned = set()
    for path, fn in reachable:
        key = (path, fn.lineno, fn.name)
        if key in scanned:
            continue
        scanned.add(key)
        _scan_reachable_fn(path, fn, violations)
    for path, tree in trees.items():
        _scan_pallas_calls(path, tree, violations)
    violations.sort(key=lambda v: (v.path, v.line, v.rule))
    return violations


def lint_source(src: str, path: str = "<string>"):
    """Lint one source string (tests/corpus convenience)."""
    return lint_sources({path: src})


def lint_paths(paths):
    """Lint files/directories as one corpus."""
    sources = {}
    for f in _collect_py(paths):
        with open(f, encoding="utf-8") as fh:
            sources[f] = fh.read()
    return lint_sources(sources)
