"""Repo-specific AST lint rules ruff cannot express (tracer discipline
in ``_tick_loop``-reachable code, pallas interpret plumbing).  See
``repro.analysis.lint.rules`` and docs/staticcheck.md."""
from repro.analysis.lint.rules import (  # noqa: F401
    RULES, LintViolation, lint_paths, lint_source)
