"""Roofline terms from a compiled dry-run artifact (no real hardware).

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / link_bw            (per-chip bytes)

``cost_analysis()`` on the compiled executable reports the per-device
(SPMD-partitioned) program, so FLOPs/bytes are per-chip; dividing by the
per-chip peaks gives the same seconds as the global form divided by
(chips x peak).  Collective bytes are parsed from the partitioned HLO text:
the result-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction (all-reduce counted twice — a
ring all-reduce moves 2N bytes per device).
"""
from __future__ import annotations

import dataclasses
import re

# TPU v5e hardware constants (per chip).
HW = {
    "peak_flops_bf16": 197e12,      # FLOP/s
    "hbm_bw": 819e9,                # B/s
    "ici_bw": 50e9,                 # B/s per link
    "dcn_bw": 3.1e9,                # B/s per chip across pods (hosts share
                                    # ~200 Gb/s NICs over 8 chips)
    "hbm_bytes": 16 * 1024 ** 3,
    "dcn_latency_s": 25e-6,         # per-message DCN overhead: the fixed
                                    # cost the auto-planner bills per
                                    # micro-batch ppermute hop across pods
                                    # (autotune.py hop_overhead_s default)
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

# e.g.  %all-gather.5 = bf16[2,16,4096]{2,1,0} all-gather(%p), ...
_COLLECTIVE_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    nb = _DTYPE_BYTES.get(dtype)
    if nb is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nb


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Per-collective-kind result bytes from (partitioned) HLO text."""
    out = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        tuple_body, dtype, dims, kind = m.group(1), m.group(2), m.group(3), \
            m.group(4)
        if tuple_body is not None:
            nb = sum(_shape_bytes(d, s)
                     for d, s in _SHAPE_RE.findall(tuple_body))
        else:
            nb = _shape_bytes(dtype, dims)
        out[kind] += nb
    return out


def weighted_collective_bytes(by_kind: dict) -> float:
    """Link bytes per chip: ring all-reduce moves ~2N; others ~N."""
    return (2.0 * by_kind["all-reduce"] + by_kind["all-gather"]
            + by_kind["reduce-scatter"] + by_kind["all-to-all"]
            + by_kind["collective-permute"])


@dataclasses.dataclass
class RooflineTerms:
    flops: float                 # per-chip HLO FLOPs
    hbm_bytes: float             # per-chip HLO bytes accessed
    coll_bytes: float            # per-chip link bytes (weighted)
    coll_by_kind: dict
    model_flops: float           # 6*N*D useful flops (global)
    chips: int
    coll_dcn_bytes: float = 0.0  # subset of coll_bytes crossing pods

    @property
    def t_compute(self) -> float:
        return self.flops / HW["peak_flops_bf16"]

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HW["hbm_bw"]

    @property
    def t_collective(self) -> float:
        """ICI term; inter-pod traffic is billed at DCN bandwidth."""
        ici = (self.coll_bytes - self.coll_dcn_bytes) / HW["ici_bw"]
        return max(ici, 0.0)

    @property
    def t_collective_dcn(self) -> float:
        return self.coll_dcn_bytes / HW["dcn_bw"]

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective,
                 "collective-dcn": self.t_collective_dcn}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Lower-bound step time if the terms fully overlap."""
        return max(self.t_compute, self.t_memory, self.t_collective,
                   self.t_collective_dcn)

    @property
    def useful_flops_frac(self) -> float:
        """MODEL_FLOPS / global HLO FLOPs (catches remat/redundancy)."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu_bound(self) -> float:
        """Model-FLOP utilisation at the roofline bound."""
        if self.t_bound <= 0:
            return 0.0
        return (self.model_flops
                / (self.chips * HW["peak_flops_bf16"] * self.t_bound))

    def to_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "coll_bytes_per_chip": self.coll_bytes,
            "coll_dcn_bytes_per_chip": self.coll_dcn_bytes,
            "coll_by_kind": self.coll_by_kind,
            "model_flops": self.model_flops,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "t_collective_dcn_s": self.t_collective_dcn,
            "bottleneck": self.bottleneck,
            "t_bound_s": self.t_bound,
            "useful_flops_frac": self.useful_flops_frac,
            "mfu_bound": self.mfu_bound,
        }


def roofline_from_compiled(compiled, *, chips: int, model_flops: float,
                           hlo_text: str | None = None) -> RooflineTerms:
    """Derive the three terms from a compiled (dry-run) executable.

    ``cost_analysis()`` counts while-loop bodies once (wrong by ~num_layers
    for a scanned transformer — verified in EXPERIMENTS.md), so the terms
    come from the trip-count-aware static analyzer over the partitioned HLO
    text (repro.analysis.hlo_costs); the flat cost_analysis numbers are
    retained in the record for reference.
    """
    from repro.analysis import hlo_costs
    text = hlo_text if hlo_text is not None else compiled.as_text()
    res = hlo_costs.analyze(text)
    return RooflineTerms(
        flops=res["flops"],
        hbm_bytes=res["bytes"],
        coll_bytes=res["coll_bytes"],
        coll_by_kind=res["coll_by_kind"],
        coll_dcn_bytes=res.get("coll_dcn_bytes", 0.0),
        model_flops=model_flops,
        chips=chips,
    )


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) per step.

    For decode shapes D = global_batch tokens (one token per sequence);
    for train/prefill D = global_batch x seq_len.  Inference (prefill,
    decode) has no backward pass: 2*N*D instead of 6*N*D.
    """
    n = cfg.param_count()
    if cfg.is_moe:
        # subtract inactive expert params: each MoE layer holds E experts,
        # only topk are active per token
        d, f = cfg.d_model, cfg.d_ff
        per_expert = 3 * d * f
        n_moe_layers = sum(1 for k in cfg.layer_kinds if k in ("attn", "local"))
        n = n - n_moe_layers * (cfg.moe_experts - cfg.moe_topk) * per_expert
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mult = 6.0
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mult = 2.0
    else:
        tokens = shape.global_batch
        mult = 2.0
    return mult * n * tokens
