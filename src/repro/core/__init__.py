"""Core contribution of the paper: C2P2SL scheduling + joint optimization."""
from repro.core.costs import LayerProfile, lm_profile, resnet18_profile
from repro.core.schedule import (Plan, TaskTimes, bubble_rate, simulate_c2p2sl,
                                 simulate_epsl, simulate_psl, simulate_sl,
                                 steady_state_ok, task_times)
from repro.core.ao import AOResult, algorithm1, lemma1_k
