"""Per-layer cost profiles: FLOPs (fwd/bwd) and cut-layer traffic.

The paper characterizes a model by, for each candidate cut point ``l``:
  * ``c_j^F`` / ``c_j^B`` — per-sample forward / backward FLOPs of layer j,
  * ``s_l``              — bytes of the cut layer's activation per sample.

``ResNet18Profile`` reproduces the paper's Table II exactly.  ``lm_profile``
derives an equivalent profile for any transformer-zoo config so the AO
optimizer and pipeline schedule apply to the assigned architectures too.
"""
from __future__ import annotations

import dataclasses
import numpy as np

BWD_FWD_RATIO = 2.0  # standard c^B ~= 2 c^F


@dataclasses.dataclass(frozen=True)
class LayerProfile:
    """Cost profile of one model expressed at its candidate cut points."""

    name: str
    layer_names: tuple            # len L
    fwd_flops: np.ndarray         # c_j^F  per sample, len L
    bwd_flops: np.ndarray         # c_j^B  per sample, len L
    act_bytes: np.ndarray         # s_l: activation bytes/sample AFTER layer j
    label_bytes: float = 4.0      # s_0

    @property
    def num_layers(self) -> int:
        return len(self.fwd_flops)

    def ue_fwd(self, l: int) -> float:
        """sum_{j<=l} c_j^F (per sample), cut AFTER layer index l (1-based)."""
        return float(self.fwd_flops[:l].sum())

    def ue_bwd(self, l: int) -> float:
        return float(self.bwd_flops[:l].sum())

    def bs_fwd(self, l: int) -> float:
        return float(self.fwd_flops[l:].sum())

    def bs_bwd(self, l: int) -> float:
        return float(self.bwd_flops[l:].sum())

    def cut_bytes(self, l: int) -> float:
        """s_l in bytes per sample for a cut after layer l."""
        return float(self.act_bytes[l - 1])

    def ue_total(self, l: int) -> float:
        """sum_{j<=l}(c^F + c^B): the LHS coefficient of storage bound C2."""
        return self.ue_fwd(l) + self.ue_bwd(l)


# --- Paper Table II: ResNet-18 adapted to 32x32 CIFAR-10 ------------------
# Layer        Params(M)  FLOPs(MFLOP)  Traffic(MB)
_RESNET18_TABLE = (
    ("conv1", 0.002, 3.802, 0.250),
    ("block1", 0.148, 303.0, 0.250),
    ("block2", 0.526, 269.1, 0.125),
    ("block3", 2.100, 268.8, 0.063),
    ("block4", 8.394, 268.6, 0.031),
    ("avgpool_fc", 0.005, 0.026, 3.81e-05),
)


def resnet18_profile() -> LayerProfile:
    names = tuple(r[0] for r in _RESNET18_TABLE)
    fwd = np.array([r[2] * 1e6 for r in _RESNET18_TABLE])
    traffic = np.array([r[3] * 2 ** 20 for r in _RESNET18_TABLE])
    return LayerProfile(
        name="resnet18_cifar10",
        layer_names=names,
        fwd_flops=fwd,
        bwd_flops=fwd * BWD_FWD_RATIO,
        act_bytes=traffic,
        label_bytes=4.0,
    )


def resnet18_params() -> np.ndarray:
    return np.array([r[1] * 1e6 for r in _RESNET18_TABLE])


def lm_profile(name: str, *, num_layers: int, d_model: int, d_ff: int,
               n_heads: int, n_kv: int, vocab: int, seq_len: int,
               moe_experts: int = 0, moe_topk: int = 0,
               act_dtype_bytes: int = 2) -> LayerProfile:
    """Derive a per-layer cost profile for a decoder LM at a given seq_len.

    "Per sample" here means per sequence.  Candidate cuts sit after the
    embedding and after each transformer block; the head is the last unit.
    """
    head_dim = d_model // max(n_heads, 1)
    # qkvo projections (GQA: kv projections scaled by n_kv/n_heads)
    qo = 2 * 2 * seq_len * d_model * d_model
    kv = 2 * 2 * seq_len * d_model * head_dim * max(n_kv, 1)
    attn_scores = 2 * 2 * seq_len * seq_len * d_model  # QK^T + PV
    if moe_experts and moe_topk:
        mlp = 2 * 3 * seq_len * d_model * d_ff * moe_topk  # gated MLP, top-k experts
    else:
        mlp = 2 * 3 * seq_len * d_model * d_ff
    block = qo + kv + attn_scores + mlp
    embed = 0.0  # gather, negligible FLOPs
    head = 2 * seq_len * d_model * vocab

    names = ("embed",) + tuple(f"block{i}" for i in range(num_layers)) + ("head",)
    fwd = np.array([embed] + [block] * num_layers + [head])
    act = np.full(len(names), seq_len * d_model * act_dtype_bytes, dtype=np.float64)
    act[-1] = 4.0  # after head+loss only a scalar-ish loss remains
    return LayerProfile(
        name=name,
        layer_names=names,
        fwd_flops=fwd,
        bwd_flops=fwd * BWD_FWD_RATIO,
        act_bytes=act,
        label_bytes=seq_len * 4.0,
    )
