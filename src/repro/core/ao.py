"""Alternating-optimization split & allocation (paper SIII, Algorithm 1).

P1 (19) is decomposed into:
  1. (l, k): enumerate cut layers, pick micro-batch count by Lemma 1;
  2. b:      MILP P3 -> LP relaxation (scipy/HiGHS) + floor/ceil rounding,
             the branch-and-bound shortcut justified by C5;
  3. tau:    convex epigraph problem P5 solved by SLSQP.

No cvxpy in this environment, so P3/P5 use scipy.optimize (the paper only
requires "available toolkits"; HiGHS is an LP/MILP solver of the same class).
"""
from __future__ import annotations

import dataclasses
import numpy as np
from scipy.optimize import linprog, minimize

from repro.core.costs import LayerProfile
from repro.core.schedule import (Plan, TaskTimes, bubble_rate, simulate_c2p2sl,
                                 task_times)
from repro.wireless.fleet import Fleet


@dataclasses.dataclass
class AOResult:
    plan: Plan
    bubble: float
    history: list            # BR per AO iteration
    times: TaskTimes


def _coeffs(profile: LayerProfile, fleet: Fleet, l: int, k: int,
            tau: np.ndarray):
    """Per-unit-batch time coefficients for fixed (l, k, tau)."""
    r_u, r_d = fleet.rates()
    T = fleet.channel.frame_s
    s_l = profile.cut_bytes(l) * 8.0
    s_0 = profile.label_bytes * 8.0
    with np.errstate(divide="ignore"):
        cF = profile.ue_fwd(l) / (k * fleet.ue_flops)          # t_i^F / b_i
        cB = profile.ue_bwd(l) / (k * fleet.ue_flops)
        cU = (s_l + s_0) * T / (k * r_u * tau)                 # t_i^U / b_i
        cD = s_l * T / (k * r_d * tau)                         # t_i^D / b_i
    cBS = (profile.bs_fwd(l) + profile.bs_bwd(l)) / (k * fleet.bs_flops)
    return cF, cB, cU, cD, cBS


def lemma1_k(profile: LayerProfile, fleet: Fleet, l: int, b: np.ndarray,
             tau: np.ndarray, k_cap: int | None = None,
             virtual_stages: int = 1) -> int:
    """Optimal micro-batch count for fixed (l, b, tau) — Lemma 1.

    The lemma's eta is, written with per-batch (k-independent) times,
        eta = max_i  W / (T_i^U + T_i^D)  =  W / min_i (T_i^U + T_i^D)
    with W = T_b^F + T_b^B, and k* = floor(1/(1-eta)).  eta -> 1 (balanced
    communication/computation) drives k up; eta >= 1 (compute-bound BS) makes
    C4 non-binding so k is capped only by the micro-batch granularity
    (b_i/k >= 1) / the external cap.

    ``virtual_stages = v > 1`` (interleaved chunks, see schedule.py): eta
    is v-free (chunk work and chunk comm both scale 1/v) but the pipeline
    already runs at slice granularity k*v, so the k needed to reach the
    steady state divides by v: k* = ceil(floor(1/(1-eta)) / v).  The
    sample-granularity cap min_i b_i does NOT divide — v slices the model
    depth, not the batch.
    """
    v = max(1, int(virtual_stages))
    t1 = task_times(profile, fleet, Plan(l=l, k=1, b=b, tau=tau))
    active = b > 0
    comm = (t1.uplink + t1.downlink)[active]
    W = t1.bs_work
    cap = int(np.min(b[active])) if active.any() else 1
    if k_cap is not None:
        cap = min(cap, k_cap)
    cap = max(cap, 1)
    if comm.size == 0 or W <= 0.0:
        return 1
    eta = W / float(np.min(comm))
    if eta >= 1.0:
        return cap
    k = -(-int(np.floor(1.0 / (1.0 - eta))) // v)
    return int(np.clip(k, 1, cap))


def pipeline_k_auto(stage_compute_s: float, link_s: float, k_cap: int,
                    virtual_stages: int = 1) -> int:
    """Lemma 1 transplanted to TPU pods (DESIGN.md §3-4).

    ``stage_compute_s`` plays t_b^F + t_b^B (per-stage compute per batch),
    ``link_s`` plays t^U + t^D (the cut-activation transfer per batch over
    the pod link — the DCN roofline term of the pipeline cell).  Both are
    batch-level times; per micro-batch each scales 1/k, so Lemma 1's
    eta = W / comm is k-free, exactly as in the wireless derivation.
    ``k_cap`` is the TPU granularity bound: global_batch / data-axis size
    (a micro-batch must still shard over the data axis — EXPERIMENTS.md
    §Perf, pipeline iteration 3).  ``virtual_stages = v > 1`` divides the
    steady-state k by v (the pipeline streams k*v interleaved slices) but
    never relaxes ``k_cap`` — v slices layers, not samples.
    """
    v = max(1, int(virtual_stages))
    if link_s <= 0.0:
        return max(1, k_cap)
    eta = stage_compute_s / link_s
    if eta >= 1.0:
        return max(1, k_cap)
    k = -(-int(np.floor(1.0 / (1.0 - eta))) // v)
    return int(np.clip(k, 1, max(k_cap, 1)))


def makespan_k(profile: LayerProfile, fleet: Fleet, l: int, b: np.ndarray,
               tau: np.ndarray, k_cap: int = 64, virtual_stages: int = 1):
    """Pick k by direct makespan minimization (robust fallback).

    Lemma 1 presumes the steady-state constraint C3 is satisfiable (BS compute
    per micro-batch >= every UE's uplink time).  In strongly comm-bound
    settings no k satisfies C3 and the lemma collapses to k=1, yet larger k
    still shrinks the makespan by overlapping the comm pipe with BS compute —
    exactly the paper's Fig 5 low-bandwidth regime.  We simply evaluate the
    event simulator over a small candidate set (at ``virtual_stages``
    interleave when v > 1).
    """
    from repro.core.schedule import simulate_c2p2sl
    active = b > 0
    cap = max(1, min(int(np.min(b[active])) if active.any() else 1, k_cap))
    cands = sorted({k for k in (1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64)
                    if k <= cap})
    best_k, best_ms = 1, np.inf
    for k in cands:
        t = task_times(profile, fleet, Plan(l=l, k=k, b=b, tau=tau))
        ms, _ = simulate_c2p2sl(t, k, virtual_stages=virtual_stages)
        if ms < best_ms - 1e-12:
            best_k, best_ms = k, ms
    return best_k, best_ms


def feasible_l(profile: LayerProfile, fleet: Fleet, b: np.ndarray):
    """Cut layers admissible under the storage bound C2 (13)."""
    out = []
    for l in range(1, profile.num_layers):
        if np.all(profile.ue_total(l) * b <= fleet.storage + 1e-9):
            out.append(l)
    return out or [1]


def solve_batch_p3(profile: LayerProfile, fleet: Fleet, l: int, k: int,
                   tau: np.ndarray, batch: int,
                   strict: bool = True) -> np.ndarray:
    """P3 (21): batch-size partition via LP relaxation + rounding.

    ``strict=False`` drops the steady-state rows (C3/C4~) which are jointly
    infeasible with C5 in strongly comm-bound settings; the objective
    (min t1+t2 = the pipeline warm-up/drain critical path) is unchanged.
    """
    n = fleet.n
    cF, cB, cU, cD, cBS = _coeffs(profile, fleet, l, k, tau)
    # A UE with no slot (tau_i = 0 after a zero-batch AO round) cannot
    # transmit: pin its batch to zero and keep the LP finite.
    dead = ~(np.isfinite(cU) & np.isfinite(cD))
    cU = np.where(dead, 0.0, cU)
    cD = np.where(dead, 0.0, cD)
    W = batch * cBS                     # t_b^F + t_b^B (depends on total b only)

    # Variables x = [b_1..b_n, t1, t2, t3, t4].
    nv = n + 4
    c = np.zeros(nv)
    c[n], c[n + 1] = 1.0, 1.0           # min t1 + t2
    # Tiny pressure on the comm-pipe epigraphs: in the comm-bound (soft)
    # regime the makespan is k*max_i t_i^U, which t1 alone under-weights.
    c[n + 2] = c[n + 3] = 1e-3 if strict else 1.0

    A_ub, b_ub = [], []

    def row(bi_coefs, t_idx=None, t_coef=0.0, rhs=0.0):
        r = np.zeros(nv)
        r[:n] = bi_coefs
        if t_idx is not None:
            r[n + t_idx] = t_coef
        A_ub.append(r)
        b_ub.append(rhs)

    for i in range(n):
        e = np.zeros(n)
        e[i] = 1.0
        row(e * profile.ue_total(l), rhs=fleet.storage[i])        # C2
        if strict:
            row(e * cF[i], rhs=W)                                 # C3 (compute)
            row(e * cU[i], rhs=W)                                 # C3 (uplink)
        row(e * (cF[i] + cU[i]), t_idx=0, t_coef=-1.0)            # C7
        row(e * (cD[i] + cB[i]), t_idx=1, t_coef=-1.0)            # C8
        row(e * cU[i], t_idx=2, t_coef=-1.0)                      # C9
        row(e * cD[i], t_idx=3, t_coef=-1.0)                      # C10
    if strict:
        # C4~: (k-1)(t3+t4) <= k W
        r = np.zeros(nv)
        r[n + 2] = r[n + 3] = (k - 1)
        A_ub.append(r)
        b_ub.append(k * W)

    A_eq = np.zeros((1, nv))
    A_eq[0, :n] = 1.0                                             # C5
    b_eq = np.array([float(batch)])

    bounds = [(0, 0) if dead[i] else (0, batch) for i in range(n)] \
        + [(0, None)] * 4
    res = linprog(c, A_ub=np.array(A_ub), b_ub=np.array(b_ub),
                  A_eq=A_eq, b_eq=b_eq, bounds=bounds, method="highs")
    if not res.success:
        if strict:
            return solve_batch_p3(profile, fleet, l, k, tau, batch,
                                  strict=False)
        return None
    b_star = res.x[:n]

    # Branch-and-bound shortcut: floor, then hand back the remainder one
    # sample at a time to the UE with the smallest marginal latency slope.
    b_int = np.floor(b_star).astype(int)
    slope = (cF + cU) + (cD + cB)
    slope = np.where(dead, np.inf, slope)     # never hand remainder to dead UEs
    order = np.argsort(slope)
    rem = batch - int(b_int.sum())
    j = 0
    while rem > 0:
        i = order[j % n]
        if profile.ue_total(l) * (b_int[i] + 1) <= fleet.storage[i]:
            b_int[i] += 1
            rem -= 1
        j += 1
        if j > 10 * n * batch:          # degenerate storage bounds
            b_int[order[0]] += rem
            break
    return b_int.astype(np.float64)


def solve_tau_p5(profile: LayerProfile, fleet: Fleet, l: int, k: int,
                 b: np.ndarray) -> np.ndarray:
    """P5 (23): slot allocation via the convex epigraph reformulation.

    tau_i enters every constraint as a lower bound g_i(t1..t4); the frame
    budget sum_i g_i <= T is a sum of maxima of convex terms, hence convex.
    After solving we distribute the leftover frame proportionally (more slot
    time never hurts).
    """
    n = fleet.n
    r_u, r_d = fleet.rates()
    T = fleet.channel.frame_s
    s_l = profile.cut_bytes(l) * 8.0
    s_0 = profile.label_bytes * 8.0
    a = b * (s_l + s_0) * T / (k * r_u)      # tau_i * t_i^U
    d = b * s_l * T / (k * r_d)              # tau_i * t_i^D
    tF = b * profile.ue_fwd(l) / (k * fleet.ue_flops)
    tB = b * profile.ue_bwd(l) / (k * fleet.ue_flops)
    W = b.sum() * (profile.bs_fwd(l) + profile.bs_bwd(l)) / (k * fleet.bs_flops)

    eps = 1e-9
    # C3~ (tau_i >= a_i / W) is jointly infeasible with the frame budget when
    # sum_i a_i/W > T (strongly comm-bound); drop it then, keeping the
    # objective's pressure toward small t1+t2.
    strict = float(np.sum(a / max(W, eps))) <= T

    def g(x):
        t1, t2, t3, t4 = x
        lb = np.maximum(a / np.maximum(t1 - tF, eps),
                        d / np.maximum(t2 - tB, eps))
        lb = np.maximum(lb, a / max(t3, eps))
        lb = np.maximum(lb, d / max(t4, eps))
        if strict:
            lb = np.maximum(lb, a / max(W, eps))                 # C3~
        return lb

    def frame_con(x):
        return T - float(np.sum(g(x)))

    def c4_con(x):
        if k <= 1 or not strict:
            return 1.0
        return k / (k - 1) * W - (x[2] + x[3])

    x0 = np.array([float(np.max(tF)) * 2 + 1e-3,
                   float(np.max(tB)) * 2 + 1e-3, W, W])
    # A feasible warm start: scale x0 up until the frame budget holds.
    for _ in range(60):
        if frame_con(x0) >= 0:
            break
        x0 = x0 * 1.5
    res = minimize(
        lambda x: x[0] + x[1], x0, method="SLSQP",
        constraints=[{"type": "ineq", "fun": frame_con},
                     {"type": "ineq", "fun": c4_con}],
        bounds=[(float(np.max(tF)) + 1e-6, None),
                (float(np.max(tB)) + 1e-6, None),
                (1e-6, None), (1e-6, None)],
        options={"maxiter": 200, "ftol": 1e-12})
    x = res.x if res.success else x0
    tau = g(x)
    slack = T - float(tau.sum())
    if slack > 0:
        w = (a + d)
        w = w / w.sum() if w.sum() > 0 else np.full(n, 1.0 / n)
        tau = tau + slack * w
    else:                                   # infeasible fit: scale into frame
        tau = tau * (T / float(tau.sum()))
    return tau


def algorithm1(profile: LayerProfile, fleet: Fleet, batch: int,
               eps: float = 1e-4, max_iters: int = 20,
               k_cap: int | None = 64,
               k_policy: str = "auto",
               v_cap: int = 1) -> AOResult:
    """Split-and-allocation AO (paper Algorithm 1).

    ``k_policy``:
      * ``"lemma1"``   — exactly the paper's Lemma 1;
      * ``"makespan"`` — argmin of the event simulator over k (robust);
      * ``"auto"``     — Lemma 1 when the steady-state regime is feasible
                         (eta < 1 gives k >= 2), makespan otherwise.

    ``v_cap`` > 1 extends subproblem 1 to the joint (l, k, v) trade:
    interleaved virtual-stage counts v in [1, v_cap] are enumerated
    alongside the cut layer, each with its own Lemma-1/makespan k, and
    the (l, k, v) triple minimizing the simulated makespan wins (the
    AC2P2SL-style adaptive-schedule direction; v_cap=1 is the paper's
    plain 1F1B).
    """
    n = fleet.n
    kc = k_cap or 64
    vc = max(1, int(v_cap))
    # Initialize: batch proportional to UE compute, uniform slots.
    w = fleet.ue_flops / fleet.ue_flops.sum()
    b = np.floor(w * batch)
    b[np.argmax(w)] += batch - b.sum()
    tau = np.full(n, fleet.channel.frame_s / n)

    def pick_k(cand_l, bb, tt, vv):
        k_lemma = lemma1_k(profile, fleet, cand_l, bb, tt, k_cap=kc,
                           virtual_stages=vv)
        if k_policy == "lemma1":
            return k_lemma
        if k_policy == "auto" and k_lemma > 1:
            return k_lemma
        k_ms, _ = makespan_k(profile, fleet, cand_l, bb, tt, k_cap=kc,
                             virtual_stages=vv)
        return k_ms

    l, k, v = 1, 1, 1
    history = []
    prev_br = np.inf
    for _ in range(max_iters):
        # --- subproblem 1: (l, k, v) — enumerate cuts x interleave ---
        best = (np.inf, np.inf, l, k, v)
        for cand_l in feasible_l(profile, fleet, b):
            for cand_v in range(1, vc + 1):
                cand_k = pick_k(cand_l, b, tau, cand_v)
                t = task_times(profile, fleet,
                               Plan(l=cand_l, k=cand_k, b=b, tau=tau))
                ms, _ = simulate_c2p2sl(t, cand_k, virtual_stages=cand_v)
                br = bubble_rate(t, cand_k, cand_v)
                if ms < best[0] - 1e-12:
                    best = (ms, br, cand_l, cand_k, cand_v)
        _, _, l, k, v = best
        # --- subproblem 2: b ---
        nb = solve_batch_p3(profile, fleet, l, k, tau, batch)
        if nb is not None:
            b = nb
        # --- subproblem 3: tau ---
        tau = solve_tau_p5(profile, fleet, l, k, b)
        # re-pick k after b/tau moved (v held from subproblem 1)
        k = pick_k(l, b, tau, v)

        t = task_times(profile, fleet, Plan(l=l, k=k, b=b, tau=tau))
        br = bubble_rate(t, k, v)
        history.append(br)
        if abs(prev_br - br) <= eps:
            break
        prev_br = br

    plan = Plan(l=l, k=k, b=b, tau=tau, v=v)
    t = task_times(profile, fleet, plan)
    return AOResult(plan=plan, bubble=bubble_rate(t, k, v),
                    history=history, times=t)
