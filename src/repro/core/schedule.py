"""Pipeline schedules and bubble-rate accounting (paper SII-C, SIII-A).

Two views are provided:

* ``TaskTimes`` — the closed-form per-micro-batch durations of eqs (7)-(12).
* ``simulate_*`` — event-driven makespan simulators for C2P2SL and the three
  baselines (SL, PSL, EPSL).  The simulators do NOT assume the steady-state
  constraints C3/C4 hold, so they remain valid for arbitrary (l, k, b, tau);
  when C3/C4 do hold, ``c2p2sl`` reproduces the paper's
  ``t_total = t_idle + t_work`` decomposition (asserted in tests).
"""
from __future__ import annotations

import dataclasses
import numpy as np

from repro.core.costs import LayerProfile
from repro.wireless.fleet import Fleet


@dataclasses.dataclass(frozen=True)
class Plan:
    """A full C2P2SL decision: cut layer, micro-batches, batch + slot split.

    ``v`` is the interleaved virtual-stage count (1 = the paper's plain
    1F1B): each side's model is sliced into v chunks whose tasks run at
    1/v the duration, shrinking the pipeline warm-up/drain (the bubble)
    by a factor of v at the same k (see parallel/pipeline.py).
    """

    l: int                 # cut layer (1-based, cut AFTER layer l)
    k: int                 # number of micro-batches
    b: np.ndarray          # per-UE batch sizes, sum == global batch
    tau: np.ndarray        # per-UE TDMA slot lengths, sum <= frame T
    v: int = 1             # interleaved virtual stages per side

    @property
    def batch(self) -> int:
        return int(self.b.sum())


@dataclasses.dataclass(frozen=True)
class TaskTimes:
    """Per-micro-batch task durations, eqs (7)-(12).  Arrays are per-UE."""

    ue_fwd: np.ndarray     # t_i^F  (7)
    uplink: np.ndarray     # t_i^U  (8)
    bs_fwd: float          # t_b^F  (9)
    bs_bwd: float          # t_b^B  (10)
    downlink: np.ndarray   # t_i^D  (11)
    ue_bwd: np.ndarray     # t_i^B  (12)

    @property
    def bs_work(self) -> float:
        return self.bs_fwd + self.bs_bwd


def task_times(profile: LayerProfile, fleet: Fleet, plan: Plan) -> TaskTimes:
    """Evaluate eqs (7)-(12) for one (l, k, b, tau) decision."""
    l, k = plan.l, plan.k
    b_i = plan.b.astype(np.float64)
    tau = plan.tau.astype(np.float64)
    T = fleet.channel.frame_s
    r_u, r_d = fleet.rates()
    f_i = fleet.ue_flops
    f_b = fleet.bs_flops

    s_l = profile.cut_bytes(l) * 8.0     # bits
    s_0 = profile.label_bytes * 8.0      # bits

    with np.errstate(divide="ignore"):
        ue_fwd = b_i * profile.ue_fwd(l) / (k * f_i)                      # (7)
        uplink = b_i * (s_l + s_0) * T / (k * r_u * tau)                  # (8)
        downlink = b_i * s_l * T / (k * r_d * tau)                        # (11)
        ue_bwd = b_i * profile.ue_bwd(l) / (k * f_i)                      # (12)
    bs_fwd = b_i.sum() * profile.bs_fwd(l) / (k * f_b)                    # (9)
    bs_bwd = b_i.sum() * profile.bs_bwd(l) / (k * f_b)                    # (10)
    # UEs with zero batch contribute no time.
    zero = b_i <= 0
    for arr in (ue_fwd, uplink, downlink, ue_bwd):
        arr[zero] = 0.0
    return TaskTimes(ue_fwd=ue_fwd, uplink=uplink, bs_fwd=float(bs_fwd),
                     bs_bwd=float(bs_bwd), downlink=downlink, ue_bwd=ue_bwd)


def bubble_rate(t: TaskTimes, k: int, virtual_stages: int = 1) -> float:
    """BR = t_idle / (t_idle + t_work), eqs (16)-(18), generalized to
    interleaved virtual stages.

    With v > 1 every per-micro-batch task is sliced into v sub-chunk
    tasks of 1/v the duration, so the warm-up/drain critical path — the
    idle term ``max_i(t_i^F + t_i^U) + max_i(t_i^D + t_i^B)`` — shrinks
    by a factor of v while the steady-state work ``k * (t_b^F + t_b^B)``
    is unchanged: the ``(S-1)``-per-direction bubble of plain 1F1B
    becomes ``(S-1)/v``.  Strictly decreasing in v whenever t_idle > 0.
    """
    v = int(virtual_stages)
    if v < 1:
        raise ValueError(f"virtual_stages={virtual_stages} must be >= 1")
    t_idle = float(np.max(t.ue_fwd + t.uplink)
                   + np.max(t.downlink + t.ue_bwd)) / v
    t_work = k * t.bs_work
    return t_idle / (t_idle + t_work)


def steady_state_ok(t: TaskTimes, k: int) -> bool:
    """Constraints C3 (14) and C4 (15)."""
    c3 = max(float(np.max(t.ue_fwd)), float(np.max(t.uplink))) <= t.bs_work + 1e-12
    c4 = (k - 1) * (float(np.max(t.uplink)) + float(np.max(t.downlink))) \
        <= k * t.bs_work + 1e-12
    return c3 and c4


# ---------------------------------------------------------------------------
# Event-driven simulators.  Each returns (makespan_seconds, timeline) where
# timeline is a list of (actor, task, mb_index, start, end) for plotting.
# ---------------------------------------------------------------------------

def simulate_c2p2sl(t: TaskTimes, k: int, collect_timeline: bool = False,
                    virtual_stages: int = 1):
    """Makespan of one batch under the C2P2SL workflow (paper Fig 2).

    ``virtual_stages = v > 1`` models interleaved scheduling: each side's
    model is sliced into v chunks, so every per-micro-batch task becomes
    v sub-tasks of 1/v the duration streaming through the same event
    logic — i.e. the makespan of k*v work items of duration t/v.  Total
    work is unchanged; the warm-up/drain shrinks ~v-fold.  Unlike simply
    raising k (bounded by the per-UE sample granularity b_i/k >= 1), v
    subdivides the model depth, so it remains available when k is capped.
    Per-message overheads of the extra chunk boundaries are not modeled
    (same idealization as eqs (7)-(12)).  Timeline entries then carry
    slice indices m in [0, k*v).

    Semantics implemented exactly as SII-C:
      * each UE is a single processor running FP(0..k-1) then BP in arrival
        order of downlink gradients;
      * BS runs 1F1B: F(m) then immediately B(m);
      * BS FP(m) needs every UE's UT(m);
      * UT has priority over DT on the shared band: DT(m) may only start
        after ALL micro-batches' UT completed (the paper's ordering rule);
      * UE BP(m) needs DT(m) and the UE's previous task to be done.
    """
    v = int(virtual_stages)
    if v < 1:
        raise ValueError(f"virtual_stages={virtual_stages} must be >= 1")
    if v > 1:
        t = TaskTimes(ue_fwd=t.ue_fwd / v, uplink=t.uplink / v,
                      bs_fwd=t.bs_fwd / v, bs_bwd=t.bs_bwd / v,
                      downlink=t.downlink / v, ue_bwd=t.ue_bwd / v)
        k = k * v
    n = len(t.ue_fwd)
    tl = [] if collect_timeline else None

    fp_done = np.zeros((n, k))
    ut_done = np.zeros((n, k))
    for i in range(n):
        busy = 0.0
        link = 0.0
        for m in range(k):
            busy += t.ue_fwd[i]
            fp_done[i, m] = busy
            link = max(link, busy) + t.uplink[i]
            ut_done[i, m] = link
            if tl is not None:
                tl.append((f"ue{i}", "FP", m, busy - t.ue_fwd[i], busy))
                tl.append((f"ue{i}", "UT", m, link - t.uplink[i], link))
    all_ut_done = float(ut_done[:, -1].max()) if k > 0 else 0.0

    # BS 1F1B.
    bs_free = 0.0
    bsb_done = np.zeros(k)
    for m in range(k):
        start_f = max(bs_free, float(ut_done[:, m].max()))
        end_f = start_f + t.bs_fwd
        end_b = end_f + t.bs_bwd
        bs_free = end_b
        bsb_done[m] = end_b
        if tl is not None:
            tl.append(("bs", "FP", m, start_f, end_f))
            tl.append(("bs", "BP", m, end_f, end_b))

    # Downlink (after the last UT per the priority rule) then UE BP.
    ue_free = fp_done[:, -1].copy()
    dt_free = np.full(n, all_ut_done)
    end_time = 0.0
    for m in range(k):
        for i in range(n):
            start_d = max(bsb_done[m], dt_free[i])
            end_d = start_d + t.downlink[i]
            dt_free[i] = end_d
            start_b = max(end_d, ue_free[i])
            end_b = start_b + t.ue_bwd[i]
            ue_free[i] = end_b
            end_time = max(end_time, end_b)
            if tl is not None:
                tl.append((f"ue{i}", "DT", m, start_d, end_d))
                tl.append((f"ue{i}", "BP", m, start_b, end_b))
    return (end_time, tl)


def simulate_psl(t1: TaskTimes):
    """PSL [7]: all UEs in parallel, whole batch at once (k == 1 TaskTimes)."""
    ut = t1.ue_fwd + t1.uplink
    bs_done = float(np.max(ut)) + t1.bs_fwd + t1.bs_bwd
    return bs_done + float(np.max(t1.downlink + t1.ue_bwd))


def simulate_sl(profile: LayerProfile, fleet: Fleet, plan: Plan):
    """Classical SL [4]: strictly sequential over UEs, full band per UE."""
    r_u, r_d = fleet.rates()
    f_i = fleet.ue_flops
    s_l = profile.cut_bytes(plan.l) * 8.0
    s_0 = profile.label_bytes * 8.0
    total = 0.0
    for i in range(fleet.n):
        b_i = float(plan.b[i])
        if b_i <= 0:
            continue
        total += b_i * profile.ue_fwd(plan.l) / f_i[i]
        total += b_i * (s_l + s_0) / r_u[i]          # full band: sole user
        total += b_i * (profile.bs_fwd(plan.l) + profile.bs_bwd(plan.l)) / fleet.bs_flops
        total += b_i * s_l / r_d[i]
        total += b_i * profile.ue_bwd(plan.l) / f_i[i]
    return total


def simulate_epsl(t1: TaskTimes, n: int, agg_ratio: float | None = None):
    """EPSL [8]: PSL + last-layer gradient aggregation.

    Aggregation shrinks the BS-side backward batch and the downlink
    activation-gradient volume by ``agg_ratio`` (default 1/n), trading
    a little accuracy (paper Fig 3) for time.
    """
    rho = 1.0 / n if agg_ratio is None else agg_ratio
    ut = t1.ue_fwd + t1.uplink
    bs_done = float(np.max(ut)) + t1.bs_fwd + rho * t1.bs_bwd
    return bs_done + float(np.max(rho * t1.downlink + t1.ue_bwd))
