"""Griffin/RecurrentGemma recurrent block: conv1d + RG-LRU (arXiv:2402.19427).

The RG-LRU is a diagonal linear recurrence with data-dependent decay:
    r_t = sigmoid(x_t W_a),  i_t = sigmoid(x_t W_x)
    log a_t = -c * softplus(Lambda) * r_t
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
Being linear in h it admits a log-depth ``associative_scan`` on TPU (the
Pallas kernel in repro/kernels/rglru.py is the fused production path; this
module is the reference / CPU path).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.common import dense_init

RG_LRU_C = 8.0


def init_recurrent(key, d_model: int, r_width: int, conv_width: int,
                   dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    # Lambda init so that a^c lands in [0.9, 0.999] (paper appendix).
    u = jax.random.uniform(ks[0], (r_width,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / RG_LRU_C))   # softplus^-1
    return {
        "in_x": dense_init(ks[1], d_model, r_width, dtype),
        "in_y": dense_init(ks[2], d_model, r_width, dtype),
        "conv_w": jax.random.normal(ks[3], (conv_width, r_width), dtype) * 0.1,
        "conv_b": jnp.zeros((r_width,), dtype),
        "gate_a": dense_init(ks[4], r_width, r_width, dtype),
        "gate_x": dense_init(ks[5], r_width, r_width, dtype),
        "lambda": lam.astype(dtype),
        "out": dense_init(jax.random.fold_in(key, 7), r_width, d_model, dtype),
    }


def causal_conv1d(x, w, b):
    """Depthwise causal conv.  x: [B, S, R], w: [W, R]."""
    width = w.shape[0]
    out = jnp.zeros_like(x)
    for j in range(width):
        shifted = jnp.pad(x, ((0, 0), (j, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * w[width - 1 - j][None, None, :]
    return out + b[None, None, :]


def rg_lru_scan(x_gated, log_a, h0=None):
    """Linear recurrence h_t = a_t h_{t-1} + sqrt(1-a_t^2) x_t via assoc scan.

    x_gated, log_a: [B, S, R].  Returns (h_all [B,S,R], h_last [B,R]).
    """
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * x_gated
    if h0 is not None:
        # fold initial state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, b), axis=1)
    return hh, hh[:, -1]


LRU_CHUNK = 256


def rg_lru_scan_chunked(x_gated, log_a, h0=None, chunk: int = LRU_CHUNK):
    """Chunked RG-LRU: lax.scan over S/C chunks, associative scan inside.

    Backward saves one [B, R] state per chunk (the chunk body is
    checkpointed); the log-depth intra-chunk scan is recomputed.  This is
    the memory-sane long-sequence path and the Pallas kernel's oracle.
    """
    bsz, s, r_w = x_gated.shape
    pad = (-s) % chunk
    if pad:
        x_gated = jnp.pad(x_gated, ((0, 0), (0, pad), (0, 0)))
        # log_a = 0 => a = 1, b = 0: padded steps keep the state unchanged
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
    n = (s + pad) // chunk
    xs = jnp.moveaxis(x_gated.reshape(bsz, n, chunk, r_w), 1, 0)
    ls = jnp.moveaxis(log_a.reshape(bsz, n, chunk, r_w), 1, 0)

    @jax.checkpoint
    def body(h_in, inp):
        xc, lc = inp
        hh, h_last = rg_lru_scan(xc, lc, h0=h_in)
        return h_last, hh

    from repro.models.common import match_vma
    h0 = jnp.zeros((bsz, r_w), x_gated.dtype) if h0 is None else h0
    h0 = match_vma(h0, x_gated)
    h_last, hs = jax.lax.scan(body, h0, (xs, ls))
    hh = jnp.moveaxis(hs, 0, 1).reshape(bsz, s + pad, r_w)
    return hh[:, :s], h_last


def apply_recurrent(p, x, dt=jnp.bfloat16, return_state: bool = False):
    """Full-sequence forward.  x: [B, S, D] -> [B, S, D].

    ``return_state=True`` additionally returns the decode state (final h +
    conv history) so a chunked prefill can hand off to decode_step.
    """
    w = lambda n: p[n].astype(dt)
    y = jax.nn.gelu(x @ w("in_y"))
    xr_raw = x @ w("in_x")
    xr = causal_conv1d(xr_raw, w("conv_w"), w("conv_b"))

    xf = xr.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["gate_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf @ p["gate_x"].astype(jnp.float32))
    log_a = -RG_LRU_C * jax.nn.softplus(p["lambda"].astype(jnp.float32)) * r
    scan = rg_lru_scan if x.shape[1] <= LRU_CHUNK else rg_lru_scan_chunked
    h, h_last = scan(i * xf, log_a)
    out = (h.astype(dt) * y) @ w("out")
    if not return_state:
        return out
    width = p["conv_w"].shape[0]
    tail = xr_raw[:, -(width - 1):]
    pad = (width - 1) - tail.shape[1]
    if pad > 0:                       # sequence shorter than the conv
        tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
    return out, {"h": h_last, "conv": tail.astype(jnp.float32)}


def init_recurrent_state(batch: int, r_width: int, conv_width: int,
                         dtype=jnp.float32):
    return {
        "h": jnp.zeros((batch, r_width), dtype),
        "conv": jnp.zeros((batch, conv_width - 1, r_width), dtype),
    }


def apply_recurrent_decode(p, x, state, dt=jnp.bfloat16):
    """Single-token decode.  x: [B, 1, D] -> ([B, 1, D], new_state)."""
    w = lambda n: p[n].astype(dt)
    y = jax.nn.gelu(x @ w("in_y"))
    xr = (x @ w("in_x"))[:, 0]                                # [B, R]
    hist = jnp.concatenate([state["conv"], xr[:, None]], axis=1)  # [B, W, R]
    cw = w("conv_w")
    xr = jnp.einsum("bwr,wr->br", hist, cw) + w("conv_b")
    new_conv = hist[:, 1:]

    xf = xr.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["gate_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf @ p["gate_x"].astype(jnp.float32))
    log_a = -RG_LRU_C * jax.nn.softplus(p["lambda"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    h = a * state["h"] + jnp.sqrt(jnp.maximum(1 - a * a, 1e-12)) * (i * xf)
    out = (h[:, None].astype(dt) * y) @ w("out")
    return out, {"h": h, "conv": new_conv}
