"""RWKV6 "Finch" block (arXiv:2404.05892): data-dependent decay WKV.

Time-mix recurrence per head (dh = head dim):
    o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
with decay w_t = exp(-exp(w0 + tanh(x W_A) W_B)) data-dependent (the Finch
novelty vs RWKV5).  Reference path uses lax.scan over time; the Pallas
kernel (repro/kernels/rwkv6.py) is the chunked production path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init


def init_rwkv_time(key, d_model: int, head_dim: int, lora: int,
                   dtype=jnp.float32):
    n_heads = d_model // head_dim
    ks = jax.random.split(key, 10)
    mu = lambda k: jax.random.uniform(k, (d_model,), dtype, 0.0, 1.0)
    return {
        "mu_r": mu(ks[0]), "mu_k": mu(ks[1]), "mu_v": mu(ks[2]),
        "mu_w": mu(ks[3]), "mu_g": mu(ks[4]),
        "w_r": dense_init(ks[5], d_model, d_model, dtype),
        "w_k": dense_init(ks[6], d_model, d_model, dtype),
        "w_v": dense_init(ks[7], d_model, d_model, dtype),
        "w_g": dense_init(ks[8], d_model, d_model, dtype),
        "w_o": dense_init(ks[9], d_model, d_model, dtype),
        "w0": jnp.full((d_model,), -6.0, dtype),          # slow decay init
        "w_a": dense_init(jax.random.fold_in(key, 11), d_model, lora, dtype),
        "w_b": dense_init(jax.random.fold_in(key, 12), lora, d_model, dtype),
        "u": jax.random.normal(jax.random.fold_in(key, 13),
                               (n_heads, head_dim), dtype) * 0.1,
        "ln_w": jnp.ones((d_model,), dtype),
        "ln_b": jnp.zeros((d_model,), dtype),
    }


def init_rwkv_channel(key, d_model: int, d_ff: int, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    mu = lambda k: jax.random.uniform(k, (d_model,), dtype, 0.0, 1.0)
    return {
        "mu_k": mu(ks[0]), "mu_r": mu(ks[1]),
        "w_k": dense_init(ks[2], d_model, d_ff, dtype),
        "w_v": dense_init(ks[3], d_ff, d_model, dtype),
        "w_r": dense_init(ks[4], d_model, d_model, dtype),
    }


def _token_shift(x, last):
    """shifted_t = x_{t-1}; position 0 uses ``last`` (carry across steps)."""
    return jnp.concatenate([last[:, None], x[:, :-1]], axis=1)


def _group_norm(x, w, b, n_heads, eps=64e-5):
    """Per-head layernorm of the WKV output, RWKV convention."""
    b_, s, d = x.shape
    xh = x.reshape(b_, s, n_heads, d // n_heads).astype(jnp.float32)
    mu = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + eps)
    return (xh.reshape(b_, s, d) * w + b).astype(x.dtype)


def wkv6_scan(r, k, v, w, u, s0=None):
    """Reference WKV6 (step-by-step).  r,k,v,w: [B, S, H, dh]; u: [H, dh].

    Returns (out [B,S,H,dh], final_state [B,H,dh,dh]).
    State S[i, j] accumulates k_i * v_j.  O(S) sequential steps; backward
    saves a state per step — use only for short sequences / as the oracle
    for the chunked path below.
    """
    b, s, h, dh = r.shape
    state = jnp.zeros((b, h, dh, dh), jnp.float32) if s0 is None else s0

    def step(carry, inp):
        rt, kt, vt, wt = inp                              # [B,H,dh] each
        kv = kt[..., :, None] * vt[..., None, :]          # [B,H,dh,dh]
        o = jnp.einsum("bhi,bhij->bhj", rt,
                       carry + u[None, :, :, None] * kv)
        new = wt[..., :, None] * carry + kv
        return new, o

    seq = lambda a: jnp.moveaxis(a.astype(jnp.float32), 1, 0)
    final, outs = jax.lax.scan(step, state,
                               (seq(r), seq(k), seq(v), seq(w)))
    return jnp.moveaxis(outs, 0, 1).astype(r.dtype), final


WKV_CHUNK = 64


def wkv6_chunked(r, k, v, w, u, s0=None, chunk: int = WKV_CHUNK):
    """Chunked WKV6 — the TPU-native formulation (and the Pallas kernel's
    oracle): O(S/C) sequential chunk steps, intra-chunk work as [C, C]
    matmuls that map onto the MXU.

    Per chunk with incoming state S and cumulative log-decay
    ``L_t = sum_{j<t} log w_j`` (L_0 = 0):

        o_t = (r_t e^{L_t})^T S_in                       (state term)
            + sum_{j<t} [r_t e^{L_t}] . [k_j e^{-L_{j+1}}] v_j   (intra)
            + (r_t . (u * k_t)) v_t                      (diagonal)
        S_out = diag(e^{L_C}) S_in + sum_j diag(e^{L_C - L_{j+1}}) k_j v_j^T

    The intra term's two exponential factors are stabilized by splitting
    around m = L_C / 2 (each factor's exponent then spans at most |L_C|/2).
    Backward memory: one state per chunk (jax.checkpoint on the chunk body).
    """
    from repro.models.common import match_vma
    b, s, h, dh = r.shape
    state = jnp.zeros((b, h, dh, dh), jnp.float32) if s0 is None else \
        s0.astype(jnp.float32)
    state = match_vma(state, r)
    pad = (-s) % chunk
    if pad:
        zp = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zp(r), zp(k), zp(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)           # w=1 => state unchanged
    n_chunks = (s + pad) // chunk

    def chunkify(a):
        return jnp.moveaxis(
            a.astype(jnp.float32).reshape(b, n_chunks, chunk, h, dh),
            1, 0)                                   # [N, B, C, H, dh]

    rs, ks, vs, ws = map(chunkify, (r, k, v, w))
    causal = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), -1)

    @jax.checkpoint
    def body(carry, inp):
        rc, kc, vc, wc = inp                        # [B, C, H, dh]
        logw = jnp.log(jnp.maximum(wc, 1e-30))
        lcum = jnp.cumsum(logw, axis=1)             # L_{t+1}
        l_t = lcum - logw                           # L_t  (exclusive)
        l_total = lcum[:, -1:]                      # L_C
        m = 0.5 * l_total
        r_t = rc * jnp.exp(l_t - m)                 # stabilized factors
        k_j = kc * jnp.exp(m - lcum)
        # intra-chunk attention-like matmul per head: [B,H,C,C]
        att = jnp.einsum("bthi,bjhi->bhtj", r_t, k_j) * causal[None, None]
        diag = jnp.einsum("bthi,bthi->bth", rc, u[None, None] * kc)
        o = jnp.einsum("bhtj,bjhi->bthi", att, vc) \
            + diag[..., None] * vc
        # state term
        o = o + jnp.einsum("bthi,bhij->bthj", rc * jnp.exp(l_t), carry)
        # state update
        k_hat = kc * jnp.exp(l_total - lcum)
        new = jnp.exp(l_total[:, 0, :, :, None]) * carry \
            + jnp.einsum("bjhi,bjhd->bhid", k_hat, vc)
        return new, o

    final, outs = jax.lax.scan(body, state, (rs, ks, vs, ws))
    outs = jnp.moveaxis(outs, 0, 1).reshape(b, s + pad, h, dh)
    return outs[:, :s].astype(r.dtype), final


def apply_rwkv_time(p, x, head_dim: int, *, shift_in=None, state_in=None,
                    dt=jnp.bfloat16):
    """Time-mix.  x: [B, S, D].  Returns (out, (last_x, state))."""
    b, s, d = x.shape
    h = d // head_dim
    last = jnp.zeros((b, d), x.dtype) if shift_in is None else shift_in
    sh = _token_shift(x, last)
    mix = lambda mu: x + (sh - x) * p[mu].astype(x.dtype)

    w_ = lambda n: p[n].astype(dt)
    r = (mix("mu_r") @ w_("w_r")).reshape(b, s, h, head_dim)
    k = (mix("mu_k") @ w_("w_k")).reshape(b, s, h, head_dim)
    v = (mix("mu_v") @ w_("w_v")).reshape(b, s, h, head_dim)
    g = jax.nn.silu(mix("mu_g") @ w_("w_g"))
    xw = mix("mu_w").astype(jnp.float32)
    decay = p["w0"].astype(jnp.float32) + \
        jnp.tanh(xw @ p["w_a"].astype(jnp.float32)) @ p["w_b"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(decay)).reshape(b, s, h, head_dim)

    wkv = wkv6_scan if s <= WKV_CHUNK else wkv6_chunked
    out, final = wkv(r, k, v, w, p["u"].astype(jnp.float32), s0=state_in)
    out = _group_norm(out.reshape(b, s, d), p["ln_w"].astype(jnp.float32),
                      p["ln_b"].astype(jnp.float32), h)
    out = (out * g) @ w_("w_o")
    return out, (x[:, -1], final)


def apply_rwkv_channel(p, x, *, shift_in=None, dt=jnp.bfloat16):
    b, s, d = x.shape
    last = jnp.zeros((b, d), x.dtype) if shift_in is None else shift_in
    sh = _token_shift(x, last)
    mix = lambda mu: x + (sh - x) * p[mu].astype(x.dtype)
    w_ = lambda n: p[n].astype(dt)
    k = jnp.square(jax.nn.relu(mix("mu_k") @ w_("w_k")))
    r = jax.nn.sigmoid(mix("mu_r") @ w_("w_r"))
    return r * (k @ w_("w_v")), x[:, -1]
