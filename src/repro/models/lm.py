"""Top-level language-model assembly: init / train forward / decode.

Handles every zoo family through ``LMConfig``:
  * dense / GQA / MoE decoders (scan-over-layers, rematerialized)
  * hybrid patterns (recurrentgemma: rglru+local attn, unrolled loop)
  * rwkv6 (attention-free)
  * whisper (enc-dec with cross attention, stub conv frontend)
  * paligemma (stub patch embeddings, prefix-LM masking)

The vocabulary cross-entropy is sequence-chunked and rematerialized so the
[B, S, V] logits tensor is never alive at once — required for 256k vocabs
at 4k sequence length.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.blocks import (apply_block, apply_block_decode,
                                 apply_block_prefill, init_block,
                                 init_block_state)
from repro.models.common import apply_norm, init_norm
from repro.models.config import LMConfig
from repro.parallel.context import constrain, get_ctx


def _sin_pos(seq: int, d: int, offset=0):
    pos = jnp.arange(seq) + offset
    half = d // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = pos[:, None] * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _softcap(logits, cap: float):
    if cap and cap > 0:
        return cap * jnp.tanh(logits / cap)
    return logits


def _find_period(kinds) -> tuple:
    """Smallest repeating prefix period covering >= 2 groups of layers."""
    n = len(kinds)
    for p in range(1, n // 2 + 1):
        g = n // p
        if all(kinds[i] == kinds[i % p] for i in range(g * p)):
            return p, g
    return n, 1


# params consumed in f32 inside the blocks (norms, gates, routers, decay
# LoRAs) — everything else is matmul weight, safe to pre-cast
_KEEP_F32 = {"ln1", "ln2", "lnx", "enc_norm", "final_norm", "gate_a",
             "gate_x", "lambda", "router", "w0", "w_a", "w_b", "u",
             "ln_w", "ln_b"}


def cast_gather_weights(tree, dt):
    """Pre-cast matmul weights to the compute dtype.

    The cast is elementwise, so it runs on the SHARDED resident weights;
    the per-layer FSDP all-gather then moves bf16 instead of f32 — half
    the collective bytes and half the gathered-weight HBM traffic.
    """
    def one(path, x):
        if x.dtype != jnp.float32 or x.ndim < 2:
            return x
        for p in path:
            if hasattr(p, "key") and str(p.key) in _KEEP_F32:
                return x
        return x.astype(dt)
    return jax.tree_util.tree_map_with_path(one, tree)


class LM:
    """Functional model wrapper; all methods are pure."""

    def __init__(self, cfg: LMConfig):
        self.cfg = cfg

    # ---------------- init ----------------

    def init(self, key):
        cfg = self.cfg
        kinds = cfg.layer_kinds
        k_embed, k_blocks, k_head, k_enc = jax.random.split(key, 4)
        params = {
            "embed": jax.random.normal(
                k_embed, (cfg.padded_vocab, cfg.d_model)) * 0.02,
            "final_norm": init_norm(cfg.d_model, cfg.norm),
        }
        if cfg.homogeneous:
            keys = jax.random.split(k_blocks, cfg.num_layers)
            params["blocks"] = jax.vmap(
                lambda k: init_block(k, cfg, kinds[0]))(keys)
        else:
            keys = jax.random.split(k_blocks, cfg.num_layers)
            params["blocks"] = tuple(
                init_block(keys[i], cfg, kinds[i])
                for i in range(cfg.num_layers))
        if not cfg.tie_embeddings:
            params["head"] = jax.random.normal(
                k_head, (cfg.d_model, cfg.padded_vocab)) * 0.02
        if cfg.enc_layers:
            ekeys = jax.random.split(k_enc, cfg.enc_layers)
            params["enc_blocks"] = jax.vmap(
                lambda k: init_block(k, cfg, "enc"))(ekeys)
            params["enc_norm"] = init_norm(cfg.d_model, cfg.norm)
        return params

    # ---------------- backbone ----------------

    def _embed(self, params, tokens, dt):
        cfg = self.cfg
        x = params["embed"].astype(dt)[tokens]
        if cfg.embed_scale:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), dt)
        return x

    def _run_blocks(self, params, x, *, positions, prefix_len=0, enc_out=None):
        cfg = self.cfg
        kinds = cfg.layer_kinds
        ctx = get_ctx()
        if ctx.cast_gathers:
            params = dict(params)
            params["blocks"] = cast_gather_weights(params["blocks"], x.dtype)
        aux_total = jnp.float32(0.0)

        def one_block(layer_params, h, kind):
            y, aux = apply_block(layer_params, h, cfg, kind,
                                 positions=positions,
                                 prefix_len=prefix_len, enc_out=enc_out,
                                 use_rope=(kind != "rwkv"))
            return constrain(y, ctx.hidden_spec), aux

        if cfg.homogeneous and not isinstance(params["blocks"], tuple):
            kind = kinds[0]

            @jax.checkpoint
            def body(carry, layer_params):
                return one_block(layer_params, carry, kind)

            x, auxes = jax.lax.scan(body, x, params["blocks"])
            aux_total = auxes.sum()
        else:
            # Heterogeneous pattern (recurrentgemma): scan over period-
            # stacked units instead of unrolling — an unrolled layer loop
            # makes XLA's buffer assignment hold every layer's rematted
            # temps concurrently (~5.7 GiB/layer; EXPERIMENTS.md §Perf).
            period, groups = _find_period(kinds)
            blocks = params["blocks"]
            if groups >= 2:
                stacked = tuple(
                    jax.tree.map(lambda *ls: jnp.stack(ls),
                                 *[blocks[g * period + j]
                                   for g in range(groups)])
                    for j in range(period))

                @jax.checkpoint
                def unit(carry, unit_params):
                    aux_u = jnp.float32(0.0)
                    for j in range(period):
                        carry, aux = one_block(unit_params[j], carry,
                                               kinds[j])
                        aux_u = aux_u + aux
                    return carry, aux_u

                x, auxes = jax.lax.scan(unit, x, stacked)
                aux_total = auxes.sum()
                start = groups * period
            else:
                start = 0
            for i in range(start, cfg.num_layers):
                x, aux = jax.checkpoint(
                    lambda p, h, k=kinds[i]: one_block(p, h, k))(blocks[i], x)
                aux_total = aux_total + aux
        return x, aux_total

    def _encode(self, params, frames):
        """Whisper encoder on stub frame embeddings [B, T, D]."""
        cfg = self.cfg
        dt = frames.dtype
        t = frames.shape[1]
        x = frames + _sin_pos(t, cfg.d_model).astype(dt)[None]
        positions = jnp.arange(t)

        def body(carry, layer_params):
            y, _ = apply_block(layer_params, carry, cfg, "enc",
                               positions=positions, use_rope=False)
            return y, None

        x, _ = jax.lax.scan(jax.checkpoint(body), x, params["enc_blocks"])
        return apply_norm(x, params["enc_norm"], cfg.norm)

    # ---------------- losses ----------------

    def _head_w(self, params, dt):
        if self.cfg.tie_embeddings:
            return params["embed"].astype(dt).T
        return params["head"].astype(dt)

    def xent(self, params, h, labels, chunk: int = 512):
        """Chunked softmax cross entropy.  h [B,S,D], labels [B,S] (-1 pad)."""
        cfg = self.cfg
        dt = h.dtype
        b, s, d = h.shape
        w = self._head_w(params, dt)
        nc = -(-s // chunk)
        pad = nc * chunk - s
        if pad:
            h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
            labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        hs = jnp.moveaxis(h.reshape(b, nc, chunk, d), 1, 0)
        ls = jnp.moveaxis(labels.reshape(b, nc, chunk), 1, 0)

        @jax.checkpoint
        def body(carry, inp):
            hc, lc = inp
            logits = _softcap(hc @ w, cfg.logit_softcap).astype(jnp.float32)
            if cfg.padded_vocab != cfg.vocab:
                neg = jnp.full((cfg.padded_vocab - cfg.vocab,), -1e30,
                               jnp.float32)
                logits = logits.at[..., cfg.vocab:].set(neg)
            lse = jax.scipy.special.logsumexp(logits, axis=-1)
            safe = jnp.maximum(lc, 0)
            ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
            mask = (lc >= 0).astype(jnp.float32)
            loss_sum, count = carry
            return (loss_sum + jnp.sum((lse - ll) * mask),
                    count + mask.sum()), None

        (loss_sum, count), _ = jax.lax.scan(
            body, (jnp.float32(0.0), jnp.float32(0.0)), (hs, ls))
        return loss_sum / jnp.maximum(count, 1.0)

    # ---------------- public API ----------------

    def forward(self, params, batch):
        """Training/prefill forward.  Returns (loss, metrics)."""
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        tokens = batch["tokens"]
        labels = batch.get("labels")
        ctx = get_ctx()
        prefix_len = 0
        enc_out = None

        x = self._embed(params, tokens, dt)
        if cfg.family == "vlm":
            patches = batch["patch_embeds"].astype(dt)
            x = jnp.concatenate([patches, x], axis=1)
            prefix_len = patches.shape[1]
            if labels is not None:
                pad = jnp.full(patches.shape[:2], -1, labels.dtype)
                labels = jnp.concatenate([pad, labels], axis=1)
        if cfg.family == "audio":
            enc_out = self._encode(params, batch["frames"].astype(dt))
        x = constrain(x, ctx.hidden_spec)

        positions = jnp.arange(x.shape[1])
        x, aux = self._run_blocks(params, x, positions=positions,
                                  prefix_len=prefix_len, enc_out=enc_out)
        x = apply_norm(x, params["final_norm"], cfg.norm)
        if labels is None:
            return x, {"aux": aux}
        loss = self.xent(params, x, labels)
        total = loss + 0.01 * aux
        return total, {"xent": loss, "aux": aux}

    def hidden(self, params, batch):
        """Final hidden states without loss (serving prefill)."""
        out, _ = self.forward(params, {**batch, "labels": None})
        return out

    def init_cache(self, batch: int, cache_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        kinds = cfg.layer_kinds
        if cfg.homogeneous:
            # stacked state for scan-decode
            one = init_block_state(cfg, kinds[0], batch, cache_len, dtype)
            return jax.tree.map(
                lambda a: jnp.broadcast_to(
                    a[None], (cfg.num_layers,) + a.shape), one)
        return tuple(init_block_state(cfg, k, batch, cache_len, dtype)
                     for k in kinds)

    def prefill_with_cache(self, params, batch, cache_len: int,
                           cache_dtype=jnp.bfloat16):
        """Chunked prefill: ONE full-sequence forward that also fills the
        decode cache (K/V buffers, ring buffers, recurrent states, cross
        K/V) — the production serving path, vs feeding the prompt through
        decode_step token by token.

        Returns (last-position logits [B, V], serve_state).
        """
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        kinds = cfg.layer_kinds
        tokens = batch["tokens"]
        prefix_len = 0
        enc_out = None

        x = self._embed(params, tokens, dt)
        if cfg.family == "vlm":
            patches = batch["patch_embeds"].astype(dt)
            x = jnp.concatenate([patches, x], axis=1)
            prefix_len = patches.shape[1]
        if cfg.family == "audio":
            enc_out = self._encode(params, batch["frames"].astype(dt))
        b, s = x.shape[0], x.shape[1]
        positions = jnp.arange(s)

        # dtype/shape template from the canonical cache
        template = jax.eval_shape(
            lambda: self.init_cache(b, cache_len, cache_dtype))

        if cfg.homogeneous and not isinstance(params["blocks"], tuple):
            kind = kinds[0]

            def body(carry, layer_params):
                y, aux, st = apply_block_prefill(
                    layer_params, carry, cfg, kind, positions=positions,
                    cache_len=cache_len, prefix_len=prefix_len,
                    enc_out=enc_out, use_rope=(kind != "rwkv"))
                return y, st

            x, states = jax.lax.scan(body, x, params["blocks"])
            cache = jax.tree.map(lambda st, t: st.astype(t.dtype),
                                 states, template)
        else:
            sts = []
            for i, kind in enumerate(kinds):
                x, aux, st = apply_block_prefill(
                    params["blocks"][i], x, cfg, kind, positions=positions,
                    cache_len=cache_len, prefix_len=prefix_len,
                    enc_out=enc_out, use_rope=(kind != "rwkv"))
                sts.append(jax.tree.map(
                    lambda a, t: a.astype(t.dtype), st, template[i]))
            cache = tuple(sts)

        x = apply_norm(x, params["final_norm"], cfg.norm)
        logits = _softcap(x[:, -1] @ self._head_w(params, dt),
                          cfg.logit_softcap)
        serve_state = {"cache": cache,
                       "position": jnp.asarray(s, jnp.int32)}
        return logits[:, :cfg.vocab].astype(jnp.float32), serve_state

    def fill_cross_kv(self, params, enc_out, cache):
        """Precompute cross-attention K/V from the encoder memory (once,
        at prefill) into the decode cache — per-token recompute of the
        1500-frame projections dominated whisper decode FLOPs."""
        cfg = self.cfg
        dt = enc_out.dtype
        b, se, _ = enc_out.shape
        hd = cfg.hd
        if cfg.homogeneous and not isinstance(params["blocks"], tuple):
            wk = params["blocks"]["cross"]["k"].astype(dt)   # [L, D, kv*hd]
            wv = params["blocks"]["cross"]["v"].astype(dt)
            ck = jnp.einsum("bed,ldk->lbek", enc_out, wk).reshape(
                cfg.num_layers, b, se, cfg.n_kv, hd)
            cv = jnp.einsum("bed,ldk->lbek", enc_out, wv).reshape(
                cfg.num_layers, b, se, cfg.n_kv, hd)
            cache = dict(cache)
            cache["ck"] = ck.astype(cache["ck"].dtype)
            cache["cv"] = cv.astype(cache["cv"].dtype)
            return cache
        new = []
        for i, st in enumerate(cache):
            p = params["blocks"][i]["cross"]
            st = dict(st)
            st["ck"] = (enc_out @ p["k"].astype(dt)).reshape(
                b, se, cfg.n_kv, hd).astype(st["ck"].dtype)
            st["cv"] = (enc_out @ p["v"].astype(dt)).reshape(
                b, se, cfg.n_kv, hd).astype(st["cv"].dtype)
            new.append(st)
        return tuple(new)

    def decode_step(self, params, tokens, cache, position, enc_out=None):
        """One serving step: tokens [B, 1] -> (logits [B, V], new cache).

        ``position`` is a scalar int (same position across the batch).
        """
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        kinds = cfg.layer_kinds
        x = self._embed(params, tokens, dt)

        if cfg.homogeneous and not isinstance(params["blocks"], tuple):
            kind = kinds[0]

            def body(carry, inp):
                layer_params, st = inp
                y, st_new = apply_block_decode(
                    layer_params, carry, st, cfg, kind, position=position,
                    enc_out=enc_out, use_rope=(kind != "rwkv"))
                return y, st_new

            x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
        else:
            new_states = []
            for i, kind in enumerate(kinds):
                x, st = apply_block_decode(
                    params["blocks"][i], x, cache[i], cfg, kind,
                    position=position, enc_out=enc_out,
                    use_rope=(kind not in ("rwkv",)))
                new_states.append(st)
            new_cache = tuple(new_states)

        x = apply_norm(x, params["final_norm"], cfg.norm)
        logits = _softcap(x[:, 0] @ self._head_w(params, dt),
                          cfg.logit_softcap)
        return logits[:, :cfg.vocab].astype(jnp.float32), new_cache
