"""Shared model building blocks (pure JAX, functional, pytree params)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(d_in)
    return jax.random.normal(key, (d_in, d_out), dtype) * scale


def match_vma(val, ref):
    """Give ``val`` (a freshly-created scan carry) the same varying-manual-
    axes as ``ref`` — required when model code runs inside a partial-manual
    shard_map (the C2P2SL pod pipeline), where zero-initialized carries are
    otherwise 'unvarying' and scan rejects the carry type mismatch.  The
    version handling lives in parallel/compat.py (no-op on legacy JAX)."""
    from repro.parallel.compat import match_vma as _match_vma
    return _match_vma(val, ref)


def rmsnorm(x, w, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def layernorm(x, w, b, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def apply_norm(x, p, kind: str):
    if kind == "rmsnorm":
        return rmsnorm(x, p["w"])
    return layernorm(x, p["w"], p["b"])


def init_norm(d: int, kind: str, dtype=jnp.float32):
    if kind == "rmsnorm":
        return {"w": jnp.zeros((d,), dtype)}
    return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def activation(x, kind: str):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu":
        return jax.nn.relu(x)
    if kind == "sqrelu":
        return jnp.square(jax.nn.relu(x))
    raise ValueError(kind)


# --- rotary embeddings ------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: [..., S, H, dh]; positions: [..., S] (broadcastable)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # [dh/2]
    ang = positions[..., None].astype(jnp.float32) * freqs   # [..., S, dh/2]
    cos = jnp.cos(ang)[..., None, :]                    # [..., S, 1, dh/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --- attention (chunked, flash-style online softmax over query blocks) ------

NEG_INF = -1e30


@jax.checkpoint
def _attend_block(q, k, v, mask):
    """q [B,hq,G,dh] (G=q block), k/v [B,hkv,S,dh], mask [G,S] bool.

    ``jax.checkpoint`` = flash-attention-style backward: the [G,S] logits /
    probabilities are recomputed in the backward pass instead of being saved
    per query chunk (which would reconstitute the full [Sq,Skv] matrix).
    """
    b, hq, g, dh = q.shape
    hkv = k.shape[1]
    rep = hq // hkv
    qg = q.reshape(b, hkv, rep, g, dh)
    logits = jnp.einsum("bkrgd,bksd->bkrgs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(dh)
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkrgs,bksd->bkrgd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, g, dh).astype(q.dtype)


def chunked_attention(q, k, v, *, q_positions, kv_positions, causal: bool,
                      window: int = 0, prefix_len: int = 0,
                      q_chunk: int = 512):
    """Memory-efficient attention.

    q: [B, Sq, Hq, dh]; k, v: [B, Skv, Hkv, dh].
    Never materializes [B, H, Sq, Skv]; peak scratch is [B, H, q_chunk, Skv].
    ``window`` > 0 restricts to a sliding causal window (local attention).
    ``prefix_len`` > 0 makes positions < prefix_len bidirectional (VLM
    prefix-LM masking).
    """
    b, sq, hq, dh = q.shape
    skv = k.shape[1]
    qt = jnp.swapaxes(q, 1, 2)          # [B,Hq,Sq,dh]
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)

    def mask_for(qpos):
        # qpos [G], kv_positions [Skv]
        qp = qpos[:, None]
        kp = kv_positions[None, :]
        m = jnp.ones((qpos.shape[0], skv), dtype=bool)
        if causal:
            cm = kp <= qp
            if prefix_len > 0:
                cm = cm | (kp < prefix_len)
            m = m & cm
        if window > 0:
            m = m & (kp > qp - window)
        return m

    if sq <= q_chunk:
        out = _attend_block(qt, kt, vt, mask_for(q_positions))
        return jnp.swapaxes(out, 1, 2)

    n_chunks = -(-sq // q_chunk)
    pad = n_chunks * q_chunk - sq
    qp = jnp.pad(qt, ((0, 0), (0, 0), (0, pad), (0, 0)))
    pos = jnp.pad(q_positions, (0, pad), constant_values=-1)
    qs = qp.reshape(b, hq, n_chunks, q_chunk, dh)
    poss = pos.reshape(n_chunks, q_chunk)

    def body(_, inp):
        qc, pc = inp
        return None, _attend_block(qc, kt, vt, mask_for(pc))

    _, outs = jax.lax.scan(body, None,
                           (jnp.moveaxis(qs, 2, 0), poss))
    out = jnp.moveaxis(outs, 0, 2).reshape(b, hq, n_chunks * q_chunk, dh)
    return jnp.swapaxes(out[:, :, :sq], 1, 2)


def decode_attention(q, k_cache, v_cache, *, position, window: int = 0):
    """Single-token decode: q [B,1,Hq,dh], caches [B,S,Hkv,dh].

    ``position`` is the index of the token being generated; cache entries at
    kv index >= position (or outside the local window) are masked.
    """
    b, _, hq, dh = q.shape
    s = k_cache.shape[1]
    kv_pos = jnp.arange(s)
    mask = kv_pos <= position
    if window > 0:
        mask = mask & (kv_pos > position - window)
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k_cache, 1, 2)
    vt = jnp.swapaxes(v_cache, 1, 2)
    out = _attend_block(qt, kt, vt, mask[None, :])
    return jnp.swapaxes(out, 1, 2)
