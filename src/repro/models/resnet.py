"""ResNet-18 adapted to 32x32 CIFAR-10 (paper SIV-A, Table II).

CIFAR adaptation (standard He et al. variant): 3x3 stem, no max-pool.
Norm is GroupNorm by default so that micro-batched gradient accumulation is
*exactly* equivalent to full-batch training — the property the paper asserts
for C2P2SL (SII-C last paragraph).  BatchNorm would break bit-equivalence
across micro-batch splits (batch statistics differ); see DESIGN.md.

The model exposes ``cut points`` matching Table II rows:
  0: conv1 | 1..4: block1..block4 | 5: avgpool+fc
so ``forward_until(l)`` / ``forward_from(l)`` implement the UE-side / BS-side
submodels for any cut layer l in {1..5}.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

CUT_NAMES = ("conv1", "block1", "block2", "block3", "block4", "avgpool_fc")


def _conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    return jax.random.normal(key, (kh, kw, cin, cout)) * jnp.sqrt(2.0 / fan_in)


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _gn(x, w, b, groups=8, eps=1e-5):
    n, h, wd, c = x.shape
    xg = x.reshape(n, h, wd, groups, c // groups).astype(jnp.float32)
    mu = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    return (xg.reshape(n, h, wd, c) * w + b).astype(x.dtype)


def _init_basic(key, cin, cout, stride):
    ks = jax.random.split(key, 3)
    p = {
        "c1": _conv_init(ks[0], 3, 3, cin, cout),
        "g1w": jnp.ones((cout,)), "g1b": jnp.zeros((cout,)),
        "c2": _conv_init(ks[1], 3, 3, cout, cout),
        "g2w": jnp.ones((cout,)), "g2b": jnp.zeros((cout,)),
    }
    if stride != 1 or cin != cout:
        p["proj"] = _conv_init(ks[2], 1, 1, cin, cout)
        p["gpw"] = jnp.ones((cout,))
        p["gpb"] = jnp.zeros((cout,))
    return p


def _apply_basic(p, x, stride):
    h = jax.nn.relu(_gn(_conv(x, p["c1"], stride), p["g1w"], p["g1b"]))
    h = _gn(_conv(h, p["c2"]), p["g2w"], p["g2b"])
    if "proj" in p:
        x = _gn(_conv(x, p["proj"], stride), p["gpw"], p["gpb"])
    return jax.nn.relu(x + h)


_STAGES = ((64, 1), (128, 2), (256, 2), (512, 2))


def init_resnet18(key, num_classes: int = 10):
    ks = jax.random.split(key, 11)
    params = {
        "conv1": _conv_init(ks[0], 3, 3, 3, 64),
        "g1w": jnp.ones((64,)), "g1b": jnp.zeros((64,)),
    }
    cin = 64
    ki = 1
    for si, (cout, stride) in enumerate(_STAGES):
        blocks = []
        for bi in range(2):
            blocks.append(_init_basic(ks[ki], cin, cout,
                                      stride if bi == 0 else 1))
            ki += 1
            cin = cout
        params[f"stage{si}"] = tuple(blocks)
    params["fc_w"] = jax.random.normal(ks[9], (512, num_classes)) * 0.02
    params["fc_b"] = jnp.zeros((num_classes,))
    return params


def forward_cut(params, x, start: int, stop: int):
    """Run cut units [start, stop).  Unit indices per CUT_NAMES."""
    if start <= 0 < stop:
        x = jax.nn.relu(_gn(_conv(x, params["conv1"]), params["g1w"],
                            params["g1b"]))
    for si, (_, stride) in enumerate(_STAGES):
        u = si + 1
        if start <= u < stop:
            for bi, bp in enumerate(params[f"stage{si}"]):
                x = _apply_basic(bp, x, stride if bi == 0 else 1)
    if start <= 5 < stop:
        x = x.mean(axis=(1, 2))
        x = x @ params["fc_w"] + params["fc_b"]
    return x


def forward(params, x):
    return forward_cut(params, x, 0, 6)


def loss_fn(params, batch):
    logits = forward(params, batch["images"])
    labels = batch["labels"]
    ll = jax.nn.log_softmax(logits.astype(jnp.float32))
    loss = -jnp.take_along_axis(ll, labels[:, None], axis=1).mean()
    acc = (logits.argmax(-1) == labels).mean()
    return loss, {"acc": acc}
