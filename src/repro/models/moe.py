"""Mixture-of-experts block: top-k routing with capacity-bounded dispatch.

The dispatch is computed per data shard inside a ``shard_map`` (expert
weights tensor-parallel along d_ff over the model axis), so:
  * any expert count works — no divisibility requirement between the number
    of experts and any mesh axis (granite's 40 experts vs a 16-wide axis);
  * no all-to-all is needed: tokens stay put, each device holds a d_ff slice
    of EVERY expert; the second projection psums over the model axis
    (row-parallel matmul);
  * capacity buffers are per-shard, keeping the scatter local.

Without an active mesh (unit tests) the same local function runs directly.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.models.common import activation, dense_init
from repro.parallel import compat
from repro.parallel.compat import PartitionSpec as P
from repro.parallel.context import get_ctx


def init_moe(key, d_model: int, d_ff: int, n_experts: int, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    ew = functools.partial(jax.random.normal, dtype=dtype)
    scale_in = 1.0 / math.sqrt(d_model)
    scale_out = 1.0 / math.sqrt(d_ff)
    return {
        "router": dense_init(ks[0], d_model, n_experts, dtype),
        "w1": ew(ks[1], (n_experts, d_model, d_ff)) * scale_in,
        "w3": ew(ks[2], (n_experts, d_model, d_ff)) * scale_in,
        "w2": ew(ks[3], (n_experts, d_ff, d_model)) * scale_out,
    }


def _capacity(n_tokens: int, topk: int, n_experts: int, factor: float) -> int:
    c = int(math.ceil(n_tokens * topk * factor / n_experts))
    return max(8, ((c + 7) // 8) * 8)


def router_aux(me, ce):
    """Switch-style load-balance aux from router statistics:
    ``E * sum_e f_e * p_e``.  ``me`` = mean router probability per expert,
    ``ce`` = fraction of top-k assignments per expert; both [E].  Exposed
    so shard-level callers can average the STATISTICS across shards
    (pmean) before forming the product — the psum'd global-statistics
    aux, which equals the full-batch aux exactly for equal shard sizes
    (the mean of per-shard ``me * ce`` products does not)."""
    e = me.shape[-1]
    return e * jnp.sum(me * ce)


def _moe_local(x, router, w1, w3, w2, *, topk: int, capacity: int, act: str,
               return_stats: bool = False):
    """Dispatch/combine on one shard.  x: [T, D] -> ([T, D], aux_loss)
    (plus the (me, ce) router statistics when ``return_stats``)."""
    t, d = x.shape
    e = router.shape[1]
    logits = (x.astype(jnp.float32) @ router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                     # [T, E]
    gate, idx = jax.lax.top_k(probs, topk)                      # [T, K]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # Load-balance aux loss (Switch-style): E * sum_e f_e * p_e.
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jax.nn.one_hot(idx, e, dtype=jnp.float32).sum(1), axis=0) / topk
    aux = router_aux(me, ce)

    eid = idx.reshape(-1)                                       # [T*K]
    onehot = jax.nn.one_hot(eid, e, dtype=jnp.int32)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - 1,
                              eid[:, None], axis=1)[:, 0]       # rank in expert
    keep = pos < capacity
    slot = jnp.where(keep, eid * capacity + pos, e * capacity)  # drop overflow

    x_rep = jnp.repeat(x, topk, axis=0)                         # [T*K, D]
    buf = jnp.zeros((e * capacity + 1, d), x.dtype).at[slot].set(x_rep)
    h = buf[:-1].reshape(e, capacity, d)

    a = activation(jnp.einsum("ecd,edf->ecf", h, w1.astype(x.dtype)), act)
    if w3 is not None:
        a = a * jnp.einsum("ecd,edf->ecf", h, w3.astype(x.dtype))
    y = jnp.einsum("ecf,efd->ecd", a, w2.astype(x.dtype))

    flat = jnp.concatenate(
        [y.reshape(e * capacity, d), jnp.zeros((1, d), y.dtype)], axis=0)
    picked = flat[slot] * (gate.reshape(-1, 1).astype(y.dtype)
                           * keep[:, None].astype(y.dtype))
    out = picked.reshape(t, topk, d).sum(axis=1)
    if return_stats:
        return out, aux, me, ce
    return out, aux


def apply_moe(params, x, *, topk: int, cap_factor: float, act: str,
              global_aux: bool = False):
    """x: [B, S, D] -> ([B, S, D], aux).  Shard-aware via the parallel ctx.

    ``global_aux`` switches the load-balance aux from the mean of
    per-shard auxes (the documented deviation) to the aux of the pmean'd
    GLOBAL router statistics — identical to the single-device full-batch
    aux when the token shards partition the batch evenly.  No effect
    without a mesh (the local aux already sees every token).
    """
    ctx = get_ctx()
    b, s, d = x.shape
    if ctx.mesh is None:
        cap = _capacity(b * s, topk, params["router"].shape[1], cap_factor)
        out, aux = _moe_local(x.reshape(-1, d), params["router"], params["w1"],
                              params["w3"], params["w2"],
                              topk=topk, capacity=cap, act=act)
        return out.reshape(b, s, d), aux

    batch_axes = ctx.batch_axes
    model_axes = ctx.model_axes
    n_data = ctx.axis_size(batch_axes)
    n_model = ctx.axis_size(model_axes)

    if ctx.seq_axes:
        # Token-sharded dispatch (sequence-parallel regime): tokens are
        # sharded over BOTH the batch axes (batch dim) and the model axes
        # (sequence dim); every device runs the dispatch for its own small
        # token slab against the full (gathered) expert weights.  Capacity
        # buffers shrink by n_model; the per-layer weight gather is a
        # transient.  No psum: each token's full d_model output is local.
        local_tokens = max(1, (b // max(n_data, 1))
                           * (s // max(n_model, 1)))
        cap = _capacity(local_tokens, topk, params["router"].shape[1],
                        cap_factor)

        def shard_fn(xs, router, w1, w3, w2):
            t_loc = xs.shape[0] * xs.shape[1]
            out, aux, me, ce = _moe_local(
                xs.reshape(t_loc, d), router, w1, w3, w2,
                topk=topk, capacity=cap, act=act, return_stats=True)
            if global_aux:
                me = jax.lax.pmean(me, batch_axes + model_axes)
                ce = jax.lax.pmean(ce, batch_axes + model_axes)
                aux = router_aux(me, ce)
            else:
                aux = jax.lax.pmean(aux, batch_axes + model_axes)
            return out.reshape(xs.shape), aux

        fn = compat.shard_map(
            shard_fn, ctx.mesh,
            in_specs=(P(batch_axes, ctx.seq_axes), P(None), P(None),
                      P(None), P(None)),
            out_specs=(P(batch_axes, ctx.seq_axes), P()),
            check=False)
        return fn(x, params["router"], params["w1"], params["w3"],
                  params["w2"])

    local_tokens = max(1, (b // max(n_data, 1)) * s)
    cap = _capacity(local_tokens, topk, params["router"].shape[1], cap_factor)

    def shard_fn(xs, router, w1, w3, w2):
        t_loc = xs.shape[0] * xs.shape[1]
        out, aux, me, ce = _moe_local(
            xs.reshape(t_loc, d), router, w1, w3, w2,
            topk=topk, capacity=cap, act=act, return_stats=True)
        # Second projection is row-parallel over the model axis (pure-DP
        # mode has no model axes: experts are whole per shard, no psum).
        if model_axes:
            out = jax.lax.psum(out, model_axes)
        if global_aux:
            # pmean the STATISTICS, then form the product: equals the
            # full-batch aux (model-axis shards see identical tokens, so
            # their pmean is an identity; data shards partition tokens)
            me = jax.lax.pmean(me, batch_axes + model_axes)
            ce = jax.lax.pmean(ce, batch_axes + model_axes)
            aux = router_aux(me, ce)
        else:
            aux = jax.lax.pmean(aux, batch_axes + model_axes)
        return out.reshape(xs.shape), aux

    w_spec = P(None, None, model_axes) if model_axes else P(None)
    w2_spec = P(None, model_axes, None) if model_axes else P(None)
    fn = compat.shard_map(
        shard_fn, ctx.mesh,
        in_specs=(P(batch_axes), P(None), w_spec, w_spec, w2_spec),
        out_specs=(P(batch_axes), P()),
        check=False)
    return fn(x, params["router"], params["w1"], params["w3"], params["w2"])
