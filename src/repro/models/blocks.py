"""Per-layer blocks: init/apply (full sequence) and decode (single token).

Block kinds:
  * "attn"  — global causal attention + MLP/MoE
  * "local" — sliding-window attention + MLP/MoE (recurrentgemma)
  * "xattn" — self-attn + cross-attn + MLP (whisper decoder)
  * "enc"   — bidirectional self-attn + MLP (whisper encoder)
  * "rglru" — Griffin recurrent block + MLP
  * "rwkv"  — RWKV6 time-mix + channel-mix
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import (activation, apply_norm, chunked_attention,
                                 decode_attention, dense_init, init_norm,
                                 apply_rope)
from repro.models.config import LMConfig
from repro.models.moe import apply_moe, init_moe
from repro.models.recurrent import (apply_recurrent, apply_recurrent_decode,
                                    init_recurrent, init_recurrent_state)
from repro.models.rwkv import (apply_rwkv_channel, apply_rwkv_time,
                               init_rwkv_channel, init_rwkv_time)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _init_attn_params(key, cfg: LMConfig, cross: bool = False):
    d, hd = cfg.d_model, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "q": dense_init(ks[0], d, cfg.n_heads * hd),
        "k": dense_init(ks[1], d, cfg.n_kv * hd),
        "v": dense_init(ks[2], d, cfg.n_kv * hd),
        "o": dense_init(ks[3], cfg.n_heads * hd, d),
    }
    if cfg.qkv_bias or cfg.mlp_bias:
        p["qb"] = jnp.zeros((cfg.n_heads * hd,))
        p["kb"] = jnp.zeros((cfg.n_kv * hd,))
        p["vb"] = jnp.zeros((cfg.n_kv * hd,))
    if cfg.mlp_bias:
        p["ob"] = jnp.zeros((d,))
    return p


def _init_mlp(key, cfg: LMConfig):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w1": dense_init(ks[0], d, f), "w2": dense_init(ks[1], f, d)}
    if cfg.mlp_gated:
        p["w3"] = dense_init(ks[2], d, f)
    if cfg.mlp_bias:
        p["b1"] = jnp.zeros((f,))
        p["b2"] = jnp.zeros((d,))
    return p


def init_block(key, cfg: LMConfig, kind: str):
    ks = jax.random.split(key, 6)
    if kind == "rwkv":
        return {
            "ln1": init_norm(cfg.d_model, cfg.norm),
            "time": init_rwkv_time(ks[0], cfg.d_model, cfg.rwkv_head_dim,
                                   cfg.rwkv_lora),
            "ln2": init_norm(cfg.d_model, cfg.norm),
            "channel": init_rwkv_channel(ks[1], cfg.d_model, cfg.d_ff),
        }
    p = {"ln1": init_norm(cfg.d_model, cfg.norm),
         "ln2": init_norm(cfg.d_model, cfg.norm)}
    if kind == "rglru":
        p["rec"] = init_recurrent(ks[0], cfg.d_model, cfg.r_width,
                                  cfg.conv_width)
    else:
        p["attn"] = _init_attn_params(ks[0], cfg)
    if kind == "xattn":
        p["lnx"] = init_norm(cfg.d_model, cfg.norm)
        p["cross"] = _init_attn_params(ks[1], cfg, cross=True)
    if cfg.is_moe and kind in ("attn", "local"):
        p["moe"] = init_moe(ks[2], cfg.d_model, cfg.d_ff, cfg.moe_experts)
    else:
        p["mlp"] = _init_mlp(ks[2], cfg)
    return p


# --------------------------------------------------------------------------
# apply (full sequence)
# --------------------------------------------------------------------------

def _proj_qkv(p, h, cfg: LMConfig, dt):
    b, s, _ = h.shape
    hd = cfg.hd
    q = h @ p["q"].astype(dt)
    k = h @ p["k"].astype(dt)
    v = h @ p["v"].astype(dt)
    if "qb" in p:
        q = q + p["qb"].astype(dt)
        k = k + p["kb"].astype(dt)
        v = v + p["vb"].astype(dt)
    return (q.reshape(b, s, cfg.n_heads, hd),
            k.reshape(b, s, cfg.n_kv, hd),
            v.reshape(b, s, cfg.n_kv, hd))


def _mlp(p, h, cfg: LMConfig, dt):
    a = h @ p["w1"].astype(dt)
    if "b1" in p:
        a = a + p["b1"].astype(dt)
    a = activation(a, cfg.act)
    if cfg.mlp_gated:
        a = a * (h @ p["w3"].astype(dt))
    out = a @ p["w2"].astype(dt)
    if "b2" in p:
        out = out + p["b2"].astype(dt)
    return out


def _ffn(p, x, cfg: LMConfig, dt):
    """Second half-block: norm + (MoE | MLP) with residual.  -> (x, aux)."""
    h = apply_norm(x, p["ln2"], cfg.norm)
    if "moe" in p:
        out, aux = apply_moe(p["moe"], h, topk=cfg.moe_topk,
                             cap_factor=cfg.moe_capacity, act=cfg.act,
                             global_aux=cfg.moe_global_aux)
        return x + out, aux
    return x + _mlp(p["mlp"], h, cfg, dt), jnp.float32(0.0)


def apply_block(p, x, cfg: LMConfig, kind: str, *, positions,
                prefix_len: int = 0, enc_out=None, use_rope: bool = True):
    """x: [B, S, D] -> ([B, S, D], aux_loss)."""
    dt = x.dtype
    if kind == "rwkv":
        h = apply_norm(x, p["ln1"], cfg.norm)
        t_out, _ = apply_rwkv_time(p["time"], h, cfg.rwkv_head_dim, dt=dt)
        x = x + t_out
        h = apply_norm(x, p["ln2"], cfg.norm)
        c_out, _ = apply_rwkv_channel(p["channel"], h, dt=dt)
        return x + c_out, jnp.float32(0.0)

    if kind == "rglru":
        h = apply_norm(x, p["ln1"], cfg.norm)
        x = x + apply_recurrent(p["rec"], h, dt=dt)
        return _ffn(p, x, cfg, dt)

    # attention kinds
    h = apply_norm(x, p["ln1"], cfg.norm)
    q, k, v = _proj_qkv(p["attn"], h, cfg, dt)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    causal = kind != "enc"
    window = cfg.window if kind == "local" else 0
    att = chunked_attention(q, k, v, q_positions=positions,
                            kv_positions=positions, causal=causal,
                            window=window, prefix_len=prefix_len)
    b, s, _, _ = att.shape
    att = att.reshape(b, s, cfg.n_heads * cfg.hd) @ p["attn"]["o"].astype(dt)
    if "ob" in p["attn"]:
        att = att + p["attn"]["ob"].astype(dt)
    x = x + att

    if kind == "xattn":
        assert enc_out is not None
        h = apply_norm(x, p["lnx"], cfg.norm)
        bq, sq, _ = h.shape
        se = enc_out.shape[1]
        hd = cfg.hd
        q = (h @ p["cross"]["q"].astype(dt)).reshape(bq, sq, cfg.n_heads, hd)
        ck = (enc_out @ p["cross"]["k"].astype(dt)).reshape(bq, se, cfg.n_kv, hd)
        cv = (enc_out @ p["cross"]["v"].astype(dt)).reshape(bq, se, cfg.n_kv, hd)
        att = chunked_attention(q, ck, cv,
                                q_positions=jnp.arange(sq),
                                kv_positions=jnp.arange(se), causal=False)
        x = x + att.reshape(bq, sq, cfg.n_heads * hd) @ p["cross"]["o"].astype(dt)

    return _ffn(p, x, cfg, dt)


# --------------------------------------------------------------------------
# prefill: full-sequence forward that also emits the decode state
# --------------------------------------------------------------------------

def _kv_into_cache(k, v, cache_len: int, window: int = 0):
    """Pack full-sequence K/V [B, S, kv, hd] into the decode cache layout.

    Global attention: zero-padded [B, cache_len, kv, hd].
    Local attention: the ring buffer holding the last ``window`` tokens at
    slots t % window (matching apply_block_decode's ring indexing).
    """
    b, s, n_kv, hd = k.shape
    if window > 0:
        w = min(window, cache_len)
        take = min(w, s)
        ts = jnp.arange(s - take, s)
        slots = ts % w
        kc = jnp.zeros((b, w, n_kv, hd), k.dtype).at[:, slots].set(
            k[:, s - take:])
        vc = jnp.zeros((b, w, n_kv, hd), v.dtype).at[:, slots].set(
            v[:, s - take:])
        return {"k": kc, "v": vc}
    pad = cache_len - s
    assert pad >= 0, (s, cache_len)
    zp = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return {"k": zp(k), "v": zp(v)}


def apply_block_prefill(p, x, cfg: LMConfig, kind: str, *, positions,
                        cache_len: int, prefix_len: int = 0, enc_out=None,
                        use_rope: bool = True):
    """Full-sequence forward returning (y, aux, decode_state)."""
    dt = x.dtype
    if kind == "rwkv":
        h = apply_norm(x, p["ln1"], cfg.norm)
        t_out, (lx, s_fin) = apply_rwkv_time(p["time"], h, cfg.rwkv_head_dim,
                                             dt=dt)
        x = x + t_out
        h2 = apply_norm(x, p["ln2"], cfg.norm)
        c_out, lc = apply_rwkv_channel(p["channel"], h2, dt=dt)
        y = x + c_out
        return y, jnp.float32(0.0), {"s": s_fin, "shift_t": lx,
                                     "shift_c": lc}

    if kind == "rglru":
        h = apply_norm(x, p["ln1"], cfg.norm)
        out, st = apply_recurrent(p["rec"], h, dt=dt, return_state=True)
        x = x + out
        y, aux = _ffn(p, x, cfg, dt)
        return y, aux, st

    # attention kinds
    h = apply_norm(x, p["ln1"], cfg.norm)
    q, k, v = _proj_qkv(p["attn"], h, cfg, dt)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    window = cfg.window if kind == "local" else 0
    att = chunked_attention(q, k, v, q_positions=positions,
                            kv_positions=positions, causal=(kind != "enc"),
                            window=window, prefix_len=prefix_len)
    state = _kv_into_cache(k, v, cache_len, window=window)
    b, s, _, _ = att.shape
    att = att.reshape(b, s, cfg.n_heads * cfg.hd) @ p["attn"]["o"].astype(dt)
    if "ob" in p["attn"]:
        att = att + p["attn"]["ob"].astype(dt)
    x = x + att

    if kind == "xattn":
        assert enc_out is not None
        h = apply_norm(x, p["lnx"], cfg.norm)
        bq, sq, _ = h.shape
        se = enc_out.shape[1]
        hd = cfg.hd
        q = (h @ p["cross"]["q"].astype(dt)).reshape(bq, sq, cfg.n_heads, hd)
        ck = (enc_out @ p["cross"]["k"].astype(dt)).reshape(bq, se,
                                                            cfg.n_kv, hd)
        cv = (enc_out @ p["cross"]["v"].astype(dt)).reshape(bq, se,
                                                            cfg.n_kv, hd)
        att = chunked_attention(q, ck, cv, q_positions=jnp.arange(sq),
                                kv_positions=jnp.arange(se), causal=False)
        x = x + att.reshape(bq, sq, cfg.n_heads * hd) \
            @ p["cross"]["o"].astype(dt)
        state["ck"] = ck
        state["cv"] = cv

    y, aux = _ffn(p, x, cfg, dt)
    return y, aux, state


# --------------------------------------------------------------------------
# decode (single token with state)
# --------------------------------------------------------------------------

def init_block_state(cfg: LMConfig, kind: str, batch: int, cache_len: int,
                     dtype=jnp.bfloat16):
    hd = cfg.hd
    if kind == "xattn":
        # cross-attention K/V are computed ONCE from the encoder memory at
        # prefill (LM.fill_cross_kv) — recomputing the 1500-frame
        # projections per decoded token dominated decode FLOPs
        # (EXPERIMENTS.md §Perf, whisper decode useful-flops 0.010).
        return {"k": jnp.zeros((batch, cache_len, cfg.n_kv, hd), dtype),
                "v": jnp.zeros((batch, cache_len, cfg.n_kv, hd), dtype),
                "ck": jnp.zeros((batch, cfg.enc_seq, cfg.n_kv, hd), dtype),
                "cv": jnp.zeros((batch, cfg.enc_seq, cfg.n_kv, hd), dtype)}
    if kind in ("attn", "enc"):
        return {"k": jnp.zeros((batch, cache_len, cfg.n_kv, hd), dtype),
                "v": jnp.zeros((batch, cache_len, cfg.n_kv, hd), dtype)}
    if kind == "local":
        w = min(cfg.window, cache_len) or cache_len
        return {"k": jnp.zeros((batch, w, cfg.n_kv, hd), dtype),
                "v": jnp.zeros((batch, w, cfg.n_kv, hd), dtype)}
    if kind == "rglru":
        return init_recurrent_state(batch, cfg.r_width, cfg.conv_width)
    if kind == "rwkv":
        h = cfg.d_model // cfg.rwkv_head_dim
        return {"s": jnp.zeros((batch, h, cfg.rwkv_head_dim,
                                cfg.rwkv_head_dim), jnp.float32),
                "shift_t": jnp.zeros((batch, cfg.d_model), dtype),
                "shift_c": jnp.zeros((batch, cfg.d_model), dtype)}
    raise ValueError(kind)


def apply_block_decode(p, x, state, cfg: LMConfig, kind: str, *, position,
                       enc_out=None, use_rope: bool = True):
    """x: [B, 1, D], state per kind -> ([B, 1, D], new_state)."""
    dt = x.dtype
    if kind == "rwkv":
        h = apply_norm(x, p["ln1"], cfg.norm)
        t_out, (lx, s_new) = apply_rwkv_time(
            p["time"], h, cfg.rwkv_head_dim,
            shift_in=state["shift_t"], state_in=state["s"], dt=dt)
        x = x + t_out
        h = apply_norm(x, p["ln2"], cfg.norm)
        c_out, lc = apply_rwkv_channel(p["channel"], h,
                                       shift_in=state["shift_c"], dt=dt)
        return x + c_out, {"s": s_new, "shift_t": lx, "shift_c": lc}

    if kind == "rglru":
        h = apply_norm(x, p["ln1"], cfg.norm)
        out, s_new = apply_recurrent_decode(p["rec"], h, state, dt=dt)
        x = x + out
        x, _ = _ffn(p, x, cfg, dt)
        return x, s_new

    h = apply_norm(x, p["ln1"], cfg.norm)
    q, k, v = _proj_qkv(p["attn"], h, cfg, dt)
    pos_arr = jnp.full((1,), position)
    if use_rope:
        q = apply_rope(q, pos_arr, cfg.rope_theta)
        k = apply_rope(k, pos_arr, cfg.rope_theta)
    if kind == "local":
        w = state["k"].shape[1]
        idx = position % w
    else:
        idx = position
    k_cache = jax.lax.dynamic_update_slice_in_dim(state["k"], k.astype(state["k"].dtype), idx, 1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(state["v"], v.astype(state["v"].dtype), idx, 1)
    if kind == "local":
        # ring buffer: all entries valid once warm; mask handled by window
        att = decode_attention(q, k_cache, v_cache,
                               position=jnp.minimum(position, k_cache.shape[1] - 1),
                               window=0)
    else:
        att = decode_attention(q, k_cache, v_cache, position=position)
    b = x.shape[0]
    att = att.reshape(b, 1, cfg.n_heads * cfg.hd) @ p["attn"]["o"].astype(dt)
    if "ob" in p["attn"]:
        att = att + p["attn"]["ob"].astype(dt)
    x = x + att
    new_state = {"k": k_cache, "v": v_cache}

    if kind == "xattn":
        h = apply_norm(x, p["lnx"], cfg.norm)
        hd = cfg.hd
        q = (h @ p["cross"]["q"].astype(dt)).reshape(b, 1, cfg.n_heads, hd)
        if "ck" in state:          # precomputed at prefill
            ck, cv = state["ck"].astype(dt), state["cv"].astype(dt)
            new_state["ck"] = state["ck"]
            new_state["cv"] = state["cv"]
        else:
            assert enc_out is not None
            se = enc_out.shape[1]
            ck = (enc_out @ p["cross"]["k"].astype(dt)).reshape(
                b, se, cfg.n_kv, hd)
            cv = (enc_out @ p["cross"]["v"].astype(dt)).reshape(
                b, se, cfg.n_kv, hd)
        att = decode_attention(q, ck, cv, position=ck.shape[1] - 1)
        x = x + att.reshape(b, 1, cfg.n_heads * hd) @ p["cross"]["o"].astype(dt)

    x, _ = _ffn(p, x, cfg, dt)
    return x, new_state
