from repro.models.config import LMConfig
from repro.models.lm import LM
from repro.models import resnet
