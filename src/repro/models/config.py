"""Architecture configuration for the LM-family model zoo."""
from __future__ import annotations

import dataclasses


def pad_to(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    num_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    family: str = "dense"           # dense | moe | hybrid | ssm | vlm | audio
    head_dim: int | None = None
    qkv_bias: bool = False
    mlp_bias: bool = False          # starcoder2 / whisper style biases
    mlp_gated: bool = True
    act: str = "silu"
    norm: str = "rmsnorm"
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    embed_scale: bool = False       # gemma-family sqrt(d) embedding scaling
    logit_softcap: float = 0.0
    # layer pattern: per-layer kind; None => all "attn"
    # kinds: attn | local | rglru | rwkv
    pattern: tuple | None = None
    window: int = 0                 # local attention window
    # MoE
    moe_experts: int = 0
    moe_topk: int = 0
    moe_capacity: float = 1.25
    # psum router statistics (me, ce) across mesh shards before forming
    # the load-balance aux — the sharded aux then equals the full-batch
    # aux exactly instead of the mean of per-shard auxes (ROADMAP item;
    # the pipeline's PER-MICRO-BATCH deviation remains, see DESIGN.md §6)
    moe_global_aux: bool = False
    # recurrent widths
    lru_width: int | None = None
    conv_width: int = 4
    rwkv_head_dim: int = 64
    rwkv_lora: int = 64
    # enc-dec (whisper)
    enc_layers: int = 0
    enc_seq: int = 0                # stub frontend sequence length (frames)
    # vlm
    num_patches: int = 0            # stub frontend patch tokens
    # vocab padding for sharding (0 = none)
    vocab_pad_multiple: int = 0
    # numerics
    dtype: str = "bfloat16"

    @property
    def padded_vocab(self) -> int:
        if self.vocab_pad_multiple:
            return pad_to(self.vocab, self.vocab_pad_multiple)
        return self.vocab

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def layer_kinds(self) -> tuple:
        if self.pattern is not None:
            assert len(self.pattern) == self.num_layers
            return self.pattern
        return ("attn",) * self.num_layers

    @property
    def homogeneous(self) -> bool:
        kinds = self.layer_kinds
        return all(k == kinds[0] for k in kinds)

    @property
    def is_moe(self) -> bool:
        return self.moe_experts > 0

    @property
    def r_width(self) -> int:
        return self.lru_width or self.d_model

    def param_count(self) -> int:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        d, f, v = self.d_model, self.d_ff, self.padded_vocab
        hd = self.hd
        total = v * d * (1 if self.tie_embeddings else 2)
        for kind in self.layer_kinds:
            if kind in ("attn", "local"):
                total += d * self.n_heads * hd * 2          # q, o
                total += d * self.n_kv * hd * 2             # k, v
            elif kind == "rglru":
                r = self.r_width
                total += 2 * d * r + r * d + self.conv_width * r + 2 * r * r + 2 * r
            elif kind == "rwkv":
                total += 6 * d * d + 2 * self.rwkv_lora * d
            if kind == "rwkv":
                total += d * f * 2 + d * d                   # channel mix
            elif self.is_moe:
                total += d * self.moe_experts + 3 * self.moe_experts * d * f
            else:
                total += d * f * (3 if self.mlp_gated else 2)
        if self.enc_layers:
            total += self.enc_layers * (4 * d * d + 2 * d * f)
        return int(total)
